#!/usr/bin/env python3
"""Bench-regression gate over the BENCH_*.json artifacts.

Run from the repo root after the bench targets have written their
artifacts (CI does this with CIVP_BENCH_QUICK=1). Three layers of checks:

1. **Schema** — every artifact is a non-empty JSON array whose rows carry
   `name`, `ns_per_op_p50` and `ops_per_sec` (replaces the old inline
   heredoc validator in ci.yml).
2. **Baseline regression** — any measurement whose `name` also appears in
   the committed baseline (`BENCH_baseline.json`) fails the gate when its
   `ns_per_op_p50` regresses more than `--tolerance` (default 25%, env
   `CIVP_BENCH_TOLERANCE`) over the baseline value. The committed
   baseline holds deliberately conservative (slow-side) seed numbers so
   the gate is portable across runner hardware; refresh it from a
   representative machine with `--update` after intentional perf changes.
3. **Machine-independent invariants** — relative properties within ONE
   run, so runner speed cancels out. The ratio slacks are deliberately
   loose (gross-inversion detectors, not microbenchmarks) because CI runs
   in quick mode where sub-microsecond p50s are noisy:
   * the pooled-oneshot reply path is not >2x slower than the
     mpsc-channel baseline it replaced;
   * the closed-form `simulate_counts` report is at least 2x faster than
     materializing and replaying the op stream;
   * compiled-plan execution is not >1.25x slower than per-call tile-DAG
     re-derivation for any scheme x precision;
   * the lane-fused batch path is never slower than the per-op path it
     replaced: for every `lanes/<cfg>/lane-path` vs `per-op-path` pair
     and every `lanes/fpu-<prec>/fused-x256` vs `per-op-x256` pair in
     `BENCH_lanes.json`, lane p50 <= per-op p50 (the `bench_lanes`
     acceptance gate);
   * the same lane-vs-per-op invariant holds per registry op class in
     `BENCH_formats.json` (`formats/...` rows) — binary16 and bfloat16
     gate regressions exactly like single/double/quad;
   * the wide-class Karatsuba ablation (`formats/wide-<class>/...` rows):
     for every wide class the `karatsuba-x<N>` batch p50 must not lose to
     its `naive-x<N>` all-pairs sibling, the static `tile-count-karatsuba`
     row must be strictly below `tile-count-naive`, and the karatsuba tile
     count must grow sub-quadratically from fp256 to fp512 (ratio < 4x for
     a 2x width step — the planner's headline claim). These rows depend on
     the batch size and runner, so they are never baselined;
   * the width x ISA ablation matrix (`lanes/simd-<class>/w<W>-<isa>`
     rows): every SIMD-dispatched sweep must have a same-(class, width)
     scalar sibling in the run and must not be slower than it — a
     vectorized kernel that loses to the scalar sweep it replaces fails
     the gate. These rows depend on which ISA the runner offers, so they
     are never baselined;
   * cluster fabric-model aggregate throughput (computed analytically —
     deterministic, machine-independent) increases monotonically with
     the shard count, strictly from 1 to 4 shards (the `bench_cluster`
     scaling acceptance gate);
   * parallel-executor efficiency (`BENCH_parallel.json`): the
     deterministic chunk-plan makespan model's per-op cycles are
     monotonically non-increasing in cores for every batch size, and the
     largest batch reaches >= 2x speedup at 4 cores (the `bench_parallel`
     acceptance gate). The `parallel/wall-*` rows are real wall time and
     are never baselined — CI runners may have fewer cores than workers.
   * network-edge loadgen rows (`net/<mix>/...`, from `civp-server
     loadgen`): latency percentiles in order (p50 <= p99 <= p999), zero
     lost replies, and reply conservation (ok + saturated + other + lost
     == frames sent). Latency/throughput magnitudes are wall time over a
     real socket, so `net/` rows are never baselined;
   * the offered-load sweep knee gate (`net/<mix>/p99@<rate>` rows from
     `civp-server loadgen --sweep`, with `lost@<rate>` count rows and a
     `sweep-workers` row stating the server's connection-worker pool
     size): the knee is the largest swept rate whose prefix of the curve
     keeps p99 within NET_KNEE_SLACK of the sweep's best p99. Every
     swept point must lose zero replies, and the knee must not regress
     below `workers x CIVP_NET_KNEE_FLOOR` req/s — gating knee
     *location* (a machine-independent shape property of one run), never
     absolute latency.

When run with no file arguments (the CI shape), the three artifacts the
bench targets write are REQUIRED to exist, and every baselined
measurement must be present in the run — a renamed or dropped
measurement fails the gate rather than silently disabling it.

Exit status 0 = gate passed, 1 = any check failed.
"""

import argparse
import glob
import json
import math
import os
import re
import sys

DEFAULT_TOLERANCE = 0.25
REQUIRED_KEYS = ("name", "ns_per_op_p50", "ops_per_sec")
REQUIRED_FILES = (
    "BENCH_e2e.json",
    "BENCH_plan.json",
    "BENCH_cluster.json",
    "BENCH_lanes.json",
    "BENCH_formats.json",
    "BENCH_parallel.json",
    "BENCH_net.json",
    "BENCH_net_sweep.json",
)
MODEL_SCALING_RE = re.compile(r"^cluster/mixed/model-scaling-(\d+)shard$")
PARALLEL_SCALING_RE = re.compile(r"^parallel/model-scaling-b(\d+)-(\d+)core$")
# Speedup the largest batch's model row must reach at this core count.
PARALLEL_SPEEDUP_CORES = 4
PARALLEL_MIN_SPEEDUP = 2.0
# Single-shot wall-clock measurements (and the optional pjrt path): too
# machine- and load-dependent to gate against a committed number, and the
# pjrt row does not exist on runners without artifacts. --update never
# writes these into the baseline.
UNBASELINEABLE_RE = re.compile(
    r"^(e2e/|cluster/mixed/wall-|cluster/mixed/policy-|parallel/wall-|lanes/simd-"
    r"|formats/wide-|net/)"
)
# Headroom --update applies on top of the measured p50 so a baseline
# refreshed on a fast machine doesn't fail the 25% gate on a slower one.
UPDATE_SLACK = 2.0

failures = []
notes = []


def fail(msg):
    failures.append(msg)
    print(f"FAIL: {msg}")


def note(msg):
    notes.append(msg)
    print(f"note: {msg}")


def load_rows(path):
    with open(path) as fh:
        rows = json.load(fh)
    if not isinstance(rows, list) or not rows:
        fail(f"{path}: empty or not a JSON array")
        return []
    ok = []
    for row in rows:
        missing = [k for k in REQUIRED_KEYS if k not in row]
        if missing:
            fail(f"{path}: row missing {missing}: {row}")
            continue
        p50 = row["ns_per_op_p50"]
        if not isinstance(p50, (int, float)) or not math.isfinite(p50) or p50 < 0:
            fail(f"{path}: bad ns_per_op_p50 in {row['name']}: {p50!r}")
            continue
        ok.append(row)
    print(f"{path}: {len(ok)} measurements ok")
    return ok


def check_baseline(current, baseline, tolerance, strict):
    gated = 0
    for name, base_p50 in sorted(baseline.items()):
        if name not in current:
            if strict:
                fail(f"baselined measurement `{name}` not produced by this run")
            else:
                note(f"baselined measurement `{name}` not produced by this run")
            continue
        cur = current[name]
        gated += 1
        if base_p50 > 0 and cur > base_p50 * (1.0 + tolerance):
            fail(
                f"`{name}` regressed: {cur:.1f} ns/op vs baseline "
                f"{base_p50:.1f} (+{(cur / base_p50 - 1) * 100:.0f}%, "
                f"tolerance {tolerance * 100:.0f}%)"
            )
    for name in sorted(set(current) - set(baseline)):
        note(f"new measurement (not in baseline): `{name}`")
    print(f"baseline gate: {gated} measurements compared at {tolerance * 100:.0f}% tolerance")


def check_ratio(current, fast, slow, max_ratio, what):
    if fast not in current or slow not in current:
        return
    f, s = current[fast], current[slow]
    if s <= 0:
        return
    if f > s * max_ratio:
        fail(f"{what}: `{fast}` = {f:.1f} ns/op vs `{slow}` = {s:.1f} (ratio {f / s:.2f} > {max_ratio})")
    else:
        print(f"invariant ok: {what} (ratio {f / s:.3f} <= {max_ratio})")


def check_plan_invariants(current):
    before = len(failures)
    for name, p50 in sorted(current.items()):
        m = re.match(r"^plan/(.+)/cached-plan$", name)
        if not m:
            continue
        sibling = f"plan/{m.group(1)}/rederive-per-call"
        if sibling not in current:
            continue
        if p50 > current[sibling] * 1.25:
            fail(
                f"compiled plan slower than re-derivation for {m.group(1)}: "
                f"{p50:.1f} vs {current[sibling]:.1f} ns/op"
            )
    if len(failures) == before:
        print("invariant ok: compiled plans beat per-call derivation everywhere measured")


# Sampling-noise allowance for the lane-vs-per-op gate: the two p50s are
# independently timed medians, so in quick mode on a loaded runner the
# faster side can still measure a few percent high. The real lane
# advantage is >20%, so 5% slack keeps the gate meaningful (any genuine
# inversion still fails) without flaking on scheduler jitter.
LANES_NOISE_SLACK = 1.05


def check_lanes_invariants(current, prefix="lanes"):
    """Lane-fused execution must never lose to the per-op path it replaced.

    Machine-independent: both sides of each pair run in the same process
    on the same operands, so runner speed cancels out. Gate: lane p50 <=
    per-op p50 (modulo LANES_NOISE_SLACK for sampling noise). Applied to
    the `lanes/...` rows and, with prefix="formats", to the per-registry-
    class rows of BENCH_formats.json.
    """
    before = len(failures)
    pairs = 0
    for name, p50 in sorted(current.items()):
        m = re.match(rf"^{prefix}/(.+)/(lane-path|fused-x256)$", name)
        if not m:
            continue
        sibling = "{}/{}/{}".format(
            prefix, m.group(1), "per-op-path" if m.group(2) == "lane-path" else "per-op-x256"
        )
        if sibling not in current:
            fail(f"`{name}` has no per-op sibling `{sibling}` — bench target incomplete?")
            continue
        pairs += 1
        if p50 > current[sibling] * LANES_NOISE_SLACK:
            fail(
                f"lane path slower than per-op path for {prefix}/{m.group(1)}: "
                f"{p50:.1f} vs {current[sibling]:.1f} ns/op"
            )
    if pairs and len(failures) == before:
        print(
            f"invariant ok: {prefix} lane path beats per-op path on all {pairs} measured pairs"
        )


KARATSUBA_ROW_RE = re.compile(r"^formats/wide-([^/]+)/karatsuba-x(\d+)$")
# Quadratic tiling quadruples the tile count when the operand width
# doubles; the karatsuba fp256 -> fp512 step must come in strictly below
# that to certify sub-quadratic growth (3-way recursion predicts ~3.24x).
KARATSUBA_SUBQUADRATIC_RATIO = 4.0


def check_karatsuba_ablation(current):
    """Wide-class planner gate over the `formats/wide-<class>/...` rows.

    Machine-independent: the karatsuba and naive organizations run in the
    same process on the same operand batch, so runner speed cancels out.
    Three properties per run:

    * karatsuba batch p50 <= naive batch p50 (modulo LANES_NOISE_SLACK,
      same rationale as the lane-vs-per-op gate) for every wide class —
      the planner must actually pay for its combine additions;
    * static tile census strictly smaller: `tile-count-karatsuba` <
      `tile-count-naive` per class (the counts ride in ns_per_op_p50 as
      pseudo-measurements written by bench_formats);
    * sub-quadratic growth: the karatsuba tile count may grow by less
      than KARATSUBA_SUBQUADRATIC_RATIO when the significand width
      doubles from fp256 to fp512.
    """
    before = len(failures)
    classes = []
    for name, p50 in sorted(current.items()):
        m = KARATSUBA_ROW_RE.match(name)
        if not m:
            continue
        cls, batch = m.group(1), m.group(2)
        classes.append(cls)
        sibling = f"formats/wide-{cls}/naive-x{batch}"
        if sibling not in current:
            fail(f"`{name}` has no naive sibling `{sibling}` — bench target incomplete?")
            continue
        if p50 > current[sibling] * LANES_NOISE_SLACK:
            fail(
                f"karatsuba batch slower than naive all-pairs for wide-{cls}: "
                f"{p50:.1f} vs {current[sibling]:.1f} ns/op"
            )
    if not classes:
        return
    tiles = {}
    for cls in classes:
        kara = current.get(f"formats/wide-{cls}/tile-count-karatsuba")
        naive = current.get(f"formats/wide-{cls}/tile-count-naive")
        if kara is None or naive is None:
            fail(f"wide-{cls}: tile-count rows missing from the run")
            continue
        tiles[cls] = kara
        if not kara < naive:
            fail(
                f"karatsuba tile count not below naive for wide-{cls}: "
                f"{kara:.0f} vs {naive:.0f} tiles/mul"
            )
    if "fp256" in tiles and "fp512" in tiles and tiles["fp256"] > 0:
        ratio = tiles["fp512"] / tiles["fp256"]
        if ratio >= KARATSUBA_SUBQUADRATIC_RATIO:
            fail(
                f"karatsuba tile growth fp256 -> fp512 is {ratio:.2f}x >= "
                f"{KARATSUBA_SUBQUADRATIC_RATIO:g}x — not sub-quadratic"
            )
    elif classes:
        fail("karatsuba ablation present but missing the fp256 or fp512 tile-count rows")
    if len(failures) == before:
        print(
            f"invariant ok: karatsuba beats naive tiling on {len(classes)} wide class(es), "
            f"tile growth sub-quadratic"
        )


SIMD_ROW_RE = re.compile(r"^lanes/simd-(.+)/(w\d+)-(\w+)$")


def check_simd_invariants(current):
    """SIMD sweeps must never lose to the same-width scalar sweep.

    The ablation matrix rows are `lanes/simd-<class>/w<W>-<isa>`. The
    scalar row per (class, width) always exists (the scalar ISA is
    unconditionally available); any other ISA row was runtime-dispatched
    on this runner, so both sides ran in the same process on the same
    operands and runner speed cancels out. Gate: simd p50 <= scalar p50
    (modulo LANES_NOISE_SLACK, same rationale as the lane-vs-per-op
    gate).
    """
    before = len(failures)
    pairs = 0
    for name, p50 in sorted(current.items()):
        m = SIMD_ROW_RE.match(name)
        if not m or m.group(3) == "scalar":
            continue
        sibling = f"lanes/simd-{m.group(1)}/{m.group(2)}-scalar"
        if sibling not in current:
            fail(f"`{name}` has no scalar sibling `{sibling}` — bench target incomplete?")
            continue
        pairs += 1
        if p50 > current[sibling] * LANES_NOISE_SLACK:
            fail(
                f"simd sweep slower than scalar for {m.group(1)} {m.group(2)}-{m.group(3)}: "
                f"{p50:.1f} vs {current[sibling]:.1f} ns/op"
            )
    if pairs and len(failures) == before:
        print(f"invariant ok: simd sweeps beat same-width scalar on all {pairs} measured rows")


def check_cluster_scaling(current):
    before = len(failures)
    points = []
    for name, row in current.items():
        m = MODEL_SCALING_RE.match(name)
        if m:
            points.append((int(m.group(1)), row))
    if not points:
        return
    points.sort()
    ops = {n: (1e9 / p50 if p50 > 0 else float("inf")) for n, p50 in points}
    prev_n, prev = points[0][0], ops[points[0][0]]
    for n, _ in points[1:]:
        if ops[n] < prev:
            fail(
                f"cluster model scaling not monotonic: {n} shards = {ops[n]:.0f} ops/s "
                f"< {prev_n} shards = {prev:.0f} ops/s"
            )
        prev_n, prev = n, ops[n]
    if 1 in ops and 4 in ops and not ops[4] > ops[1]:
        fail(
            f"cluster aggregate throughput must increase strictly from 1 shard "
            f"({ops[1]:.0f} ops/s) to 4 shards ({ops[4]:.0f} ops/s)"
        )
    curve = "  ".join(f"{n}sh={ops[n]:.0f}/s" for n, _ in points)
    status = "ok" if len(failures) == before else "VIOLATED"
    print(f"cluster scaling ({status}): {curve}")


def check_parallel_scaling(current):
    """Parallel-efficiency gate over the deterministic makespan model.

    For every batch size: per-op model cycles must be monotonically
    non-increasing as cores grow (adding cores never loses throughput in
    the ideal model — a violation means the chunk split stopped
    spreading). For the largest batch: >= PARALLEL_MIN_SPEEDUP speedup at
    PARALLEL_SPEEDUP_CORES cores, pinning that big batches actually split
    into enough chunks to occupy a multi-core pool.
    """
    before = len(failures)
    curves = {}
    for name, p50 in current.items():
        m = PARALLEL_SCALING_RE.match(name)
        if m:
            curves.setdefault(int(m.group(1)), []).append((int(m.group(2)), p50))
    if not curves:
        return
    for batch, points in sorted(curves.items()):
        points.sort()
        prev_c, prev = points[0]
        for cores, p50 in points[1:]:
            if p50 > prev:
                fail(
                    f"parallel model not monotonic for b{batch}: {cores} cores = "
                    f"{p50:.1f} ns/op > {prev_c} cores = {prev:.1f} ns/op"
                )
            prev_c, prev = cores, p50
    largest = max(curves)
    by_cores = dict(curves[largest])
    if 1 in by_cores and PARALLEL_SPEEDUP_CORES in by_cores:
        speedup = by_cores[1] / by_cores[PARALLEL_SPEEDUP_CORES]
        if speedup < PARALLEL_MIN_SPEEDUP:
            fail(
                f"parallel speedup at {PARALLEL_SPEEDUP_CORES} cores on b{largest} is "
                f"{speedup:.2f}x < required {PARALLEL_MIN_SPEEDUP}x"
            )
    else:
        fail(
            f"parallel model rows for b{largest} missing the 1-core or "
            f"{PARALLEL_SPEEDUP_CORES}-core point"
        )
    status = "ok" if len(failures) == before else "VIOLATED"
    curve = "  ".join(
        f"b{b}:{dict(pts)[1] / min(p for _, p in pts):.1f}x" for b, pts in sorted(curves.items())
        if 1 in dict(pts)
    )
    print(f"parallel scaling ({status}): best speedups {curve}")


NET_LATENCY_RE = re.compile(r"^net/([^/]+)/latency-p50$")
# Count rows emitted by the load generator, carrying their count in
# `total_ops` (latencies zeroed): conservation can be checked without
# parsing row names beyond the suffix.
NET_COUNT_SUFFIXES = ("frames-sent", "replies-ok", "replies-saturated", "replies-other", "lost")


def check_net_invariants(current, totals):
    """Machine-independent gates over the loadgen rows (`net/<mix>/...`).

    Latency and throughput magnitudes are runner-dependent (never
    baselined), but three properties hold on any machine:

    * percentile ordering: p50 <= p99 <= p999 within one run;
    * zero lost replies: every frame the generator sent was answered
      (a lost reply means the server dropped a connection instead of
      answering with a status code);
    * reply conservation: ok + saturated + other + lost == frames sent —
      `Saturated` is an answered admission outcome, so saturation shifts
      replies between statuses without changing the total.
    """
    before = len(failures)
    mixes = sorted(m.group(1) for m in filter(None, map(NET_LATENCY_RE.match, current)))
    for mix in mixes:
        prefix = f"net/{mix}"
        p50 = current.get(f"{prefix}/latency-p50")
        p99 = current.get(f"{prefix}/latency-p99")
        p999 = current.get(f"{prefix}/latency-p999")
        if None in (p99, p999):
            fail(f"{prefix}: latency-p50 present but p99/p999 missing")
            continue
        if not p50 <= p99 <= p999:
            fail(
                f"{prefix}: latency percentiles out of order: "
                f"p50={p50:.0f} p99={p99:.0f} p999={p999:.0f} ns"
            )
        counts = {}
        for suffix in NET_COUNT_SUFFIXES:
            name = f"{prefix}/{suffix}"
            if name not in totals:
                fail(f"{prefix}: count row `{suffix}` missing")
                break
            counts[suffix] = totals[name]
        if len(counts) != len(NET_COUNT_SUFFIXES):
            continue
        if counts["lost"] != 0:
            fail(f"{prefix}: {counts['lost']} lost replies (must be 0)")
        answered = sum(counts[s] for s in NET_COUNT_SUFFIXES if s != "frames-sent")
        if answered != counts["frames-sent"]:
            fail(
                f"{prefix}: replies not conserved: ok+saturated+other+lost = {answered} "
                f"!= frames-sent = {counts['frames-sent']}"
            )
        if counts["frames-sent"] == 0:
            fail(f"{prefix}: loadgen sent no frames")
    if mixes and len(failures) == before:
        print(f"invariant ok: net percentile order + reply conservation over {len(mixes)} mix(es)")


NET_SWEEP_P99_RE = re.compile(r"^net/([^/]+)/p99@([0-9.]+)$")
# p99 at a swept rate at-or-below the knee may exceed the sweep's best
# p99 by at most this factor; the first rate whose curve prefix breaks
# it is past the knee.
NET_KNEE_SLACK = float(os.environ.get("CIVP_NET_KNEE_SLACK", 3.0))
# The knee must sit at or above this many req/s per connection worker —
# the regression contract is knee *location* relative to the pool size,
# not absolute throughput.
NET_KNEE_FLOOR_PER_WORKER = float(os.environ.get("CIVP_NET_KNEE_FLOOR", 50.0))


def check_net_knee(current, totals):
    """Knee-location gate over the offered-load sweep rows.

    For each mix with `net/<mix>/p99@<rate>` rows: sort points by rate,
    take the sweep's best (minimum) p99 as the flat-region reference,
    and walk the curve upward — the knee is the last rate whose entire
    prefix keeps p99 within NET_KNEE_SLACK of that best. Gates:

    * every swept point has a `lost@<rate>` row equal to 0 (the sweep
      is closed-loop, so a lost reply is a server drop, not overload);
    * a `sweep-workers` count row states the server's pool size;
    * some rate qualifies as the knee at all (a curve that blows up
      immediately means the edge lost its flat region);
    * knee_rate >= workers x NET_KNEE_FLOOR_PER_WORKER — the knee may
      not regress below what the worker pool is sized to absorb.

    Both sides of every comparison come from one run on one machine, so
    runner speed cancels out: only the curve's *shape* is gated.
    """
    sweeps = {}
    for name, p50 in current.items():
        m = NET_SWEEP_P99_RE.match(name)
        if m:
            sweeps.setdefault(m.group(1), []).append((float(m.group(2)), m.group(2), p50))
    if not sweeps:
        return
    for mix, points in sorted(sweeps.items()):
        points.sort()
        prefix = f"net/{mix}"
        workers = totals.get(f"{prefix}/sweep-workers")
        if not workers:
            fail(f"{prefix}: sweep rows present but the `sweep-workers` row is missing")
            continue
        bad = False
        for _rate, label, _p99 in points:
            lost = totals.get(f"{prefix}/lost@{label}")
            if lost is None:
                fail(f"{prefix}: swept rate {label} has no `lost@{label}` row")
                bad = True
            elif lost != 0:
                fail(f"{prefix}: {lost} lost replies at swept rate {label} (must be 0)")
                bad = True
        if bad:
            continue
        min_p99 = min(p99 for _, _, p99 in points)
        if min_p99 <= 0:
            fail(f"{prefix}: degenerate sweep (best p99 = {min_p99})")
            continue
        knee = None
        for rate, _label, p99 in points:
            if p99 <= min_p99 * NET_KNEE_SLACK:
                knee = rate
            else:
                break
        if knee is None:
            fail(
                f"{prefix}: no swept rate keeps p99 within {NET_KNEE_SLACK:g}x of the "
                f"best ({min_p99:.0f} ns) — the curve has no flat region"
            )
            continue
        floor = workers * NET_KNEE_FLOOR_PER_WORKER
        curve = "  ".join(f"{label}:{p99:.0f}ns" for _, label, p99 in points)
        if knee < floor:
            fail(
                f"{prefix}: knee at {knee:g} req/s is below the floor {floor:g} "
                f"({workers:g} workers x {NET_KNEE_FLOOR_PER_WORKER:g} req/s) [{curve}]"
            )
        else:
            print(f"net knee ok ({mix}): knee @ {knee:g} req/s >= floor {floor:g} [{curve}]")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", help="BENCH_*.json artifacts (default: glob repo root)")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=float(os.environ.get("CIVP_BENCH_TOLERANCE", DEFAULT_TOLERANCE)),
        help="allowed fractional p50 regression vs baseline (default 0.25)",
    )
    ap.add_argument(
        "--update",
        action="store_true",
        help="rewrite the baseline from the current artifacts instead of gating",
    )
    args = ap.parse_args()

    strict = not args.files
    files = args.files or sorted(
        f
        for f in glob.glob("BENCH_*.json")
        if os.path.basename(f) != os.path.basename(args.baseline)
    )
    if not files:
        fail("no BENCH_*.json artifacts found — did the benches run?")
        return 1
    if strict:
        for required in REQUIRED_FILES:
            if required not in files:
                fail(f"required artifact {required} missing — did its bench target run?")

    current = {}
    totals = {}
    for path in files:
        for row in load_rows(path):
            current[row["name"]] = row["ns_per_op_p50"]
            totals[row["name"]] = row.get("total_ops", 0)

    if args.update:
        rows = [
            {"name": name, "ns_per_op_p50": round(p50 * UPDATE_SLACK, 3)}
            for name, p50 in sorted(current.items())
            if not UNBASELINEABLE_RE.match(name)
        ]
        if rows:
            rows[0]["note"] = (
                f"written by check_bench.py --update with {UPDATE_SLACK}x slack over the "
                "measured p50s; wall-clock e2e/cluster-wall/policy rows are never baselined"
            )
        with open(args.baseline, "w") as fh:
            json.dump(rows, fh, indent=2)
            fh.write("\n")
        skipped = len(current) - len(rows)
        print(f"wrote {args.baseline} ({len(rows)} measurements, {skipped} wall-clock rows skipped)")
        return 0

    baseline = {}
    if os.path.exists(args.baseline):
        with open(args.baseline) as fh:
            for row in json.load(fh):
                baseline[row["name"]] = row["ns_per_op_p50"]
    else:
        note(f"{args.baseline} not found — skipping the baseline gate")

    if baseline:
        check_baseline(current, baseline, args.tolerance, strict)
    check_ratio(
        current,
        "reply/pooled-oneshot",
        "reply/mpsc-channel-pre-pr",
        2.0,
        "pooled oneshot reply path vs per-request mpsc channel",
    )
    check_ratio(
        current,
        "fabric-report/simulate-counts",
        "fabric-report/replay-stream-pre-pr",
        0.5,
        "closed-form fabric report vs materialized stream replay",
    )
    check_plan_invariants(current)
    check_lanes_invariants(current)
    check_lanes_invariants(current, prefix="formats")
    check_karatsuba_ablation(current)
    check_simd_invariants(current)
    check_cluster_scaling(current)
    check_parallel_scaling(current)
    check_net_invariants(current, totals)
    check_net_knee(current, totals)

    if failures:
        print(f"\nbench gate FAILED: {len(failures)} failure(s)")
        return 1
    print(f"\nbench gate passed: {len(current)} measurements, {len(notes)} note(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
