#!/usr/bin/env python3
"""Recompute the deterministic model-scaling baseline rows offline.

Two families of `BENCH_baseline.json` rows are *models*, not wall-clock
measurements — they depend only on committed constants, so their exact
values can be reproduced without a Rust toolchain:

* `cluster/mixed/model-scaling-{n}shard` — `benches/bench_cluster.rs`:
  the per-class op counts of the seeded mixed trace (`TraceGen::new(0xC1,
  Mixed, 0)`, 40 000 requests in full mode) split evenly across `n`
  one-column CIVP fabrics, each run through the closed-form
  `simulate_counts` schedule, aggregated with makespan semantics at
  1 GHz.
* `parallel/model-scaling-b{N}-{c}core` — `benches/bench_parallel.rs`:
  the chunk-plan makespan model over the executor's actual block-aligned
  split (`chunk_plan(full, cores, LANES)`), 9 tiles per double multiply.

This script reimplements both models bit-for-bit (SplitMix64 stream,
draw-for-draw trace generation, the same integer schedule arithmetic) and
rewrites those rows in the baseline with the same `UPDATE_SLACK` headroom
`check_bench.py --update` applies. It also simulates the CI quick-mode
run (`scaled(40_000)` = 800 requests) and asserts the quick values pass
the gate tolerance against the refreshed baseline, so a refresh can never
land a row that CI immediately fails.

Usage:
    python3 python/tools/seed_model_baseline.py           # report only
    python3 python/tools/seed_model_baseline.py --write   # update baseline

Keep the constants below in sync with their Rust sources (each block
cites its origin); `test_check_bench.py` does not cover this script, but
a drifted constant shows up as a baseline-gate failure in the first CI
run after the Rust side changes.
"""

import argparse
import json
import math
import sys
from pathlib import Path

MASK64 = (1 << 64) - 1

# check_bench.py --update conventions.
UPDATE_SLACK = 2.0
GATE_TOLERANCE = 0.25

# trace::TraceGen seed and request counts (benches/bench_cluster.rs).
TRACE_SEED = 0xC1
FULL_REQUESTS = 40_000
QUICK_REQUESTS = FULL_REQUESTS // 50  # benchx::scaled under CIVP_BENCH_QUICK
SHARD_COUNTS = (1, 2, 4, 8)

# decomp::OpClass::ALL order (drives WorkloadMix::pick's cumulative walk).
CLASSES = ("bf16", "half", "single", "double", "quad")

# trace::WorkloadSpec::Mixed.mix().
MIXED_WEIGHTS = {"bf16": 0.15, "half": 0.10, "single": 0.35, "double": 0.25, "quad": 0.15}

# fpu::FpFormat frac_bits per class — fixes the number of RNG draws one
# operand consumes in TraceGen::operand (1 exponent + 1-or-2 fraction +
# 1 sign).
FRAC_BITS = {"bf16": 7, "half": 10, "single": 23, "double": 52, "quad": 112}

# CIVP tile multiset per class (decomp::Scheme::tiles with the civp chunk
# table: bf16=[9]x[9], half=[11]x[9,2], single=[24]x[24],
# double=[24,24,9]^2, quad=[24,24,9,24,24,9]^2; smallest fitting block).
TILE_NEED = {
    "bf16": {"9x9": 1},
    "half": {"24x9": 2},
    "single": {"24x24": 1},
    "double": {"24x24": 4, "24x9": 4, "9x9": 1},
    "quad": {"24x24": 16, "24x9": 16, "9x9": 4},
}

# fabric::FabricConfig::civp_scaled(1) instance counts.
FABRIC = {"24x24": 16, "24x9": 16, "9x9": 4}

# fabric::CostModel::default latency constants.
BLOCK_LATENCY = 2
ADDER_LEVEL_LATENCY = 1

# decomp::parallel chunk-plan constants (LANES = default W8 lane width).
LANES = 8
MIN_CHUNK_BLOCKS = 4
CHUNKS_PER_WORKER = 4
PAR_THRESHOLD = 256  # benches/bench_parallel.rs THRESHOLD
PARALLEL_SIZES = (128, 1024, 8192)
PARALLEL_CORES = (1, 2, 4, 8)
DOUBLE_TILES = 9  # CIVP double = [24,24,9] x [24,24,9]


class SplitMix64:
    """proput::Rng — SplitMix64, same stream for the same seed."""

    GAMMA = 0x9E3779B97F4A7C15

    def __init__(self, seed):
        self.state = (seed + self.GAMMA) & MASK64

    def next_u64(self):
        self.state = (self.state + self.GAMMA) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def below(self, n):
        return (self.next_u64() * n) >> 64

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


def pick_class(u):
    """WorkloadMix::pick over the Mixed weights in registry order."""
    total = sum(MIXED_WEIGHTS.values())
    acc = 0.0
    for cls in CLASSES:
        acc += MIXED_WEIGHTS[cls] / total
        if u < acc:
            return cls
    return CLASSES[-1]


def trace_counts(n_requests):
    """Per-class counts of TraceGen::new(0xC1, Mixed, 0).take(n).

    Only the class sequence matters for the model, but the RNG must
    advance exactly as TraceGen::operand does: per operand one `below`
    for the biased exponent, one `next_u64` per 64 fraction bits (quad's
    112-bit fraction takes two), one `below(2)` for the sign.
    """
    rng = SplitMix64(TRACE_SEED)
    counts = dict.fromkeys(CLASSES, 0)
    for _ in range(n_requests):
        cls = pick_class(rng.f64())
        counts[cls] += 1
        draws_per_operand = 2 + (2 if FRAC_BITS[cls] > 64 else 1)
        for _ in range(2 * draws_per_operand):
            rng.next_u64()
    return counts


def class_latency(cls):
    """schedule_op latency on civp_scaled(1): waves-1 + block pipeline +
    ceil(log2 tiles) adder levels (waves = 1 for every class on one
    column)."""
    need = TILE_NEED[cls]
    waves = max(-(-n // FABRIC[k]) for k, n in need.items())
    tiles = sum(need.values())
    depth = 0 if tiles <= 1 else (tiles - 1).bit_length()
    return waves - 1 + BLOCK_LATENCY + ADDER_LEVEL_LATENCY * depth


def shard_cycles(share):
    """simulate_counts cycles for one shard's per-class counts."""
    cycles = 0
    last_latency = 0
    for cls in CLASSES:
        count = share.get(cls, 0)
        if count == 0:
            continue
        issue = max(1, max(-(-(count * n) // FABRIC[k]) for k, n in TILE_NEED[cls].items()))
        cycles += issue
        last_latency = max(last_latency, class_latency(cls))
    return cycles + last_latency


def cluster_model_rows(n_requests):
    """bench_cluster model_scaling: even split, makespan aggregate, 1 GHz."""
    counts = trace_counts(n_requests)
    rows = {}
    for shards in SHARD_COUNTS:
        wall = 0
        total = 0
        for shard in range(shards):
            share = {
                cls: c // shards + (1 if shard < c % shards else 0) for cls, c in counts.items()
            }
            if not any(share.values()):
                continue
            wall = max(wall, shard_cycles(share))
            total += sum(share.values())
        rows[f"cluster/mixed/model-scaling-{shards}shard"] = wall / max(total, 1)
    return counts, rows


def chunk_plan(full, workers, block):
    """decomp::parallel::chunk_plan — block-aligned split."""
    min_chunk = MIN_CHUNK_BLOCKS * block
    if full == 0:
        return (min_chunk, 0)
    target = max(full // (max(workers, 1) * CHUNKS_PER_WORKER), min_chunk)
    chunk = -(-target // block) * block
    return (chunk, -(-full // chunk))


def parallel_model_rows():
    """bench_parallel model_row over every (batch, cores) point."""
    rows = {}
    for n in PARALLEL_SIZES:
        full = n - n % LANES
        tail = n - full
        for cores in PARALLEL_CORES:
            chunk, n_chunks = chunk_plan(full, cores, LANES)
            if n < PAR_THRESHOLD or n_chunks < 2:
                slots = n
            else:
                slots = -(-n_chunks // cores) * chunk + tail
            rows[f"parallel/model-scaling-b{n}-{cores}core"] = slots * DOUBLE_TILES / n
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument(
        "--write", action="store_true", help="rewrite the model rows in the baseline file"
    )
    args = ap.parse_args()

    full_counts, full_rows = cluster_model_rows(FULL_REQUESTS)
    _, quick_rows = cluster_model_rows(QUICK_REQUESTS)
    par_rows = parallel_model_rows()

    print(f"mixed trace class counts @ {FULL_REQUESTS} requests: {full_counts}")
    print(f"{'row':<44} {'full ns/op':>12} {'quick ns/op':>12} {'baseline':>10}")
    model = {}
    ok = True
    for name in sorted(full_rows):
        base = round(full_rows[name] * UPDATE_SLACK, 3)
        model[name] = base
        quick = quick_rows[name]
        gate_ok = quick <= base * (1.0 + GATE_TOLERANCE)
        ok &= gate_ok
        print(
            f"{name:<44} {full_rows[name]:>12.6f} {quick:>12.6f} {base:>10.3f}"
            f"{'' if gate_ok else '  << quick run would FAIL the gate'}"
        )
    for name in sorted(par_rows):
        base = round(par_rows[name] * UPDATE_SLACK, 3)
        model[name] = base
        # The parallel model is request-count independent (same split in
        # quick mode), so the gate check is the model itself.
        print(f"{name:<44} {par_rows[name]:>12.6f} {par_rows[name]:>12.6f} {base:>10.3f}")
    if not ok:
        print("refusing: quick-mode values exceed the gate tolerance", file=sys.stderr)
        return 1

    # The cluster curve must satisfy check_cluster_scaling on both modes.
    for label, rows in (("full", full_rows), ("quick", quick_rows)):
        ops = [1e9 / rows[f"cluster/mixed/model-scaling-{n}shard"] for n in SHARD_COUNTS]
        assert all(b >= a for a, b in zip(ops, ops[1:])), f"{label} curve not monotonic"
        assert ops[2] > ops[0], f"{label} curve not strict 1->4"

    if not args.write:
        print("\ndry run — pass --write to update the baseline")
        return 0

    path = Path(args.baseline)
    rows = json.loads(path.read_text())
    replaced = 0
    for row in rows:
        if row["name"] in model:
            row["ns_per_op_p50"] = model.pop(row["name"])
            replaced += 1
    for name, p50 in sorted(model.items()):
        rows.append({"name": name, "ns_per_op_p50": p50})
    rows.sort(key=lambda r: r["name"])
    path.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"\nwrote {path}: {replaced} rows refreshed, {len(model)} added")
    return 0


if __name__ == "__main__":
    sys.exit(main())
