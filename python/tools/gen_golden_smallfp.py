"""Generate binary16 / bfloat16 multiplication golden vectors with
pure-integer math.

Sibling of `gen_golden_fp128.py`, generalized over (exp_bits, frac_bits):
an independent oracle for the Rust softfloat's sub-single registry classes
— no code shared with the Rust pipeline. Output is Rust array literals
pasted into `rust/src/fpu/golden.rs`.
"""
import random


class Fmt:
    def __init__(self, tag, exp_bits, frac_bits):
        self.tag = tag
        self.exp_bits = exp_bits
        self.frac_bits = frac_bits
        self.bias = (1 << (exp_bits - 1)) - 1
        self.emin = 1 - self.bias
        self.emax = self.bias
        self.exp_mask = (1 << exp_bits) - 1
        self.total = 1 + exp_bits + frac_bits

    def unpack(self, bits):
        sign = bits >> (self.total - 1)
        biased = (bits >> self.frac_bits) & self.exp_mask
        frac = bits & ((1 << self.frac_bits) - 1)
        if biased == self.exp_mask:
            return (sign, 'nan' if frac else 'inf', 0, 0)
        if biased == 0:
            if frac == 0:
                return (sign, 'zero', 0, 0)
            return (sign, 'fin', self.emin, frac)  # subnormal, no hidden bit
        return (sign, 'fin', biased - self.bias, frac | (1 << self.frac_bits))

    def mul_mode(self, a_bits, b_bits, mode):
        """IEEE multiply under any rounding-direction attribute.

        mode: 'rne' | 'rna' | 'rtz' | 'rup' | 'rdn'
        """
        f = self.frac_bits
        sa, ca, ea, ma = self.unpack(a_bits)
        sb, cb, eb, mb = self.unpack(b_bits)
        sign = sa ^ sb
        qnan = (self.exp_mask << f) | (1 << (f - 1))
        inf = self.exp_mask << f
        top_bit = self.total - 1
        if ca == 'nan' or cb == 'nan':
            return qnan
        if (ca == 'inf' and cb == 'zero') or (ca == 'zero' and cb == 'inf'):
            return qnan
        if ca == 'inf' or cb == 'inf':
            return (sign << top_bit) | inf
        if ca == 'zero' or cb == 'zero':
            return sign << top_bit
        while ma < (1 << f):
            ma <<= 1
            ea -= 1
        while mb < (1 << f):
            mb <<= 1
            eb -= 1
        prod = ma * mb
        top = prod.bit_length() - 1
        exp = ea + eb + (top - 2 * f)
        shift = top - f
        if exp < self.emin:
            shift += self.emin - exp
            exp = self.emin
        kept = prod >> shift
        rem = prod & ((1 << shift) - 1) if shift > 0 else 0
        half = 1 << (shift - 1) if shift > 0 else 0
        inc = False
        if rem:
            if mode == 'rne':
                inc = rem > half or (rem == half and kept & 1)
            elif mode == 'rna':
                inc = rem >= half
            elif mode == 'rtz':
                inc = False
            elif mode == 'rup':
                inc = sign == 0
            elif mode == 'rdn':
                inc = sign == 1
        if inc:
            kept += 1
        if kept.bit_length() > f + 1:
            kept >>= 1
            exp += 1
        if exp > self.emax:
            to_inf = mode in ('rne', 'rna') or (mode == 'rup' and sign == 0) or (
                mode == 'rdn' and sign == 1)
            if to_inf:
                return (sign << top_bit) | inf
            return (sign << top_bit) | ((self.exp_mask - 1) << f) | ((1 << f) - 1)
        if kept == 0:
            return sign << top_bit
        if kept < (1 << f):
            return (sign << top_bit) | kept  # subnormal (exp == emin)
        return (sign << top_bit) | ((exp + self.bias) << f) | (kept - (1 << f))

    def rand_bits(self, rng):
        f = self.frac_bits
        kind = rng.randrange(8)
        if kind == 0:
            return rng.getrandbits(self.total)
        if kind == 1:
            return rng.getrandbits(f)  # subnormal
        if kind == 2:  # near overflow
            return ((self.exp_mask - 1 - rng.randrange(3)) << f) | rng.getrandbits(f)
        if kind == 3:  # near underflow
            return ((1 + rng.randrange(3)) << f) | rng.getrandbits(f)
        if kind == 4:  # all-ones significand
            return (rng.randrange(self.exp_mask) << f) | ((1 << f) - 1)
        if kind == 5:  # power of two
            return rng.randrange(self.exp_mask) << f
        if kind == 6:  # sparse significand
            return (rng.randrange(self.exp_mask) << f) | (1 << rng.randrange(f))
        return rng.getrandbits(self.total) | (1 << (self.total - 1))  # negative


MODES = ['rne', 'rna', 'rtz', 'rup', 'rdn']


def exact_tie_case(fmt):
    """Smallest normal pair whose product is an exact round-bit tie with an
    *even* kept significand — the one case where NearestEven (stay) and
    NearestAway (up) give different answers, so the all-modes vectors can
    catch an RNA tie-handling regression."""
    f = fmt.frac_bits
    for sa in range(1 << f, (1 << (f + 1))):
        a = (fmt.bias << f) | (sa - (1 << f))
        for sb in range(1 << f, (1 << (f + 1))):
            prod = sa * sb
            top = prod.bit_length() - 1
            shift = top - f
            if shift <= 0:
                continue
            kept = prod >> shift
            rem = prod & ((1 << shift) - 1)
            if rem == 1 << (shift - 1) and kept % 2 == 0:
                b = (fmt.bias << f) | (sb - (1 << f))
                assert fmt.mul_mode(a, b, 'rne') != fmt.mul_mode(a, b, 'rna')
                return (a, b)
    raise AssertionError("no exact tie with even kept significand found")


def emit(fmt, seed):
    rng = random.Random(seed)
    f = fmt.frac_bits
    one = fmt.bias << f
    max_finite = ((fmt.exp_mask - 1) << f) | ((1 << f) - 1)
    directed = [
        (one, one),
        (one, 1),  # 1 * min_subnormal
        ((1 << f) - 1, (1 << f) - 1),  # max subnormal^2 -> 0
        (max_finite, max_finite),  # max_finite^2 -> overflow
        ((fmt.bias - 1) << f, 1 << f),  # 0.5 * min_normal
        (one | ((1 << f) - 1), one | ((1 << f) - 1)),  # (2-ulp)^2 round
        exact_tie_case(fmt),  # RNE stays even, RNA rounds away
    ]
    cases = [(a, b, fmt.mul_mode(a, b, 'rne')) for a, b in directed]
    while len(cases) < 64:
        a, b = fmt.rand_bits(rng), fmt.rand_bits(rng)
        cases.append((a, b, fmt.mul_mode(a, b, 'rne')))
    tag = fmt.tag
    print(f"pub const GOLDEN_{tag}_MUL_RNE: &[(u16, u16, u16)] = &[")
    for a, b, r in cases:
        print(f"    ({a:#06x}, {b:#06x}, {r:#06x}),")
    print("];")
    print()
    # mode order matches RoundMode::ALL = [NearestEven, NearestAway,
    # TowardZero, TowardPositive, TowardNegative]
    print(f"pub const GOLDEN_{tag}_MUL_MODES: &[(u8, u16, u16, u16)] = &[")
    for mi, mode in enumerate(MODES):
        for a, b, _ in cases[:24]:
            r = fmt.mul_mode(a, b, mode)
            print(f"    ({mi}, {a:#06x}, {b:#06x}, {r:#06x}),")
    print("];")


def main():
    print("// @generated by python/tools/gen_golden_smallfp.py — do not edit.")
    emit(Fmt('FP16', exp_bits=5, frac_bits=10), seed=20260729)
    print()
    emit(Fmt('BF16', exp_bits=8, frac_bits=7), seed=20260730)


if __name__ == "__main__":
    main()
