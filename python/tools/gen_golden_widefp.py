"""Generate Fp256/Fp512 multiplication golden vectors with pure-integer math.

Independent oracle for the Rust softfloat's wide (tree-path) pipeline:
the same IEEE-754 multiply model as `gen_golden_fp128.py`, generalized
over the format geometry and instantiated for the two extended registry
classes (fp256: 19/236, fp512: 23/488). No code shared with the Rust
pipeline. Output is Rust array literals pasted into
`rust/src/fpu/golden.rs`; operands are hex strings because Rust has no
integer literal wide enough to hold them (`Wide::from_hex` parses them
back at test time).
"""
import random


class Fmt:
    def __init__(self, name, exp_bits, frac_bits):
        self.name = name
        self.exp_bits = exp_bits
        self.frac_bits = frac_bits
        self.total = 1 + exp_bits + frac_bits
        self.bias = (1 << (exp_bits - 1)) - 1
        self.emin = 1 - self.bias
        self.emax = self.bias
        self.exp_mask = (1 << exp_bits) - 1


FP256 = Fmt("FP256", 19, 236)
FP512 = Fmt("FP512", 23, 488)


def unpack(f, bits):
    sign = bits >> (f.total - 1)
    biased = (bits >> f.frac_bits) & f.exp_mask
    frac = bits & ((1 << f.frac_bits) - 1)
    if biased == f.exp_mask:
        return (sign, 'nan' if frac else 'inf', 0, 0)
    if biased == 0:
        if frac == 0:
            return (sign, 'zero', 0, 0)
        return (sign, 'fin', f.emin, frac)  # subnormal, no hidden bit
    return (sign, 'fin', biased - f.bias, frac | (1 << f.frac_bits))


def mul_mode(f, a_bits, b_bits, mode):
    """IEEE multiply in format `f` under any rounding-direction attribute.

    mode: 'rne' | 'rna' | 'rtz' | 'rup' | 'rdn'
    """
    sa, ca, ea, ma = unpack(f, a_bits)
    sb, cb, eb, mb = unpack(f, b_bits)
    sign = sa ^ sb
    sign_shift = f.total - 1
    QNAN = (f.exp_mask << f.frac_bits) | (1 << (f.frac_bits - 1))
    INF = f.exp_mask << f.frac_bits
    if ca == 'nan' or cb == 'nan':
        return QNAN
    if (ca == 'inf' and cb == 'zero') or (ca == 'zero' and cb == 'inf'):
        return QNAN
    if ca == 'inf' or cb == 'inf':
        return (sign << sign_shift) | INF
    if ca == 'zero' or cb == 'zero':
        return sign << sign_shift
    while ma < (1 << f.frac_bits):
        ma <<= 1
        ea -= 1
    while mb < (1 << f.frac_bits):
        mb <<= 1
        eb -= 1
    prod = ma * mb
    top = prod.bit_length() - 1
    exp = ea + eb + (top - 2 * f.frac_bits)
    shift = top - f.frac_bits
    if exp < f.emin:
        shift += f.emin - exp
        exp = f.emin
    kept = prod >> shift
    rem = prod & ((1 << shift) - 1) if shift > 0 else 0
    half = 1 << (shift - 1) if shift > 0 else 0
    inc = False
    if rem:
        if mode == 'rne':
            inc = rem > half or (rem == half and kept & 1)
        elif mode == 'rna':
            inc = rem >= half
        elif mode == 'rtz':
            inc = False
        elif mode == 'rup':
            inc = sign == 0
        elif mode == 'rdn':
            inc = sign == 1
    if inc:
        kept += 1
    if kept.bit_length() > f.frac_bits + 1:
        kept >>= 1
        exp += 1
    if exp > f.emax:
        to_inf = mode in ('rne', 'rna') or (mode == 'rup' and sign == 0) or (
            mode == 'rdn' and sign == 1)
        if to_inf:
            return (sign << sign_shift) | INF
        return (sign << sign_shift) | ((f.exp_mask - 1) << f.frac_bits) | (
            (1 << f.frac_bits) - 1)
    if kept == 0:
        return sign << sign_shift
    if kept < (1 << f.frac_bits):
        return (sign << sign_shift) | kept  # subnormal (exp == emin)
    return (sign << sign_shift) | ((exp + f.bias) << f.frac_bits) | (
        kept - (1 << f.frac_bits))


def rand_bits(f, rng):
    kind = rng.randrange(8)
    if kind == 0:
        return rng.getrandbits(f.total)
    if kind == 1:
        return rng.getrandbits(f.frac_bits)  # subnormal
    if kind == 2:  # near overflow
        return ((f.exp_mask - 1 - rng.randrange(4)) << f.frac_bits) | rng.getrandbits(
            f.frac_bits)
    if kind == 3:  # near underflow
        return ((1 + rng.randrange(4)) << f.frac_bits) | rng.getrandbits(f.frac_bits)
    if kind == 4:  # all-ones significand
        return (rng.randrange(f.exp_mask) << f.frac_bits) | ((1 << f.frac_bits) - 1)
    if kind == 5:  # power of two
        return rng.randrange(f.exp_mask) << f.frac_bits
    if kind == 6:  # sparse significand
        return (rng.randrange(f.exp_mask) << f.frac_bits) | (
            1 << rng.randrange(f.frac_bits))
    return rng.getrandbits(f.total) | (1 << (f.total - 1))  # negative


def hx(f, v):
    return f'"{v:#0{f.total // 4 + 2}x}"'


def emit(f):
    rng = random.Random(20260808 ^ f.total)
    cases = []
    one = f.bias << f.frac_bits
    directed = [
        (one, one),
        (one, 1),  # 1 * min_subnormal
        ((1 << f.frac_bits) - 1, (1 << f.frac_bits) - 1),  # max subnormal^2 -> 0
        (((f.exp_mask - 1) << f.frac_bits) | ((1 << f.frac_bits) - 1),) * 2,
        ((f.bias - 1) << f.frac_bits, 1 << f.frac_bits),  # 0.5 * min_normal
        ((f.bias << f.frac_bits) | ((1 << f.frac_bits) - 1),) * 2,  # (2-ulp)^2
    ]
    for a, b in directed:
        cases.append((a, b, mul_mode(f, a, b, 'rne')))
    while len(cases) < 32:
        a, b = rand_bits(f, rng), rand_bits(f, rng)
        cases.append((a, b, mul_mode(f, a, b, 'rne')))
    print(f"pub const GOLDEN_{f.name}_MUL_RNE: &[(&str, &str, &str)] = &[")
    for a, b, r in cases:
        print(f"    ({hx(f, a)}, {hx(f, b)}, {hx(f, r)}),")
    print("];")
    # directed-mode vectors: (mode_idx, a, b, result); mode order matches
    # RoundMode::ALL = [NearestEven, NearestAway, TowardZero,
    # TowardPositive, TowardNegative]
    modes = ['rne', 'rna', 'rtz', 'rup', 'rdn']
    print()
    print(f"pub const GOLDEN_{f.name}_MUL_MODES: &[(u8, &str, &str, &str)] = &[")
    for mi, mode in enumerate(modes):
        for a, b, _ in cases[:12]:
            r = mul_mode(f, a, b, mode)
            print(f"    ({mi}, {hx(f, a)}, {hx(f, b)}, {hx(f, r)}),")
    print("];")


def main():
    print("// @generated by python/tools/gen_golden_widefp.py — do not edit.")
    emit(FP256)
    print()
    emit(FP512)


if __name__ == "__main__":
    main()
