"""Generate binary128 multiplication golden vectors with pure-integer math.

Independent oracle for the Rust softfloat: implements IEEE-754 binary128
multiply (round-to-nearest-even) directly on Python ints — no shared code
with the Rust pipeline. Output is a Rust array literal pasted into
`rust/src/fpu/golden.rs`.
"""
import random

EXP_BITS = 15
FRAC_BITS = 112
BIAS = (1 << (EXP_BITS - 1)) - 1
EMIN = 1 - BIAS
EMAX = BIAS
EXP_MASK = (1 << EXP_BITS) - 1
TOTAL = 128


def unpack(bits):
    sign = bits >> 127
    biased = (bits >> FRAC_BITS) & EXP_MASK
    frac = bits & ((1 << FRAC_BITS) - 1)
    if biased == EXP_MASK:
        return (sign, 'nan' if frac else 'inf', 0, 0)
    if biased == 0:
        if frac == 0:
            return (sign, 'zero', 0, 0)
        return (sign, 'fin', EMIN, frac)  # subnormal, no hidden bit
    return (sign, 'fin', biased - BIAS, frac | (1 << FRAC_BITS))


def mul_mode(a_bits, b_bits, mode):
    """IEEE binary128 multiply under any rounding-direction attribute.

    mode: 'rne' | 'rna' | 'rtz' | 'rup' | 'rdn'
    """
    sa, ca, ea, ma = unpack(a_bits)
    sb, cb, eb, mb = unpack(b_bits)
    sign = sa ^ sb
    QNAN = (EXP_MASK << FRAC_BITS) | (1 << (FRAC_BITS - 1))
    INF = EXP_MASK << FRAC_BITS
    if ca == 'nan' or cb == 'nan':
        return QNAN
    if (ca == 'inf' and cb == 'zero') or (ca == 'zero' and cb == 'inf'):
        return QNAN
    if ca == 'inf' or cb == 'inf':
        return (sign << 127) | INF
    if ca == 'zero' or cb == 'zero':
        return sign << 127
    while ma < (1 << FRAC_BITS):
        ma <<= 1
        ea -= 1
    while mb < (1 << FRAC_BITS):
        mb <<= 1
        eb -= 1
    prod = ma * mb
    top = prod.bit_length() - 1
    exp = ea + eb + (top - 2 * FRAC_BITS)
    shift = top - FRAC_BITS
    if exp < EMIN:
        shift += EMIN - exp
        exp = EMIN
    kept = prod >> shift
    rem = prod & ((1 << shift) - 1) if shift > 0 else 0
    half = 1 << (shift - 1) if shift > 0 else 0
    inc = False
    if rem:
        if mode == 'rne':
            inc = rem > half or (rem == half and kept & 1)
        elif mode == 'rna':
            inc = rem >= half
        elif mode == 'rtz':
            inc = False
        elif mode == 'rup':
            inc = sign == 0
        elif mode == 'rdn':
            inc = sign == 1
    if inc:
        kept += 1
    if kept.bit_length() > FRAC_BITS + 1:
        kept >>= 1
        exp += 1
    if exp > EMAX:
        to_inf = mode in ('rne', 'rna') or (mode == 'rup' and sign == 0) or (
            mode == 'rdn' and sign == 1)
        if to_inf:
            return (sign << 127) | INF
        return (sign << 127) | ((EXP_MASK - 1) << FRAC_BITS) | ((1 << FRAC_BITS) - 1)
    if kept == 0:
        return sign << 127
    if kept < (1 << FRAC_BITS):
        return (sign << 127) | kept
    return (sign << 127) | ((exp + BIAS) << FRAC_BITS) | (kept - (1 << FRAC_BITS))


def mul_rne(a_bits, b_bits):
    sa, ca, ea, ma = unpack(a_bits)
    sb, cb, eb, mb = unpack(b_bits)
    sign = sa ^ sb
    QNAN = (EXP_MASK << FRAC_BITS) | (1 << (FRAC_BITS - 1))
    if ca == 'nan' or cb == 'nan':
        return QNAN
    if (ca == 'inf' and cb == 'zero') or (ca == 'zero' and cb == 'inf'):
        return QNAN
    if ca == 'inf' or cb == 'inf':
        return (sign << 127) | (EXP_MASK << FRAC_BITS)
    if ca == 'zero' or cb == 'zero':
        return sign << 127
    # normalize subnormals
    while ma < (1 << FRAC_BITS):
        ma <<= 1
        ea -= 1
    while mb < (1 << FRAC_BITS):
        mb <<= 1
        eb -= 1
    prod = ma * mb
    top = prod.bit_length() - 1
    exp = ea + eb + (top - 2 * FRAC_BITS)
    shift = top - FRAC_BITS
    if exp < EMIN:
        shift += EMIN - exp
        exp = EMIN
    kept = prod >> shift
    rem = prod & ((1 << shift) - 1)
    half = 1 << (shift - 1) if shift > 0 else 0
    if shift > 0 and (rem > half or (rem == half and kept & 1)):
        kept += 1
    if kept.bit_length() > FRAC_BITS + 1:
        kept >>= 1
        exp += 1
    if exp > EMAX:
        return (sign << 127) | (EXP_MASK << FRAC_BITS)  # inf (RNE)
    if kept == 0:
        return sign << 127
    if kept < (1 << FRAC_BITS):
        return (sign << 127) | kept  # subnormal (exp == EMIN)
    return (sign << 127) | ((exp + BIAS) << FRAC_BITS) | (kept - (1 << FRAC_BITS))


def rand_bits(rng):
    kind = rng.randrange(8)
    if kind == 0:
        return rng.getrandbits(128)
    if kind == 1:
        return rng.getrandbits(FRAC_BITS)  # subnormal
    if kind == 2:  # near overflow
        return ((EXP_MASK - 1 - rng.randrange(4)) << FRAC_BITS) | rng.getrandbits(FRAC_BITS)
    if kind == 3:  # near underflow
        return ((1 + rng.randrange(4)) << FRAC_BITS) | rng.getrandbits(FRAC_BITS)
    if kind == 4:  # all-ones significand
        return (rng.randrange(EXP_MASK) << FRAC_BITS) | ((1 << FRAC_BITS) - 1)
    if kind == 5:  # power of two
        return rng.randrange(EXP_MASK) << FRAC_BITS
    if kind == 6:  # sparse significand
        return (rng.randrange(EXP_MASK) << FRAC_BITS) | (1 << rng.randrange(FRAC_BITS))
    return rng.getrandbits(128) | (1 << 127)  # negative


def main():
    rng = random.Random(20260710)
    cases = []
    # Directed cases
    ONE = 0x3FFF << FRAC_BITS
    directed = [
        (ONE, ONE),
        (ONE, 1),  # 1 * min_subnormal
        ((1 << FRAC_BITS) - 1, (1 << FRAC_BITS) - 1),  # max subnormal^2 -> 0
        (((EXP_MASK - 1) << FRAC_BITS) | ((1 << FRAC_BITS) - 1),) * 2,  # max_finite^2
        ((0x3FFE << FRAC_BITS), (1 << FRAC_BITS)),  # 0.5 * min_normal
        ((0x3FFF << FRAC_BITS) | ((1 << FRAC_BITS) - 1),) * 2,  # (2-ulp)^2 round
    ]
    for a, b in directed:
        cases.append((a, b, mul_rne(a, b)))
    while len(cases) < 64:
        a, b = rand_bits(rng), rand_bits(rng)
        cases.append((a, b, mul_rne(a, b)))
    print("// @generated by python/tools/gen_golden_fp128.py — do not edit.")
    print("pub const GOLDEN_FP128_MUL_RNE: &[(u128, u128, u128)] = &[")
    for a, b, r in cases:
        print(f"    ({a:#034x}, {b:#034x}, {r:#034x}),")
    print("];")
    # directed-mode vectors: (mode_idx, a, b, result); mode order matches
    # RoundMode::ALL = [NearestEven, NearestAway, TowardZero, TowardPositive,
    # TowardNegative]
    modes = ['rne', 'rna', 'rtz', 'rup', 'rdn']
    print()
    print("pub const GOLDEN_FP128_MUL_MODES: &[(u8, u128, u128, u128)] = &[")
    for mi, mode in enumerate(modes):
        for a, b, _ in cases[:24]:
            r = mul_mode(a, b, mode)
            print(f"    ({mi}, {a:#034x}, {b:#034x}, {r:#034x}),")
    print("];")


if __name__ == "__main__":
    main()
