"""Build-time compile path: JAX model + Pallas kernels, AOT-lowered to HLO.

Nothing in this package runs at serving time — `aot.py` emits
`artifacts/*.hlo.txt` once and the Rust coordinator executes them via PJRT.
"""
