"""Layer-1 Pallas kernel: CIVP limb-decomposed significand multiplication.

The kernel is a software transcription of Fig. 2(b) / Fig. 4(b): each
partial product ``a_chunk[i] * b_chunk[j]`` is one dedicated-block
multiplication (<= 24x24 bits, so the 48-bit product is exact in int64),
and the shifted accumulation is the adder tree. Batched over requests; the
batch is the Pallas grid dimension.

TPU adaptation (DESIGN.md §3): the paper's spatial DSP tiles become the
static chunk structure unrolled inside the kernel body (fully vectorizable
on the VPU lanes across the batch), and the HBM<->VMEM schedule the paper
expressed with block wiring is expressed with a BlockSpec over the batch
dimension. ``interpret=True`` always — the CPU PJRT client cannot run
Mosaic custom-calls.

Accumulation strategy: tile offsets are not multiples of a machine word, so
each shifted 48-bit partial product is scattered into base-2^12 digit
buckets (digit = 12 bits guarantees ``(product << (offset % 12))`` fits in
int64 and per-digit sums stay far below 2^63 for <= 36 tiles). A single
static carry sweep then yields canonical base-2^24 limbs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .schemes import SigScheme

DIGIT = 12
DIGIT_MASK = (1 << DIGIT) - 1


def _n_digits(scheme: SigScheme) -> int:
    # one spare digit for the final carry sweep
    return -(-scheme.product_bits // DIGIT) + 1


def sig_mul_kernel_body(scheme: SigScheme, a_ref, b_ref, out_ref):
    """Kernel body: a_ref/b_ref [TB, n_chunks] int64 -> out_ref [TB, n_limb24]."""
    n_dig = _n_digits(scheme)
    tb = a_ref.shape[0]
    acc = [jnp.zeros((tb,), dtype=jnp.int64) for _ in range(n_dig)]
    # --- partial products: one dedicated block per (i, j) tile -----------
    for i, (wa, oa) in enumerate(zip(scheme.chunks, scheme.offsets)):
        ai = a_ref[:, i]
        for j, (wb, ob) in enumerate(zip(scheme.chunks, scheme.offsets)):
            bj = b_ref[:, j]
            prod = ai * bj  # <= wa+wb <= 48 bits, exact in int64
            off = oa + ob
            q, r = divmod(off, DIGIT)
            shifted = prod << r  # <= 59 bits
            # scatter the shifted value into its digit buckets
            for k in range((wa + wb + r + DIGIT - 1) // DIGIT):
                acc[q + k] = acc[q + k] + ((shifted >> (DIGIT * k)) & DIGIT_MASK)
    # --- carry sweep (the adder tree) -------------------------------------
    for d in range(n_dig - 1):
        carry = acc[d] >> DIGIT
        acc[d] = acc[d] & DIGIT_MASK
        acc[d + 1] = acc[d + 1] + carry
    # top digit must have no residual carry by construction
    # --- pack pairs of 12-bit digits into base-2^24 limbs ------------------
    for k in range(scheme.n_limb24):
        lo = acc[2 * k] if 2 * k < n_dig else jnp.zeros((tb,), jnp.int64)
        hi = acc[2 * k + 1] if 2 * k + 1 < n_dig else jnp.zeros((tb,), jnp.int64)
        out_ref[:, k] = lo + (hi << DIGIT)


@functools.partial(jax.jit, static_argnums=(0, 3))
def sig_mul(scheme: SigScheme, a_chunks, b_chunks, batch_tile: int = 128):
    """Batched significand multiply through the CIVP tile structure.

    Args:
      scheme: static partition scheme.
      a_chunks, b_chunks: int64 [B, n_chunks], chunk values (< 2^24 each).
      batch_tile: Pallas block size along the batch dimension; B must be a
        multiple (callers pad — padding waste is measured in EXPERIMENTS.md
        §Perf, mirroring the paper's block-padding argument).

    Returns:
      int64 [B, n_limb24] base-2^24 limbs of the exact product.
    """
    b = a_chunks.shape[0]
    assert b % batch_tile == 0, f"batch {b} not a multiple of tile {batch_tile}"
    grid = (b // batch_tile,)
    return pl.pallas_call(
        functools.partial(sig_mul_kernel_body, scheme),
        out_shape=jax.ShapeDtypeStruct((b, scheme.n_limb24), jnp.int64),
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch_tile, scheme.n_chunks), lambda i: (i, 0)),
            pl.BlockSpec((batch_tile, scheme.n_chunks), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch_tile, scheme.n_limb24), lambda i: (i, 0)),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(a_chunks, b_chunks)
