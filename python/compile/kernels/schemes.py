"""CIVP partition schemes — the Python mirror of `rust/src/decomp/scheme.rs`.

Chunk layouts follow the paper exactly (least-significant first):

* single — 24-bit significand = one ``24`` chunk (§II.A);
* double — 53 bits padded to 57 = ``[24, 24, 9]`` (Fig. 2);
* quad   — 113 bits padded to 114 = two 57-bit halves (Fig. 4), i.e.
  ``[24, 24, 9, 24, 24, 9]``.

The kernel consumes these statically: the chunk structure is baked into the
lowered HLO, exactly as the paper's block wiring is baked into silicon.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class SigScheme:
    """A partition of one significand operand for the CIVP block set."""

    name: str
    #: significand width including hidden bit (24 / 53 / 113)
    sig_bits: int
    #: chunk widths, least-significant first; sum == padded width
    chunks: tuple
    #: chunk bit offsets (derived)
    offsets: tuple = field(init=False)

    def __post_init__(self):
        offs, o = [], 0
        for w in self.chunks:
            offs.append(o)
            o += w
        object.__setattr__(self, "offsets", tuple(offs))

    @property
    def padded_bits(self):
        return sum(self.chunks)

    @property
    def n_chunks(self):
        return len(self.chunks)

    @property
    def product_bits(self):
        return 2 * self.padded_bits

    @property
    def n_limb24(self):
        """Output limbs (base 2^24) needed for the full product."""
        return -(-self.product_bits // 24)

    def block_kinds(self):
        """Block kind (a, b) -> 'AxB' label for every tile, row-major."""
        out = []
        for wa in self.chunks:
            for wb in self.chunks:
                hi, lo = max(wa, wb), min(wa, wb)
                out.append(f"{hi}x{lo}")
        return out


SINGLE = SigScheme("civp-single", 24, (24,))
DOUBLE = SigScheme("civp-double", 53, (24, 24, 9))
QUAD = SigScheme("civp-quad", 113, (24, 24, 9, 24, 24, 9))

BY_NAME = {"single": SINGLE, "double": DOUBLE, "quad": QUAD}
