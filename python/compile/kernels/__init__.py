"""Layer-1 Pallas kernels (CIVP limb-decomposed significand multiply)."""
