"""Correctness oracles for the Pallas kernel and the JAX model.

Two independent references:

* :func:`sig_mul_ref` — a pure-jnp transcription of the same tile
  accumulation (no Pallas), used to check the kernel's lowering; and
* host-side exact big-int helpers (:func:`chunks_to_int`,
  :func:`int_to_limb24`, :func:`ieee_mul_bits`) built on Python integers —
  no shared code with either the kernel or the Rust pipeline. pytest
  compares all three.
"""

import jax.numpy as jnp

from .schemes import SigScheme

# ---------------------------------------------------------------------------
# pure-jnp reference (traced, but independent of Pallas)
# ---------------------------------------------------------------------------


def sig_mul_ref(scheme: SigScheme, a_chunks, b_chunks):
    """Same math as the kernel, expressed as a flat jnp reduction."""
    b = a_chunks.shape[0]
    n_dig = -(-scheme.product_bits // 12) + 1
    acc = jnp.zeros((b, n_dig), dtype=jnp.int64)
    for i, (wa, oa) in enumerate(zip(scheme.chunks, scheme.offsets)):
        for j, (wb, ob) in enumerate(zip(scheme.chunks, scheme.offsets)):
            prod = a_chunks[:, i] * b_chunks[:, j]
            off = oa + ob
            q, r = divmod(off, 12)
            shifted = prod << r
            for k in range((wa + wb + r + 11) // 12):
                acc = acc.at[:, q + k].add((shifted >> (12 * k)) & 0xFFF)
    # carry sweep
    for d in range(n_dig - 1):
        carry = acc[:, d] >> 12
        acc = acc.at[:, d].set(acc[:, d] & 0xFFF)
        acc = acc.at[:, d + 1].add(carry)
    out = []
    for k in range(scheme.n_limb24):
        lo = acc[:, 2 * k] if 2 * k < n_dig else 0
        hi = acc[:, 2 * k + 1] if 2 * k + 1 < n_dig else 0
        out.append(lo + (hi << 12))
    return jnp.stack(out, axis=-1)


# ---------------------------------------------------------------------------
# host-side exact big-int reference (Python ints, never traced)
# ---------------------------------------------------------------------------


def int_to_chunks(v: int, scheme: SigScheme):
    """Split an integer into the scheme's chunk values (host side)."""
    assert 0 <= v < (1 << scheme.padded_bits)
    return [(v >> o) & ((1 << w) - 1) for w, o in zip(scheme.chunks, scheme.offsets)]


def chunks_to_int(chunks, scheme: SigScheme) -> int:
    """Reassemble chunk values into the integer they encode."""
    return sum(int(c) << o for c, o in zip(chunks, scheme.offsets))


def int_to_limb24(v: int, n: int):
    """Split an integer into ``n`` base-2^24 limbs (host side)."""
    return [(v >> (24 * k)) & 0xFFFFFF for k in range(n)]


def limb24_to_int(limbs) -> int:
    """Reassemble base-2^24 limbs."""
    return sum(int(l) << (24 * k) for k, l in enumerate(limbs))


# --- IEEE-754 binary multiply on Python ints (round-to-nearest-even) -------

FORMATS = {
    # name: (exp_bits, frac_bits)
    "single": (8, 23),
    "double": (11, 52),
    "quad": (15, 112),
}


def ieee_mul_bits(a_bits: int, b_bits: int, fmt: str) -> int:
    """Exact IEEE-754 multiply (RNE) on packed bit patterns, via Python ints.

    Independent of both the JAX model and the Rust softfloat — the third
    implementation used to cross-check the other two.
    """
    eb, fb = FORMATS[fmt]
    bias = (1 << (eb - 1)) - 1
    emin, emax = 1 - bias, bias
    exp_mask = (1 << eb) - 1
    total = 1 + eb + fb

    def unpack(bits):
        sign = bits >> (total - 1)
        biased = (bits >> fb) & exp_mask
        frac = bits & ((1 << fb) - 1)
        if biased == exp_mask:
            return sign, ("nan" if frac else "inf"), 0, 0
        if biased == 0:
            return (sign, "zero", 0, 0) if frac == 0 else (sign, "fin", emin, frac)
        return sign, "fin", biased - bias, frac | (1 << fb)

    sa, ca, ea, ma = unpack(a_bits)
    sb, cb, eb_, mb = unpack(b_bits)
    sign = sa ^ sb
    qnan = (exp_mask << fb) | (1 << (fb - 1))
    if ca == "nan" or cb == "nan":
        return qnan
    if (ca == "inf" and cb == "zero") or (ca == "zero" and cb == "inf"):
        return qnan
    if ca == "inf" or cb == "inf":
        return (sign << (total - 1)) | (exp_mask << fb)
    if ca == "zero" or cb == "zero":
        return sign << (total - 1)
    while ma < (1 << fb):
        ma, ea = ma << 1, ea - 1
    while mb < (1 << fb):
        mb, eb_ = mb << 1, eb_ - 1
    prod = ma * mb
    top = prod.bit_length() - 1
    exp = ea + eb_ + (top - 2 * fb)
    shift = top - fb
    if exp < emin:
        shift += emin - exp
        exp = emin
    kept, rem = prod >> shift, prod & ((1 << shift) - 1)
    half = 1 << (shift - 1) if shift else 0
    if shift and (rem > half or (rem == half and kept & 1)):
        kept += 1
    if kept.bit_length() > fb + 1:
        kept, exp = kept >> 1, exp + 1
    if exp > emax:
        return (sign << (total - 1)) | (exp_mask << fb)
    if kept == 0:
        return sign << (total - 1)
    if kept < (1 << fb):
        return (sign << (total - 1)) | kept  # subnormal
    return (sign << (total - 1)) | ((exp + bias) << fb) | (kept - (1 << fb))
