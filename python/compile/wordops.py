"""Vectorized multi-word (uint64-limb) bit machinery for the JAX model.

The IEEE pipeline needs a handful of exact integer operations on values
wider than 64 bits (the quad significand is 113 bits, its product 226):
dynamic shifts, sticky-bit queries, bit tests and bit-lengths — all
batched, all expressible as elementwise uint64 ops so XLA fuses them.

A "wordvec" is a Python list of ``[B]``-shaped uint64 arrays,
least-significant word first. The word count is static; only shift
*amounts* are dynamic (per batch element).
"""

import jax.numpy as jnp

U64 = jnp.uint64


def _u(x):
    return jnp.asarray(x, dtype=U64)


def const_words(value: int, n_words: int, batch):
    """Broadcast a Python int into an n-word wordvec."""
    return [
        jnp.full(batch, (value >> (64 * k)) & 0xFFFFFFFFFFFFFFFF, dtype=U64)
        for k in range(n_words)
    ]


def _shl64(x, n):
    """x << n with n in [0, 64]; n == 64 yields 0 (numpy shift is UB there)."""
    n = jnp.asarray(n)
    safe = jnp.clip(n, 0, 63)
    shifted = x << safe.astype(U64)
    return jnp.where(n >= 64, _u(0), shifted)


def _shr64(x, n):
    """x >> n with n in [0, 64]; n == 64 yields 0."""
    n = jnp.asarray(n)
    safe = jnp.clip(n, 0, 63)
    shifted = x >> safe.astype(U64)
    return jnp.where(n >= 64, _u(0), shifted)


def bitlen64(x):
    """Bit length of a uint64 array (0 for 0), via 6-step binary search."""
    x = jnp.asarray(x, dtype=U64)
    out = jnp.zeros(x.shape, dtype=jnp.int32)
    cur = x
    for sh in (32, 16, 8, 4, 2, 1):
        m = cur >> _u(sh)
        take = m > 0
        out = out + jnp.where(take, sh, 0).astype(jnp.int32)
        cur = jnp.where(take, m, cur)
    return out + (cur > 0).astype(jnp.int32)


def bitlen(ws):
    """Bit length of a wordvec."""
    out = bitlen64(ws[0])
    for k in range(1, len(ws)):
        blk = bitlen64(ws[k])
        out = jnp.where(blk > 0, blk + 64 * k, out)
    return out


def get_bit(ws, i):
    """Bit ``i`` (dynamic, per element) of a wordvec -> uint64 0/1.

    Out-of-range indices (including negative) read as 0.
    """
    i = jnp.asarray(i)
    out = jnp.zeros(ws[0].shape, dtype=U64)
    for k, w in enumerate(ws):
        sel = (i >= 64 * k) & (i < 64 * (k + 1))
        bit = _shr64(w, jnp.clip(i - 64 * k, 0, 63)) & _u(1)
        out = jnp.where(sel, bit, out)
    return out


def any_below(ws, n):
    """True where any bit strictly below dynamic position ``n`` is set."""
    n = jnp.asarray(n)
    acc = jnp.zeros(ws[0].shape, dtype=jnp.bool_)
    for k, w in enumerate(ws):
        rel = jnp.clip(n - 64 * k, 0, 64)
        # mask of the low `rel` bits; rel==64 -> all ones
        mask = jnp.where(rel >= 64, _u(0xFFFFFFFFFFFFFFFF), _shl64(_u(1), rel) - _u(1))
        acc = acc | ((w & mask) != 0)
    return acc


def shr(ws, n, out_words=None):
    """Wordvec >> n (dynamic, per element), producing ``out_words`` words."""
    n = jnp.asarray(n)
    m = out_words if out_words is not None else len(ws)
    out = []
    for j in range(m):
        acc = jnp.zeros(ws[0].shape, dtype=U64)
        for k in range(len(ws)):
            # ws[k] contributes to out[j] bits: rel = 64*(k - j) - n
            rel = 64 * (k - j) - n
            left = _shl64(ws[k], jnp.clip(rel, 0, 64))
            right = _shr64(ws[k], jnp.clip(-rel, 0, 64))
            contrib = jnp.where(rel >= 64, _u(0), jnp.where(rel >= 0, left, jnp.where(rel > -64, right, _u(0))))
            acc = acc | contrib
        out.append(acc)
    return out


def shl(ws, n, out_words=None):
    """Wordvec << n (dynamic, per element)."""
    return shr(ws, -jnp.asarray(n), out_words=out_words or len(ws))


def add_small(ws, inc):
    """Wordvec + inc where ``inc`` is a per-element uint64 (carry rippled)."""
    out = []
    carry = jnp.asarray(inc, dtype=U64)
    for w in ws:
        s = w + carry
        out.append(s)
        carry = (s < w).astype(U64)  # overflow detect
    return out


def mask_low_static(ws, n_bits: int):
    """Keep only the low ``n_bits`` (static) bits."""
    out = []
    for k, w in enumerate(ws):
        lo = 64 * k
        if lo >= n_bits:
            out.append(jnp.zeros_like(w))
        elif n_bits - lo >= 64:
            out.append(w)
        else:
            out.append(w & _u((1 << (n_bits - lo)) - 1))
    return out


def is_zero(ws):
    """True where the wordvec is zero."""
    acc = ws[0] == 0
    for w in ws[1:]:
        acc = acc & (w == 0)
    return acc


def words_eq(a, b):
    """Elementwise equality of two wordvecs."""
    acc = a[0] == b[0]
    for x, y in zip(a[1:], b[1:]):
        acc = acc & (x == y)
    return acc


def select(cond, a, b):
    """Per-element wordvec select."""
    return [jnp.where(cond, x, y) for x, y in zip(a, b)]
