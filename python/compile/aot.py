"""AOT-lower the Layer-2 model to HLO *text* artifacts for the Rust runtime.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts [--batch 256]

Emits one artifact per precision plus a manifest:

* ``civp_fp32.hlo.txt``  — (u32[B], u32[B]) -> u32[B]
* ``civp_fp64.hlo.txt``  — (u64[B], u64[B]) -> u64[B]
* ``civp_fp128.hlo.txt`` — (u64[B,2], u64[B,2]) -> u64[B,2]
* ``manifest.txt``       — batch size + entry list for the Rust loader
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model

BATCH_DEFAULT = 256
BATCH_TILE = 128


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries(batch):
    u32 = jax.ShapeDtypeStruct((batch,), jnp.uint32)
    u64 = jax.ShapeDtypeStruct((batch,), jnp.uint64)
    u64x2 = jax.ShapeDtypeStruct((batch, 2), jnp.uint64)
    tile = min(BATCH_TILE, batch)
    return {
        "civp_fp32": (functools.partial(model.mul_fp32, batch_tile=tile), (u32, u32)),
        "civp_fp64": (functools.partial(model.mul_fp64, batch_tile=tile), (u64, u64)),
        "civp_fp128": (functools.partial(model.mul_fp128, batch_tile=tile), (u64x2, u64x2)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=BATCH_DEFAULT)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = [f"batch={args.batch}"]
    for name, (fn, specs) in entries(args.batch).items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(name)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
