"""Layer-2 JAX model: the full IEEE-754 multiplication pipeline.

Everything around the significand product is standard IEEE machinery
(unpack -> normalize subnormals -> multiply -> round-to-nearest-even ->
pack, with the NaN/Inf/zero lattice as vectorized selects); the significand
product itself goes through the Layer-1 CIVP Pallas kernel
(:mod:`compile.kernels.limbmul`), so the lowered HLO contains the paper's
tile structure.

Three batched entry points (fixed batch per artifact, Rust pads):

* ``mul_fp32(a_u32[B], b_u32[B]) -> u32[B]``
* ``mul_fp64(a_u64[B], b_u64[B]) -> u64[B]``
* ``mul_fp128(a_u64[B,2], b_u64[B,2]) -> u64[B,2]``  (lo, hi words)

All are bit-exact against the host big-int oracle (``kernels/ref.py``) and
— for fp32/fp64 — against numpy hardware multiplication; see
``python/tests/``.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from . import wordops as wo
from .kernels import limbmul
from .kernels.schemes import DOUBLE, QUAD, SINGLE

U64 = jnp.uint64


def _u(x):
    return jnp.asarray(x, dtype=U64)


# ---------------------------------------------------------------------------
# generic pipeline over wordvecs
# ---------------------------------------------------------------------------


class _Fmt:
    def __init__(self, name, exp_bits, frac_bits, scheme, sig_words, prod_words):
        self.name = name
        self.exp_bits = exp_bits
        self.frac_bits = frac_bits
        self.scheme = scheme
        self.sig_words = sig_words
        self.prod_words = prod_words
        self.bias = (1 << (exp_bits - 1)) - 1
        self.emin = 1 - self.bias
        self.emax = self.bias
        self.exp_mask = (1 << exp_bits) - 1
        self.total = 1 + exp_bits + frac_bits


FMT32 = _Fmt("single", 8, 23, SINGLE, 1, 1)
FMT64 = _Fmt("double", 11, 52, DOUBLE, 1, 2)
FMT128 = _Fmt("quad", 15, 112, QUAD, 2, 4)


def _unpack(fmt: _Fmt, bits):
    """bits: wordvec of the packed value -> (sign, biased, frac wordvec)."""
    total, fb = fmt.total, fmt.frac_bits
    sign = wo.get_bit(bits, jnp.full(bits[0].shape, total - 1, jnp.int32))
    shifted = wo.shr(bits, jnp.full(bits[0].shape, fb, jnp.int32), out_words=1)[0]
    biased = (shifted & _u(fmt.exp_mask)).astype(jnp.int32)
    frac = wo.mask_low_static(bits, fb)
    return sign, biased, frac


def _normalize(fmt: _Fmt, biased, frac):
    """Normalized (exp, sig) for finite non-zero inputs.

    Normal: sig = frac | hidden, exp = biased - bias.
    Subnormal: shift frac up so the top bit reaches frac_bits, exp adjusts.
    """
    t = fmt.frac_bits + 1
    is_sub = biased == 0
    # normal path
    hidden = wo.const_words(1 << fmt.frac_bits, fmt.sig_words, frac[0].shape[0])
    sig_norm = [a | b for a, b in zip(wo.mask_low_static(frac, fmt.frac_bits), hidden)]
    exp_norm = biased - fmt.bias
    # subnormal path
    bl = wo.bitlen(frac)
    up = (t - bl).astype(jnp.int32)
    sig_sub = wo.shl(frac, up, out_words=fmt.sig_words)
    exp_sub = fmt.emin - up
    sig = wo.select(is_sub, sig_sub, sig_norm)
    exp = jnp.where(is_sub, exp_sub, exp_norm)
    return exp, sig


def _extract_chunks(fmt: _Fmt, sig):
    """Cut a normalized significand wordvec into the scheme's chunk columns
    (int64 [B, n_chunks]) for the Pallas kernel."""
    cols = []
    for w, o in zip(fmt.scheme.chunks, fmt.scheme.offsets):
        piece = wo.shr(sig, jnp.full(sig[0].shape, o, jnp.int32), out_words=1)[0]
        cols.append((piece & _u((1 << w) - 1)).astype(jnp.int64))
    return jnp.stack(cols, axis=-1)


def _limbs_to_words(fmt: _Fmt, limbs):
    """Pack base-2^24 kernel limbs into an exact product wordvec.

    Limbs are canonical (< 2^24) and occupy disjoint bit ranges, so each
    word is an OR of statically-shifted pieces — no carries.
    """
    n = limbs.shape[-1]
    words = []
    for j in range(fmt.prod_words):
        acc = jnp.zeros(limbs.shape[0], dtype=U64)
        for k in range(n):
            lo_bit = 24 * k
            rel = lo_bit - 64 * j
            if rel <= -24 or rel >= 64:
                continue
            piece = limbs[:, k].astype(U64)
            if rel >= 0:
                acc = acc | ((piece << _u(rel)) if rel < 64 else _u(0))
            else:
                acc = acc | (piece >> _u(-rel))
        words.append(acc)
    return words


def _round_pack(fmt: _Fmt, sign, exp, prod, batch_tile):
    """RNE-round the exact product and pack the finite result."""
    del batch_tile
    f = fmt.frac_bits
    t = f + 1
    b = prod[0].shape[0]
    # top bit is at 2f or 2f+1
    is_big = wo.get_bit(prod, jnp.full(b, 2 * f + 1, jnp.int32)).astype(jnp.int32)
    exp = exp + is_big
    shift = (f + is_big).astype(jnp.int32)
    # underflow denormalization
    extra = jnp.clip(fmt.emin - exp, 0, 2 * t + 4)
    shift = shift + extra.astype(jnp.int32)
    exp = jnp.maximum(exp, fmt.emin)
    # round to nearest even
    kept = wo.shr(prod, shift, out_words=fmt.sig_words)
    round_bit = wo.get_bit(prod, shift - 1)
    sticky = wo.any_below(prod, shift - 1)
    inc = (round_bit == 1) & (sticky | ((kept[0] & _u(1)) == 1))
    kept = wo.add_small(kept, inc.astype(U64))
    # carry renormalize: if bit t set, halve (low bits are then zero)
    carry = wo.get_bit(kept, jnp.full(b, t, jnp.int32)) == 1
    kept = wo.select(carry, wo.shr(kept, jnp.full(b, 1, jnp.int32)), kept)
    exp = exp + carry.astype(jnp.int32)
    # classify result
    hidden_set = wo.get_bit(kept, jnp.full(b, f, jnp.int32)) == 1
    overflow = exp > fmt.emax
    # pack finite
    biased = jnp.where(hidden_set, (exp + fmt.bias).astype(jnp.int64), 0).astype(U64)
    frac = wo.mask_low_static(kept, f)
    packed = list(frac)
    packed = _or_field(packed, biased, f)
    packed = _or_field(packed, sign.astype(U64), fmt.total - 1)
    # overflow -> inf (RNE)
    inf = wo.const_words((fmt.exp_mask << f), fmt.sig_words, b)
    inf = _or_field(list(inf), sign.astype(U64), fmt.total - 1)
    return wo.select(overflow, inf, packed)


def _or_field(ws, value_u64, bit_offset: int):
    """OR a (<64-bit) field into a wordvec at a static bit offset."""
    j, r = divmod(bit_offset, 64)
    ws[j] = ws[j] | ((value_u64 << _u(r)) if r < 64 else _u(0))
    if r > 0 and j + 1 < len(ws):
        ws[j + 1] = ws[j + 1] | (value_u64 >> _u(64 - r))
    return ws


def _mul_pipeline(fmt: _Fmt, a_bits, b_bits, batch_tile):
    """Full multiply on packed wordvecs -> packed wordvec."""
    b = a_bits[0].shape[0]
    sa, ba, fa = _unpack(fmt, a_bits)
    sb, bb, fb_ = _unpack(fmt, b_bits)
    sign = sa ^ sb

    # classes
    a_is_nan = (ba == fmt.exp_mask) & ~wo.is_zero(fa)
    b_is_nan = (bb == fmt.exp_mask) & ~wo.is_zero(fb_)
    a_is_inf = (ba == fmt.exp_mask) & wo.is_zero(fa)
    b_is_inf = (bb == fmt.exp_mask) & wo.is_zero(fb_)
    a_is_zero = (ba == 0) & wo.is_zero(fa)
    b_is_zero = (bb == 0) & wo.is_zero(fb_)

    # finite x finite path
    ea, siga = _normalize(fmt, ba, fa)
    eb, sigb = _normalize(fmt, bb, fb_)
    # guard the all-zero significand (zero inputs) so bitlen math stays sane:
    # results for those lanes are overridden by the lattice below.
    one = wo.const_words(1 << fmt.frac_bits, fmt.sig_words, b)
    siga = wo.select(a_is_zero | a_is_nan | a_is_inf, one, siga)
    sigb = wo.select(b_is_zero | b_is_nan | b_is_inf, one, sigb)
    ea = jnp.where(a_is_zero | a_is_nan | a_is_inf, 0, ea)
    eb = jnp.where(b_is_zero | b_is_nan | b_is_inf, 0, eb)

    a_chunks = _extract_chunks(fmt, siga)
    b_chunks = _extract_chunks(fmt, sigb)
    limbs = limbmul.sig_mul(fmt.scheme, a_chunks, b_chunks, batch_tile)
    prod = _limbs_to_words(fmt, limbs)
    finite = _round_pack(fmt, sign, ea + eb, prod, batch_tile)

    # special lattice (priority: NaN > inf*0 -> NaN > inf > zero > finite)
    qnan = wo.const_words((fmt.exp_mask << fmt.frac_bits) | (1 << (fmt.frac_bits - 1)),
                          fmt.sig_words, b)
    inf = _or_field(list(wo.const_words(fmt.exp_mask << fmt.frac_bits, fmt.sig_words, b)),
                    sign.astype(U64), fmt.total - 1)
    zero = _or_field(list(wo.const_words(0, fmt.sig_words, b)),
                     sign.astype(U64), fmt.total - 1)

    any_nan = a_is_nan | b_is_nan
    inf_times_zero = (a_is_inf & b_is_zero) | (a_is_zero & b_is_inf)
    any_inf = a_is_inf | b_is_inf
    any_zero = a_is_zero | b_is_zero

    out = finite
    out = wo.select(any_zero, zero, out)
    out = wo.select(any_inf, inf, out)
    out = wo.select(inf_times_zero | any_nan, qnan, out)
    return out


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def mul_fp32(a_u32, b_u32, batch_tile=128):
    """Batched binary32 multiply on packed uint32 bits."""
    aw = [a_u32.astype(U64)]
    bw = [b_u32.astype(U64)]
    out = _mul_pipeline(FMT32, aw, bw, batch_tile)
    return out[0].astype(jnp.uint32)


def mul_fp64(a_u64, b_u64, batch_tile=128):
    """Batched binary64 multiply on packed uint64 bits."""
    out = _mul_pipeline(FMT64, [a_u64], [b_u64], batch_tile)
    return out[0]


def mul_fp128(a_words, b_words, batch_tile=128):
    """Batched binary128 multiply; operands are uint64 [B, 2] (lo, hi)."""
    aw = [a_words[:, 0], a_words[:, 1]]
    bw = [b_words[:, 0], b_words[:, 1]]
    out = _mul_pipeline(FMT128, aw, bw, batch_tile)
    return jnp.stack(out, axis=-1)
