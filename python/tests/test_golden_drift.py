"""Pin `tools/gen_golden_fp128.py` against the checked-in Rust golden
vectors.

The generator is the independent binary128 oracle; its output was pasted
into `rust/src/fpu/golden.rs`. If either side drifts — the generator's
rounding model, its seed/case list, or a hand edit to the Rust file —
the bit-exact contract between the Python oracle and the Rust softfloat
tests silently weakens. This test regenerates the vectors and compares
them tuple-for-tuple with what the Rust tests actually consume.
"""

import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
GENERATOR = REPO / "python" / "tools" / "gen_golden_fp128.py"
SMALLFP_GENERATOR = REPO / "python" / "tools" / "gen_golden_smallfp.py"
WIDEFP_GENERATOR = REPO / "python" / "tools" / "gen_golden_widefp.py"
GOLDEN_RS = REPO / "rust" / "src" / "fpu" / "golden.rs"

TUPLE_RE = re.compile(r"^\s*\(([^)]+)\),\s*$")


def parse_arrays(text):
    """Extract {const_name: [tuple_of_ints, ...]} from Rust-array text."""
    arrays = {}
    current = None
    for line in text.splitlines():
        decl = re.search(r"pub const (\w+):", line)
        if decl:
            current = decl.group(1)
            arrays[current] = []
            continue
        if current is None:
            continue
        if line.strip().startswith("];"):
            current = None
            continue
        m = TUPLE_RE.match(line)
        if m:
            # Wide-format vectors carry operands as quoted hex strings
            # (Rust has no u256/u512 literal); strip the quotes so every
            # array parses to plain int tuples.
            arrays[current].append(
                tuple(int(f.strip().strip('"'), 0) for f in m.group(1).split(","))
            )
    return arrays


def run_generator(path):
    out = subprocess.run(
        [sys.executable, str(path)],
        capture_output=True,
        text=True,
        check=True,
    ).stdout
    return parse_arrays(out)


def assert_arrays_match(gen, rust, names, generator):
    for name in names:
        assert name in gen, f"generator no longer emits {name}"
        assert name in rust, f"golden.rs no longer contains {name}"
        assert gen[name], f"generator emitted an empty {name}"
        assert gen[name] == rust[name], (
            f"{name} drifted: regenerate with `python3 {generator.relative_to(REPO)}` "
            f"and paste into {GOLDEN_RS.relative_to(REPO)} (first mismatch at index "
            f"{next(i for i, (a, b) in enumerate(zip(gen[name], rust[name])) if a != b)})"
            if len(gen[name]) == len(rust[name])
            else f"{name} length drifted: generator {len(gen[name])} vs rust {len(rust[name])}"
        )


def test_generator_matches_checked_in_golden_vectors():
    gen = run_generator(GENERATOR)
    rust = parse_arrays(GOLDEN_RS.read_text())
    assert_arrays_match(
        gen, rust, ("GOLDEN_FP128_MUL_RNE", "GOLDEN_FP128_MUL_MODES"), GENERATOR
    )


def test_smallfp_generator_matches_checked_in_golden_vectors():
    gen = run_generator(SMALLFP_GENERATOR)
    rust = parse_arrays(GOLDEN_RS.read_text())
    assert_arrays_match(
        gen,
        rust,
        (
            "GOLDEN_FP16_MUL_RNE",
            "GOLDEN_FP16_MUL_MODES",
            "GOLDEN_BF16_MUL_RNE",
            "GOLDEN_BF16_MUL_MODES",
        ),
        SMALLFP_GENERATOR,
    )


def test_widefp_generator_matches_checked_in_golden_vectors():
    gen = run_generator(WIDEFP_GENERATOR)
    rust = parse_arrays(GOLDEN_RS.read_text())
    assert_arrays_match(
        gen,
        rust,
        (
            "GOLDEN_FP256_MUL_RNE",
            "GOLDEN_FP256_MUL_MODES",
            "GOLDEN_FP512_MUL_RNE",
            "GOLDEN_FP512_MUL_MODES",
        ),
        WIDEFP_GENERATOR,
    )


def test_widefp_generalized_model_matches_fp128_oracle():
    # The wide generator's format-generic rounding model must agree with
    # the pinned binary128 oracle when instantiated at its geometry —
    # otherwise the fp256/fp512 vectors rest on a divergent model.
    import importlib.util
    import random

    def load(name, path):
        spec = importlib.util.spec_from_file_location(name, str(path))
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    fp128 = load("gen_golden_fp128", GENERATOR)
    wide = load("gen_golden_widefp", WIDEFP_GENERATOR)
    f128 = wide.Fmt("FP128", 15, 112)
    rng = random.Random(0xC1F9)
    modes = ["rne", "rna", "rtz", "rup", "rdn"]
    for _ in range(2000):
        a, b = fp128.rand_bits(rng), fp128.rand_bits(rng)
        mode = modes[rng.randrange(5)]
        assert wide.mul_mode(f128, a, b, mode) == fp128.mul_mode(a, b, mode)
        assert wide.mul_mode(f128, a, b, "rne") == fp128.mul_rne(a, b)
