"""AOT path sanity: lowering emits loadable HLO text and the compiled
executable (via jax itself) reproduces the eager results."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


def test_hlo_text_emitted_for_all_entries():
    ents = aot.entries(256)
    assert set(ents) == {"civp_fp32", "civp_fp64", "civp_fp128"}
    for name, (fn, specs) in ents.items():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        # the whole pipeline must have lowered to one module with an
        # ENTRY computation and integer multiply ops present
        assert "ENTRY" in text
        assert "multiply" in text, name


def test_aot_main_writes_artifacts(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--batch", "128"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    for name in ("civp_fp32", "civp_fp64", "civp_fp128"):
        p = tmp_path / f"{name}.hlo.txt"
        assert p.exists() and p.stat().st_size > 1000
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert manifest[0] == "batch=128"
    assert len(manifest) == 4


def test_lowered_fp64_executes_same_as_eager():
    """Compile the lowered module and compare against the eager call —
    guards against lowering-only bugs (constant folding, layout)."""
    rng = np.random.default_rng(3)
    B = 256
    av = jnp.array([int.from_bytes(rng.bytes(8), "little") for _ in range(B)], dtype=jnp.uint64)
    bv = jnp.array([int.from_bytes(rng.bytes(8), "little") for _ in range(B)], dtype=jnp.uint64)
    fn, specs = aot.entries(B)["civp_fp64"]
    compiled = jax.jit(fn).lower(*specs).compile()
    out_aot = np.asarray(compiled(av, bv))
    out_eager = np.asarray(fn(av, bv))
    np.testing.assert_array_equal(out_aot, out_eager)
