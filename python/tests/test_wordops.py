"""Unit + hypothesis tests for the multi-word bit machinery the JAX model
is built on — each op checked against Python big-int semantics."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import wordops as wo

MASK64 = (1 << 64) - 1


def to_words(vals, n_words):
    """Python ints -> wordvec of [B] uint64 arrays."""
    return [
        jnp.array([(v >> (64 * k)) & MASK64 for v in vals], dtype=jnp.uint64)
        for k in range(n_words)
    ]


def from_words(ws):
    """wordvec -> list of Python ints."""
    arrs = [np.asarray(w, dtype=np.uint64) for w in ws]
    out = []
    for i in range(arrs[0].shape[0]):
        v = 0
        for k, a in enumerate(arrs):
            v |= int(a[i]) << (64 * k)
        out.append(v)
    return out


ints256 = st.lists(st.integers(0, (1 << 256) - 1), min_size=1, max_size=16)


@settings(max_examples=50, deadline=None)
@given(vals=ints256)
def test_bitlen(vals):
    ws = to_words(vals, 4)
    got = np.asarray(wo.bitlen(ws))
    want = [v.bit_length() for v in vals]
    assert list(got) == want


@settings(max_examples=50, deadline=None)
@given(vals=ints256, bit=st.integers(0, 300))
def test_get_bit(vals, bit):
    ws = to_words(vals, 4)
    idx = jnp.full(len(vals), bit, dtype=jnp.int32)
    got = np.asarray(wo.get_bit(ws, idx))
    want = [(v >> bit) & 1 if bit < 256 else 0 for v in vals]
    assert list(got) == want


@settings(max_examples=50, deadline=None)
@given(vals=ints256, n=st.integers(0, 300))
def test_any_below(vals, n):
    ws = to_words(vals, 4)
    nn = jnp.full(len(vals), n, dtype=jnp.int32)
    got = np.asarray(wo.any_below(ws, nn))
    want = [(v & ((1 << min(n, 256)) - 1)) != 0 for v in vals]
    assert list(got) == want


@settings(max_examples=50, deadline=None)
@given(vals=ints256, shift=st.integers(0, 280))
def test_shr(vals, shift):
    ws = to_words(vals, 4)
    s = jnp.full(len(vals), shift, dtype=jnp.int32)
    got = from_words(wo.shr(ws, s))
    want = [v >> shift for v in vals]
    assert got == want


@settings(max_examples=50, deadline=None)
@given(vals=st.lists(st.integers(0, (1 << 100) - 1), min_size=1, max_size=16),
       shift=st.integers(0, 150))
def test_shl_within_width(vals, shift):
    # shifts that stay inside 256 bits must be exact
    ws = to_words(vals, 4)
    s = jnp.full(len(vals), shift, dtype=jnp.int32)
    got = from_words(wo.shl(ws, s))
    want = [(v << shift) & ((1 << 256) - 1) for v in vals]
    assert got == want


@settings(max_examples=50, deadline=None)
@given(vals=ints256, inc=st.lists(st.integers(0, 1), min_size=16, max_size=16))
def test_add_small(vals, inc):
    vals = (vals * 16)[:16]
    ws = to_words(vals, 4)
    iv = jnp.array(inc, dtype=jnp.uint64)
    got = from_words(wo.add_small(ws, iv))
    want = [(v + i) & ((1 << 256) - 1) for v, i in zip(vals, inc)]
    assert got == want


@settings(max_examples=50, deadline=None)
@given(vals=ints256, nbits=st.integers(0, 256))
def test_mask_low_static(vals, nbits):
    ws = to_words(vals, 4)
    got = from_words(wo.mask_low_static(ws, nbits))
    want = [v & ((1 << nbits) - 1) for v in vals]
    assert got == want


def test_is_zero_and_select():
    ws = to_words([0, 5, 1 << 200], 4)
    assert list(np.asarray(wo.is_zero(ws))) == [True, False, False]
    other = to_words([7, 7, 7], 4)
    cond = jnp.array([True, False, True])
    sel = from_words(wo.select(cond, ws, other))
    assert sel == [0, 7, 1 << 200]


def test_const_words():
    ws = wo.const_words((1 << 130) | 5, 4, 3)
    assert from_words(ws) == [(1 << 130) | 5] * 3


def test_shift_helpers_edge_64():
    # n == 64 must yield 0, not UB
    x = jnp.array([MASK64], dtype=jnp.uint64)
    assert int(np.asarray(wo._shl64(x, jnp.array([64])))[0]) == 0
    assert int(np.asarray(wo._shr64(x, jnp.array([64])))[0]) == 0
    assert int(np.asarray(wo.bitlen64(x))[0]) == 64
    assert int(np.asarray(wo.bitlen64(jnp.array([0], dtype=jnp.uint64)))[0]) == 0
