"""Layer-1 kernel correctness: Pallas vs pure-jnp ref vs host big-int.

The CORE correctness signal for the compiled artifacts: the CIVP tile
structure must produce the exact integer product for every scheme, every
batch shape, and adversarial operand patterns. Hypothesis drives the
shape/value sweeps.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import limbmul, ref
from compile.kernels.schemes import BY_NAME, DOUBLE, QUAD, SINGLE

SCHEMES = [SINGLE, DOUBLE, QUAD]


def chunk_arrays(scheme, vals):
    return jnp.array([ref.int_to_chunks(v, scheme) for v in vals], dtype=jnp.int64)


def run_kernel(scheme, avals, bvals, tile):
    a = chunk_arrays(scheme, avals)
    b = chunk_arrays(scheme, bvals)
    return np.asarray(limbmul.sig_mul(scheme, a, b, tile))


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.name)
def test_kernel_matches_bigint_oracle(scheme):
    rng = np.random.default_rng(42)
    B = 128
    avals = [int.from_bytes(rng.bytes(16), "little") % (1 << scheme.sig_bits) for _ in range(B)]
    bvals = [int.from_bytes(rng.bytes(16), "little") % (1 << scheme.sig_bits) for _ in range(B)]
    out = run_kernel(scheme, avals, bvals, 64)
    for i in range(B):
        assert ref.limb24_to_int(out[i]) == avals[i] * bvals[i]


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.name)
def test_kernel_matches_jnp_ref(scheme):
    rng = np.random.default_rng(43)
    B = 128
    avals = [int.from_bytes(rng.bytes(16), "little") % (1 << scheme.sig_bits) for _ in range(B)]
    bvals = [int.from_bytes(rng.bytes(16), "little") % (1 << scheme.sig_bits) for _ in range(B)]
    a = chunk_arrays(scheme, avals)
    b = chunk_arrays(scheme, bvals)
    out = np.asarray(limbmul.sig_mul(scheme, a, b, 128))
    out_ref = np.asarray(ref.sig_mul_ref(scheme, a, b))
    np.testing.assert_array_equal(out, out_ref)


@pytest.mark.parametrize("scheme", SCHEMES, ids=lambda s: s.name)
def test_kernel_edge_operands(scheme):
    bits = scheme.sig_bits
    edge = [0, 1, (1 << bits) - 1, 1 << (bits - 1), ((1 << bits) - 1) >> 1, 0b1010 % (1 << bits)]
    # all pairs, padded to a full tile
    pairs = [(x, y) for x in edge for y in edge]
    while len(pairs) % 36 != 0:
        pairs.append((0, 0))
    avals = [p[0] for p in pairs]
    bvals = [p[1] for p in pairs]
    out = run_kernel(scheme, avals, bvals, len(pairs))
    for i, (x, y) in enumerate(pairs):
        assert ref.limb24_to_int(out[i]) == x * y, (x, y)


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(["single", "double", "quad"]),
    seed=st.integers(0, 2**32 - 1),
    tiles=st.integers(1, 4),
    tile=st.sampled_from([32, 64, 128]),
)
def test_kernel_shape_sweep(name, seed, tiles, tile):
    """Hypothesis sweep over batch shapes and block sizes."""
    scheme = BY_NAME[name]
    B = tiles * tile
    rng = np.random.default_rng(seed)
    avals = [int.from_bytes(rng.bytes(16), "little") % (1 << scheme.sig_bits) for _ in range(B)]
    bvals = [int.from_bytes(rng.bytes(16), "little") % (1 << scheme.sig_bits) for _ in range(B)]
    out = run_kernel(scheme, avals, bvals, tile)
    assert out.shape == (B, scheme.n_limb24)
    idx = rng.integers(0, B, size=min(16, B))
    for i in idx:
        assert ref.limb24_to_int(out[i]) == avals[i] * bvals[i]


@settings(max_examples=50, deadline=None)
@given(
    name=st.sampled_from(["single", "double", "quad"]),
    a=st.integers(min_value=0),
    b=st.integers(min_value=0),
)
def test_kernel_single_pair_property(name, a, b):
    """Any operand pair multiplies exactly (values reduced mod 2^W)."""
    scheme = BY_NAME[name]
    a %= 1 << scheme.sig_bits
    b %= 1 << scheme.sig_bits
    out = run_kernel(scheme, [a] * 32, [b] * 32, 32)
    assert ref.limb24_to_int(out[0]) == a * b


def test_scheme_structure_matches_paper():
    """Fig. 2 / Fig. 4 chunk structure pinned."""
    assert SINGLE.chunks == (24,)
    assert DOUBLE.chunks == (24, 24, 9)
    assert DOUBLE.padded_bits == 57
    assert QUAD.chunks == (24, 24, 9, 24, 24, 9)
    assert QUAD.padded_bits == 114
    # tile census matches Fig. 2(b): four 24x24, four 24x9, one 9x9
    kinds = DOUBLE.block_kinds()
    assert kinds.count("24x24") == 4
    assert kinds.count("24x9") == 4
    assert kinds.count("9x9") == 1
    # Fig. 4: 16 / 16 / 4
    kinds = QUAD.block_kinds()
    assert kinds.count("24x24") == 16
    assert kinds.count("24x9") == 16
    assert kinds.count("9x9") == 4


def test_chunk_roundtrip():
    rng = np.random.default_rng(7)
    for scheme in SCHEMES:
        for _ in range(50):
            v = int.from_bytes(rng.bytes(16), "little") % (1 << scheme.sig_bits)
            assert ref.chunks_to_int(ref.int_to_chunks(v, scheme), scheme) == v


def test_kernel_rejects_misaligned_batch():
    with pytest.raises(AssertionError):
        a = jnp.zeros((100, 1), dtype=jnp.int64)
        limbmul.sig_mul(SINGLE, a, a, 64)
