"""Exercise `tools/check_bench.py` itself — both verdicts.

The bench gate is load-bearing CI: a bug that makes it vacuously pass
would silently disable every perf invariant in the repo. These tests
drive the script as a subprocess over synthetic artifacts and pin the
parallel-executor efficiency gate added with `BENCH_parallel.json`:

* pass path — monotone model curves with >= 2x speedup at 4 cores;
* fail paths — a non-monotonic curve, an insufficient speedup, and a
  missing core point each exit 1 with a targeted message;
* `parallel/wall-*` rows are wall-clock: never written into the
  baseline by `--update`, so runner core counts cannot gate PRs.

Later gates (simd ablation, net conservation + knee, wide-class
karatsuba ablation) are pinned the same way further down: pass shape,
each targeted failure message, and baseline exclusion for their
machine-dependent rows.
"""

import json
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
CHECK = REPO / "python" / "tools" / "check_bench.py"


def row(name, p50):
    return {"name": name, "ns_per_op_p50": p50, "ops_per_sec": 1e9 / p50 if p50 else 0.0}


def write_artifact(path, rows):
    path.write_text(json.dumps(rows))
    return path


def run_gate(tmp_path, *args):
    """Run check_bench.py from `tmp_path`; returns (exit_code, stdout)."""
    proc = subprocess.run(
        [sys.executable, str(CHECK), *map(str, args)],
        cwd=tmp_path,
        capture_output=True,
        text=True,
    )
    return proc.returncode, proc.stdout + proc.stderr


def parallel_rows(curves):
    """`{batch: {cores: p50}}` -> model rows plus one wall row."""
    rows = [row("parallel/wall-double-b1024/cores-4", 123.0)]
    for batch, by_cores in curves.items():
        for cores, p50 in by_cores.items():
            rows.append(row(f"parallel/model-scaling-b{batch}-{cores}core", p50))
    return rows


GOOD_CURVES = {
    128: {1: 40.0, 2: 40.0, 4: 40.0, 8: 40.0},  # below threshold: flat is legal
    8192: {1: 100.0, 2: 50.0, 4: 25.0, 8: 12.5},  # 4x at 4 cores
}


def test_parallel_gate_passes_on_monotone_curves(tmp_path):
    art = write_artifact(tmp_path / "BENCH_parallel.json", parallel_rows(GOOD_CURVES))
    code, out = run_gate(tmp_path, art.name)
    assert code == 0, out
    assert "parallel scaling (ok)" in out


def test_parallel_gate_fails_on_non_monotonic_curve(tmp_path):
    bad = {8192: {1: 100.0, 2: 50.0, 4: 60.0, 8: 12.5}}  # 4 cores slower than 2
    art = write_artifact(tmp_path / "BENCH_parallel.json", parallel_rows(bad))
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "not monotonic" in out


def test_parallel_gate_fails_on_insufficient_speedup(tmp_path):
    bad = {8192: {1: 100.0, 2: 90.0, 4: 80.0, 8: 70.0}}  # only 1.25x at 4 cores
    art = write_artifact(tmp_path / "BENCH_parallel.json", parallel_rows(bad))
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "parallel speedup" in out and "2.0" in out


def test_parallel_gate_fails_on_missing_core_point(tmp_path):
    bad = {8192: {1: 100.0, 2: 50.0, 8: 12.5}}  # no 4-core row
    art = write_artifact(tmp_path / "BENCH_parallel.json", parallel_rows(bad))
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "missing the 1-core or 4-core point" in out


def test_update_never_baselines_wall_rows(tmp_path):
    art = write_artifact(tmp_path / "BENCH_parallel.json", parallel_rows(GOOD_CURVES))
    code, out = run_gate(tmp_path, art.name, "--update", "--baseline", "BL.json")
    assert code == 0, out
    names = [r["name"] for r in json.loads((tmp_path / "BL.json").read_text())]
    assert not any(n.startswith("parallel/wall-") for n in names), names
    assert any(n.startswith("parallel/model-scaling-") for n in names), names


def test_baseline_regression_still_fires_on_model_rows(tmp_path):
    # The parallel model rows are deterministic, so they DO gate against
    # the committed baseline: a 2x regression must fail.
    art = write_artifact(tmp_path / "BENCH_parallel.json", parallel_rows(GOOD_CURVES))
    write_artifact(
        tmp_path / "BL.json", [{"name": "parallel/model-scaling-b8192-4core", "ns_per_op_p50": 10.0}]
    )
    code, out = run_gate(tmp_path, art.name, "--baseline", "BL.json")
    assert code == 1, out
    assert "regressed" in out


def simd_rows(matrix):
    """`{(class, width): {isa: p50}}` -> ablation-matrix rows."""
    rows = []
    for (cls, width), by_isa in matrix.items():
        for isa, p50 in by_isa.items():
            rows.append(row(f"lanes/simd-{cls}/{width}-{isa}", p50))
    return rows


GOOD_MATRIX = {
    ("double", "w8"): {"scalar": 100.0, "avx2": 60.0},
    ("double", "w16"): {"scalar": 95.0, "avx2": 55.0, "avx512": 40.0},
    ("quad", "w8"): {"scalar": 400.0},  # scalar-only host: no pair to gate
}


def test_simd_gate_passes_when_simd_beats_scalar(tmp_path):
    art = write_artifact(tmp_path / "BENCH_lanes.json", simd_rows(GOOD_MATRIX))
    code, out = run_gate(tmp_path, art.name)
    assert code == 0, out
    assert "simd sweeps beat same-width scalar on all 3 measured rows" in out


def test_simd_gate_fails_when_simd_slower_than_scalar(tmp_path):
    bad = {("double", "w16"): {"scalar": 95.0, "avx2": 120.0}}  # inversion
    art = write_artifact(tmp_path / "BENCH_lanes.json", simd_rows(bad))
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "simd sweep slower than scalar for double w16-avx2" in out


def test_simd_gate_fails_on_missing_scalar_sibling(tmp_path):
    bad = {("double", "w16"): {"avx2": 55.0}}  # no scalar row for the width
    art = write_artifact(tmp_path / "BENCH_lanes.json", simd_rows(bad))
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "no scalar sibling" in out


def test_simd_gate_tolerates_small_noise(tmp_path):
    # Within LANES_NOISE_SLACK (5%) the gate must not flake.
    noisy = {("double", "w8"): {"scalar": 100.0, "avx2": 104.0}}
    art = write_artifact(tmp_path / "BENCH_lanes.json", simd_rows(noisy))
    code, out = run_gate(tmp_path, art.name)
    assert code == 0, out


def test_update_never_baselines_simd_rows(tmp_path):
    # The matrix rows depend on which ISA the runner offers, so --update
    # must not pin them (a baselined avx512 row would fail strict mode on
    # a runner without avx512).
    rows = simd_rows(GOOD_MATRIX) + [row("lanes/civp-double/lane-path", 80.0)]
    art = write_artifact(tmp_path / "BENCH_lanes.json", rows)
    code, out = run_gate(tmp_path, art.name, "--update", "--baseline", "BL.json")
    assert code == 0, out
    names = [r["name"] for r in json.loads((tmp_path / "BL.json").read_text())]
    assert not any(n.startswith("lanes/simd-") for n in names), names
    assert "lanes/civp-double/lane-path" in names


def test_strict_mode_requires_parallel_artifact(tmp_path):
    # CI runs with no file args: every required artifact must exist, and
    # BENCH_parallel.json is now one of them.
    required = [
        "BENCH_e2e.json",
        "BENCH_plan.json",
        "BENCH_cluster.json",
        "BENCH_lanes.json",
        "BENCH_formats.json",
        "BENCH_net.json",
        "BENCH_net_sweep.json",
    ]
    for name in required:
        write_artifact(tmp_path / name, [row("dummy/" + name, 1.0)])
    code, out = run_gate(tmp_path)
    assert code == 1, out
    assert "required artifact BENCH_parallel.json missing" in out


def count_row(name, n):
    """A loadgen count row: the count lives in total_ops, timings zeroed."""
    return {
        "name": name,
        "ns_per_op_p50": 0.0,
        "ns_per_op_mean": 0.0,
        "ns_per_op_min": 0.0,
        "ops_per_sec": 0.0,
        "total_ops": n,
    }


def net_rows(mix, p50, p99, p999, sent, ok, saturated, other, lost):
    prefix = f"net/{mix}"
    return [
        row(f"{prefix}/latency-p50", p50),
        row(f"{prefix}/latency-p99", p99),
        row(f"{prefix}/latency-p999", p999),
        row(f"{prefix}/throughput", 500.0),
        count_row(f"{prefix}/frames-sent", sent),
        count_row(f"{prefix}/replies-ok", ok),
        count_row(f"{prefix}/replies-saturated", saturated),
        count_row(f"{prefix}/replies-other", other),
        count_row(f"{prefix}/lost", lost),
    ]


GOOD_NET = net_rows("mixed", 1000.0, 5000.0, 9000.0, 2000, 1900, 100, 0, 0) + net_rows(
    "ml", 800.0, 4000.0, 7000.0, 2000, 2000, 0, 0, 0
)


def test_net_gate_passes_on_conserved_replies(tmp_path):
    art = write_artifact(tmp_path / "BENCH_net.json", GOOD_NET)
    code, out = run_gate(tmp_path, art.name)
    assert code == 0, out
    assert "net percentile order + reply conservation over 2 mix(es)" in out


def test_net_gate_fails_on_percentile_inversion(tmp_path):
    bad = net_rows("mixed", 5000.0, 1000.0, 9000.0, 100, 100, 0, 0, 0)  # p50 > p99
    art = write_artifact(tmp_path / "BENCH_net.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "latency percentiles out of order" in out


def test_net_gate_fails_on_lost_replies(tmp_path):
    bad = net_rows("mixed", 1000.0, 5000.0, 9000.0, 2000, 1990, 0, 0, 10)
    art = write_artifact(tmp_path / "BENCH_net.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "10 lost replies" in out


def test_net_gate_fails_when_replies_not_conserved(tmp_path):
    # Saturated replies must show up in the totals: ok + saturated +
    # other + lost != sent means the server double-replied or the
    # generator miscounted.
    bad = net_rows("mixed", 1000.0, 5000.0, 9000.0, 2000, 1900, 50, 0, 0)
    art = write_artifact(tmp_path / "BENCH_net.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "replies not conserved" in out


def test_net_gate_fails_on_missing_count_row(tmp_path):
    rows = [r for r in GOOD_NET if r["name"] != "net/mixed/replies-saturated"]
    art = write_artifact(tmp_path / "BENCH_net.json", rows)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "count row `replies-saturated` missing" in out


def test_update_never_baselines_net_rows(tmp_path):
    # net latencies are wall time over a real socket — pinning them would
    # gate PRs on runner load. Sweep rows share the net/ prefix, so they
    # are equally unbaselineable.
    rows = (
        GOOD_NET
        + sweep_rows("mixed", 4, [("1000", 1000.0, 0), ("2000", 1200.0, 0)])
        + [row("lanes/civp-double/lane-path", 80.0)]
    )
    art = write_artifact(tmp_path / "BENCH_net.json", rows)
    code, out = run_gate(tmp_path, art.name, "--update", "--baseline", "BL.json")
    assert code == 0, out
    names = [r["name"] for r in json.loads((tmp_path / "BL.json").read_text())]
    assert not any(n.startswith("net/") for n in names), names
    assert "lanes/civp-double/lane-path" in names


def karatsuba_rows(cls, naive, kara, tiles_naive, tiles_kara):
    """One wide class's ablation quartet from bench_formats."""
    prefix = f"formats/wide-{cls}"
    return [
        row(f"{prefix}/naive-x64", naive),
        row(f"{prefix}/karatsuba-x64", kara),
        row(f"{prefix}/tile-count-naive", float(tiles_naive)),
        row(f"{prefix}/tile-count-karatsuba", float(tiles_kara)),
    ]


# The real census: fp512/fp256 karatsuba tile ratio 243/75 = 3.24x, below
# the 4x a quadratic tiler would pay for the doubled width.
GOOD_KARATSUBA = karatsuba_rows("fp256", 900.0, 500.0, 169, 75) + karatsuba_rows(
    "fp512", 3600.0, 1500.0, 676, 243
)


def test_karatsuba_gate_passes_on_subquadratic_census(tmp_path):
    art = write_artifact(tmp_path / "BENCH_formats.json", GOOD_KARATSUBA)
    code, out = run_gate(tmp_path, art.name)
    assert code == 0, out
    assert "karatsuba beats naive tiling on 2 wide class(es)" in out


def test_karatsuba_gate_fails_when_karatsuba_slower(tmp_path):
    bad = karatsuba_rows("fp256", 500.0, 900.0, 169, 75) + karatsuba_rows(
        "fp512", 3600.0, 1500.0, 676, 243
    )
    art = write_artifact(tmp_path / "BENCH_formats.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "karatsuba batch slower than naive all-pairs for wide-fp256" in out


def test_karatsuba_gate_fails_when_tile_count_not_below_naive(tmp_path):
    bad = karatsuba_rows("fp256", 900.0, 500.0, 169, 169) + karatsuba_rows(
        "fp512", 3600.0, 1500.0, 676, 243
    )
    art = write_artifact(tmp_path / "BENCH_formats.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "karatsuba tile count not below naive for wide-fp256" in out


def test_karatsuba_gate_fails_on_quadratic_tile_growth(tmp_path):
    # fp512 at 300 tiles makes the fp256 -> fp512 ratio exactly 4x: the
    # boundary is exclusive, so quadratic growth must fail.
    bad = karatsuba_rows("fp256", 900.0, 500.0, 169, 75) + karatsuba_rows(
        "fp512", 3600.0, 1500.0, 676, 300
    )
    art = write_artifact(tmp_path / "BENCH_formats.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "4.00x" in out and "not sub-quadratic" in out


def test_karatsuba_gate_fails_on_missing_naive_sibling(tmp_path):
    bad = [r for r in GOOD_KARATSUBA if r["name"] != "formats/wide-fp512/naive-x64"]
    art = write_artifact(tmp_path / "BENCH_formats.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "no naive sibling `formats/wide-fp512/naive-x64`" in out


def test_karatsuba_gate_fails_on_missing_tile_census(tmp_path):
    bad = [r for r in GOOD_KARATSUBA if "tile-count" not in r["name"]]
    art = write_artifact(tmp_path / "BENCH_formats.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "tile-count rows missing" in out


def test_karatsuba_gate_tolerates_small_noise(tmp_path):
    # Within LANES_NOISE_SLACK (5%) the batch-timing leg must not flake;
    # the tile-census legs stay exact.
    noisy = karatsuba_rows("fp256", 500.0, 520.0, 169, 75) + karatsuba_rows(
        "fp512", 3600.0, 1500.0, 676, 243
    )
    art = write_artifact(tmp_path / "BENCH_formats.json", noisy)
    code, out = run_gate(tmp_path, art.name)
    assert code == 0, out


def test_update_never_baselines_wide_rows(tmp_path):
    # The wide-ablation timings are machine-dependent wall time and the
    # tile counts are pseudo-measurements — neither belongs in the
    # baseline. The formats/civp-* rows still do.
    rows = GOOD_KARATSUBA + [row("formats/civp-double/batch-x256", 40.0)]
    art = write_artifact(tmp_path / "BENCH_formats.json", rows)
    code, out = run_gate(tmp_path, art.name, "--update", "--baseline", "BL.json")
    assert code == 0, out
    names = [r["name"] for r in json.loads((tmp_path / "BL.json").read_text())]
    assert not any(n.startswith("formats/wide-") for n in names), names
    assert "formats/civp-double/batch-x256" in names


def sweep_rows(mix, workers, points):
    """`points` = [(rate_label, p99_ns, lost)] -> offered-load sweep rows."""
    prefix = f"net/{mix}"
    rows = [count_row(f"{prefix}/sweep-workers", workers)] if workers else []
    for label, p99, lost in points:
        rows.append(row(f"{prefix}/p50@{label}", p99 / 2.0))
        rows.append(row(f"{prefix}/p99@{label}", p99))
        rows.append(count_row(f"{prefix}/lost@{label}", lost))
    return rows


# Flat through 2000 req/s (p99 within 3x of the best), blows up at 4000:
# the knee sits at 2000, comfortably above 4 workers x 50 req/s = 200.
GOOD_SWEEP = sweep_rows(
    "mixed", 4, [("1000", 1000.0, 0), ("2000", 1800.0, 0), ("4000", 9000.0, 0)]
)


def test_knee_gate_passes_and_locates_the_knee(tmp_path):
    art = write_artifact(tmp_path / "BENCH_net_sweep.json", GOOD_SWEEP)
    code, out = run_gate(tmp_path, art.name)
    assert code == 0, out
    assert "net knee ok (mixed): knee @ 2000 req/s" in out


def test_knee_gate_fails_when_knee_below_worker_floor(tmp_path):
    # 4 workers -> floor 200 req/s; a curve already past 3x slack at
    # 150 req/s pins the knee at 100, below the floor.
    bad = sweep_rows("mixed", 4, [("100", 1000.0, 0), ("150", 5000.0, 0)])
    art = write_artifact(tmp_path / "BENCH_net_sweep.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "below the floor 200" in out


def test_knee_gate_fails_on_lost_replies_at_any_rate(tmp_path):
    bad = sweep_rows("mixed", 4, [("1000", 1000.0, 0), ("2000", 1800.0, 3)])
    art = write_artifact(tmp_path / "BENCH_net_sweep.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "3 lost replies at swept rate 2000" in out


def test_knee_gate_fails_without_sweep_workers_row(tmp_path):
    # Without the pool size the floor is meaningless — the run must
    # declare what it was sized for.
    bad = sweep_rows("mixed", None, [("1000", 1000.0, 0), ("2000", 1800.0, 0)])
    art = write_artifact(tmp_path / "BENCH_net_sweep.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "`sweep-workers` row is missing" in out


def test_knee_gate_fails_when_curve_has_no_flat_region(tmp_path):
    # p99 at the lowest rate is already past 3x the sweep's best: no
    # prefix qualifies, so there is no knee to locate.
    bad = sweep_rows("mixed", 4, [("100", 9000.0, 0), ("200", 1000.0, 0)])
    art = write_artifact(tmp_path / "BENCH_net_sweep.json", bad)
    code, out = run_gate(tmp_path, art.name)
    assert code == 1, out
    assert "no flat region" in out
