"""Layer-2 model correctness: the full IEEE pipeline vs hardware (fp32/64)
and vs the independent host big-int oracle (all precisions)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ieee_mul_bits

B = 256
TILE = 128


def nasty_bits(rng, total, eb, fb):
    kind = rng.integers(0, 8)
    emask = (1 << eb) - 1
    if kind == 0:
        return int.from_bytes(rng.bytes(16), "little") % (1 << total)
    if kind == 1:
        return 0
    if kind == 2:  # subnormal
        return int.from_bytes(rng.bytes(16), "little") % (1 << fb)
    if kind == 3:  # near overflow
        return ((emask - 1) << fb) | (int.from_bytes(rng.bytes(16), "little") % (1 << fb))
    if kind == 4:  # min normal
        return (1 << fb) | (int.from_bytes(rng.bytes(16), "little") % (1 << fb))
    if kind == 5:  # all-ones significand
        return (int(rng.integers(0, emask)) << fb) | ((1 << fb) - 1)
    if kind == 6:  # power of two
        return int(rng.integers(0, emask)) << fb
    return (int(rng.integers(0, emask + 1)) << fb) | (1 << int(rng.integers(0, fb)))


def is_qnan(bits, eb, fb):
    emask = (1 << eb) - 1
    return ((bits >> fb) & emask) == emask and (bits & ((1 << fb) - 1)) != 0


def check_all(got_bits, av, bv, fmt, eb, fb):
    bad = []
    for i, (a, b) in enumerate(zip(av, bv)):
        want = ieee_mul_bits(a, b, fmt)
        got = got_bits[i]
        if is_qnan(want, eb, fb):
            ok = is_qnan(got, eb, fb)
        else:
            ok = got == want
        if not ok:
            bad.append((i, a, b, got, want))
    assert not bad, f"{len(bad)} mismatches, first: {bad[0]}"


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fp64_vs_hardware(seed):
    rng = np.random.default_rng(seed)
    av = [nasty_bits(rng, 64, 11, 52) for _ in range(B)]
    bv = [nasty_bits(rng, 64, 11, 52) for _ in range(B)]
    out = np.asarray(
        model.mul_fp64(jnp.array(av, dtype=jnp.uint64), jnp.array(bv, dtype=jnp.uint64), TILE)
    )
    for i in range(B):
        a = np.uint64(av[i]).view(np.float64)
        b = np.uint64(bv[i]).view(np.float64)
        with np.errstate(all="ignore"):
            hw = a * b
        got = int(out[i])
        if np.isnan(hw):
            assert is_qnan(got, 11, 52), (hex(av[i]), hex(bv[i]))
        else:
            assert got == int(np.float64(hw).view(np.uint64)), (hex(av[i]), hex(bv[i]), hex(got))


@pytest.mark.parametrize("seed", [10, 11, 12])
def test_fp32_vs_hardware(seed):
    rng = np.random.default_rng(seed)
    av = [nasty_bits(rng, 32, 8, 23) for _ in range(B)]
    bv = [nasty_bits(rng, 32, 8, 23) for _ in range(B)]
    out = np.asarray(
        model.mul_fp32(jnp.array(av, dtype=jnp.uint32), jnp.array(bv, dtype=jnp.uint32), TILE)
    )
    for i in range(B):
        a = np.uint32(av[i]).view(np.float32)
        b = np.uint32(bv[i]).view(np.float32)
        with np.errstate(all="ignore"):
            hw = np.float32(a * b)
        got = int(out[i])
        if np.isnan(hw):
            assert is_qnan(got, 8, 23)
        else:
            assert got == int(np.float32(hw).view(np.uint32)), (hex(av[i]), hex(bv[i]))


@pytest.mark.parametrize("seed", [20, 21, 22, 23])
def test_fp128_vs_bigint_oracle(seed):
    rng = np.random.default_rng(seed)
    av = [nasty_bits(rng, 128, 15, 112) for _ in range(B)]
    bv = [nasty_bits(rng, 128, 15, 112) for _ in range(B)]
    aw = jnp.array([[v & ((1 << 64) - 1), v >> 64] for v in av], dtype=jnp.uint64)
    bw = jnp.array([[v & ((1 << 64) - 1), v >> 64] for v in bv], dtype=jnp.uint64)
    out = np.asarray(model.mul_fp128(aw, bw, TILE))
    got_bits = [int(out[i][0]) | (int(out[i][1]) << 64) for i in range(B)]
    check_all(got_bits, av, bv, "quad", 15, 112)


def test_fp64_specials_lattice():
    INF = 0x7FF0000000000000
    NINF = 0xFFF0000000000000
    QNAN = 0x7FF8000000000000
    ONE = 0x3FF0000000000000
    NZERO = 0x8000000000000000
    cases = [
        (INF, 0, "nan"), (0, INF, "nan"), (QNAN, ONE, "nan"), (ONE, QNAN, "nan"),
        (INF, ONE, INF), (INF, NINF, NINF), (NINF, NINF, INF),
        (0, ONE, 0), (NZERO, ONE, NZERO), (NZERO, NZERO, 0),
        (ONE, ONE, ONE),
    ]
    while len(cases) % TILE != 0:
        cases.append((ONE, ONE, ONE))
    av = jnp.array([c[0] for c in cases], dtype=jnp.uint64)
    bv = jnp.array([c[1] for c in cases], dtype=jnp.uint64)
    out = np.asarray(model.mul_fp64(av, bv, TILE))
    for i, (_, _, want) in enumerate(cases):
        got = int(out[i])
        if want == "nan":
            assert is_qnan(got, 11, 52), i
        else:
            assert got == want, (i, hex(got), hex(want))


def test_fp64_subnormal_results():
    rng = np.random.default_rng(99)
    # tiny * tiny products that land subnormal or underflow to zero
    av, bv = [], []
    for _ in range(B):
        av.append((int(rng.integers(1, 64)) << 52) | int(rng.integers(0, 1 << 52)))
        bv.append((int(rng.integers(1, 64)) << 52) | int(rng.integers(0, 1 << 52)))
    out = np.asarray(
        model.mul_fp64(jnp.array(av, dtype=jnp.uint64), jnp.array(bv, dtype=jnp.uint64), TILE)
    )
    got_bits = [int(v) for v in out]
    check_all(got_bits, av, bv, "double", 11, 52)


@settings(max_examples=40, deadline=None)
@given(a=st.integers(0, (1 << 64) - 1), b=st.integers(0, (1 << 64) - 1))
def test_fp64_hypothesis_pairs(a, b):
    out = np.asarray(
        model.mul_fp64(
            jnp.full(TILE, a, dtype=jnp.uint64), jnp.full(TILE, b, dtype=jnp.uint64), TILE
        )
    )
    want = ieee_mul_bits(a, b, "double")
    got = int(out[0])
    if is_qnan(want, 11, 52):
        assert is_qnan(got, 11, 52)
    else:
        assert got == want


@settings(max_examples=25, deadline=None)
@given(a=st.integers(0, (1 << 128) - 1), b=st.integers(0, (1 << 128) - 1))
def test_fp128_hypothesis_pairs(a, b):
    aw = jnp.tile(jnp.array([[a & ((1 << 64) - 1), a >> 64]], dtype=jnp.uint64), (TILE, 1))
    bw = jnp.tile(jnp.array([[b & ((1 << 64) - 1), b >> 64]], dtype=jnp.uint64), (TILE, 1))
    out = np.asarray(model.mul_fp128(aw, bw, TILE))
    got = int(out[0][0]) | (int(out[0][1]) << 64)
    want = ieee_mul_bits(a, b, "quad")
    if is_qnan(want, 15, 112):
        assert is_qnan(got, 15, 112)
    else:
        assert got == want


def test_fp128_commutative_batch():
    rng = np.random.default_rng(5)
    av = [nasty_bits(rng, 128, 15, 112) for _ in range(B)]
    bv = [nasty_bits(rng, 128, 15, 112) for _ in range(B)]
    aw = jnp.array([[v & ((1 << 64) - 1), v >> 64] for v in av], dtype=jnp.uint64)
    bw = jnp.array([[v & ((1 << 64) - 1), v >> 64] for v in bv], dtype=jnp.uint64)
    ab = np.asarray(model.mul_fp128(aw, bw, TILE))
    ba = np.asarray(model.mul_fp128(bw, aw, TILE))
    np.testing.assert_array_equal(ab, ba)
