//! The "combined integer" half of CIVP: integer DSP on the same blocks.
//!
//! ```bash
//! cargo run --release --example dsp_filter
//! ```
//!
//! The paper's §I/§III point out that the proposed 24x24/24x9/9x9 block set
//! must remain efficient for plain *integer* multiplication — FPGAs serve
//! DSP kernels first. This example runs a 32-tap FIR filter over synthetic
//! 16-bit audio three ways:
//!
//! * direct i64 arithmetic (oracle),
//! * CIVP-decomposed 16-bit integer multiplies,
//! * 18x18-decomposed multiplies (legacy baseline),
//!
//! verifies all three agree sample-for-sample, and compares block usage and
//! simulated energy for the integer workload.

use civp::decomp::{execute, ExecStats, Scheme, SchemeKind};
use civp::fabric::{adder_tree_depth, CostModel};
use civp::proput::Rng;
use civp::wideint::U128;

const TAPS: usize = 32;
const SAMPLES: usize = 4096;
const WIDTH: u32 = 16; // 16-bit audio samples and coefficients

/// One FIR output via decomposed multiplies, tallying block usage.
fn fir_decomposed(
    scheme: &Scheme,
    window: &[i64],
    coeffs: &[i64],
    stats: &mut ExecStats,
) -> i64 {
    let mut acc = 0i64;
    for (&x, &c) in window.iter().zip(coeffs) {
        // sign/magnitude through the unsigned block array (hardware does
        // Baugh-Wooley; sign-magnitude keeps the example simple and exact)
        let sign = (x < 0) ^ (c < 0);
        let prod = execute(
            scheme,
            U128::from_u64(x.unsigned_abs()),
            U128::from_u64(c.unsigned_abs()),
            stats,
        );
        let mag = prod.as_u128() as i64;
        acc += if sign { -mag } else { mag };
    }
    acc
}

fn main() {
    let mut rng = Rng::new(77);
    // synthetic "audio": sum of two tones + noise, 16-bit signed
    let signal: Vec<i64> = (0..SAMPLES)
        .map(|i| {
            let t = i as f64 / 48_000.0;
            let tone = 12_000.0 * (2.0 * std::f64::consts::PI * 440.0 * t).sin()
                + 6_000.0 * (2.0 * std::f64::consts::PI * 1_000.0 * t).sin();
            let noise = (rng.f64() - 0.5) * 2_000.0;
            (tone + noise) as i64
        })
        .collect();
    // low-pass-ish random coefficients, 16-bit
    let coeffs: Vec<i64> = (0..TAPS).map(|_| rng.range(0, 1 << 14) as i64 - (1 << 13)).collect();

    let civp_scheme = Scheme::for_int(SchemeKind::Civp, WIDTH);
    let b18_scheme = Scheme::for_int(SchemeKind::Baseline18, WIDTH);
    println!(
        "16-bit integer multiply mapping: civp -> {:?} chunks, 18x18 -> {:?} chunks",
        civp_scheme.a_chunks, b18_scheme.a_chunks
    );

    let mut civp_stats = ExecStats::default();
    let mut b18_stats = ExecStats::default();
    let mut mismatches = 0;
    for i in TAPS..SAMPLES {
        let window = &signal[i - TAPS..i];
        let direct: i64 = window.iter().zip(&coeffs).map(|(&x, &c)| x * c).sum();
        let civp = fir_decomposed(&civp_scheme, window, &coeffs, &mut civp_stats);
        let b18 = fir_decomposed(&b18_scheme, window, &coeffs, &mut b18_stats);
        if civp != direct || b18 != direct {
            mismatches += 1;
        }
    }
    assert_eq!(mismatches, 0, "decomposed FIR diverged from direct arithmetic");
    println!("FIR over {} samples, {} taps: all outputs exact ✓", SAMPLES - TAPS, TAPS);

    let cost = CostModel::default();
    let per_mul_civp = civp_scheme.tiles();
    let per_mul_b18 = b18_scheme.tiles();
    let civp_energy: f64 =
        per_mul_civp.iter().map(|t| cost.block_energy(t.kind)).sum::<f64>()
            + cost.adder_energy(per_mul_civp.len(), civp_scheme.padded_bits);
    let b18_energy: f64 = per_mul_b18.iter().map(|t| cost.block_energy(t.kind)).sum::<f64>()
        + cost.adder_energy(per_mul_b18.len(), b18_scheme.padded_bits);

    println!("\nper 16x16 multiply:");
    println!(
        "  civp : {} block(s), energy {civp_energy:.3}, adder depth {}",
        per_mul_civp.len(),
        adder_tree_depth(per_mul_civp.len())
    );
    println!(
        "  18x18: {} block(s), energy {b18_energy:.3}, adder depth {}",
        per_mul_b18.len(),
        adder_tree_depth(per_mul_b18.len())
    );
    println!("\ntotal blocks fired:");
    println!(
        "  civp : {:?} (utilization {:.1}%)",
        civp_stats.by_kind(),
        civp_stats.utilization() * 100.0
    );
    println!(
        "  18x18: {:?} (utilization {:.1}%)",
        b18_stats.by_kind(),
        b18_stats.utilization() * 100.0
    );
    println!("\ndsp_filter OK");
}
