//! Quickstart: the CIVP library in five minutes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the three layers: (1) the decomposition engine multiplying real
//! IEEE values through the paper's block structure, (2) the fabric
//! simulator pricing those blocks, (3) the serving coordinator.

use civp::config::ServiceConfig;
use civp::coordinator::{BackendChoice, Service};
use civp::decomp::{scheme_census, DecompMul, ExecStats, OpClass, PlanCache, Scheme, SchemeKind};
use civp::fabric::{schedule_op, CostModel, FabricConfig};
use civp::fpu::{Fp128, Fp32, Fp64, FpuBatch, RoundMode};
use civp::wideint::U128;

fn main() {
    // ------------------------------------------------------------------
    // 1. IEEE multiplication through the CIVP decomposition
    // ------------------------------------------------------------------
    println!("== 1. CIVP-decomposed IEEE multiplication ==");
    let mut civp_mul = DecompMul::new(SchemeKind::Civp);

    let (r32, _) =
        Fp32::from_f32(3.5).mul_with(Fp32::from_f32(-2.0), RoundMode::NearestEven, &mut civp_mul);
    println!("single: 3.5 x -2.0      = {}", r32.to_f32());

    let (r64, _) =
        Fp64::from_f64(0.1).mul_with(Fp64::from_f64(0.2), RoundMode::NearestEven, &mut civp_mul);
    println!("double: 0.1 x 0.2       = {:.17}", r64.to_f64());
    assert_eq!(r64.to_f64(), 0.1 * 0.2); // bit-exact vs hardware

    let (r128, _) = Fp128::from_f64(1e200).mul_with(
        Fp128::from_f64(1e100),
        RoundMode::NearestEven,
        &mut civp_mul,
    );
    println!("quad:   1e200 x 1e100   = {:e} (113-bit significand)", r128.to_f64_lossy());

    println!("\nblocks fired so far: {:?}", civp_mul.stats.by_kind());
    println!("array utilization:   {:.1}%", civp_mul.stats.utilization() * 100.0);

    // ------------------------------------------------------------------
    // 2. What does each multiplication cost on the fabric?
    // ------------------------------------------------------------------
    println!("\n== 2. fabric cost per multiplication ==");
    let cost = CostModel::default();
    let civp_fabric = FabricConfig::civp_default();
    let legacy_fabric = FabricConfig::legacy_default();
    for prec in OpClass::ALL {
        let civp = schedule_op(&Scheme::new(SchemeKind::Civp, prec), &civp_fabric, &cost);
        let legacy = schedule_op(&Scheme::new(SchemeKind::Baseline18, prec), &legacy_fabric, &cost);
        println!(
            "{:<7} civp: {} cyc, {:.2} energy ({:.0}% useful) | 18x18: {} cyc, {:.2} energy ({:.0}% useful)",
            prec.name(),
            civp.latency_cycles,
            civp.dyn_energy,
            civp.useful_energy / civp.dyn_energy * 100.0,
            legacy.latency_cycles,
            legacy.dyn_energy,
            legacy.useful_energy / legacy.dyn_energy * 100.0,
        );
    }

    // Block counts straight from the paper's figures:
    let fig2 = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Double));
    println!(
        "\nFig. 2(b) check — double precision: {} blocks ({} 24x24 + {} 24x9 + {} 9x9)",
        fig2.total_blocks,
        fig2.count(civp::decomp::BlockKind::M24x24),
        fig2.count(civp::decomp::BlockKind::M24x9),
        fig2.count(civp::decomp::BlockKind::M9x9),
    );

    // ------------------------------------------------------------------
    // 3. Compiled tile plans — the hot path behind every multiply above
    // ------------------------------------------------------------------
    println!("\n== 3. compiled tile plans (process-wide cache) ==");
    for prec in OpClass::ALL {
        let plan = PlanCache::get(SchemeKind::Civp, prec);
        println!(
            "{:<7} plan: {} pre-resolved steps for a {}-bit product",
            prec.name(),
            plan.steps().len(),
            plan.width(),
        );
    }
    // A plan executes the exact integer product with no per-call planning:
    let plan = PlanCache::get(SchemeKind::Civp, OpClass::Double);
    let mut stats = ExecStats::default();
    let p = plan.execute(U128::from_u64(3 << 50), U128::from_u64(5 << 50), &mut stats);
    println!("plan.execute(3<<50 x 5<<50) -> {} (stats: {} tiles)", p.to_hex(), stats.tiles);

    // Batches take the lane path: tiles outer, lanes inner, over SoA
    // blocks — one fused call multiplies the whole batch (specials are
    // peeled into a scalar sidecar, so NaN/Inf/zero still come out
    // bit-exact).
    let mut fpu = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
    let xs: Vec<Fp64> = [1.5, -2.25, f64::INFINITY, 0.1].map(Fp64::from_f64).to_vec();
    let ys: Vec<Fp64> = [4.0, 2.0, 0.0, 0.2].map(Fp64::from_f64).to_vec();
    let mut prods = Vec::new();
    let flags = fpu.mul_batch(&xs, &ys, RoundMode::NearestEven, &mut prods);
    println!(
        "lane batch: 1.5x4.0 = {}, -2.25x2.0 = {}, inf x 0 = {} (invalid={}), 0.1x0.2 = {:.17}",
        prods[0].to_f64(),
        prods[1].to_f64(),
        prods[2].to_f64(),
        flags.invalid,
        prods[3].to_f64(),
    );

    // ------------------------------------------------------------------
    // 4. The serving coordinator
    // ------------------------------------------------------------------
    println!("\n== 4. variable-precision multiplication service ==");
    let cfg = ServiceConfig::default();
    let svc = Service::start(&cfg, BackendChoice::native(SchemeKind::Civp));
    let product = svc.mul_blocking(
        OpClass::Double,
        (6.0f64).to_bits() as u128,
        (7.0f64).to_bits() as u128,
    );
    println!("service: 6.0 x 7.0 = {}", f64::from_bits(product.as_u64()));
    let report = svc.shutdown();
    println!("service handled {} request(s); backend = {}", report.responses, report.backend);
    println!("\nquickstart OK");
}
