//! Adaptive-precision computational geometry — the paper's motivating
//! application ([5] Shewchuk).
//!
//! ```bash
//! cargo run --release --example adaptive_geometry
//! ```
//!
//! Builds a 2-D convex hull twice: once with naive double-precision
//! orientation tests (which mis-classify near-collinear triples) and once
//! with the adaptive single→double→quad escalation running through the
//! CIVP multiplication service. Points are placed on a tilted grid so many
//! triples are *exactly* collinear — the adversarial case for floating
//! point. The adaptive hull matches the exact-rational oracle; the naive
//! one generally does not.

use civp::config::ServiceConfig;
use civp::coordinator::{orient2d_adaptive, AdaptiveStats, BackendChoice, Orient, Service};
use civp::decomp::SchemeKind;
use civp::proput::Rng;

type P = (f64, f64);

/// Exact orientation via i128 arithmetic on scaled-integer coordinates.
fn orient_exact(a: P, b: P, c: P, scale: f64) -> i32 {
    let s = |x: f64| (x * scale).round() as i128;
    let det = (s(a.0) - s(c.0)) * (s(b.1) - s(c.1)) - (s(a.1) - s(c.1)) * (s(b.0) - s(c.0));
    det.signum() as i32
}

/// Naive double-precision orientation.
fn orient_naive(a: P, b: P, c: P) -> i32 {
    let det = (a.0 - c.0) * (b.1 - c.1) - (a.1 - c.1) * (b.0 - c.0);
    if det > 0.0 {
        1
    } else if det < 0.0 {
        -1
    } else {
        0
    }
}

/// Andrew's monotone-chain hull, parameterized by the orientation test.
fn hull(points: &[P], mut orient: impl FnMut(P, P, P) -> i32) -> Vec<P> {
    let mut pts = points.to_vec();
    pts.sort_by(|p, q| p.partial_cmp(q).unwrap());
    pts.dedup();
    if pts.len() < 3 {
        return pts;
    }
    let mut out: Vec<P> = Vec::new();
    for phase in 0..2 {
        let start = out.len();
        let iter: Box<dyn Iterator<Item = &P>> =
            if phase == 0 { Box::new(pts.iter()) } else { Box::new(pts.iter().rev()) };
        for &p in iter {
            while out.len() >= start + 2
                && orient(out[out.len() - 2], out[out.len() - 1], p) <= 0
            {
                out.pop();
            }
            out.push(p);
        }
        out.pop();
    }
    out
}

fn main() {
    let cfg = ServiceConfig::default();
    let svc = Service::start(&cfg, BackendChoice::native(SchemeKind::Civp));
    let mut stats = AdaptiveStats::default();

    // Points on a tilted lattice: coordinates i*2^12 + j*2^-26 (exactly
    // representable in f64, 48-bit values), so orientation determinants
    // need ~96 bits — far beyond double precision. Many triples are
    // *exactly* collinear on the integer lattice; naive f64 predicates
    // misclassify them, the adaptive quad path cannot.
    let mut rng = Rng::new(42);
    let mut points: Vec<P> = Vec::new();
    let (big, tiny) = (4096.0, (1.0 / (1u64 << 26) as f64));
    for _ in 0..600 {
        let i = rng.below(1024) as f64;
        let j = rng.below(1024) as f64;
        let x = i * big + j * tiny;
        let y = i * tiny + j * big;
        points.push((x, y));
    }

    // Exact hull (oracle), naive hull, adaptive hull.
    let s = (1u64 << 26) as f64; // coords * 2^26 are integers (< 2^48)
    let exact = hull(&points, |a, b, c| orient_exact(a, b, c, s));
    let naive = hull(&points, orient_naive);
    let adaptive = hull(&points, |a, b, c| {
        match orient2d_adaptive(&svc, a, b, c, &mut stats) {
            Orient::Ccw => 1,
            Orient::Cw => -1,
            Orient::Collinear => 0,
        }
    });

    println!("points:        {}", points.len());
    println!("exact hull:    {} vertices", exact.len());
    println!("naive f64:     {} vertices", naive.len());
    println!("adaptive:      {} vertices", adaptive.len());
    println!(
        "\nescalation stats: single={} double={} quad={} (of {} predicates)",
        stats.settled_single,
        stats.settled_double,
        stats.settled_quad,
        stats.total()
    );

    assert_eq!(
        adaptive, exact,
        "adaptive hull must match the exact-rational oracle"
    );
    println!(
        "naive hull {} the oracle",
        if naive == exact { "matches (lucky draw)" } else { "DIFFERS from" }
    );

    // ------------------------------------------------------------------
    // Exactly-collinear stress: P3 = P1 + 2*(P2-P1) stays on the lattice
    // and on the line. The determinant terms need ~96 bits, so the f32 and
    // f64 filters cannot *certify* the sign — every one of these triples
    // must escalate to quad, where the comparison is exact. (Naive f64
    // happens to survive exact-difference collinear inputs because its two
    // product roundings cancel; the filter cannot know that, which is
    // precisely why the paper's variable-precision traffic exists.)
    // ------------------------------------------------------------------
    let lattice = |i: f64, j: f64| (i * big + j * tiny, i * tiny + j * big);
    let mut adaptive_wrong = 0;
    let quad_before = stats.settled_quad;
    let n_triples = 2000;
    for _ in 0..n_triples {
        let (i1, j1) = (rng.below(512) as f64, rng.below(512) as f64);
        let (i2, j2) = (rng.below(512) as f64, rng.below(512) as f64);
        let p1 = lattice(i1, j1);
        let p2 = lattice(i2, j2);
        let p3 = lattice(2.0 * i2 - i1, 2.0 * j2 - j1); // exactly collinear
        if orient2d_adaptive(&svc, p1, p2, p3, &mut stats) != Orient::Collinear {
            adaptive_wrong += 1;
        }
    }
    let quad_used = stats.settled_quad - quad_before;
    println!(
        "\nexactly-collinear triples ({n_triples}): adaptive wrong on {adaptive_wrong}, {quad_used} escalated to quad"
    );
    assert_eq!(adaptive_wrong, 0, "adaptive predicate must be exact");
    assert_eq!(quad_used, n_triples as u64, "collinear inputs cannot settle below quad");

    // What the fabric saw: this is the single→quad traffic mix the paper
    // says FPGAs should serve with one block family.
    let fabric = svc.fabric_report();
    println!("\nfabric traffic:");
    for class in &fabric.per_class {
        println!("  {:<16} {:>8} ops", class.label, class.ops);
    }
    println!("fabric energy/op: {:.3} (wasted {:.1}%)",
        fabric.energy_per_op(), fabric.wasted_fraction() * 100.0);
    println!("\nadaptive_geometry OK");
}
