//! End-to-end driver (E7): the full three-layer system on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving_pipeline
//! ```
//!
//! Drives a mixed-precision multimedia trace through the coordinator twice:
//!
//! 1. **PJRT backend** — requests execute in the AOT-compiled JAX/Pallas
//!    artifacts (Layer 1+2) through the PJRT runtime, proving all layers
//!    compose, with results cross-checked against the native softfloat.
//! 2. **Native backend** — CIVP fabric vs legacy 18x18 fabric accounting,
//!    reproducing the paper's headline claim (full block utilization →
//!    lower energy per op) on serving traffic.
//!
//! Reported: throughput, p50/p99 latency, simulated fabric energy/op and
//! wasted-energy fraction. Numbers land in EXPERIMENTS.md E7.

use civp::config::ServiceConfig;
use civp::coordinator::{BackendChoice, Service};
use civp::decomp::SchemeKind;
use civp::fabric::FabricKind;
use civp::decomp::OpClass;
use civp::fpu::{mul_bits_wide, DirectMul, Fp128, Fp32, Fp64, RoundMode};
use civp::runtime::EngineHandle;
use civp::trace::{TraceGen, TraceRequest, WorkloadSpec};
use civp::wideint::PackedBits;
use std::time::Instant;

const REQUESTS: usize = 30_000;

fn drive(svc: &Service, trace: &[TraceRequest]) -> (f64, Vec<PackedBits>) {
    let t0 = Instant::now();
    let mut results = vec![PackedBits::ZERO; trace.len()];
    let mut pending: Vec<(usize, civp::coordinator::ReplyHandle)> = Vec::with_capacity(4096);
    for (idx, req) in trace.iter().enumerate() {
        pending.push((idx, svc.submit(req.id, req.class, req.a, req.b).unwrap()));
        if pending.len() >= 4096 {
            for (i, rx) in pending.drain(..) {
                results[i] = rx.recv().unwrap().bits;
            }
        }
    }
    for (i, rx) in pending.drain(..) {
        results[i] = rx.recv().unwrap().bits;
    }
    (t0.elapsed().as_secs_f64(), results)
}

fn verify_against_softfloat(trace: &[TraceRequest], results: &[PackedBits]) -> usize {
    let mut checked = 0;
    for (req, &got) in trace.iter().zip(results) {
        let (a, b) = (req.a, req.b);
        let want = match req.class {
            OpClass::Bf16 => PackedBits::from_u64(
                civp::fpu::Bf16(a.as_u64() as u16).mul(civp::fpu::Bf16(b.as_u64() as u16)).0 as u64,
            ),
            OpClass::Half => PackedBits::from_u64(
                civp::fpu::Fp16(a.as_u64() as u16).mul(civp::fpu::Fp16(b.as_u64() as u16)).0 as u64,
            ),
            OpClass::Single => {
                PackedBits::from_u64(Fp32(a.as_u64() as u32).mul(Fp32(b.as_u64() as u32)).0 as u64)
            }
            OpClass::Double => PackedBits::from_u64(Fp64(a.as_u64()).mul(Fp64(b.as_u64())).0),
            OpClass::Quad => PackedBits::from_u128(Fp128(a.as_u128()).mul(Fp128(b.as_u128())).0),
            OpClass::Fp256 | OpClass::Fp512 => {
                mul_bits_wide(req.class.format(), a, b, RoundMode::NearestEven, &mut DirectMul).0
            }
        };
        assert_eq!(got, want, "req {} ({:?}) diverged", req.id, req.class);
        checked += 1;
    }
    checked
}

fn report(label: &str, svc: Service, wall: f64, n: usize) {
    let fabric = svc.fabric_report();
    let rep = svc.shutdown();
    println!("\n---- {label} ----");
    println!("requests        {n}");
    println!("wall            {wall:.3} s");
    println!("throughput      {:.0} mult/s", n as f64 / wall);
    for p in civp::decomp::OpClass::ALL.map(|c| c.name()) {
        if let Some(h) = rep.snapshot.hists.get(&format!("latency_ns_{p}")) {
            println!(
                "latency {p:<7} p50={:>9} ns   p99={:>9} ns   (n={})",
                h.p50, h.p99, h.count
            );
        }
    }
    println!("fabric          {}", fabric.fabric);
    println!("  cycles        {}", fabric.cycles);
    println!("  energy/op     {:.3}", fabric.energy_per_op());
    println!("  wasted energy {:.1}%", fabric.wasted_fraction() * 100.0);
}

fn main() {
    let workload = WorkloadSpec::Graphics;
    let trace = TraceGen::new(20260710, workload.mix(), 0).take(REQUESTS);
    println!(
        "workload `{}`: {} requests ({} single / {} double / {} quad)",
        workload.name(),
        trace.len(),
        trace.iter().filter(|r| r.class == civp::decomp::OpClass::Single).count(),
        trace.iter().filter(|r| r.class == civp::decomp::OpClass::Double).count(),
        trace.iter().filter(|r| r.class == civp::decomp::OpClass::Quad).count(),
    );

    // ------------------------------------------------------------------
    // 1. Full three-layer path: PJRT artifacts behind the coordinator.
    // ------------------------------------------------------------------
    match EngineHandle::load("artifacts") {
        Ok(handle) => {
            let info = handle.info().unwrap();
            println!("\nPJRT engine: platform={} batch={}", info.platform, info.batch);
            let cfg = ServiceConfig {
                max_batch: info.batch,
                linger_us: 500,
                ..ServiceConfig::default()
            };
            let svc = Service::start(&cfg, BackendChoice::Pjrt(handle.clone()));
            let (wall, results) = drive(&svc, &trace);
            let checked = verify_against_softfloat(&trace, &results);
            println!("PJRT results verified against softfloat: {checked}/{}", trace.len());
            report("PJRT backend (JAX/Pallas artifacts)", svc, wall, trace.len());
            handle.stop();
        }
        Err(e) => {
            println!("\n(skipping PJRT pass: {e:#}; run `make artifacts`)");
        }
    }

    // ------------------------------------------------------------------
    // 2. Fabric comparison: CIVP vs legacy 18x18 on the same trace.
    // ------------------------------------------------------------------
    let civp_cfg = ServiceConfig::default();
    let svc = Service::start(&civp_cfg, BackendChoice::native(SchemeKind::Civp));
    let (wall, civp_results) = drive(&svc, &trace);
    report("native backend, CIVP fabric", svc, wall, trace.len());

    let legacy_cfg = ServiceConfig {
        scheme: SchemeKind::Baseline18,
        fabric: FabricKind::Legacy,
        ..ServiceConfig::default()
    };
    let svc = Service::start(&legacy_cfg, BackendChoice::native(SchemeKind::Baseline18));
    let (wall, legacy_results) = drive(&svc, &trace);
    assert_eq!(civp_results, legacy_results, "organizations must agree bit-for-bit");
    report("native backend, legacy 18x18 fabric", svc, wall, trace.len());

    println!("\nserving_pipeline OK (all backends bit-identical)");
}
