//! Parallel-equivalence harness: the work-stealing [`Executor`] must be
//! **bit-for-bit** indistinguishable from the single-threaded lane path —
//! products, output order, merged [`ExecStats`], and (through
//! [`FpuBatch`]) IEEE results and flag unions — for every `SchemeKind ×
//! OpClass`, every ragged tail, worker counts 1–8 and batch sizes
//! straddling the parallel threshold.
//!
//! These tests pin the executor's one hard promise: turning on `--cores`
//! changes wall-clock time and *nothing else*.

use civp::decomp::{
    chunk_plan, DecompMul, ExecStats, Executor, LaneConfig, LaneWidth, OpClass, PlanCache,
    SchemeKind, SimdIsa, LANES,
};
use civp::fpu::{FpFormat, FpuBatch, RoundMode, BF16, DOUBLE, HALF, QUAD, SINGLE};
use civp::proput::{forall, Rng};
use civp::wideint::{U128, U256};
use std::sync::Arc;

/// The classes whose significands fit the executor's `U128` batch path.
/// The wide classes (Fp256/Fp512) run `Plan::execute_batch_wide` — their
/// batch ≡ scalar equivalence is pinned in `plan_equiv.rs` and
/// `decomp::tests`, and the service-level stress covers them in parallel.
fn narrow_classes() -> Vec<OpClass> {
    OpClass::ALL.into_iter().filter(|c| !c.is_wide()).collect()
}

/// Batch sizes worth pinning: empty, sub-block, block ± 1, straddling the
/// test threshold (64) and well past it with every tail residue.
const SIZES: [usize; 10] = [0, 1, 7, 63, 64, 65, 256, 257, 777, 1024];

fn run_seq(
    plan: &civp::decomp::Plan,
    a: &[U128],
    b: &[U128],
) -> (Vec<U256>, ExecStats) {
    let mut stats = ExecStats::default();
    let mut out = Vec::new();
    plan.execute_batch(a, b, &mut stats, &mut out);
    (out, stats)
}

fn run_par(
    exec: &Executor,
    plan: &civp::decomp::Plan,
    a: &[U128],
    b: &[U128],
) -> (Vec<U256>, ExecStats) {
    let mut stats = ExecStats::default();
    let mut out = Vec::new();
    exec.execute_batch(plan, a, b, &mut stats, &mut out);
    (out, stats)
}

#[test]
fn executor_matches_sequential_every_class_scheme_and_tail() {
    // The core property: for every registry class × scheme organization ×
    // batch size (ragged tails included), the parallel path produces the
    // same products in the same order with the same merged stats.
    let exec = Executor::with_threshold(3, 64);
    let mut rng = Rng::new(0x720);
    for prec in narrow_classes() {
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get(kind, prec);
            for n in SIZES {
                let a: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                let b: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                let (out_seq, seq) = run_seq(&plan, &a, &b);
                let (out_par, par) = run_par(&exec, &plan, &a, &b);
                assert_eq!(out_seq, out_par, "{kind:?} {prec:?} n={n}");
                assert_eq!(seq, par, "{kind:?} {prec:?} n={n} stats diverged");
            }
        }
    }
}

#[test]
fn executor_matches_sequential_for_worker_counts_1_through_8() {
    // The worker count is a pure throughput knob: 1 worker, 8 workers and
    // an oversubscribed pool (more workers than chunks, more chunks than
    // workers) all produce identical bits. Sizes straddle the threshold so
    // both the sequential fallback and the fan-out path are exercised at
    // every pool size.
    let plan = PlanCache::get(SchemeKind::Civp, OpClass::Double);
    let mut rng = Rng::new(0x721);
    for workers in 1..=8 {
        let exec = Executor::with_threshold(workers, 64);
        assert_eq!(exec.workers(), workers);
        for n in [63, 64, 65, 512, 1000] {
            let a: Vec<U128> = (0..n).map(|_| rng.sig(53)).collect();
            let b: Vec<U128> = (0..n).map(|_| rng.sig(53)).collect();
            let (out_seq, seq) = run_seq(&plan, &a, &b);
            let (out_par, par) = run_par(&exec, &plan, &a, &b);
            assert_eq!(out_seq, out_par, "workers={workers} n={n}");
            assert_eq!(seq, par, "workers={workers} n={n} stats diverged");
        }
        // The big batches really fanned out (512 and 1000 always split
        // into >= 2 chunks at every pool size), and every chunk ran
        // exactly once — across workers and helping submitters.
        let c = exec.counters();
        assert!(c.parallel_batches >= 2, "workers={workers}: {c:?}");
        let full = 512 - 512 % LANES;
        let (_, chunks) = chunk_plan(full, workers, LANES);
        assert!(chunks >= 2, "chunk_plan must split 512 at workers={workers}");
        let ran: u64 = c.workers.iter().map(|w| w.executed).sum::<u64>() + c.helper_executed;
        assert!(ran > 0, "workers={workers}: no chunk ever executed");
    }
}

#[test]
fn executor_matches_sequential_every_lane_width_and_isa() {
    // The width-parameterized engine keeps the executor's one hard
    // promise at every block width × every ISA this build + CPU can
    // dispatch: chunks stay block-aligned to the configured width, and
    // products / order / merged stats are identical to the scalar
    // sequential path. Sizes cover every residue class mod the widest
    // block so each width sees full blocks, a ragged lane tail, and the
    // chunked fan-out path.
    let mut rng = Rng::new(0x726);
    for width in LaneWidth::ALL {
        for isa in SimdIsa::ALL {
            if !isa.available() {
                continue;
            }
            let lane = LaneConfig { width, isa };
            let exec = Executor::with_config(3, 64, lane);
            assert_eq!(exec.lane_config(), lane);
            for prec in [OpClass::Single, OpClass::Double, OpClass::Quad] {
                let plan = PlanCache::get(SchemeKind::Civp, prec);
                for n in [0, 1, width.width() - 1, width.width() + 1, 256, 256 + 7, 777] {
                    let a: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                    let b: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                    let (out_seq, seq) = run_seq(&plan, &a, &b);
                    let (out_par, par) = run_par(&exec, &plan, &a, &b);
                    assert_eq!(out_seq, out_par, "{} {prec:?} n={n}", lane.kernel_name());
                    assert_eq!(seq, par, "{} {prec:?} n={n} stats", lane.kernel_name());
                }
            }
        }
    }
}

#[test]
fn fpu_batch_matches_across_lane_widths_end_to_end() {
    // Full IEEE pipeline: a parallel FpuBatch at each width ≡ the plain
    // single-threaded one — packed results, flag unions, and stats —
    // over nasty inputs (specials, subnormals).
    let mut rng = Rng::new(0x727);
    for width in LaneWidth::ALL {
        let lane = LaneConfig::detect(width);
        let exec = Arc::new(Executor::with_config(4, 16, lane));
        for fmt in [&HALF, &DOUBLE, &QUAD] {
            let n = 300 + width.width();
            let a: Vec<u128> = (0..n).map(|_| nasty_packed(&mut rng, fmt)).collect();
            let b: Vec<u128> = (0..n).map(|_| nasty_packed(&mut rng, fmt)).collect();

            let mut par = FpuBatch::new(DecompMul::with_executor(SchemeKind::Civp, exec.clone()));
            let mut out_par = Vec::new();
            let flags_par = par.mul_batch_bits(fmt, &a, &b, RoundMode::NearestEven, &mut out_par);

            let mut seq = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
            let mut out_seq = Vec::new();
            let flags_seq = seq.mul_batch_bits(fmt, &a, &b, RoundMode::NearestEven, &mut out_seq);

            assert_eq!(out_par, out_seq, "{} {}", lane.kernel_name(), fmt.name);
            assert_eq!(flags_par, flags_seq, "{} {} flags", lane.kernel_name(), fmt.name);
            assert_eq!(
                par.multiplier().stats,
                seq.multiplier().stats,
                "{} {} stats",
                lane.kernel_name(),
                fmt.name
            );
        }
        assert!(exec.counters().parallel_batches > 0, "{width:?} never fanned out");
    }
}

#[test]
fn executor_matches_sequential_randomized() {
    // Randomized sweep: random class, scheme, size (biased around the
    // threshold) and a shared executor — the configuration space between
    // the pinned sizes above.
    let exec = Executor::with_threshold(4, 64);
    forall(0x722, 60, |rng| {
        let narrow = narrow_classes();
        let prec = narrow[rng.below(narrow.len() as u64) as usize];
        let kind = SchemeKind::ALL[rng.below(SchemeKind::ALL.len() as u64) as usize];
        let plan = PlanCache::get(kind, prec);
        let n = rng.range(1, 700) as usize;
        let a: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
        let b: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
        let (out_seq, seq) = run_seq(&plan, &a, &b);
        let (out_par, par) = run_par(&exec, &plan, &a, &b);
        assert_eq!(out_seq, out_par, "{kind:?} {prec:?} n={n}");
        assert_eq!(seq, par, "{kind:?} {prec:?} n={n} stats diverged");
    });
}

#[test]
fn executor_integer_widths_match_sequential() {
    // The "combined integer" half rides the executor too: arbitrary
    // operand widths through `PlanCache::get_width`.
    let exec = Executor::with_threshold(2, 64);
    forall(0x723, 40, |rng| {
        let width = rng.range(2, 128) as u32;
        let kind = SchemeKind::ALL[rng.below(SchemeKind::ALL.len() as u64) as usize];
        let plan = PlanCache::get_width(kind, width);
        let n = rng.range(64, 400) as usize;
        let a: Vec<U128> = (0..n).map(|_| rng.sig(width)).collect();
        let b: Vec<U128> = (0..n).map(|_| rng.sig(width)).collect();
        let (out_seq, seq) = run_seq(&plan, &a, &b);
        let (out_par, par) = run_par(&exec, &plan, &a, &b);
        assert_eq!(out_seq, out_par, "{kind:?} w={width} n={n}");
        assert_eq!(seq, par, "{kind:?} w={width} n={n} stats diverged");
    });
}

/// Nasty packed bit patterns for any registry format (specials included),
/// mirrored from `plan_equiv.rs` — specials exercise the sidecar peel
/// *around* the parallel significand multiply.
fn nasty_packed(rng: &mut Rng, fmt: &FpFormat) -> u128 {
    let frac_mask = (1u128 << fmt.frac_bits) - 1;
    let rand_wide = |rng: &mut Rng| ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128;
    match rng.below(7) {
        0 => 0,
        1 => ((fmt.exp_mask() as u128) << fmt.frac_bits)
            | ((rng.below(2) as u128) << (fmt.total_bits() - 1)), // ±inf
        2 => ((fmt.exp_mask() as u128) << fmt.frac_bits) | (1u128 << (fmt.frac_bits - 1)), // qNaN
        3 => rand_wide(rng) & frac_mask, // subnormal
        _ => {
            let sign = (rng.below(2) as u128) << (fmt.total_bits() - 1);
            let exp = rng.below(fmt.exp_mask() as u64) as u128;
            sign | (exp << fmt.frac_bits) | (rand_wide(rng) & frac_mask)
        }
    }
}

#[test]
fn fpu_batch_on_executor_matches_sequential_results_flags_and_stats() {
    // End to end through the IEEE pipeline: an `FpuBatch` whose multiplier
    // fans out across the executor ≡ the plain single-threaded `FpuBatch`
    // — packed results, the batch flag union, and the multiplier's block
    // accounting — over nasty inputs (specials, subnormals), every
    // registry format and every rounding mode.
    let exec = Arc::new(Executor::with_threshold(4, 16));
    forall(0x724, 40, |rng| {
        let mode = RoundMode::ALL[rng.below(5) as usize];
        for fmt in [&BF16, &HALF, &SINGLE, &DOUBLE, &QUAD] {
            let n = rng.range(200, 600) as usize;
            let a: Vec<u128> = (0..n).map(|_| nasty_packed(rng, fmt)).collect();
            let b: Vec<u128> = (0..n).map(|_| nasty_packed(rng, fmt)).collect();

            let mut par = FpuBatch::new(DecompMul::with_executor(SchemeKind::Civp, exec.clone()));
            let mut out_par = Vec::new();
            let flags_par = par.mul_batch_bits(fmt, &a, &b, mode, &mut out_par);

            let mut seq = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
            let mut out_seq = Vec::new();
            let flags_seq = seq.mul_batch_bits(fmt, &a, &b, mode, &mut out_seq);

            assert_eq!(out_par, out_seq, "{} {mode:?}", fmt.name);
            assert_eq!(flags_par, flags_seq, "{} {mode:?} flag union", fmt.name);
            assert_eq!(
                par.multiplier().stats,
                seq.multiplier().stats,
                "{} {mode:?} stats",
                fmt.name
            );
        }
    });
    // The big nasty batches really exercised the fan-out path.
    assert!(exec.counters().parallel_batches > 0, "{:?}", exec.counters());
}

#[test]
fn executor_is_shareable_and_reusable_across_plans() {
    // One executor serves interleaved batches from different plans and
    // widths without cross-talk — the deployment shape (`Arc` shared by
    // every backend) in miniature, sequentially.
    let exec = Arc::new(Executor::with_threshold(2, 64));
    let mut rng = Rng::new(0x725);
    for round in 0..3 {
        for prec in narrow_classes() {
            let plan = PlanCache::get(SchemeKind::Civp, prec);
            let n = 300 + 17 * round;
            let a: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
            let b: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
            let (out_seq, seq) = run_seq(&plan, &a, &b);
            let (out_par, par) = run_par(&exec, &plan, &a, &b);
            assert_eq!(out_seq, out_par, "{prec:?} round={round}");
            assert_eq!(seq, par, "{prec:?} round={round}");
        }
    }
    let c = exec.counters();
    assert_eq!(c.workers.len(), 2);
    assert!(c.parallel_batches + c.sequential_batches >= 15);
}
