//! Concurrency stress harness for the work-stealing [`Executor`]: many
//! submitting threads hammer ONE shared executor across every registry
//! class and scheme organization, each checking its own batches
//! bit-for-bit against the single-threaded oracle. This is the test the
//! raw-pointer `BatchJob` protocol answers to — disjoint chunk writes,
//! the AcqRel completion handoff, helper draining and steal races all
//! run hot here.
//!
//! Iteration counts default to a CI-friendly size so tier-1 `cargo test`
//! stays quick; the dedicated CI stress job sets `CIVP_STRESS_FULL=1`
//! (release mode) to multiply the load.

use civp::config::ServiceConfig;
use civp::coordinator::{BackendChoice, NativeOptions, Service};
use civp::serve::AdmissionError;
use civp::decomp::{DecompMul, ExecStats, Executor, OpClass, PlanCache, SchemeKind};
use civp::fpu::{FpuBatch, RoundMode};
use civp::proput::Rng;
use civp::wideint::U128;
use std::sync::Arc;

/// Stress scale: (submitting threads, batches per thread).
fn scale() -> (usize, usize) {
    if std::env::var_os("CIVP_STRESS_FULL").is_some() {
        (8, 150)
    } else {
        (4, 25)
    }
}

#[test]
fn many_submitters_one_executor_all_classes_and_schemes() {
    // Every thread draws random (class, scheme, size) batches, runs them
    // through the shared executor and through a private sequential plan,
    // and asserts bit-equality of products and merged stats. Any lost
    // chunk, double-executed chunk, torn write or misordered stats merge
    // shows up as a mismatch on some thread.
    let (threads, iters) = scale();
    let exec = Arc::new(Executor::with_threshold(4, 64));
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let exec = exec.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(0x730 + t as u64);
                // The executor's batch path is U128-based; the wide classes'
                // batch equivalence runs in decomp::tests::wide_batch_matches_scalar
                // and through the service-level stress below.
                let narrow: Vec<OpClass> =
                    OpClass::ALL.into_iter().filter(|c| !c.is_wide()).collect();
                for i in 0..iters {
                    let prec = narrow[rng.below(narrow.len() as u64) as usize];
                    let kind =
                        SchemeKind::ALL[rng.below(SchemeKind::ALL.len() as u64) as usize];
                    let plan = PlanCache::get(kind, prec);
                    let n = rng.range(64, 1500) as usize;
                    let a: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                    let b: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                    let (mut seq, mut par) = (ExecStats::default(), ExecStats::default());
                    let (mut out_seq, mut out_par) = (Vec::new(), Vec::new());
                    plan.execute_batch(&a, &b, &mut seq, &mut out_seq);
                    exec.execute_batch(&plan, &a, &b, &mut par, &mut out_par);
                    assert_eq!(out_seq, out_par, "t={t} i={i} {kind:?} {prec:?} n={n}");
                    assert_eq!(seq, par, "t={t} i={i} {kind:?} {prec:?} n={n} stats");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Accounting stayed coherent under contention: chunks executed is
    // consistent with batches fanned out (every parallel batch has >= 2
    // chunks), and the big batches did fan out.
    let c = exec.counters();
    assert!(c.parallel_batches > 0, "{c:?}");
    let ran: u64 = c.workers.iter().map(|w| w.executed).sum::<u64>() + c.helper_executed;
    assert!(ran >= 2 * c.parallel_batches, "{c:?}");
}

#[test]
fn many_submitters_fpu_pipeline_with_specials() {
    // Same hammer one layer up: concurrent `FpuBatch` pipelines (specials
    // sidecar + parallel significand multiply + batched finish) against
    // private sequential pipelines — results, flag unions and block
    // accounting — so the executor races inside its real call site.
    let (threads, iters) = scale();
    let iters = iters / 2 + 1;
    let exec = Arc::new(Executor::with_threshold(4, 16));
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let exec = exec.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(0x731 + t as u64);
                let mut par =
                    FpuBatch::new(DecompMul::with_executor(SchemeKind::Civp, exec.clone()));
                let mut seq = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
                let narrow: Vec<OpClass> =
                    OpClass::ALL.into_iter().filter(|c| !c.is_wide()).collect();
                for i in 0..iters {
                    let prec = narrow[rng.below(narrow.len() as u64) as usize];
                    let fmt = prec.format();
                    let mode = RoundMode::ALL[rng.below(5) as usize];
                    let n = rng.range(100, 800) as usize;
                    let wide = |rng: &mut Rng| {
                        (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128)
                            & ((1u128 << (fmt.total_bits() - 1)) | ((1u128 << (fmt.total_bits() - 1)) - 1))
                    };
                    let a: Vec<u128> = (0..n).map(|_| wide(&mut rng)).collect();
                    let b: Vec<u128> = (0..n).map(|_| wide(&mut rng)).collect();
                    let (mut out_par, mut out_seq) = (Vec::new(), Vec::new());
                    let fp = par.mul_batch_bits(fmt, &a, &b, mode, &mut out_par);
                    let fs = seq.mul_batch_bits(fmt, &a, &b, mode, &mut out_seq);
                    assert_eq!(out_par, out_seq, "t={t} i={i} {} {mode:?}", fmt.name);
                    assert_eq!(fp, fs, "t={t} i={i} {} {mode:?} flags", fmt.name);
                }
                assert_eq!(
                    par.multiplier().stats,
                    seq.multiplier().stats,
                    "t={t} accumulated stats"
                );
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn service_on_shared_executor_under_concurrent_load() {
    // The full deployment shape: a `Service` whose worker backends all
    // share one executor, hammered by concurrent submitters over every
    // registry class, then drained. Every accepted request must get its
    // (exact, 1.0 × 1.0) reply and the counters must balance.
    let (threads, iters) = scale();
    let per_thread = (iters * 20) as u64;
    let cfg = ServiceConfig {
        workers: 2,
        max_batch: 256,
        linger_us: 200,
        ..ServiceConfig::default()
    };
    let exec = Arc::new(Executor::with_threshold(2, 64));
    let svc = Arc::new(Service::start(
        &cfg,
        BackendChoice::Native(NativeOptions::new(SchemeKind::Civp).executor(exec.clone())),
    ));
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let mut pending = Vec::new();
                for i in 0..per_thread {
                    let class =
                        OpClass::from_index(((t as u64 + i) % OpClass::COUNT as u64) as usize);
                    let one = class.format().one_w();
                    match svc.submit(i, class, one, one) {
                        Ok(rx) => pending.push((one, rx)),
                        Err(AdmissionError::Draining) => {
                            unreachable!("nobody closes during load")
                        }
                        Err(e) => panic!("unexpected {e:?}"),
                    }
                    if pending.len() >= 512 {
                        for (one, rx) in pending.drain(..) {
                            assert_eq!(rx.recv().unwrap().bits, one);
                        }
                    }
                }
                for (one, rx) in pending {
                    assert_eq!(rx.recv().unwrap().bits, one);
                }
                per_thread
            })
        })
        .collect();
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    svc.drain();
    let snap = svc.metrics();
    assert_eq!(snap.counters["requests_total"], total);
    assert_eq!(snap.counters["responses_total"], total);
    assert_eq!(svc.op_counts().values().sum::<u64>(), total);
    // The executor's telemetry made it into the service snapshot.
    assert!(snap.gauges.contains_key("par_worker0_executed"), "{:?}", snap.gauges.keys());
}
