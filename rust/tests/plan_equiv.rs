//! Property tests pinning the compiled-plan executor to the `DirectMul`
//! oracle: for every `(SchemeKind, Precision)` pair, executing through a
//! cached [`civp::decomp::Plan`] is bit-identical to the plain widening
//! multiply — across random significands and the edge cases where
//! rounding/accumulation bugs live (all-ones, single-bit, subnormal-range).

use civp::decomp::{execute, DecompMul, ExecStats, Plan, PlanCache, Precision, Scheme, SchemeKind};
use civp::fpu::{mul_bits, DirectMul, RoundMode, DOUBLE, QUAD, SINGLE};
use civp::proput::{forall, Rng};
use civp::wideint::{mul_u128, U128};
use std::sync::Arc;


/// Edge-case significands for a given width: all-ones, single-bit at every
/// byte boundary, the subnormal-range pattern (low bits only), and the
/// minimal/maximal values.
fn edge_sigs(bits: u32) -> Vec<U128> {
    let ones = U128::ONE.shl(bits).wrapping_sub(&U128::ONE);
    let mut v = vec![
        U128::ZERO,
        U128::ONE,
        ones,
        U128::ONE.shl(bits - 1),           // top bit only
        ones.shr(bits / 2),                // subnormal-range: low half ones
        U128::ONE.shl(bits / 2),           // middle single bit
    ];
    let mut i = 7;
    while i < bits {
        v.push(U128::ONE.shl(i));
        i += 8;
    }
    v
}

#[test]
fn plan_product_equals_direct_mul_random() {
    // The cached plan's integer product == DirectMul's widening multiply,
    // for every scheme x precision, over random normalized significands.
    forall(0x700, 2_000, |rng| {
        for prec in Precision::ALL {
            for kind in SchemeKind::ALL {
                let plan = PlanCache::get(kind, prec);
                let a = rng.sig(prec.sig_bits());
                let b = rng.sig(prec.sig_bits());
                let mut stats = ExecStats::default();
                let got = plan.execute(a, b, &mut stats);
                // DirectMul's product IS the plain widening multiply.
                let want = mul_u128(a, b);
                assert_eq!(got, want, "{kind:?} {prec:?}");
            }
        }
    });
}

#[test]
fn plan_product_equals_direct_mul_edge_cases() {
    for prec in Precision::ALL {
        let edges = edge_sigs(prec.sig_bits());
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get(kind, prec);
            let mut stats = ExecStats::default();
            for &a in &edges {
                for &b in &edges {
                    let got = plan.execute(a, b, &mut stats);
                    assert_eq!(got, mul_u128(a, b), "{kind:?} {prec:?}");
                }
            }
        }
    }
}

#[test]
fn plan_matches_rederived_tile_executor_and_stats() {
    // The compiled plan is a pure lowering: product AND accounting must be
    // identical to deriving the tile DAG per call.
    forall(0x701, 500, |rng| {
        for prec in Precision::ALL {
            for kind in SchemeKind::ALL {
                let scheme = Scheme::new(kind, prec);
                let plan = PlanCache::get(kind, prec);
                let a = rng.sig(prec.sig_bits());
                let b = rng.sig(prec.sig_bits());
                let mut ps = ExecStats::default();
                let mut ts = ExecStats::default();
                let via_plan = plan.execute(a, b, &mut ps);
                let via_tiles = execute(&scheme, a, b, &mut ts);
                assert_eq!(via_plan, via_tiles, "{kind:?} {prec:?}");
                assert_eq!(ps.tiles, ts.tiles);
                assert_eq!(ps.padded_tiles, ts.padded_tiles);
                assert_eq!(ps.useful_bitops, ts.useful_bitops);
                assert_eq!(ps.capacity_bitops, ts.capacity_bitops);
                assert_eq!(ps.muls, ts.muls);
                for bk in civp::decomp::BlockKind::ALL {
                    assert_eq!(ps.ops(bk), ts.ops(bk), "{kind:?} {prec:?} {bk:?}");
                }
            }
        }
    });
}

#[test]
fn plan_equivalence_for_integer_widths() {
    // The "combined integer" half: compiled plans serve arbitrary widths.
    forall(0x702, 300, |rng| {
        let width = rng.range(2, 128) as u32;
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get_width(kind, width);
            let a = rng.sig(width);
            let b = rng.sig(width);
            let mut stats = ExecStats::default();
            assert_eq!(plan.execute(a, b, &mut stats), mul_u128(a, b), "{kind:?} w={width}");
        }
    });
}

#[test]
fn full_ieee_pipeline_plan_vs_direct_all_modes() {
    // End to end: mul_bits through the plan-backed DecompMul == mul_bits
    // through DirectMul, for every scheme, precision and rounding mode.
    forall(0x703, 800, |rng| {
        let mode = RoundMode::ALL[rng.below(5) as usize];
        for (fmt, bits) in [(&SINGLE, 32u32), (&DOUBLE, 64), (&QUAD, 128)] {
            let mut raw_a = U128::ZERO;
            raw_a.limbs[0] = rng.next_u64();
            raw_a.limbs[1] = rng.next_u64();
            let a = raw_a.mask_low(bits);
            let mut raw_b = U128::ZERO;
            raw_b.limbs[0] = rng.next_u64();
            raw_b.limbs[1] = rng.next_u64();
            let b = raw_b.mask_low(bits);
            let (want, wf) = mul_bits(fmt, a, b, mode, &mut DirectMul);
            for kind in SchemeKind::ALL {
                let mut m = DecompMul::new(kind);
                let (got, gf) = mul_bits(fmt, a, b, mode, &mut m);
                assert_eq!(got, want, "{kind:?} {} {mode:?}", fmt.name);
                assert_eq!(gf, wf, "flags diverged: {kind:?} {}", fmt.name);
            }
        }
    });
}

#[test]
fn plan_cache_shares_one_plan_per_key() {
    for prec in Precision::ALL {
        for kind in SchemeKind::ALL {
            let a = PlanCache::get(kind, prec);
            let b = PlanCache::get(kind, prec);
            assert!(Arc::ptr_eq(&a, &b), "{kind:?} {prec:?} not shared");
            // IEEE widths route to the same shared plan
            let c = PlanCache::get_width(kind, prec.sig_bits());
            assert!(Arc::ptr_eq(&a, &c));
        }
    }
    let w1 = PlanCache::get_width(SchemeKind::Civp, 40);
    let w2 = PlanCache::get_width(SchemeKind::Civp, 40);
    assert!(Arc::ptr_eq(&w1, &w2));
    assert!(PlanCache::ieee_cached() > 0);
    assert!(PlanCache::int_cached() > 0);
}

#[test]
fn plan_batch_matches_scalar_path() {
    let plan: Arc<Plan> = PlanCache::get(SchemeKind::Civp, Precision::Double);
    let mut rng = Rng::new(0x704);
    let a: Vec<U128> = (0..257).map(|_| rng.sig(53)).collect();
    let b: Vec<U128> = (0..257).map(|_| rng.sig(53)).collect();
    let mut batch_stats = ExecStats::default();
    let mut out = Vec::new();
    plan.execute_batch(&a, &b, &mut batch_stats, &mut out);
    assert_eq!(out.len(), a.len());
    let mut scalar_stats = ExecStats::default();
    for i in 0..a.len() {
        assert_eq!(out[i], plan.execute(a[i], b[i], &mut scalar_stats), "i={i}");
    }
    assert_eq!(batch_stats.muls, scalar_stats.muls);
    assert_eq!(batch_stats.tiles, scalar_stats.tiles);
}
