//! Property tests pinning the compiled-plan executor to the `DirectMul`
//! oracle: for every `(SchemeKind, OpClass)` pair, executing through a
//! cached [`civp::decomp::Plan`] is bit-identical to the plain widening
//! multiply — across random significands and the edge cases where
//! rounding/accumulation bugs live (all-ones, single-bit, subnormal-range).
//!
//! The lane-fused batch paths are pinned here too: `Plan::execute_lanes`
//! against N× `Plan::execute` (every scheme kind, IEEE + integer widths,
//! every ragged tail length, stats included), and `FpuBatch::mul_batch`
//! against N× `mul_bits` (specials, subnormals, every rounding mode,
//! flag unions included).

use civp::decomp::{
    execute, DecompMul, ExecStats, LaneConfig, LaneWidth, OpClass, Plan, PlanCache, Scheme,
    SchemeKind, SimdIsa, LANES,
};
use civp::fpu::{
    mul_bits, mul_bits_batch, mul_bits_wide, DirectMul, Flags, Fp128, Fp32, Fp64, FpFormat,
    FpuBatch, RoundMode, BF16, DOUBLE, HALF, QUAD, SINGLE,
};
use civp::proput::{forall, Rng};
use civp::wideint::{mul_u128, PackedBits, U128, U256};
use std::sync::Arc;

/// The classes whose significands fit the `U128` scalar/lane entry points.
/// The wide classes (Fp256/Fp512) run the `execute_wide` tree path, pinned
/// in the wide section at the bottom of this file.
fn narrow_classes() -> impl Iterator<Item = OpClass> {
    OpClass::ALL.into_iter().filter(|c| !c.is_wide())
}

/// Edge-case significands for a given width: all-ones, single-bit at every
/// byte boundary, the subnormal-range pattern (low bits only), and the
/// minimal/maximal values.
fn edge_sigs(bits: u32) -> Vec<U128> {
    let ones = U128::ONE.shl(bits).wrapping_sub(&U128::ONE);
    let mut v = vec![
        U128::ZERO,
        U128::ONE,
        ones,
        U128::ONE.shl(bits - 1),           // top bit only
        ones.shr(bits / 2),                // subnormal-range: low half ones
        U128::ONE.shl(bits / 2),           // middle single bit
    ];
    let mut i = 7;
    while i < bits {
        v.push(U128::ONE.shl(i));
        i += 8;
    }
    v
}

#[test]
fn plan_product_equals_direct_mul_random() {
    // The cached plan's integer product == DirectMul's widening multiply,
    // for every scheme x precision, over random normalized significands.
    forall(0x700, 2_000, |rng| {
        for prec in narrow_classes() {
            for kind in SchemeKind::ALL {
                let plan = PlanCache::get(kind, prec);
                let a = rng.sig(prec.sig_bits());
                let b = rng.sig(prec.sig_bits());
                let mut stats = ExecStats::default();
                let got = plan.execute(a, b, &mut stats);
                // DirectMul's product IS the plain widening multiply.
                let want = mul_u128(a, b);
                assert_eq!(got, want, "{kind:?} {prec:?}");
            }
        }
    });
}

#[test]
fn plan_product_equals_direct_mul_edge_cases() {
    for prec in narrow_classes() {
        let edges = edge_sigs(prec.sig_bits());
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get(kind, prec);
            let mut stats = ExecStats::default();
            for &a in &edges {
                for &b in &edges {
                    let got = plan.execute(a, b, &mut stats);
                    assert_eq!(got, mul_u128(a, b), "{kind:?} {prec:?}");
                }
            }
        }
    }
}

#[test]
fn plan_matches_rederived_tile_executor_and_stats() {
    // The compiled plan is a pure lowering: product AND accounting must be
    // identical to deriving the tile DAG per call.
    forall(0x701, 500, |rng| {
        for prec in narrow_classes() {
            for kind in SchemeKind::ALL {
                let scheme = Scheme::new(kind, prec);
                let plan = PlanCache::get(kind, prec);
                let a = rng.sig(prec.sig_bits());
                let b = rng.sig(prec.sig_bits());
                let mut ps = ExecStats::default();
                let mut ts = ExecStats::default();
                let via_plan = plan.execute(a, b, &mut ps);
                let via_tiles = execute(&scheme, a, b, &mut ts);
                assert_eq!(via_plan, via_tiles, "{kind:?} {prec:?}");
                assert_eq!(ps.tiles, ts.tiles);
                assert_eq!(ps.padded_tiles, ts.padded_tiles);
                assert_eq!(ps.useful_bitops, ts.useful_bitops);
                assert_eq!(ps.capacity_bitops, ts.capacity_bitops);
                assert_eq!(ps.muls, ts.muls);
                for bk in civp::decomp::BlockKind::ALL {
                    assert_eq!(ps.ops(bk), ts.ops(bk), "{kind:?} {prec:?} {bk:?}");
                }
            }
        }
    });
}

#[test]
fn plan_equivalence_for_integer_widths() {
    // The "combined integer" half: compiled plans serve arbitrary widths.
    forall(0x702, 300, |rng| {
        let width = rng.range(2, 128) as u32;
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get_width(kind, width);
            let a = rng.sig(width);
            let b = rng.sig(width);
            let mut stats = ExecStats::default();
            assert_eq!(plan.execute(a, b, &mut stats), mul_u128(a, b), "{kind:?} w={width}");
        }
    });
}

#[test]
fn full_ieee_pipeline_plan_vs_direct_all_modes() {
    // End to end: mul_bits through the plan-backed DecompMul == mul_bits
    // through DirectMul, for every scheme, precision and rounding mode.
    forall(0x703, 800, |rng| {
        let mode = RoundMode::ALL[rng.below(5) as usize];
        for fmt in [&BF16, &HALF, &SINGLE, &DOUBLE, &QUAD] {
            let bits = fmt.total_bits();
            let mut raw_a = U128::ZERO;
            raw_a.limbs[0] = rng.next_u64();
            raw_a.limbs[1] = rng.next_u64();
            let a = raw_a.mask_low(bits);
            let mut raw_b = U128::ZERO;
            raw_b.limbs[0] = rng.next_u64();
            raw_b.limbs[1] = rng.next_u64();
            let b = raw_b.mask_low(bits);
            let (want, wf) = mul_bits(fmt, a, b, mode, &mut DirectMul);
            for kind in SchemeKind::ALL {
                let mut m = DecompMul::new(kind);
                let (got, gf) = mul_bits(fmt, a, b, mode, &mut m);
                assert_eq!(got, want, "{kind:?} {} {mode:?}", fmt.name);
                assert_eq!(gf, wf, "flags diverged: {kind:?} {}", fmt.name);
            }
        }
    });
}

#[test]
fn plan_cache_shares_one_plan_per_key() {
    for prec in OpClass::ALL {
        for kind in SchemeKind::ALL {
            let a = PlanCache::get(kind, prec);
            let b = PlanCache::get(kind, prec);
            assert!(Arc::ptr_eq(&a, &b), "{kind:?} {prec:?} not shared");
            // IEEE widths route to the same shared plan
            let c = PlanCache::get_width(kind, prec.sig_bits());
            assert!(Arc::ptr_eq(&a, &c));
        }
    }
    let w1 = PlanCache::get_width(SchemeKind::Civp, 40);
    let w2 = PlanCache::get_width(SchemeKind::Civp, 40);
    assert!(Arc::ptr_eq(&w1, &w2));
    assert!(PlanCache::class_cached() > 0);
    assert!(PlanCache::int_cached() > 0);
}

#[test]
fn plan_batch_matches_scalar_path() {
    let plan: Arc<Plan> = PlanCache::get(SchemeKind::Civp, OpClass::Double);
    let mut rng = Rng::new(0x704);
    let a: Vec<U128> = (0..257).map(|_| rng.sig(53)).collect();
    let b: Vec<U128> = (0..257).map(|_| rng.sig(53)).collect();
    let mut batch_stats = ExecStats::default();
    let mut out = Vec::new();
    plan.execute_batch(&a, &b, &mut batch_stats, &mut out);
    assert_eq!(out.len(), a.len());
    let mut scalar_stats = ExecStats::default();
    for i in 0..a.len() {
        assert_eq!(out[i], plan.execute(a[i], b[i], &mut scalar_stats), "i={i}");
    }
    assert_eq!(batch_stats.muls, scalar_stats.muls);
    assert_eq!(batch_stats.tiles, scalar_stats.tiles);
}

// ---------------------------------------------------------------------
// Lane-fused batch execution: `Plan::execute_lanes` and the batched FP
// pipeline `FpuBatch`, pinned against the per-op oracles.
// ---------------------------------------------------------------------

fn assert_stats_eq(a: &ExecStats, b: &ExecStats, ctx: &str) {
    assert_eq!(a.muls, b.muls, "{ctx}: muls");
    assert_eq!(a.tiles, b.tiles, "{ctx}: tiles");
    assert_eq!(a.padded_tiles, b.padded_tiles, "{ctx}: padded_tiles");
    assert_eq!(a.useful_bitops, b.useful_bitops, "{ctx}: useful_bitops");
    assert_eq!(a.capacity_bitops, b.capacity_bitops, "{ctx}: capacity_bitops");
    for bk in civp::decomp::BlockKind::ALL {
        assert_eq!(a.ops(bk), b.ops(bk), "{ctx}: ops({bk:?})");
    }
}

#[test]
fn execute_lanes_matches_per_op_all_schemes_and_tails() {
    // Tile-major lane execution ≡ N× the scalar per-op kernel — products
    // AND accounting — for every scheme kind, every IEEE width, and every
    // ragged tail length around the LANES block size (including the
    // empty batch and a batch smaller than one block).
    let mut rng = Rng::new(0x710);
    for prec in narrow_classes() {
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get(kind, prec);
            for n in [0, 1, LANES - 1, LANES, LANES + 1, 2 * LANES, 2 * LANES + 3, 67] {
                let a: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                let b: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                let mut lane_stats = ExecStats::default();
                let mut out: Vec<U256> = Vec::new();
                plan.execute_lanes(&a, &b, &mut lane_stats, &mut out);
                assert_eq!(out.len(), n, "{kind:?} {prec:?} n={n}");
                let mut scalar_stats = ExecStats::default();
                for i in 0..n {
                    let want = plan.execute(a[i], b[i], &mut scalar_stats);
                    assert_eq!(out[i], want, "{kind:?} {prec:?} n={n} i={i}");
                }
                assert_stats_eq(&lane_stats, &scalar_stats, &format!("{kind:?} {prec:?} n={n}"));
            }
        }
    }
}

#[test]
fn execute_lanes_cfg_every_width_isa_and_tail_residue() {
    // The width-parameterized engine at every block width × every ISA
    // this build + CPU can dispatch, pinned against the scalar per-op
    // oracle at **every** tail residue class `n % W` (one full block
    // plus a tail of each possible length, including the block-aligned
    // residue 0). Products and merged stats both.
    let mut rng = Rng::new(0x715);
    for width in LaneWidth::ALL {
        let w = width.width();
        for isa in SimdIsa::ALL {
            if !isa.available() {
                continue;
            }
            let cfg = LaneConfig { width, isa };
            for prec in narrow_classes() {
                let plan = PlanCache::get(SchemeKind::Civp, prec);
                for residue in 0..w {
                    let n = w + residue;
                    let a: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                    let b: Vec<U128> = (0..n).map(|_| rng.sig(prec.sig_bits())).collect();
                    let mut lane_stats = ExecStats::default();
                    let mut out: Vec<U256> = Vec::new();
                    plan.execute_lanes_cfg(cfg, &a, &b, &mut lane_stats, &mut out);
                    assert_eq!(out.len(), n);
                    let mut scalar_stats = ExecStats::default();
                    for i in 0..n {
                        let want = plan.execute(a[i], b[i], &mut scalar_stats);
                        assert_eq!(
                            out[i],
                            want,
                            "{} {prec:?} n={n} i={i}",
                            cfg.kernel_name()
                        );
                    }
                    assert_stats_eq(
                        &lane_stats,
                        &scalar_stats,
                        &format!("{} {prec:?} n={n}", cfg.kernel_name()),
                    );
                }
            }
        }
    }
}

#[test]
fn execute_lanes_cfg_edge_significands_every_width() {
    // Worst-case bit patterns (all-ones carry chains, single bits, top-
    // limb-only values) through every width × dispatched ISA — the lane
    // positions where SIMD carry propagation bugs would live.
    for width in LaneWidth::ALL {
        for isa in SimdIsa::ALL {
            if !isa.available() {
                continue;
            }
            let cfg = LaneConfig { width, isa };
            for prec in [OpClass::Double, OpClass::Quad] {
                let edges = edge_sigs(prec.sig_bits());
                let plan = PlanCache::get(SchemeKind::Civp, prec);
                let mut a = Vec::new();
                let mut b = Vec::new();
                for &x in &edges {
                    for &y in &edges {
                        a.push(x);
                        b.push(y);
                    }
                }
                let mut stats = ExecStats::default();
                let mut out: Vec<U256> = Vec::new();
                plan.execute_lanes_cfg(cfg, &a, &b, &mut stats, &mut out);
                for i in 0..a.len() {
                    assert_eq!(
                        out[i],
                        mul_u128(a[i], b[i]),
                        "{} {prec:?} i={i}",
                        cfg.kernel_name()
                    );
                }
            }
        }
    }
}

#[test]
fn execute_lanes_matches_per_op_integer_widths() {
    // The "combined integer" half rides the lane path too: arbitrary
    // operand widths, batch sizes straddling the block boundary.
    forall(0x711, 120, |rng| {
        let width = rng.range(2, 128) as u32;
        let n = rng.range(1, 3 * LANES as u64) as usize;
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get_width(kind, width);
            let a: Vec<U128> = (0..n).map(|_| rng.sig(width)).collect();
            let b: Vec<U128> = (0..n).map(|_| rng.sig(width)).collect();
            let mut stats = ExecStats::default();
            let mut out: Vec<U256> = Vec::new();
            plan.execute_lanes(&a, &b, &mut stats, &mut out);
            for i in 0..n {
                assert_eq!(out[i], mul_u128(a[i], b[i]), "{kind:?} w={width} i={i}");
            }
            assert_eq!(stats.muls, n as u64);
        }
    });
}

#[test]
fn execute_lanes_edge_significands() {
    // Edge significands (all-ones, single bits, low-half patterns) through
    // full blocks: the SoA extraction and carry chains see the worst-case
    // bit patterns in every lane position, for every scheme.
    for prec in narrow_classes() {
        let edges = edge_sigs(prec.sig_bits());
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get(kind, prec);
            // Pair every edge with every edge, processed in lane blocks.
            let mut a = Vec::new();
            let mut b = Vec::new();
            for &x in &edges {
                for &y in &edges {
                    a.push(x);
                    b.push(y);
                }
            }
            let mut stats = ExecStats::default();
            let mut out: Vec<U256> = Vec::new();
            plan.execute_lanes(&a, &b, &mut stats, &mut out);
            for i in 0..a.len() {
                assert_eq!(out[i], mul_u128(a[i], b[i]), "{kind:?} {prec:?} i={i}");
            }
        }
    }
}

/// Nasty packed bit patterns for any registry format: specials
/// (NaN/Inf/zero), subnormals, boundary exponents, uniform noise — built
/// from the format descriptor, so the sub-single classes get the same
/// adversarial coverage as the paper's three.
fn nasty_packed(rng: &mut Rng, fmt: &FpFormat) -> u128 {
    let frac_mask = (1u128 << fmt.frac_bits) - 1;
    let rand_wide = |rng: &mut Rng| ((rng.next_u64() as u128) << 64) | rng.next_u64() as u128;
    match rng.below(7) {
        0 => 0,
        1 => ((fmt.exp_mask() as u128) << fmt.frac_bits)
            | ((rng.below(2) as u128) << (fmt.total_bits() - 1)), // ±inf
        2 => ((fmt.exp_mask() as u128) << fmt.frac_bits) | (1u128 << (fmt.frac_bits - 1)), // qNaN
        3 => rand_wide(rng) & frac_mask, // subnormal
        4 => {
            // boundary exponents: emin and emax neighbourhoods
            let biased = if rng.below(2) == 0 {
                1 + rng.below(3)
            } else {
                fmt.exp_mask() as u64 - 1 - rng.below(3)
            };
            ((biased as u128) << fmt.frac_bits) | (rand_wide(rng) & frac_mask)
        }
        _ => {
            let sign = (rng.below(2) as u128) << (fmt.total_bits() - 1);
            let exp = rng.below(fmt.exp_mask() as u64) as u128;
            sign | (exp << fmt.frac_bits) | (rand_wide(rng) & frac_mask)
        }
    }
}

#[test]
fn fpu_batch_matches_scalar_pipeline_with_specials() {
    // The fused pipeline (specials sidecar + one lane multiply + batched
    // finish) ≡ N× `mul_bits`, results AND flag union, across nasty
    // inputs, every format, every rounding mode, ragged batch sizes.
    forall(0x712, 250, |rng| {
        let mode = RoundMode::ALL[rng.below(5) as usize];
        for fmt in [&BF16, &HALF, &SINGLE, &DOUBLE, &QUAD] {
            let n = rng.below(3 * LANES as u64 + 2) as usize;
            let a: Vec<u128> = (0..n).map(|_| nasty_packed(rng, fmt)).collect();
            let b: Vec<u128> = (0..n).map(|_| nasty_packed(rng, fmt)).collect();
            let mut fused = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
            let mut out = Vec::new();
            let got_flags = fused.mul_batch_bits(fmt, &a, &b, mode, &mut out);
            assert_eq!(out.len(), n);

            let mut dm = DecompMul::new(SchemeKind::Civp);
            let mut want_flags = Flags::default();
            for i in 0..n {
                let (w, f) =
                    mul_bits(fmt, U128::from_u128(a[i]), U128::from_u128(b[i]), mode, &mut dm);
                want_flags.merge(f);
                assert_eq!(out[i], w.as_u128(), "{} {mode:?} i={i}", fmt.name);
            }
            assert_eq!(got_flags, want_flags, "{} {mode:?} flag union", fmt.name);
            // Block accounting parity: the sidecar skips exactly the
            // elements the scalar pipeline never multiplies.
            assert_stats_eq(&fused.multiplier().stats, &dm.stats, fmt.name);

            // The per-op batch helper is the same oracle in batch shape.
            let mut dm2 = DecompMul::new(SchemeKind::Civp);
            let mut out2 = Vec::new();
            let f2 = mul_bits_batch(fmt, &a, &b, mode, &mut dm2, &mut out2);
            assert_eq!(out, out2, "{}", fmt.name);
            assert_eq!(got_flags, f2, "{}", fmt.name);
        }
    });
}

#[test]
fn fpu_batch_all_specials_runs_sidecar_only() {
    let a = vec![
        f64::NAN.to_bits() as u128,
        f64::INFINITY.to_bits() as u128,
        0u128,
        f64::NEG_INFINITY.to_bits() as u128,
    ];
    let b = vec![
        1.5f64.to_bits() as u128,
        0u128,
        (-0.0f64).to_bits() as u128,
        2.0f64.to_bits() as u128,
    ];
    let mut fused = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
    let mut out = Vec::new();
    let flags = fused.mul_batch_bits(&DOUBLE, &a, &b, RoundMode::NearestEven, &mut out);
    assert!(f64::from_bits(out[0] as u64).is_nan());
    assert!(f64::from_bits(out[1] as u64).is_nan(), "inf × 0 is invalid → qNaN");
    assert!(flags.invalid);
    assert_eq!(out[2] as u64, (-0.0f64).to_bits(), "+0 × -0 = -0");
    assert_eq!(out[3] as u64, f64::NEG_INFINITY.to_bits());
    // No significand product ever executed: the batch was pure sidecar.
    assert_eq!(fused.multiplier().stats.muls, 0);
    // An empty batch is fine too.
    let empty: Vec<u128> = Vec::new();
    let f = fused.mul_batch_bits(&DOUBLE, &empty, &empty, RoundMode::NearestEven, &mut out);
    assert!(out.is_empty());
    assert_eq!(f, Flags::default());
}

#[test]
fn fpu_batch_typed_surface_sub_single() {
    use civp::fpu::{Bf16, Fp16};
    let mut fused = FpuBatch::new(DecompMul::new(SchemeKind::Civp));

    // binary16: fused batch ≡ scalar typed multiply ≡ the f32 hardware
    // oracle (11-bit products are exact in f32, so f32-mul + one RNE
    // narrowing is the correctly rounded binary16 product).
    let mut rng = Rng::new(0x714);
    let a16: Vec<Fp16> = (0..3 * LANES + 5).map(|_| Fp16(rng.next_u64() as u16)).collect();
    let b16: Vec<Fp16> = (0..a16.len()).map(|_| Fp16(rng.next_u64() as u16)).collect();
    let mut out16 = Vec::new();
    fused.mul_batch(&a16, &b16, RoundMode::NearestEven, &mut out16);
    for i in 0..a16.len() {
        let want = a16[i].mul(b16[i]);
        assert_eq!(out16[i].0, want.0, "i={i}");
        let hw = Fp16::from_f32(a16[i].to_f32() * b16[i].to_f32());
        if !hw.is_nan() {
            assert_eq!(out16[i].0, hw.0, "i={i} vs f32 oracle");
        } else {
            assert!(out16[i].is_nan(), "i={i}");
        }
    }

    // bfloat16: fused ≡ scalar typed multiply, specials and carry cases.
    let abf: Vec<Bf16> = (0..2 * LANES + 3).map(|_| Bf16(rng.next_u64() as u16)).collect();
    let bbf: Vec<Bf16> = (0..abf.len()).map(|_| Bf16(rng.next_u64() as u16)).collect();
    let mut outbf = Vec::new();
    fused.mul_batch(&abf, &bbf, RoundMode::NearestEven, &mut outbf);
    for i in 0..abf.len() {
        let want = abf[i].mul(bbf[i]);
        if want.is_nan() {
            assert!(outbf[i].is_nan(), "i={i}");
        } else {
            assert_eq!(outbf[i].0, want.0, "i={i}");
        }
    }
}

#[test]
fn fpu_batch_typed_surface_all_three_widths() {
    let mut fused = FpuBatch::new(DirectMul);

    let a32: Vec<Fp32> = [1.5f32, -0.0, f32::MAX].map(Fp32::from_f32).to_vec();
    let b32: Vec<Fp32> = [2.0f32, 5.0, 2.0].map(Fp32::from_f32).to_vec();
    let mut out32 = Vec::new();
    let fl = fused.mul_batch(&a32, &b32, RoundMode::NearestEven, &mut out32);
    assert_eq!(out32[0].to_f32(), 3.0);
    assert_eq!(out32[1].to_f32().to_bits(), (-0.0f32).to_bits());
    assert!(out32[2].to_f32().is_infinite() && fl.overflow);

    // f64: fused ≡ scalar typed multiply ≡ host hardware (non-NaN cases).
    let mut rng = Rng::new(0x713);
    let a64: Vec<Fp64> = (0..37).map(|_| Fp64(rng.nasty_bits64())).collect();
    let b64: Vec<Fp64> = (0..37).map(|_| Fp64(rng.nasty_bits64())).collect();
    let mut out64 = Vec::new();
    fused.mul_batch(&a64, &b64, RoundMode::NearestEven, &mut out64);
    for i in 0..a64.len() {
        let want = a64[i].mul(b64[i]);
        assert_eq!(out64[i].0, want.0, "i={i}");
        let host = a64[i].to_f64() * b64[i].to_f64();
        if !host.is_nan() {
            assert_eq!(out64[i].to_f64().to_bits(), host.to_bits(), "i={i} vs hardware");
        }
    }

    // f128: fused ≡ the scalar quad path (no hardware oracle exists).
    let qa: Vec<Fp128> = [1e200, 1e-100, 2.0].map(Fp128::from_f64).to_vec();
    let qb: Vec<Fp128> = [1e100, 1e-200, 0.5].map(Fp128::from_f64).to_vec();
    let mut outq = Vec::new();
    fused.mul_batch(&qa, &qb, RoundMode::NearestEven, &mut outq);
    for i in 0..qa.len() {
        assert_eq!(outq[i].0, qa[i].mul(qb[i]).0, "i={i}");
    }
}

// ---------------------------------------------------------------------
// Wide classes (Fp256/Fp512): the compiled wide plan — the flat all-pairs
// sweep or the karatsuba24 combine tree — pinned against the direct
// widening multiply, and the full IEEE pipeline across organizations.
// ---------------------------------------------------------------------

/// A normalized wide significand: `bits` wide with the hidden bit set —
/// the wide sibling of `Rng::sig`.
fn wide_sig(rng: &mut Rng, bits: u32) -> PackedBits {
    let mut v = PackedBits::ZERO;
    for l in v.limbs.iter_mut() {
        *l = rng.next_u64();
    }
    let mut v = v.mask_low(bits);
    v.set_bit(bits - 1);
    v
}

/// Nasty packed wide values: specials, subnormals, boundary exponents and
/// uniform noise, built from the format descriptor like `nasty_packed`.
fn nasty_packed_wide(rng: &mut Rng, fmt: &FpFormat) -> PackedBits {
    let rand_wide = |rng: &mut Rng| {
        let mut v = PackedBits::ZERO;
        for l in v.limbs.iter_mut() {
            *l = rng.next_u64();
        }
        v.mask_low(fmt.total_bits())
    };
    let exp_field = |biased: u32| PackedBits::from_u64(biased as u64).shl(fmt.frac_bits);
    match rng.below(7) {
        0 => PackedBits::ZERO,
        1 => {
            // ±inf
            let mut v = exp_field(fmt.exp_mask());
            if rng.below(2) == 1 {
                v.set_bit(fmt.total_bits() - 1);
            }
            v
        }
        2 => {
            // qNaN
            let mut v = exp_field(fmt.exp_mask());
            v.set_bit(fmt.frac_bits - 1);
            v
        }
        3 => rand_wide(rng).mask_low(fmt.frac_bits), // subnormal
        4 => {
            // boundary exponents: emin and emax neighbourhoods
            let biased = if rng.below(2) == 0 {
                1 + rng.below(3) as u32
            } else {
                fmt.exp_mask() - 1 - rng.below(3) as u32
            };
            exp_field(biased).or(&rand_wide(rng).mask_low(fmt.frac_bits))
        }
        _ => rand_wide(rng),
    }
}

#[test]
fn wide_plan_product_equals_direct_mul_every_scheme() {
    forall(0x720, 200, |rng| {
        for prec in [OpClass::Fp256, OpClass::Fp512] {
            for kind in SchemeKind::ALL {
                let plan = PlanCache::get(kind, prec);
                assert!(plan.is_wide(), "{prec:?} must compile to a wide plan");
                let a = wide_sig(rng, prec.sig_bits());
                let b = wide_sig(rng, prec.sig_bits());
                let mut stats = ExecStats::default();
                let got = plan.execute_wide(a, b, &mut stats);
                assert_eq!(got, a.mul_full(&b), "{kind:?} {prec:?}");
            }
        }
    });
}

#[test]
fn wide_plan_batch_matches_scalar_every_scheme() {
    let mut rng = Rng::new(0x722);
    for prec in [OpClass::Fp256, OpClass::Fp512] {
        for kind in SchemeKind::ALL {
            let plan = PlanCache::get(kind, prec);
            let n = 33;
            let a: Vec<PackedBits> = (0..n).map(|_| wide_sig(&mut rng, prec.sig_bits())).collect();
            let b: Vec<PackedBits> = (0..n).map(|_| wide_sig(&mut rng, prec.sig_bits())).collect();
            let mut batch_stats = ExecStats::default();
            let mut out = Vec::new();
            plan.execute_batch_wide(&a, &b, &mut batch_stats, &mut out);
            assert_eq!(out.len(), n);
            let mut scalar_stats = ExecStats::default();
            for i in 0..n {
                let want = plan.execute_wide(a[i], b[i], &mut scalar_stats);
                assert_eq!(out[i], want, "{kind:?} {prec:?} i={i}");
            }
            assert_stats_eq(&batch_stats, &scalar_stats, &format!("{kind:?} {prec:?}"));
        }
    }
}

#[test]
fn wide_ieee_pipeline_karatsuba_equals_naive_equals_direct() {
    // Organization equivalence one layer up: packed wide products through
    // `DecompMul(karatsuba24)` == `DecompMul(civp)` == every other scheme
    // == `DirectMul`, across rounding modes, flags included.
    forall(0x721, 150, |rng| {
        let mode = RoundMode::ALL[rng.below(5) as usize];
        for prec in [OpClass::Fp256, OpClass::Fp512] {
            let fmt = prec.format();
            let a = nasty_packed_wide(rng, fmt);
            let b = nasty_packed_wide(rng, fmt);
            let (want, wf) = mul_bits_wide(fmt, a, b, mode, &mut DirectMul);
            for kind in SchemeKind::ALL {
                let mut m = DecompMul::new(kind);
                let (got, gf) = mul_bits_wide(fmt, a, b, mode, &mut m);
                assert_eq!(got, want, "{kind:?} {} {mode:?}", fmt.name);
                assert_eq!(gf, wf, "flags diverged: {kind:?} {}", fmt.name);
            }
        }
    });
}
