//! End-to-end tests for the network serving edge: the built-in load
//! generator against a loopback `NetServer`, plus socket-level protocol
//! abuse. These are the integration-level counterparts of the unit tests
//! inside `civp::net` — full frames over real TCP connections, checked
//! against the cluster's own per-class op counters.

use civp::cluster::ClusterConfig;
use civp::config::ServiceConfig;
use civp::coordinator::BackendChoice;
use civp::decomp::{OpClass, SchemeKind};
use civp::fpu::RoundMode;
use civp::net::wire::{self, FrameRead, Request, Response};
use civp::net::{LoadgenConfig, NetServer, NetServerConfig, Status};
use civp::trace::WorkloadSpec;
use std::collections::BTreeMap;
use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn small_server(max_inflight: u64) -> NetServer {
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 2,
            service: ServiceConfig {
                workers: 2,
                max_batch: 64,
                linger_us: 50,
                ..Default::default()
            },
            max_inflight,
            ..Default::default()
        },
        ..Default::default()
    };
    NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap()
}

fn loadgen_config(server: &NetServer, spec: WorkloadSpec, requests: u64) -> LoadgenConfig {
    LoadgenConfig {
        addr: server.local_addr().to_string(),
        conns: 3,
        requests,
        warmup: requests / 20,
        mix: spec.mix(),
        mix_name: spec.name().to_string(),
        ..LoadgenConfig::default()
    }
}

/// The acceptance-criterion run: `mixed` and `ml` mixes over loopback,
/// every frame answered exactly once, and the per-class frame counts the
/// generator sent equal to the per-class op counts the cluster executed.
#[test]
fn loopback_mixes_lose_nothing_and_counters_match() {
    for spec in [WorkloadSpec::Mixed, WorkloadSpec::MlInference] {
        let server = small_server(4096);
        let cfg = loadgen_config(&server, spec, 2000);
        let report = civp::net::loadgen::run(&cfg).unwrap();
        assert_eq!(report.sent, 2000, "{spec:?}: every request must go out");
        assert_eq!(report.lost, 0, "{spec:?}: no reply may be dropped");
        assert_eq!(
            report.replies(),
            report.sent,
            "{spec:?}: exactly one reply per frame (no loss, no duplication)"
        );
        // Uncontended in-flight budget: everything is admitted and executed.
        assert_eq!(report.ok, report.sent, "{spec:?}: all replies Ok");
        // The e2e oracle: what the generator stamped per class is what the
        // fabric executed per class.
        let mut executed = [0u64; OpClass::COUNT];
        for (op, n) in server.cluster().op_counts() {
            executed[op.class.index()] += n;
        }
        assert_eq!(
            executed, report.per_class_sent,
            "{spec:?}: per-class executed ops must match per-class frames sent"
        );
        // The ml mix must actually exercise more than one class end to end.
        let classes_hit = report.per_class_sent.iter().filter(|&&n| n > 0).count();
        assert!(classes_hit >= 2, "{spec:?}: expected a multi-class mix, hit {classes_hit}");
        let cluster_report = server.stop();
        assert_eq!(cluster_report.total_ops, 2000);
        assert_eq!(cluster_report.rejected_saturated, 0);
    }
}

/// Saturation is a wire status, not a dropped connection: with a one-slot
/// in-flight budget and a closed-loop flood, some frames must come back
/// `Saturated`, every frame still gets exactly one reply, and the wire
/// counts agree with the cluster's admission counters.
#[test]
fn saturated_cluster_answers_with_status_codes() {
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 1,
            service: ServiceConfig {
                workers: 1,
                max_batch: 8,
                linger_us: 200,
                ..Default::default()
            },
            max_inflight: 1,
            ..Default::default()
        },
        ..Default::default()
    };
    let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();
    let lg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        conns: 4,
        requests: 800,
        warmup: 0,
        mix: WorkloadSpec::Mixed.mix(),
        mix_name: "mixed".to_string(),
        ..LoadgenConfig::default()
    };
    let report = civp::net::loadgen::run(&lg).unwrap();
    assert_eq!(report.lost, 0, "saturation must not cost replies");
    assert_eq!(report.replies(), report.sent);
    assert!(report.saturated > 0, "a one-slot cluster under flood must push back");
    assert!(report.ok > 0, "admitted requests still complete");
    assert_eq!(report.other, 0, "only Ok and Saturated can occur here");
    let cluster_report = server.stop();
    assert_eq!(
        cluster_report.rejected_saturated, report.saturated,
        "wire Saturated replies must equal cluster admission rejections"
    );
    assert_eq!(cluster_report.total_ops, report.ok, "executed ops equal Ok replies");
}

/// Socket-level protocol abuse: in-frame garbage answers `BadRequest` and
/// keeps the connection usable; a framing-level lie (oversized length
/// prefix) answers `BadRequest` once and then the server closes.
#[test]
fn malformed_frames_get_error_responses_not_hangs() {
    let server = small_server(4096);
    let one = OpClass::Single.format().one();

    // A well-formed frame with a bad version byte: BadRequest, then the
    // same connection still serves a valid request.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut frame = Vec::new();
    Request {
        id: 7,
        class: OpClass::Single,
        scheme: SchemeKind::Civp,
        round: RoundMode::NearestEven,
        a: one.into(),
        b: one.into(),
    }
    .encode(&mut frame);
    let mut bad = frame.clone();
    bad[4] = 0x7f; // version byte lives right after the length prefix
    stream.write_all(&bad).unwrap();
    let mut payload = Vec::new();
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
    assert_eq!(Response::decode(&payload).unwrap().status, Status::BadRequest);
    stream.write_all(&frame).unwrap();
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
    let resp = Response::decode(&payload).unwrap();
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.id, 7);
    drop(stream);

    // An oversized length prefix: one BadRequest, then a clean close.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
    assert_eq!(Response::decode(&payload).unwrap().status, Status::BadRequest);
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Eof);
    drop(stream);

    // A truncated header (connection dies mid-prefix): the server must
    // just close its side without wedging the listener.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(&[0x01, 0x02]).unwrap();
    drop(stream);

    // The listener survived all three: a fresh connection still works.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(&frame).unwrap();
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
    assert_eq!(Response::decode(&payload).unwrap().status, Status::Ok);
    drop(stream);
    server.stop();
}

/// Encode one well-formed request frame for `class` with operands 1.0.
fn one_frame(id: u64, class: OpClass, scheme: SchemeKind) -> Vec<u8> {
    let one = class.format().one();
    let mut frame = Vec::new();
    Request { id, class, scheme, round: RoundMode::NearestEven, a: one.into(), b: one.into() }
        .encode(&mut frame);
    frame
}

/// Read exactly `n` responses off one socket, tallying per request id.
fn read_n_responses(stream: &mut TcpStream, n: usize) -> BTreeMap<u64, (Status, u64)> {
    stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut payload = Vec::new();
    let mut seen: BTreeMap<u64, (Status, u64)> = BTreeMap::new();
    for _ in 0..n {
        assert_eq!(
            wire::read_frame(stream, &mut payload).unwrap(),
            FrameRead::Frame,
            "server must deliver all {n} replies"
        );
        let resp = Response::decode(&payload).unwrap();
        let entry = seen.entry(resp.id).or_insert((resp.status, 0));
        entry.1 += 1;
    }
    seen
}

/// The pipelining contract: K frames with distinct request ids written
/// back-to-back on one connection, an in-flight depth much smaller than
/// K on the server, and every id answered exactly once — in whatever
/// order completions land (responses carry ids; ordering is NOT part of
/// the contract, and the depth high-water mark proves requests really
/// were concurrent inside the server, bounded by the configured depth).
#[test]
fn pipelined_frames_answered_exactly_once_out_of_order_tolerated() {
    const K: u64 = 64;
    const DEPTH: usize = 8;
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 2,
            service: ServiceConfig {
                workers: 2,
                max_batch: 16,
                linger_us: 50,
                ..Default::default()
            },
            ..Default::default()
        },
        pipeline_depth: DEPTH,
        ..Default::default()
    };
    let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    // Alternate classes so completion latency varies across the window.
    let classes = [OpClass::Single, OpClass::Double, OpClass::Quad];
    let mut burst = Vec::new();
    for i in 0..K {
        burst.extend_from_slice(&one_frame(
            1000 + i,
            classes[(i % 3) as usize],
            SchemeKind::Civp,
        ));
    }
    stream.write_all(&burst).unwrap();
    let seen = read_n_responses(&mut stream, K as usize);
    assert_eq!(seen.len(), K as usize, "every distinct id must be answered");
    for i in 0..K {
        let (status, count) = seen[&(1000 + i)];
        assert_eq!(count, 1, "id {} answered exactly once", 1000 + i);
        assert_eq!(status, Status::Ok);
    }
    let snapshot = server.metrics();
    let hwm = snapshot.gauges["net_pipeline_inflight_hwm"];
    assert!(hwm >= 2, "a {K}-frame burst must actually pipeline (hwm {hwm})");
    assert!(hwm <= DEPTH as i64, "in-flight depth is bounded by the config (hwm {hwm})");
    assert_eq!(snapshot.counters["net_frames_ok"], K);
    drop(stream);
    let report = server.stop();
    assert_eq!(report.total_ops, K);
}

/// The slow-reader contract: a client that floods requests and reads
/// nothing for a while, against a writer queue a fraction of that size,
/// still gets every reply exactly once — the bounded queue stalls the
/// server's reads (TCP backpressure) instead of dropping or duplicating
/// replies.
#[test]
fn slow_reader_bounded_writer_queue_drops_nothing() {
    const K: u64 = 48;
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 1,
            service: ServiceConfig {
                workers: 1,
                max_batch: 16,
                linger_us: 50,
                ..Default::default()
            },
            ..Default::default()
        },
        // Both bounds far below the burst: the server can hold at most
        // 2 responses queued and 2 requests in flight per connection.
        writer_queue: 2,
        pipeline_depth: 2,
        net_workers: 1,
        ..Default::default()
    };
    let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut burst = Vec::new();
    for i in 0..K {
        burst.extend_from_slice(&one_frame(i, OpClass::Single, SchemeKind::Civp));
    }
    stream.write_all(&burst).unwrap();
    // Read nothing while the server chews through the burst two at a
    // time; the writer-queue bound caps what it may buffer per step.
    std::thread::sleep(Duration::from_millis(300));
    let seen = read_n_responses(&mut stream, K as usize);
    assert_eq!(seen.len(), K as usize);
    for i in 0..K {
        let (status, count) = seen[&i];
        assert_eq!(count, 1, "id {i} answered exactly once through the bounded queue");
        assert_eq!(status, Status::Ok);
    }
    let snapshot = server.metrics();
    assert!(
        snapshot.gauges["net_pipeline_inflight_hwm"] <= 2,
        "depth bound must hold under the backlog"
    );
    drop(stream);
    server.stop();
}

/// Per-scheme multiplexing end to end: the load generator stamps a
/// non-primary scheme and the listener serves it through that scheme's
/// own cluster instead of answering `Unsupported`.
#[test]
fn loadgen_traffic_routes_to_extra_scheme_cluster() {
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 1,
            service: ServiceConfig {
                workers: 2,
                max_batch: 64,
                linger_us: 50,
                ..Default::default()
            },
            ..Default::default()
        },
        extra_schemes: vec![SchemeKind::Baseline18],
        ..Default::default()
    };
    let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();
    let lg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        conns: 2,
        requests: 400,
        warmup: 20,
        mix: WorkloadSpec::Mixed.mix(),
        mix_name: "mixed".to_string(),
        scheme: SchemeKind::Baseline18,
        ..LoadgenConfig::default()
    };
    let report = civp::net::loadgen::run(&lg).unwrap();
    assert_eq!(report.lost, 0);
    assert_eq!(report.ok, report.sent, "the 18x18 cluster must serve, not Unsupported");
    let routed: u64 =
        server.cluster_for(SchemeKind::Baseline18).unwrap().op_counts().values().sum();
    assert_eq!(routed, report.sent, "all frames landed in the 18x18 scheme's cluster");
    let primary: u64 = server.cluster().op_counts().values().sum();
    assert_eq!(primary, 0, "the primary CIVP cluster saw none of it");
    server.stop();
}

/// Accept-side connection admission and the idle reaper: a connection
/// beyond `max_conns` is closed at accept (counted in
/// `net_conns_rejected`, never queued onto a worker), and connections
/// that go quiet past `idle_timeout` are reaped so their slots admit
/// fresh clients again.
#[test]
fn max_conns_rejects_at_accept_and_idle_timeout_reclaims_slots() {
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 1,
            service: ServiceConfig {
                workers: 1,
                max_batch: 16,
                linger_us: 50,
                ..Default::default()
            },
            ..Default::default()
        },
        net_workers: 1,
        max_conns: 2,
        idle_timeout: Some(Duration::from_millis(100)),
        ..Default::default()
    };
    let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();

    // Fill both slots and prove they serve (the round trips also settle
    // the accept-side connection counts before the third connect).
    let mut a = TcpStream::connect(server.local_addr()).unwrap();
    let mut b = TcpStream::connect(server.local_addr()).unwrap();
    let mut payload = Vec::new();
    for (i, stream) in [&mut a, &mut b].into_iter().enumerate() {
        stream.write_all(&one_frame(i as u64, OpClass::Single, SchemeKind::Civp)).unwrap();
        assert_eq!(wire::read_frame(stream, &mut payload).unwrap(), FrameRead::Frame);
        assert_eq!(Response::decode(&payload).unwrap().status, Status::Ok);
    }

    // Third connection: turned away at accept — no frame ever comes
    // back, only a close (clean FIN or reset, depending on timing).
    let mut c = TcpStream::connect(server.local_addr()).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let _ = c.write_all(&one_frame(9, OpClass::Single, SchemeKind::Civp));
    assert!(
        !matches!(wire::read_frame(&mut c, &mut payload), Ok(FrameRead::Frame)),
        "a connection beyond max_conns must not be served"
    );
    drop(c);
    assert_eq!(server.metrics().counters["net_conns_rejected"], 1);

    // The two admitted connections go quiet past the idle window: the
    // reaper closes them and the open-connection gauge returns to zero.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let open = server.metrics().gauges["net_open_connections"];
        if open == 0 {
            break;
        }
        assert!(Instant::now() < deadline, "idle connections must be reaped ({open} open)");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(server.metrics().counters["net_conns_idle_closed"] >= 2);
    a.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    assert!(
        !matches!(wire::read_frame(&mut a, &mut payload), Ok(FrameRead::Frame)),
        "a reaped connection delivers no further frames"
    );

    // The freed slots admit a fresh connection again.
    let mut d = TcpStream::connect(server.local_addr()).unwrap();
    d.write_all(&one_frame(10, OpClass::Single, SchemeKind::Civp)).unwrap();
    assert_eq!(wire::read_frame(&mut d, &mut payload).unwrap(), FrameRead::Frame);
    assert_eq!(Response::decode(&payload).unwrap().status, Status::Ok);
    drop(a);
    drop(b);
    drop(d);
    server.stop();
}

/// The acceptance-criterion run: 4 net workers serving 256 loopback
/// connections, a closed-loop two-point offered-load sweep, zero lost
/// replies at every point — and the thread-count bound asserted through
/// the worker registry (4 fixed workers owning all 256 connections), not
/// by groveling `/proc`.
#[test]
fn sweep_256_conns_over_4_workers_loses_nothing() {
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 2,
            service: ServiceConfig {
                workers: 2,
                max_batch: 64,
                linger_us: 50,
                ..Default::default()
            },
            ..Default::default()
        },
        net_workers: 4,
        ..Default::default()
    };
    let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();
    let lg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        conns: 256,
        requests: 2560,
        warmup: 256,
        concurrency: 1024,
        mix: WorkloadSpec::Mixed.mix(),
        mix_name: "mixed".to_string(),
        ..LoadgenConfig::default()
    };
    let sweep = std::thread::spawn(move || {
        civp::net::loadgen::run_sweep(&lg, &[4000.0, 16000.0], 4).unwrap()
    });
    // While the sweep drives load, watch the worker registry: the pool
    // never grows, and at peak all 256 connections are owned by it.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut max_open = 0usize;
    while Instant::now() < deadline {
        let registry = server.worker_registry();
        assert_eq!(registry.len(), 4, "the pool is fixed at 4 workers");
        let open: usize = registry.iter().map(|(_, n)| n).sum();
        max_open = max_open.max(open);
        if max_open >= 256 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    // `>=`: between sweep points, closes from the previous point can
    // briefly overlap the next point's connects in the registry sums.
    assert!(max_open >= 256, "all 256 connections must be owned by the 4-worker pool");
    let sweep_report = sweep.join().unwrap();
    assert_eq!(sweep_report.points.len(), 2);
    for point in &sweep_report.points {
        assert_eq!(point.report.sent, 2560, "rate {}", point.rate);
        assert_eq!(point.report.lost, 0, "zero lost replies at rate {}", point.rate);
        assert_eq!(point.report.replies(), point.report.sent);
    }
    // The sweep's bench rows carry the knee-gate inputs.
    let mut json = civp::benchx::JsonReport::new();
    sweep_report.push_bench_rows(&mut json);
    let text = json.to_json();
    for name in ["net/mixed/sweep-workers", "net/mixed/p99@4000", "net/mixed/lost@16000"] {
        assert!(text.contains(name), "{name} missing from sweep rows");
    }
    server.stop();
}
