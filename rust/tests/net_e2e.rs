//! End-to-end tests for the network serving edge: the built-in load
//! generator against a loopback `NetServer`, plus socket-level protocol
//! abuse. These are the integration-level counterparts of the unit tests
//! inside `civp::net` — full frames over real TCP connections, checked
//! against the cluster's own per-class op counters.

use civp::cluster::ClusterConfig;
use civp::config::ServiceConfig;
use civp::coordinator::BackendChoice;
use civp::decomp::{OpClass, SchemeKind};
use civp::fpu::RoundMode;
use civp::net::wire::{self, FrameRead, Request, Response};
use civp::net::{LoadgenConfig, NetServer, NetServerConfig, Status};
use civp::trace::WorkloadSpec;
use std::io::Write;
use std::net::TcpStream;

fn small_server(max_inflight: u64) -> NetServer {
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 2,
            service: ServiceConfig {
                workers: 2,
                max_batch: 64,
                linger_us: 50,
                ..Default::default()
            },
            max_inflight,
            ..Default::default()
        },
        ..Default::default()
    };
    NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap()
}

fn loadgen_config(server: &NetServer, spec: WorkloadSpec, requests: u64) -> LoadgenConfig {
    LoadgenConfig {
        addr: server.local_addr().to_string(),
        conns: 3,
        requests,
        warmup: requests / 20,
        mix: spec.mix(),
        mix_name: spec.name().to_string(),
        ..LoadgenConfig::default()
    }
}

/// The acceptance-criterion run: `mixed` and `ml` mixes over loopback,
/// every frame answered exactly once, and the per-class frame counts the
/// generator sent equal to the per-class op counts the cluster executed.
#[test]
fn loopback_mixes_lose_nothing_and_counters_match() {
    for spec in [WorkloadSpec::Mixed, WorkloadSpec::MlInference] {
        let server = small_server(4096);
        let cfg = loadgen_config(&server, spec, 2000);
        let report = civp::net::loadgen::run(&cfg).unwrap();
        assert_eq!(report.sent, 2000, "{spec:?}: every request must go out");
        assert_eq!(report.lost, 0, "{spec:?}: no reply may be dropped");
        assert_eq!(
            report.replies(),
            report.sent,
            "{spec:?}: exactly one reply per frame (no loss, no duplication)"
        );
        // Uncontended in-flight budget: everything is admitted and executed.
        assert_eq!(report.ok, report.sent, "{spec:?}: all replies Ok");
        // The e2e oracle: what the generator stamped per class is what the
        // fabric executed per class.
        let mut executed = [0u64; OpClass::COUNT];
        for (op, n) in server.cluster().op_counts() {
            executed[op.class.index()] += n;
        }
        assert_eq!(
            executed, report.per_class_sent,
            "{spec:?}: per-class executed ops must match per-class frames sent"
        );
        // The ml mix must actually exercise more than one class end to end.
        let classes_hit = report.per_class_sent.iter().filter(|&&n| n > 0).count();
        assert!(classes_hit >= 2, "{spec:?}: expected a multi-class mix, hit {classes_hit}");
        let cluster_report = server.stop();
        assert_eq!(cluster_report.total_ops, 2000);
        assert_eq!(cluster_report.rejected_saturated, 0);
    }
}

/// Saturation is a wire status, not a dropped connection: with a one-slot
/// in-flight budget and a closed-loop flood, some frames must come back
/// `Saturated`, every frame still gets exactly one reply, and the wire
/// counts agree with the cluster's admission counters.
#[test]
fn saturated_cluster_answers_with_status_codes() {
    let cfg = NetServerConfig {
        cluster: ClusterConfig {
            shards: 1,
            service: ServiceConfig {
                workers: 1,
                max_batch: 8,
                linger_us: 200,
                ..Default::default()
            },
            max_inflight: 1,
            ..Default::default()
        },
        ..Default::default()
    };
    let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();
    let lg = LoadgenConfig {
        addr: server.local_addr().to_string(),
        conns: 4,
        requests: 800,
        warmup: 0,
        mix: WorkloadSpec::Mixed.mix(),
        mix_name: "mixed".to_string(),
        ..LoadgenConfig::default()
    };
    let report = civp::net::loadgen::run(&lg).unwrap();
    assert_eq!(report.lost, 0, "saturation must not cost replies");
    assert_eq!(report.replies(), report.sent);
    assert!(report.saturated > 0, "a one-slot cluster under flood must push back");
    assert!(report.ok > 0, "admitted requests still complete");
    assert_eq!(report.other, 0, "only Ok and Saturated can occur here");
    let cluster_report = server.stop();
    assert_eq!(
        cluster_report.rejected_saturated, report.saturated,
        "wire Saturated replies must equal cluster admission rejections"
    );
    assert_eq!(cluster_report.total_ops, report.ok, "executed ops equal Ok replies");
}

/// Socket-level protocol abuse: in-frame garbage answers `BadRequest` and
/// keeps the connection usable; a framing-level lie (oversized length
/// prefix) answers `BadRequest` once and then the server closes.
#[test]
fn malformed_frames_get_error_responses_not_hangs() {
    let server = small_server(4096);
    let one = OpClass::Single.format().one();

    // A well-formed frame with a bad version byte: BadRequest, then the
    // same connection still serves a valid request.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut frame = Vec::new();
    Request {
        id: 7,
        class: OpClass::Single,
        scheme: SchemeKind::Civp,
        round: RoundMode::NearestEven,
        a: one,
        b: one,
    }
    .encode(&mut frame);
    let mut bad = frame.clone();
    bad[4] = 0x7f; // version byte lives right after the length prefix
    stream.write_all(&bad).unwrap();
    let mut payload = Vec::new();
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
    assert_eq!(Response::decode(&payload).unwrap().status, Status::BadRequest);
    stream.write_all(&frame).unwrap();
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
    let resp = Response::decode(&payload).unwrap();
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.id, 7);
    drop(stream);

    // An oversized length prefix: one BadRequest, then a clean close.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
    assert_eq!(Response::decode(&payload).unwrap().status, Status::BadRequest);
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Eof);
    drop(stream);

    // A truncated header (connection dies mid-prefix): the server must
    // just close its side without wedging the listener.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(&[0x01, 0x02]).unwrap();
    drop(stream);

    // The listener survived all three: a fresh connection still works.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.write_all(&frame).unwrap();
    assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
    assert_eq!(Response::decode(&payload).unwrap().status, Status::Ok);
    drop(stream);
    server.stop();
}
