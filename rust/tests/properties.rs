//! Cross-cutting property tests over the public API: algebraic laws that
//! must hold across precisions, schemes, rounding modes and backends.

use civp::decomp::{scheme_census, DecompMul, ExecStats, OpClass, Scheme, SchemeKind};
use civp::fpu::{DirectMul, Fp128, Fp32, Fp64, FpClass, RoundMode, BF16, DOUBLE, HALF, QUAD, SINGLE};
use civp::proput::{forall, Rng};
use civp::wideint::{mul_u128, U128};

#[cfg(test)]
fn rand_bits(rng: &mut Rng, bits: u32) -> U128 {
    let mut v = U128::ZERO;
    v.limbs[0] = rng.next_u64();
    v.limbs[1] = rng.next_u64();
    v.mask_low(bits)
}

#[test]
fn every_scheme_is_exact_for_every_width_exhaustive_small() {
    // Exhaustive over tiny widths: decomposition must be exact for every
    // operand pair up to 7 bits (all 16384 pairs), every organization.
    for width in 1..=7u32 {
        for kind in SchemeKind::ALL {
            let s = Scheme::for_int(kind, width);
            let mut stats = ExecStats::default();
            for a in 0..(1u64 << width) {
                for b in 0..(1u64 << width) {
                    let wa = U128::from_u64(a);
                    let wb = U128::from_u64(b);
                    let got = civp::decomp::execute(&s, wa, wb, &mut stats);
                    assert_eq!(got.as_u128(), (a as u128) * (b as u128), "{} {a}x{b}", s.name);
                }
            }
        }
    }
}

#[test]
fn census_matches_exec_stats_for_all_precisions() {
    // Static census and dynamic execution must agree on what fired. The
    // wide classes run the tree path — decomp::tests pins their census
    // against `Plan::execute_wide` per-mul stats instead.
    for prec in OpClass::ALL.into_iter().filter(|c| !c.is_wide()) {
        for kind in SchemeKind::ALL {
            let s = Scheme::new(kind, prec);
            let census = scheme_census(&s);
            let mut stats = ExecStats::default();
            let a = U128::ONE.shl(prec.sig_bits() - 1);
            civp::decomp::execute(&s, a, a, &mut stats);
            assert_eq!(stats.tiles, census.total_blocks as u64);
            assert_eq!(stats.padded_tiles, census.padded_blocks as u64);
            for (k, n) in &census.by_kind {
                assert_eq!(stats.ops(*k), *n as u64, "{kind:?} {prec:?}");
            }
        }
    }
}

#[test]
fn multiplication_sign_laws_all_precisions() {
    forall(0x600, 2_000, |rng| {
        // (-a) * b == -(a * b) for finite non-NaN results, all precisions.
        let a64 = f64::from_bits(rng.nasty_bits64() & !(1 << 63));
        let b64 = f64::from_bits(rng.nasty_bits64() & !(1 << 63));
        if a64.is_nan() || b64.is_nan() {
            return;
        }
        let pos = Fp64::from_f64(a64).mul(Fp64::from_f64(b64));
        let neg = Fp64::from_f64(-a64).mul(Fp64::from_f64(b64));
        if !pos.is_nan() {
            assert_eq!(neg.0, pos.0 ^ (1 << 63));
        }
        let qa = Fp128::from_f64(a64);
        let qb = Fp128::from_f64(b64);
        let qpos = qa.mul(qb);
        let qneg = Fp128(qa.0 ^ (1u128 << 127)).mul(qb);
        if !qpos.is_nan() {
            assert_eq!(qneg.0, qpos.0 ^ (1u128 << 127));
        }
    });
}

#[test]
fn rounding_mode_ordering_fp128() {
    // For positive finite products: rdn <= rtz <= rne <= rup, pairwise
    // within 1 ulp.
    forall(0x601, 2_000, |rng| {
        let a = Fp128::from_f64(f64::from_bits(rng.nasty_bits64() & !(1 << 63)));
        let b = Fp128::from_f64(f64::from_bits(rng.nasty_bits64() & !(1 << 63)));
        if a.is_nan() || b.is_nan() {
            return;
        }
        let get = |mode| {
            let (r, _) = a.mul_with(b, mode, &mut DirectMul);
            r.0
        };
        let dn = get(RoundMode::TowardNegative);
        let tz = get(RoundMode::TowardZero);
        let ne = get(RoundMode::NearestEven);
        let up = get(RoundMode::TowardPositive);
        if Fp128(ne).is_nan() || Fp128(up).class() == FpClass::Infinite {
            return;
        }
        // positive operands: packed-bit order == value order
        assert!(dn <= tz && tz <= ne && ne <= up, "a={:#x} b={:#x}", a.0, b.0);
        assert!(up - dn <= 1, "directed modes differ by > 1 ulp");
    });
}

#[test]
fn decomposed_equals_direct_under_every_mode() {
    forall(0x602, 1_000, |rng| {
        let mode = RoundMode::ALL[rng.below(5) as usize];
        let a = Fp64(rng.nasty_bits64());
        let b = Fp64(rng.nasty_bits64());
        let (want, wf) = a.mul_with(b, mode, &mut DirectMul);
        for kind in SchemeKind::ALL {
            let mut m = DecompMul::new(kind);
            let (got, gf) = a.mul_with(b, mode, &mut m);
            if want.is_nan() {
                assert!(got.is_nan());
            } else {
                assert_eq!(got.0, want.0, "{kind:?} {mode:?}");
            }
            assert_eq!(gf, wf, "flags must not depend on the multiplier backend");
        }
    });
}

#[test]
fn flags_consistency_across_precisions() {
    // overflow -> inexact; underflow -> inexact; exact small-int products
    // raise nothing.
    forall(0x603, 3_000, |rng| {
        let a = Fp64(rng.nasty_bits64());
        let b = Fp64(rng.nasty_bits64());
        let (r, f) = a.mul_with(b, RoundMode::NearestEven, &mut DirectMul);
        if f.overflow {
            assert!(f.inexact, "overflow implies inexact");
        }
        if f.underflow {
            assert!(f.inexact, "underflow (as flagged) implies inexact");
        }
        if f.invalid {
            assert!(r.is_nan());
        }
    });
    for prec_case in 0..3 {
        let (x, y) = (3.0f64, 5.0f64);
        match prec_case {
            0 => {
                let (r, f) = Fp32::from_f32(x as f32)
                    .mul_with(Fp32::from_f32(y as f32), RoundMode::NearestEven, &mut DirectMul);
                assert_eq!(r.to_f32(), 15.0);
                assert_eq!(f, Default::default());
            }
            1 => {
                let (r, f) = Fp64::from_f64(x)
                    .mul_with(Fp64::from_f64(y), RoundMode::NearestEven, &mut DirectMul);
                assert_eq!(r.to_f64(), 15.0);
                assert_eq!(f, Default::default());
            }
            _ => {
                let (r, f) = Fp128::from_f64(x)
                    .mul_with(Fp128::from_f64(y), RoundMode::NearestEven, &mut DirectMul);
                assert_eq!(r.to_f64_lossy(), 15.0);
                assert_eq!(f, Default::default());
            }
        }
    }
}

#[test]
fn pack_unpack_roundtrip_all_formats() {
    forall(0x604, 5_000, |rng| {
        for fmt in [&BF16, &HALF, &SINGLE, &DOUBLE, &QUAD] {
            let bits = fmt.total_bits();
            let raw = rand_bits(rng, bits);
            let u = fmt.unpack(raw);
            if matches!(u.class, FpClass::Nan) {
                return; // NaN payloads canonicalize; skip
            }
            let repacked = fmt.pack(u.sign, u.exp, u.sig);
            assert_eq!(repacked, raw, "{} roundtrip", fmt.name);
        }
    });
}

#[test]
fn quad_monotonicity_samples() {
    // x -> x*c is monotone in x for positive c (spot-check order preserved).
    forall(0x605, 1_000, |rng| {
        let c = Fp128::from_f64((rng.f64() + 0.5) * 1e3);
        let x1 = rng.f64() * 1e6;
        let x2 = x1 + rng.f64() * 1e3 + 1e-3;
        let p1 = Fp128::from_f64(x1).mul(c);
        let p2 = Fp128::from_f64(x2).mul(c);
        assert!(p1.0 <= p2.0, "monotonicity: {x1} {x2}");
    });
}

#[test]
fn decomp_exactness_against_wideint_oracle_wide_sweep() {
    // 128-bit-wide randomized sweep over all integer widths.
    forall(0x606, 1_500, |rng| {
        let width = rng.range(8, 128) as u32;
        let a = {
            let mut v = rand_bits(rng, width);
            if v.is_zero() {
                v = U128::ONE;
            }
            v
        };
        let b = rand_bits(rng, width);
        for kind in [SchemeKind::Civp, SchemeKind::Baseline18] {
            let s = Scheme::for_int(kind, width);
            let mut stats = ExecStats::default();
            let got = civp::decomp::execute(&s, a, b, &mut stats);
            assert_eq!(got, mul_u128(a, b), "{}", s.name);
            assert!(stats.utilization() > 0.0 && stats.utilization() <= 1.0);
        }
    });
}

#[test]
fn civp_full_utilization_only_at_native_widths() {
    // The paper's design point: utilization is 1.0 exactly when operand
    // widths tile perfectly (24/48/9/33...), below 1.0 otherwise.
    for width in [24u32, 48, 9, 33, 57, 96] {
        let c = scheme_census(&Scheme::for_int(SchemeKind::Civp, width));
        assert!(
            (c.utilization - 1.0).abs() < 1e-12,
            "width {width} should tile perfectly, got {}",
            c.utilization
        );
    }
    for width in [16u32, 25, 50, 113] {
        let c = scheme_census(&Scheme::for_int(SchemeKind::Civp, width));
        assert!(c.utilization < 1.0, "width {width} cannot tile perfectly");
    }
}
