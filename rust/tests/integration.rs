//! Cross-module integration tests: config → service → fabric accounting,
//! PJRT runtime behind the coordinator, trace-driven end-to-end runs, and
//! failure injection.

use civp::config::ServiceConfig;
use civp::coordinator::{Backend, BackendChoice, Service};
use civp::decomp::{OpClass, SchemeKind};
use civp::fabric::FabricKind;
use civp::fpu::{mul_bits_wide, Bf16, DirectMul, Fp128, Fp16, Fp32, Fp64, RoundMode};
use civp::wideint::PackedBits;
use civp::proput::Rng;
use civp::runtime::EngineHandle;
use civp::trace::{TraceGen, WorkloadSpec};
use std::path::Path;

fn artifacts_ready() -> bool {
    if cfg!(not(feature = "pjrt-xla")) {
        eprintln!("skipping: pjrt-xla feature disabled (stub engine)");
        return false;
    }
    let ok = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.txt").exists();
    if !ok {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
    }
    ok
}

#[test]
fn config_file_drives_service_end_to_end() {
    let dir = std::env::temp_dir().join(format!("civp-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("svc.toml");
    std::fs::write(
        &cfg_path,
        "[service]\nworkers = 1\nuse_pjrt = false\n[batcher]\nmax_batch = 16\nlinger_us = 50\n\
         [fabric]\nscheme = \"18x18\"\nkind = \"legacy\"\n[workload]\nspec = \"uniform\"\nseed = 3\n",
    )
    .unwrap();
    let cfg = ServiceConfig::from_file(&cfg_path).unwrap();
    assert_eq!(cfg.scheme, SchemeKind::Baseline18);
    let svc = Service::start(&cfg, BackendChoice::Native(cfg.scheme));
    let mut gen = TraceGen::new(cfg.seed, cfg.workload.mix(), 0);
    for req in gen.take(300) {
        let got = svc.mul_blocking(req.class, req.a, req.b);
        let (a, b) = (req.a, req.b);
        let want = match req.class {
            OpClass::Bf16 => {
                PackedBits::from_u64(Bf16(a.as_u64() as u16).mul(Bf16(b.as_u64() as u16)).0 as u64)
            }
            OpClass::Half => {
                PackedBits::from_u64(Fp16(a.as_u64() as u16).mul(Fp16(b.as_u64() as u16)).0 as u64)
            }
            OpClass::Single => {
                PackedBits::from_u64(Fp32(a.as_u64() as u32).mul(Fp32(b.as_u64() as u32)).0 as u64)
            }
            OpClass::Double => PackedBits::from_u64(Fp64(a.as_u64()).mul(Fp64(b.as_u64())).0),
            OpClass::Quad => PackedBits::from_u128(Fp128(a.as_u128()).mul(Fp128(b.as_u128())).0),
            OpClass::Fp256 | OpClass::Fp512 => {
                mul_bits_wide(req.class.format(), a, b, RoundMode::NearestEven, &mut DirectMul).0
            }
        };
        assert_eq!(got, want);
    }
    // fabric accounting uses the configured legacy fabric + 18x18 scheme
    let report = svc.fabric_report();
    assert!(report.fabric.starts_with("legacy"));
    assert_eq!(report.total_ops, 300);
    assert!(report.wasted_fraction() > 0.0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn pjrt_service_agrees_with_native_service() {
    if !artifacts_ready() {
        return;
    }
    let handle = EngineHandle::load(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )
    .expect("engine load");
    let cfg = ServiceConfig { workers: 1, max_batch: 256, linger_us: 300, ..Default::default() };
    let pjrt = Service::start(&cfg, BackendChoice::Pjrt(handle.clone()));
    let native = Service::start(&cfg, BackendChoice::native(SchemeKind::Civp));

    // The PJRT artifacts cover the paper's three classes only; sub-single
    // formats are native-backend-only until fp16/bf16 artifacts exist.
    let mix = civp::trace::WorkloadMix::from_pairs(&[
        (OpClass::Single, 1.0),
        (OpClass::Double, 1.0),
        (OpClass::Quad, 1.0),
    ]);
    let trace = TraceGen::new(99, mix, 0).take(600);
    let mut pjrt_rx = Vec::new();
    let mut native_rx = Vec::new();
    for req in &trace {
        pjrt_rx.push(pjrt.submit(req.id, req.class, req.a, req.b).unwrap());
        native_rx.push(native.submit(req.id, req.class, req.a, req.b).unwrap());
    }
    for (i, (p, n)) in pjrt_rx.into_iter().zip(native_rx).enumerate() {
        let pv = p.recv().unwrap().bits;
        let nv = n.recv().unwrap().bits;
        assert_eq!(pv, nv, "request {i} diverged between PJRT and native");
    }
    handle.stop();
}

#[test]
fn engine_handle_concurrent_clients() {
    if !artifacts_ready() {
        return;
    }
    let handle = EngineHandle::load(
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    )
    .unwrap();
    let threads: Vec<_> = (0..4)
        .map(|t| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                for _ in 0..5 {
                    let a: Vec<u128> =
                        (0..100).map(|_| (rng.nasty_bits64()) as u128).collect();
                    let b: Vec<u128> =
                        (0..100).map(|_| (rng.nasty_bits64()) as u128).collect();
                    let out = h.mul(OpClass::Double, a.clone(), b.clone()).unwrap();
                    for i in 0..100 {
                        let want = Fp64(a[i] as u64).mul(Fp64(b[i] as u64));
                        if !want.is_nan() {
                            assert_eq!(out[i] as u64, want.0);
                        }
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    handle.stop();
}

#[test]
fn engine_handle_load_failure_is_clean() {
    let err = EngineHandle::load("/nonexistent/artifacts-dir");
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("manifest") || msg.contains("reading"), "unhelpful error: {msg}");
}

/// A backend that fails on demand — exercises the worker error path.
struct FlakyBackend {
    fail_every: u64,
    count: u64,
}

impl Backend for FlakyBackend {
    fn execute(
        &mut self,
        _class: OpClass,
        a: &[u128],
        _b: &[u128],
        out: &mut Vec<u128>,
    ) -> civp::error::Result<()> {
        self.count += 1;
        if self.count % self.fail_every == 0 {
            civp::bail!("injected backend failure");
        }
        out.clear();
        out.extend_from_slice(a);
        Ok(())
    }
    fn name(&self) -> &'static str {
        "flaky"
    }
}

#[test]
fn worker_survives_backend_failures() {
    // Wrap the flaky backend through the native choice is not possible via
    // public API; instead drive the Backend trait directly to document the
    // failure contract, then verify the service-level error counter via a
    // real run with the native backend (which never fails).
    let mut be = FlakyBackend { fail_every: 3, count: 0 };
    let mut out = Vec::new();
    let mut ok = 0;
    let mut failed = 0;
    for _ in 0..9 {
        match be.execute(OpClass::Double, &[1, 2], &[3, 4], &mut out) {
            Ok(()) => {
                assert_eq!(out, vec![1, 2]);
                ok += 1;
            }
            Err(_) => failed += 1,
        }
    }
    assert_eq!((ok, failed), (6, 3));
}

#[test]
fn dropped_receiver_does_not_wedge_service() {
    let cfg = ServiceConfig { workers: 1, max_batch: 8, linger_us: 50, ..Default::default() };
    let svc = Service::start(&cfg, BackendChoice::native(SchemeKind::Civp));
    // submit and immediately drop receivers
    for i in 0..200u64 {
        let rx = svc.submit(i, OpClass::Double, 1u128 << 62, 1u128 << 62).unwrap();
        drop(rx);
    }
    // service still answers new requests
    let two = (2.0f64).to_bits() as u128;
    let bits = svc.mul_blocking(OpClass::Double, two, two);
    assert_eq!(f64::from_bits(bits.as_u64()), 4.0);
    let report = svc.shutdown();
    assert_eq!(report.responses, 201);
}

#[test]
fn service_under_all_workload_mixes() {
    for spec in WorkloadSpec::ALL {
        let cfg = ServiceConfig::default();
        let svc = Service::start(&cfg, BackendChoice::native(SchemeKind::Civp));
        let trace = TraceGen::new(5, spec.mix(), 0).take(400);
        let mut rxs = Vec::new();
        for req in &trace {
            rxs.push(svc.submit(req.id, req.class, req.a, req.b).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let fabric = svc.fabric_report();
        assert_eq!(fabric.total_ops, 400, "{}", spec.name());
        // CIVP fabric keeps waste low on every mix the paper's classes
        // dominate. The ml mix is sub-single-heavy: binary16's two-24x9
        // mapping pays extra array capacity for keeping the 24x24 pool
        // free, so its waste ceiling is documentedly higher.
        let ceiling = if spec == WorkloadSpec::MlInference { 0.45 } else { 0.15 };
        assert!(
            fabric.wasted_fraction() < ceiling,
            "{}: {}",
            spec.name(),
            fabric.wasted_fraction()
        );
    }
}

#[test]
fn legacy_vs_civp_fabric_headline_on_uniform_mix() {
    // The paper's conclusion, end-to-end: same traffic, CIVP fabric wastes
    // far less energy than the 18x18 fabric.
    let run = |scheme, fabric| {
        let cfg = ServiceConfig { scheme, fabric, ..Default::default() };
        let svc = Service::start(&cfg, BackendChoice::Native(scheme));
        let trace = TraceGen::new(11, WorkloadSpec::Uniform.mix(), 0).take(600);
        let mut rxs = Vec::new();
        for req in &trace {
            rxs.push(svc.submit(req.id, req.class, req.a, req.b).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        svc.fabric_report()
    };
    let civp = run(SchemeKind::Civp, FabricKind::Civp);
    let legacy = run(SchemeKind::Baseline18, FabricKind::Legacy);
    // E7 uniform mix: civp ~3%, legacy ~13% wasted (EXPERIMENTS.md)
    assert!(civp.wasted_fraction() < 0.10);
    assert!(legacy.wasted_fraction() > 0.10);
    assert!(legacy.energy_per_op() > civp.energy_per_op());
}
