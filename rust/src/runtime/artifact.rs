//! Artifact manifest parsing.

use crate::error::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// The manifest `aot.py` writes next to the HLO artifacts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    /// Fixed batch size every artifact was lowered with.
    pub batch: usize,
    /// Entry names, e.g. `civp_fp64` -> `<dir>/civp_fp64.hlo.txt`.
    pub entries: Vec<String>,
    /// Directory containing the artifacts.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text.
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut batch = None;
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(v) = line.strip_prefix("batch=") {
                batch = Some(v.parse::<usize>().context("manifest batch")?);
            } else {
                entries.push(line.to_string());
            }
        }
        let Some(batch) = batch else { bail!("manifest missing batch= line") };
        if batch == 0 {
            bail!("manifest batch must be positive");
        }
        if entries.is_empty() {
            bail!("manifest lists no entries");
        }
        Ok(Manifest { batch, entries, dir })
    }

    /// Path of one entry's HLO text.
    pub fn entry_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let m = Manifest::parse("batch=256\ncivp_fp32\ncivp_fp64\n", PathBuf::from("/a")).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.entries, vec!["civp_fp32", "civp_fp64"]);
        assert_eq!(m.entry_path("civp_fp32"), PathBuf::from("/a/civp_fp32.hlo.txt"));
    }

    #[test]
    fn parse_rejects_bad_manifests() {
        assert!(Manifest::parse("civp_fp32\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("batch=0\ncivp_fp32\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("batch=64\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("batch=x\na\n", PathBuf::new()).is_err());
    }
}
