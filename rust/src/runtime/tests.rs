//! Runtime integration tests: load the real artifacts, execute, and compare
//! bit-for-bit against the Rust softfloat (which is itself hardware-
//! verified). Requires `make artifacts` to have run; tests are skipped with
//! a clear message otherwise.

use super::*;
use crate::fpu::{Fp128, Fp32, Fp64};
use crate::proput::{forall, Rng};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    if cfg!(not(feature = "pjrt-xla")) {
        eprintln!("skipping runtime test: pjrt-xla feature disabled (stub engine)");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime test: artifacts/ missing (run `make artifacts`)");
        None
    }
}

fn engine() -> Option<Engine> {
    artifacts_dir().map(|d| Engine::load(d).expect("engine load"))
}

#[test]
fn load_reports_all_precisions() {
    let Some(e) = engine() else { return };
    assert_eq!(e.loaded().len(), 3);
    assert!(e.batch > 0);
    assert!(!e.platform().is_empty());
}

#[test]
fn fp64_matches_softfloat_exact_batch() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(0x900);
    let n = e.batch;
    let a: Vec<u64> = (0..n).map(|_| rng.nasty_bits64()).collect();
    let b: Vec<u64> = (0..n).map(|_| rng.nasty_bits64()).collect();
    let out = e.mul_fp64(&a, &b).unwrap();
    for i in 0..n {
        let sw = Fp64(a[i]).mul(Fp64(b[i]));
        if sw.is_nan() {
            assert!(Fp64(out[i]).is_nan(), "i={i}");
        } else {
            assert_eq!(out[i], sw.0, "i={i} a={:#x} b={:#x}", a[i], b[i]);
        }
    }
}

#[test]
fn fp32_matches_softfloat_with_padding() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(0x901);
    // deliberately not a multiple of the batch: exercises the pad path
    let n = e.batch + e.batch / 3 + 1;
    let a: Vec<u32> = (0..n).map(|_| rng.nasty_bits32()).collect();
    let b: Vec<u32> = (0..n).map(|_| rng.nasty_bits32()).collect();
    let out = e.mul_fp32(&a, &b).unwrap();
    assert_eq!(out.len(), n);
    for i in 0..n {
        let sw = Fp32(a[i]).mul(Fp32(b[i]));
        if sw.is_nan() {
            assert!(Fp32(out[i]).is_nan());
        } else {
            assert_eq!(out[i], sw.0, "i={i}");
        }
    }
    assert!(e.stats.padding_fraction() > 0.0);
}

#[test]
fn fp128_matches_softfloat() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(0x902);
    let n = 64; // sub-batch: pad path for the 2-word layout
    let a: Vec<u128> = (0..n)
        .map(|_| Fp128::from_f64(f64::from_bits(rng.nasty_bits64())).0)
        .collect();
    let b: Vec<u128> = (0..n)
        .map(|_| Fp128::from_f64(f64::from_bits(rng.nasty_bits64())).0)
        .collect();
    let out = e.mul_fp128(&a, &b).unwrap();
    for i in 0..n {
        let sw = Fp128(a[i]).mul(Fp128(b[i]));
        if sw.is_nan() {
            assert!(Fp128(out[i]).is_nan());
        } else {
            assert_eq!(out[i], sw.0, "i={i} a={:#x} b={:#x}", a[i], b[i]);
        }
    }
}

#[test]
fn fp64_multi_chunk_roundtrip() {
    let Some(e) = engine() else { return };
    forall(0x903, 3, |rng| {
        let n = e.batch * 2 + rng.below(e.batch as u64) as usize;
        let a: Vec<u64> = (0..n).map(|_| rng.nasty_bits64()).collect();
        let b: Vec<u64> = (0..n).map(|_| rng.nasty_bits64()).collect();
        let out = e.mul_fp64(&a, &b).unwrap();
        assert_eq!(out.len(), n);
        // spot-check a sample
        for _ in 0..32 {
            let i = rng.below(n as u64) as usize;
            let sw = Fp64(a[i]).mul(Fp64(b[i]));
            if !sw.is_nan() {
                assert_eq!(out[i], sw.0);
            }
        }
    });
}

#[test]
fn mismatched_lengths_rejected() {
    let Some(e) = engine() else { return };
    assert!(e.mul_fp64(&[1, 2], &[1]).is_err());
    assert!(e.mul_fp32(&[1], &[]).is_err());
}
