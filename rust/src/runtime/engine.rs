//! The PJRT engine: compiled executables per precision + batched dispatch.
//!
//! The real engine drives the `xla` crate (xla_extension PJRT bindings),
//! which the offline build environment cannot provide. It is therefore
//! compiled only under the `pjrt-xla` feature (which requires adding a
//! vendored `xla` dependency to `Cargo.toml`); the default build exposes a
//! stub [`Engine`] with the same surface whose `load` fails with a
//! descriptive error, so every caller — [`super::EngineHandle`], the
//! coordinator's PJRT backend, the CLI — compiles and degrades cleanly.

use std::sync::atomic::{AtomicU64, Ordering};

/// Dispatch counters (telemetry for EXPERIMENTS.md §Perf).
#[derive(Debug, Default)]
pub struct EngineStats {
    /// Batches executed per precision.
    pub batches_fp32: AtomicU64,
    /// Batches executed (fp64).
    pub batches_fp64: AtomicU64,
    /// Batches executed (fp128).
    pub batches_fp128: AtomicU64,
    /// Elements computed (including padding lanes).
    pub lanes_total: AtomicU64,
    /// Elements that were padding (measured waste, the serving analogue of
    /// the paper's padded blocks).
    pub lanes_padding: AtomicU64,
}

impl EngineStats {
    /// Padding fraction so far.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.lanes_total.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        self.lanes_padding.load(Ordering::Relaxed) as f64 / total as f64
    }
}

#[cfg(not(feature = "pjrt-xla"))]
pub use stub::Engine;
#[cfg(feature = "pjrt-xla")]
pub use xla_impl::Engine;

/// Stub engine for builds without the `pjrt-xla` feature.
#[cfg(not(feature = "pjrt-xla"))]
mod stub {
    use super::EngineStats;
    use crate::decomp::OpClass;
    use crate::error::{bail, Result};
    use std::path::Path;

    /// Placeholder for the PJRT runtime when the `pjrt-xla` feature (and
    /// its vendored `xla` dependency) is absent.
    ///
    /// [`Engine::load`] still validates the artifact manifest — so missing
    /// artifacts report the same actionable error as the real engine —
    /// then fails with a message naming the feature. The batched-multiply
    /// surface exists for API compatibility and always errors.
    pub struct Engine {
        /// Fixed artifact batch size.
        pub batch: usize,
        /// Dispatch counters.
        pub stats: EngineStats,
    }

    const UNAVAILABLE: &str =
        "PJRT engine not compiled in: enable the `pjrt-xla` feature with a vendored `xla` crate \
         (the native softfloat backend serves all precisions without it)";

    impl Engine {
        /// Validate the manifest, then fail: this build has no PJRT.
        pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
            let manifest = super::super::Manifest::load(&dir)?;
            bail!("{UNAVAILABLE} (found {} artifact entries)", manifest.entries.len());
        }

        /// Which op classes are loaded (always none in the stub).
        pub fn loaded(&self) -> Vec<OpClass> {
            Vec::new()
        }

        /// Batched binary32 multiply on packed bits (unavailable).
        pub fn mul_fp32(&self, _a: &[u32], _b: &[u32]) -> Result<Vec<u32>> {
            bail!("{UNAVAILABLE}");
        }

        /// Batched binary64 multiply on packed bits (unavailable).
        pub fn mul_fp64(&self, _a: &[u64], _b: &[u64]) -> Result<Vec<u64>> {
            bail!("{UNAVAILABLE}");
        }

        /// Batched binary128 multiply on packed bits (unavailable).
        pub fn mul_fp128(&self, _a: &[u128], _b: &[u128]) -> Result<Vec<u128>> {
            bail!("{UNAVAILABLE}");
        }

        /// Platform string (diagnostics).
        pub fn platform(&self) -> String {
            "unavailable (pjrt-xla feature disabled)".to_string()
        }
    }
}

/// Real PJRT engine, compiled only with the `pjrt-xla` feature.
#[cfg(feature = "pjrt-xla")]
mod xla_impl {
    use super::super::artifact::Manifest;
    use super::EngineStats;
    use crate::decomp::OpClass;
    use crate::error::{bail, ensure, Context, Result};
    use std::path::Path;
    use std::sync::atomic::Ordering;

    /// A compiled multiply executable for one precision.
    struct Entry {
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT runtime: one CPU client, one compiled executable per
    /// precision.
    ///
    /// `execute` takes packed bit patterns and returns packed bit patterns
    /// — the engine is oblivious to IEEE semantics (those live in the
    /// artifact). Inputs shorter than the artifact batch are padded with
    /// zeros; longer inputs are chunked.
    ///
    /// The xla crate's handles are not `Send`; multi-threaded callers use
    /// [`super::super::EngineHandle`], which owns the engine on a
    /// dedicated executor thread.
    pub struct Engine {
        client: xla::PjRtClient,
        fp32: Option<Entry>,
        fp64: Option<Entry>,
        fp128: Option<Entry>,
        /// Fixed artifact batch size.
        pub batch: usize,
        /// Dispatch counters.
        pub stats: EngineStats,
    }

    impl Engine {
        /// Load every artifact listed in `<dir>/manifest.txt`.
        pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
            let manifest = Manifest::load(&dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let mut engine = Engine {
                client,
                fp32: None,
                fp64: None,
                fp128: None,
                batch: manifest.batch,
                stats: EngineStats::default(),
            };
            for name in &manifest.entries {
                let path = manifest.entry_path(name);
                let entry = engine.compile_entry(&path)?;
                match name.as_str() {
                    "civp_fp32" => engine.fp32 = Some(entry),
                    "civp_fp64" => engine.fp64 = Some(entry),
                    "civp_fp128" => engine.fp128 = Some(entry),
                    other => bail!("unknown artifact entry {other}"),
                }
            }
            Ok(engine)
        }

        fn compile_entry(&self, path: &Path) -> Result<Entry> {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Entry { exe })
        }

        /// Which op classes are loaded.
        pub fn loaded(&self) -> Vec<OpClass> {
            let mut v = Vec::new();
            if self.fp32.is_some() {
                v.push(OpClass::Single);
            }
            if self.fp64.is_some() {
                v.push(OpClass::Double);
            }
            if self.fp128.is_some() {
                v.push(OpClass::Quad);
            }
            v
        }

        /// Batched binary32 multiply on packed bits. Arbitrary length; the
        /// engine chunks/pads to the artifact batch.
        pub fn mul_fp32(&self, a: &[u32], b: &[u32]) -> Result<Vec<u32>> {
            ensure!(a.len() == b.len(), "operand length mismatch");
            let Some(entry) = &self.fp32 else { bail!("fp32 artifact not loaded") };
            self.stats
                .batches_fp32
                .fetch_add(a.len().div_ceil(self.batch) as u64, Ordering::Relaxed);
            self.run_chunked(entry, a, b, |xs| xla::Literal::vec1(xs), |lit| lit.to_vec::<u32>())
        }

        /// Batched binary64 multiply on packed bits.
        pub fn mul_fp64(&self, a: &[u64], b: &[u64]) -> Result<Vec<u64>> {
            ensure!(a.len() == b.len(), "operand length mismatch");
            let Some(entry) = &self.fp64 else { bail!("fp64 artifact not loaded") };
            self.stats
                .batches_fp64
                .fetch_add(a.len().div_ceil(self.batch) as u64, Ordering::Relaxed);
            self.run_chunked(entry, a, b, |xs| xla::Literal::vec1(xs), |lit| lit.to_vec::<u64>())
        }

        /// Batched binary128 multiply on packed bits (u128 = lo | hi<<64).
        pub fn mul_fp128(&self, a: &[u128], b: &[u128]) -> Result<Vec<u128>> {
            ensure!(a.len() == b.len(), "operand length mismatch");
            let Some(entry) = &self.fp128 else { bail!("fp128 artifact not loaded") };
            self.stats
                .batches_fp128
                .fetch_add(a.len().div_ceil(self.batch) as u64, Ordering::Relaxed);
            let n = self.batch;
            let mut out = Vec::with_capacity(a.len());
            for (ca, cb) in a.chunks(n).zip(b.chunks(n)) {
                let len = ca.len();
                self.stats.lanes_total.fetch_add(n as u64, Ordering::Relaxed);
                self.stats.lanes_padding.fetch_add((n - len) as u64, Ordering::Relaxed);
                // words layout [B, 2]: row-major (lo, hi) pairs
                let mut wa = vec![0u64; 2 * n];
                let mut wb = vec![0u64; 2 * n];
                for i in 0..len {
                    wa[2 * i] = ca[i] as u64;
                    wa[2 * i + 1] = (ca[i] >> 64) as u64;
                    wb[2 * i] = cb[i] as u64;
                    wb[2 * i + 1] = (cb[i] >> 64) as u64;
                }
                let la = xla::Literal::vec1(&wa).reshape(&[n as i64, 2])?;
                let lb = xla::Literal::vec1(&wb).reshape(&[n as i64, 2])?;
                let result =
                    entry.exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
                let words = result.to_tuple1()?.to_vec::<u64>()?;
                ensure!(words.len() == 2 * n, "unexpected fp128 output length");
                for i in 0..len {
                    out.push(words[2 * i] as u128 | ((words[2 * i + 1] as u128) << 64));
                }
            }
            Ok(out)
        }

        fn run_chunked<T: Copy + Default + xla::NativeType + xla::ArrayElement>(
            &self,
            entry: &Entry,
            a: &[T],
            b: &[T],
            make: impl Fn(&[T]) -> xla::Literal,
            read: impl Fn(&xla::Literal) -> core::result::Result<Vec<T>, xla::Error>,
        ) -> Result<Vec<T>> {
            let n = self.batch;
            let mut out = Vec::with_capacity(a.len());
            let mut buf_a = vec![T::default(); n];
            let mut buf_b = vec![T::default(); n];
            for (ca, cb) in a.chunks(n).zip(b.chunks(n)) {
                let len = ca.len();
                self.stats.lanes_total.fetch_add(n as u64, Ordering::Relaxed);
                self.stats.lanes_padding.fetch_add((n - len) as u64, Ordering::Relaxed);
                let (la, lb) = if len == n {
                    (make(ca), make(cb))
                } else {
                    buf_a[..len].copy_from_slice(ca);
                    buf_a[len..].fill(T::default());
                    buf_b[..len].copy_from_slice(cb);
                    buf_b[len..].fill(T::default());
                    (make(&buf_a), make(&buf_b))
                };
                let result =
                    entry.exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
                let vals = read(&result.to_tuple1()?)?;
                ensure!(vals.len() == n, "unexpected output length");
                out.extend_from_slice(&vals[..len]);
            }
            Ok(out)
        }

        /// Platform string (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }
}
