//! Thread-safe handle to an [`Engine`](super::Engine) running on a
//! dedicated executor thread.
//!
//! The xla crate's PJRT wrappers hold `Rc`s and raw pointers, so
//! [`Engine`](super::Engine) is not `Send`. The handle owns the engine on
//! one executor thread and multiplexes batch jobs over an mpsc channel —
//! the standard "pinned device thread" pattern. Cloning the handle is
//! cheap; all clones feed the same executor (PJRT CPU execution is
//! serialized anyway).

use crate::decomp::OpClass;
use crate::error::{err, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

enum Job {
    Mul {
        class: OpClass,
        a: Vec<u128>,
        b: Vec<u128>,
        reply: mpsc::Sender<Result<Vec<u128>>>,
    },
    Info {
        reply: mpsc::Sender<EngineInfo>,
    },
    Stop,
}

/// Static facts about the loaded engine.
#[derive(Clone, Debug)]
pub struct EngineInfo {
    /// Artifact batch size.
    pub batch: usize,
    /// PJRT platform name.
    pub platform: String,
    /// Loaded op classes.
    pub loaded: Vec<OpClass>,
    /// Padding fraction so far (see `EngineStats`).
    pub padding_fraction: f64,
}

struct HandleInner {
    tx: mpsc::Sender<Job>,
    join: Mutex<Option<JoinHandle<()>>>,
}

/// Cloneable, `Send + Sync` front-end to a pinned-thread
/// [`Engine`](super::Engine).
#[derive(Clone)]
pub struct EngineHandle {
    inner: Arc<HandleInner>,
}

impl EngineHandle {
    /// Load the artifacts on a fresh executor thread.
    pub fn load(dir: impl Into<PathBuf>) -> Result<EngineHandle> {
        let dir = dir.into();
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("civp-pjrt-exec".to_string())
            .spawn(move || {
                let engine = match super::Engine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for job in rx {
                    match job {
                        Job::Mul { class, a, b, reply } => {
                            let out = match class {
                                OpClass::Single => {
                                    let xa: Vec<u32> = a.iter().map(|&v| v as u32).collect();
                                    let xb: Vec<u32> = b.iter().map(|&v| v as u32).collect();
                                    engine.mul_fp32(&xa, &xb).map(|v| {
                                        v.into_iter().map(|x| x as u128).collect()
                                    })
                                }
                                OpClass::Double => {
                                    let xa: Vec<u64> = a.iter().map(|&v| v as u64).collect();
                                    let xb: Vec<u64> = b.iter().map(|&v| v as u64).collect();
                                    engine.mul_fp64(&xa, &xb).map(|v| {
                                        v.into_iter().map(|x| x as u128).collect()
                                    })
                                }
                                OpClass::Quad => engine.mul_fp128(&a, &b),
                                // No sub-single or wide artifacts are
                                // compiled yet (the u128 job payload also
                                // cannot carry a wide operand);
                                // `PjrtBackend` serves these through its
                                // embedded native fallback, so reaching the
                                // engine with one is a caller error, not a
                                // panic.
                                OpClass::Half
                                | OpClass::Bf16
                                | OpClass::Fp256
                                | OpClass::Fp512 => Err(err!(
                                    "pjrt engine has no {} artifact (use the native backend \
                                     for sub-single and wide classes)",
                                    class.name()
                                )),
                            };
                            let _ = reply.send(out);
                        }
                        Job::Info { reply } => {
                            let _ = reply.send(EngineInfo {
                                batch: engine.batch,
                                platform: engine.platform(),
                                loaded: engine.loaded(),
                                padding_fraction: engine.stats.padding_fraction(),
                            });
                        }
                        Job::Stop => break,
                    }
                }
            })?;
        ready_rx.recv().map_err(|_| err!("executor thread died during load"))??;
        Ok(EngineHandle { inner: Arc::new(HandleInner { tx, join: Mutex::new(Some(join)) }) })
    }

    /// Batched multiply of packed bit patterns (any length).
    pub fn mul(&self, class: OpClass, a: Vec<u128>, b: Vec<u128>) -> Result<Vec<u128>> {
        let (reply, rx) = mpsc::channel();
        self.inner
            .tx
            .send(Job::Mul { class, a, b, reply })
            .map_err(|_| err!("engine executor stopped"))?;
        rx.recv().map_err(|_| err!("engine executor dropped reply"))?
    }

    /// Engine facts.
    pub fn info(&self) -> Result<EngineInfo> {
        let (reply, rx) = mpsc::channel();
        self.inner.tx.send(Job::Info { reply }).map_err(|_| err!("engine executor stopped"))?;
        rx.recv().map_err(|_| err!("engine executor dropped reply"))
    }

    /// Stop the executor (joins the thread). Subsequent calls error.
    pub fn stop(&self) {
        let _ = self.inner.tx.send(Job::Stop);
        if let Some(j) = self.inner.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

impl Drop for HandleInner {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Stop);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}
