//! PJRT execution of the AOT-compiled JAX/Pallas artifacts.
//!
//! The Python side (`python/compile/aot.py`) lowers the Layer-2 model once
//! to HLO *text*; this module loads those artifacts, compiles them on the
//! PJRT CPU client and exposes batched multiply calls to the coordinator.
//! Python never runs on this path.
//!
//! Interchange is HLO text (not serialized `HloModuleProto`): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

mod artifact;
mod engine;
mod handle;
#[cfg(test)]
mod tests;

pub use artifact::Manifest;
pub use engine::{Engine, EngineStats};
pub use handle::{EngineHandle, EngineInfo};
