//! The service: router → per-class batchers → worker pool → backend,
//! with fabric accounting and telemetry.

use super::backend::BackendChoice;
use super::batcher::Batcher;
use super::oneshot::{ReplyHandle, ReplyPool, ReplySender};
use crate::serve::AdmissionError;
use super::request::{Request, Response};
use crate::config::ServiceConfig;
use crate::decomp::{OpClass, SchemeKind};
use crate::fabric::{simulate_counts, CostModel, FabricConfig, FabricKind, FabricOp, StreamReport};
use crate::metrics::Registry;
use crate::wideint::PackedBits;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct Item {
    req: Request,
    reply: ReplySender,
}

struct Shared {
    /// One batcher per op class, indexed by [`OpClass::index`] — a flat
    /// array lookup on the submit and worker paths (no map walk — §Perf).
    batchers: [Batcher<Item>; OpClass::COUNT],
    metrics: Registry,
    /// Hot-path instruments, resolved once (no registry lookup or string
    /// formatting per request — §Perf).
    hot: HotMetrics,
    /// Lock-free per-class op counters for the fabric report.
    op_counts: OpCounters,
    /// Recycled oneshot reply slots, one pool per op class (no
    /// per-request channel allocation, and the free-list mutex shares the
    /// serialization domain of that class's batcher instead of being a
    /// single cross-class contention point).
    pools: [ReplyPool; OpClass::COUNT],
    max_batch: usize,
    linger: Duration,
    scheme: SchemeKind,
}

struct HotMetrics {
    requests_total: std::sync::Arc<crate::metrics::Counter>,
    requests_by_class: [std::sync::Arc<crate::metrics::Counter>; OpClass::COUNT],
    rejected: std::sync::Arc<crate::metrics::Counter>,
}

impl HotMetrics {
    fn resolve(metrics: &Registry) -> HotMetrics {
        HotMetrics {
            requests_total: metrics.counter("requests_total"),
            requests_by_class: core::array::from_fn(|i| {
                metrics.counter(&format!("requests_{}", OpClass::from_index(i).name()))
            }),
            rejected: metrics.counter("rejected_queue_full"),
        }
    }
}

/// Flat array of per-(organization × class) operation counters.
///
/// Workers bump one [`AtomicU64`] per *batch* (relaxed ordering); report
/// readers snapshot the whole array without taking any lock. The
/// consistency guarantee for clients: a worker increments the counter
/// *before* releasing the batch's replies, and the release/acquire pairing
/// of the reply-slot mutex makes the increment visible to any thread that
/// has observed the response — so a client that got its answer always sees
/// its op in [`Service::fabric_report`].
struct OpCounters {
    /// Indexed `kind.index() * OpClass::COUNT + class.index()`.
    counts: [AtomicU64; SchemeKind::COUNT * OpClass::COUNT],
}

/// `const` initializer usable for array repetition.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO_COUNTER: AtomicU64 = AtomicU64::new(0);

impl OpCounters {
    fn new() -> OpCounters {
        OpCounters { counts: [ZERO_COUNTER; SchemeKind::COUNT * OpClass::COUNT] }
    }

    #[inline]
    fn slot(&self, op: FabricOp) -> &AtomicU64 {
        &self.counts[op.organization.index() * OpClass::COUNT + op.class.index()]
    }

    /// Lock-free snapshot of all non-zero classes.
    fn snapshot(&self) -> BTreeMap<FabricOp, u64> {
        let mut out = BTreeMap::new();
        for kind in SchemeKind::ALL {
            for class in OpClass::ALL {
                let op = FabricOp { class, organization: kind };
                let n = self.slot(op).load(Ordering::Relaxed);
                if n > 0 {
                    out.insert(op, n);
                }
            }
        }
        out
    }
}

/// The running multiplication service.
///
/// `submit` routes a request to its op-class queue and returns a reply
/// handle for the response; `mul_blocking` is the convenience wrapper.
/// Dropping the service (or calling [`Service::shutdown`]) drains queues
/// and joins the workers.
pub struct Service {
    shared: Arc<Shared>,
    /// Worker handles, behind a mutex so [`Service::drain`] works from
    /// `&self` (and therefore through an `Arc<Service>` shared with
    /// submitting threads). Joining happens *inside* the lock, so every
    /// concurrent drain caller returns only once the pool is quiescent.
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// Shared lane executor when the backend choice carries one —
    /// kept so telemetry snapshots can publish its counters.
    executor: Option<Arc<crate::decomp::Executor>>,
    /// Lane configuration the workers batch under (native backends) —
    /// published as the `lane_width` / `lane_kernel_*` gauges.
    lane: Option<crate::decomp::LaneConfig>,
    fabric: FabricConfig,
    cost: CostModel,
    backend_name: &'static str,
}

impl Service {
    /// Start a service per `cfg` with the given backend.
    pub fn start(cfg: &ServiceConfig, backend: BackendChoice) -> Service {
        let metrics = Registry::new();
        let hot = HotMetrics::resolve(&metrics);
        let shared = Arc::new(Shared {
            batchers: core::array::from_fn(|_| Batcher::new(cfg.queue_depth)),
            metrics,
            hot,
            op_counts: OpCounters::new(),
            pools: core::array::from_fn(|_| ReplyPool::new()),
            max_batch: cfg.max_batch,
            linger: Duration::from_micros(cfg.linger_us),
            scheme: cfg.scheme,
        });
        let backend_name = match &backend {
            BackendChoice::Native(_) => "native",
            BackendChoice::Pjrt(_) => "pjrt",
        };
        let executor = backend.executor().cloned();
        let lane = backend.lane_config();
        // One worker set per op-class queue; each worker owns a backend
        // instance (op classes tallied lock-free into `op_counts`).
        let mut workers = Vec::new();
        for class in OpClass::ALL {
            for w in 0..cfg.workers {
                let shared = shared.clone();
                let mut be = backend.build();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("civp-{}-{w}", class.name()))
                        .spawn(move || worker_loop(class, shared, be.as_mut()))
                        .expect("spawn worker"),
                );
            }
        }
        let fabric = match cfg.fabric {
            FabricKind::Civp => FabricConfig::civp_scaled(cfg.fabric_scale),
            FabricKind::Legacy => FabricConfig::legacy_scaled(cfg.fabric_scale),
        };
        Service {
            shared,
            workers: Mutex::new(workers),
            executor,
            lane,
            fabric,
            cost: CostModel::default(),
            backend_name,
        }
    }

    /// Submit a request; returns the reply handle. Blocks on backpressure
    /// when the class queue is full.
    ///
    /// Request counters are bumped only once the batcher has *accepted*
    /// the item, so `requests_total` / `requests_{class}` count exactly the
    /// requests that will receive a reply (or be drained at shutdown).
    pub fn submit(
        &self,
        id: u64,
        class: OpClass,
        a: impl Into<PackedBits>,
        b: impl Into<PackedBits>,
    ) -> Result<ReplyHandle, AdmissionError> {
        let (tx, rx) = self.shared.pools[class.index()].acquire();
        let req = Request { id, class, a: a.into(), b: b.into(), enqueued: Instant::now() };
        self.shared.batchers[class.index()].submit(Item { req, reply: tx })?;
        self.shared.hot.requests_total.inc();
        self.shared.hot.requests_by_class[class.index()].inc();
        Ok(rx)
    }

    /// Submit without blocking; `Saturated` applies backpressure to the
    /// caller. Accounting matches [`Service::submit`]: accepted requests
    /// bump `requests_total` and the per-class counter exactly once;
    /// rejected ones bump only `rejected_queue_full`.
    pub fn try_submit(
        &self,
        id: u64,
        class: OpClass,
        a: impl Into<PackedBits>,
        b: impl Into<PackedBits>,
    ) -> Result<ReplyHandle, AdmissionError> {
        let (tx, rx) = self.shared.pools[class.index()].acquire();
        let req = Request { id, class, a: a.into(), b: b.into(), enqueued: Instant::now() };
        match self.shared.batchers[class.index()].try_submit(Item { req, reply: tx }) {
            Ok(()) => {
                self.shared.hot.requests_total.inc();
                self.shared.hot.requests_by_class[class.index()].inc();
                Ok(rx)
            }
            Err(e) => {
                if e == AdmissionError::Saturated {
                    self.shared.hot.rejected.inc();
                }
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn mul_blocking(
        &self,
        class: OpClass,
        a: impl Into<PackedBits>,
        b: impl Into<PackedBits>,
    ) -> PackedBits {
        let rx = self.submit(0, class, a, b).expect("service closed");
        rx.recv().expect("worker dropped reply").bits
    }

    /// Telemetry snapshot. When the backend runs on the shared lane
    /// executor, its per-worker steal/execute counters are published
    /// into the registry (as gauges) before the snapshot is taken.
    /// Native backends also publish the lane configuration: the
    /// `lane_width` gauge carries the SoA block width and the
    /// `lane_kernel_{isa}-{width}` gauge (value 1) names the dispatched
    /// sweep kernel, e.g. `lane_kernel_avx2-w16`.
    pub fn metrics(&self) -> crate::metrics::Snapshot {
        if let Some(exec) = &self.executor {
            exec.publish(&self.shared.metrics);
        }
        if let Some(lane) = self.lane {
            self.shared.metrics.gauge("lane_width").set(lane.width.width() as i64);
            self.shared
                .metrics
                .gauge(&format!("lane_kernel_{}", lane.kernel_name()))
                .set(1);
        }
        self.shared.metrics.snapshot()
    }

    /// Lock-free snapshot of the per-class op counters.
    ///
    /// Consistency: workers account a batch's ops *before* releasing its
    /// replies, so a caller that has received a response is guaranteed to
    /// see that op included here. No lock is held while reading; a
    /// snapshot taken concurrently with in-flight batches may trail them.
    pub fn op_counts(&self) -> BTreeMap<FabricOp, u64> {
        self.shared.op_counts.snapshot()
    }

    /// Fabric-level report for everything executed so far: the accumulated
    /// per-class counts through the cycle/energy model (E7), computed in
    /// closed form — O(#op-classes), independent of how many requests have
    /// been served, and bit-identical to replaying the op stream through
    /// [`crate::fabric::simulate_stream`].
    pub fn fabric_report(&self) -> StreamReport {
        simulate_counts(&self.shared.op_counts.snapshot(), &self.fabric, &self.cost)
    }

    /// Service-level summary (throughput etc. come from the caller's wall
    /// clock; this report carries queue/batch telemetry).
    pub fn report(&self) -> ServiceReport {
        let snap = self.metrics();
        ServiceReport {
            backend: self.backend_name,
            requests: snap.counters.get("requests_total").copied().unwrap_or(0),
            responses: snap.counters.get("responses_total").copied().unwrap_or(0),
            rejected: snap.counters.get("rejected_queue_full").copied().unwrap_or(0),
            snapshot: snap,
        }
    }

    /// Close queues and join workers (drains in-flight batches).
    pub fn shutdown(self) -> ServiceReport {
        self.shutdown_inner();
        self.report()
    }

    /// Close queues and join workers *without* consuming the service —
    /// the cluster layer drains every shard first, then reads the final
    /// (now quiescent) op counters for the aggregated fabric report.
    ///
    /// Takes `&self`, so any thread holding an `Arc<Service>` may drain
    /// while others are still submitting (late submits fail with
    /// `Draining`; everything accepted before the close still gets exactly
    /// one reply). Idempotent and safe to race with itself: concurrent
    /// drains serialize on the worker-handle lock, and every caller
    /// returns only after the worker pool is quiescent — so the op
    /// counters a drainer reads afterwards are final. Pinned by
    /// `service_concurrent_drain_under_load_loses_nothing`.
    pub fn drain(&self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&self) {
        for b in &self.shared.batchers {
            b.close();
        }
        // Join while holding the lock: a concurrent drain caller blocks
        // here until the first finishes joining, so *every* drain returns
        // with the pool stopped (not just the winner of the race).
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(class: OpClass, shared: Arc<Shared>, backend: &mut dyn super::Backend) {
    let lat = shared.metrics.histogram(&format!("latency_ns_{}", class.name()));
    let bsize = shared.metrics.histogram(&format!("batch_size_{}", class.name()));
    let responses = shared.metrics.counter("responses_total");
    let batches = shared.metrics.counter("batches_total");
    let errors = shared.metrics.counter("backend_errors");
    // Everything loop-invariant is resolved once: the class's batcher,
    // the op counter slot, and the scratch buffers. With the backend
    // writing into `out` and the significand plans shared via `PlanCache`,
    // the steady-state batch path performs no allocation; each drained
    // batch then executes through the native backend's lane-fused pipeline
    // (specials sidecar + tile-major `Plan::execute_lanes`), so the worker
    // hands the whole batch to one fused call instead of N scalar
    // pipeline passes (§Perf).
    let batcher = &shared.batchers[class.index()];
    let op_counter = shared.op_counts.slot(FabricOp { class, organization: shared.scheme });
    let mut a: Vec<PackedBits> = Vec::with_capacity(shared.max_batch);
    let mut b: Vec<PackedBits> = Vec::with_capacity(shared.max_batch);
    let mut out: Vec<PackedBits> = Vec::with_capacity(shared.max_batch);
    while let Some(batch) = batcher.next_batch(shared.max_batch, shared.linger) {
        let n = batch.len();
        bsize.record(n as u64);
        batches.inc();
        a.clear();
        a.extend(batch.iter().map(|i| i.req.a));
        b.clear();
        b.extend(batch.iter().map(|i| i.req.b));
        match backend.execute(class, &a, &b, &mut out) {
            Ok(()) => {
                debug_assert_eq!(out.len(), n, "backend produced wrong batch size");
                // Account the ops *before* releasing replies so a client
                // that observed its response also observes the op in
                // `fabric_report` (see `OpCounters`).
                op_counter.fetch_add(n as u64, Ordering::Relaxed);
                let now = Instant::now();
                for (item, &bits) in batch.into_iter().zip(out.iter()) {
                    let latency = now.duration_since(item.req.enqueued).as_nanos() as u64;
                    lat.record(latency);
                    responses.inc();
                    // Receiver may have given up; delivery into an
                    // abandoned slot is harmless.
                    item.reply.send(Response {
                        id: item.req.id,
                        bits,
                        latency_ns: latency,
                        batch_size: n as u32,
                    });
                }
            }
            Err(e) => {
                errors.inc();
                eprintln!(
                    "civp worker: backend {} failed on {} batch: {e:#}",
                    backend.name(),
                    class.name()
                );
                // Drop replies: receivers observe a closed slot.
            }
        }
    }
}

/// Summary returned by [`Service::report`] / [`Service::shutdown`].
#[derive(Clone, Debug)]
pub struct ServiceReport {
    /// Backend name.
    pub backend: &'static str,
    /// Requests accepted.
    pub requests: u64,
    /// Responses delivered.
    pub responses: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Full metrics snapshot.
    pub snapshot: crate::metrics::Snapshot,
}
