//! The service: router → per-precision batchers → worker pool → backend,
//! with fabric accounting and telemetry.

use super::backend::BackendChoice;
use super::batcher::{Batcher, SubmitError};
use super::request::{Request, Response};
use crate::config::ServiceConfig;
use crate::decomp::{Precision, SchemeKind};
use crate::fabric::{simulate_stream, CostModel, FabricConfig, FabricKind, OpClass, StreamReport};
use crate::metrics::Registry;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

struct Item {
    req: Request,
    reply: mpsc::Sender<Response>,
}

struct Shared {
    batchers: BTreeMap<Precision, Batcher<Item>>,
    metrics: Registry,
    /// Hot-path instruments, resolved once (no registry lookup or string
    /// formatting per request — §Perf).
    hot: HotMetrics,
    /// Op counts per class for the fabric report.
    op_counts: Mutex<BTreeMap<OpClass, u64>>,
    max_batch: usize,
    linger: Duration,
    scheme: SchemeKind,
}

struct HotMetrics {
    requests_total: std::sync::Arc<crate::metrics::Counter>,
    requests_by_prec: [std::sync::Arc<crate::metrics::Counter>; 3],
    rejected: std::sync::Arc<crate::metrics::Counter>,
}

impl HotMetrics {
    fn resolve(metrics: &Registry) -> HotMetrics {
        HotMetrics {
            requests_total: metrics.counter("requests_total"),
            requests_by_prec: [
                metrics.counter("requests_single"),
                metrics.counter("requests_double"),
                metrics.counter("requests_quad"),
            ],
            rejected: metrics.counter("rejected_queue_full"),
        }
    }
}

#[inline]
fn prec_idx(p: Precision) -> usize {
    match p {
        Precision::Single => 0,
        Precision::Double => 1,
        Precision::Quad => 2,
    }
}

/// The running multiplication service.
///
/// `submit` routes a request to its precision queue and returns a receiver
/// for the response; `mul_blocking` is the convenience wrapper. Dropping
/// the service (or calling [`Service::shutdown`]) drains queues and joins
/// the workers.
pub struct Service {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    fabric: FabricConfig,
    cost: CostModel,
    backend_name: &'static str,
}

impl Service {
    /// Start a service per `cfg` with the given backend.
    pub fn start(cfg: &ServiceConfig, backend: BackendChoice) -> Service {
        let mut batchers = BTreeMap::new();
        for p in Precision::ALL {
            batchers.insert(p, Batcher::new(cfg.queue_depth));
        }
        let metrics = Registry::new();
        let hot = HotMetrics::resolve(&metrics);
        let shared = Arc::new(Shared {
            batchers,
            metrics,
            hot,
            op_counts: Mutex::new(BTreeMap::new()),
            max_batch: cfg.max_batch,
            linger: Duration::from_micros(cfg.linger_us),
            scheme: cfg.scheme,
        });
        let backend_name = match &backend {
            BackendChoice::Native(_) => "native",
            BackendChoice::Pjrt(_) => "pjrt",
        };
        // One worker set per precision queue; each worker owns a backend
        // instance (DecompMul stats merge into op_counts via class counts).
        let mut workers = Vec::new();
        for p in Precision::ALL {
            for w in 0..cfg.workers {
                let shared = shared.clone();
                let mut be = backend.build();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("civp-{}-{w}", p.name()))
                        .spawn(move || worker_loop(p, shared, be.as_mut()))
                        .expect("spawn worker"),
                );
            }
        }
        let fabric = match cfg.fabric {
            FabricKind::Civp => FabricConfig::civp_scaled(cfg.fabric_scale),
            FabricKind::Legacy => FabricConfig::legacy_scaled(cfg.fabric_scale),
        };
        Service { shared, workers, fabric, cost: CostModel::default(), backend_name }
    }

    /// Submit a request; returns the response channel. Blocks on
    /// backpressure when the precision queue is full.
    pub fn submit(
        &self,
        id: u64,
        precision: Precision,
        a: u128,
        b: u128,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        let req = Request { id, precision, a, b, enqueued: Instant::now() };
        self.shared.hot.requests_total.inc();
        self.shared.hot.requests_by_prec[prec_idx(precision)].inc();
        self.shared.batchers[&precision].submit(Item { req, reply: tx })?;
        Ok(rx)
    }

    /// Submit without blocking; `QueueFull` applies backpressure to the
    /// caller.
    pub fn try_submit(
        &self,
        id: u64,
        precision: Precision,
        a: u128,
        b: u128,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        let req = Request { id, precision, a, b, enqueued: Instant::now() };
        match self.shared.batchers[&precision].try_submit(Item { req, reply: tx }) {
            Ok(()) => {
                self.shared.hot.requests_total.inc();
                Ok(rx)
            }
            Err(e) => {
                if e == SubmitError::QueueFull {
                    self.shared.hot.rejected.inc();
                }
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn mul_blocking(&self, precision: Precision, a: u128, b: u128) -> u128 {
        let rx = self.submit(0, precision, a, b).expect("service closed");
        rx.recv().expect("worker dropped reply").bits
    }

    /// Telemetry snapshot.
    pub fn metrics(&self) -> crate::metrics::Snapshot {
        self.shared.metrics.snapshot()
    }

    /// Fabric-level report for everything executed so far: replays the op
    /// mix through the cycle/energy model (E7).
    pub fn fabric_report(&self) -> StreamReport {
        let counts = self.shared.op_counts.lock().unwrap().clone();
        let mut ops = Vec::new();
        for (class, n) in counts {
            for _ in 0..n {
                ops.push(class);
            }
        }
        simulate_stream(&ops, &self.fabric, &self.cost)
    }

    /// Service-level summary (throughput etc. come from the caller's wall
    /// clock; this report carries queue/batch telemetry).
    pub fn report(&self) -> ServiceReport {
        let snap = self.metrics();
        ServiceReport {
            backend: self.backend_name,
            requests: snap.counters.get("requests_total").copied().unwrap_or(0),
            responses: snap.counters.get("responses_total").copied().unwrap_or(0),
            rejected: snap.counters.get("rejected_queue_full").copied().unwrap_or(0),
            snapshot: snap,
        }
    }

    /// Close queues and join workers (drains in-flight batches).
    pub fn shutdown(mut self) -> ServiceReport {
        self.shutdown_inner();
        self.report()
    }

    fn shutdown_inner(&mut self) {
        for b in self.shared.batchers.values() {
            b.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn worker_loop(precision: Precision, shared: Arc<Shared>, backend: &mut dyn super::Backend) {
    let lat = shared.metrics.histogram(&format!("latency_ns_{}", precision.name()));
    let bsize = shared.metrics.histogram(&format!("batch_size_{}", precision.name()));
    let responses = shared.metrics.counter("responses_total");
    let batches = shared.metrics.counter("batches_total");
    let errors = shared.metrics.counter("backend_errors");
    // Per-worker scratch, reused across batches: with the backend writing
    // into `out` and the significand plans shared via `PlanCache`, the
    // steady-state batch path performs no allocation (§Perf).
    let mut a: Vec<u128> = Vec::with_capacity(shared.max_batch);
    let mut b: Vec<u128> = Vec::with_capacity(shared.max_batch);
    let mut out: Vec<u128> = Vec::with_capacity(shared.max_batch);
    while let Some(batch) = shared.batchers[&precision].next_batch(shared.max_batch, shared.linger)
    {
        let n = batch.len();
        bsize.record(n as u64);
        batches.inc();
        a.clear();
        a.extend(batch.iter().map(|i| i.req.a));
        b.clear();
        b.extend(batch.iter().map(|i| i.req.b));
        match backend.execute(precision, &a, &b, &mut out) {
            Ok(()) => {
                debug_assert_eq!(out.len(), n, "backend produced wrong batch size");
                // Account the ops *before* releasing replies so a client
                // that observed its response also observes the op in
                // `fabric_report`.
                let class = OpClass { precision, organization: shared.scheme };
                *shared.op_counts.lock().unwrap().entry(class).or_insert(0) += n as u64;
                let now = Instant::now();
                for (item, &bits) in batch.into_iter().zip(out.iter()) {
                    let latency = now.duration_since(item.req.enqueued).as_nanos() as u64;
                    lat.record(latency);
                    responses.inc();
                    // Receiver may have given up; ignore send failures.
                    let _ = item.reply.send(Response {
                        id: item.req.id,
                        bits,
                        latency_ns: latency,
                        batch_size: n as u32,
                    });
                }
            }
            Err(e) => {
                errors.inc();
                eprintln!(
                    "civp worker: backend {} failed on {} batch: {e:#}",
                    backend.name(),
                    precision.name()
                );
                // Drop replies: receivers observe a closed channel.
            }
        }
    }
}

/// Summary returned by [`Service::report`] / [`Service::shutdown`].
#[derive(Clone, Debug)]
pub struct ServiceReport {
    /// Backend name.
    pub backend: &'static str,
    /// Requests accepted.
    pub requests: u64,
    /// Responses delivered.
    pub responses: u64,
    /// Requests rejected by backpressure.
    pub rejected: u64,
    /// Full metrics snapshot.
    pub snapshot: crate::metrics::Snapshot,
}
