//! Request/response types for the multiplication service.

use crate::decomp::OpClass;
use crate::wideint::PackedBits;
use std::time::Instant;

/// A multiplication request. Operand bits are packed interchange patterns
/// of the request's op class, carried in the low bits of a [`PackedBits`]
/// word — wide enough for every registry class up to binary512.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Client-assigned id, echoed in the response.
    pub id: u64,
    /// Operation class of the operands and result.
    pub class: OpClass,
    /// Packed operand A.
    pub a: PackedBits,
    /// Packed operand B.
    pub b: PackedBits,
    /// Enqueue timestamp (set by the service).
    pub enqueued: Instant,
}

/// A completed multiplication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Response {
    /// Echo of the request id.
    pub id: u64,
    /// Packed product bits.
    pub bits: PackedBits,
    /// Queue + batch + execute time.
    pub latency_ns: u64,
    /// Size of the batch this request was served in (telemetry).
    pub batch_size: u32,
}
