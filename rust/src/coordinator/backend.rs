//! Execution backends: native softfloat (+CIVP decomposition accounting)
//! and the AOT PJRT engine.

use crate::decomp::{DecompMul, ExecStats, Executor, LaneConfig, OpClass, SchemeKind};
use crate::error::{ensure, Result};
use crate::fpu::{FpuBatch, RoundMode};
use crate::runtime::EngineHandle;
use crate::wideint::PackedBits;
use std::sync::Arc;

/// A batch executor for one op class.
///
/// Operands travel as [`PackedBits`] — the universal packed word, wide
/// enough for every registry class (binary512 included); narrow classes
/// use the low `total_bits`. `execute` writes into a caller-owned output
/// vector so the worker pool can reuse one scratch allocation across
/// batches — together with the process-wide plan cache this makes the
/// batch path allocation-free in steady state.
pub trait Backend: Send {
    /// Multiply packed bit patterns elementwise. `a` and `b` must have
    /// equal length; `out` is cleared and filled with packed patterns of
    /// the same class (one per input pair).
    fn execute(
        &mut self,
        class: OpClass,
        a: &[PackedBits],
        b: &[PackedBits],
        out: &mut Vec<PackedBits>,
    ) -> Result<()>;
    /// Backend display name.
    fn name(&self) -> &'static str;
    /// Decomposition stats accumulated so far (native backend only).
    fn exec_stats(&self) -> Option<&ExecStats> {
        None
    }
    /// The lane configuration (SoA width × dispatched vector ISA) this
    /// backend's batches run under, when it has one (native backends).
    fn lane_config(&self) -> Option<LaneConfig> {
        None
    }
}

/// Native-backend construction options: one builder for every native
/// shape instead of the accreted `Native` / `NativeLane` /
/// `NativeParallel` variant triple this replaced.
///
/// Start from [`NativeOptions::new`] (scheme only — default scalar
/// `LANES`-wide lane blocks) and chain:
///
/// * [`lane_config`](NativeOptions::lane_config) — explicit SoA block
///   width × dispatched vector ISA (`--lane-width`). Bit-identical to
///   the default for every width and ISA.
/// * [`executor`](NativeOptions::executor) — share a work-stealing lane
///   [`Executor`] (`--cores`): large batches fan out across its worker
///   pool. The executor carries its own lane configuration, which takes
///   precedence over [`lane_config`](NativeOptions::lane_config).
///
/// ```
/// use civp::coordinator::{BackendChoice, NativeOptions};
/// use civp::decomp::{LaneConfig, LaneWidth, SchemeKind};
///
/// let plain = BackendChoice::native(SchemeKind::Civp);
/// let lanes = BackendChoice::Native(
///     NativeOptions::new(SchemeKind::Civp)
///         .lane_config(LaneConfig::detect(LaneWidth::W16)),
/// );
/// assert_eq!(plain.lane_config().unwrap().width, LaneWidth::W8);
/// assert_eq!(lanes.lane_config().unwrap().width, LaneWidth::W16);
/// ```
#[derive(Clone)]
pub struct NativeOptions {
    scheme: SchemeKind,
    lane: Option<LaneConfig>,
    executor: Option<Arc<Executor>>,
}

impl NativeOptions {
    /// Options for the given partition organization, with the default
    /// scalar lane configuration and no shared executor.
    pub fn new(scheme: SchemeKind) -> NativeOptions {
        NativeOptions { scheme, lane: None, executor: None }
    }

    /// Override the partition organization.
    pub fn scheme(mut self, scheme: SchemeKind) -> NativeOptions {
        self.scheme = scheme;
        self
    }

    /// Explicit lane configuration for inline batches (ignored when an
    /// [`executor`](NativeOptions::executor) is also set — the executor's
    /// own lane configuration wins).
    pub fn lane_config(mut self, lane: LaneConfig) -> NativeOptions {
        self.lane = Some(lane);
        self
    }

    /// Share a work-stealing lane executor across every worker's backend
    /// (the executor's worker pool is a machine resource shared by the
    /// whole service).
    pub fn executor(mut self, exec: Arc<Executor>) -> NativeOptions {
        self.executor = Some(exec);
        self
    }

    /// The configured partition organization.
    pub fn scheme_kind(&self) -> SchemeKind {
        self.scheme
    }

    /// The lane configuration batches built from these options run under.
    pub fn effective_lane_config(&self) -> LaneConfig {
        match (&self.executor, self.lane) {
            (Some(exec), _) => exec.lane_config(),
            (None, Some(lane)) => lane,
            (None, None) => LaneConfig::SCALAR,
        }
    }

    fn build(&self) -> NativeBackend {
        match (&self.executor, self.lane) {
            (Some(exec), _) => NativeBackend::with_executor(self.scheme, exec.clone()),
            (None, Some(lane)) => NativeBackend::with_lane(self.scheme, lane),
            (None, None) => NativeBackend::new(self.scheme),
        }
    }
}

/// How a service should construct its workers' backends.
#[derive(Clone)]
pub enum BackendChoice {
    /// Native softfloat, configured through one [`NativeOptions`] builder
    /// (scheme × lane configuration × optional shared executor).
    Native(NativeOptions),
    /// AOT JAX/Pallas artifacts through PJRT (pinned executor thread).
    Pjrt(EngineHandle),
}

impl BackendChoice {
    /// Convenience: a plain native choice for `scheme` with default
    /// options (scalar lane blocks, no shared executor).
    pub fn native(scheme: SchemeKind) -> BackendChoice {
        BackendChoice::Native(NativeOptions::new(scheme))
    }

    /// Instantiate a backend for one worker.
    pub fn build(&self) -> Box<dyn Backend> {
        match self {
            BackendChoice::Native(opts) => Box::new(opts.build()),
            BackendChoice::Pjrt(handle) => Box::new(PjrtBackend::new(handle.clone())),
        }
    }

    /// The shared lane executor, when this choice carries one.
    pub fn executor(&self) -> Option<&Arc<Executor>> {
        match self {
            BackendChoice::Native(opts) => opts.executor.as_ref(),
            BackendChoice::Pjrt(_) => None,
        }
    }

    /// The lane configuration the built backends will batch under
    /// (native choices only — PJRT batches bypass the lane engine).
    pub fn lane_config(&self) -> Option<LaneConfig> {
        match self {
            BackendChoice::Native(opts) => Some(opts.effective_lane_config()),
            BackendChoice::Pjrt(_) => None,
        }
    }
}

/// Native softfloat backend: the IEEE pipeline with the CIVP (or baseline)
/// decomposed significand multiplier. Tallies block usage per multiply.
///
/// §Perf: batches run the **lane-fused** pipeline end-to-end — a
/// [`FpuBatch`] peels specials into a scalar sidecar and streams every
/// remaining significand product tile-major through the shared
/// [`crate::decomp::PlanCache`] plans (`Plan::execute_lanes`), so every
/// worker in the pool reuses the same compiled tile plans and the whole
/// batch is accounted with one scaled stats merge.
pub struct NativeBackend {
    fpu: FpuBatch<DecompMul>,
    /// Narrow scratch: `PackedBits` batches from the service surface fold
    /// down to `u128` for the lane-fused narrow pipeline (wide classes go
    /// straight through [`FpuBatch::mul_batch_bits_wide`]).
    na: Vec<u128>,
    nb: Vec<u128>,
    nout: Vec<u128>,
}

impl NativeBackend {
    /// New backend with the given organization.
    pub fn new(kind: SchemeKind) -> NativeBackend {
        Self::from_mul(DecompMul::new(kind))
    }

    /// New backend sharing a work-stealing [`Executor`]: significand
    /// batches at or above the executor's threshold split into
    /// lane-aligned chunks across its worker pool (§Perf), bit-for-bit
    /// identical to [`NativeBackend::new`]'s single-threaded path —
    /// results, flags and stats (pinned by `rust/tests/parallel_equiv.rs`).
    pub fn with_executor(kind: SchemeKind, exec: Arc<Executor>) -> NativeBackend {
        Self::from_mul(DecompMul::with_executor(kind, exec))
    }

    /// New backend with an explicit lane configuration for its inline
    /// batches. Every width × ISA combination is bit-identical to
    /// [`NativeBackend::new`] (pinned by the lane property tests).
    pub fn with_lane(kind: SchemeKind, lane: LaneConfig) -> NativeBackend {
        Self::from_mul(DecompMul::with_lane(kind, lane))
    }

    fn from_mul(m: DecompMul) -> NativeBackend {
        NativeBackend {
            fpu: FpuBatch::new(m),
            na: Vec::new(),
            nb: Vec::new(),
            nout: Vec::new(),
        }
    }

    /// Multiply one batch, appending packed products to `out` (cleared
    /// first). Exposed for direct (service-less) batch callers and benches.
    /// The format descriptor comes straight off the [`OpClass`] registry,
    /// so every served class — sub-single and wide formats included — runs
    /// the appropriate fused pipeline: lane-fused SoA for classes within
    /// the `u128` operand word, the tile-tree wide path above it.
    pub fn mul_batch(
        &mut self,
        class: OpClass,
        a: &[PackedBits],
        b: &[PackedBits],
        out: &mut Vec<PackedBits>,
    ) -> Result<()> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        if class.is_wide() {
            self.fpu.mul_batch_bits_wide(class.format(), a, b, RoundMode::NearestEven, out);
            return Ok(());
        }
        self.na.clear();
        self.na.extend(a.iter().map(PackedBits::as_u128));
        self.nb.clear();
        self.nb.extend(b.iter().map(PackedBits::as_u128));
        self.fpu.mul_batch_bits(
            class.format(),
            &self.na,
            &self.nb,
            RoundMode::NearestEven,
            &mut self.nout,
        );
        out.clear();
        out.extend(self.nout.iter().map(|&v| PackedBits::from_u128(v)));
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn execute(
        &mut self,
        class: OpClass,
        a: &[PackedBits],
        b: &[PackedBits],
        out: &mut Vec<PackedBits>,
    ) -> Result<()> {
        self.mul_batch(class, a, b, out)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn exec_stats(&self) -> Option<&ExecStats> {
        Some(&self.fpu.multiplier().stats)
    }

    fn lane_config(&self) -> Option<LaneConfig> {
        Some(self.fpu.multiplier().lane_config())
    }
}

/// PJRT backend: batches go through the compiled HLO artifacts on the
/// pinned executor thread. The artifacts cover the paper's three classes
/// (single/double/quad); sub-single and wide batches fall back to the
/// embedded native pipeline, so a PJRT service still serves the whole
/// registry.
pub struct PjrtBackend {
    handle: EngineHandle,
    /// Native fallback for classes without a compiled artifact.
    native: NativeBackend,
}

impl PjrtBackend {
    /// New backend sharing a loaded engine.
    pub fn new(handle: EngineHandle) -> PjrtBackend {
        PjrtBackend { handle, native: NativeBackend::new(SchemeKind::Civp) }
    }
}

impl Backend for PjrtBackend {
    fn execute(
        &mut self,
        class: OpClass,
        a: &[PackedBits],
        b: &[PackedBits],
        out: &mut Vec<PackedBits>,
    ) -> Result<()> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        match class {
            // No fp16/bf16/fp256/fp512 artifacts exist yet (the engine's
            // job payload is u128-wide): serve these natively instead of
            // erroring the batch (and dropping its replies).
            OpClass::Bf16 | OpClass::Half | OpClass::Fp256 | OpClass::Fp512 => {
                self.native.execute(class, a, b, out)
            }
            _ => {
                let xa: Vec<u128> = a.iter().map(PackedBits::as_u128).collect();
                let xb: Vec<u128> = b.iter().map(PackedBits::as_u128).collect();
                let bits = self.handle.mul(class, xa, xb)?;
                out.clear();
                out.extend(bits.into_iter().map(PackedBits::from_u128));
                Ok(())
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
