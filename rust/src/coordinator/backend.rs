//! Execution backends: native softfloat (+CIVP decomposition accounting)
//! and the AOT PJRT engine.

use crate::decomp::{DecompMul, ExecStats, Precision, SchemeKind};
use crate::fpu::{mul_bits, RoundMode, DOUBLE, QUAD, SINGLE};
use crate::runtime::EngineHandle;
use crate::wideint::U128;
use anyhow::Result;

/// A batch executor for one precision class.
pub trait Backend: Send {
    /// Multiply packed bit patterns elementwise. All slices have equal
    /// length; results are packed patterns of the same precision.
    fn execute(&mut self, precision: Precision, a: &[u128], b: &[u128]) -> Result<Vec<u128>>;
    /// Backend display name.
    fn name(&self) -> &'static str;
    /// Decomposition stats accumulated so far (native backend only).
    fn exec_stats(&self) -> Option<&ExecStats> {
        None
    }
}

/// How a service should construct its workers' backends.
#[derive(Clone)]
pub enum BackendChoice {
    /// Native softfloat with the given partition organization.
    Native(SchemeKind),
    /// AOT JAX/Pallas artifacts through PJRT (pinned executor thread).
    Pjrt(EngineHandle),
}

impl BackendChoice {
    /// Instantiate a backend for one worker.
    pub fn build(&self) -> Box<dyn Backend> {
        match self {
            BackendChoice::Native(kind) => Box::new(NativeBackend::new(*kind)),
            BackendChoice::Pjrt(handle) => Box::new(PjrtBackend::new(handle.clone())),
        }
    }
}

/// Native softfloat backend: the IEEE pipeline with the CIVP (or baseline)
/// decomposed significand multiplier. Tallies block usage per multiply.
pub struct NativeBackend {
    mul: DecompMul,
}

impl NativeBackend {
    /// New backend with the given organization.
    pub fn new(kind: SchemeKind) -> NativeBackend {
        NativeBackend { mul: DecompMul::new(kind) }
    }
}

impl Backend for NativeBackend {
    fn execute(&mut self, precision: Precision, a: &[u128], b: &[u128]) -> Result<Vec<u128>> {
        anyhow::ensure!(a.len() == b.len(), "operand length mismatch");
        let fmt = match precision {
            Precision::Single => &SINGLE,
            Precision::Double => &DOUBLE,
            Precision::Quad => &QUAD,
        };
        let mut out = Vec::with_capacity(a.len());
        for (&x, &y) in a.iter().zip(b) {
            let (bits, _flags) = mul_bits(
                fmt,
                U128::from_u128(x),
                U128::from_u128(y),
                RoundMode::NearestEven,
                &mut self.mul,
            );
            out.push(bits.as_u128());
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn exec_stats(&self) -> Option<&ExecStats> {
        Some(&self.mul.stats)
    }
}

/// PJRT backend: batches go through the compiled HLO artifacts on the
/// pinned executor thread.
pub struct PjrtBackend {
    handle: EngineHandle,
}

impl PjrtBackend {
    /// New backend sharing a loaded engine.
    pub fn new(handle: EngineHandle) -> PjrtBackend {
        PjrtBackend { handle }
    }
}

impl Backend for PjrtBackend {
    fn execute(&mut self, precision: Precision, a: &[u128], b: &[u128]) -> Result<Vec<u128>> {
        anyhow::ensure!(a.len() == b.len(), "operand length mismatch");
        self.handle.mul(precision, a.to_vec(), b.to_vec())
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
