//! Execution backends: native softfloat (+CIVP decomposition accounting)
//! and the AOT PJRT engine.

use crate::decomp::{DecompMul, ExecStats, Executor, LaneConfig, OpClass, SchemeKind};
use crate::error::{ensure, Result};
use crate::fpu::{FpuBatch, RoundMode};
use crate::runtime::EngineHandle;
use std::sync::Arc;

/// A batch executor for one op class.
///
/// `execute` writes into a caller-owned output vector so the worker pool
/// can reuse one scratch allocation across batches — together with the
/// process-wide plan cache this makes the batch path allocation-free in
/// steady state.
pub trait Backend: Send {
    /// Multiply packed bit patterns elementwise. `a` and `b` must have
    /// equal length; `out` is cleared and filled with packed patterns of
    /// the same class (one per input pair).
    fn execute(
        &mut self,
        class: OpClass,
        a: &[u128],
        b: &[u128],
        out: &mut Vec<u128>,
    ) -> Result<()>;
    /// Backend display name.
    fn name(&self) -> &'static str;
    /// Decomposition stats accumulated so far (native backend only).
    fn exec_stats(&self) -> Option<&ExecStats> {
        None
    }
    /// The lane configuration (SoA width × dispatched vector ISA) this
    /// backend's batches run under, when it has one (native backends).
    fn lane_config(&self) -> Option<LaneConfig> {
        None
    }
}

/// How a service should construct its workers' backends.
#[derive(Clone)]
pub enum BackendChoice {
    /// Native softfloat with the given partition organization (default
    /// scalar `LANES`-wide lane blocks).
    Native(SchemeKind),
    /// Native softfloat with an explicit lane configuration: SoA block
    /// width (`service.lane_width` / `--lane-width`) × the dispatched
    /// vector ISA. Bit-identical to [`BackendChoice::Native`] for every
    /// width and ISA.
    NativeLane(SchemeKind, LaneConfig),
    /// Native softfloat whose large batches fan out across the shared
    /// work-stealing lane executor (`--cores`). Every worker's backend
    /// holds the same `Arc` — the executor's worker pool is a machine
    /// resource shared by the whole service.
    NativeParallel(SchemeKind, Arc<Executor>),
    /// AOT JAX/Pallas artifacts through PJRT (pinned executor thread).
    Pjrt(EngineHandle),
}

impl BackendChoice {
    /// Instantiate a backend for one worker.
    pub fn build(&self) -> Box<dyn Backend> {
        match self {
            BackendChoice::Native(kind) => Box::new(NativeBackend::new(*kind)),
            BackendChoice::NativeLane(kind, lane) => {
                Box::new(NativeBackend::with_lane(*kind, *lane))
            }
            BackendChoice::NativeParallel(kind, exec) => {
                Box::new(NativeBackend::with_executor(*kind, exec.clone()))
            }
            BackendChoice::Pjrt(handle) => Box::new(PjrtBackend::new(handle.clone())),
        }
    }

    /// The shared lane executor, when this choice carries one.
    pub fn executor(&self) -> Option<&Arc<Executor>> {
        match self {
            BackendChoice::NativeParallel(_, exec) => Some(exec),
            _ => None,
        }
    }

    /// The lane configuration the built backends will batch under
    /// (native choices only — PJRT batches bypass the lane engine).
    pub fn lane_config(&self) -> Option<LaneConfig> {
        match self {
            BackendChoice::Native(_) => Some(LaneConfig::SCALAR),
            BackendChoice::NativeLane(_, lane) => Some(*lane),
            BackendChoice::NativeParallel(_, exec) => Some(exec.lane_config()),
            BackendChoice::Pjrt(_) => None,
        }
    }
}

/// Native softfloat backend: the IEEE pipeline with the CIVP (or baseline)
/// decomposed significand multiplier. Tallies block usage per multiply.
///
/// §Perf: batches run the **lane-fused** pipeline end-to-end — a
/// [`FpuBatch`] peels specials into a scalar sidecar and streams every
/// remaining significand product tile-major through the shared
/// [`crate::decomp::PlanCache`] plans (`Plan::execute_lanes`), so every
/// worker in the pool reuses the same compiled tile plans and the whole
/// batch is accounted with one scaled stats merge.
pub struct NativeBackend {
    fpu: FpuBatch<DecompMul>,
}

impl NativeBackend {
    /// New backend with the given organization.
    pub fn new(kind: SchemeKind) -> NativeBackend {
        NativeBackend { fpu: FpuBatch::new(DecompMul::new(kind)) }
    }

    /// New backend sharing a work-stealing [`Executor`]: significand
    /// batches at or above the executor's threshold split into
    /// lane-aligned chunks across its worker pool (§Perf), bit-for-bit
    /// identical to [`NativeBackend::new`]'s single-threaded path —
    /// results, flags and stats (pinned by `rust/tests/parallel_equiv.rs`).
    pub fn with_executor(kind: SchemeKind, exec: Arc<Executor>) -> NativeBackend {
        NativeBackend { fpu: FpuBatch::new(DecompMul::with_executor(kind, exec)) }
    }

    /// New backend with an explicit lane configuration for its inline
    /// batches. Every width × ISA combination is bit-identical to
    /// [`NativeBackend::new`] (pinned by the lane property tests).
    pub fn with_lane(kind: SchemeKind, lane: LaneConfig) -> NativeBackend {
        NativeBackend { fpu: FpuBatch::new(DecompMul::with_lane(kind, lane)) }
    }

    /// Multiply one batch, appending packed products to `out` (cleared
    /// first). Exposed for direct (service-less) batch callers and benches.
    /// The format descriptor comes straight off the [`OpClass`] registry,
    /// so every served class — sub-single formats included — runs the same
    /// lane-fused pipeline.
    pub fn mul_batch(
        &mut self,
        class: OpClass,
        a: &[u128],
        b: &[u128],
        out: &mut Vec<u128>,
    ) -> Result<()> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        self.fpu.mul_batch_bits(class.format(), a, b, RoundMode::NearestEven, out);
        Ok(())
    }
}

impl Backend for NativeBackend {
    fn execute(
        &mut self,
        class: OpClass,
        a: &[u128],
        b: &[u128],
        out: &mut Vec<u128>,
    ) -> Result<()> {
        self.mul_batch(class, a, b, out)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn exec_stats(&self) -> Option<&ExecStats> {
        Some(&self.fpu.multiplier().stats)
    }

    fn lane_config(&self) -> Option<LaneConfig> {
        Some(self.fpu.multiplier().lane_config())
    }
}

/// PJRT backend: batches go through the compiled HLO artifacts on the
/// pinned executor thread. The artifacts cover the paper's three classes
/// (single/double/quad); sub-single batches fall back to the embedded
/// native lane-fused pipeline, so a PJRT service still serves the whole
/// registry.
pub struct PjrtBackend {
    handle: EngineHandle,
    /// Native fallback for classes without a compiled artifact.
    native: NativeBackend,
}

impl PjrtBackend {
    /// New backend sharing a loaded engine.
    pub fn new(handle: EngineHandle) -> PjrtBackend {
        PjrtBackend { handle, native: NativeBackend::new(SchemeKind::Civp) }
    }
}

impl Backend for PjrtBackend {
    fn execute(
        &mut self,
        class: OpClass,
        a: &[u128],
        b: &[u128],
        out: &mut Vec<u128>,
    ) -> Result<()> {
        ensure!(a.len() == b.len(), "operand length mismatch");
        match class {
            // No fp16/bf16 artifacts exist yet: serve these natively
            // instead of erroring the batch (and dropping its replies).
            OpClass::Bf16 | OpClass::Half => self.native.execute(class, a, b, out),
            _ => {
                let bits = self.handle.mul(class, a.to_vec(), b.to_vec())?;
                out.clear();
                out.extend(bits);
                Ok(())
            }
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
