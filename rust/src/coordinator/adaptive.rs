//! Adaptive-precision geometric predicates — the paper's motivating
//! application class ([5] Shewchuk: "Adaptive precision floating-point
//! arithmetic and fast robust geometric predicates").
//!
//! `orient2d` decides which side of the line AB the point C lies on. The
//! fast path computes the determinant in single precision with a forward
//! error bound; when the determinant's magnitude falls inside the bound the
//! sign is unreliable and the computation escalates (double, then quad) —
//! exactly the single→higher-precision demand pattern §I argues FPGAs
//! should serve, and the reason a CIVP fabric sees mixed-precision traffic.
//!
//! Multiplications go through the [`Service`] (they are the operations the
//! paper's fabric accelerates); additions are host-side (soft logic).

use super::service::Service;
use crate::decomp::OpClass;
use crate::fpu::{Fp128, Fp32, Fp64};

/// Orientation of C relative to the directed line A→B.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Orient {
    /// Counter-clockwise (positive determinant).
    Ccw,
    /// Clockwise (negative determinant).
    Cw,
    /// Exactly collinear.
    Collinear,
}

/// Telemetry from adaptive evaluation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdaptiveStats {
    /// Predicates settled in single precision.
    pub settled_single: u64,
    /// Escalations to double that settled there.
    pub settled_double: u64,
    /// Escalations all the way to quad.
    pub settled_quad: u64,
}

impl AdaptiveStats {
    /// Total predicates evaluated.
    pub fn total(&self) -> u64 {
        self.settled_single + self.settled_double + self.settled_quad
    }
}

/// Machine epsilons for the error bound, per precision.
const EPS32: f64 = 5.9604644775390625e-8; // 2^-24
const EPS64: f64 = 1.1102230246251565e-16; // 2^-53

/// Evaluate orient2d adaptively, escalating through the service.
///
/// Error-bound structure follows Shewchuk's `orient2dfast` filter:
/// `|det| > c * eps * (|t1| + |t2|)` certifies the sign at the evaluating
/// precision; otherwise escalate. Quad precision is treated as exact for
/// f64 input coordinates (113 bits >= the 106-bit exact products; the
/// subtraction preconditioning keeps the sums representable).
pub fn orient2d_adaptive(
    svc: &Service,
    a: (f64, f64),
    b: (f64, f64),
    c: (f64, f64),
    stats: &mut AdaptiveStats,
) -> Orient {
    // --- single-precision attempt ---------------------------------------
    let (acx, acy) = ((a.0 - c.0) as f32, (a.1 - c.1) as f32);
    let (bcx, bcy) = ((b.0 - c.0) as f32, (b.1 - c.1) as f32);
    let t1 = mul32(svc, acx, bcy);
    let t2 = mul32(svc, acy, bcx);
    let det = t1 as f64 - t2 as f64;
    let bound = 4.0 * EPS32 as f64 * (t1.abs() as f64 + t2.abs() as f64);
    if det.abs() > bound && det_inputs_exact32(a, b, c) {
        stats.settled_single += 1;
        return sign_of(det);
    }

    // --- double-precision attempt ----------------------------------------
    let (acx, acy) = (a.0 - c.0, a.1 - c.1);
    let (bcx, bcy) = (b.0 - c.0, b.1 - c.1);
    let t1 = mul64(svc, acx, bcy);
    let t2 = mul64(svc, acy, bcx);
    let det = t1 - t2;
    let bound = 4.0 * EPS64 * (t1.abs() + t2.abs());
    if det.abs() > bound {
        stats.settled_double += 1;
        return sign_of(det);
    }

    // --- quad (exact for f64 inputs after exact differences) --------------
    stats.settled_quad += 1;
    let t1 = mul128(svc, Fp128::from_f64(acx), Fp128::from_f64(bcy));
    let t2 = mul128(svc, Fp128::from_f64(acy), Fp128::from_f64(bcx));
    // the products are exact in binary128; compare them directly
    match cmp_fp128(t1, t2) {
        core::cmp::Ordering::Greater => Orient::Ccw,
        core::cmp::Ordering::Less => Orient::Cw,
        core::cmp::Ordering::Equal => Orient::Collinear,
    }
}

/// The f32 filter is only sound when the coordinate differences were
/// computed exactly; for the synthetic workloads here we simply check the
/// round-trip. (Shewchuk's full scheme uses expansion arithmetic instead.)
fn det_inputs_exact32(a: (f64, f64), b: (f64, f64), c: (f64, f64)) -> bool {
    let exact = |x: f64, y: f64| ((x - y) as f32) as f64 == x - y;
    exact(a.0, c.0) && exact(a.1, c.1) && exact(b.0, c.0) && exact(b.1, c.1)
}

fn mul32(svc: &Service, x: f32, y: f32) -> f32 {
    let (a, b) = (Fp32::from_f32(x).0 as u128, Fp32::from_f32(y).0 as u128);
    let bits = svc.mul_blocking(OpClass::Single, a, b);
    Fp32(bits.as_u64() as u32).to_f32()
}

fn mul64(svc: &Service, x: f64, y: f64) -> f64 {
    let (a, b) = (Fp64::from_f64(x).0 as u128, Fp64::from_f64(y).0 as u128);
    let bits = svc.mul_blocking(OpClass::Double, a, b);
    Fp64(bits.as_u64()).to_f64()
}

fn mul128(svc: &Service, x: Fp128, y: Fp128) -> Fp128 {
    Fp128(svc.mul_blocking(OpClass::Quad, x.0, y.0).as_u128())
}

fn sign_of(det: f64) -> Orient {
    if det > 0.0 {
        Orient::Ccw
    } else if det < 0.0 {
        Orient::Cw
    } else {
        Orient::Collinear
    }
}

/// Total order on finite binary128 values by value (sign + magnitude).
fn cmp_fp128(x: Fp128, y: Fp128) -> core::cmp::Ordering {
    let sx = x.sign();
    let sy = y.sign();
    let mag = |v: Fp128| v.0 & !(1u128 << 127);
    // normalize -0 == +0
    if mag(x) == 0 && mag(y) == 0 {
        return core::cmp::Ordering::Equal;
    }
    match (sx, sy) {
        (false, true) => core::cmp::Ordering::Greater,
        (true, false) => core::cmp::Ordering::Less,
        (false, false) => mag(x).cmp(&mag(y)),
        (true, true) => mag(y).cmp(&mag(x)),
    }
}
