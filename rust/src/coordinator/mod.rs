//! Layer-3 coordinator: the variable-precision multiplication service.
//!
//! The deployment shape the paper motivates (§I: multimedia pipelines whose
//! precision demand varies per request, single through quadruple) as a
//! serving system:
//!
//! ```text
//!   clients ──submit──▶ router ──▶ per-precision dynamic batcher (bounded,
//!       size+linger policy, backpressure) ──▶ worker pool ──▶ backend
//!                                                              │
//!                          native softfloat + CIVP decomposition│
//!                          or AOT JAX/Pallas artifacts via PJRT ┘
//! ```
//!
//! Workers tally simulated FPGA block usage per operation class (lock-free
//! atomic counters), so every run also produces the paper's fabric-level
//! utilization/energy report — computed in closed form from the per-class
//! counts, independent of how many requests were served. Responses travel
//! back through pooled oneshot reply slots (`oneshot`), not per-request
//! channels, keeping the steady-state submit→response path allocation-free.
//!
//! Each worker's native backend executes its drained batches through the
//! lane-fused FP pipeline ([`crate::fpu::FpuBatch`] →
//! `Plan::execute_lanes`): specials peel into a scalar sidecar and every
//! remaining significand product streams tile-major through the shared
//! compiled plans — the batch analogue of the paper's static tile wiring.

mod adaptive;
mod backend;
mod batcher;
mod oneshot;
mod request;
mod service;
#[cfg(test)]
mod tests;

pub use adaptive::{orient2d_adaptive, AdaptiveStats, Orient};
pub use backend::{Backend, BackendChoice, NativeBackend, NativeOptions, PjrtBackend};
pub use batcher::Batcher;
pub use oneshot::{RecvError, ReplyHandle, ReplyPool, ReplySender, TryRecvError};
pub use request::{Request, Response};
pub use service::{Service, ServiceReport};
