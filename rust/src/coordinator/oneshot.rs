//! Pooled oneshot reply slots: the request→response rendezvous without a
//! per-request channel allocation.
//!
//! The seed service created an `std::sync::mpsc::channel()` per request —
//! an allocation (and its upgrade machinery) on the submit hot path for a
//! value that is sent exactly once. A [`ReplyPool`] replaces it with a
//! recycled slot: one `Mutex<SlotState>` + `Condvar` per in-flight request,
//! drawn from a free list and returned to it when **both** sides (the
//! worker's [`ReplySender`] and the client's [`ReplyHandle`]) are done. In
//! steady state — a bounded number of requests in flight — `submit` performs
//! no allocation at all (§Perf).
//!
//! Protocol: each side sets its `*_dropped` flag in its `Drop` impl under
//! the slot mutex; whichever side drops *second* observes both flags set,
//! resets the slot and pushes it back onto the free list. Because the flags
//! are only ever written in `Drop` and the check happens in the same
//! critical section, exactly one side recycles and never while the other
//! side can still touch the slot.

use super::request::Response;
use std::sync::{Arc, Condvar, Mutex};

/// Upper bound on pooled slots — beyond this, retired slots are simply
/// freed. Sized generously above any sane in-flight count (queue depths
/// default to 4096 per precision).
const POOL_CAP: usize = 16_384;

/// The worker side of the slot dropped without delivering a response
/// (backend error or shutdown) — the oneshot analogue of a closed channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RecvError;

impl core::fmt::Display for RecvError {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "reply sender dropped without a response")
    }
}

impl std::error::Error for RecvError {}

/// Error from [`ReplyHandle::try_recv`] — mirrors
/// `std::sync::mpsc::TryRecvError` so pollers can tell a pending response
/// from a dead request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TryRecvError {
    /// No response yet; the worker may still deliver one.
    Empty,
    /// The sender dropped without delivering a response (backend error or
    /// shutdown) — no response will ever arrive.
    Disconnected,
}

impl core::fmt::Display for TryRecvError {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        match self {
            TryRecvError::Empty => write!(f, "response not ready yet"),
            TryRecvError::Disconnected => {
                write!(f, "reply sender dropped without a response")
            }
        }
    }
}

impl std::error::Error for TryRecvError {}

/// Rendezvous state for one in-flight request.
#[derive(Debug, Default)]
struct SlotState {
    /// The delivered response, if any (taken by the first successful recv).
    resp: Option<Response>,
    /// The worker-side [`ReplySender`] has been dropped (after `send` or on
    /// the error path).
    sender_dropped: bool,
    /// The client-side [`ReplyHandle`] has been dropped.
    receiver_dropped: bool,
}

#[derive(Debug, Default)]
struct SlotInner {
    state: Mutex<SlotState>,
    ready: Condvar,
}

#[derive(Debug, Default)]
struct PoolInner {
    free: Mutex<Vec<Arc<SlotInner>>>,
}

impl PoolInner {
    /// Return a retired slot to the free list (unless the pool is full).
    /// The slot's state has already been reset by the caller.
    fn recycle(&self, slot: Arc<SlotInner>) {
        let mut free = self.free.lock().unwrap();
        if free.len() < POOL_CAP {
            free.push(slot);
        }
    }
}

/// Called from both `Drop` impls: mark this side done and, if the other
/// side is already done, reset the slot and hand it back to the pool.
fn finish_side(slot: &Arc<SlotInner>, pool: &Arc<PoolInner>, is_sender: bool) {
    let both_done = {
        let mut st = slot.state.lock().unwrap();
        if is_sender {
            st.sender_dropped = true;
        } else {
            st.receiver_dropped = true;
        }
        if st.sender_dropped && st.receiver_dropped {
            // Reset under the same lock so the slot re-enters the pool
            // pristine; the other side's handle is already gone.
            *st = SlotState::default();
            true
        } else {
            false
        }
    };
    if both_done {
        pool.recycle(slot.clone());
    } else if is_sender {
        // Sender gone without (or after) a response: wake any blocked recv
        // so it can observe the disconnect.
        slot.ready.notify_all();
    }
}

/// A recycling pool of oneshot reply slots.
///
/// Cloning the pool is cheap (one `Arc`); all clones share the free list.
/// [`ReplyPool::acquire`] pops a slot (or allocates one the first few
/// times) and returns the two ends of the rendezvous.
#[derive(Clone, Debug, Default)]
pub struct ReplyPool {
    inner: Arc<PoolInner>,
}

impl ReplyPool {
    /// New empty pool.
    pub fn new() -> ReplyPool {
        ReplyPool::default()
    }

    /// Take a slot from the pool (allocating only when the free list is
    /// empty) and split it into the sender and receiver ends.
    pub fn acquire(&self) -> (ReplySender, ReplyHandle) {
        // Pop under the lock; allocate the fallback slot only after the
        // guard is released so a pool miss doesn't hold up other threads.
        let pooled = self.inner.free.lock().unwrap().pop();
        let slot = pooled.unwrap_or_default();
        (
            ReplySender { slot: slot.clone(), pool: self.inner.clone() },
            ReplyHandle { slot, pool: self.inner.clone() },
        )
    }

    /// Slots currently sitting in the free list (diagnostics/tests).
    pub fn pooled(&self) -> usize {
        self.inner.free.lock().unwrap().len()
    }
}

/// Worker-side end of a reply slot: delivers at most one [`Response`].
///
/// Dropping the sender without calling [`ReplySender::send`] closes the
/// slot — a blocked [`ReplyHandle::recv`] returns [`RecvError`], exactly
/// like a dropped `mpsc::Sender`.
#[derive(Debug)]
pub struct ReplySender {
    slot: Arc<SlotInner>,
    pool: Arc<PoolInner>,
}

impl ReplySender {
    /// Deliver the response and wake the receiver. Consumes the sender;
    /// the slot is recycled once the client side is also done.
    pub fn send(self, resp: Response) {
        {
            let mut st = self.slot.state.lock().unwrap();
            // Client may have given up already; the slot is recycled by
            // our Drop below either way.
            st.resp = Some(resp);
        }
        self.slot.ready.notify_one();
        // `self` drops here: sets `sender_dropped` and recycles if the
        // receiver is already gone.
    }
}

impl Drop for ReplySender {
    fn drop(&mut self) {
        finish_side(&self.slot, &self.pool, true);
    }
}

/// Client-side end of a reply slot, returned by
/// [`super::Service::submit`] / [`super::Service::try_submit`].
///
/// Mirrors the `mpsc::Receiver` surface the service used to return:
/// [`ReplyHandle::recv`] blocks, [`ReplyHandle::try_recv`] polls. The
/// response can be received exactly once; a second call reports
/// [`RecvError`].
#[derive(Debug)]
pub struct ReplyHandle {
    slot: Arc<SlotInner>,
    pool: Arc<PoolInner>,
}

impl ReplyHandle {
    /// Block until the worker delivers the response (or drops the sender).
    pub fn recv(&self) -> Result<Response, RecvError> {
        let mut st = self.slot.state.lock().unwrap();
        loop {
            if let Some(resp) = st.resp.take() {
                return Ok(resp);
            }
            if st.sender_dropped {
                return Err(RecvError);
            }
            st = self.slot.ready.wait(st).unwrap();
        }
    }

    /// Non-blocking poll: [`TryRecvError::Empty`] while the response is
    /// pending, [`TryRecvError::Disconnected`] once the sender dropped
    /// without delivering one (so poll loops can bail on dead requests).
    pub fn try_recv(&self) -> Result<Response, TryRecvError> {
        let mut st = self.slot.state.lock().unwrap();
        if let Some(resp) = st.resp.take() {
            Ok(resp)
        } else if st.sender_dropped {
            Err(TryRecvError::Disconnected)
        } else {
            Err(TryRecvError::Empty)
        }
    }
}

impl Drop for ReplyHandle {
    fn drop(&mut self) {
        finish_side(&self.slot, &self.pool, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Response;

    fn resp(id: u64) -> Response {
        let bits = crate::wideint::PackedBits::from_u128(id as u128 * 3);
        Response { id, bits, latency_ns: 1, batch_size: 1 }
    }

    #[test]
    fn roundtrip_and_recycle() {
        let pool = ReplyPool::new();
        for i in 0..100u64 {
            let (tx, rx) = pool.acquire();
            tx.send(resp(i));
            assert_eq!(rx.recv().unwrap().id, i);
            drop(rx);
            // Both ends done: the slot is back in the free list.
            assert_eq!(pool.pooled(), 1, "iteration {i}");
        }
    }

    #[test]
    fn sender_drop_closes() {
        let pool = ReplyPool::new();
        let (tx, rx) = pool.acquire();
        drop(tx);
        assert_eq!(rx.recv(), Err(RecvError));
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
        drop(rx);
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn receiver_drop_first_still_recycles() {
        let pool = ReplyPool::new();
        let (tx, rx) = pool.acquire();
        drop(rx);
        tx.send(resp(7)); // delivered into the void
        assert_eq!(pool.pooled(), 1);
        // The recycled slot comes back pristine: pending, not disconnected.
        let (tx2, rx2) = pool.acquire();
        assert_eq!(pool.pooled(), 0);
        assert_eq!(rx2.try_recv(), Err(TryRecvError::Empty));
        tx2.send(resp(8));
        assert_eq!(rx2.recv().unwrap().id, 8);
    }

    #[test]
    fn recv_blocks_until_send_across_threads() {
        let pool = ReplyPool::new();
        let (tx, rx) = pool.acquire();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            tx.send(resp(42));
        });
        assert_eq!(rx.recv().unwrap().id, 42);
        t.join().unwrap();
    }

    #[test]
    fn second_recv_errors() {
        let pool = ReplyPool::new();
        let (tx, rx) = pool.acquire();
        tx.send(resp(1));
        assert!(rx.recv().is_ok());
        assert_eq!(rx.recv(), Err(RecvError));
    }
}
