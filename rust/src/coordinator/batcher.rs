//! Bounded dynamic batcher: size + linger dispatch policy, blocking or
//! failing submit (backpressure), condvar-based (no busy wait).
//!
//! Rejections speak the unified serving vocabulary
//! ([`crate::serve::AdmissionError`]): a full queue is `Saturated`
//! (transient backpressure), a closed batcher is `Draining`.

use crate::serve::AdmissionError;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

struct Inner<T> {
    queue: VecDeque<T>,
    closed: bool,
}

/// A bounded MPMC batch queue.
///
/// Producers `submit` (blocking on backpressure) or `try_submit`
/// (fail-fast). Consumers call `next_batch(max, linger)`: it returns as
/// soon as `max` items are waiting, or `linger` after the *first* waiting
/// item arrived — the classic dynamic-batching policy (vLLM-style) that
/// trades a bounded latency hit for batch efficiency.
pub struct Batcher<T> {
    inner: Mutex<Inner<T>>,
    /// Signalled when items arrive or the batcher closes.
    items: Condvar,
    /// Signalled when space frees up.
    space: Condvar,
    depth: usize,
}

impl<T> Batcher<T> {
    /// New batcher with a bounded depth.
    pub fn new(depth: usize) -> Batcher<T> {
        assert!(depth > 0);
        Batcher {
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            items: Condvar::new(),
            space: Condvar::new(),
            depth,
        }
    }

    /// Current queue length.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking submit; `Saturated` when full, `Draining` when closed.
    pub fn try_submit(&self, item: T) -> Result<(), AdmissionError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(AdmissionError::Draining);
        }
        if g.queue.len() >= self.depth {
            return Err(AdmissionError::Saturated);
        }
        g.queue.push_back(item);
        drop(g);
        self.items.notify_one();
        Ok(())
    }

    /// Blocking submit: waits for space (backpressure) unless closed
    /// (`Draining`).
    pub fn submit(&self, item: T) -> Result<(), AdmissionError> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(AdmissionError::Draining);
            }
            if g.queue.len() < self.depth {
                g.queue.push_back(item);
                drop(g);
                self.items.notify_one();
                return Ok(());
            }
            g = self.space.wait(g).unwrap();
        }
    }

    /// Take the next batch: up to `max` items, dispatching early once the
    /// oldest waiting item has lingered `linger`. Returns `None` only after
    /// close with an empty queue.
    pub fn next_batch(&self, max: usize, linger: Duration) -> Option<Vec<T>> {
        debug_assert!(max > 0);
        let mut g = self.inner.lock().unwrap();
        // Phase 1: wait for at least one item (or close).
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.items.wait(g).unwrap();
        }
        // Phase 2: fill until `max` or the linger deadline.
        let deadline = Instant::now() + linger;
        while g.queue.len() < max && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, timeout) = self.items.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if timeout.timed_out() {
                break;
            }
        }
        let take = g.queue.len().min(max);
        let batch: Vec<T> = g.queue.drain(..take).collect();
        drop(g);
        self.space.notify_all();
        Some(batch)
    }

    /// Close: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.items.notify_all();
        self.space.notify_all();
    }
}
