//! Coordinator tests: batcher policy, service correctness against hardware,
//! backpressure, adaptive escalation, failure behaviour.

use super::*;
use crate::config::ServiceConfig;
use crate::decomp::{OpClass, SchemeKind};
use crate::proput::forall;
use crate::serve::AdmissionError;
use std::sync::Arc;
use std::time::Duration;

fn native_cfg() -> ServiceConfig {
    ServiceConfig { workers: 2, max_batch: 32, linger_us: 100, ..ServiceConfig::default() }
}

fn native_service(cfg: &ServiceConfig) -> Service {
    Service::start(cfg, BackendChoice::native(SchemeKind::Civp))
}

/// 1.0 in each registry format's packed bits (1.0 × 1.0 is exact
/// everywhere) — derived from the registry, no hand-mirrored table. The
/// wide word covers every class up to binary512.
fn one_bits(class: OpClass) -> crate::wideint::PackedBits {
    class.format().one_w()
}

// ---------------------------------------------------------------------
// Batcher
// ---------------------------------------------------------------------

#[test]
fn batcher_batches_up_to_max() {
    let b: Batcher<u32> = Batcher::new(100);
    for i in 0..10 {
        b.submit(i).unwrap();
    }
    let batch = b.next_batch(4, Duration::from_millis(1)).unwrap();
    assert_eq!(batch, vec![0, 1, 2, 3]);
    let batch = b.next_batch(100, Duration::from_millis(1)).unwrap();
    assert_eq!(batch.len(), 6);
}

#[test]
fn batcher_linger_dispatches_partial() {
    let b: Batcher<u32> = Batcher::new(100);
    b.submit(1).unwrap();
    let t0 = std::time::Instant::now();
    let batch = b.next_batch(1000, Duration::from_millis(5)).unwrap();
    assert_eq!(batch, vec![1]);
    assert!(t0.elapsed() >= Duration::from_millis(4));
}

#[test]
fn batcher_try_submit_backpressure() {
    let b: Batcher<u32> = Batcher::new(2);
    b.try_submit(1).unwrap();
    b.try_submit(2).unwrap();
    assert_eq!(b.try_submit(3), Err(AdmissionError::Saturated));
    let _ = b.next_batch(2, Duration::ZERO);
    b.try_submit(3).unwrap();
}

#[test]
fn batcher_close_semantics() {
    let b: Batcher<u32> = Batcher::new(4);
    b.submit(1).unwrap();
    b.close();
    assert_eq!(b.submit(2), Err(AdmissionError::Draining));
    // drains remaining, then None
    assert_eq!(b.next_batch(4, Duration::ZERO), Some(vec![1]));
    assert_eq!(b.next_batch(4, Duration::ZERO), None);
}

#[test]
fn batcher_concurrent_producers_consumers() {
    let b: Arc<Batcher<u64>> = Arc::new(Batcher::new(64));
    let n_items = 10_000u64;
    let producers: Vec<_> = (0..4)
        .map(|p| {
            let b = b.clone();
            std::thread::spawn(move || {
                for i in 0..n_items / 4 {
                    b.submit(p * 1_000_000 + i).unwrap();
                }
            })
        })
        .collect();
    let consumer = {
        let b = b.clone();
        std::thread::spawn(move || {
            let mut seen = 0u64;
            while let Some(batch) = b.next_batch(32, Duration::from_millis(1)) {
                seen += batch.len() as u64;
                if seen == n_items {
                    break;
                }
            }
            seen
        })
    };
    for p in producers {
        p.join().unwrap();
    }
    assert_eq!(consumer.join().unwrap(), n_items);
}

// ---------------------------------------------------------------------
// Service (native backend; PJRT covered in integration tests)
// ---------------------------------------------------------------------

#[test]
fn service_multiplies_correctly_all_precisions() {
    let svc = native_service(&native_cfg());
    forall(0x500, 200, |rng| {
        let a = f64::from_bits(rng.nasty_bits64());
        let b = f64::from_bits(rng.nasty_bits64());
        if !a.is_finite() || !b.is_finite() {
            return;
        }
        let out = svc.mul_blocking(
            OpClass::Double,
            crate::fpu::Fp64::from_f64(a).0 as u128,
            crate::fpu::Fp64::from_f64(b).0 as u128,
        );
        let hw = a * b;
        if !hw.is_nan() {
            assert_eq!(out.as_u64(), hw.to_bits());
        }
        let af = a as f32;
        let bf = b as f32;
        let out = svc.mul_blocking(
            OpClass::Single,
            af.to_bits() as u128,
            bf.to_bits() as u128,
        );
        let hw = af * bf;
        if !hw.is_nan() {
            assert_eq!(out.as_u64() as u32, hw.to_bits());
        }
    });
    let report = svc.shutdown();
    assert_eq!(report.requests, report.responses);
    assert_eq!(report.rejected, 0);
}

#[test]
fn service_batches_concurrent_submissions() {
    let cfg = ServiceConfig { workers: 1, max_batch: 64, linger_us: 2000, ..Default::default() };
    let svc = Arc::new(native_service(&cfg));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let mut rxs = Vec::new();
                for i in 0..100u64 {
                    let x = 1.0 + (t as f64) + i as f64;
                    let bits = crate::fpu::Fp64::from_f64(x).0 as u128;
                    rxs.push((x, svc.submit(i, OpClass::Double, bits, bits).unwrap()));
                }
                for (x, rx) in rxs {
                    let resp = rx.recv().unwrap();
                    assert_eq!(resp.bits.as_u64(), (x * x).to_bits());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let metrics = svc.metrics();
    // batching actually happened: fewer batches than requests
    assert!(metrics.counters["batches_total"] < metrics.counters["requests_total"]);
}

#[test]
fn service_fabric_report_tracks_mix() {
    let svc = native_service(&native_cfg());
    for _ in 0..10 {
        svc.mul_blocking(OpClass::Double, 1u128 << 62, 1u128 << 62);
    }
    for _ in 0..5 {
        svc.mul_blocking(OpClass::Single, 0x3F80_0000, 0x3F80_0000);
    }
    let report = svc.fabric_report();
    assert_eq!(report.total_ops, 15);
    assert_eq!(report.per_class.len(), 2);
    assert!(report.dyn_energy > 0.0);
}

#[test]
fn service_serves_sub_single_classes_end_to_end() {
    // binary16 and bfloat16 ride the same submit → batcher → lane-fused
    // backend path; results check against the typed scalar pipeline (and,
    // for half, the f32 hardware oracle inside `Fp16` tests).
    use crate::fpu::{Bf16, Fp16};
    let svc = native_service(&native_cfg());
    let mut rng = crate::proput::Rng::new(0x5AB);
    for i in 0..300u64 {
        let (a, b) = (rng.next_u64() as u16, rng.next_u64() as u16);
        let got = svc.mul_blocking(OpClass::Half, a as u128, b as u128).as_u64() as u16;
        let want = Fp16(a).mul(Fp16(b));
        if want.is_nan() {
            assert!(Fp16(got).is_nan(), "i={i}");
        } else {
            assert_eq!(got, want.0, "half i={i} a={a:#06x} b={b:#06x}");
        }
        let got = svc.mul_blocking(OpClass::Bf16, a as u128, b as u128).as_u64() as u16;
        let want = Bf16(a).mul(Bf16(b));
        if want.is_nan() {
            assert!(Bf16(got).is_nan(), "i={i}");
        } else {
            assert_eq!(got, want.0, "bf16 i={i} a={a:#06x} b={b:#06x}");
        }
    }
    let fabric = svc.fabric_report();
    let labels: Vec<&str> = fabric.per_class.iter().map(|c| c.label.as_str()).collect();
    assert!(labels.contains(&"civp-half"), "per-class accounting rows: {labels:?}");
    assert!(labels.contains(&"civp-bf16"), "per-class accounting rows: {labels:?}");
    svc.shutdown();
}

#[test]
fn service_try_submit_backpressure() {
    // Tiny queue, zero workers draining fast: force QueueFull.
    let cfg = ServiceConfig {
        workers: 1,
        max_batch: 4,
        queue_depth: 4,
        linger_us: 50_000,
        ..Default::default()
    };
    let svc = native_service(&cfg);
    // Stuff the double queue faster than the single worker drains.
    let mut rejected = 0;
    for i in 0..5_000u64 {
        match svc.try_submit(i, OpClass::Double, 1u128 << 62, 1u128 << 62) {
            Ok(_rx) => {}
            Err(AdmissionError::Saturated) => rejected += 1,
            Err(e) => panic!("unexpected {e:?}"),
        }
    }
    assert!(rejected > 0, "expected backpressure rejections");
    let report = svc.shutdown();
    assert_eq!(report.rejected, rejected);
}

#[test]
fn service_shutdown_drains_inflight() {
    let svc = native_service(&native_cfg());
    let mut rxs = Vec::new();
    for i in 0..500u64 {
        let bits = crate::fpu::Fp64::from_f64(i as f64).0 as u128;
        rxs.push(svc.submit(i, OpClass::Double, bits, bits).unwrap());
    }
    let report = svc.shutdown();
    // every accepted request got an answer before shutdown returned
    assert_eq!(report.responses, 500);
    for rx in rxs {
        assert!(rx.try_recv().is_ok());
    }
}

#[test]
fn service_try_submit_counts_per_class_exactly_once() {
    // Accounting contract: accepted requests bump `requests_total` AND the
    // per-class counter exactly once, for every registry class; nothing is
    // rejected when the queues have room.
    let cfg = ServiceConfig { workers: 2, max_batch: 64, linger_us: 100, ..Default::default() };
    let svc = native_service(&cfg);
    let mut per_class = [0u64; OpClass::COUNT];
    let mut rxs = Vec::new();
    for i in 0..1000u64 {
        let class = OpClass::from_index((i % OpClass::COUNT as u64) as usize);
        per_class[class.index()] += 1;
        // 1.0 in each format's packed bits: 1.0 * 1.0 is exact everywhere.
        let one = one_bits(class);
        rxs.push(svc.try_submit(i, class, one, one).expect("queue has room"));
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    let snap = svc.metrics();
    for class in OpClass::ALL {
        assert_eq!(
            snap.counters[&format!("requests_{}", class.name())],
            per_class[class.index()],
            "{}",
            class.name()
        );
    }
    assert_eq!(snap.counters["requests_total"], per_class.iter().sum::<u64>());
    let report = svc.shutdown();
    assert_eq!(report.rejected, 0);
    assert_eq!(report.responses, 1000);
}

#[test]
fn service_fabric_report_is_count_based_and_matches_stream_oracle() {
    // Acceptance gate: after >= 100k executed ops the report must still be
    // computed from per-class counts (no per-op replay buffer) and agree
    // bit-for-bit with the materialized-stream oracle.
    use crate::fabric::{simulate_stream, CostModel, FabricConfig, FabricOp};
    let cfg = ServiceConfig { workers: 2, max_batch: 512, linger_us: 100, ..Default::default() };
    let svc = native_service(&cfg);
    // 10k bf16 + 10k half + 55k single + 20k double + 5k quad = 100k ops —
    // the full registry, sub-single classes included. Exact values (1.0)
    // keep the debug-mode oracle cross-check cheap.
    let plan: [(OpClass, u64); 5] = [
        (OpClass::Bf16, 10_000),
        (OpClass::Half, 10_000),
        (OpClass::Single, 55_000),
        (OpClass::Double, 20_000),
        (OpClass::Quad, 5_000),
    ];
    let mut expected_ops: Vec<FabricOp> = Vec::new();
    let mut pending = Vec::with_capacity(1024);
    for &(class, n) in &plan {
        let one = one_bits(class);
        let op = FabricOp { class, organization: SchemeKind::Civp };
        for i in 0..n {
            expected_ops.push(op);
            pending.push(svc.submit(i, class, one, one).unwrap());
            if pending.len() == 1024 {
                for rx in pending.drain(..) {
                    rx.recv().unwrap();
                }
            }
        }
        for rx in pending.drain(..) {
            rx.recv().unwrap();
        }
    }
    // Every response observed => every op is visible in the counters.
    let counts = svc.op_counts();
    assert_eq!(counts.values().sum::<u64>(), 100_000);
    assert_eq!(counts.len(), 5, "one entry per executed class: {counts:?}");
    let report = svc.fabric_report();
    let oracle =
        simulate_stream(&expected_ops, &FabricConfig::civp_scaled(1), &CostModel::default());
    assert_eq!(report, oracle, "count-based report diverged from stream oracle");
    assert_eq!(report.total_ops, 100_000);
}

#[test]
fn service_concurrent_drain_under_load_loses_nothing() {
    // Satellite of the parallel-executor PR: `drain` takes `&self`, so any
    // number of threads may drain a shared service while submitters are
    // still pushing. Contract under that race:
    //   * every *accepted* submit (Ok handle) gets exactly one reply;
    //   * late submits fail with `Closed`, never hang or half-enqueue;
    //   * after any drain returns the pool is quiescent, so
    //     requests_total == responses_total == sum of per-class op counts.
    // The backend runs on a shared 2-core lane executor with a tiny fan-out
    // threshold, so drains also race the work-stealing chunk path.
    use crate::decomp::Executor;
    let cfg = ServiceConfig { workers: 2, max_batch: 64, linger_us: 200, ..Default::default() };
    let exec = Arc::new(Executor::with_threshold(2, 16));
    let svc = Arc::new(Service::start(
        &cfg,
        BackendChoice::Native(NativeOptions::new(SchemeKind::Civp).executor(exec)),
    ));
    let submitters: Vec<_> = (0..6)
        .map(|t| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let mut accepted = 0u64;
                let mut rxs = Vec::new();
                for i in 0..3_000u64 {
                    let class = OpClass::from_index(((t + i) % OpClass::COUNT as u64) as usize);
                    let one = one_bits(class);
                    match svc.submit(i, class, one, one) {
                        Ok(rx) => {
                            accepted += 1;
                            rxs.push((one, rx));
                        }
                        Err(AdmissionError::Draining) => break,
                        Err(e) => panic!("unexpected {e:?}"),
                    }
                }
                for (one, rx) in rxs {
                    // exactly one reply per accepted request, even for the
                    // tail accepted just before the queues closed
                    let resp = rx.recv().expect("accepted request lost its reply");
                    assert_eq!(resp.bits, one, "1.0 * 1.0 must be exact");
                }
                accepted
            })
        })
        .collect();
    let drainers: Vec<_> = (0..2)
        .map(|_| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(5));
                svc.drain();
                // drain returned => the pool is stopped for *this* caller
                // too (not just the race winner): submits must refuse.
                assert_eq!(
                    svc.submit(0, OpClass::Double, 1u128 << 62, 1u128 << 62).err(),
                    Some(AdmissionError::Draining)
                );
            })
        })
        .collect();
    let accepted: u64 = submitters.into_iter().map(|h| h.join().unwrap()).sum();
    for d in drainers {
        d.join().unwrap();
    }
    assert!(accepted > 0, "drain raced ahead of every submit");
    svc.drain(); // idempotent
    let snap = svc.metrics();
    assert_eq!(snap.counters["requests_total"], accepted);
    assert_eq!(snap.counters["responses_total"], accepted);
    assert_eq!(snap.counters["rejected_queue_full"], 0);
    assert_eq!(svc.op_counts().values().sum::<u64>(), accepted);
}

#[test]
fn service_reply_slots_are_recycled() {
    // Steady-state allocation check by proxy: sequential blocking requests
    // reuse one pooled slot instead of allocating per request.
    let svc = native_service(&native_cfg());
    for _ in 0..50 {
        svc.mul_blocking(OpClass::Double, 0x3FF0_0000_0000_0000u128, 0x3FF0_0000_0000_0000u128);
    }
    // The pool is service-internal; observable contract: requests completed
    // and nothing leaked enough to matter. Covered directly by the oneshot
    // module's `roundtrip_and_recycle` unit test.
    let report = svc.shutdown();
    assert_eq!(report.requests, 50);
    assert_eq!(report.responses, 50);
}

// ---------------------------------------------------------------------
// Adaptive precision
// ---------------------------------------------------------------------

#[test]
fn adaptive_clear_cases_settle_single() {
    let svc = native_service(&native_cfg());
    let mut stats = AdaptiveStats::default();
    let o = orient2d_adaptive(&svc, (0.0, 0.0), (1.0, 0.0), (0.5, 1.0), &mut stats);
    assert_eq!(o, Orient::Ccw);
    let o = orient2d_adaptive(&svc, (0.0, 0.0), (1.0, 0.0), (0.5, -1.0), &mut stats);
    assert_eq!(o, Orient::Cw);
    assert_eq!(stats.settled_single, 2);
}

#[test]
fn adaptive_degenerate_cases_escalate_and_are_exact() {
    let svc = native_service(&native_cfg());
    let mut stats = AdaptiveStats::default();
    // exactly collinear points with coordinates unrepresentable in f32
    let a = (0.1, 0.1);
    let b = (0.2, 0.2);
    let c = (0.30000000000000004, 0.30000000000000004);
    let o = orient2d_adaptive(&svc, a, b, c, &mut stats);
    assert_eq!(o, Orient::Collinear);
    assert!(stats.settled_quad >= 1, "degenerate case must escalate: {stats:?}");
    // near-degenerate: a point displaced by one ulp must get a definite sign
    let c2 = (0.30000000000000004, 0.3000000000000001);
    let o2 = orient2d_adaptive(&svc, a, b, c2, &mut stats);
    assert_ne!(o2, Orient::Collinear);
}

#[test]
fn adaptive_sign_agrees_with_exact_rational() {
    // Exact oracle via i128 rational arithmetic on scaled integer coords.
    let svc = native_service(&native_cfg());
    let mut stats = AdaptiveStats::default();
    forall(0x501, 300, |rng| {
        let coord = |rng: &mut crate::proput::Rng| (rng.below(2000) as i64 - 1000) as f64 / 16.0;
        let (ax, ay) = (coord(rng), coord(rng));
        let (bx, by) = (coord(rng), coord(rng));
        let (cx, cy) = (coord(rng), coord(rng));
        let o = orient2d_adaptive(&svc, (ax, ay), (bx, by), (cx, cy), &mut stats);
        // scaled by 16: exact in i128
        let det = ((ax * 16.0) as i128 - (cx * 16.0) as i128)
            * ((by * 16.0) as i128 - (cy * 16.0) as i128)
            - ((ay * 16.0) as i128 - (cy * 16.0) as i128)
                * ((bx * 16.0) as i128 - (cx * 16.0) as i128);
        let want = match det.cmp(&0) {
            core::cmp::Ordering::Greater => Orient::Ccw,
            core::cmp::Ordering::Less => Orient::Cw,
            core::cmp::Ordering::Equal => Orient::Collinear,
        };
        assert_eq!(o, want, "a=({ax},{ay}) b=({bx},{by}) c=({cx},{cy})");
    });
    assert!(stats.total() >= 300);
}
