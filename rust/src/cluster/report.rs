//! Aggregated cluster reports: per-shard fabric/serving summaries rolled
//! up into the numbers the CLI and `bench_cluster` print.

use crate::fabric::StreamReport;

/// One shard's slice of the cluster report.
#[derive(Clone, Debug)]
pub struct ShardSummary {
    /// Shard index.
    pub id: usize,
    /// Fraction of original block capacity still live.
    pub health: f64,
    /// Routing weight at report time (0 = drained).
    pub weight: u64,
    /// Whether a quad multiplication still issues in one wave.
    pub quad_one_wave: bool,
    /// Requests in flight at report time.
    pub inflight: u64,
    /// Requests this shard accepted.
    pub accepted: u64,
    /// Closed-form fabric report over every op the shard executed
    /// (per-shard `simulate_counts` summary).
    pub fabric: StreamReport,
}

/// Cluster-level aggregate built from the per-shard summaries.
///
/// Shards run in parallel, so the cluster's wall-clock cycle count is the
/// *maximum* over shards while ops and energies are sums — which is what
/// makes aggregate throughput scale with the shard count until a single
/// shard saturates.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Per-shard breakdown.
    pub shards: Vec<ShardSummary>,
    /// Total ops executed across all shards.
    pub total_ops: u64,
    /// Parallel makespan: the slowest shard's cycle count.
    pub wall_cycles: u64,
    /// Total dynamic energy across shards.
    pub dyn_energy: f64,
    /// Useful portion of the dynamic energy.
    pub useful_energy: f64,
    /// Total leakage across shards.
    pub static_energy: f64,
    /// Requests accepted cluster-wide.
    pub accepted: u64,
    /// Requests that spilled from a full shard to another before
    /// acceptance (spill-over admissions, not failures).
    pub spilled: u64,
    /// Requests rejected because every live shard was saturated.
    pub rejected_saturated: u64,
}

impl ClusterReport {
    /// Build the aggregate from per-shard summaries plus the cluster
    /// admission counters.
    pub fn aggregate(shards: Vec<ShardSummary>, spilled: u64, rejected_saturated: u64) -> Self {
        let total_ops = shards.iter().map(|s| s.fabric.total_ops).sum();
        let wall_cycles = shards.iter().map(|s| s.fabric.cycles).max().unwrap_or(0);
        let dyn_energy = shards.iter().map(|s| s.fabric.dyn_energy).sum();
        let useful_energy = shards.iter().map(|s| s.fabric.useful_energy).sum();
        let static_energy = shards.iter().map(|s| s.fabric.static_energy).sum();
        let accepted = shards.iter().map(|s| s.accepted).sum();
        ClusterReport {
            shards,
            total_ops,
            wall_cycles,
            dyn_energy,
            useful_energy,
            static_energy,
            accepted,
            spilled,
            rejected_saturated,
        }
    }

    /// Aggregate ops per cycle (ops divided by the parallel makespan).
    pub fn throughput(&self) -> f64 {
        if self.wall_cycles == 0 {
            return 0.0;
        }
        self.total_ops as f64 / self.wall_cycles as f64
    }

    /// Total energy (dynamic + static) per op.
    pub fn energy_per_op(&self) -> f64 {
        if self.total_ops == 0 {
            return 0.0;
        }
        (self.dyn_energy + self.static_energy) / self.total_ops as f64
    }

    /// Fraction of dynamic energy wasted on padded ports.
    pub fn wasted_fraction(&self) -> f64 {
        if self.dyn_energy == 0.0 {
            return 0.0;
        }
        1.0 - self.useful_energy / self.dyn_energy
    }

    /// Render the per-shard table plus the aggregate line (for the CLI).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<6} {:>8} {:>7} {:>6} {:>9} {:>10} {:>10} {:>9}\n",
            "shard", "ops", "health", "weight", "quad-1w", "cycles", "E/op", "inflight"
        ));
        for s in &self.shards {
            out.push_str(&format!(
                "{:<6} {:>8} {:>6.1}% {:>6} {:>9} {:>10} {:>10.3} {:>9}\n",
                s.id,
                s.fabric.total_ops,
                s.health * 100.0,
                s.weight,
                if s.quad_one_wave { "yes" } else { "no" },
                s.fabric.cycles,
                s.fabric.energy_per_op(),
                s.inflight,
            ));
        }
        out.push_str(&format!(
            "total  {:>8} ops  {:>10} wall cycles  {:.3} ops/cycle  {:.3} E/op  \
             {:.1}% wasted\n",
            self.total_ops,
            self.wall_cycles,
            self.throughput(),
            self.energy_per_op(),
            self.wasted_fraction() * 100.0,
        ));
        out.push_str(&format!(
            "admission: {} accepted, {} spilled, {} rejected saturated\n",
            self.accepted, self.spilled, self.rejected_saturated
        ));
        out
    }
}
