//! One fabric shard: a complete serving [`Service`] (its own
//! per-precision batchers, worker pool and lock-free op counters) bound to
//! one simulated fabric column set, plus the lock-free routing state the
//! cluster's [`super::Router`] reads on every submit.
//!
//! Execution inside a shard is the coordinator's lane path end-to-end:
//! every worker drains batches into the native backend's lane-fused
//! pipeline (`FpuBatch` → `Plan::execute_lanes`), so a multi-shard
//! cluster runs N independent tile-major SoA engines in parallel.

use crate::config::ServiceConfig;
use crate::coordinator::{BackendChoice, Service, ServiceReport};
use crate::decomp::{BlockKind, OpClass, Scheme, SchemeKind};
use crate::fabric::{
    schedule_op, simulate_counts, CostModel, FabricConfig, FabricKind, FaultOutcome,
    RepairableFabric, StreamReport,
};
use crate::proput::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;

/// Routing credits a fully healthy shard carries; degradation scales a
/// shard's weight down proportionally to the block capacity it has lost.
pub const FULL_WEIGHT: u64 = 16;

/// One servability bit per registry class (the mask fits a `u8` as long as
/// the registry stays ≤ 8 classes — asserted in the fpu registry tests).
#[inline]
fn class_bit(c: OpClass) -> u8 {
    1 << c.index()
}

/// All-classes-servable mask for a healthy shard.
const ALL_SERVABLE: u8 = (1 << OpClass::COUNT) - 1;

/// Routing-visible state of one shard. Every field the router reads is an
/// atomic, so shard selection takes no lock; degradation events (rare,
/// control-plane) rewrite the weight and affinity bits in place.
#[derive(Debug)]
pub struct ShardState {
    /// Admission bound: maximum requests in flight on this shard.
    pub max_inflight: u64,
    /// Requests currently in flight — reserved at submit, released when
    /// the client consumes or drops its [`super::ClusterReply`].
    inflight: AtomicU64,
    /// Routing weight in credits ([`FULL_WEIGHT`] = healthy, `0` =
    /// drained — the router never selects a zero-weight shard).
    weight: AtomicU64,
    /// Per-class servability bits (one per [`OpClass`] registry entry, all
    /// set on a healthy shard): degradation that kills every block of a
    /// kind steers only the classes that *need* that kind away, so a shard
    /// that lost its 9x9 pool keeps serving single-precision (pure 24x24)
    /// and binary16 (pure 24x9) traffic while bf16/double/quad route
    /// around it.
    servable: AtomicU8,
    /// True while the shard's (possibly degraded) block pools still issue
    /// one quadruple-precision multiplication per wave — the
    /// precision-affinity routing bit.
    quad_one_wave: AtomicBool,
}

impl ShardState {
    /// Healthy state with the given admission bound.
    pub fn new(max_inflight: u64) -> ShardState {
        assert!(max_inflight > 0, "shard in-flight bound must be >= 1");
        ShardState {
            max_inflight,
            inflight: AtomicU64::new(0),
            weight: AtomicU64::new(FULL_WEIGHT),
            servable: AtomicU8::new(ALL_SERVABLE),
            quad_one_wave: AtomicBool::new(true),
        }
    }

    /// Reserve one in-flight slot; `false` when the shard is at its bound.
    /// The reservation is a single CAS loop — the bound can never be
    /// exceeded, regardless of how many threads race the admission.
    pub fn try_acquire(&self) -> bool {
        self.inflight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                if v < self.max_inflight {
                    Some(v + 1)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// Release one reserved slot.
    pub fn release(&self) {
        let prev = self.inflight.fetch_sub(1, Ordering::Relaxed);
        debug_assert!(prev > 0, "shard in-flight underflow");
    }

    /// Requests currently in flight.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Current routing weight (0 = drained).
    pub fn weight(&self) -> u64 {
        self.weight.load(Ordering::Relaxed)
    }

    /// Whether a quad multiplication issues in one wave on this shard.
    pub fn quad_one_wave(&self) -> bool {
        self.quad_one_wave.load(Ordering::Relaxed)
    }

    /// Whether this shard's block pools can still schedule `class`.
    pub fn servable(&self, class: OpClass) -> bool {
        self.servable.load(Ordering::Relaxed) & class_bit(class) != 0
    }

    /// Set the routing weight (degradation control plane).
    pub fn set_weight(&self, w: u64) {
        self.weight.store(w, Ordering::Relaxed);
    }

    /// Set one class's servability bit.
    pub fn set_servable(&self, class: OpClass, v: bool) {
        if v {
            self.servable.fetch_or(class_bit(class), Ordering::Relaxed);
        } else {
            self.servable.fetch_and(!class_bit(class), Ordering::Relaxed);
        }
    }

    /// Set the quad-affinity bit.
    pub fn set_quad_one_wave(&self, v: bool) {
        self.quad_one_wave.store(v, Ordering::Relaxed);
    }
}

/// Fault-injection summary returned by [`Shard::inject_faults`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DegradeOutcome {
    /// Faults absorbed by spare sub-units (no capacity loss).
    pub repaired: u64,
    /// Block instances permanently retired (spares exhausted).
    pub lost: u64,
}

/// One serving shard: a [`Service`] plus its repairable fabric and the
/// routing state derived from that fabric's current condition.
pub struct Shard {
    /// Shard index within the cluster.
    pub id: usize,
    service: Service,
    state: Arc<ShardState>,
    fabric: RepairableFabric,
    cost: CostModel,
    scheme: SchemeKind,
}

impl Shard {
    /// Start a shard: its own worker pool, batchers and op counters (one
    /// [`Service`]), wrapped with a repairable fabric of
    /// `spares_per_block` spare sub-units per block instance.
    pub fn start(
        id: usize,
        cfg: &ServiceConfig,
        backend: BackendChoice,
        max_inflight: u64,
        spares_per_block: u32,
    ) -> Shard {
        let base = match cfg.fabric {
            FabricKind::Civp => FabricConfig::civp_scaled(cfg.fabric_scale),
            FabricKind::Legacy => FabricConfig::legacy_scaled(cfg.fabric_scale),
        };
        let mut shard = Shard {
            id,
            service: Service::start(cfg, backend),
            state: Arc::new(ShardState::new(max_inflight)),
            fabric: RepairableFabric::new(base, spares_per_block),
            cost: CostModel::default(),
            scheme: cfg.scheme,
        };
        shard.refresh_routing();
        shard
    }

    /// The underlying service (submit paths, op counters, metrics).
    pub fn service(&self) -> &Service {
        &self.service
    }

    /// Shared routing state (read by the router, released by replies).
    pub fn state(&self) -> &Arc<ShardState> {
        &self.state
    }

    /// Fraction of original block capacity still live.
    pub fn health(&self) -> f64 {
        self.fabric.health()
    }

    /// The shard's fabric as currently degraded.
    pub fn effective_fabric(&self) -> FabricConfig {
        if self.is_degraded() {
            self.fabric.effective_config()
        } else {
            self.fabric.base.clone()
        }
    }

    fn is_degraded(&self) -> bool {
        self.fabric.degradation().values().any(|(_, dead)| *dead > 0)
    }

    /// Inject `n` sub-unit faults into random live instances of `kind`,
    /// then recompute the routing weight and affinity bits. A shard whose
    /// repaired fabric has lost blocks gets proportionally less traffic;
    /// one that can no longer serve its scheme at all is drained
    /// (weight 0).
    pub fn inject_faults(&mut self, kind: BlockKind, n: usize, rng: &mut Rng) -> DegradeOutcome {
        let mut out = DegradeOutcome::default();
        for _ in 0..n {
            match self.fabric.inject_fault(kind, rng) {
                FaultOutcome::Repaired => out.repaired += 1,
                FaultOutcome::BlockLost => out.lost += 1,
                FaultOutcome::NoTarget => break,
            }
        }
        self.refresh_routing();
        out
    }

    /// Recompute `weight` / per-class servability / `quad_one_wave` from
    /// the fabric's condition. A class whose block kinds are all gone is
    /// steered away individually (its servable bit clears — e.g. a dead
    /// 9x9 pool under CIVP clears bf16/double/quad but keeps single and
    /// binary16 servable); the whole shard drains to weight 0 only when
    /// *no* registry class remains servable.
    pub fn refresh_routing(&mut self) {
        let effective = self.fabric.effective_config();
        let mut any = false;
        let mut quad_servable = false;
        for class in OpClass::ALL {
            let scheme = Scheme::new(self.scheme, class);
            let ok = effective.can_serve(scheme.tiles().iter().map(|t| t.kind));
            self.state.set_servable(class, ok);
            any |= ok;
            if class == OpClass::Quad {
                quad_servable = ok;
            }
        }
        if !any {
            self.state.set_weight(0);
            self.state.set_quad_one_wave(false);
            return;
        }
        let weight = ((self.fabric.health() * FULL_WEIGHT as f64).round() as u64).max(1);
        self.state.set_weight(weight);
        let one_wave = quad_servable && {
            let quad = Scheme::new(self.scheme, OpClass::Quad);
            schedule_op(&quad, &effective, &self.cost).initiation_interval == 1
        };
        self.state.set_quad_one_wave(one_wave);
    }

    /// Fabric-level report for everything this shard executed, replayed in
    /// closed form on its *current* (degraded) fabric. If degradation has
    /// removed a block kind some already-executed class needs, the report
    /// falls back to the pristine fabric — those ops ran before the blocks
    /// died, and a fabric that cannot serve them cannot be scheduled.
    pub fn fabric_report(&self) -> StreamReport {
        let counts = self.service.op_counts();
        let effective = self.effective_fabric();
        let all_servable = counts
            .keys()
            .all(|c| effective.can_serve(c.scheme().tiles().iter().map(|t| t.kind)));
        let fabric = if all_servable { &effective } else { &self.fabric.base };
        simulate_counts(&counts, fabric, &self.cost)
    }

    /// Close the shard's queues and join its workers; the op counters are
    /// final afterwards, so a subsequent [`Shard::fabric_report`] covers
    /// every op the shard ever executed. Idempotent, and `&self` so a
    /// shared cluster ([`super::Cluster::drain`]) can quiesce its shards
    /// while other threads still hold references.
    pub fn drain(&self) {
        self.service.drain();
    }

    /// Final serving-layer report (meaningful after [`Shard::drain`]).
    pub fn service_report(&self) -> ServiceReport {
        self.service.report()
    }
}
