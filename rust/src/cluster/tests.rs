//! Cluster tests: router policy properties (pure, no threads), admission
//! bounds under flood, degradation-aware routing, and end-to-end
//! accounting invariants across shards.

use super::*;
use crate::config::ServiceConfig;
use crate::coordinator::BackendChoice;
use crate::decomp::{BlockKind, OpClass, SchemeKind};
use crate::proput::{forall, Rng};
use crate::serve::AdmissionError;
use std::sync::Arc;

fn one_bits(class: OpClass) -> u128 {
    // 1.0 in the class's packed bits, derived from the registry format.
    class.format().one()
}

fn small_cfg() -> ClusterConfig {
    ClusterConfig {
        shards: 2,
        service: ServiceConfig { workers: 1, max_batch: 32, linger_us: 100, ..Default::default() },
        policy: RouterPolicy::LeastLoaded,
        max_inflight: 1024,
        spares_per_block: 0,
    }
}

fn native(cfg: &ClusterConfig) -> Cluster {
    Cluster::start(cfg, BackendChoice::native(SchemeKind::Civp))
}

// ---------------------------------------------------------------------
// Router (pure state, no services)
// ---------------------------------------------------------------------

fn states(n: usize, bound: u64) -> Vec<Arc<ShardState>> {
    (0..n).map(|_| Arc::new(ShardState::new(bound))).collect()
}

#[test]
fn policy_parse_roundtrip() {
    for p in RouterPolicy::ALL {
        assert_eq!(RouterPolicy::parse(p.name()), Some(p));
    }
    assert_eq!(RouterPolicy::parse("nope"), None);
}

#[test]
fn round_robin_distributes_exactly_by_weight() {
    let s = states(2, 100);
    s[0].set_weight(8);
    s[1].set_weight(16);
    let router = Router::new(RouterPolicy::RoundRobin);
    let mut hits = [0u64; 2];
    for _ in 0..2400 {
        hits[router.pick(OpClass::Double, &s, 0).unwrap()] += 1;
    }
    // ticket space cycles through 24 credits: 8 then 16, exactly.
    assert_eq!(hits, [800, 1600]);
}

#[test]
fn least_loaded_balances_alternately() {
    let s = states(2, 100);
    let router = Router::new(RouterPolicy::LeastLoaded);
    let mut hits = [0u64; 2];
    for _ in 0..10 {
        let idx = router.pick(OpClass::Single, &s, 0).unwrap();
        assert!(s[idx].try_acquire());
        hits[idx] += 1;
    }
    assert_eq!(hits, [5, 5]);
}

#[test]
fn least_loaded_weighs_load_per_credit() {
    let s = states(2, 100);
    s[0].set_weight(16);
    s[1].set_weight(8);
    // 3/16 per credit on shard 0 vs 2/8 on shard 1: shard 0 is less loaded.
    for _ in 0..3 {
        assert!(s[0].try_acquire());
    }
    for _ in 0..2 {
        assert!(s[1].try_acquire());
    }
    let router = Router::new(RouterPolicy::LeastLoaded);
    assert_eq!(router.pick(OpClass::Double, &s, 0), Some(0));
}

#[test]
fn affinity_pins_quads_and_reserves_quad_columns() {
    let s = states(2, 100);
    s[0].set_quad_one_wave(true);
    s[1].set_quad_one_wave(false);
    let router = Router::new(RouterPolicy::PrecisionAffinity);
    // Quads go to the one-wave shard; single/double keep it free.
    assert_eq!(router.pick(OpClass::Quad, &s, 0), Some(0));
    assert_eq!(router.pick(OpClass::Single, &s, 0), Some(1));
    assert_eq!(router.pick(OpClass::Double, &s, 0), Some(1));
    // Spill-over: once the affine shard has been tried, fall back to the
    // other (capacity beats placement).
    assert_eq!(router.pick(OpClass::Quad, &s, 1 << 0), Some(1));
    assert_eq!(router.pick(OpClass::Single, &s, 1 << 1), Some(0));
}

#[test]
fn router_skips_drained_shards_every_policy() {
    for policy in RouterPolicy::ALL {
        let s = states(3, 100);
        s[1].set_weight(0);
        let router = Router::new(policy);
        for _ in 0..50 {
            let idx = router.pick(OpClass::Double, &s, 0).unwrap();
            assert_ne!(idx, 1, "{policy:?} picked a drained shard");
        }
        // All drained: nothing to pick.
        s[0].set_weight(0);
        s[2].set_weight(0);
        assert_eq!(router.pick(OpClass::Double, &s, 0), None, "{policy:?}");
    }
}

/// The satellite property: for every policy, simulated admission through
/// the router (a) never exceeds any shard's in-flight bound, (b) accounts
/// every submission as exactly one accept or one reject, and (c) never
/// routes to a drained or already-tried shard.
#[test]
fn admission_respects_bounds_and_accounts_exactly() {
    for (pi, policy) in RouterPolicy::ALL.into_iter().enumerate() {
        forall(0x600 + pi as u64, 40, |rng| {
            let n = rng.range(1, 6) as usize;
            let s: Vec<Arc<ShardState>> =
                (0..n).map(|_| Arc::new(ShardState::new(rng.range(1, 8)))).collect();
            for st in &s {
                st.set_weight(rng.below(3) * 8); // 0, 8 or 16 credits
                st.set_quad_one_wave(rng.chance(0.7));
                for class in OpClass::ALL {
                    st.set_servable(class, rng.chance(0.8));
                }
            }
            let router = Router::new(policy);
            let mut held: Vec<usize> = Vec::new();
            let (mut accepted, mut rejected) = (0u64, 0u64);
            let submitted = 200u64;
            for _ in 0..submitted {
                let class = OpClass::from_index(rng.below(OpClass::COUNT as u64) as usize);
                let mut tried = 0u64;
                let mut placed = None;
                while let Some(idx) = router.pick(class, &s, tried) {
                    assert_eq!(tried & (1 << idx), 0, "router repeated a tried shard");
                    assert!(s[idx].weight() > 0, "router picked a drained shard");
                    assert!(s[idx].servable(class), "router picked an unservable shard");
                    tried |= 1 << idx;
                    if s[idx].try_acquire() {
                        placed = Some(idx);
                        break;
                    }
                }
                match placed {
                    Some(idx) => {
                        accepted += 1;
                        held.push(idx);
                    }
                    None => rejected += 1,
                }
                for st in &s {
                    assert!(st.inflight() <= st.max_inflight, "in-flight bound exceeded");
                }
                if !held.is_empty() && rng.chance(0.4) {
                    let k = rng.below(held.len() as u64) as usize;
                    s[held.swap_remove(k)].release();
                }
            }
            assert_eq!(accepted + rejected, submitted);
            if s.iter().all(|st| st.weight() == 0) {
                assert_eq!(accepted, 0);
            }
        });
    }
}

// ---------------------------------------------------------------------
// Cluster end-to-end (real shards, native backend)
// ---------------------------------------------------------------------

#[test]
fn cluster_multiplies_correctly_and_releases_slots() {
    let cluster = native(&small_cfg());
    let one = one_bits(OpClass::Double);
    for i in 0..20u64 {
        let rx = cluster.try_submit(i, OpClass::Double, one, one).expect("capacity available");
        assert_eq!(rx.recv().unwrap().bits, one);
        drop(rx);
    }
    for st in cluster.states() {
        assert_eq!(st.inflight(), 0, "reply drop must release the slot");
    }
    let report = cluster.shutdown();
    assert_eq!(report.total_ops, 20);
    assert_eq!(report.rejected_saturated, 0);
}

/// The accounting invariant across shards, for every policy: total
/// executed ops across all shard op counters equals the number of
/// accepted submissions, class by class.
#[test]
fn total_ops_across_shards_equals_submitted_every_policy() {
    for policy in RouterPolicy::ALL {
        let cfg = ClusterConfig { shards: 3, policy, ..small_cfg() };
        let cluster = native(&cfg);
        let plan = [
            (OpClass::Bf16, 150u64),
            (OpClass::Half, 150),
            (OpClass::Single, 300),
            (OpClass::Double, 200),
            (OpClass::Quad, 100),
        ];
        let mut pending = Vec::new();
        for &(class, n) in &plan {
            for i in 0..n {
                pending.push(
                    cluster
                        .submit(i, class, one_bits(class), one_bits(class))
                        .expect("cluster open"),
                );
                if pending.len() >= 256 {
                    for rx in pending.drain(..) {
                        rx.recv().unwrap();
                    }
                }
            }
        }
        for rx in pending {
            rx.recv().unwrap();
        }
        let counts = cluster.op_counts();
        for &(class, n) in &plan {
            let op = crate::fabric::FabricOp { class, organization: SchemeKind::Civp };
            assert_eq!(counts.get(&op), Some(&n), "{policy:?} lost ops of {class:?}");
        }
        let report = cluster.shutdown();
        assert_eq!(report.total_ops, 900, "{policy:?}");
        assert_eq!(report.accepted, 900, "{policy:?}");
        assert_eq!(report.rejected_saturated, 0, "{policy:?}");
    }
}

#[test]
fn inflight_bound_is_hard_under_flood() {
    // Slow drain (long linger, one worker) + tiny in-flight bound: the
    // flood must stop at exactly bound × shards acceptances while nothing
    // is released, and every shard must stay at or under its bound.
    let cfg = ClusterConfig {
        shards: 2,
        service: ServiceConfig {
            workers: 1,
            max_batch: 8,
            linger_us: 50_000,
            ..Default::default()
        },
        policy: RouterPolicy::LeastLoaded,
        max_inflight: 4,
        spares_per_block: 0,
    };
    let cluster = native(&cfg);
    let mut held = Vec::new();
    let mut rejected = 0u64;
    let one = one_bits(OpClass::Double);
    for i in 0..500u64 {
        match cluster.try_submit(i, OpClass::Double, one, one) {
            Ok(rx) => held.push(rx),
            Err(AdmissionError::Saturated) => rejected += 1,
            Err(e) => panic!("unexpected {e:?}"),
        }
        for st in cluster.states() {
            assert!(st.inflight() <= 4, "bound exceeded: {}", st.inflight());
        }
    }
    assert_eq!(held.len(), 8, "exactly bound × shards accepted");
    assert_eq!(rejected, 492);
    let snap = cluster.metrics();
    assert_eq!(snap.counters["rejected_saturated"], 492);
    assert!(snap.gauges["shard0_inflight"] <= 4);
    for rx in held {
        rx.recv().unwrap();
    }
    let report = cluster.shutdown();
    assert_eq!(report.total_ops, 8);
    assert_eq!(report.rejected_saturated, 492);
}

#[test]
fn degraded_shard_loses_quad_affinity_and_traffic() {
    let cfg = ClusterConfig { policy: RouterPolicy::PrecisionAffinity, ..small_cfg() };
    let mut cluster = native(&cfg);
    // Kill one 24x24 block on shard 0 (zero spares: one fault = one block).
    let mut rng = Rng::new(42);
    let out = cluster.degrade_shard(0, BlockKind::M24x24, 1, &mut rng);
    assert_eq!(out.lost, 1);
    let s0 = &cluster.states()[0];
    assert!(!s0.quad_one_wave(), "15 of 16 24x24s cannot issue a quad in one wave");
    assert!(s0.weight() < FULL_WEIGHT, "lost capacity must shed weight");
    assert!(s0.weight() > 0, "still servable — not drained");
    assert!(cluster.states()[1].quad_one_wave());
    // Quad traffic now pins to shard 1; single traffic prefers shard 0.
    for i in 0..40u64 {
        let rx = cluster
            .submit(i, OpClass::Quad, one_bits(OpClass::Quad), one_bits(OpClass::Quad))
            .unwrap();
        assert_eq!(rx.shard(), 1);
        rx.recv().unwrap();
    }
    for i in 0..40u64 {
        let rx = cluster
            .submit(i, OpClass::Single, one_bits(OpClass::Single), one_bits(OpClass::Single))
            .unwrap();
        assert_eq!(rx.shard(), 0);
        rx.recv().unwrap();
    }
    let quad = crate::fabric::FabricOp { class: OpClass::Quad, organization: SchemeKind::Civp };
    assert_eq!(cluster.shard(0).service().op_counts().get(&quad), None);
    assert_eq!(cluster.shard(1).service().op_counts().get(&quad), Some(&40));
    let report = cluster.shutdown();
    assert_eq!(report.total_ops, 80);
    assert!(report.shards[0].health < 1.0);
    assert!(!report.shards[0].quad_one_wave);
}

#[test]
fn partial_unservability_steers_per_precision_then_drains() {
    let mut cluster = native(&small_cfg());
    // Execute a few quads first so shard 0 has history in its counters.
    for i in 0..10u64 {
        let one = one_bits(OpClass::Quad);
        cluster.submit(i, OpClass::Quad, one, one).unwrap().recv().unwrap();
    }
    // Kill all four 9x9 blocks on shard 0: CIVP bf16/double/quad lose a
    // block kind there — but single (pure 24x24) and binary16 (pure 24x9)
    // must keep serving.
    let mut rng = Rng::new(7);
    let out = cluster.degrade_shard(0, BlockKind::M9x9, 4, &mut rng);
    assert_eq!(out.lost, 4);
    let s0 = &cluster.states()[0];
    assert!(s0.weight() > 0, "single/half capacity remains — not drained");
    assert!(s0.servable(OpClass::Single));
    assert!(s0.servable(OpClass::Half), "binary16 needs only the live 24x9 pool");
    assert!(!s0.servable(OpClass::Bf16), "bf16 needs the dead 9x9 pool");
    assert!(!s0.servable(OpClass::Double));
    assert!(!s0.servable(OpClass::Quad));
    assert!(!s0.quad_one_wave());
    // Doubles route around shard 0; singles still reach it (least-loaded
    // tie breaks toward the lower index).
    let one_d = one_bits(OpClass::Double);
    for i in 0..30u64 {
        let rx = cluster.submit(i, OpClass::Double, one_d, one_d).unwrap();
        assert_eq!(rx.shard(), 1);
        rx.recv().unwrap();
    }
    let one_s = one_bits(OpClass::Single);
    let rx = cluster.submit(40, OpClass::Single, one_s, one_s).unwrap();
    assert_eq!(rx.shard(), 0);
    rx.recv().unwrap();
    // Kill the 24x24 pool too: binary16 (24x9-only) still holds the shard
    // above weight 0 — the open registry makes "fully drained" strictly
    // harder to reach than in the 3-class world.
    let out = cluster.degrade_shard(0, BlockKind::M24x24, 16, &mut rng);
    assert_eq!(out.lost, 16);
    assert!(cluster.states()[0].weight() > 0, "half still servable via 24x9");
    assert!(cluster.states()[0].servable(OpClass::Half));
    assert!(!cluster.states()[0].servable(OpClass::Single));
    // Only killing the 24x9 pool as well drains the shard completely.
    let out = cluster.degrade_shard(0, BlockKind::M24x9, 16, &mut rng);
    assert_eq!(out.lost, 16);
    assert_eq!(cluster.states()[0].weight(), 0);
    let rx = cluster.submit(41, OpClass::Single, one_s, one_s).unwrap();
    assert_eq!(rx.shard(), 1);
    rx.recv().unwrap();
    // The report still accounts shard 0's pre-degradation ops (pristine-
    // fabric fallback for classes its dead pools can no longer schedule).
    let report = cluster.shutdown();
    assert_eq!(report.total_ops, 42);
    let s0 = &report.shards[0];
    assert_eq!(s0.weight, 0);
    assert!(s0.fabric.total_ops > 0);
}

#[test]
fn fully_drained_cluster_reports_unservable_not_saturated() {
    // One shard, zero spares: killing every pool (24x24, 24x9 and 9x9 —
    // the registry's sub-single classes hold the shard up until the small
    // pools die too) leaves nothing servable. Submitting must fail fast
    // with `Unservable` (a retry loop on Saturated would spin forever).
    let cfg = ClusterConfig { shards: 1, ..small_cfg() };
    let mut cluster = native(&cfg);
    let mut rng = Rng::new(5);
    let out = cluster.degrade_shard(0, BlockKind::M24x24, 16, &mut rng);
    assert_eq!(out.lost, 16);
    assert!(cluster.states()[0].weight() > 0, "sub-single classes still servable");
    let out = cluster.degrade_shard(0, BlockKind::M24x9, 16, &mut rng);
    assert_eq!(out.lost, 16);
    let out = cluster.degrade_shard(0, BlockKind::M9x9, 4, &mut rng);
    assert_eq!(out.lost, 4);
    assert_eq!(cluster.states()[0].weight(), 0);
    let one = one_bits(OpClass::Single);
    let err = cluster.try_submit(0, OpClass::Single, one, one).unwrap_err();
    assert_eq!(err, AdmissionError::Unservable);
    let err = cluster.submit(1, OpClass::Quad, one, one).unwrap_err();
    assert_eq!(err, AdmissionError::Unservable, "blocking submit must not spin");
    let snap = cluster.metrics();
    assert_eq!(snap.counters["rejected_unservable"], 2);
    assert_eq!(snap.counters["rejected_saturated"], 0);
    cluster.shutdown();
}

#[test]
fn report_aggregates_sums_and_makespan() {
    let cluster = native(&ClusterConfig { policy: RouterPolicy::RoundRobin, ..small_cfg() });
    let one = one_bits(OpClass::Double);
    let mut pending = Vec::new();
    for i in 0..200u64 {
        pending.push(cluster.submit(i, OpClass::Double, one, one).unwrap());
    }
    for rx in pending {
        rx.recv().unwrap();
    }
    let report = cluster.report();
    let sum: u64 = report.shards.iter().map(|s| s.fabric.total_ops).sum();
    let max: u64 = report.shards.iter().map(|s| s.fabric.cycles).max().unwrap();
    assert_eq!(report.total_ops, sum);
    assert_eq!(report.total_ops, 200);
    assert_eq!(report.wall_cycles, max);
    // Round-robin over two healthy shards: both served some traffic.
    for s in &report.shards {
        assert!(s.fabric.total_ops > 0, "shard {} idle under round-robin", s.id);
    }
    let text = report.render();
    assert!(text.contains("total"));
    assert!(text.contains("accepted"));
    cluster.shutdown();
}

#[test]
fn shutdown_drains_inflight_ops_into_the_report() {
    let cluster = native(&small_cfg());
    let one = one_bits(OpClass::Single);
    let mut pending = Vec::new();
    for i in 0..300u64 {
        pending.push(cluster.submit(i, OpClass::Single, one, one).unwrap());
    }
    // Shut down with replies still un-received: drain must execute and
    // account every accepted op before the final report is built.
    drop(pending);
    let report = cluster.shutdown();
    assert_eq!(report.total_ops, 300);
    assert_eq!(report.accepted, 300);
}
