//! Pluggable shard-selection policies.
//!
//! A policy answers one question — "which live shard should this request
//! try next?" — over nothing but the lock-free [`ShardState`] snapshots
//! (weight, in-flight count, quad-affinity bit). Exclusion of
//! already-tried shards is a caller-maintained `u64` bitmask, which is
//! what makes spill-over admission (try the pick, on backpressure ask for
//! the next one) allocation-free.

use super::shard::ShardState;
use crate::decomp::OpClass;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Maximum shards a router addresses (candidate bookkeeping is a `u64`
/// bitmask).
pub const MAX_SHARDS: usize = 64;

/// Shard-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RouterPolicy {
    /// Weighted round-robin over live shards: a degraded shard's reduced
    /// weight directly reduces its share of the ticket space.
    RoundRobin,
    /// Lowest in-flight-per-weight-credit shard first (the atomic
    /// in-flight counters are the load signal).
    LeastLoaded,
    /// Quad traffic is pinned to shards whose block pools issue a quad in
    /// one wave; single/double traffic is steered away from those shards
    /// while non-affine capacity exists, keeping the quad columns free.
    /// Within the candidate set, least-loaded order applies.
    PrecisionAffinity,
}

impl RouterPolicy {
    /// All policies.
    pub const ALL: [RouterPolicy; 3] =
        [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded, RouterPolicy::PrecisionAffinity];

    /// Display / CLI name.
    pub fn name(self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::PrecisionAffinity => "precision-affinity",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        Self::ALL.into_iter().find(|p| p.name() == s)
    }
}

/// The shard router: one policy plus the round-robin cursor.
#[derive(Debug)]
pub struct Router {
    policy: RouterPolicy,
    rr: AtomicU64,
}

impl Router {
    /// New router with the given policy.
    pub fn new(policy: RouterPolicy) -> Router {
        Router { policy, rr: AtomicU64::new(0) }
    }

    /// The configured policy.
    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Pick the next candidate shard for `class`, excluding the
    /// indices set in the `tried` bitmask. Returns `None` when no live
    /// (weight > 0, class-servable) untried shard remains. Lock-free
    /// and allocation-free: a couple of passes over the state slice
    /// reading relaxed atomics.
    pub fn pick(
        &self,
        class: OpClass,
        shards: &[Arc<ShardState>],
        tried: u64,
    ) -> Option<usize> {
        debug_assert!(shards.len() <= MAX_SHARDS);
        match self.policy {
            RouterPolicy::RoundRobin => self.pick_weighted_rr(class, shards, tried),
            RouterPolicy::LeastLoaded => pick_least_loaded(class, shards, tried, |_| true),
            RouterPolicy::PrecisionAffinity => {
                // Phase 1: the affine candidate set. Quads want one-wave
                // shards; every lighter class (sub-single through double)
                // keeps those shards free while any other live capacity
                // exists.
                let affine: fn(&ShardState) -> bool = match class {
                    OpClass::Quad => |s| s.quad_one_wave(),
                    _ => |s| !s.quad_one_wave(),
                };
                pick_least_loaded(class, shards, tried, affine)
                    // Phase 2: any live shard (affinity is a preference,
                    // not a partition — capacity beats placement).
                    .or_else(|| pick_least_loaded(class, shards, tried, |_| true))
            }
        }
    }

    /// Weighted round-robin: one ticket per call, mapped onto the
    /// cumulative weight distribution of the live candidates.
    fn pick_weighted_rr(
        &self,
        class: OpClass,
        shards: &[Arc<ShardState>],
        tried: u64,
    ) -> Option<usize> {
        let live = |i: usize, s: &ShardState| {
            tried & (1u64 << i) == 0 && s.weight() > 0 && s.servable(class)
        };
        let total: u64 =
            shards.iter().enumerate().filter(|(i, s)| live(*i, s)).map(|(_, s)| s.weight()).sum();
        if total == 0 {
            return None;
        }
        let mut ticket = self.rr.fetch_add(1, Ordering::Relaxed) % total;
        for (i, s) in shards.iter().enumerate() {
            if !live(i, s) {
                continue;
            }
            let w = s.weight();
            if ticket < w {
                return Some(i);
            }
            ticket -= w;
        }
        // Weights moved between the two passes (concurrent degradation);
        // fall back to the first live candidate.
        shards.iter().enumerate().find(|(i, s)| live(*i, s)).map(|(i, _)| i)
    }
}

/// Argmin of in-flight-per-weight-credit over the eligible live shards
/// that can still serve `class`; ties break toward the lower absolute
/// in-flight count, then the lower index (deterministic).
fn pick_least_loaded(
    class: OpClass,
    shards: &[Arc<ShardState>],
    tried: u64,
    eligible: impl Fn(&ShardState) -> bool,
) -> Option<usize> {
    let mut best: Option<(u128, u64, usize)> = None;
    for (i, s) in shards.iter().enumerate() {
        if tried & (1u64 << i) != 0 || !eligible(s) || !s.servable(class) {
            continue;
        }
        let w = s.weight();
        if w == 0 {
            continue;
        }
        let inflight = s.inflight();
        // Scale before dividing so fractional loads order correctly:
        // 3 in flight at weight 16 (0.1875/credit) beats 2 at weight 8
        // (0.25/credit).
        let score = (inflight as u128) * 1_000_000 / w as u128;
        let key = (score, inflight, i);
        if best.map(|b| key < b).unwrap_or(true) {
            best = Some(key);
        }
    }
    best.map(|(_, _, i)| i)
}
