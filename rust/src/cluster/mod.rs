//! Layer-4 cluster: sharded serving across N independent fabric columns.
//!
//! PR 2 made a *single* CIVP fabric serve at hardware speed; this layer
//! scales out. The paper already frames the fabric as a replicated
//! resource (§III sizes the 24x24/24x9/9x9 pool per quad "column", and
//! [`crate::fabric::FabricConfig::civp_scaled`] models N columns) — a
//! cluster owns N such columns as independent **shards**, each a complete
//! PR-2 [`crate::coordinator::Service`] (its own batchers, worker pool and
//! lock-free op counters) plus a repairable fabric model:
//!
//! ```text
//!   clients ── Cluster::try_submit(id, precision, a, b)
//!        │           │
//!        ▼           ▼  Router policy (lock-free ShardState reads):
//!   round-robin (weighted) | least-loaded | precision-affinity
//!        │   admission: reserve an in-flight slot (hard per-shard bound),
//!        │   on backpressure spill over to the policy's next candidate
//!        ▼
//!   Shard 0..N  ──  Service (batchers → workers → backend) per shard
//!        │
//!        ▼  per-shard op counters → simulate_counts → ShardSummary
//!   ClusterReport (ops Σ, wall cycles = max, energy Σ, admission stats)
//! ```
//!
//! Degradation is first-class: faults injected through
//! [`crate::fabric::repair`] reduce a shard's routing weight in proportion
//! to the block capacity it lost; a shard whose pools no longer issue a
//! quad in one wave drops out of the quad-affinity set; a registry class
//! whose block kinds are entirely gone has its servable bit cleared so
//! only that traffic routes around the shard — the run-time-reconfigurable
//! multiplier line of work (Arish & Sharma) routing around degraded IP
//! cores.

mod report;
mod router;
mod shard;
#[cfg(test)]
mod tests;

pub use report::{ClusterReport, ShardSummary};
pub use router::{Router, RouterPolicy, MAX_SHARDS};
pub use shard::{DegradeOutcome, Shard, ShardState, FULL_WEIGHT};

use crate::config::ServiceConfig;
use crate::coordinator::{BackendChoice, RecvError, ReplyHandle, Response, TryRecvError};
use crate::decomp::{BlockKind, OpClass};
use crate::fabric::FabricOp;
use crate::metrics::{Counter, Gauge, Registry, Snapshot};
use crate::proput::Rng;
use crate::serve::AdmissionError;
use crate::wideint::PackedBits;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// Cluster deployment shape.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of shards (1..=[`MAX_SHARDS`]).
    pub shards: usize,
    /// Per-shard service configuration (batchers, workers, fabric preset —
    /// every shard is a full PR-2 service).
    pub service: ServiceConfig,
    /// Shard-selection policy.
    pub policy: RouterPolicy,
    /// Admission bound: max requests in flight per shard.
    pub max_inflight: u64,
    /// Spare sub-units provisioned per block instance (self-repair
    /// budget — see [`crate::fabric::RepairableFabric`]).
    pub spares_per_block: u32,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            shards: 4,
            service: ServiceConfig::default(),
            policy: RouterPolicy::LeastLoaded,
            max_inflight: 4096,
            spares_per_block: 2,
        }
    }
}

/// Reply handle for a cluster submit: the shard's pooled oneshot reply
/// plus the in-flight slot reservation, which is released exactly once —
/// when this handle drops (after `recv`, or on abandonment).
#[derive(Debug)]
pub struct ClusterReply {
    shard: usize,
    state: Arc<ShardState>,
    inner: ReplyHandle,
}

impl ClusterReply {
    /// Which shard served this request.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Block until the shard's worker delivers the response.
    pub fn recv(&self) -> Result<Response, RecvError> {
        self.inner.recv()
    }

    /// Non-blocking poll (see [`ReplyHandle::try_recv`]).
    pub fn try_recv(&self) -> Result<Response, TryRecvError> {
        self.inner.try_recv()
    }
}

impl Drop for ClusterReply {
    fn drop(&mut self) {
        self.state.release();
    }
}

/// Per-shard hot instruments, resolved once at startup.
struct ShardInstruments {
    accepted: Arc<Counter>,
    spilled: Arc<Counter>,
    inflight_gauge: Arc<Gauge>,
    weight_gauge: Arc<Gauge>,
    quad_gauge: Arc<Gauge>,
}

/// The sharded multi-fabric serving layer.
pub struct Cluster {
    shards: Vec<Shard>,
    states: Vec<Arc<ShardState>>,
    router: Router,
    metrics: Registry,
    instruments: Vec<ShardInstruments>,
    rejected: Arc<Counter>,
    unservable: Arc<Counter>,
}

impl Cluster {
    /// Start `cfg.shards` independent shards, each with its own worker
    /// pool, batchers, op counters and repairable fabric.
    pub fn start(cfg: &ClusterConfig, backend: BackendChoice) -> Cluster {
        assert!(
            cfg.shards >= 1 && cfg.shards <= MAX_SHARDS,
            "cluster needs 1..={MAX_SHARDS} shards, got {}",
            cfg.shards
        );
        let mut shards = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            shards.push(Shard::start(
                i,
                &cfg.service,
                backend.clone(),
                cfg.max_inflight,
                cfg.spares_per_block,
            ));
        }
        let states: Vec<Arc<ShardState>> = shards.iter().map(|s| s.state().clone()).collect();
        let metrics = Registry::new();
        let instruments = (0..cfg.shards)
            .map(|i| ShardInstruments {
                accepted: metrics.counter(&format!("shard{i}_accepted")),
                spilled: metrics.counter(&format!("shard{i}_spilled")),
                inflight_gauge: metrics.gauge(&format!("shard{i}_inflight")),
                weight_gauge: metrics.gauge(&format!("shard{i}_weight")),
                quad_gauge: metrics.gauge(&format!("shard{i}_quad_one_wave")),
            })
            .collect();
        let rejected = metrics.counter("rejected_saturated");
        let unservable = metrics.counter("rejected_unservable");
        Cluster {
            shards,
            states,
            router: Router::new(cfg.policy),
            metrics,
            instruments,
            rejected,
            unservable,
        }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// True if the cluster has no shards (never: `start` asserts >= 1).
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// One shard (tests, diagnostics).
    pub fn shard(&self, idx: usize) -> &Shard {
        &self.shards[idx]
    }

    /// The lock-free routing states (tests, diagnostics).
    pub fn states(&self) -> &[Arc<ShardState>] {
        &self.states
    }

    /// Submit without blocking. The router proposes shards in policy
    /// order; admission reserves an in-flight slot on the first shard with
    /// room, spilling to the next candidate when a shard is at its bound
    /// or its precision queue is full. [`AdmissionError::Saturated`]
    /// is cluster-wide backpressure.
    pub fn try_submit(
        &self,
        id: u64,
        class: OpClass,
        a: impl Into<PackedBits>,
        b: impl Into<PackedBits>,
    ) -> Result<ClusterReply, AdmissionError> {
        let (a, b): (PackedBits, PackedBits) = (a.into(), b.into());
        let mut tried: u64 = 0;
        // The first shard that turns the request away; charged with one
        // `spilled` only if the request is later accepted elsewhere (a
        // request that every shard refuses counts once as rejected, not
        // as a spill too).
        let mut spilled_from: Option<usize> = None;
        while let Some(idx) = self.router.pick(class, &self.states, tried) {
            tried |= 1u64 << idx;
            let state = &self.states[idx];
            if !state.try_acquire() {
                spilled_from.get_or_insert(idx);
                continue;
            }
            match self.shards[idx].service().try_submit(id, class, a, b) {
                Ok(rx) => {
                    self.instruments[idx].accepted.inc();
                    if let Some(from) = spilled_from {
                        self.instruments[from].spilled.inc();
                    }
                    return Ok(ClusterReply { shard: idx, state: state.clone(), inner: rx });
                }
                Err(AdmissionError::Saturated) => {
                    state.release();
                    spilled_from.get_or_insert(idx);
                }
                Err(e) => {
                    // `Draining`: the shard has shut down — surface it as
                    // a terminal admission outcome, not backpressure.
                    state.release();
                    return Err(e);
                }
            }
        }
        if tried == 0 {
            // The router had no candidate at all: nothing live can serve
            // this class — permanent until capacity is restored, so
            // it must not read as retryable backpressure.
            self.unservable.inc();
            return Err(AdmissionError::Unservable);
        }
        self.rejected.inc();
        Err(AdmissionError::Saturated)
    }

    /// Submit, parking briefly under cluster-wide backpressure until a
    /// shard frees up. The blocking analogue of [`Cluster::try_submit`].
    /// Does NOT retry on [`AdmissionError::Unservable`] — waiting
    /// cannot conjure back a block kind the fabric has lost.
    pub fn submit(
        &self,
        id: u64,
        class: OpClass,
        a: impl Into<PackedBits>,
        b: impl Into<PackedBits>,
    ) -> Result<ClusterReply, AdmissionError> {
        let (a, b): (PackedBits, PackedBits) = (a.into(), b.into());
        loop {
            match self.try_submit(id, class, a, b) {
                Err(AdmissionError::Saturated) => {
                    std::thread::sleep(Duration::from_micros(20));
                }
                other => return other,
            }
        }
    }

    /// Inject `faults` sub-unit faults into `kind` blocks of shard `idx`
    /// and recompute its routing weight/affinity. The cluster keeps
    /// serving throughout: a shard that lost blocks gets proportionally
    /// less traffic; one that can no longer serve its scheme is drained.
    pub fn degrade_shard(
        &mut self,
        idx: usize,
        kind: BlockKind,
        faults: usize,
        rng: &mut Rng,
    ) -> DegradeOutcome {
        self.shards[idx].inject_faults(kind, faults, rng)
    }

    /// Aggregated per-class op counts across all shards (the cluster-wide
    /// analogue of [`crate::coordinator::Service::op_counts`]).
    pub fn op_counts(&self) -> BTreeMap<FabricOp, u64> {
        let mut out: BTreeMap<FabricOp, u64> = BTreeMap::new();
        for shard in &self.shards {
            for (class, n) in shard.service().op_counts() {
                *out.entry(class).or_insert(0) += n;
            }
        }
        out
    }

    /// Telemetry snapshot: per-shard accepted/spilled counters plus the
    /// per-shard gauges (in-flight, weight, quad-affinity), refreshed from
    /// the lock-free shard states at snapshot time.
    pub fn metrics(&self) -> Snapshot {
        for (state, inst) in self.states.iter().zip(&self.instruments) {
            inst.inflight_gauge.set(state.inflight() as i64);
            inst.weight_gauge.set(state.weight() as i64);
            inst.quad_gauge.set(i64::from(state.quad_one_wave()));
        }
        self.metrics.snapshot()
    }

    /// One shard's report slice (single construction point shared by the
    /// live [`Cluster::report`] and the final [`Cluster::shutdown`]).
    fn summarize(&self, shard: &Shard) -> ShardSummary {
        ShardSummary {
            id: shard.id,
            health: shard.health(),
            weight: shard.state().weight(),
            quad_one_wave: shard.state().quad_one_wave(),
            inflight: shard.state().inflight(),
            accepted: self.instruments[shard.id].accepted.get(),
            fabric: shard.fabric_report(),
        }
    }

    /// Aggregated cluster report over everything executed so far.
    pub fn report(&self) -> ClusterReport {
        let summaries = self.shards.iter().map(|s| self.summarize(s)).collect();
        ClusterReport::aggregate(summaries, self.spilled_total(), self.rejected.get())
    }

    fn spilled_total(&self) -> u64 {
        self.instruments.iter().map(|i| i.spilled.get()).sum()
    }

    /// Drain every shard (close queues, join workers) *without* consuming
    /// the cluster, so any thread holding an `Arc<Cluster>` — the network
    /// listener does — can stop admission and quiesce the worker pools.
    /// Late submits fail with [`AdmissionError::Draining`]; everything
    /// accepted before the close still gets exactly one reply. Idempotent
    /// (delegates to the shards' idempotent [`Shard::drain`]).
    pub fn drain(&self) {
        for shard in &self.shards {
            shard.drain();
        }
    }

    /// Drain every shard (op counters are final afterwards) and return
    /// the final aggregated report.
    pub fn shutdown(self) -> ClusterReport {
        self.drain();
        self.report()
    }
}
