//! Fixed-width multi-limb unsigned integers.
//!
//! The CIVP decomposition engine needs exact integer arithmetic wider than
//! `u128`: a quadruple-precision significand product is 226 bits (113x113),
//! and the padded CIVP form is 228 bits (114x114). This module provides
//! `Wide<N>` — a little-endian array of `N` u64 limbs — with the handful of
//! exact operations the library needs: add/sub with carry, shifts, widening
//! schoolbook multiplication, bit extraction, and sticky-bit queries used by
//! the rounding stage.
//!
//! `Wide<N>` is deliberately *not* a general bignum: widths are fixed at
//! compile time, there is no allocation, and overflow on `add`/`shl` is a
//! checked error in debug and wraps in release (matching hardware
//! accumulator semantics). The decomposition executor uses `U256` as the
//! accumulator for every precision.

mod ops;
#[cfg(test)]
mod tests;

pub use ops::{add_limbs, mul_limb, sub_limbs};

/// Little-endian fixed array of `N` 64-bit limbs.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Wide<const N: usize> {
    /// limbs\[0\] is least significant.
    pub limbs: [u64; N],
}

/// 128-bit value (2 limbs) — significand container for the narrow classes.
pub type U128 = Wide<2>;
/// 192-bit value (3 limbs).
pub type U192 = Wide<3>;
/// 256-bit value (4 limbs) — product accumulator for the narrow classes.
pub type U256 = Wide<4>;
/// 512-bit value (8 limbs) — significand/operand container for the wide
/// classes (binary256/binary512 significands are 237/489 bits).
pub type U512 = Wide<8>;
/// 1024-bit value (16 limbs) — product accumulator for the wide classes
/// (a 489×489 product is 978 bits).
pub type U1024 = Wide<16>;

/// The universal packed-operand word the serving layers carry: big enough
/// for every registry class (the widest packed format is binary512 = 512
/// bits). Narrow classes occupy the low limbs; the rest stay zero.
pub type PackedBits = U512;

impl<const N: usize> Default for Wide<N> {
    fn default() -> Self {
        Self::ZERO
    }
}

impl<const N: usize> Wide<N> {
    /// The zero value.
    pub const ZERO: Self = Wide { limbs: [0u64; N] };
    /// The value 1.
    pub const ONE: Self = {
        let mut l = [0u64; N];
        l[0] = 1;
        Wide { limbs: l }
    };
    /// Total bit width.
    pub const BITS: u32 = 64 * N as u32;

    /// Construct from a `u64`.
    #[inline]
    pub const fn from_u64(v: u64) -> Self {
        let mut l = [0u64; N];
        l[0] = v;
        Wide { limbs: l }
    }

    /// Construct from a `u128` (low two limbs).
    #[inline]
    pub const fn from_u128(v: u128) -> Self {
        assert!(N >= 2);
        let mut l = [0u64; N];
        l[0] = v as u64;
        l[1] = (v >> 64) as u64;
        Wide { limbs: l }
    }

    /// Low 64 bits.
    #[inline]
    pub const fn as_u64(&self) -> u64 {
        self.limbs[0]
    }

    /// Low 128 bits.
    #[inline]
    pub fn as_u128(&self) -> u128 {
        let lo = self.limbs[0] as u128;
        let hi = if N >= 2 { self.limbs[1] as u128 } else { 0 };
        lo | (hi << 64)
    }

    /// True if the value is zero.
    #[inline]
    pub fn is_zero(&self) -> bool {
        self.limbs.iter().all(|&l| l == 0)
    }

    /// Number of significant bits (position of highest set bit + 1); 0 for zero.
    pub fn bit_len(&self) -> u32 {
        for i in (0..N).rev() {
            if self.limbs[i] != 0 {
                return 64 * i as u32 + (64 - self.limbs[i].leading_zeros());
            }
        }
        0
    }

    /// Extract bit `i` (0 = LSB). Bits past the width read as 0.
    #[inline]
    pub fn bit(&self, i: u32) -> bool {
        let limb = (i / 64) as usize;
        if limb >= N {
            return false;
        }
        (self.limbs[limb] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i` to 1. Panics if out of range.
    #[inline]
    pub fn set_bit(&mut self, i: u32) {
        let limb = (i / 64) as usize;
        assert!(limb < N, "bit index {i} out of range for {} limbs", N);
        self.limbs[limb] |= 1u64 << (i % 64);
    }

    /// Extract `width` bits starting at bit `lo` as a u64 (`width <= 64`).
    /// Hot path of the tile executor — reads at most two limbs directly
    /// instead of materializing a shifted value.
    #[inline]
    pub fn extract_u64(&self, lo: u32, width: u32) -> u64 {
        assert!(width <= 64);
        if width == 0 {
            return 0;
        }
        let limb = (lo / 64) as usize;
        let sh = lo % 64;
        let mut v = if limb < N { self.limbs[limb] >> sh } else { 0 };
        if sh > 0 && limb + 1 < N {
            v |= self.limbs[limb + 1] << (64 - sh);
        }
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        v & mask
    }

    /// Extract `width` bits starting at bit `lo` as a new `Wide` (`width <= BITS`).
    pub fn extract(&self, lo: u32, width: u32) -> Self {
        let shifted = self.shr(lo);
        shifted.mask_low(width)
    }

    /// Keep only the low `width` bits.
    pub fn mask_low(&self, width: u32) -> Self {
        let mut out = *self;
        for i in 0..N {
            let lo = 64 * i as u32;
            if lo >= width {
                out.limbs[i] = 0;
            } else {
                let keep = width - lo;
                if keep < 64 {
                    out.limbs[i] &= (1u64 << keep) - 1;
                }
            }
        }
        out
    }

    /// True if any of the low `width` bits is set — the "sticky" query used
    /// by round-to-nearest-even.
    pub fn any_below(&self, width: u32) -> bool {
        !self.mask_low(width).is_zero()
    }

    /// Logical shift left. Bits shifted past the top are dropped (hardware
    /// accumulator semantics); callers size the accumulator so this never
    /// loses information on valid inputs.
    pub fn shl(&self, n: u32) -> Self {
        if n == 0 {
            return *self;
        }
        let mut out = Self::ZERO;
        let limb_shift = (n / 64) as usize;
        let bit_shift = n % 64;
        for i in (0..N).rev() {
            if i < limb_shift {
                continue;
            }
            let src = i - limb_shift;
            let mut v = self.limbs[src] << bit_shift;
            if bit_shift > 0 && src > 0 {
                v |= self.limbs[src - 1] >> (64 - bit_shift);
            }
            out.limbs[i] = v;
        }
        out
    }

    /// Logical shift right.
    pub fn shr(&self, n: u32) -> Self {
        if n == 0 {
            return *self;
        }
        if n >= Self::BITS {
            return Self::ZERO;
        }
        let mut out = Self::ZERO;
        let limb_shift = (n / 64) as usize;
        let bit_shift = n % 64;
        for i in 0..N {
            let src = i + limb_shift;
            if src >= N {
                break;
            }
            let mut v = self.limbs[src] >> bit_shift;
            if bit_shift > 0 && src + 1 < N {
                v |= self.limbs[src + 1] << (64 - bit_shift);
            }
            out.limbs[i] = v;
        }
        out
    }

    /// Wrapping addition (carry out of the top limb is dropped; debug-asserts
    /// it is zero, since callers size accumulators to avoid overflow).
    pub fn wrapping_add(&self, rhs: &Self) -> Self {
        let (out, carry) = self.overflowing_add(rhs);
        debug_assert!(!carry, "Wide::add overflow");
        out
    }

    /// Addition reporting carry-out.
    pub fn overflowing_add(&self, rhs: &Self) -> (Self, bool) {
        let mut out = Self::ZERO;
        let mut carry = 0u64;
        for i in 0..N {
            let (s1, c1) = self.limbs[i].overflowing_add(rhs.limbs[i]);
            let (s2, c2) = s1.overflowing_add(carry);
            out.limbs[i] = s2;
            carry = (c1 as u64) + (c2 as u64);
        }
        (out, carry != 0)
    }

    /// Wrapping subtraction (borrow out of the top limb is dropped;
    /// debug-asserts no borrow, i.e. `self >= rhs`).
    pub fn wrapping_sub(&self, rhs: &Self) -> Self {
        let mut out = Self::ZERO;
        let mut borrow = 0u64;
        for i in 0..N {
            let (d1, b1) = self.limbs[i].overflowing_sub(rhs.limbs[i]);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.limbs[i] = d2;
            borrow = (b1 as u64) + (b2 as u64);
        }
        debug_assert!(borrow == 0, "Wide::sub underflow");
        out
    }

    /// Bitwise OR.
    pub fn or(&self, rhs: &Self) -> Self {
        let mut out = *self;
        for i in 0..N {
            out.limbs[i] |= rhs.limbs[i];
        }
        out
    }

    /// Bitwise AND.
    pub fn and(&self, rhs: &Self) -> Self {
        let mut out = *self;
        for i in 0..N {
            out.limbs[i] &= rhs.limbs[i];
        }
        out
    }

    /// Three-way compare.
    pub fn cmp_wide(&self, rhs: &Self) -> core::cmp::Ordering {
        for i in (0..N).rev() {
            match self.limbs[i].cmp(&rhs.limbs[i]) {
                core::cmp::Ordering::Equal => continue,
                ord => return ord,
            }
        }
        core::cmp::Ordering::Equal
    }

    /// Multiply by a u64, accumulating into a value of the same width
    /// (debug-asserts no overflow past the top limb).
    pub fn mul_u64(&self, m: u64) -> Self {
        let mut out = Self::ZERO;
        let mut carry = 0u128;
        for i in 0..N {
            let prod = self.limbs[i] as u128 * m as u128 + carry;
            out.limbs[i] = prod as u64;
            carry = prod >> 64;
        }
        debug_assert!(carry == 0, "Wide::mul_u64 overflow");
        out
    }

    /// Widen into a larger limb count.
    pub fn widen<const M: usize>(&self) -> Wide<M> {
        assert!(M >= N);
        let mut out = Wide::<M>::ZERO;
        out.limbs[..N].copy_from_slice(&self.limbs);
        out
    }

    /// Truncate into a smaller (or equal) limb count, debug-asserting the
    /// dropped limbs are zero.
    pub fn narrow<const M: usize>(&self) -> Wide<M> {
        let mut out = Wide::<M>::ZERO;
        for i in 0..M.min(N) {
            out.limbs[i] = self.limbs[i];
        }
        for i in M..N {
            debug_assert!(self.limbs[i] == 0, "Wide::narrow drops non-zero limb");
        }
        out
    }

    /// Exact schoolbook multiply into a fixed `Wide<M>` — the
    /// allocation-free sibling of [`Wide::mul_wide`]. `M` must hold the
    /// full `2N`-limb product of the operands' significant limbs
    /// (debug-asserted; limbs past `M` must come out zero).
    pub fn mul_full<const M: usize>(&self, rhs: &Self) -> Wide<M> {
        let mut out = Wide::<M>::ZERO;
        for i in 0..N {
            if self.limbs[i] == 0 {
                continue;
            }
            let mut carry = 0u128;
            for j in 0..N {
                let idx = i + j;
                let prod = self.limbs[i] as u128 * rhs.limbs[j] as u128 + carry;
                if idx < M {
                    let s = out.limbs[idx] as u128 + (prod as u64 as u128);
                    out.limbs[idx] = s as u64;
                    carry = (prod >> 64) + (s >> 64);
                } else {
                    debug_assert!(prod == 0, "Wide::mul_full drops non-zero limb");
                    carry = 0;
                }
            }
            let mut idx = i + N;
            while carry != 0 {
                debug_assert!(idx < M, "Wide::mul_full carry past top limb");
                if idx >= M {
                    break;
                }
                let s = out.limbs[idx] as u128 + carry;
                out.limbs[idx] = s as u64;
                carry = s >> 64;
                idx += 1;
            }
        }
        out
    }

    /// Exact schoolbook widening multiply: `N x N -> 2N` limbs.
    pub fn mul_wide(&self, rhs: &Self) -> WideProduct<N> {
        let mut out = vec![0u64; 2 * N];
        for i in 0..N {
            if self.limbs[i] == 0 {
                continue;
            }
            let mut carry = 0u128;
            for j in 0..N {
                let idx = i + j;
                let prod =
                    self.limbs[i] as u128 * rhs.limbs[j] as u128 + out[idx] as u128 + carry;
                out[idx] = prod as u64;
                carry = prod >> 64;
            }
            let mut idx = i + N;
            while carry != 0 {
                let s = out[idx] as u128 + carry;
                out[idx] = s as u64;
                carry = s >> 64;
                idx += 1;
            }
        }
        WideProduct { limbs: out }
    }

    /// Parse a hex string (with or without a `0x` prefix). Panics on
    /// invalid digits or overflow — intended for tests and golden vectors.
    pub fn from_hex(s: &str) -> Self {
        let s = s.strip_prefix("0x").unwrap_or(s);
        let mut out = Self::ZERO;
        let mut bit = 0u32;
        for c in s.as_bytes().iter().rev() {
            let d = (*c as char).to_digit(16).expect("invalid hex digit") as u64;
            assert!(bit + 4 <= Self::BITS || d == 0, "hex literal overflows width");
            if d != 0 {
                out.limbs[(bit / 64) as usize] |= d << (bit % 64);
            }
            bit += 4;
        }
        out
    }

    /// Hex string (for debugging / golden tests).
    pub fn to_hex(&self) -> String {
        let mut s = String::from("0x");
        let mut started = false;
        for i in (0..N).rev() {
            if !started {
                if self.limbs[i] == 0 && i != 0 {
                    continue;
                }
                s.push_str(&format!("{:x}", self.limbs[i]));
                started = true;
            } else {
                s.push_str(&format!("{:016x}", self.limbs[i]));
            }
        }
        s
    }
}

impl<const N: usize> core::fmt::Debug for Wide<N> {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "Wide<{}>({})", N, self.to_hex())
    }
}

impl<const N: usize> PartialOrd for Wide<N> {
    fn partial_cmp(&self, other: &Self) -> Option<core::cmp::Ordering> {
        Some(self.cmp_wide(other))
    }
}

impl<const N: usize> Ord for Wide<N> {
    fn cmp(&self, other: &Self) -> core::cmp::Ordering {
        self.cmp_wide(other)
    }
}

impl<const N: usize> From<u64> for Wide<N> {
    fn from(v: u64) -> Self {
        Self::from_u64(v)
    }
}

impl<const N: usize> From<u128> for Wide<N> {
    fn from(v: u128) -> Self {
        Self::from_u128(v)
    }
}

/// Equality against a `u128`: the low 128 bits match and every higher limb
/// is zero. Lets narrow-operand call sites keep comparing against `u128`
/// literals after the serving layers widened to [`PackedBits`].
impl<const N: usize> PartialEq<u128> for Wide<N> {
    fn eq(&self, other: &u128) -> bool {
        self.as_u128() == *other && self.limbs.iter().skip(2).all(|&l| l == 0)
    }
}

/// Dynamically-sized product of `Wide<N> x Wide<N>` (2N limbs). Only used as
/// an intermediate before narrowing into `U256`.
pub struct WideProduct<const N: usize> {
    /// Little-endian limbs, length 2N.
    pub limbs: Vec<u64>,
}

impl<const N: usize> WideProduct<N> {
    /// Convert into a fixed `Wide<M>`, debug-asserting dropped limbs are zero.
    pub fn into_wide<const M: usize>(self) -> Wide<M> {
        let mut out = Wide::<M>::ZERO;
        for (i, &l) in self.limbs.iter().enumerate() {
            if i < M {
                out.limbs[i] = l;
            } else {
                debug_assert!(l == 0, "WideProduct::into_wide drops non-zero limb");
            }
        }
        out
    }
}

/// Convenience: exact `U128 x U128 -> U256`.
pub fn mul_u128(a: U128, b: U128) -> U256 {
    a.mul_wide(&b).into_wide::<4>()
}
