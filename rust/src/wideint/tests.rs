//! Unit + property tests for `wideint`. The u128 native type is the oracle
//! for everything that fits in 128 bits; 256-bit behaviour is checked by
//! algebraic identities (distributivity, shift/mask laws).

use super::*;
use crate::proput::{forall, Rng};

fn rand_u128(rng: &mut Rng) -> u128 {
    let hi = rng.next_u64() as u128;
    let lo = rng.next_u64() as u128;
    (hi << 64) | lo
}

#[test]
fn zero_one_constants() {
    assert!(U256::ZERO.is_zero());
    assert_eq!(U256::ONE.as_u64(), 1);
    assert_eq!(U128::BITS, 128);
    assert_eq!(U256::BITS, 256);
}

#[test]
fn from_as_u128_roundtrip() {
    forall(0x11, 2000, |rng| {
        let v = rand_u128(rng);
        assert_eq!(U128::from_u128(v).as_u128(), v);
        assert_eq!(U256::from_u128(v).as_u128(), v);
    });
}

#[test]
fn bit_len_matches_u128() {
    forall(0x12, 2000, |rng| {
        let v = rand_u128(rng);
        let expect = 128 - v.leading_zeros();
        assert_eq!(U128::from_u128(v).bit_len(), expect);
    });
    assert_eq!(U256::ZERO.bit_len(), 0);
    assert_eq!(U256::ONE.bit_len(), 1);
    let big = U256::ONE.shl(255);
    assert_eq!(big.bit_len(), 256);
}

#[test]
fn shl_shr_match_u128() {
    forall(0x13, 4000, |rng| {
        let v = rand_u128(rng);
        let n = rng.below(128) as u32;
        let w = U128::from_u128(v);
        assert_eq!(w.shl(n).as_u128(), v << n, "shl {n}");
        assert_eq!(w.shr(n).as_u128(), v >> n, "shr {n}");
    });
}

#[test]
fn shr_past_width_is_zero() {
    let v = U256::from_u128(u128::MAX);
    assert!(v.shr(256).is_zero());
    assert!(v.shr(300).is_zero());
}

#[test]
fn shl_shr_roundtrip_256() {
    forall(0x14, 2000, |rng| {
        let v = rand_u128(rng);
        let n = rng.below(128) as u32; // keep within range so no bits drop
        let w = U256::from_u128(v);
        assert_eq!(w.shl(n).shr(n).as_u128(), v);
    });
}

#[test]
fn add_sub_match_u128() {
    forall(0x15, 4000, |rng| {
        let a = rand_u128(rng);
        let b = rand_u128(rng);
        let wa = U256::from_u128(a);
        let wb = U256::from_u128(b);
        let sum = wa.wrapping_add(&wb);
        // a + b fits in 129 bits; check low 128 and bit 128.
        assert_eq!(sum.mask_low(128).as_u128(), a.wrapping_add(b));
        assert_eq!(sum.bit(128), a.checked_add(b).is_none());
        // (a+b) - b == a
        assert_eq!(sum.wrapping_sub(&wb).as_u128(), a);
    });
}

#[test]
fn overflowing_add_carry() {
    let max = {
        let mut w = U128::ZERO;
        w.limbs = [u64::MAX; 2];
        w
    };
    let (sum, carry) = max.overflowing_add(&U128::ONE);
    assert!(carry);
    assert!(sum.is_zero());
}

#[test]
fn mul_wide_matches_u128_oracle() {
    forall(0x16, 4000, |rng| {
        let a = rng.next_u64() as u128;
        let b = rng.next_u64() as u128;
        let prod = U128::from_u128(a).mul_wide(&U128::from_u128(b));
        let w: U256 = prod.into_wide();
        assert_eq!(w.as_u128(), a * b);
    });
}

#[test]
fn mul_u128_distributive() {
    // (a + b) * c == a*c + b*c over 256-bit results, with a,b < 2^127 so the
    // sum does not overflow 128 bits.
    forall(0x17, 2000, |rng| {
        let a = rand_u128(rng) >> 1;
        let b = rand_u128(rng) >> 1;
        let c = rand_u128(rng);
        let ab = U128::from_u128(a.wrapping_add(b));
        let lhs = mul_u128(ab, U128::from_u128(c));
        let rhs = mul_u128(U128::from_u128(a), U128::from_u128(c))
            .wrapping_add(&mul_u128(U128::from_u128(b), U128::from_u128(c)));
        assert_eq!(lhs, rhs);
    });
}

#[test]
fn mul_u128_commutative_and_identity() {
    forall(0x18, 2000, |rng| {
        let a = rand_u128(rng);
        let b = rand_u128(rng);
        let wa = U128::from_u128(a);
        let wb = U128::from_u128(b);
        assert_eq!(mul_u128(wa, wb), mul_u128(wb, wa));
        assert_eq!(mul_u128(wa, U128::ONE).mask_low(128).as_u128(), a);
        assert!(mul_u128(wa, U128::ZERO).is_zero());
    });
}

#[test]
fn extract_and_mask() {
    forall(0x19, 3000, |rng| {
        let v = rand_u128(rng);
        let lo = rng.below(120) as u32;
        let width = rng.range(1, (128 - lo as u64).min(64)) as u32;
        let w = U128::from_u128(v);
        let expect = if width == 64 {
            ((v >> lo) & u64::MAX as u128) as u64
        } else {
            ((v >> lo) as u64) & ((1u64 << width) - 1)
        };
        assert_eq!(w.extract_u64(lo, width), expect);
        assert_eq!(w.extract(lo, width).as_u64(), expect);
    });
}

#[test]
fn mask_low_idempotent() {
    forall(0x1a, 2000, |rng| {
        let v = rand_u128(rng);
        let width = rng.below(257) as u32;
        let w = U256::from_u128(v);
        let m = w.mask_low(width);
        assert_eq!(m.mask_low(width), m);
        // masked value never exceeds width bits
        assert!(m.bit_len() <= width);
    });
}

#[test]
fn any_below_sticky() {
    let mut v = U256::ZERO;
    v.set_bit(10);
    assert!(!v.any_below(10));
    assert!(v.any_below(11));
    assert!(v.any_below(200));
    assert!(!U256::ZERO.any_below(256));
}

#[test]
fn bit_set_get() {
    forall(0x1b, 1000, |rng| {
        let i = rng.below(256) as u32;
        let mut v = U256::ZERO;
        v.set_bit(i);
        assert!(v.bit(i));
        assert_eq!(v.bit_len(), i + 1);
    });
}

#[test]
fn bit_past_width_reads_zero() {
    let v = U128::from_u128(u128::MAX);
    assert!(!v.bit(128));
    assert!(!v.bit(1000));
}

#[test]
fn mul_u64_matches_mul_wide() {
    forall(0x1c, 2000, |rng| {
        let a = rand_u128(rng) >> 64; // keep to 64 bits so result fits 128
        let m = rng.next_u64();
        let w = U128::from_u128(a);
        let lhs = w.mul_u64(m);
        let rhs: U256 = w.mul_wide(&U128::from_u64(m)).into_wide();
        assert_eq!(lhs.as_u128(), rhs.as_u128());
    });
}

#[test]
fn widen_narrow_roundtrip() {
    forall(0x1d, 1000, |rng| {
        let v = rand_u128(rng);
        let w: U128 = U128::from_u128(v);
        let wide: U256 = w.widen();
        let back: U128 = wide.narrow();
        assert_eq!(back, w);
    });
}

#[test]
fn ordering_matches_u128() {
    forall(0x1e, 2000, |rng| {
        let a = rand_u128(rng);
        let b = rand_u128(rng);
        assert_eq!(U128::from_u128(a).cmp(&U128::from_u128(b)), a.cmp(&b));
    });
}

#[test]
fn to_hex_small_values() {
    assert_eq!(U128::from_u64(0xabc).to_hex(), "0xabc");
    assert_eq!(U128::ZERO.to_hex(), "0x0");
    assert_eq!(
        U128::from_u128(0x1_0000_0000_0000_0000).to_hex(),
        "0x10000000000000000"
    );
}

#[test]
fn slice_ops_match_wide() {
    forall(0x1f, 2000, |rng| {
        let a = rand_u128(rng);
        let b = rand_u128(rng) >> 1;
        let a2 = a >> 1;
        // add_limbs
        let mut acc = [a2 as u64, (a2 >> 64) as u64, 0];
        let addend = [b as u64, (b >> 64) as u64];
        let carry = add_limbs(&mut acc, &addend);
        assert_eq!(carry, 0);
        let sum = acc[0] as u128 | ((acc[1] as u128) << 64);
        assert_eq!(sum, a2 + b);
        // sub back
        let borrow = sub_limbs(&mut acc, &addend);
        assert_eq!(borrow, 0);
        let diff = acc[0] as u128 | ((acc[1] as u128) << 64);
        assert_eq!(diff, a2);
    });
}

#[test]
fn mul_limb_matches_oracle() {
    forall(0x20, 2000, |rng| {
        let a = rand_u128(rng);
        let m = rng.next_u64();
        let limbs = [a as u64, (a >> 64) as u64];
        let mut out = [0u64; 3];
        mul_limb(&limbs, m, &mut out);
        // Oracle via U128 widening multiply.
        let oracle = U128::from_u128(a).mul_wide(&U128::from_u64(m));
        assert_eq!(out[0], oracle.limbs[0]);
        assert_eq!(out[1], oracle.limbs[1]);
        assert_eq!(out[2], oracle.limbs[2]);
    });
}
