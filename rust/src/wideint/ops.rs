//! Slice-level limb primitives shared by `Wide` and the decomposition
//! executor's scratch accumulators.

/// `acc += addend`, both little-endian limb slices; `addend` may be shorter.
/// Returns the final carry (0 or 1) out of `acc`.
pub fn add_limbs(acc: &mut [u64], addend: &[u64]) -> u64 {
    debug_assert!(acc.len() >= addend.len());
    let mut carry = 0u64;
    for i in 0..addend.len() {
        let (s1, c1) = acc[i].overflowing_add(addend[i]);
        let (s2, c2) = s1.overflowing_add(carry);
        acc[i] = s2;
        carry = (c1 as u64) + (c2 as u64);
    }
    let mut i = addend.len();
    while carry != 0 && i < acc.len() {
        let (s, c) = acc[i].overflowing_add(carry);
        acc[i] = s;
        carry = c as u64;
        i += 1;
    }
    carry
}

/// `acc -= sub`, both little-endian; returns the final borrow.
pub fn sub_limbs(acc: &mut [u64], sub: &[u64]) -> u64 {
    debug_assert!(acc.len() >= sub.len());
    let mut borrow = 0u64;
    for i in 0..sub.len() {
        let (d1, b1) = acc[i].overflowing_sub(sub[i]);
        let (d2, b2) = d1.overflowing_sub(borrow);
        acc[i] = d2;
        borrow = (b1 as u64) + (b2 as u64);
    }
    let mut i = sub.len();
    while borrow != 0 && i < acc.len() {
        let (d, b) = acc[i].overflowing_sub(borrow);
        acc[i] = d;
        borrow = b as u64;
        i += 1;
    }
    borrow
}

/// `out = a * m` for a limb slice and a single u64; `out.len() == a.len()+1`.
pub fn mul_limb(a: &[u64], m: u64, out: &mut [u64]) {
    debug_assert!(out.len() >= a.len() + 1);
    let mut carry = 0u128;
    for i in 0..a.len() {
        let prod = a[i] as u128 * m as u128 + carry;
        out[i] = prod as u64;
        carry = prod >> 64;
    }
    out[a.len()] = carry as u64;
}
