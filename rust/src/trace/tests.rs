//! Trace generator tests: determinism, mix fidelity, operand validity.
//!
//! Format field widths come from the [`OpClass`] registry (one source of
//! truth in `fpu::format`) — no hand-copied `(exp_bits, frac_bits)` tables.

use super::*;
use crate::decomp::OpClass;

#[test]
fn deterministic_for_fixed_seed() {
    let mut g1 = TraceGen::new(7, WorkloadSpec::Graphics.mix(), 100);
    let mut g2 = TraceGen::new(7, WorkloadSpec::Graphics.mix(), 100);
    assert_eq!(g1.take(100), g2.take(100));
}

#[test]
fn different_seeds_differ() {
    let mut g1 = TraceGen::new(1, WorkloadSpec::Uniform.mix(), 0);
    let mut g2 = TraceGen::new(2, WorkloadSpec::Uniform.mix(), 0);
    assert_ne!(g1.take(50), g2.take(50));
}

#[test]
fn mix_fractions_respected() {
    let mut g = TraceGen::new(11, WorkloadSpec::Graphics.mix(), 0);
    let reqs = g.take(20_000);
    let singles = reqs.iter().filter(|r| r.class == OpClass::Single).count() as f64;
    let quads = reqs.iter().filter(|r| r.class == OpClass::Quad).count() as f64;
    let n = reqs.len() as f64;
    assert!((singles / n - 0.80).abs() < 0.02, "single frac {}", singles / n);
    assert!((quads / n - 0.03).abs() < 0.01, "quad frac {}", quads / n);
}

#[test]
fn single_only_is_single_only() {
    let mut g = TraceGen::new(3, WorkloadSpec::SingleOnly.mix(), 0);
    assert!(g.take(1000).iter().all(|r| r.class == OpClass::Single));
}

#[test]
fn operands_fit_format_and_are_finite_every_class() {
    let mut g = TraceGen::new(5, WorkloadSpec::Uniform.mix(), 0);
    for r in g.take(5000) {
        // Field widths read off the registry format — the single source of
        // truth (no local (exp_bits, frac_bits) mirror).
        let fmt = r.class.format();
        let total = fmt.total_bits();
        assert!(r.a.bit_len() <= total, "operand overflows format");
        assert!(r.b.bit_len() <= total);
        // finite: biased exponent below the all-ones marker
        let emask = fmt.exp_mask() as u128;
        assert_ne!(r.a.shr(fmt.frac_bits).as_u128() & emask, emask, "operand must be finite");
        assert_ne!(r.b.shr(fmt.frac_bits).as_u128() & emask, emask);
    }
}

#[test]
fn uniform_mix_exercises_every_registry_class() {
    let mut g = TraceGen::new(19, WorkloadSpec::Uniform.mix(), 0);
    let reqs = g.take(10_000);
    for class in OpClass::ALL {
        let n = reqs.iter().filter(|r| r.class == class).count();
        assert!(n > 0, "uniform mix never produced {}", class.name());
    }
}

#[test]
fn arrivals_monotone_open_loop() {
    let mut g = TraceGen::new(9, WorkloadSpec::Scientific.mix(), 1000);
    let reqs = g.take(1000);
    for w in reqs.windows(2) {
        assert!(w[1].arrival_ns >= w[0].arrival_ns);
    }
    // mean gap in the right ballpark (within 3x)
    let span = reqs.last().unwrap().arrival_ns;
    let mean = span as f64 / reqs.len() as f64;
    assert!(mean > 300.0 && mean < 3000.0, "mean gap {mean}");
}

#[test]
fn closed_loop_all_at_zero() {
    let mut g = TraceGen::new(13, WorkloadSpec::Uniform.mix(), 0);
    assert!(g.take(100).iter().all(|r| r.arrival_ns == 0));
}

#[test]
fn mixed_spec_carries_every_class() {
    let mut g = TraceGen::new(17, WorkloadSpec::Mixed.mix(), 0);
    let reqs = g.take(20_000);
    let n = reqs.len() as f64;
    let frac = |c: OpClass| reqs.iter().filter(|r| r.class == c).count() as f64 / n;
    let mix = WorkloadSpec::Mixed.mix();
    let total = mix.total();
    for class in OpClass::ALL {
        let want = mix.weight(class) / total;
        assert!(want > 0.0, "mixed spec must carry {}", class.name());
        assert!(
            (frac(class) - want).abs() < 0.02,
            "{}: got {} want {want}",
            class.name(),
            frac(class)
        );
    }
}

#[test]
fn ml_spec_is_sub_single_dominant() {
    let mut g = TraceGen::new(23, WorkloadSpec::MlInference.mix(), 0);
    let reqs = g.take(10_000);
    let n = reqs.len() as f64;
    let sub_single = reqs
        .iter()
        .filter(|r| matches!(r.class, OpClass::Bf16 | OpClass::Half))
        .count() as f64;
    assert!(sub_single / n > 0.80, "ml mix sub-single frac {}", sub_single / n);
    assert!(reqs.iter().all(|r| r.class != OpClass::Quad && r.class != OpClass::Double));
}

#[test]
fn custom_mix_builder_routes_all_mass() {
    let mix = WorkloadMix::ZERO.with(OpClass::Half, 1.0).with(OpClass::Bf16, 3.0);
    let mut g = TraceGen::new(29, mix, 0);
    let reqs = g.take(8_000);
    let bf = reqs.iter().filter(|r| r.class == OpClass::Bf16).count() as f64;
    assert!(reqs.iter().all(|r| matches!(r.class, OpClass::Bf16 | OpClass::Half)));
    assert!((bf / reqs.len() as f64 - 0.75).abs() < 0.03);
}

#[test]
fn spec_parse_roundtrip() {
    for spec in WorkloadSpec::ALL {
        assert_eq!(WorkloadSpec::parse(spec.name()), Some(spec));
    }
    assert_eq!(WorkloadSpec::parse("nope"), None);
}
