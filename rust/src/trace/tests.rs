//! Trace generator tests: determinism, mix fidelity, operand validity.

use super::*;
use crate::decomp::Precision;

#[test]
fn deterministic_for_fixed_seed() {
    let mut g1 = TraceGen::new(7, WorkloadSpec::Graphics.mix(), 100);
    let mut g2 = TraceGen::new(7, WorkloadSpec::Graphics.mix(), 100);
    assert_eq!(g1.take(100), g2.take(100));
}

#[test]
fn different_seeds_differ() {
    let mut g1 = TraceGen::new(1, WorkloadSpec::Uniform.mix(), 0);
    let mut g2 = TraceGen::new(2, WorkloadSpec::Uniform.mix(), 0);
    assert_ne!(g1.take(50), g2.take(50));
}

#[test]
fn mix_fractions_respected() {
    let mut g = TraceGen::new(11, WorkloadSpec::Graphics.mix(), 0);
    let reqs = g.take(20_000);
    let singles = reqs.iter().filter(|r| r.precision == Precision::Single).count() as f64;
    let quads = reqs.iter().filter(|r| r.precision == Precision::Quad).count() as f64;
    let n = reqs.len() as f64;
    assert!((singles / n - 0.80).abs() < 0.02, "single frac {}", singles / n);
    assert!((quads / n - 0.03).abs() < 0.01, "quad frac {}", quads / n);
}

#[test]
fn single_only_is_single_only() {
    let mut g = TraceGen::new(3, WorkloadSpec::SingleOnly.mix(), 0);
    assert!(g.take(1000).iter().all(|r| r.precision == Precision::Single));
}

#[test]
fn operands_fit_format_and_are_finite() {
    let mut g = TraceGen::new(5, WorkloadSpec::Uniform.mix(), 0);
    for r in g.take(5000) {
        let total = match r.precision {
            Precision::Single => 32,
            Precision::Double => 64,
            Precision::Quad => 128,
        };
        if total < 128 {
            assert!(r.a < (1u128 << total), "operand overflows format");
            assert!(r.b < (1u128 << total));
        }
        // finite: biased exponent below the all-ones marker
        let (eb, fb) = match r.precision {
            Precision::Single => (8, 23),
            Precision::Double => (11, 52),
            Precision::Quad => (15, 112),
        };
        let emask = (1u128 << eb) - 1;
        assert_ne!((r.a >> fb) & emask, emask, "operand must be finite");
        assert_ne!((r.b >> fb) & emask, emask);
    }
}

#[test]
fn arrivals_monotone_open_loop() {
    let mut g = TraceGen::new(9, WorkloadSpec::Scientific.mix(), 1000);
    let reqs = g.take(1000);
    for w in reqs.windows(2) {
        assert!(w[1].arrival_ns >= w[0].arrival_ns);
    }
    // mean gap in the right ballpark (within 3x)
    let span = reqs.last().unwrap().arrival_ns;
    let mean = span as f64 / reqs.len() as f64;
    assert!(mean > 300.0 && mean < 3000.0, "mean gap {mean}");
}

#[test]
fn closed_loop_all_at_zero() {
    let mut g = TraceGen::new(13, WorkloadSpec::Uniform.mix(), 0);
    assert!(g.take(100).iter().all(|r| r.arrival_ns == 0));
}

#[test]
fn mixed_spec_carries_every_precision() {
    let mut g = TraceGen::new(17, WorkloadSpec::Mixed.mix(), 0);
    let reqs = g.take(20_000);
    let n = reqs.len() as f64;
    let frac = |p: Precision| reqs.iter().filter(|r| r.precision == p).count() as f64 / n;
    assert!((frac(Precision::Single) - 0.50).abs() < 0.02, "single {}", frac(Precision::Single));
    assert!((frac(Precision::Double) - 0.35).abs() < 0.02, "double {}", frac(Precision::Double));
    assert!((frac(Precision::Quad) - 0.15).abs() < 0.02, "quad {}", frac(Precision::Quad));
}

#[test]
fn spec_parse_roundtrip() {
    for spec in WorkloadSpec::ALL {
        assert_eq!(WorkloadSpec::parse(spec.name()), Some(spec));
    }
    assert_eq!(WorkloadSpec::parse("nope"), None);
}
