//! Workload mixes: named precision distributions modeled on the paper's
//! motivating applications.

use crate::decomp::Precision;

/// A precision mix (weights need not sum to 1; they are normalized).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkloadMix {
    /// Weight of single-precision requests.
    pub single: f64,
    /// Weight of double-precision requests.
    pub double: f64,
    /// Weight of quad-precision requests.
    pub quad: f64,
}

impl WorkloadMix {
    /// Normalize to a cumulative distribution (single, single+double).
    pub fn cdf(&self) -> (f64, f64) {
        let total = self.single + self.double + self.quad;
        assert!(total > 0.0, "workload mix has zero mass");
        ((self.single) / total, (self.single + self.double) / total)
    }

    /// Pick a precision from a uniform sample in [0, 1).
    pub fn pick(&self, u: f64) -> Precision {
        let (c1, c2) = self.cdf();
        if u < c1 {
            Precision::Single
        } else if u < c2 {
            Precision::Double
        } else {
            Precision::Quad
        }
    }
}

/// Named workload specs (the mixes used in EXPERIMENTS.md E7).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadSpec {
    /// Graphics pipeline: mostly single, occasional double for geometric
    /// predicates (Shewchuk-style escalation), rare quad fallback.
    Graphics,
    /// Scientific post-processing: double-dominant with quad refinement.
    Scientific,
    /// Stress mix: equal thirds — the worst case for a fixed-block fabric.
    Uniform,
    /// Pure single precision (the CIFM [2] setting the paper extends).
    SingleOnly,
    /// Cluster-serving mix: single-heavy with a significant quad tail —
    /// enough quad mass that precision-affinity routing matters, enough
    /// single/double that every shard stays busy. The `bench_cluster`
    /// scaling curves run this spec.
    Mixed,
}

impl WorkloadSpec {
    /// All named specs.
    pub const ALL: [WorkloadSpec; 5] = [
        WorkloadSpec::Graphics,
        WorkloadSpec::Scientific,
        WorkloadSpec::Uniform,
        WorkloadSpec::SingleOnly,
        WorkloadSpec::Mixed,
    ];

    /// The precision mix for this spec.
    pub fn mix(self) -> WorkloadMix {
        match self {
            WorkloadSpec::Graphics => WorkloadMix { single: 0.80, double: 0.17, quad: 0.03 },
            WorkloadSpec::Scientific => WorkloadMix { single: 0.10, double: 0.70, quad: 0.20 },
            WorkloadSpec::Uniform => WorkloadMix { single: 1.0, double: 1.0, quad: 1.0 },
            WorkloadSpec::SingleOnly => WorkloadMix { single: 1.0, double: 0.0, quad: 0.0 },
            WorkloadSpec::Mixed => WorkloadMix { single: 0.50, double: 0.35, quad: 0.15 },
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            WorkloadSpec::Graphics => "graphics",
            WorkloadSpec::Scientific => "scientific",
            WorkloadSpec::Uniform => "uniform",
            WorkloadSpec::SingleOnly => "single-only",
            WorkloadSpec::Mixed => "mixed",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<WorkloadSpec> {
        Self::ALL.into_iter().find(|w| w.name() == s)
    }
}
