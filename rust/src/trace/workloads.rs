//! Workload mixes: named op-class distributions modeled on the paper's
//! motivating applications, generalized over the open [`OpClass`] registry
//! (the ML-inference mixes exercise the sub-single classes).

use crate::decomp::OpClass;

/// An op-class mix: one weight per registry class (weights need not sum to
/// 1; they are normalized).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WorkloadMix {
    /// Weight per class, indexed by [`OpClass::index`].
    pub weights: [f64; OpClass::COUNT],
}

impl WorkloadMix {
    /// A mix with zero mass everywhere (build up with [`WorkloadMix::with`]).
    pub const ZERO: WorkloadMix = WorkloadMix { weights: [0.0; OpClass::COUNT] };

    /// Build from explicit `(class, weight)` pairs; unlisted classes get
    /// zero mass.
    pub fn from_pairs(pairs: &[(OpClass, f64)]) -> WorkloadMix {
        let mut mix = Self::ZERO;
        for &(class, w) in pairs {
            mix.weights[class.index()] = w;
        }
        mix
    }

    /// Builder-style single-class weight override.
    pub fn with(mut self, class: OpClass, w: f64) -> WorkloadMix {
        self.weights[class.index()] = w;
        self
    }

    /// Weight of one class.
    pub fn weight(&self, class: OpClass) -> f64 {
        self.weights[class.index()]
    }

    /// Total mass (before normalization).
    pub fn total(&self) -> f64 {
        self.weights.iter().sum()
    }

    /// Pick a class from a uniform sample in [0, 1) by walking the
    /// cumulative distribution over the registry.
    pub fn pick(&self, u: f64) -> OpClass {
        let total = self.total();
        assert!(total > 0.0, "workload mix has zero mass");
        let mut acc = 0.0;
        for class in OpClass::ALL {
            acc += self.weight(class) / total;
            if u < acc {
                return class;
            }
        }
        // Floating-point slack at u ≈ 1.0: the last class with mass.
        OpClass::ALL
            .into_iter()
            .rev()
            .find(|c| self.weight(*c) > 0.0)
            .expect("workload mix has zero mass")
    }
}

/// Named workload specs (the mixes used in EXPERIMENTS.md E7).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadSpec {
    /// Graphics pipeline: mostly single, occasional double for geometric
    /// predicates (Shewchuk-style escalation), rare quad fallback.
    Graphics,
    /// Scientific post-processing: double-dominant with quad refinement.
    Scientific,
    /// Stress mix: equal mass on every registry class — the worst case for
    /// a fixed-block fabric.
    Uniform,
    /// Pure single precision (the CIFM [2] setting the paper extends).
    SingleOnly,
    /// Cluster-serving mix: the full registry in one stream — sub-single
    /// ML traffic (half/bf16) riding alongside the paper's three classes
    /// and a wide (binary256/binary512) refinement tail, with enough
    /// quad mass that precision-affinity routing matters. The
    /// `bench_cluster` scaling curves run this spec.
    Mixed,
    /// ML inference: bf16-dominant with a binary16 side channel and a
    /// single-precision accumulation tail — the run-time multi-precision
    /// workload of the related reconfigurable-multiplier line of work.
    MlInference,
}

impl WorkloadSpec {
    /// All named specs.
    pub const ALL: [WorkloadSpec; 6] = [
        WorkloadSpec::Graphics,
        WorkloadSpec::Scientific,
        WorkloadSpec::Uniform,
        WorkloadSpec::SingleOnly,
        WorkloadSpec::Mixed,
        WorkloadSpec::MlInference,
    ];

    /// The op-class mix for this spec.
    pub fn mix(self) -> WorkloadMix {
        use OpClass::*;
        match self {
            WorkloadSpec::Graphics => {
                WorkloadMix::from_pairs(&[(Single, 0.80), (Double, 0.17), (Quad, 0.03)])
            }
            WorkloadSpec::Scientific => {
                WorkloadMix::from_pairs(&[(Single, 0.10), (Double, 0.70), (Quad, 0.20)])
            }
            WorkloadSpec::Uniform => WorkloadMix { weights: [1.0; OpClass::COUNT] },
            WorkloadSpec::SingleOnly => WorkloadMix::from_pairs(&[(Single, 1.0)]),
            WorkloadSpec::Mixed => WorkloadMix::from_pairs(&[
                (Bf16, 0.15),
                (Half, 0.10),
                (Single, 0.33),
                (Double, 0.22),
                (Quad, 0.10),
                (Fp256, 0.06),
                (Fp512, 0.04),
            ]),
            WorkloadSpec::MlInference => WorkloadMix::from_pairs(&[
                (Bf16, 0.55),
                (Half, 0.30),
                (Single, 0.15),
            ]),
        }
    }

    /// Display name.
    pub fn name(self) -> &'static str {
        match self {
            WorkloadSpec::Graphics => "graphics",
            WorkloadSpec::Scientific => "scientific",
            WorkloadSpec::Uniform => "uniform",
            WorkloadSpec::SingleOnly => "single-only",
            WorkloadSpec::Mixed => "mixed",
            WorkloadSpec::MlInference => "ml",
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Option<WorkloadSpec> {
        Self::ALL.into_iter().find(|w| w.name() == s)
    }
}
