//! Deterministic trace generation.

use super::workloads::WorkloadMix;
use crate::decomp::Precision;
use crate::proput::Rng;

/// One multiplication request in a trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceRequest {
    /// Request id (sequential).
    pub id: u64,
    /// Precision demanded by the application.
    pub precision: Precision,
    /// Packed operand A bits (low `total_bits` of the precision are valid).
    pub a: u128,
    /// Packed operand B bits.
    pub b: u128,
    /// Arrival offset in nanoseconds from trace start (open-loop arrivals,
    /// exponential inter-arrival).
    pub arrival_ns: u64,
}

/// Deterministic request generator.
pub struct TraceGen {
    rng: Rng,
    mix: WorkloadMix,
    next_id: u64,
    clock_ns: u64,
    /// Mean inter-arrival gap in ns (0 = closed-loop, all arrive at t=0).
    pub mean_gap_ns: u64,
}

impl TraceGen {
    /// New generator with a fixed seed.
    pub fn new(seed: u64, mix: WorkloadMix, mean_gap_ns: u64) -> TraceGen {
        TraceGen { rng: Rng::new(seed), mix, next_id: 0, clock_ns: 0, mean_gap_ns }
    }

    /// Generate finite operand bits for `prec` — realistic magnitudes
    /// (media-processing values cluster near 1.0; exponents within ±40 of
    /// bias) with adversarial significands.
    fn operand(&mut self, prec: Precision) -> u128 {
        let (exp_bits, frac_bits) = match prec {
            Precision::Single => (8u32, 23u32),
            Precision::Double => (11, 52),
            Precision::Quad => (15, 112),
        };
        let bias = (1u64 << (exp_bits - 1)) - 1;
        let e_span = 80u64;
        let biased = bias - e_span / 2 + self.rng.below(e_span);
        let frac = if frac_bits <= 64 {
            (self.rng.next_u64() & ((1u64 << frac_bits) - 1)) as u128
        } else {
            let hi = self.rng.next_u64() as u128 & ((1u128 << (frac_bits - 64)) - 1);
            (hi << 64) | self.rng.next_u64() as u128
        };
        let sign = (self.rng.below(2) as u128) << (exp_bits + frac_bits);
        sign | ((biased as u128) << frac_bits) | frac
    }

    /// Next request.
    pub fn next(&mut self) -> TraceRequest {
        let precision = self.mix.pick(self.rng.f64());
        let a = self.operand(precision);
        let b = self.operand(precision);
        let id = self.next_id;
        self.next_id += 1;
        if self.mean_gap_ns > 0 {
            // exponential inter-arrival (open loop)
            let u = self.rng.f64().max(1e-12);
            let gap = (-(u.ln()) * self.mean_gap_ns as f64) as u64;
            self.clock_ns += gap;
        }
        TraceRequest { id, precision, a, b, arrival_ns: self.clock_ns }
    }

    /// Generate `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<TraceRequest> {
        (0..n).map(|_| self.next()).collect()
    }
}
