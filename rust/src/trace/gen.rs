//! Deterministic trace generation.

use super::workloads::WorkloadMix;
use crate::decomp::OpClass;
use crate::proput::Rng;
use crate::wideint::PackedBits;

/// One multiplication request in a trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceRequest {
    /// Request id (sequential).
    pub id: u64,
    /// Op class demanded by the application.
    pub class: OpClass,
    /// Packed operand A bits (low `total_bits` of the class are valid —
    /// the [`PackedBits`] word carries every registry class up to
    /// binary512).
    pub a: PackedBits,
    /// Packed operand B bits.
    pub b: PackedBits,
    /// Arrival offset in nanoseconds from trace start (open-loop arrivals,
    /// exponential inter-arrival).
    pub arrival_ns: u64,
}

/// Deterministic request generator.
pub struct TraceGen {
    rng: Rng,
    mix: WorkloadMix,
    next_id: u64,
    clock_ns: u64,
    /// Mean inter-arrival gap in ns (0 = closed-loop, all arrive at t=0).
    pub mean_gap_ns: u64,
}

impl TraceGen {
    /// New generator with a fixed seed.
    pub fn new(seed: u64, mix: WorkloadMix, mean_gap_ns: u64) -> TraceGen {
        TraceGen { rng: Rng::new(seed), mix, next_id: 0, clock_ns: 0, mean_gap_ns }
    }

    /// Generate finite operand bits for `class` — realistic magnitudes
    /// (media-processing values cluster near 1.0; exponents within ±40 of
    /// bias, clamped to the format's range) with adversarial significands.
    ///
    /// Field widths come straight from the class's [`crate::fpu::FpFormat`]
    /// descriptor — the registry is the single source of truth; no
    /// per-format table is duplicated here.
    fn operand(&mut self, class: OpClass) -> PackedBits {
        let fmt = class.format();
        let (exp_bits, frac_bits) = (fmt.exp_bits, fmt.frac_bits);
        let bias = fmt.bias() as u64;
        let exp_mask = fmt.exp_mask() as u64;
        // Biased exponent window: ±40 around the bias, clamped into the
        // finite normal range [1, exp_mask - 1] (binary16's 5-bit exponent
        // spans less than the window).
        let lo = bias.saturating_sub(40).max(1);
        let hi = (bias + 40).min(exp_mask - 1);
        let biased = lo + self.rng.below(hi - lo + 1);
        // Random fraction: fill the packed word limb-wise and mask —
        // covers every fraction width in the registry (7..488 bits)
        // without per-width byte bookkeeping.
        let mut frac = PackedBits::ZERO;
        for limb in frac.limbs.iter_mut() {
            *limb = self.rng.next_u64();
        }
        let frac = frac.mask_low(frac_bits);
        let mut v = PackedBits::from_u64(biased).shl(frac_bits).or(&frac);
        if self.rng.below(2) == 1 {
            v.set_bit(exp_bits + frac_bits);
        }
        v
    }

    /// Next request.
    pub fn next(&mut self) -> TraceRequest {
        let class = self.mix.pick(self.rng.f64());
        let a = self.operand(class);
        let b = self.operand(class);
        let id = self.next_id;
        self.next_id += 1;
        if self.mean_gap_ns > 0 {
            // exponential inter-arrival (open loop)
            let u = self.rng.f64().max(1e-12);
            let gap = (-(u.ln()) * self.mean_gap_ns as f64) as u64;
            self.clock_ns += gap;
        }
        TraceRequest { id, class, a, b, arrival_ns: self.clock_ns }
    }

    /// Generate `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<TraceRequest> {
        (0..n).map(|_| self.next()).collect()
    }
}
