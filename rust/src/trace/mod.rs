//! Synthetic multimedia workload traces.
//!
//! The paper motivates CIVP with "multi-media processing applications ...
//! where required degree of accuracy depends on their inputs (single
//! precision to higher precision)". No production trace of such an
//! application exists publicly (2007-era), so this module generates
//! synthetic traces with the same structure: streams of multiplication
//! requests whose precision demand varies per request (DESIGN.md §2).

mod gen;
mod workloads;
#[cfg(test)]
mod tests;

pub use gen::{TraceGen, TraceRequest};
pub use workloads::{WorkloadMix, WorkloadSpec};
