//! Reconfigurable, self-repairing multiplier blocks — the paper's stated
//! future work (§III: "a novel design of 24x24 bit multiplier having the
//! feature of reconfigurability and self reparability at run time ... with
//! considerable dynamic power saving").
//!
//! Model: each dedicated block is built from a grid of 12x12 sub-multiplier
//! units (a 24x24 block = 2x2 grid, 24x9 = 2x1, 9x9 = 1x1 — a 9-bit port
//! occupies one 12-bit sub-unit column), plus a configurable number of
//! spare units per block.
//!
//! * **Self-repair**: a faulty sub-unit is remapped to a spare at run time;
//!   only when spares are exhausted does the whole block fall out of the
//!   fabric (degrading the schedule — more issue waves).
//! * **Reconfigurability / power gating**: when a tile uses fewer effective
//!   bits than the block's ports, the unused sub-units are power-gated, so
//!   the block burns energy proportional to the *sub-units engaged* rather
//!   than its full array — the "considerable dynamic power saving".

use super::cost::CostModel;
use super::pool::FabricConfig;
use crate::decomp::{BlockKind, Tile};
use crate::proput::Rng;
use std::collections::BTreeMap;

/// Sub-multiplier grid dimensions for a block kind (rows x cols of 12x12
/// units; a 9-bit port still occupies one 12-bit unit).
pub fn subunit_grid(kind: BlockKind) -> (u32, u32) {
    let (a, b) = kind.dims();
    (a.div_ceil(12), b.div_ceil(12))
}

/// Total sub-units in one block of `kind`.
pub fn subunits(kind: BlockKind) -> u32 {
    let (r, c) = subunit_grid(kind);
    r * c
}

/// A fabric whose blocks can fail sub-unit by sub-unit and repair
/// themselves from spares.
#[derive(Clone, Debug)]
pub struct RepairableFabric {
    /// The pristine configuration.
    pub base: FabricConfig,
    /// Spare sub-units provisioned per block instance.
    pub spares_per_block: u32,
    /// Faults absorbed so far, per block kind: (repaired, dead_blocks).
    state: BTreeMap<BlockKind, KindState>,
}

#[derive(Clone, Debug, Default)]
struct KindState {
    /// Sub-unit faults remapped onto spares (no capacity loss).
    repaired: u64,
    /// Spare budget consumed per live instance index.
    used_spares: Vec<u32>,
    /// Instances permanently lost (spares exhausted).
    dead: u32,
}

/// Outcome of one fault injection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultOutcome {
    /// Remapped to a spare; full capacity retained.
    Repaired,
    /// Spares exhausted; the block is retired from the fabric.
    BlockLost,
    /// The targeted kind has no live instances left.
    NoTarget,
}

impl RepairableFabric {
    /// Wrap a fabric with `spares_per_block` spare 12x12 units per block.
    pub fn new(base: FabricConfig, spares_per_block: u32) -> RepairableFabric {
        let mut state = BTreeMap::new();
        for (kind, n) in &base.instances {
            state.insert(
                *kind,
                KindState { repaired: 0, used_spares: vec![0; *n as usize], dead: 0 },
            );
        }
        RepairableFabric { base, spares_per_block, state }
    }

    /// Live instances of a kind after degradation.
    pub fn live(&self, kind: BlockKind) -> u32 {
        let total = self.base.count(kind);
        let dead = self.state.get(&kind).map(|s| s.dead).unwrap_or(0);
        total.saturating_sub(dead)
    }

    /// Inject one sub-unit fault into a random live instance of `kind`.
    pub fn inject_fault(&mut self, kind: BlockKind, rng: &mut Rng) -> FaultOutcome {
        let spares = self.spares_per_block;
        let Some(s) = self.state.get_mut(&kind) else { return FaultOutcome::NoTarget };
        let live: Vec<usize> = s
            .used_spares
            .iter()
            .enumerate()
            .filter(|(_, &u)| u != u32::MAX)
            .map(|(i, _)| i)
            .collect();
        if live.is_empty() {
            return FaultOutcome::NoTarget;
        }
        let idx = live[rng.below(live.len() as u64) as usize];
        if s.used_spares[idx] < spares {
            s.used_spares[idx] += 1;
            s.repaired += 1;
            FaultOutcome::Repaired
        } else {
            s.used_spares[idx] = u32::MAX; // tombstone
            s.dead += 1;
            FaultOutcome::BlockLost
        }
    }

    /// The degraded fabric as a plain config (for the scheduler).
    pub fn effective_config(&self) -> FabricConfig {
        let mut cfg = self.base.clone();
        cfg.name = format!("{}-degraded", self.base.name);
        for (kind, n) in cfg.instances.iter_mut() {
            *n = self.live(*kind);
        }
        cfg.instances.retain(|_, n| *n > 0);
        cfg
    }

    /// (repaired faults, lost blocks) per kind.
    pub fn degradation(&self) -> BTreeMap<BlockKind, (u64, u32)> {
        self.state.iter().map(|(k, s)| (*k, (s.repaired, s.dead))).collect()
    }

    /// Fraction of original block capacity still live.
    pub fn health(&self) -> f64 {
        let total: f64 = self.base.total_capacity();
        if total == 0.0 {
            return 1.0;
        }
        let live: f64 = self
            .base
            .instances
            .keys()
            .map(|k| k.capacity() as f64 * self.live(*k) as f64)
            .sum();
        live / total
    }
}

/// Dynamic energy of a tile on a *reconfigurable* block: only the
/// sub-units covering the effective bits stay powered; the rest are gated.
/// Sub-units tile the block exactly (a 24-bit port splits into 2x12, an
/// 18-bit port into 2x9, a 9-bit port is one unit), so a fully-engaged
/// block costs exactly [`CostModel::block_energy`] and a padded one costs
/// less. This is the paper's "considerable dynamic power saving"
/// quantified.
pub fn gated_tile_energy(cost: &CostModel, tile: &Tile) -> f64 {
    let (dim_a, dim_b) = {
        // orient block dims to match the tile's port assignment
        let (da, db) = tile.kind.dims();
        if tile.wa <= da && tile.wb <= db {
            (da, db)
        } else {
            (db, da)
        }
    };
    let (rows, cols) = (dim_a.div_ceil(12), dim_b.div_ceil(12));
    let (sub_a, sub_b) = (dim_a / rows, dim_b / cols); // exact: 12, 9 or block dim
    let engaged_rows = tile.eff_a.div_ceil(sub_a).min(rows);
    let engaged_cols = tile.eff_b.div_ceil(sub_b).min(cols);
    let engaged_cells = engaged_rows * sub_a * engaged_cols * sub_b;
    cost.energy_per_capacity * engaged_cells as f64 / 324.0
}

/// Total gated energy for a tile set vs the ungated (hard-wired) energy.
pub fn gating_report(cost: &CostModel, tiles: &[Tile]) -> (f64, f64) {
    let gated: f64 = tiles.iter().map(|t| gated_tile_energy(cost, t)).sum();
    let fixed: f64 = tiles.iter().map(|t| cost.block_energy(t.kind)).sum();
    (gated, fixed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{OpClass, Scheme, SchemeKind};
    use crate::fabric::{schedule_op, CostModel};

    #[test]
    fn subunit_grids() {
        assert_eq!(subunit_grid(BlockKind::M24x24), (2, 2));
        assert_eq!(subunit_grid(BlockKind::M24x9), (2, 1));
        assert_eq!(subunit_grid(BlockKind::M9x9), (1, 1));
        assert_eq!(subunit_grid(BlockKind::M18x18), (2, 2));
        assert_eq!(subunits(BlockKind::M24x24), 4);
    }

    #[test]
    fn spares_absorb_first_faults_without_degradation() {
        let mut f = RepairableFabric::new(FabricConfig::civp_default(), 2);
        let mut rng = Rng::new(1);
        // 16 instances x 2 spares = 32 faults absorbable in the best case;
        // inject a handful and require zero capacity loss.
        for _ in 0..8 {
            let out = f.inject_fault(BlockKind::M24x24, &mut rng);
            assert_ne!(out, FaultOutcome::NoTarget);
        }
        assert!(f.health() > 0.99 || f.live(BlockKind::M24x24) == 16);
    }

    #[test]
    fn exhausted_spares_lose_blocks_monotonically() {
        let mut f = RepairableFabric::new(FabricConfig::civp_default(), 1);
        let mut rng = Rng::new(2);
        let mut last_live = f.live(BlockKind::M24x24);
        let mut lost = 0;
        for _ in 0..200 {
            if f.inject_fault(BlockKind::M24x24, &mut rng) == FaultOutcome::BlockLost {
                lost += 1;
            }
            let live = f.live(BlockKind::M24x24);
            assert!(live <= last_live, "live count must be monotone");
            last_live = live;
        }
        assert!(lost > 0);
        assert_eq!(f.live(BlockKind::M24x24), 16 - lost);
        assert!(f.health() < 1.0);
    }

    #[test]
    fn zero_spares_every_fault_kills_a_block() {
        let mut f = RepairableFabric::new(FabricConfig::civp_default(), 0);
        let mut rng = Rng::new(3);
        for i in 0..4 {
            assert_eq!(f.inject_fault(BlockKind::M9x9, &mut rng), FaultOutcome::BlockLost, "{i}");
        }
        // all four 9x9s gone
        assert_eq!(f.inject_fault(BlockKind::M9x9, &mut rng), FaultOutcome::NoTarget);
        assert_eq!(f.live(BlockKind::M9x9), 0);
        assert!(f.effective_config().instances.get(&BlockKind::M9x9).is_none());
    }

    #[test]
    fn degraded_fabric_needs_more_waves() {
        let mut f = RepairableFabric::new(FabricConfig::civp_default(), 0);
        let mut rng = Rng::new(4);
        // kill half the 24x24s
        let mut killed = 0;
        while killed < 8 {
            if f.inject_fault(BlockKind::M24x24, &mut rng) == FaultOutcome::BlockLost {
                killed += 1;
            }
        }
        let cost = CostModel::default();
        let scheme = Scheme::new(SchemeKind::Civp, OpClass::Quad);
        let healthy = schedule_op(&scheme, &FabricConfig::civp_default(), &cost);
        let degraded = schedule_op(&scheme, &f.effective_config(), &cost);
        assert_eq!(healthy.initiation_interval, 1);
        assert_eq!(degraded.initiation_interval, 2, "8 of 16 24x24s -> 2 waves");
    }

    #[test]
    fn gating_saves_energy_exactly_where_padding_lives() {
        let cost = CostModel::default();
        // Single precision on CIVP: zero padding -> gating saves nothing.
        let sp = Scheme::new(SchemeKind::Civp, OpClass::Single).tiles();
        let (gated, fixed) = gating_report(&cost, &sp);
        assert!((gated - fixed).abs() < 1e-9, "fully-used block gains nothing");
        // Quad on 18x18: 13 padded tiles -> gating must save energy.
        let qp18 = Scheme::new(SchemeKind::Baseline18, OpClass::Quad).tiles();
        let (gated, fixed) = gating_report(&cost, &qp18);
        assert!(gated < fixed * 0.95, "gated {gated} vs fixed {fixed}");
        // And gated energy is never more than fixed for any scheme.
        for prec in OpClass::ALL {
            for kind in SchemeKind::ALL {
                let tiles = Scheme::new(kind, prec).tiles();
                let (g, f) = gating_report(&cost, &tiles);
                assert!(g <= f + 1e-9, "{kind:?} {prec:?}");
            }
        }
    }

    #[test]
    fn gated_energy_monotone_in_effective_bits() {
        let cost = CostModel::default();
        let mk = |eff_a, eff_b| Tile {
            i: 0,
            j: 0,
            off_a: 0,
            off_b: 0,
            wa: 24,
            wb: 24,
            eff_a,
            eff_b,
            kind: BlockKind::M24x24,
        };
        let mut last = 0.0;
        for eff in [1u32, 9, 12, 13, 24] {
            let e = gated_tile_energy(&cost, &mk(eff, eff));
            assert!(e >= last);
            last = e;
        }
        assert!((last - cost.block_energy(BlockKind::M24x24)).abs() < 1e-9);
    }
}
