//! Cycle-level FPGA DSP-block fabric simulator.
//!
//! The paper's hardware claims (block counts, "wasted computation", low
//! power) are about what happens on an FPGA's dedicated multiplier fabric.
//! No FPGA is available in this environment, so this module simulates the
//! relevant behaviour at the block level (DESIGN.md §2 substitution map):
//!
//! * [`cost`] — area / latency / dynamic-energy models per block kind,
//!   normalized so `E(18x18) = 1.0` (the paper argues *relative* power).
//! * [`pool`] — a fabric configuration: how many instances of each block
//!   kind exist (the paper's proposal is a fabric shipping `24x24`/`24x9`/
//!   `9x9`; the legacy baseline ships `18x18`/`25x18`/`9x9`).
//! * [`sched`] — list-scheduling of a multiplication's tile DAG onto the
//!   finite block instances: latency (cycles), pipelined initiation
//!   interval, energy per operation. Stream reports come in two flavors:
//!   `simulate_stream` (walks a materialized op list — the oracle) and
//!   `simulate_counts` (closed form over per-class counts, O(#classes)).
//! * [`report`] — aggregated per-run reports used by the benches.

pub mod cost;
pub mod pool;
pub mod repair;
pub mod report;
pub mod sched;
#[cfg(test)]
mod tests;

pub use cost::{adder_tree_depth, CostModel};
pub use pool::{FabricConfig, FabricKind};
pub use repair::{gated_tile_energy, gating_report, FaultOutcome, RepairableFabric};
pub use report::{FabricReport, StreamReport};
pub use sched::{schedule_op, simulate_counts, simulate_stream, FabricOp, ScheduledOp};
