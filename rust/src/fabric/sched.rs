//! Scheduling of multiplication tile-DAGs onto finite block instances.
//!
//! Each significand multiplication is a two-stage DAG: a set of independent
//! partial-product tiles (Fig. 2(b) / Fig. 4(b)) followed by a shifted-
//! accumulation adder tree. Dedicated blocks are fully pipelined (II = 1),
//! so scheduling is a counting problem: a fabric with `n_k` instances of
//! kind `k` issues at most `n_k` kind-`k` tiles per cycle.

use super::cost::CostModel;
use super::pool::FabricConfig;
use super::report::{FabricReport, StreamReport};
use crate::decomp::{OpClass, Scheme, SchemeKind};
use std::collections::BTreeMap;

/// One operation kind flowing through the fabric: a significand multiply
/// of registry class `class` under `organization`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FabricOp {
    /// Operation class of the multiply (any [`OpClass`] registry entry).
    pub class: OpClass,
    /// Partition organization executing it.
    pub organization: SchemeKind,
}

impl FabricOp {
    /// The scheme for this op kind.
    pub fn scheme(&self) -> Scheme {
        Scheme::new(self.organization, self.class)
    }
}

/// Result of scheduling one multiplication on a fabric.
#[derive(Clone, Debug)]
pub struct ScheduledOp {
    /// Cycles from issue to result (block pipeline + issue serialization +
    /// adder tree).
    pub latency_cycles: u32,
    /// Cycles between successive results of the same class when streamed
    /// (pipelined initiation interval).
    pub initiation_interval: u32,
    /// Dynamic energy of the op (blocks at full capacity + adder tree).
    pub dyn_energy: f64,
    /// Energy doing useful work (effective bits only).
    pub useful_energy: f64,
    /// Tiles issued per cycle, per kind (diagnostic).
    pub issue_waves: u32,
}

/// Schedule one multiplication described by `scheme` onto `fabric`.
///
/// Panics if the fabric lacks a block kind the scheme needs (callers use
/// [`FabricConfig::can_serve`] to route first — the coordinator refuses to
/// place CIVP ops on a legacy fabric, mirroring real synthesis).
pub fn schedule_op(scheme: &Scheme, fabric: &FabricConfig, cost: &CostModel) -> ScheduledOp {
    let tiles = scheme.tiles();
    let mut need: BTreeMap<crate::decomp::BlockKind, u32> = BTreeMap::new();
    let mut dyn_energy = 0.0;
    let mut useful = 0.0;
    for t in &tiles {
        *need.entry(t.kind).or_insert(0) += 1;
        dyn_energy += cost.block_energy(t.kind);
        useful += cost.useful_energy(t.kind, t.eff_a, t.eff_b);
    }
    // Issue waves: the kind that is most oversubscribed relative to the
    // fabric's instance count dictates how many cycles the tile set takes
    // to enter the pipelines.
    let mut waves = 1u32;
    for (kind, n) in &need {
        let avail = fabric.count(*kind);
        assert!(avail > 0, "fabric {} lacks {} blocks", fabric.name, kind.name());
        waves = waves.max(n.div_ceil(avail));
    }
    let adder = cost.adder_energy(tiles.len(), scheme.padded_bits);
    dyn_energy += adder;
    useful += adder; // the tree adds real partial products either way
    ScheduledOp {
        latency_cycles: waves - 1 + cost.unconstrained_latency(tiles.len()),
        initiation_interval: waves,
        dyn_energy,
        useful_energy: useful,
        issue_waves: waves,
    }
}

/// Simulate a stream of `ops` (a workload mix) through `fabric`, assuming
/// full pipelining and in-order issue — the steady-state model behind the
/// paper's throughput/power comparison (E7).
///
/// This walks the materialized op list (O(#ops) just to count it) and is
/// kept as the *oracle*: [`simulate_counts`] computes the same report in
/// closed form from per-class counts, and the property tests pin the two
/// bit-for-bit against each other.
pub fn simulate_stream(
    ops: &[FabricOp],
    fabric: &FabricConfig,
    cost: &CostModel,
) -> StreamReport {
    let mut per_class: BTreeMap<FabricOp, u64> = BTreeMap::new();
    for op in ops {
        *per_class.entry(*op).or_insert(0) += 1;
    }
    let mut cycles = 0u64;
    let mut dyn_energy = 0.0;
    let mut useful_energy = 0.0;
    let mut last_latency = 0u32;
    let mut per_class_reports = Vec::new();
    for (class, count) in &per_class {
        let scheme = class.scheme();
        let s = schedule_op(&scheme, fabric, cost);
        // Issue cycles for `count` pipelined ops of this class: the most
        // oversubscribed block kind gates the stream. An oversized fabric
        // (more instances than one op's tiles) issues several ops per
        // cycle, so this can be < count.
        let mut need: BTreeMap<crate::decomp::BlockKind, u64> = BTreeMap::new();
        for t in scheme.tiles() {
            *need.entry(t.kind).or_insert(0) += 1;
        }
        let mut issue = 1u64;
        for (kind, n) in &need {
            let avail = fabric.count(*kind) as u64;
            issue = issue.max((count * n).div_ceil(avail));
        }
        cycles += issue;
        last_latency = last_latency.max(s.latency_cycles);
        dyn_energy += s.dyn_energy * *count as f64;
        useful_energy += s.useful_energy * *count as f64;
        per_class_reports.push(FabricReport {
            label: format!("{}-{}", class.organization.name(), class.class.name()),
            ops: *count,
            cycles: issue + s.latency_cycles as u64,
            dyn_energy: s.dyn_energy * *count as f64,
            useful_energy: s.useful_energy * *count as f64,
            latency_cycles: s.latency_cycles,
            initiation_interval: s.initiation_interval,
        });
    }
    cycles += last_latency as u64;
    let static_energy = cost.static_energy(fabric.total_capacity(), cycles);
    StreamReport {
        fabric: fabric.name.clone(),
        total_ops: ops.len() as u64,
        cycles,
        dyn_energy,
        useful_energy,
        static_energy,
        per_class: per_class_reports,
    }
}

/// Compute the steady-state stream report in closed form from per-class
/// operation counts — O(#op-classes) time and memory, independent of how
/// many operations the counts represent.
///
/// The static tile wiring means each class needs scheduling exactly once;
/// `n` pipelined ops of a class then cost `n`-scaled energy and an issue
/// interval dictated by the most oversubscribed block kind — the same
/// analytical pipelining model [`simulate_stream`] applies per class.
/// Classes with a zero count are skipped (they never appear in a
/// materialized op stream either), and the per-class arithmetic follows
/// `simulate_stream`'s exact operation order so the two agree *bit-for-
/// bit* on every field — pinned by `simulate_counts_matches_stream_oracle`
/// in the fabric tests.
///
/// This is what [`crate::coordinator::Service::fabric_report`] runs over
/// the service's lock-free per-class counters: reporting cost no longer
/// grows with traffic.
pub fn simulate_counts(
    counts: &BTreeMap<FabricOp, u64>,
    fabric: &FabricConfig,
    cost: &CostModel,
) -> StreamReport {
    let mut total_ops = 0u64;
    let mut cycles = 0u64;
    let mut dyn_energy = 0.0;
    let mut useful_energy = 0.0;
    let mut last_latency = 0u32;
    let mut per_class_reports = Vec::new();
    for (class, &count) in counts {
        if count == 0 {
            continue;
        }
        total_ops += count;
        let scheme = class.scheme();
        let s = schedule_op(&scheme, fabric, cost);
        let mut need: BTreeMap<crate::decomp::BlockKind, u64> = BTreeMap::new();
        for t in scheme.tiles() {
            *need.entry(t.kind).or_insert(0) += 1;
        }
        let mut issue = 1u64;
        for (kind, n) in &need {
            let avail = fabric.count(*kind) as u64;
            issue = issue.max((count * n).div_ceil(avail));
        }
        cycles += issue;
        last_latency = last_latency.max(s.latency_cycles);
        dyn_energy += s.dyn_energy * count as f64;
        useful_energy += s.useful_energy * count as f64;
        per_class_reports.push(FabricReport {
            label: format!("{}-{}", class.organization.name(), class.class.name()),
            ops: count,
            cycles: issue + s.latency_cycles as u64,
            dyn_energy: s.dyn_energy * count as f64,
            useful_energy: s.useful_energy * count as f64,
            latency_cycles: s.latency_cycles,
            initiation_interval: s.initiation_interval,
        });
    }
    cycles += last_latency as u64;
    let static_energy = cost.static_energy(fabric.total_capacity(), cycles);
    StreamReport {
        fabric: fabric.name.clone(),
        total_ops,
        cycles,
        dyn_energy,
        useful_energy,
        static_energy,
        per_class: per_class_reports,
    }
}
