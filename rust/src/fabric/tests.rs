//! Fabric simulator tests: cost-model sanity, scheduling invariants, and
//! the paper's §II.C power argument reproduced quantitatively.

use super::*;
use crate::decomp::{BlockKind, OpClass, Scheme, SchemeKind};
use crate::proput::forall;

#[test]
fn adder_tree_depth_values() {
    assert_eq!(adder_tree_depth(1), 0);
    assert_eq!(adder_tree_depth(2), 1);
    assert_eq!(adder_tree_depth(4), 2);
    assert_eq!(adder_tree_depth(9), 4);
    assert_eq!(adder_tree_depth(36), 6);
    assert_eq!(adder_tree_depth(49), 6);
}

#[test]
fn block_energy_normalized_to_18x18() {
    let cm = CostModel::default();
    assert!((cm.block_energy(BlockKind::M18x18) - 1.0).abs() < 1e-12);
    assert!((cm.block_energy(BlockKind::M24x24) - 576.0 / 324.0).abs() < 1e-12);
    assert!((cm.block_energy(BlockKind::M9x9) - 81.0 / 324.0).abs() < 1e-12);
    assert!(cm.block_energy(BlockKind::M24x9) < cm.block_energy(BlockKind::M18x18));
}

#[test]
fn useful_energy_never_exceeds_block_energy() {
    let cm = CostModel::default();
    for kind in BlockKind::ALL {
        let (da, db) = kind.dims();
        for ea in [0, 1, da / 2, da] {
            for eb in [0, 1, db / 2, db] {
                assert!(cm.useful_energy(kind, ea, eb) <= cm.block_energy(kind) + 1e-12);
            }
        }
        assert!((cm.useful_energy(kind, da, db) - cm.block_energy(kind)).abs() < 1e-12);
    }
}

#[test]
fn fabric_presets() {
    let civp = FabricConfig::civp_default();
    assert_eq!(civp.count(BlockKind::M24x24), 16);
    assert_eq!(civp.count(BlockKind::M24x9), 16);
    assert_eq!(civp.count(BlockKind::M9x9), 4);
    assert_eq!(civp.count(BlockKind::M18x18), 0);

    let legacy = FabricConfig::legacy_default();
    assert_eq!(legacy.count(BlockKind::M18x18), 49);

    // Iso-area configs really are iso-area (within 1%).
    let iso = FabricConfig::legacy_iso_area(1);
    let ratio = iso.total_capacity() / civp.total_capacity();
    assert!((ratio - 1.0).abs() < 0.01, "iso-area ratio {ratio}");
}

#[test]
fn schedule_qp_single_wave_on_default_fabrics() {
    // Both default fabrics are sized for one quad multiply per wave.
    let cm = CostModel::default();
    let civp = schedule_op(
        &Scheme::new(SchemeKind::Civp, OpClass::Quad),
        &FabricConfig::civp_default(),
        &cm,
    );
    assert_eq!(civp.initiation_interval, 1);
    let legacy = schedule_op(
        &Scheme::new(SchemeKind::Baseline18, OpClass::Quad),
        &FabricConfig::legacy_default(),
        &cm,
    );
    assert_eq!(legacy.initiation_interval, 1);
    // CIVP's tree is shallower: 36 partial products vs 49.
    assert!(civp.latency_cycles <= legacy.latency_cycles);
}

#[test]
fn paper_power_claim_qp() {
    // §II.C quantified: on 18x18 fabric a quad multiply wastes a
    // substantial fraction of its dynamic block energy; CIVP wastes almost
    // none.
    let cm = CostModel::default();
    let civp = schedule_op(
        &Scheme::new(SchemeKind::Civp, OpClass::Quad),
        &FabricConfig::civp_default(),
        &cm,
    );
    let legacy = schedule_op(
        &Scheme::new(SchemeKind::Baseline18, OpClass::Quad),
        &FabricConfig::legacy_default(),
        &cm,
    );
    let civp_waste = 1.0 - civp.useful_energy / civp.dyn_energy;
    let legacy_waste = 1.0 - legacy.useful_energy / legacy.dyn_energy;
    assert!(civp_waste < 0.02, "civp qp waste {civp_waste}");
    assert!(legacy_waste > 0.10, "legacy qp waste {legacy_waste}");
    assert!(legacy_waste > 5.0 * civp_waste);
}

#[test]
fn schedule_waves_scale_with_undersized_fabric() {
    // Half-size CIVP fabric: a quad op needs 2 waves.
    let cm = CostModel::default();
    let mut fabric = FabricConfig::civp_default();
    for n in fabric.instances.values_mut() {
        *n = (*n).div_ceil(2);
    }
    let s = schedule_op(&Scheme::new(SchemeKind::Civp, OpClass::Quad), &fabric, &cm);
    assert_eq!(s.initiation_interval, 2);
}

#[test]
#[should_panic(expected = "lacks")]
fn schedule_panics_on_missing_kind() {
    let cm = CostModel::default();
    schedule_op(
        &Scheme::new(SchemeKind::Civp, OpClass::Quad),
        &FabricConfig::legacy_default(),
        &cm,
    );
}

#[test]
fn can_serve_routes_correctly() {
    let civp = FabricConfig::civp_default();
    let legacy = FabricConfig::legacy_default();
    let needs_civp = Scheme::new(SchemeKind::Civp, OpClass::Quad)
        .tiles()
        .iter()
        .map(|t| t.kind)
        .collect::<Vec<_>>();
    let needs_18 = Scheme::new(SchemeKind::Baseline18, OpClass::Quad)
        .tiles()
        .iter()
        .map(|t| t.kind)
        .collect::<Vec<_>>();
    assert!(civp.can_serve(needs_civp.iter().copied()));
    assert!(!legacy.can_serve(needs_civp.iter().copied()));
    assert!(legacy.can_serve(needs_18.iter().copied()));
    assert!(!civp.can_serve(needs_18));
}

#[test]
fn stream_throughput_monotone_in_fabric_size() {
    let cm = CostModel::default();
    let ops: Vec<FabricOp> = (0..100)
        .map(|_| FabricOp { class: OpClass::Double, organization: SchemeKind::Civp })
        .collect();
    let r1 = simulate_stream(&ops, &FabricConfig::civp_scaled(1), &cm);
    let r4 = simulate_stream(&ops, &FabricConfig::civp_scaled(4), &cm);
    assert!(r4.cycles <= r1.cycles);
    assert!(r4.throughput() >= r1.throughput());
    // Dynamic energy identical (same work), static differs.
    assert!((r4.dyn_energy - r1.dyn_energy).abs() < 1e-9);
}

#[test]
fn stream_mixed_classes_full_registry() {
    let cm = CostModel::default();
    let mut ops = Vec::new();
    for i in 0..300usize {
        let class = OpClass::from_index(i % OpClass::COUNT);
        ops.push(FabricOp { class, organization: SchemeKind::Civp });
    }
    let r = simulate_stream(&ops, &FabricConfig::civp_scaled(2), &cm);
    assert_eq!(r.total_ops, 300);
    assert_eq!(r.per_class.len(), OpClass::COUNT);
    assert!(r.cycles > 0);
    assert!(r.wasted_fraction() < 0.15);
}

#[test]
fn simulate_counts_matches_stream_oracle() {
    // The closed-form count simulator must agree *bit-for-bit* with the
    // materialized-stream oracle, over random op mixes covering all four
    // organizations and every registry class (counts 0..1000). Each fabric
    // only serves the organizations whose block kinds it ships.
    use std::collections::BTreeMap;
    let cm = CostModel::default();
    let fabric_classes: [(FabricConfig, Vec<SchemeKind>); 2] = [
        (FabricConfig::civp_scaled(1), vec![SchemeKind::Civp, SchemeKind::Baseline9]),
        (
            FabricConfig::legacy_scaled(1),
            vec![SchemeKind::Baseline18, SchemeKind::Baseline25x18, SchemeKind::Baseline9],
        ),
    ];
    forall(0x301, 50, |rng| {
        for (fabric, kinds) in &fabric_classes {
            let mut counts: BTreeMap<FabricOp, u64> = BTreeMap::new();
            let mut ops: Vec<FabricOp> = Vec::new();
            for &organization in kinds {
                for class in OpClass::ALL {
                    let n = rng.below(1000);
                    let op = FabricOp { class, organization };
                    if n > 0 {
                        counts.insert(op, n);
                        ops.extend(std::iter::repeat(op).take(n as usize));
                    } else if rng.chance(0.5) {
                        // Zero-count entries must be ignored, matching a
                        // stream in which the class never appears.
                        counts.insert(op, 0);
                    }
                }
            }
            let from_counts = simulate_counts(&counts, fabric, &cm);
            let from_stream = simulate_stream(&ops, fabric, &cm);
            assert_eq!(from_counts, from_stream, "fabric {}", fabric.name);
        }
    });
}

#[test]
fn simulate_counts_empty_is_empty() {
    let cm = CostModel::default();
    let r = simulate_counts(&std::collections::BTreeMap::new(), &FabricConfig::civp_scaled(1), &cm);
    assert_eq!(r, simulate_stream(&[], &FabricConfig::civp_scaled(1), &cm));
    assert_eq!(r.total_ops, 0);
    assert!(r.per_class.is_empty());
}

#[test]
fn stream_energy_accounting_consistent() {
    forall(0x300, 100, |rng| {
        let cm = CostModel::default();
        let n = rng.range(1, 50);
        let ops: Vec<FabricOp> = (0..n)
            .map(|_| {
                let class = OpClass::from_index(rng.below(OpClass::COUNT as u64) as usize);
                FabricOp { class, organization: SchemeKind::Civp }
            })
            .collect();
        let r = simulate_stream(&ops, &FabricConfig::civp_scaled(1), &cm);
        assert!(r.useful_energy <= r.dyn_energy + 1e-9);
        assert!(r.static_energy >= 0.0);
        let class_dyn: f64 = r.per_class.iter().map(|c| c.dyn_energy).sum();
        assert!((class_dyn - r.dyn_energy).abs() < 1e-6);
    });
}
