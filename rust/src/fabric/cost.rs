//! Area / latency / energy cost models for dedicated multiplier blocks.
//!
//! An `m×n` array multiplier has `m·n` partial-product cells; both silicon
//! area and dynamic switching energy scale with the cell count to first
//! order, which is the approximation the paper itself reasons with ("17
//! blocks ... consuming the power of 18x18 multiplication"). All constants
//! are *normalized to the 18x18 block = 1.0* so only relative comparisons —
//! the only kind the paper makes — are meaningful.
//!
//! The latency model gives each dedicated block a fixed pipeline depth
//! (dedicated FPGA multipliers are fully pipelined, initiation interval 1)
//! and charges the partial-product reduction (adder tree) `log2` levels of
//! soft-logic carry-save addition — the structure of Fig. 2(b)'s shifted
//! additions.

use crate::decomp::BlockKind;

/// Reference capacity: the 18x18 block's `324` bit-product cells.
const REF_CAPACITY: f64 = 324.0;

/// Tunable cost model. The defaults are first-order array-multiplier
/// scalings; the constructor doc-comments record the datasheet intuition.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Dynamic energy per firing of a block, per unit of normalized
    /// capacity (capacity / 324). A block always burns its full-capacity
    /// energy when it fires — this is exactly the waste the paper targets.
    pub energy_per_capacity: f64,
    /// Static (leakage) power per unit capacity per cycle, as a fraction of
    /// the dynamic per-op energy. Idle provisioned blocks still leak.
    pub static_per_capacity_cycle: f64,
    /// Soft-logic energy per accumulated output bit in the adder tree,
    /// relative to one 18x18 firing.
    pub adder_energy_per_bit: f64,
    /// Pipeline depth (cycles) of a dedicated block.
    pub block_latency: u32,
    /// Cycles per carry-save adder-tree level in soft logic.
    pub adder_level_latency: u32,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            energy_per_capacity: 1.0,
            // Leakage per cycle is small relative to an op; 0.5% of a
            // full-capacity firing per idle cycle.
            static_per_capacity_cycle: 0.005,
            // One CSA bit ≈ a full adder ≈ tiny next to a 324-cell array.
            adder_energy_per_bit: 0.002,
            block_latency: 2,
            adder_level_latency: 1,
        }
    }
}

impl CostModel {
    /// Normalized dynamic energy of one firing of `kind` (1.0 = 18x18).
    pub fn block_energy(&self, kind: BlockKind) -> f64 {
        self.energy_per_capacity * kind.capacity() as f64 / REF_CAPACITY
    }

    /// Normalized area of one instance of `kind` (1.0 = 18x18).
    pub fn block_area(&self, kind: BlockKind) -> f64 {
        kind.capacity() as f64 / REF_CAPACITY
    }

    /// Energy actually *useful* in a firing where only `eff_a x eff_b` of
    /// the array carries real data. The difference from
    /// [`Self::block_energy`] is the paper's wasted power.
    pub fn useful_energy(&self, kind: BlockKind, eff_a: u32, eff_b: u32) -> f64 {
        debug_assert!({
            let (da, db) = kind.dims();
            (eff_a <= da && eff_b <= db) || (eff_a <= db && eff_b <= da)
        });
        self.energy_per_capacity * (eff_a * eff_b) as f64 / REF_CAPACITY
    }

    /// Energy of the shifted-accumulation adder tree for `tiles` partial
    /// products of a `width`-bit multiplication: reducing `n` values needs
    /// `n - 1` two-input additions of (at most) `2*width` bits each; the
    /// tree shape affects latency, not the addition count.
    pub fn adder_energy(&self, tiles: usize, width: u32) -> f64 {
        if tiles <= 1 {
            return 0.0;
        }
        self.adder_energy_per_bit * (2 * width) as f64 * (tiles - 1) as f64
    }

    /// Static leakage of a whole fabric over `cycles`.
    pub fn static_energy(&self, total_capacity: f64, cycles: u64) -> f64 {
        self.static_per_capacity_cycle * total_capacity / REF_CAPACITY * cycles as f64
    }

    /// End-to-end latency (cycles) of one multiplication whose tiles all
    /// issue immediately: block pipeline + adder tree.
    pub fn unconstrained_latency(&self, tiles: usize) -> u32 {
        self.block_latency + self.adder_level_latency * adder_tree_depth(tiles)
    }
}

/// Carry-save adder tree depth for `n` partial products: `ceil(log2 n)`
/// (3:2 compressor trees are a constant factor shallower; `log2` keeps the
/// model simple and monotone, which is all relative comparisons need).
pub fn adder_tree_depth(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as u32
    }
}
