//! Report structures produced by the fabric simulator.

/// Per-class scheduling report.
///
/// `PartialEq` is exact (including the `f64` energy fields): the
/// count-based and stream-based simulators are required to agree
/// bit-for-bit, and the equivalence tests compare whole reports.
#[derive(Clone, Debug, PartialEq)]
pub struct FabricReport {
    /// "organization-precision" label.
    pub label: String,
    /// Operations of this class.
    pub ops: u64,
    /// Cycles consumed (issue + drain).
    pub cycles: u64,
    /// Total dynamic energy (normalized, 18x18-op = 1.0).
    pub dyn_energy: f64,
    /// Portion of the dynamic energy doing useful bit-products.
    pub useful_energy: f64,
    /// Latency of one op.
    pub latency_cycles: u32,
    /// Initiation interval when streamed.
    pub initiation_interval: u32,
}

impl FabricReport {
    /// Fraction of dynamic energy wasted on padding.
    pub fn wasted_fraction(&self) -> f64 {
        if self.dyn_energy == 0.0 {
            return 0.0;
        }
        1.0 - self.useful_energy / self.dyn_energy
    }
}

/// Whole-stream simulation report (E7 rows).
///
/// `PartialEq` is exact, including `f64` fields — see [`FabricReport`].
#[derive(Clone, Debug, PartialEq)]
pub struct StreamReport {
    /// Fabric name.
    pub fabric: String,
    /// Total ops simulated.
    pub total_ops: u64,
    /// Total cycles.
    pub cycles: u64,
    /// Total dynamic energy.
    pub dyn_energy: f64,
    /// Useful portion.
    pub useful_energy: f64,
    /// Leakage over the run.
    pub static_energy: f64,
    /// Per-class breakdown.
    pub per_class: Vec<FabricReport>,
}

impl StreamReport {
    /// Ops per cycle.
    pub fn throughput(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.total_ops as f64 / self.cycles as f64
    }
    /// Total energy (dynamic + static).
    pub fn total_energy(&self) -> f64 {
        self.dyn_energy + self.static_energy
    }
    /// Energy per op.
    pub fn energy_per_op(&self) -> f64 {
        if self.total_ops == 0 {
            return 0.0;
        }
        self.total_energy() / self.total_ops as f64
    }
    /// Fraction of dynamic energy wasted on padded ports.
    pub fn wasted_fraction(&self) -> f64 {
        if self.dyn_energy == 0.0 {
            return 0.0;
        }
        1.0 - self.useful_energy / self.dyn_energy
    }
}
