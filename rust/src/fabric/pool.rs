//! Fabric configurations: how many dedicated blocks of each kind exist.

use crate::decomp::BlockKind;
use std::collections::BTreeMap;

/// Named fabric presets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FabricKind {
    /// The paper's proposal: `24x24` + `24x9` + `9x9` blocks.
    Civp,
    /// Legacy Xilinx/Altera-style fabric: `18x18` + `25x18` + `9x9`.
    Legacy,
}

/// A concrete fabric: instance counts per block kind.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FabricConfig {
    /// Display name.
    pub name: String,
    /// Instances per kind. Kinds absent from the map do not exist in this
    /// fabric.
    pub instances: BTreeMap<BlockKind, u32>,
}

impl FabricConfig {
    /// The paper's proposed fabric, sized so one quadruple-precision
    /// multiplication issues in a single wave (Fig. 4 needs 16/16/4).
    pub fn civp_default() -> FabricConfig {
        Self::civp_scaled(1)
    }

    /// CIVP fabric with `scale` quad-multiplication "columns".
    pub fn civp_scaled(scale: u32) -> FabricConfig {
        let mut m = BTreeMap::new();
        m.insert(BlockKind::M24x24, 16 * scale);
        m.insert(BlockKind::M24x9, 16 * scale);
        m.insert(BlockKind::M9x9, 4 * scale);
        FabricConfig { name: format!("civp-x{scale}"), instances: m }
    }

    /// Legacy fabric with the *same total multiplier-array area* as
    /// [`Self::civp_scaled`] — the iso-area comparison the paper implies.
    pub fn legacy_iso_area(scale: u32) -> FabricConfig {
        // CIVP column area: 16*576 + 16*216 + 4*81 = 12996 cells.
        // One 18x18 block = 324 cells -> 40 blocks per column ≈ iso-area
        // (12960 cells, within 0.3%).
        let mut m = BTreeMap::new();
        m.insert(BlockKind::M18x18, 40 * scale);
        FabricConfig { name: format!("legacy-iso-area-x{scale}"), instances: m }
    }

    /// Legacy fabric sized so one quad multiplication issues in one wave
    /// (49 blocks), plus the 9x9s legacy fabrics ship.
    pub fn legacy_default() -> FabricConfig {
        Self::legacy_scaled(1)
    }

    /// Legacy fabric with `scale` quad columns. Ships every block kind the
    /// legacy family offers: `18x18` (49 = one quad wave), `25x18` (35 =
    /// one quad wave under the DSP48E-style tiling) and `9x9`.
    pub fn legacy_scaled(scale: u32) -> FabricConfig {
        let mut m = BTreeMap::new();
        m.insert(BlockKind::M18x18, 49 * scale);
        m.insert(BlockKind::M25x18, 35 * scale);
        m.insert(BlockKind::M9x9, 4 * scale);
        FabricConfig { name: format!("legacy-x{scale}"), instances: m }
    }

    /// Build for a named preset.
    pub fn preset(kind: FabricKind) -> FabricConfig {
        match kind {
            FabricKind::Civp => Self::civp_default(),
            FabricKind::Legacy => Self::legacy_default(),
        }
    }

    /// Instances of one kind.
    pub fn count(&self, kind: BlockKind) -> u32 {
        self.instances.get(&kind).copied().unwrap_or(0)
    }

    /// Total multiplier-array capacity (bit-product cells) provisioned.
    pub fn total_capacity(&self) -> f64 {
        self.instances.iter().map(|(k, n)| k.capacity() as f64 * *n as f64).sum()
    }

    /// Total normalized area (18x18 = 1.0).
    pub fn total_area(&self) -> f64 {
        self.total_capacity() / 324.0
    }

    /// True if the fabric has at least one instance of every kind in
    /// `needs`.
    pub fn can_serve(&self, needs: impl IntoIterator<Item = BlockKind>) -> bool {
        needs.into_iter().all(|k| self.count(k) > 0)
    }
}
