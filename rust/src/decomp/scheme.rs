//! Partition schemes: operand chunking + tile-to-block assignment.

use crate::fpu::OpClass;

/// A dedicated hardware multiplier block kind.
///
/// `M18x18`, `M25x18` and `M9x9` are the blocks shipped by Xilinx/Altera
/// fabrics at the time of the paper; `M24x24` and `M24x9` are the blocks the
/// paper proposes to replace them with.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BlockKind {
    /// 9x9 bit (kept by the proposal).
    M9x9,
    /// 18x18 bit (existing fabric, to be replaced).
    M18x18,
    /// 24x9 bit (proposed replacement for 25x18).
    M24x9,
    /// 25x18 bit (existing fabric, to be replaced).
    M25x18,
    /// 24x24 bit (proposed replacement for 18x18).
    M24x24,
}

impl BlockKind {
    /// All kinds, for iteration / reporting.
    pub const ALL: [BlockKind; 5] = [
        BlockKind::M9x9,
        BlockKind::M18x18,
        BlockKind::M24x9,
        BlockKind::M25x18,
        BlockKind::M24x24,
    ];

    /// Operand widths `(a_bits, b_bits)` with `a_bits >= b_bits`.
    pub const fn dims(self) -> (u32, u32) {
        match self {
            BlockKind::M9x9 => (9, 9),
            BlockKind::M18x18 => (18, 18),
            BlockKind::M24x9 => (24, 9),
            BlockKind::M25x18 => (25, 18),
            BlockKind::M24x24 => (24, 24),
        }
    }

    /// Capacity in bit-products (`a_bits * b_bits`) — proportional to the
    /// multiplier array's area and switching energy.
    pub const fn capacity(self) -> u32 {
        let (a, b) = self.dims();
        a * b
    }

    /// True if a `wa x wb` tile fits this block (either orientation).
    pub const fn fits(self, wa: u32, wb: u32) -> bool {
        let (da, db) = self.dims();
        (wa <= da && wb <= db) || (wa <= db && wb <= da)
    }

    /// Short display name.
    pub const fn name(self) -> &'static str {
        match self {
            BlockKind::M9x9 => "9x9",
            BlockKind::M18x18 => "18x18",
            BlockKind::M24x9 => "24x9",
            BlockKind::M25x18 => "25x18",
            BlockKind::M24x24 => "24x24",
        }
    }
}

/// Which multiplier organization a scheme models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SchemeKind {
    /// The paper's proposal: `24x24` + `24x9` + `9x9` blocks.
    Civp,
    /// Existing fabric baseline: `18x18` blocks only.
    Baseline18,
    /// DSP48E-style baseline: `25x18` blocks.
    Baseline25x18,
    /// Small-block baseline: `9x9` blocks only.
    Baseline9,
    /// Sub-quadratic wide-operand organization: the CIVP block set under a
    /// recursive Karatsuba tile planner. At or below the
    /// [`KARATSUBA_CROSSOVER`] width it is tile-for-tile identical to
    /// [`SchemeKind::Civp`]; above it the operand splits into halves and
    /// the three half-width products recurse, so the tile count grows as
    /// ~w^1.585 instead of w².
    Karatsuba24,
}

impl SchemeKind {
    /// All kinds, CIVP first.
    pub const ALL: [SchemeKind; 5] = [
        SchemeKind::Civp,
        SchemeKind::Baseline18,
        SchemeKind::Baseline25x18,
        SchemeKind::Baseline9,
        SchemeKind::Karatsuba24,
    ];

    /// Number of organizations (sizes `kind × class` flat arrays).
    pub const COUNT: usize = Self::ALL.len();

    /// Dense index into kind-indexed arrays (position in [`SchemeKind::ALL`]).
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Inverse of [`SchemeKind::index`]; `None` for out-of-range indices
    /// (the checked path wire decoding needs).
    #[inline]
    pub fn from_index(i: usize) -> Option<SchemeKind> {
        Self::ALL.get(i).copied()
    }

    /// Display name.
    pub const fn name(self) -> &'static str {
        match self {
            SchemeKind::Civp => "civp",
            SchemeKind::Baseline18 => "18x18",
            SchemeKind::Baseline25x18 => "25x18",
            SchemeKind::Baseline9 => "9x9",
            SchemeKind::Karatsuba24 => "karatsuba24",
        }
    }

    /// Inverse of [`SchemeKind::name`]: parse a display name (the TOML
    /// `fabric.scheme` value and the CLI `--schemes` entries resolve
    /// through here, so the accepted vocabulary is the registry itself).
    pub fn parse(name: &str) -> Option<SchemeKind> {
        Self::ALL.into_iter().find(|k| k.name() == name)
    }
}

/// One partial-product tile: chunk `i` of A times chunk `j` of B on a
/// dedicated block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    /// Chunk index in A (0 = least significant).
    pub i: usize,
    /// Chunk index in B.
    pub j: usize,
    /// Bit offset of the A chunk.
    pub off_a: u32,
    /// Bit offset of the B chunk.
    pub off_b: u32,
    /// Chunk width drawn from A (== block port width).
    pub wa: u32,
    /// Chunk width drawn from B.
    pub wb: u32,
    /// Bits of the A chunk that carry real operand data (rest is padding).
    pub eff_a: u32,
    /// Bits of the B chunk that carry real operand data.
    pub eff_b: u32,
    /// Block kind executing this tile.
    pub kind: BlockKind,
}

impl Tile {
    /// Fraction of the block's multiplier array doing useful work.
    pub fn utilization(&self) -> f64 {
        (self.eff_a * self.eff_b) as f64 / self.kind.capacity() as f64
    }
    /// True if any port carries padding bits (the paper's "wasted
    /// computation" criterion).
    pub fn is_padded(&self) -> bool {
        self.eff_a < self.wa || self.eff_b < self.wb
    }
    /// A tile that multiplies only padding contributes nothing at all.
    pub fn is_dead(&self) -> bool {
        self.eff_a == 0 || self.eff_b == 0
    }
}

/// A complete partition scheme for one `W x W` significand multiplication.
///
/// ```
/// use civp::decomp::{BlockKind, OpClass, Scheme, SchemeKind};
///
/// // Fig. 2: a double-precision operand (53 bits) pads to 57 = 24+24+9,
/// // so the product needs 3x3 = 9 dedicated blocks.
/// let s = Scheme::new(SchemeKind::Civp, OpClass::Double);
/// assert_eq!(s.padded_bits, 57);
/// assert_eq!(s.a_chunks, vec![24, 24, 9]);
/// let tiles = s.tiles();
/// assert_eq!(tiles.len(), 9);
/// assert_eq!(tiles.iter().filter(|t| t.kind == BlockKind::M24x24).count(), 4);
///
/// // Sub-single classes tile the small-block end of the set: a bf16
/// // product is one 9x9 firing, a binary16 product two 24x9 firings.
/// assert_eq!(Scheme::new(SchemeKind::Civp, OpClass::Bf16).tiles().len(), 1);
/// assert_eq!(Scheme::new(SchemeKind::Civp, OpClass::Half).tiles().len(), 2);
///
/// // The same blocks serve plain integer multiplication ("combined
/// // integer"): a 48-bit operand tiles two 24-bit chunks exactly.
/// let i = Scheme::for_int(SchemeKind::Civp, 48);
/// assert_eq!(i.padded_bits, 48);
/// ```
#[derive(Clone, Debug)]
pub struct Scheme {
    /// e.g. "civp-double".
    pub name: String,
    /// Organization family.
    pub kind: SchemeKind,
    /// Real operand width (significand bits actually carrying data).
    pub eff_bits: u32,
    /// Padded operand width (sum of chunk widths).
    pub padded_bits: u32,
    /// Chunk widths for operand A, least-significant first.
    pub a_chunks: Vec<u32>,
    /// Chunk widths for operand B, least-significant first.
    pub b_chunks: Vec<u32>,
    /// Block kinds available to this organization, preferred order.
    pub blocks: Vec<BlockKind>,
}

impl Scheme {
    /// Build a scheme for `kind` at operation class `class` — any entry of
    /// the open [`OpClass`] registry, sub-single formats included.
    pub fn new(kind: SchemeKind, class: OpClass) -> Scheme {
        Self::for_width(kind, class.sig_bits(), Some(class))
    }

    /// Build a scheme for an arbitrary integer operand width (the "combined
    /// integer" half of the paper: the same blocks serve plain integer
    /// multiplication).
    pub fn for_int(kind: SchemeKind, width: u32) -> Scheme {
        Self::for_width(kind, width, None)
    }

    fn for_width(kind: SchemeKind, width: u32, class: Option<OpClass>) -> Scheme {
        assert!(width >= 1 && width <= 512, "operand width out of range");
        let name = class
            .map(|c| format!("{}-{}", kind.name(), c.name()))
            .unwrap_or_else(|| format!("{}-int{width}", kind.name()));
        let (a_chunks, b_chunks, blocks) = match kind {
            SchemeKind::Civp | SchemeKind::Karatsuba24 => {
                let (a, b) = civp_chunks(width, class);
                (a, b, vec![BlockKind::M24x24, BlockKind::M24x9, BlockKind::M9x9])
            }
            SchemeKind::Baseline18 => {
                let c = uniform_chunks(width, 18);
                (c.clone(), c, vec![BlockKind::M18x18])
            }
            SchemeKind::Baseline9 => {
                let c = uniform_chunks(width, 9);
                (c.clone(), c, vec![BlockKind::M9x9])
            }
            SchemeKind::Baseline25x18 => {
                // Asymmetric: A side in 25-bit chunks, B side in 18-bit.
                (uniform_chunks(width, 25), uniform_chunks(width, 18), vec![BlockKind::M25x18])
            }
        };
        let padded_a: u32 = a_chunks.iter().sum();
        let padded_b: u32 = b_chunks.iter().sum();
        Scheme {
            name,
            kind,
            eff_bits: width,
            padded_bits: padded_a.max(padded_b),
            a_chunks,
            b_chunks,
            blocks,
        }
    }

    /// Generate the partial-product tile set.
    ///
    /// For the all-pairs organizations this is row-major over `(i, j)`:
    /// effective bits per chunk are the overlap of the chunk's bit range
    /// with `[0, eff_bits)` — operands are placed at bit 0 and padded at the
    /// most-significant end (value-preserving).
    ///
    /// For [`SchemeKind::Karatsuba24`] above the [`KARATSUBA_CROSSOVER`]
    /// the tile set is the concatenation of the recursion tree's *leaf*
    /// multiplies, each tiled as a CIVP integer multiply of the leaf width
    /// (tile offsets are leaf-local; the inter-leaf shift/add/subtract
    /// combine schedule lives in `decomp::plan`'s wide executor, not in
    /// the tile vocabulary). At or below the crossover the tree is a
    /// single leaf and the tile set is identical to [`SchemeKind::Civp`].
    pub fn tiles(&self) -> Vec<Tile> {
        if self.kind == SchemeKind::Karatsuba24 {
            let tree = karatsuba_tree(self.eff_bits);
            if matches!(tree, KaraTree::Split { .. }) {
                let mut widths = Vec::new();
                tree.leaf_widths(&mut widths);
                let mut out = Vec::new();
                for w in widths {
                    out.extend(Scheme::for_int(SchemeKind::Civp, w).tiles());
                }
                return out;
            }
        }
        let mut out = Vec::with_capacity(self.a_chunks.len() * self.b_chunks.len());
        let mut off_a = 0u32;
        for (i, &wa) in self.a_chunks.iter().enumerate() {
            let eff_a = effective_bits(off_a, wa, self.eff_bits);
            let mut off_b = 0u32;
            for (j, &wb) in self.b_chunks.iter().enumerate() {
                let eff_b = effective_bits(off_b, wb, self.eff_bits);
                let kind = self.assign_block(wa, wb);
                out.push(Tile { i, j, off_a, off_b, wa, wb, eff_a, eff_b, kind });
                off_b += wb;
            }
            off_a += wa;
        }
        out
    }

    /// Pick the preferred available block for a `wa x wb` tile.
    fn assign_block(&self, wa: u32, wb: u32) -> BlockKind {
        // Prefer the smallest-capacity block that fits — that is what a
        // synthesis tool does when mapping a partial product to DSP blocks.
        self.blocks
            .iter()
            .copied()
            .filter(|k| k.fits(wa, wb))
            .min_by_key(|k| k.capacity())
            .unwrap_or_else(|| panic!("no block in {:?} fits {wa}x{wb}", self.blocks))
    }

    /// Total number of dedicated blocks consumed by one multiplication.
    pub fn block_count(&self) -> usize {
        if self.kind == SchemeKind::Karatsuba24
            && matches!(karatsuba_tree(self.eff_bits), KaraTree::Split { .. })
        {
            // Above the crossover the DAG is no longer an a×b product:
            // count the leaf tiles.
            return self.tiles().len();
        }
        self.a_chunks.len() * self.b_chunks.len()
    }
}

/// Chunk widths `(a_chunks, b_chunks)` for the CIVP organization,
/// least-significant first.
///
/// The paper's precisions follow §II exactly:
/// * single — 24 = one `24` chunk (§II.A);
/// * double — 53 → pad to 57 = `[24, 24, 9]` (Fig. 2: A3/A2 24-bit low
///   parts, A1 9-bit high part);
/// * quad — 113 → pad to 114 = two 57-bit halves, each `[24, 24, 9]`
///   (Fig. 4 over Fig. 2).
///
/// The sub-single classes extend the same block set *downward* (§II census
/// continued below single precision):
/// * bf16 — 8 → pad to 9 = one `[9]` chunk per side: the whole significand
///   product is a single `9x9` firing;
/// * half — 11-bit operands don't fit a `9x9` and would waste a `24x24`
///   almost entirely, so the A side stays whole (`[11]`, on the 24-bit
///   port) and the B side splits `[9, 2]` across the 9-bit port: two
///   `24x9` firings, zero padding bits.
///
/// Other integer widths chunk greedily with 24s and close with a 9 where the
/// remainder allows, mirroring the same block set.
fn civp_chunks(width: u32, class: Option<OpClass>) -> (Vec<u32>, Vec<u32>) {
    match class {
        Some(OpClass::Bf16) => return (vec![9], vec![9]),
        Some(OpClass::Half) => return (vec![11], vec![9, 2]),
        Some(OpClass::Single) => return (vec![24], vec![24]),
        Some(OpClass::Double) => return (vec![24, 24, 9], vec![24, 24, 9]),
        Some(OpClass::Quad) => {
            let half = [24, 24, 9, 24, 24, 9];
            return (half.to_vec(), half.to_vec());
        }
        Some(OpClass::Fp256) => {
            // 237 = 4 × 57 + 9: four Fig.-2 groups and one closing 9 —
            // zero padding bits (13 chunks, 169 all-pairs tiles).
            let mut c = Vec::with_capacity(13);
            for _ in 0..4 {
                c.extend_from_slice(&[24, 24, 9]);
            }
            c.push(9);
            return (c.clone(), c);
        }
        Some(OpClass::Fp512) => {
            // 489 = 8 × 57 + 24 + 9 — zero padding bits (26 chunks,
            // 676 all-pairs tiles).
            let mut c = Vec::with_capacity(26);
            for _ in 0..8 {
                c.extend_from_slice(&[24, 24, 9]);
            }
            c.extend_from_slice(&[24, 9]);
            return (c.clone(), c);
        }
        None => {}
    }
    // Greedy integer chunking: as many 24s as possible, remainder served by
    // a 9 (if <= 9) or a final 24 (padded).
    let mut chunks = Vec::new();
    let mut rem = width;
    while rem > 0 {
        if rem >= 24 {
            chunks.push(24);
            rem -= 24;
        } else if rem <= 9 {
            chunks.push(9);
            rem = 0;
        } else {
            chunks.push(24); // padded final chunk
            rem = 0;
        }
    }
    (chunks.clone(), chunks)
}

/// `ceil(width / w)` chunks of width `w` (last one padded).
fn uniform_chunks(width: u32, w: u32) -> Vec<u32> {
    let n = width.div_ceil(w);
    vec![w; n as usize]
}

/// Overlap of `[off, off+w)` with `[0, eff)`.
fn effective_bits(off: u32, w: u32, eff: u32) -> u32 {
    if off >= eff {
        0
    } else {
        (eff - off).min(w)
    }
}

/// Top-level widths at or below this always take the flat all-pairs plan.
///
/// The measured crossover for the recursion: at ≤ 128 bits the operands
/// fit the `u128` scalar and lane fast paths, and the combine overhead
/// (two wide additions, two subtractions, three shifted accumulates per
/// split) outweighs the handful of tiles a split would save. *Inside* a
/// wide recursion the operands are already on the wide execution path, so
/// sub-128-bit internal nodes may still split whenever the tile estimate
/// says it pays (Fp512 recurses down to ~61-bit leaves).
pub const KARATSUBA_CROSSOVER: u32 = 128;

/// The Karatsuba recursion tree for one operand width: how
/// [`SchemeKind::Karatsuba24`] decomposes a `width × width` multiply.
///
/// A `Split { h, .. }` node computes `a = a_hi·2^h + a_lo` (same for `b`)
/// and reduces the product to three recursive multiplies:
/// `z0 = a_lo·b_lo` (the `low` child, width `h`), `z2 = a_hi·b_hi` (the
/// `high` child, width `width − h`) and
/// `z1 = (a_lo+a_hi)(b_lo+b_hi) − z2 − z0` (the `mid` child — the sums
/// carry one extra bit, so its width is `max(h, width−h) + 1`), combined
/// as `z2·2^{2h} + z1·2^h + z0`. A `Leaf` multiplies flat through the
/// CIVP all-pairs tiling of its width.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KaraTree {
    /// Flat CIVP multiply of this width.
    Leaf(u32),
    /// Three-product split at bit `h`.
    Split {
        /// Split point: low half is `[0, h)`, high half `[h, width)`.
        h: u32,
        /// `z0` subtree (width `h`).
        low: Box<KaraTree>,
        /// `z2` subtree (width `width − h`).
        high: Box<KaraTree>,
        /// `z1` subtree (width `max(h, width − h) + 1` — the operand sums).
        mid: Box<KaraTree>,
    },
}

impl KaraTree {
    /// Append every leaf width in combine order (low, high, mid).
    pub fn leaf_widths(&self, out: &mut Vec<u32>) {
        match self {
            KaraTree::Leaf(w) => out.push(*w),
            KaraTree::Split { low, high, mid, .. } => {
                low.leaf_widths(out);
                high.leaf_widths(out);
                mid.leaf_widths(out);
            }
        }
    }

    /// Number of leaf multiplies in the tree.
    pub fn leaf_count(&self) -> usize {
        match self {
            KaraTree::Leaf(_) => 1,
            KaraTree::Split { low, high, mid, .. } => {
                low.leaf_count() + high.leaf_count() + mid.leaf_count()
            }
        }
    }
}

/// Flat-plan cost estimate in tiles: the square of the greedy CIVP chunk
/// count (every chunk pair is one block firing in the all-pairs plan).
fn flat_tile_estimate(width: u32) -> u32 {
    let mut n = 0u32;
    let mut rem = width;
    while rem > 0 {
        rem = rem.saturating_sub(24);
        n += 1;
    }
    n * n
}

/// Build the Karatsuba recursion tree for a top-level operand width.
///
/// Top-level widths at or below [`KARATSUBA_CROSSOVER`] return a single
/// [`KaraTree::Leaf`] (the flat fallback). Above it, each node splits at
/// `h = width / 2` whenever the three children's flat tile estimates sum
/// below the node's own — the planner's cost model — and the children
/// recurse under the same rule.
pub fn karatsuba_tree(width: u32) -> KaraTree {
    if width <= KARATSUBA_CROSSOVER {
        return KaraTree::Leaf(width);
    }
    build_kara_node(width)
}

/// Recursive node builder: est-driven, no top-level crossover (internal
/// nodes already execute on the wide path, so sub-crossover widths may
/// split when the tile estimate pays).
fn build_kara_node(width: u32) -> KaraTree {
    let h = width / 2;
    let lw = h;
    let hw = width - h;
    let mw = lw.max(hw) + 1;
    // A split needs real halves; below ~2 chunks per side it can't pay.
    if lw < 25 {
        return KaraTree::Leaf(width);
    }
    let split_est = flat_tile_estimate(lw) + flat_tile_estimate(hw) + flat_tile_estimate(mw);
    if split_est >= flat_tile_estimate(width) {
        return KaraTree::Leaf(width);
    }
    KaraTree::Split {
        h,
        low: Box::new(build_kara_node(lw)),
        high: Box::new(build_kara_node(hw)),
        mid: Box::new(build_kara_node(mw)),
    }
}
