//! Exact tiled execution of a partition scheme, and the [`DecompMul`]
//! adapter that plugs decomposed multiplication into the IEEE pipeline.
//!
//! §Perf — two execution modes share this module's accounting and the
//! shared inner kernel `accumulate_shifted`:
//!
//! * **per-op** — [`SigMultiplier::mul_sig`] → [`Plan::execute`]: one
//!   operand pair at a time, the latency path and the bit-exactness
//!   oracle;
//! * **lane** — [`SigBatchMultiplier::mul_sig_batch`] →
//!   [`Plan::execute_lanes`]: tile-major SoA blocks with one scaled
//!   stats merge per batch, the steady-state serving path.

use super::lanes::LaneConfig;
use super::parallel::Executor;
use super::plan::{Plan, PlanCache};
use super::scheme::{BlockKind, Scheme, SchemeKind, Tile};
use crate::fpu::{OpClass, SigBatchMultiplier, SigMultiplier, WideProd, WIDE_PROD_LIMBS};
use crate::wideint::{PackedBits, Wide, U128, U256};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Accounting from executed tile multiplications.
///
/// Hot-path representation: per-kind counters are a fixed array indexed by
/// the `BlockKind` discriminant (no hashing on the multiply path — §Perf).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// Multiplications performed, indexed by `BlockKind as usize`.
    ops_by_kind: [u64; 5],
    /// Total tiles executed.
    pub tiles: u64,
    /// Tiles where a port carried padding (the paper's wasted blocks).
    pub padded_tiles: u64,
    /// Sum over tiles of `eff_a * eff_b` (useful bit-products).
    pub useful_bitops: u64,
    /// Sum over tiles of block capacity (total bit-products paid for).
    pub capacity_bitops: u64,
    /// Whole significand multiplications completed.
    pub muls: u64,
}

impl ExecStats {
    /// Aggregate utilization = useful / capacity.
    pub fn utilization(&self) -> f64 {
        if self.capacity_bitops == 0 {
            return 1.0;
        }
        self.useful_bitops as f64 / self.capacity_bitops as f64
    }

    /// Merge another stats block in.
    pub fn merge(&mut self, other: &ExecStats) {
        self.merge_scaled(other, 1);
    }

    /// Merge `other` scaled by `n` — the accounting of `n` identical
    /// multiplications in one shot. Block usage per multiply is a static
    /// property of the scheme, so a batch of `n` executions through one
    /// plan contributes exactly `n ×` the plan's per-multiply delta; this
    /// is what makes [`super::Plan::execute_batch`]'s accounting O(1) in
    /// the batch size (§Perf).
    pub fn merge_scaled(&mut self, other: &ExecStats, n: u64) {
        for i in 0..5 {
            self.ops_by_kind[i] += other.ops_by_kind[i] * n;
        }
        self.tiles += other.tiles * n;
        self.padded_tiles += other.padded_tiles * n;
        self.useful_bitops += other.useful_bitops * n;
        self.capacity_bitops += other.capacity_bitops * n;
        self.muls += other.muls * n;
    }

    /// Ops for one kind (0 if none).
    pub fn ops(&self, kind: BlockKind) -> u64 {
        self.ops_by_kind[kind as usize]
    }

    /// All non-zero per-kind counts (reporting). Returned as a `BTreeMap`
    /// so iteration order — and therefore report output and golden
    /// comparisons — is deterministic across runs.
    pub fn by_kind(&self) -> BTreeMap<BlockKind, u64> {
        BlockKind::ALL
            .into_iter()
            .filter(|k| self.ops(*k) > 0)
            .map(|k| (k, self.ops(k)))
            .collect()
    }
}

/// Execute `a × b` exactly through `scheme`, accumulating block usage into
/// `stats`. `a, b < 2^scheme.eff_bits`.
///
/// Every tile is one dedicated-block multiplication: chunk values are
/// extracted, multiplied (each chunk ≤ 25 bits, so the product fits u64) and
/// shift-accumulated — exactly the dataflow of Fig. 2(b) / Fig. 4(b).
pub fn execute(scheme: &Scheme, a: U128, b: U128, stats: &mut ExecStats) -> U256 {
    execute_tiles(&scheme.tiles(), scheme.eff_bits, a, b, stats)
}

/// Tile-level executor used by [`execute`] and by [`Plan`] compilation
/// (which runs it once to precompute the per-multiply stats delta). The
/// multiply hot path itself goes through [`Plan::execute`], which mirrors
/// this loop over pre-resolved steps.
pub fn execute_tiles(
    tiles: &[Tile],
    eff_bits: u32,
    a: U128,
    b: U128,
    stats: &mut ExecStats,
) -> U256 {
    debug_assert!(a.bit_len() <= eff_bits, "operand A wider than scheme");
    debug_assert!(b.bit_len() <= eff_bits, "operand B wider than scheme");
    tally_tiles(tiles, stats);
    let mut acc = U256::ZERO;
    for tile in tiles {
        let pa = a.extract_u64(tile.off_a, tile.wa);
        let pb = b.extract_u64(tile.off_b, tile.wb);
        let prod = (pa as u128) * (pb as u128);
        let off = tile.off_a + tile.off_b;
        accumulate_shifted(&mut acc, prod, (off / 64) as usize, off % 64);
    }
    stats.muls += 1;
    acc
}

/// Tally one firing of every tile in the set — the value-independent half
/// of [`execute_tiles`]'s accounting (everything except `muls`), also used
/// to precompute wide-plan stats deltas without running the tree.
///
/// The dedicated block always fires (it is hard-wired into the
/// partial-product array) — even when a port is all padding. That is
/// precisely the energy waste the paper argues about, so the stats count
/// it either way.
pub(crate) fn tally_tiles(tiles: &[Tile], stats: &mut ExecStats) {
    for tile in tiles {
        stats.ops_by_kind[tile.kind as usize] += 1;
        if tile.is_padded() {
            stats.padded_tiles += 1;
        }
        stats.useful_bitops += (tile.eff_a * tile.eff_b) as u64;
        stats.capacity_bitops += tile.kind.capacity() as u64;
    }
    stats.tiles += tiles.len() as u64;
}

/// Accumulate `prod << (64*limb + shift)` into `acc` without building a
/// temporary wide value: the shifted ≤50-bit product spans at most two
/// 64-bit limbs (three when the in-limb shift wraps) — add limb-wise with
/// carry. Limb-count generic: `N = 4` (`U256`) on the narrow paths,
/// `N = 16` ([`WideProd`]) in the wide-plan leaf sweeps.
///
/// The shared inner kernel of [`execute_tiles`] and [`Plan::execute`]
/// (`shift < 64`).
#[inline]
pub(crate) fn accumulate_shifted<const N: usize>(
    acc: &mut Wide<N>,
    prod: u128,
    limb: usize,
    shift: u32,
) {
    let parts = [
        (prod << shift) as u64,
        (prod >> (64 - shift).min(127)) as u64, // shift==0 -> prod>>64
        if shift == 0 { 0 } else { (prod >> (128 - shift)) as u64 },
    ];
    let mut carry = false;
    for (i, &p) in parts.iter().enumerate() {
        let idx = limb + i;
        if idx < N {
            let (v, c1) = acc.limbs[idx].overflowing_add(p);
            let (v, c2) = v.overflowing_add(carry as u64);
            acc.limbs[idx] = v;
            carry = c1 || c2;
        } else {
            debug_assert!(p == 0 && !carry, "accumulator overflow");
        }
    }
    if carry && limb + 3 < N {
        acc.limbs[limb + 3] = acc.limbs[limb + 3].wrapping_add(1);
    }
}

/// A [`SigMultiplier`] that computes significand products through a
/// partition scheme, tallying simulated FPGA block usage — drop-in for the
/// IEEE pipeline so CIVP (and baselines) run real FP multiplications.
///
/// §Perf: products execute through compiled [`Plan`]s shared process-wide
/// via [`PlanCache`] — the paper's point is precisely that the tile wiring
/// is static hardware, so re-deriving the tile DAG per multiplication
/// would be both slow and unfaithful. The adapter holds `Arc` handles in
/// one fast slot per registry class, so the hot path is an array index,
/// not a hash lookup.
#[derive(Clone, Debug)]
pub struct DecompMul {
    kind: SchemeKind,
    /// Fast slots, one per [`OpClass`] significand width
    /// (8/11/24/53/113/237/489).
    classes: [Option<Arc<Plan>>; OpClass::COUNT],
    /// Cached plans for other (integer) widths.
    plans: HashMap<u32, Arc<Plan>>,
    /// Accumulated usage across all multiplications.
    pub stats: ExecStats,
    /// Cross-check every product against the direct widening multiply
    /// (debug builds always do; this forces it in release too).
    pub verify: bool,
    /// Shared work-stealing executor: batches at or above its threshold
    /// fan out across cores ([`Executor::execute_batch`], bit-for-bit
    /// equivalent to the single-threaded lane path). `None` keeps every
    /// batch on the submitting thread.
    par: Option<Arc<Executor>>,
    /// Lane configuration (SoA block width × vector ISA) for inline
    /// batches. With an attached executor the executor's own
    /// configuration governs instead (its chunk alignment must match its
    /// width). Every configuration is bit-identical.
    lane: LaneConfig,
}

/// Fast-slot index for registry significand widths.
#[inline]
fn class_slot(width: u32) -> Option<usize> {
    OpClass::from_sig_bits(width).map(OpClass::index)
}

impl DecompMul {
    /// New adapter for the given organization.
    pub fn new(kind: SchemeKind) -> DecompMul {
        DecompMul {
            kind,
            classes: core::array::from_fn(|_| None),
            plans: HashMap::new(),
            stats: ExecStats::default(),
            verify: false,
            par: None,
            lane: LaneConfig::SCALAR,
        }
    }

    /// New adapter that re-verifies every product against the oracle.
    pub fn verified(kind: SchemeKind) -> DecompMul {
        let mut m = Self::new(kind);
        m.verify = true;
        m
    }

    /// New adapter whose batches fan out across the shared work-stealing
    /// executor (batches below the executor's threshold stay inline).
    pub fn with_executor(kind: SchemeKind, exec: Arc<Executor>) -> DecompMul {
        let mut m = Self::new(kind);
        m.par = Some(exec);
        m
    }

    /// Attach (or detach, with `None`) a shared executor.
    pub fn set_executor(&mut self, exec: Option<Arc<Executor>>) {
        self.par = exec;
    }

    /// The attached executor, if any.
    pub fn executor(&self) -> Option<&Arc<Executor>> {
        self.par.as_ref()
    }

    /// New adapter with an explicit lane configuration for inline
    /// batches (width-parameterized SoA blocks, optionally SIMD-swept).
    pub fn with_lane(kind: SchemeKind, lane: LaneConfig) -> DecompMul {
        let mut m = Self::new(kind);
        m.lane = lane;
        m
    }

    /// Set the lane configuration for inline batches.
    pub fn set_lane_config(&mut self, lane: LaneConfig) {
        self.lane = lane;
    }

    /// The lane configuration governing this adapter's batches: the
    /// attached executor's if one is attached, the inline one otherwise.
    pub fn lane_config(&self) -> LaneConfig {
        match &self.par {
            Some(exec) => exec.lane_config(),
            None => self.lane,
        }
    }

    #[inline]
    fn entry_for(&mut self, width: u32) -> &Arc<Plan> {
        let kind = self.kind;
        if let Some(slot) = class_slot(width) {
            return self.classes[slot].get_or_insert_with(|| PlanCache::get_width(kind, width));
        }
        self.plans.entry(width).or_insert_with(|| PlanCache::get_width(kind, width))
    }

    /// The shared compiled plan used for a given operand width.
    pub fn plan_for(&mut self, width: u32) -> Arc<Plan> {
        self.entry_for(width).clone()
    }

    /// The scheme used for a given operand width.
    pub fn scheme_for(&mut self, width: u32) -> &Scheme {
        self.entry_for(width).scheme()
    }

    /// Reset accumulated stats.
    pub fn reset_stats(&mut self) {
        self.stats = ExecStats::default();
    }
}

impl SigMultiplier for DecompMul {
    fn mul_sig(&mut self, a: U128, b: U128, width: u32) -> U256 {
        // Take stats out to split the borrow (ExecStats is plain counters —
        // the take is free).
        let mut stats = std::mem::take(&mut self.stats);
        let out = self.entry_for(width).execute(a, b, &mut stats);
        self.stats = stats;
        if self.verify {
            let oracle = crate::wideint::mul_u128(a, b);
            assert_eq!(out, oracle, "decomposed product mismatch (width={width})");
        } else {
            debug_assert_eq!(out, crate::wideint::mul_u128(a, b));
        }
        out
    }

    /// Wide path (widths > 128): the product runs through the cached
    /// plan's Karatsuba/naive tile tree ([`Plan::execute_wide`]) instead
    /// of the flat step table. Verified against the schoolbook limb
    /// multiply oracle exactly like the narrow path.
    fn mul_sig_wide(&mut self, a: PackedBits, b: PackedBits, width: u32) -> WideProd {
        let mut stats = std::mem::take(&mut self.stats);
        let out = self.entry_for(width).execute_wide(a, b, &mut stats);
        self.stats = stats;
        if self.verify {
            let oracle = a.mul_full::<WIDE_PROD_LIMBS>(&b);
            assert_eq!(out, oracle, "decomposed wide product mismatch (width={width})");
        } else {
            debug_assert_eq!(out, a.mul_full::<WIDE_PROD_LIMBS>(&b));
        }
        out
    }
}

impl SigBatchMultiplier for DecompMul {
    /// The lane path: the whole batch executes tile-major through the
    /// cached plan's [`Plan::execute_lanes`], with one scaled stats merge
    /// — the batch counterpart of [`SigMultiplier::mul_sig`], and
    /// bit-exact against it (pinned by `rust/tests/plan_equiv.rs`). With
    /// an attached [`Executor`], batches at or above its threshold fan
    /// out across cores — still bit-exact, outputs and stats (pinned by
    /// `rust/tests/parallel_equiv.rs`).
    fn mul_sig_batch(&mut self, a: &[U128], b: &[U128], width: u32, out: &mut Vec<U256>) {
        let mut stats = std::mem::take(&mut self.stats);
        let plan = self.entry_for(width).clone();
        match &self.par {
            Some(exec) => exec.execute_batch(&plan, a, b, &mut stats, out),
            None => plan.execute_lanes_cfg(self.lane, a, b, &mut stats, out),
        }
        self.stats = stats;
        if self.verify {
            for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
                let oracle = crate::wideint::mul_u128(x, y);
                assert_eq!(out[i], oracle, "decomposed product mismatch (width={width}, i={i})");
            }
        } else {
            debug_assert!(a
                .iter()
                .zip(b)
                .zip(out.iter())
                .all(|((&x, &y), &p)| p == crate::wideint::mul_u128(x, y)));
        }
    }

    /// Wide batch path: element-wise tree evaluation through the cached
    /// plan with one scaled stats merge ([`Plan::execute_batch_wide`]).
    /// The SoA lane engine and the work-stealing executor are narrow-word
    /// machinery (`U128` operand lanes), so wide batches stay on the
    /// submitting thread — the tree itself already amortizes per-element
    /// work into large-limb adds.
    fn mul_sig_batch_wide(
        &mut self,
        a: &[PackedBits],
        b: &[PackedBits],
        width: u32,
        out: &mut Vec<WideProd>,
    ) {
        let mut stats = std::mem::take(&mut self.stats);
        self.entry_for(width).execute_batch_wide(a, b, &mut stats, out);
        self.stats = stats;
        if self.verify {
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                let oracle = x.mul_full::<WIDE_PROD_LIMBS>(y);
                assert_eq!(
                    out[i], oracle,
                    "decomposed wide product mismatch (width={width}, i={i})"
                );
            }
        } else {
            debug_assert!(a
                .iter()
                .zip(b)
                .zip(out.iter())
                .all(|((x, y), p)| *p == x.mul_full::<WIDE_PROD_LIMBS>(y)));
        }
    }
}

#[cfg(test)]
mod slot_tests {
    use super::*;

    #[test]
    fn class_widths_use_fast_slots_not_the_map() {
        let mut m = DecompMul::new(SchemeKind::Civp);
        assert!(m.classes.iter().all(Option::is_none));
        for class in OpClass::ALL {
            let plan = m.plan_for(class.sig_bits());
            assert_eq!(plan.width(), class.sig_bits());
        }
        // Every registry width landed in the fast slots; the integer map
        // stayed empty.
        assert!(m.classes.iter().all(Option::is_some));
        assert!(m.plans.is_empty());
        // Repeat lookups reuse the slot (same shared Arc).
        let again = m.plan_for(53);
        assert!(Arc::ptr_eq(&again, m.classes[OpClass::Double.index()].as_ref().unwrap()));
    }

    #[test]
    fn integer_widths_use_only_the_map() {
        let mut m = DecompMul::new(SchemeKind::Baseline18);
        for w in [10, 40, 96] {
            let plan = m.plan_for(w);
            assert_eq!(plan.width(), w);
        }
        assert!(m.classes.iter().all(Option::is_none));
        assert_eq!(m.plans.len(), 3);
        // Cached: a repeat lookup does not grow the map.
        let _ = m.plan_for(40);
        assert_eq!(m.plans.len(), 3);
    }
}
