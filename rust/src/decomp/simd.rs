//! `core::arch` kernels for the three hot lane sweeps.
//!
//! Compiled only with the `simd` cargo feature; dispatched at run time by
//! [`super::lanes::LaneScratch::run_with`] after an
//! [`super::lanes::SimdIsa::available`] probe (AVX-512F → AVX2 on x86_64,
//! NEON on aarch64). Everything here is pinned bit-identical to the
//! scalar sweeps in `lanes.rs` by the directed tests below plus the
//! `width_equiv` property tests.
//!
//! The kernels rely on one invariant that `LanePlan::compile` *asserts*
//! rather than assumes: **every chunk is ≤ 32 bits** (the registry's
//! widest is 25, from the `25x18` legacy organization). That buys two
//! simplifications over the scalar u128 dataflow:
//!
//! * the widening multiply is an exact 32x32→64 (`mul_epu32` /
//!   `vmull_u32`): both chunk values sit in the low half of their 64-bit
//!   lane, so the single-instruction low-half product is the full
//!   product;
//! * the ≤50-bit product never reaches the scalar kernel's third limb
//!   part (`p2 = prod >> (128 - sh)` with `sh ≤ 63` is identically 0),
//!   so each step is two shifted parts plus the carry ripple.
//!
//! Layout note: operands arrive AoS (`[U128; W]`). Each block first
//! deinterleaves them into contiguous `lo`/`hi` staging rows (a scalar
//! copy), after which every sweep — chunk extraction, multiply,
//! shift/carry accumulate — is a unit-stride vector loop. The vector
//! kernels are deliberately **non-generic** (`&[u64]` slices, lane count
//! at run time): `#[target_feature]` functions stay monomorphic, and the
//! generic `run_*` drivers pass `W` through as a slice length. Each
//! kernel keeps a scalar remainder loop so any `W` is correct even
//! though the shipped widths (8/16/32) are multiples of every vector
//! width.

#![allow(dead_code)] // non-native-arch builds compile only the drivers' deps

use super::lanes::{LanePlan, LaneScratch};
use crate::wideint::{U128, U256};

/// Split AoS operands into contiguous low/high limb rows.
#[inline]
fn deinterleave<const W: usize>(ops: &[U128; W], lo: &mut [u64; W], hi: &mut [u64; W]) {
    for ((x, l), h) in ops.iter().zip(lo.iter_mut()).zip(hi.iter_mut()) {
        *l = x.limbs[0];
        *h = x.limbs[1];
    }
}

/// View the 4×W SoA accumulator as one contiguous row-major slice
/// (nested arrays have guaranteed contiguous layout).
#[inline]
fn acc_flat<const W: usize>(acc: &mut [[u64; W]; 4]) -> &mut [u64] {
    unsafe { core::slice::from_raw_parts_mut(acc.as_mut_ptr() as *mut u64, 4 * W) }
}

/// Scalar tail shared by every ISA's extraction kernel — identical math
/// to `lanes::extract_chunks`.
#[inline]
fn extract_tail(lo: &[u64], hi: &[u64], limb: u32, sh: u32, mask: u64, dst: &mut [u64], from: usize) {
    for i in from..dst.len() {
        dst[i] = if limb == 0 {
            ((lo[i] >> sh) | ((hi[i] << (63 - sh)) << 1)) & mask
        } else {
            (hi[i] >> sh) & mask
        };
    }
}

/// Scalar tail shared by every ISA's step kernel — identical math to
/// `lanes::apply_step` under the ≤32-bit chunk contract (`p2 ≡ 0`).
#[inline]
fn step_tail(acc: &mut [u64], w: usize, limb: usize, sh: u32, pa: &[u64], pb: &[u64], from: usize) {
    for i in from..w {
        let prod = pa[i].wrapping_mul(pb[i]); // exact: both < 2^32
        let p0 = prod << sh;
        let p1 = if sh == 0 { 0 } else { prod >> (64 - sh) };
        let (v, c0) = acc[limb * w + i].overflowing_add(p0);
        acc[limb * w + i] = v;
        let mut carry = c0 as u64;
        if limb + 1 < 4 {
            let r = &mut acc[(limb + 1) * w + i];
            let (v, c1) = r.overflowing_add(p1);
            let (v, c2) = v.overflowing_add(carry);
            *r = v;
            carry = (c1 as u64) + (c2 as u64);
        }
        if limb + 2 < 4 {
            let r = &mut acc[(limb + 2) * w + i];
            let (v, c) = r.overflowing_add(carry);
            *r = v;
            carry = c as u64;
        }
        if limb + 3 < 4 {
            let r = &mut acc[(limb + 3) * w + i];
            *r = r.wrapping_add(carry);
        }
    }
}

/// Extract every chunk of both operand sides through `$extract`, then
/// run the step table through `$step` — the shared driver each ISA's
/// `run_*` instantiates with its kernels. The kernels are called by path
/// (never through a function pointer — `#[target_feature]` functions
/// don't coerce to pointers), so each stays a direct unsafe call from
/// the monomorphized driver.
macro_rules! define_run {
    ($(#[$doc:meta])* $name:ident, $extract:path, $step:path) => {
        $(#[$doc])*
        pub(crate) unsafe fn $name<const W: usize>(
            s: &mut LaneScratch<W>,
            plan: &LanePlan,
            a: &[U128; W],
            b: &[U128; W],
            out: &mut Vec<U256>,
        ) {
            let (mut lo, mut hi) = ([0u64; W], [0u64; W]);
            deinterleave(a, &mut lo, &mut hi);
            for (spec, dst) in plan.a_chunks.iter().zip(s.a.iter_mut()) {
                unsafe { $extract(&lo, &hi, spec.limb, spec.shift, spec.mask, dst) };
            }
            deinterleave(b, &mut lo, &mut hi);
            for (spec, dst) in plan.b_chunks.iter().zip(s.b.iter_mut()) {
                unsafe { $extract(&lo, &hi, spec.limb, spec.shift, spec.mask, dst) };
            }
            s.acc = [[0; W]; 4];
            let acc = acc_flat(&mut s.acc);
            for step in plan.steps.iter() {
                let (ia, ib) = (step.ia as usize, step.ib as usize);
                unsafe {
                    $step(acc, W, step.limb as usize, step.shift, &s.a[ia], &s.b[ib]);
                }
            }
            s.push_products(out);
        }
    };
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::*;
    use core::arch::x86_64::*;

    /// Unsigned 64-bit per-lane `a < b`, as a 0/1 carry vector. AVX2 has
    /// no unsigned compare; biasing both sides by `i64::MIN` turns the
    /// signed compare into the unsigned one.
    #[inline(always)]
    unsafe fn ltu256(a: __m256i, b: __m256i) -> __m256i {
        unsafe {
            let sign = _mm256_set1_epi64x(i64::MIN);
            let m = _mm256_cmpgt_epi64(_mm256_xor_si256(b, sign), _mm256_xor_si256(a, sign));
            _mm256_srli_epi64(m, 63)
        }
    }

    /// AVX2 chunk-extraction sweep (4 lanes per iteration).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn extract_avx2(
        lo: &[u64],
        hi: &[u64],
        limb: u32,
        sh: u32,
        mask: u64,
        dst: &mut [u64],
    ) {
        unsafe {
            let n = dst.len();
            let vmask = _mm256_set1_epi64x(mask as i64);
            let vsh = _mm_cvtsi32_si128(sh as i32);
            let vsh63 = _mm_cvtsi32_si128(63 - sh as i32);
            let mut i = 0;
            while i + 4 <= n {
                let v = if limb == 0 {
                    let vlo = _mm256_loadu_si256(lo.as_ptr().add(i) as *const __m256i);
                    let vhi = _mm256_loadu_si256(hi.as_ptr().add(i) as *const __m256i);
                    _mm256_or_si256(
                        _mm256_srl_epi64(vlo, vsh),
                        _mm256_slli_epi64(_mm256_sll_epi64(vhi, vsh63), 1),
                    )
                } else {
                    let vhi = _mm256_loadu_si256(hi.as_ptr().add(i) as *const __m256i);
                    _mm256_srl_epi64(vhi, vsh)
                };
                let v = _mm256_and_si256(v, vmask);
                _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, v);
                i += 4;
            }
            extract_tail(lo, hi, limb, sh, mask, dst, i);
        }
    }

    /// AVX2 multiply + shift/carry accumulate sweep (4 lanes per
    /// iteration). `_mm256_srl_epi64` yields 0 for counts ≥ 64, so the
    /// `sh == 0` middle part needs no branch: `prod >> 64 = 0`, exactly
    /// the scalar value for a ≤64-bit product.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn step_avx2(
        acc: &mut [u64],
        w: usize,
        limb: usize,
        sh: u32,
        pa: &[u64],
        pb: &[u64],
    ) {
        unsafe {
            debug_assert_eq!(acc.len(), 4 * w);
            let vsh = _mm_cvtsi32_si128(sh as i32);
            let vshr = _mm_cvtsi32_si128(64 - sh as i32);
            let base = acc.as_mut_ptr();
            let mut i = 0;
            while i + 4 <= w {
                let va = _mm256_loadu_si256(pa.as_ptr().add(i) as *const __m256i);
                let vb = _mm256_loadu_si256(pb.as_ptr().add(i) as *const __m256i);
                let prod = _mm256_mul_epu32(va, vb); // exact: both < 2^32
                let p0 = _mm256_sll_epi64(prod, vsh);
                let p1 = _mm256_srl_epi64(prod, vshr);
                let r0p = base.add(limb * w + i) as *mut __m256i;
                let s0 = _mm256_add_epi64(_mm256_loadu_si256(r0p), p0);
                let mut carry = ltu256(s0, p0);
                _mm256_storeu_si256(r0p, s0);
                if limb + 1 < 4 {
                    let rp = base.add((limb + 1) * w + i) as *mut __m256i;
                    let v1 = _mm256_add_epi64(_mm256_loadu_si256(rp), p1);
                    let c1 = ltu256(v1, p1);
                    let v2 = _mm256_add_epi64(v1, carry);
                    let c2 = ltu256(v2, carry);
                    _mm256_storeu_si256(rp, v2);
                    carry = _mm256_add_epi64(c1, c2);
                }
                if limb + 2 < 4 {
                    let rp = base.add((limb + 2) * w + i) as *mut __m256i;
                    let v = _mm256_add_epi64(_mm256_loadu_si256(rp), carry);
                    let c = ltu256(v, carry);
                    _mm256_storeu_si256(rp, v);
                    carry = c;
                }
                if limb + 3 < 4 {
                    let rp = base.add((limb + 3) * w + i) as *mut __m256i;
                    _mm256_storeu_si256(rp, _mm256_add_epi64(_mm256_loadu_si256(rp), carry));
                }
                i += 4;
            }
            step_tail(acc, w, limb, sh, pa, pb, i);
        }
    }

    /// AVX-512F chunk-extraction sweep (8 lanes per iteration).
    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn extract_avx512(
        lo: &[u64],
        hi: &[u64],
        limb: u32,
        sh: u32,
        mask: u64,
        dst: &mut [u64],
    ) {
        unsafe {
            let n = dst.len();
            let vmask = _mm512_set1_epi64(mask as i64);
            let vsh = _mm_cvtsi32_si128(sh as i32);
            let vsh63 = _mm_cvtsi32_si128(63 - sh as i32);
            let mut i = 0;
            while i + 8 <= n {
                let v = if limb == 0 {
                    let vlo = _mm512_loadu_epi64(lo.as_ptr().add(i) as *const i64);
                    let vhi = _mm512_loadu_epi64(hi.as_ptr().add(i) as *const i64);
                    _mm512_or_si512(
                        _mm512_srl_epi64(vlo, vsh),
                        _mm512_slli_epi64(_mm512_sll_epi64(vhi, vsh63), 1),
                    )
                } else {
                    let vhi = _mm512_loadu_epi64(hi.as_ptr().add(i) as *const i64);
                    _mm512_srl_epi64(vhi, vsh)
                };
                let v = _mm512_and_si512(v, vmask);
                _mm512_storeu_epi64(dst.as_mut_ptr().add(i) as *mut i64, v);
                i += 8;
            }
            extract_tail(lo, hi, limb, sh, mask, dst, i);
        }
    }

    /// AVX-512F multiply + shift/carry accumulate sweep (8 lanes per
    /// iteration); carries come straight from the native unsigned
    /// compare-into-mask.
    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn step_avx512(
        acc: &mut [u64],
        w: usize,
        limb: usize,
        sh: u32,
        pa: &[u64],
        pb: &[u64],
    ) {
        unsafe {
            debug_assert_eq!(acc.len(), 4 * w);
            let vsh = _mm_cvtsi32_si128(sh as i32);
            let vshr = _mm_cvtsi32_si128(64 - sh as i32);
            let base = acc.as_mut_ptr();
            let mut i = 0;
            while i + 8 <= w {
                let va = _mm512_loadu_epi64(pa.as_ptr().add(i) as *const i64);
                let vb = _mm512_loadu_epi64(pb.as_ptr().add(i) as *const i64);
                let prod = _mm512_mul_epu32(va, vb); // exact: both < 2^32
                let p0 = _mm512_sll_epi64(prod, vsh);
                let p1 = _mm512_srl_epi64(prod, vshr);
                let r0p = base.add(limb * w + i) as *mut i64;
                let s0 = _mm512_add_epi64(_mm512_loadu_epi64(r0p), p0);
                let mut carry = _mm512_maskz_set1_epi64(_mm512_cmplt_epu64_mask(s0, p0), 1);
                _mm512_storeu_epi64(r0p, s0);
                if limb + 1 < 4 {
                    let rp = base.add((limb + 1) * w + i) as *mut i64;
                    let v1 = _mm512_add_epi64(_mm512_loadu_epi64(rp), p1);
                    let c1 = _mm512_maskz_set1_epi64(_mm512_cmplt_epu64_mask(v1, p1), 1);
                    let v2 = _mm512_add_epi64(v1, carry);
                    let c2 = _mm512_maskz_set1_epi64(_mm512_cmplt_epu64_mask(v2, carry), 1);
                    _mm512_storeu_epi64(rp, v2);
                    carry = _mm512_add_epi64(c1, c2);
                }
                if limb + 2 < 4 {
                    let rp = base.add((limb + 2) * w + i) as *mut i64;
                    let v = _mm512_add_epi64(_mm512_loadu_epi64(rp), carry);
                    let c = _mm512_maskz_set1_epi64(_mm512_cmplt_epu64_mask(v, carry), 1);
                    _mm512_storeu_epi64(rp, v);
                    carry = c;
                }
                if limb + 3 < 4 {
                    let rp = base.add((limb + 3) * w + i) as *mut i64;
                    _mm512_storeu_epi64(rp, _mm512_add_epi64(_mm512_loadu_epi64(rp), carry));
                }
                i += 8;
            }
            step_tail(acc, w, limb, sh, pa, pb, i);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::*;
    use core::arch::aarch64::*;

    /// NEON chunk-extraction sweep (2 lanes per iteration).
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn extract_neon(
        lo: &[u64],
        hi: &[u64],
        limb: u32,
        sh: u32,
        mask: u64,
        dst: &mut [u64],
    ) {
        unsafe {
            let n = dst.len();
            let vmask = vdupq_n_u64(mask);
            let vshr = vdupq_n_s64(-(sh as i64)); // negative USHL = right shift
            let vshl63 = vdupq_n_s64((63 - sh) as i64);
            let vone = vdupq_n_s64(1);
            let mut i = 0;
            while i + 2 <= n {
                let v = if limb == 0 {
                    let vlo = vld1q_u64(lo.as_ptr().add(i));
                    let vhi = vld1q_u64(hi.as_ptr().add(i));
                    vorrq_u64(
                        vshlq_u64(vlo, vshr),
                        vshlq_u64(vshlq_u64(vhi, vshl63), vone),
                    )
                } else {
                    vshlq_u64(vld1q_u64(hi.as_ptr().add(i)), vshr)
                };
                vst1q_u64(dst.as_mut_ptr().add(i), vandq_u64(v, vmask));
                i += 2;
            }
            extract_tail(lo, hi, limb, sh, mask, dst, i);
        }
    }

    /// NEON multiply + shift/carry accumulate sweep (2 lanes per
    /// iteration): `vmull_u32` over the narrowed low halves is the exact
    /// 32x32→64 product; unsigned compares give the carries directly.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn step_neon(
        acc: &mut [u64],
        w: usize,
        limb: usize,
        sh: u32,
        pa: &[u64],
        pb: &[u64],
    ) {
        unsafe {
            debug_assert_eq!(acc.len(), 4 * w);
            let vshl = vdupq_n_s64(sh as i64);
            let vshr = vdupq_n_s64(-((64 - sh) as i64));
            let base = acc.as_mut_ptr();
            let mut i = 0;
            while i + 2 <= w {
                let va = vld1q_u64(pa.as_ptr().add(i));
                let vb = vld1q_u64(pb.as_ptr().add(i));
                let prod = vmull_u32(vmovn_u64(va), vmovn_u64(vb)); // exact: both < 2^32
                let p0 = vshlq_u64(prod, vshl);
                let p1 = if sh == 0 { vdupq_n_u64(0) } else { vshlq_u64(prod, vshr) };
                let r0p = base.add(limb * w + i);
                let s0 = vaddq_u64(vld1q_u64(r0p), p0);
                let mut carry = vshrq_n_u64(vcltq_u64(s0, p0), 63);
                vst1q_u64(r0p, s0);
                if limb + 1 < 4 {
                    let rp = base.add((limb + 1) * w + i);
                    let v1 = vaddq_u64(vld1q_u64(rp), p1);
                    let c1 = vshrq_n_u64(vcltq_u64(v1, p1), 63);
                    let v2 = vaddq_u64(v1, carry);
                    let c2 = vshrq_n_u64(vcltq_u64(v2, carry), 63);
                    vst1q_u64(rp, v2);
                    carry = vaddq_u64(c1, c2);
                }
                if limb + 2 < 4 {
                    let rp = base.add((limb + 2) * w + i);
                    let v = vaddq_u64(vld1q_u64(rp), carry);
                    let c = vshrq_n_u64(vcltq_u64(v, carry), 63);
                    vst1q_u64(rp, v);
                    carry = c;
                }
                if limb + 3 < 4 {
                    let rp = base.add((limb + 3) * w + i);
                    vst1q_u64(rp, vaddq_u64(vld1q_u64(rp), carry));
                }
                i += 2;
            }
            step_tail(acc, w, limb, sh, pa, pb, i);
        }
    }
}

#[cfg(target_arch = "x86_64")]
define_run!(
    /// Full-block AVX2 path. SAFETY: caller verified AVX2 is available.
    run_avx2,
    x86::extract_avx2,
    x86::step_avx2
);

#[cfg(target_arch = "x86_64")]
define_run!(
    /// Full-block AVX-512F path. SAFETY: caller verified AVX-512F is
    /// available.
    run_avx512,
    x86::extract_avx512,
    x86::step_avx512
);

#[cfg(target_arch = "aarch64")]
define_run!(
    /// Full-block NEON path. SAFETY: NEON is baseline on aarch64.
    run_neon,
    arm::extract_neon,
    arm::step_neon
);

#[cfg(all(test, any(target_arch = "x86_64", target_arch = "aarch64")))]
mod tests {
    use super::super::lanes::{LaneScratch, SimdIsa};
    use super::super::scheme::Scheme;
    use super::super::{LanePlan, OpClass, SchemeKind};
    use crate::proput::Rng;
    use crate::wideint::{U128, U256};

    /// The ISAs this build + CPU can actually dispatch (besides scalar).
    fn dispatchable() -> Vec<SimdIsa> {
        [SimdIsa::Avx2, SimdIsa::Avx512, SimdIsa::Neon]
            .into_iter()
            .filter(|isa| isa.available())
            .collect()
    }

    fn lane_plan(class: OpClass) -> (Scheme, LanePlan) {
        let scheme = Scheme::new(SchemeKind::Civp, class);
        let tiles = scheme.tiles();
        let plan = LanePlan::compile(&scheme, &tiles);
        (scheme, plan)
    }

    fn compare_block<const W: usize>(plan: &LanePlan, a: &[U128; W], b: &[U128; W]) {
        let mut scratch = LaneScratch::<W>::new();
        let mut want: Vec<U256> = Vec::new();
        scratch.run(plan, a, b, &mut want);
        for isa in dispatchable() {
            let mut got: Vec<U256> = Vec::new();
            scratch.run_with(plan, a, b, &mut got, isa);
            assert_eq!(got, want, "isa {} diverges from scalar sweeps", isa.name());
        }
    }

    fn splat<const W: usize>(bits: u128) -> [U128; W] {
        [U128::from_u128(bits); W]
    }

    /// All-ones operands: every chunk at its max, so every step's
    /// product is maximal and the add/carry chain ripples on every lane.
    #[test]
    fn carry_chain_pattern_matches_scalar() {
        for class in OpClass::ALL {
            let (scheme, plan) = lane_plan(class);
            let ones = (1u128 << scheme.eff_bits.min(127)) - 1;
            compare_block::<8>(&plan, &splat(ones), &splat(ones));
            compare_block::<16>(&plan, &splat(ones), &splat(ones));
            compare_block::<32>(&plan, &splat(ones), &splat(ones));
        }
    }

    /// Quad operands with only the top limb populated: accumulation lands
    /// in the highest product limbs, exercising the `limb + k < 4` row
    /// clipping and the final carry ripple into limb 3.
    #[test]
    fn top_limb_overflow_pattern_matches_scalar() {
        let (scheme, plan) = lane_plan(OpClass::Quad);
        let top = ((1u128 << (scheme.eff_bits - 64)) - 1) << 64;
        compare_block::<8>(&plan, &splat(top), &splat(top));
        compare_block::<16>(&plan, &splat(top), &splat(top));
        compare_block::<32>(&plan, &splat(top), &splat(top));
    }

    /// Randomized operands per class: every dispatchable ISA at every
    /// width must match the scalar sweeps bit-for-bit.
    #[test]
    fn randomized_blocks_match_scalar() {
        let mut rng = Rng::new(0x51D_0001);
        for class in OpClass::ALL {
            let (scheme, plan) = lane_plan(class);
            let mask = if scheme.eff_bits >= 128 {
                u128::MAX
            } else {
                (1u128 << scheme.eff_bits) - 1
            };
            for _ in 0..16 {
                let mut a = [U128::ZERO; 32];
                let mut b = [U128::ZERO; 32];
                for l in 0..32 {
                    a[l] = U128::from_u128(
                        (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask,
                    );
                    b[l] = U128::from_u128(
                        (((rng.next_u64() as u128) << 64) | rng.next_u64() as u128) & mask,
                    );
                }
                compare_block::<32>(&plan, &a, &b);
                let a8: [U128; 8] = a[..8].try_into().unwrap();
                let b8: [U128; 8] = b[..8].try_into().unwrap();
                compare_block::<8>(&plan, &a8, &b8);
                let a16: [U128; 16] = a[..16].try_into().unwrap();
                let b16: [U128; 16] = b[..16].try_into().unwrap();
                compare_block::<16>(&plan, &a16, &b16);
            }
        }
    }
}
