//! The paper's contribution: partitioned (tiled) significand multiplication
//! over dedicated FPGA multiplier blocks.
//!
//! A [`Scheme`] describes how each operand of an `W x W` significand
//! multiplication is cut into chunks and which dedicated block kind computes
//! each partial-product tile. The CIVP schemes (Fig. 2 / Fig. 4 of the
//! paper) cut a padded 57-bit double-precision operand into `[24, 24, 9]`
//! and a padded 114-bit quad operand into two 57-bit halves; the baselines
//! tile with `18x18` (existing Xilinx/Altera fabric), `25x18` (DSP48E-style)
//! or `9x9` blocks. The open [`OpClass`] registry extends the same block
//! set below single precision: a bfloat16 significand product is one `9x9`
//! firing and a binary16 product is two `24x9` firings, so `Scheme::new`
//! accepts any registry class.
//!
//! [`exec::execute`] runs a scheme *exactly* (bit-for-bit) and tallies which
//! blocks fired and how full they were — the quantity all of the paper's
//! claims are about. [`exec::DecompMul`] plugs that into the IEEE pipeline
//! in [`crate::fpu`], so every decomposition is validated against hardware
//! floating point, reproducing the paper's ModelSim functional check.
//!
//! The multiply hot path does **not** re-derive the tile DAG per call: the
//! [`plan`] layer compiles each `(SchemeKind, width)` pair once into a flat
//! [`Plan`] and memoizes it process-wide in [`PlanCache`], so repeated
//! multiplications run straight over pre-resolved offsets — the software
//! analogue of the tile wiring being static hardware. Batches go further:
//! [`Plan::execute_lanes`] (the target of every batch surface, from
//! [`Plan::execute_batch`] up through [`crate::fpu::FpuBatch`] and the
//! coordinator's native backend) runs the step table **tile-major** over
//! [`lanes`] structure-of-arrays blocks, so a fixed scheme streams a whole
//! batch through one decoded datapath — the software analogue of deep
//! pipelining. Large batches go further still: the [`parallel`] module's
//! work-stealing [`Executor`] splits a batch into lane-aligned chunks and
//! fans them out across per-core workers, bit-for-bit equivalent to the
//! single-threaded path (outputs *and* merged stats — pinned by
//! `rust/tests/parallel_equiv.rs`).
//!
//! Above the 128-bit operand word (binary256 / binary512 significands) the
//! flat all-pairs tiling goes quadratic in the chunk count, and
//! [`SchemeKind::Karatsuba24`] takes over: [`karatsuba_tree`] recursively
//! halves the operand while the three-way split is cheaper than the flat
//! tiling (measured in tiles via the same census model), and each leaf is
//! tiled with the ordinary CIVP `[24, 24, 9]` vocabulary. The compiled
//! wide plan evaluates that DAG with exact wide-limb adds/subtracts — the
//! combine network costs no dedicated multiplier blocks, which is the
//! whole point: `Fp512` drops from 676 flat tiles to 243.

pub mod analysis;
pub mod exec;
pub mod lanes;
pub mod parallel;
pub mod plan;
pub mod scheme;
#[cfg(feature = "simd")]
mod simd;
#[cfg(test)]
mod tests;

pub use analysis::{scheme_census, AnalysisRow, BlockCensus};
pub use exec::{execute, DecompMul, ExecStats};
pub use lanes::{LaneBlock, LaneConfig, LanePlan, LaneScratch, LaneWidth, SimdIsa, LANES};
pub use parallel::{chunk_plan, Executor, ExecutorCounters, WorkerCounters, DEFAULT_PAR_THRESHOLD};
pub use plan::{Plan, PlanCache, PlanStep};
pub use scheme::{
    karatsuba_tree, BlockKind, KaraTree, Scheme, SchemeKind, Tile, KARATSUBA_CROSSOVER,
};

pub use crate::fpu::OpClass;
