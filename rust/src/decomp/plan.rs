//! Compiled tile plans: the decomposition hot path without the planner.
//!
//! [`Scheme::tiles`] re-derives the partial-product tile DAG — a `Vec` of
//! [`super::scheme::Tile`]s with per-tile block assignment — every time it
//! is called. That is fine for static analysis, but executing multiplies
//! through it makes every measurement an *interpreter* benchmark: each
//! operation pays the chunk-walk, the allocation and the per-tile stats
//! arithmetic, none of which exist in the hardware the paper describes
//! (the tile wiring is static).
//!
//! A [`Plan`] lowers one `(SchemeKind, width)` pair **once** into a flat,
//! allocation-free execution recipe:
//!
//! * a contiguous array of [`PlanStep`]s with pre-resolved chunk offsets /
//!   widths and pre-split accumulator limb/shift positions (no division on
//!   the execute path);
//! * a precomputed per-multiplication [`ExecStats`] delta, so executing a
//!   plan does one `merge` instead of five counter updates per tile.
//!
//! [`PlanCache`] memoizes plans process-wide, keyed by scheme × op class
//! (lock-free `OnceLock` fast slots for every `SchemeKind × OpClass`
//! registry combination, an `RwLock`ed map for arbitrary integer widths).
//! Everything that multiplies in a loop — [`super::DecompMul`], the
//! coordinator's native backend, the benches — shares the same compiled
//! plans.
//!
//! §Perf — a plan executes in one of **two modes**:
//!
//! * **per-op** ([`Plan::execute`]) — operand-major: one pair at a time
//!   through the width-specialized scalar kernel, one stats merge per
//!   call. This is the latency path and the bit-exactness oracle.
//! * **lane** ([`Plan::execute_lanes`], reached by every batch surface
//!   through [`Plan::execute_batch`]) — tile-major over [`super::lanes`]
//!   SoA blocks: each step's constants are decoded once per block of
//!   [`super::lanes::LANES`] operands and applied with branch-free,
//!   auto-vectorizable lane sweeps; the whole batch is accounted with a
//!   single scaled stats merge. This is the throughput path the serving
//!   stack runs in steady state.

use super::exec::{accumulate_shifted, execute_tiles, tally_tiles, ExecStats};
use super::lanes::{LaneConfig, LanePlan, LaneScratch, LaneWidth, SimdIsa};
use super::scheme::{karatsuba_tree, KaraTree, Scheme, SchemeKind, Tile};
use crate::fpu::{OpClass, WideProd};
use crate::wideint::{PackedBits, U128, U256};
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

/// One pre-resolved partial-product step of a [`Plan`].
///
/// Compared to [`super::scheme::Tile`] this carries only what the execute
/// loop reads, with the accumulator position pre-split into a limb index
/// and an in-limb shift.
#[derive(Clone, Copy, Debug)]
pub struct PlanStep {
    /// Bit offset of the A chunk.
    pub off_a: u32,
    /// Chunk width drawn from A.
    pub wa: u32,
    /// Bit offset of the B chunk.
    pub off_b: u32,
    /// Chunk width drawn from B.
    pub wb: u32,
    /// Accumulator limb index of `off_a + off_b`.
    pub limb: u32,
    /// In-limb bit shift of `off_a + off_b`.
    pub shift: u32,
    /// Precomputed `wa`-bit mask (`(1 << wa) - 1`).
    pub mask_a: u64,
    /// Precomputed `wb`-bit mask.
    pub mask_b: u64,
}

/// Width-specialized execute loop, selected once at plan-compile time.
///
/// The generic loop calls [`U128::extract_u64`] per chunk, which pays a
/// limb-index computation and a cross-limb splice that narrow schemes never
/// need. The paper's IEEE partitions are narrow: every single-precision
/// organization and most double-precision ones keep both operands entirely
/// inside limb 0 (padded widths ≤ 64), and CIVP single precision is one
/// full-width block firing. The kernel is a static property of the step
/// table, so it is picked in [`Plan::compile`], not per multiply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kernel {
    /// Exactly one step at offset `(0, 0)` — the whole product is a single
    /// dedicated-block firing (CIVP single precision).
    Mono,
    /// Every chunk of both operands lies within bit range `[0, 64)`: read
    /// `limbs[0]` once per operand and shift/mask per step.
    Limb0,
    /// Arbitrary widths (quad, `25x18` double, wide integer schemes).
    Generic,
}

/// A compiled, allocation-free execution plan for one scheme.
///
/// Built once by [`Plan::compile`] (usually through [`PlanCache`]), then
/// executed any number of times with [`Plan::execute`]. Execution is
/// bit-identical to [`super::execute`] over the same scheme — the property
/// tests in `tests/plan_equiv.rs` pin this against `DirectMul` for every
/// scheme × precision pair.
///
/// ```
/// use civp::decomp::{ExecStats, OpClass, PlanCache, SchemeKind};
/// use civp::wideint::U128;
///
/// let plan = PlanCache::get(SchemeKind::Civp, OpClass::Double);
/// let mut stats = ExecStats::default();
/// let product = plan.execute(U128::from_u64(3), U128::from_u64(5), &mut stats);
/// assert_eq!(product.as_u64(), 15);
/// assert_eq!(stats.muls, 1);
/// assert_eq!(stats.tiles, 9); // Fig. 2(b): nine blocks per DP multiply
/// ```
#[derive(Clone, Debug)]
pub struct Plan {
    scheme: Scheme,
    steps: Box<[PlanStep]>,
    per_mul: ExecStats,
    kernel: Kernel,
    /// Tile-major SoA lowering of the same step table (see
    /// [`super::lanes`]); compiled once, used by [`Plan::execute_lanes`].
    /// `None` for wide plans — operands past 128 bits have no SoA lane
    /// path; their batch parallelism lives in the tile DAG itself.
    lanes: Option<LanePlan>,
    /// Wide execution recipe (operands as [`PackedBits`], products as
    /// [`WideProd`]): the compiled Karatsuba combine tree, or a single
    /// flat leaf for the all-pairs organizations. `Some` exactly when
    /// `scheme.eff_bits > 128`.
    wide: Option<WidePlan>,
}

impl Plan {
    /// Lower a scheme into a flat plan. This is the only place the tile
    /// DAG is walked; every subsequent [`Plan::execute`] runs straight over
    /// the step array.
    pub fn compile(scheme: Scheme) -> Plan {
        if scheme.eff_bits > 128 {
            // Wide plan: no U128 step table, no lane lowering — execution
            // goes through the compiled wide node tree. The stats delta is
            // value-independent, tallied straight off the leaf tile sets.
            let mut per_mul = ExecStats::default();
            let wide = WidePlan::compile(&scheme, &mut per_mul);
            per_mul.muls = 1;
            return Plan {
                scheme,
                steps: Box::new([]),
                per_mul,
                kernel: Kernel::Generic,
                lanes: None,
                wide: Some(wide),
            };
        }
        let tiles = scheme.tiles();
        // One multiplication's worth of accounting. The stats a tile set
        // produces do not depend on operand values, so running the tile
        // executor once on zeros yields the exact per-multiply delta
        // (including `muls = 1`).
        let mut per_mul = ExecStats::default();
        let _ = execute_tiles(&tiles, scheme.eff_bits, U128::ZERO, U128::ZERO, &mut per_mul);
        let steps: Vec<PlanStep> = tiles
            .iter()
            .map(|t| {
                let off = t.off_a + t.off_b;
                PlanStep {
                    off_a: t.off_a,
                    wa: t.wa,
                    off_b: t.off_b,
                    wb: t.wb,
                    limb: off / 64,
                    shift: off % 64,
                    mask_a: low_mask(t.wa),
                    mask_b: low_mask(t.wb),
                }
            })
            .collect();
        let kernel = if steps.len() == 1 && steps[0].off_a == 0 && steps[0].off_b == 0 {
            Kernel::Mono
        } else if steps.iter().all(|s| s.off_a + s.wa <= 64 && s.off_b + s.wb <= 64) {
            Kernel::Limb0
        } else {
            Kernel::Generic
        };
        let lanes = Some(LanePlan::compile(&scheme, &tiles));
        Plan { scheme, steps: steps.into_boxed_slice(), per_mul, kernel, lanes, wide: None }
    }

    /// The scheme this plan was compiled from.
    pub fn scheme(&self) -> &Scheme {
        &self.scheme
    }

    /// Organization family.
    pub fn kind(&self) -> SchemeKind {
        self.scheme.kind
    }

    /// Real operand width in bits.
    pub fn width(&self) -> u32 {
        self.scheme.eff_bits
    }

    /// The compiled steps (one per dedicated-block firing).
    pub fn steps(&self) -> &[PlanStep] {
        &self.steps
    }

    /// The precomputed stats delta one execution contributes.
    pub fn per_mul_stats(&self) -> &ExecStats {
        &self.per_mul
    }

    /// Execute `a × b` exactly through the compiled plan, accumulating
    /// block usage into `stats`. `a, b < 2^self.width()`.
    ///
    /// Identical dataflow to [`super::exec::execute_tiles`] — each step is
    /// one dedicated-block multiplication, shift-accumulated limb-wise —
    /// but with no tile vector, no per-step stats arithmetic, no offset
    /// division, and a width-specialized inner loop (see `Kernel`).
    pub fn execute(&self, a: U128, b: U128, stats: &mut ExecStats) -> U256 {
        let acc = self.product(a, b);
        stats.merge(&self.per_mul);
        acc
    }

    /// The raw product through the compiled steps — the shared inner body
    /// of [`Plan::execute`] and [`Plan::execute_batch`], with the kernel
    /// dispatch resolved from the compile-time classification.
    #[inline]
    fn product(&self, a: U128, b: U128) -> U256 {
        debug_assert!(self.wide.is_none(), "wide plan: use execute_wide");
        debug_assert!(a.bit_len() <= self.scheme.eff_bits, "operand A wider than plan");
        debug_assert!(b.bit_len() <= self.scheme.eff_bits, "operand B wider than plan");
        match self.kernel {
            Kernel::Mono => {
                // One full-width firing: chunk 0 is the whole operand.
                let step = &self.steps[0];
                let prod = ((a.limbs[0] & step.mask_a) as u128)
                    * ((b.limbs[0] & step.mask_b) as u128);
                U256::from_u128(prod)
            }
            Kernel::Limb0 => {
                // All chunks live in limb 0: one limb read per operand,
                // then shift/mask per step — no cross-limb extraction.
                let a0 = a.limbs[0];
                let b0 = b.limbs[0];
                let mut acc = U256::ZERO;
                for step in self.steps.iter() {
                    let pa = (a0 >> step.off_a) & step.mask_a;
                    let pb = (b0 >> step.off_b) & step.mask_b;
                    let prod = (pa as u128) * (pb as u128);
                    accumulate_shifted(&mut acc, prod, step.limb as usize, step.shift);
                }
                acc
            }
            Kernel::Generic => {
                let mut acc = U256::ZERO;
                for step in self.steps.iter() {
                    let pa = a.extract_u64(step.off_a, step.wa);
                    let pb = b.extract_u64(step.off_b, step.wb);
                    let prod = (pa as u128) * (pb as u128);
                    accumulate_shifted(&mut acc, prod, step.limb as usize, step.shift);
                }
                acc
            }
        }
    }

    /// Execute a whole batch of raw significand products through the
    /// plan, appending them to `out` (cleared first).
    ///
    /// §Perf: this is the lane path — it forwards to
    /// [`Plan::execute_lanes`], so steady-state batch serving runs the
    /// tile-major SoA kernels end-to-end. The per-op mode
    /// ([`Plan::execute`] in a loop) remains available as the
    /// bit-exactness oracle; `rust/tests/plan_equiv.rs` pins the two
    /// modes against each other for every scheme kind and width. The
    /// multi-core counterpart is
    /// [`Executor::execute_batch`](super::parallel::Executor::execute_batch),
    /// which splits large batches into lane-aligned chunks across a
    /// work-stealing worker pool — bit-for-bit equivalent to this method,
    /// stats included (`rust/tests/parallel_equiv.rs`).
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    pub fn execute_batch(
        &self,
        a: &[U128],
        b: &[U128],
        stats: &mut ExecStats,
        out: &mut Vec<U256>,
    ) {
        self.execute_lanes(a, b, stats, out);
    }

    /// [`Plan::execute_batch`] under an explicit lane configuration
    /// (block width × vector ISA); the plain method is this with
    /// [`LaneConfig::SCALAR`].
    pub fn execute_batch_cfg(
        &self,
        cfg: LaneConfig,
        a: &[U128],
        b: &[U128],
        stats: &mut ExecStats,
        out: &mut Vec<U256>,
    ) {
        self.execute_lanes_cfg(cfg, a, b, stats, out);
    }

    /// Tile-major, lane-fused batch execution (§Perf): process the batch
    /// in [`super::lanes::LANES`]-wide SoA blocks (the scalar default
    /// configuration; see [`Plan::execute_lanes_cfg`] for the
    /// width/ISA-parameterized form), looping **tiles outer, lanes inner**
    /// — each compiled step's offsets/widths/masks are decoded once and
    /// applied across the whole block with branch-free inner loops (see
    /// [`super::lanes`]). The ragged tail shorter than a block runs
    /// through the scalar per-op kernel. Zero allocations beyond `out`'s
    /// (reusable) capacity, and the batch's accounting is one scaled
    /// merge of the precomputed per-multiply delta — O(1) in the batch
    /// size.
    ///
    /// Bit-identical to `a.len()` calls of [`Plan::execute`] (the per-op
    /// oracle), including the accumulated stats.
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    pub fn execute_lanes(
        &self,
        a: &[U128],
        b: &[U128],
        stats: &mut ExecStats,
        out: &mut Vec<U256>,
    ) {
        self.execute_lanes_cfg(LaneConfig::SCALAR, a, b, stats, out);
    }

    /// [`Plan::execute_lanes`] under an explicit lane configuration: the
    /// SoA block width (`W ∈ {8, 16, 32}`, monomorphized per
    /// [`LaneWidth`]) and the vector ISA backing the hot sweeps. Every
    /// combination is bit-identical to the scalar `W = 8` path —
    /// including the accumulated stats — pinned by the `width_equiv`
    /// property tests; an ISA the build/CPU cannot dispatch falls back
    /// to the scalar sweeps at the selected width.
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    pub fn execute_lanes_cfg(
        &self,
        cfg: LaneConfig,
        a: &[U128],
        b: &[U128],
        stats: &mut ExecStats,
        out: &mut Vec<U256>,
    ) {
        match cfg.width {
            LaneWidth::W8 => self.execute_lanes_w::<8>(cfg.isa, a, b, stats, out),
            LaneWidth::W16 => self.execute_lanes_w::<16>(cfg.isa, a, b, stats, out),
            LaneWidth::W32 => self.execute_lanes_w::<32>(cfg.isa, a, b, stats, out),
        }
    }

    /// The width-monomorphized lane loop behind
    /// [`Plan::execute_lanes_cfg`].
    fn execute_lanes_w<const W: usize>(
        &self,
        isa: SimdIsa,
        a: &[U128],
        b: &[U128],
        stats: &mut ExecStats,
        out: &mut Vec<U256>,
    ) {
        assert_eq!(a.len(), b.len(), "operand length mismatch");
        out.clear();
        out.reserve(a.len());
        if self.kernel == Kernel::Mono {
            // One full-width firing per element (CIVP single precision):
            // the SoA staging would only shuffle one chunk around, so the
            // lane loop degenerates to a flat multiply sweep — still one
            // scaled stats merge for the whole batch.
            let step = &self.steps[0];
            for (&x, &y) in a.iter().zip(b) {
                let prod =
                    ((x.limbs[0] & step.mask_a) as u128) * ((y.limbs[0] & step.mask_b) as u128);
                out.push(U256::from_u128(prod));
            }
            stats.merge_scaled(&self.per_mul, a.len() as u64);
            return;
        }
        let lanes = self.lanes.as_ref().expect("wide plan: use execute_batch_wide");
        let full = a.len() - a.len() % W;
        let mut block = LaneScratch::<W>::new();
        let mut i = 0;
        while i < full {
            let ba: &[U128; W] = a[i..i + W].try_into().expect("block width");
            let bb: &[U128; W] = b[i..i + W].try_into().expect("block width");
            block.run_with(lanes, ba, bb, out, isa);
            i += W;
        }
        for (&x, &y) in a[full..].iter().zip(&b[full..]) {
            out.push(self.product(x, y));
        }
        stats.merge_scaled(&self.per_mul, a.len() as u64);
    }

    /// True when this plan executes on the wide operand path
    /// (`width() > 128`): [`Plan::execute_wide`] /
    /// [`Plan::execute_batch_wide`] instead of the `U128` entry points.
    pub fn is_wide(&self) -> bool {
        self.wide.is_some()
    }

    /// Execute `a × b` exactly through the compiled wide plan,
    /// accumulating block usage into `stats`. `a, b < 2^self.width()`.
    ///
    /// For the all-pairs organizations this is one flat tile sweep into a
    /// [`WideProd`] accumulator; for `karatsuba24` it walks the compiled
    /// combine tree — leaf tile sweeps plus the shift/add/subtract combine
    /// schedule. Bit-exact against `PackedBits::mul_full` (pinned by
    /// `rust/tests/plan_equiv.rs`).
    ///
    /// # Panics
    ///
    /// Panics if this is a narrow plan (`width() <= 128`).
    pub fn execute_wide(&self, a: PackedBits, b: PackedBits, stats: &mut ExecStats) -> WideProd {
        let wide = self.wide.as_ref().expect("narrow plan: use execute");
        debug_assert!(a.bit_len() <= self.scheme.eff_bits, "operand A wider than plan");
        debug_assert!(b.bit_len() <= self.scheme.eff_bits, "operand B wider than plan");
        let out = wide.root.eval(&a, &b);
        stats.merge(&self.per_mul);
        out
    }

    /// Batch counterpart of [`Plan::execute_wide`]: per-element tree
    /// walks with one scaled stats merge for the whole batch.
    ///
    /// # Panics
    ///
    /// Panics if this is a narrow plan, or if `a` and `b` have different
    /// lengths.
    pub fn execute_batch_wide(
        &self,
        a: &[PackedBits],
        b: &[PackedBits],
        stats: &mut ExecStats,
        out: &mut Vec<WideProd>,
    ) {
        assert_eq!(a.len(), b.len(), "operand length mismatch");
        let wide = self.wide.as_ref().expect("narrow plan: use execute_batch");
        out.clear();
        out.reserve(a.len());
        for (x, y) in a.iter().zip(b) {
            out.push(wide.root.eval(x, y));
        }
        stats.merge_scaled(&self.per_mul, a.len() as u64);
    }
}

/// Compiled wide execution recipe: the [`KaraTree`] lowered to leaf tile
/// sets plus the combine schedule, evaluated over [`PackedBits`] operands
/// into a [`WideProd`] accumulator.
#[derive(Clone, Debug)]
struct WidePlan {
    root: WideNode,
}

/// One node of the compiled wide plan.
#[derive(Clone, Debug)]
enum WideNode {
    /// Flat tile sweep: the naive all-pairs plan, or one Karatsuba leaf
    /// multiply (tile offsets are node-local).
    Leaf { tiles: Box<[Tile]> },
    /// Karatsuba split at bit `h`:
    /// `z2·2^{2h} + [zm − z2 − z0]·2^h + z0` over the three children.
    Split { h: u32, low: Box<WideNode>, high: Box<WideNode>, mid: Box<WideNode> },
}

impl WidePlan {
    /// Lower `scheme` into a wide plan, tallying the value-independent
    /// per-multiply stats delta (everything except `muls`) into `per_mul`.
    fn compile(scheme: &Scheme, per_mul: &mut ExecStats) -> WidePlan {
        let root = if scheme.kind == SchemeKind::Karatsuba24 {
            WideNode::from_tree(&karatsuba_tree(scheme.eff_bits), per_mul)
        } else {
            let tiles = scheme.tiles();
            tally_tiles(&tiles, per_mul);
            WideNode::Leaf { tiles: tiles.into_boxed_slice() }
        };
        WidePlan { root }
    }
}

impl WideNode {
    /// Lower one [`KaraTree`] node, tallying leaf tile accounting.
    fn from_tree(tree: &KaraTree, per_mul: &mut ExecStats) -> WideNode {
        match tree {
            KaraTree::Leaf(w) => {
                // Each leaf is a flat CIVP integer multiply of its width —
                // the same tile source `Scheme::tiles` uses for the
                // karatsuba census, so plan stats and census always agree.
                let tiles = Scheme::for_int(SchemeKind::Civp, *w).tiles();
                tally_tiles(&tiles, per_mul);
                WideNode::Leaf { tiles: tiles.into_boxed_slice() }
            }
            KaraTree::Split { h, low, high, mid } => WideNode::Split {
                h: *h,
                low: Box::new(WideNode::from_tree(low, per_mul)),
                high: Box::new(WideNode::from_tree(high, per_mul)),
                mid: Box::new(WideNode::from_tree(mid, per_mul)),
            },
        }
    }

    /// Evaluate the exact product of `a × b` for this node's width.
    ///
    /// Leaves sweep their tiles into a wide accumulator (chunk products
    /// are ≤ 50 bits, shift-accumulated limb-wise, same dataflow as the
    /// narrow executor). Splits recurse: `z0 = lo·lo`, `z2 = hi·hi`,
    /// `zm = (lo+hi)(lo+hi)`, combined as
    /// `z0 + (zm − z2 − z0)·2^h + z2·2^{2h}` — `zm − z2 − z0` is
    /// non-negative by construction, and every partial sum is bounded by
    /// the true ≤ 978-bit product, so the wrapping ops never wrap.
    fn eval(&self, a: &PackedBits, b: &PackedBits) -> WideProd {
        match self {
            WideNode::Leaf { tiles } => {
                let mut acc = WideProd::ZERO;
                for t in tiles.iter() {
                    let pa = a.extract_u64(t.off_a, t.wa);
                    let pb = b.extract_u64(t.off_b, t.wb);
                    let prod = (pa as u128) * (pb as u128);
                    let off = t.off_a + t.off_b;
                    accumulate_shifted(&mut acc, prod, (off / 64) as usize, off % 64);
                }
                acc
            }
            WideNode::Split { h, low, high, mid } => {
                let h = *h;
                let a_lo = a.mask_low(h);
                let a_hi = a.shr(h);
                let b_lo = b.mask_low(h);
                let b_hi = b.shr(h);
                let z0 = low.eval(&a_lo, &b_lo);
                let z2 = high.eval(&a_hi, &b_hi);
                let sa = a_lo.wrapping_add(&a_hi);
                let sb = b_lo.wrapping_add(&b_hi);
                let zm = mid.eval(&sa, &sb);
                let z1 = zm.wrapping_sub(&z2).wrapping_sub(&z0);
                z0.wrapping_add(&z1.shl(h)).wrapping_add(&z2.shl(2 * h))
            }
        }
    }
}

/// Low `w`-bit mask (`w <= 64`).
#[inline]
pub(crate) const fn low_mask(w: u32) -> u64 {
    if w >= 64 {
        u64::MAX
    } else {
        (1u64 << w) - 1
    }
}

/// Process-wide cache of compiled [`Plan`]s, keyed by scheme × width.
///
/// Every registry combination ([`SchemeKind::COUNT`] organizations ×
/// [`OpClass::COUNT`] classes) lives in a static `OnceLock` slot indexed
/// densely by `SchemeKind::index() * OpClass::COUNT + OpClass::index()` —
/// after first use a lookup is one atomic load and an `Arc` clone. The
/// slot table sizes itself from the registry, so landing a new served
/// class never touches this file. Integer widths go through an `RwLock`ed
/// map.
///
/// ```
/// use civp::decomp::{OpClass, PlanCache, SchemeKind};
/// use std::sync::Arc;
///
/// let a = PlanCache::get(SchemeKind::Civp, OpClass::Quad);
/// let b = PlanCache::get(SchemeKind::Civp, OpClass::Quad);
/// assert!(Arc::ptr_eq(&a, &b)); // compiled once, shared process-wide
/// assert_eq!(a.steps().len(), 36); // Fig. 4: 36 blocks per quad multiply
/// ```
pub struct PlanCache {
    _private: (),
}

/// `const` initializer for the static slot array (usable on rustc versions
/// without inline-const array repetition).
#[allow(clippy::declare_interior_mutable_const)]
const EMPTY_SLOT: OnceLock<Arc<Plan>> = OnceLock::new();

/// One fast slot per `SchemeKind × OpClass` registry combination.
const N_CLASS_SLOTS: usize = SchemeKind::COUNT * OpClass::COUNT;

/// Fast slots: `kind.index() * OpClass::COUNT + class.index()`.
static CLASS_PLANS: [OnceLock<Arc<Plan>>; N_CLASS_SLOTS] = [EMPTY_SLOT; N_CLASS_SLOTS];

/// Plans for non-class (integer) widths.
static INT_PLANS: OnceLock<RwLock<HashMap<(SchemeKind, u32), Arc<Plan>>>> = OnceLock::new();

impl PlanCache {
    /// The shared plan for a served op class (compiled on first use).
    pub fn get(kind: SchemeKind, class: OpClass) -> Arc<Plan> {
        let slot = &CLASS_PLANS[kind.index() * OpClass::COUNT + class.index()];
        slot.get_or_init(|| Arc::new(Plan::compile(Scheme::new(kind, class)))).clone()
    }

    /// The shared plan for an arbitrary operand width. Registry significand
    /// widths (8 / 11 / 24 / 53 / 113) route to the class partitions via
    /// [`PlanCache::get`]; anything else compiles an integer scheme.
    pub fn get_width(kind: SchemeKind, width: u32) -> Arc<Plan> {
        match OpClass::from_sig_bits(width) {
            Some(class) => Self::get(kind, class),
            None => {
                let map = INT_PLANS.get_or_init(|| RwLock::new(HashMap::new()));
                if let Some(p) = map.read().unwrap().get(&(kind, width)) {
                    return p.clone();
                }
                // Compile outside the write lock; a racing thread's entry
                // wins via the `or_insert` below, so all callers still
                // share one plan.
                let plan = Arc::new(Plan::compile(Scheme::for_int(kind, width)));
                map.write().unwrap().entry((kind, width)).or_insert(plan).clone()
            }
        }
    }

    /// Number of class fast slots populated so far (diagnostics).
    pub fn class_cached() -> usize {
        CLASS_PLANS.iter().filter(|s| s.get().is_some()).count()
    }

    /// Number of integer-width plans cached so far (diagnostics).
    pub fn int_cached() -> usize {
        INT_PLANS.get().map(|m| m.read().unwrap().len()).unwrap_or(0)
    }
}
