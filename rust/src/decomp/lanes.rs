//! Lane-fused, tile-major SoA execution of compiled plans.
//!
//! The paper's point is that for a fixed precision the partial-product
//! array is *static hardware*: every multiplication fires the exact same
//! blocks in the exact same order. [`super::Plan`] already exploits that
//! per call (pre-resolved steps, no planning); this module exploits it per
//! **batch**. Instead of walking the step table once per operand pair
//! (operand-major, the per-op path), the lane engine walks it once per
//! block of operands — **tiles outer, lanes inner** — the software
//! analogue of streaming a batch through a deeply pipelined fixed
//! datapath (de Fine Licht et al. 2022).
//!
//! Structure-of-arrays layout is what makes the inner loops branch-free
//! and auto-vectorizable:
//!
//! * every per-step constant (chunk offsets, widths/masks, accumulator
//!   limb index and in-limb shift) is decoded **once per step**, outside
//!   the lane loop;
//! * chunk values are extracted once per *chunk* (not once per tile that
//!   reuses the chunk) into chunk-major `[u64; W]` buffers;
//! * the accumulator is a 4-limb SoA array `[[u64; W]; 4]`, so the
//!   shift/add/carry chain of one step runs as four flat lane sweeps.
//!
//! The block width is a const generic `W ∈ {8, 16, 32}`
//! ([`LaneScratch`]), selected at run time through [`LaneWidth`] —
//! the software analogue of Arish & Sharma's run-time reconfigurable
//! datapath width. With the `simd` cargo feature the three hot sweeps
//! (chunk extraction, the widening 32x32→64 multiply, the shift/carry
//! accumulate) additionally dispatch to `core::arch` kernels selected by
//! [`SimdIsa::detect`]; the scalar sweeps below remain the oracle and the
//! fallback, so the default build stays std-only and dependency-free.
//!
//! The kernels here are bit-identical to the scalar
//! `exec::accumulate_shifted` dataflow; `rust/tests/plan_equiv.rs` pins
//! `Plan::execute_lanes` against N× `Plan::execute` for every scheme
//! kind, width and ragged tail length, and the `width_equiv` tests pin
//! every `W`/ISA combination against the `W = 8` scalar path.

use super::plan::low_mask;
use super::scheme::{Scheme, Tile};
use crate::wideint::{U128, U256};

/// Default operands processed per SoA block. Eight 64-bit lanes fill one
/// AVX-512 register (or two NEON/AVX2 registers) per sweep; the tail
/// shorter than a block falls back to the scalar per-op kernel.
pub const LANES: usize = 8;

/// Operand container width in bits (two 64-bit limbs). Everything the
/// engine multiplies arrives as a [`U128`]; the compile-time assert below
/// keeps [`MAX_CHUNKS`] honest if the container ever grows.
pub const MAX_OPERAND_BITS: usize = 64 * 2;

/// Narrowest *uniform* chunk width any organization in the registry
/// emits: the `9x9` baseline tiles the whole operand in 9-bit chunks.
/// (CIVP's half-precision side emits one 2-bit *remainder* chunk, but at
/// most one per side — covered by the `+ 1` headroom in [`MAX_CHUNKS`].)
pub const NARROWEST_UNIFORM_CHUNK: usize = 9;

/// Upper bound on chunks per operand side, derived from the registry's
/// narrowest uniform chunk width plus one sub-width remainder chunk —
/// `ceil(128 / 9) + 1 = 16`. [`LanePlan::compile`] asserts every scheme
/// fits, so a wider future `OpClass` (ROADMAP item 2) that overflows this
/// bound fails loudly instead of silently truncating the scratch arrays.
pub const MAX_CHUNKS: usize = MAX_OPERAND_BITS.div_ceil(NARROWEST_UNIFORM_CHUNK) + 1;

// If the operand container grows (e.g. Fp256 via a U256 operand type),
// MAX_OPERAND_BITS — and with it MAX_CHUNKS and the extraction kernels'
// two-limb splice — must be revisited. Fail the build, not the data.
const _: () = assert!(MAX_OPERAND_BITS == std::mem::size_of::<U128>() * 8);
const _: () = assert!(MAX_CHUNKS >= MAX_OPERAND_BITS.div_ceil(NARROWEST_UNIFORM_CHUNK));

/// Runtime-selectable SoA block width. The three widths are the
/// monomorphized [`LaneScratch`] instantiations the crate ships: `W8`
/// (one AVX-512 register per sweep), `W16` and `W32` (deeper software
/// pipelining per step-table walk, amortizing the per-step constant
/// decode across more operands).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LaneWidth {
    /// 8 operands per block (the pre-width-parameterization default).
    W8,
    /// 16 operands per block.
    W16,
    /// 32 operands per block.
    W32,
}

impl LaneWidth {
    /// Every supported width, narrowest first.
    pub const ALL: [LaneWidth; 3] = [LaneWidth::W8, LaneWidth::W16, LaneWidth::W32];

    /// The block width as a lane count.
    pub const fn width(self) -> usize {
        match self {
            LaneWidth::W8 => 8,
            LaneWidth::W16 => 16,
            LaneWidth::W32 => 32,
        }
    }

    /// Display name (`w8` / `w16` / `w32`).
    pub const fn name(self) -> &'static str {
        match self {
            LaneWidth::W8 => "w8",
            LaneWidth::W16 => "w16",
            LaneWidth::W32 => "w32",
        }
    }

    /// Parse a lane count (`8` / `16` / `32`).
    pub fn from_width(w: usize) -> Option<LaneWidth> {
        match w {
            8 => Some(LaneWidth::W8),
            16 => Some(LaneWidth::W16),
            32 => Some(LaneWidth::W32),
            _ => None,
        }
    }
}

impl Default for LaneWidth {
    fn default() -> Self {
        LaneWidth::W8
    }
}

/// Vector ISA backing the three hot sweeps. Variants exist on every
/// target so config files and CLI flags parse everywhere; whether a
/// variant can actually *dispatch* on this build + CPU is
/// [`SimdIsa::available`]. Detection order on x86_64 is AVX-512 → AVX2 →
/// scalar; aarch64 dispatches NEON (baseline on that architecture);
/// every other target — and any build without the `simd` cargo feature —
/// runs the scalar sweeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SimdIsa {
    /// Portable scalar lane sweeps (the oracle every other path is pinned
    /// against).
    Scalar,
    /// x86_64 AVX2: 4 lanes per 256-bit sweep.
    Avx2,
    /// x86_64 AVX-512F: 8 lanes per 512-bit sweep.
    Avx512,
    /// aarch64 NEON: 2 lanes per 128-bit sweep.
    Neon,
}

impl SimdIsa {
    /// Every ISA variant, scalar first.
    pub const ALL: [SimdIsa; 4] = [SimdIsa::Scalar, SimdIsa::Avx2, SimdIsa::Avx512, SimdIsa::Neon];

    /// Display name (`scalar` / `avx2` / `avx512` / `neon`).
    pub const fn name(self) -> &'static str {
        match self {
            SimdIsa::Scalar => "scalar",
            SimdIsa::Avx2 => "avx2",
            SimdIsa::Avx512 => "avx512",
            SimdIsa::Neon => "neon",
        }
    }

    /// Best ISA this build + CPU can dispatch: AVX-512 → AVX2 → scalar on
    /// x86_64, NEON on aarch64, scalar everywhere else (and always scalar
    /// without the `simd` cargo feature).
    pub fn detect() -> SimdIsa {
        Self::detect_impl()
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    fn detect_impl() -> SimdIsa {
        if std::arch::is_x86_feature_detected!("avx512f") {
            SimdIsa::Avx512
        } else if std::arch::is_x86_feature_detected!("avx2") {
            SimdIsa::Avx2
        } else {
            SimdIsa::Scalar
        }
    }

    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    fn detect_impl() -> SimdIsa {
        // NEON is a baseline feature of aarch64; no runtime probe needed.
        SimdIsa::Neon
    }

    #[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    fn detect_impl() -> SimdIsa {
        SimdIsa::Scalar
    }

    /// Whether this ISA can dispatch on the current build + CPU. The lane
    /// engine re-checks this before entering a vector kernel (calling a
    /// `#[target_feature]` function on a CPU without the feature is UB),
    /// falling back to the scalar sweeps otherwise.
    pub fn available(self) -> bool {
        match self {
            SimdIsa::Scalar => true,
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            SimdIsa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            SimdIsa::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            SimdIsa::Neon => true,
            #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
            SimdIsa::Avx2 | SimdIsa::Avx512 => false,
            #[cfg(not(all(feature = "simd", target_arch = "aarch64")))]
            SimdIsa::Neon => false,
        }
    }
}

impl Default for SimdIsa {
    fn default() -> Self {
        SimdIsa::Scalar
    }
}

/// Lane-engine configuration: block width × vector ISA. The default is
/// the scalar `W = 8` engine — exactly the pre-parameterization behavior,
/// which keeps every equivalence oracle and the committed parallel
/// baselines byte-identical. Serving entry points (`--lane-width`,
/// `service.lane_width`) construct one with [`LaneConfig::detect`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaneConfig {
    /// SoA block width.
    pub width: LaneWidth,
    /// Vector ISA for the hot sweeps.
    pub isa: SimdIsa,
}

impl LaneConfig {
    /// The scalar `W = 8` reference configuration.
    pub const SCALAR: LaneConfig = LaneConfig { width: LaneWidth::W8, isa: SimdIsa::Scalar };

    /// `width` with the best ISA this build + CPU dispatches.
    pub fn detect(width: LaneWidth) -> LaneConfig {
        LaneConfig { width, isa: SimdIsa::detect() }
    }

    /// The dispatched-kernel label published as a metrics gauge and
    /// printed by `serve` (e.g. `avx2-w16`, `scalar-w8`).
    pub fn kernel_name(&self) -> String {
        format!("{}-{}", self.isa.name(), self.width.name())
    }
}

/// Pre-decoded extraction recipe for one operand chunk: which [`U128`]
/// limb it starts in, the in-limb shift, and the width mask. Decoded once
/// at plan-compile time so the load loop does no division or width
/// arithmetic.
#[derive(Clone, Copy, Debug)]
pub struct LaneChunk {
    /// Limb index of the chunk's low bit (`off / 64`).
    pub limb: u32,
    /// In-limb bit shift of the chunk's low bit (`off % 64`).
    pub shift: u32,
    /// Low `width`-bit mask.
    pub mask: u64,
}

/// One tile of the lane plan, referencing pre-extracted chunks by index
/// (chunk values are shared by every tile in that row/column of the
/// partial-product array, so they are extracted once per block, not once
/// per tile).
#[derive(Clone, Copy, Debug)]
pub struct LaneStep {
    /// Index into the A-side chunk buffer.
    pub ia: u32,
    /// Index into the B-side chunk buffer.
    pub ib: u32,
    /// Accumulator limb index of `off_a + off_b`.
    pub limb: u32,
    /// In-limb bit shift of `off_a + off_b`.
    pub shift: u32,
}

/// The tile-major recipe [`super::Plan`] compiles alongside its scalar
/// step table: per-side chunk extraction specs plus the step list in
/// chunk-index form. Everything the lane kernels read per step is a plain
/// integer resolved at compile time.
#[derive(Clone, Debug)]
pub struct LanePlan {
    /// Extraction recipes for operand A's chunks, least-significant first.
    pub a_chunks: Box<[LaneChunk]>,
    /// Extraction recipes for operand B's chunks.
    pub b_chunks: Box<[LaneChunk]>,
    /// All tiles, row-major, in chunk-index form.
    pub steps: Box<[LaneStep]>,
}

impl LanePlan {
    /// Lower a scheme's tile DAG into the lane form. Called once from
    /// [`super::Plan::compile`]; never on the execute path.
    pub fn compile(scheme: &Scheme, tiles: &[Tile]) -> LanePlan {
        assert!(
            scheme.a_chunks.len() <= MAX_CHUNKS && scheme.b_chunks.len() <= MAX_CHUNKS,
            "scheme exceeds MAX_CHUNKS"
        );
        // The SIMD kernels' contract: chunk values fit 32 bits, so the
        // widening multiply is an exact 32x32→64 (`mul_epu32` /
        // `vmull_u32`) and the ≤64-bit product never reaches the third
        // limb part (`p2 ≡ 0` for every in-limb shift). Every registry
        // organization is ≤25-bit chunks; assert rather than assume.
        for &w in scheme.a_chunks.iter().chain(scheme.b_chunks.iter()) {
            assert!(w <= 32, "chunk width {w} breaks the 32x32->64 lane-kernel contract");
        }
        let chunk_specs = |widths: &[u32]| -> Box<[LaneChunk]> {
            let mut off = 0u32;
            widths
                .iter()
                .map(|&w| {
                    // Chunks always *start* inside the real operand
                    // (off < eff_bits <= 128); only their padding may
                    // extend past it.
                    debug_assert!(off < 128, "chunk start beyond operand container");
                    let spec = LaneChunk { limb: off / 64, shift: off % 64, mask: low_mask(w) };
                    off += w;
                    spec
                })
                .collect()
        };
        let steps = tiles
            .iter()
            .map(|t| {
                let off = t.off_a + t.off_b;
                LaneStep { ia: t.i as u32, ib: t.j as u32, limb: off / 64, shift: off % 64 }
            })
            .collect();
        LanePlan {
            a_chunks: chunk_specs(&scheme.a_chunks),
            b_chunks: chunk_specs(&scheme.b_chunks),
            steps,
        }
    }
}

/// Reusable SoA scratch for one `W`-wide block of multiplications:
/// chunk-major operand buffers and the 4-limb SoA accumulator. Lives on
/// the stack of [`super::Plan::execute_lanes`] (~3 KiB at `W = 8`,
/// ~9 KiB at `W = 32`); no allocation.
pub struct LaneScratch<const W: usize> {
    /// `a[c][l]` = chunk `c` of lane `l`'s A operand.
    pub(crate) a: [[u64; W]; MAX_CHUNKS],
    /// `b[c][l]` = chunk `c` of lane `l`'s B operand.
    pub(crate) b: [[u64; W]; MAX_CHUNKS],
    /// SoA product accumulator: `acc[k][l]` = limb `k` of lane `l`.
    pub(crate) acc: [[u64; W]; 4],
}

/// The default-width scratch (the pre-parameterization `LaneBlock` name).
pub type LaneBlock = LaneScratch<LANES>;

impl<const W: usize> LaneScratch<W> {
    /// Fresh (zeroed) scratch.
    pub fn new() -> LaneScratch<W> {
        LaneScratch { a: [[0; W]; MAX_CHUNKS], b: [[0; W]; MAX_CHUNKS], acc: [[0; W]; 4] }
    }

    /// Execute one full block with the scalar sweeps: extract chunks, run
    /// every step tile-major, and append the `W` products to `out`.
    #[inline]
    pub fn run(&mut self, plan: &LanePlan, a: &[U128; W], b: &[U128; W], out: &mut Vec<U256>) {
        extract_chunks(&plan.a_chunks, a, &mut self.a);
        extract_chunks(&plan.b_chunks, b, &mut self.b);
        self.acc = [[0; W]; 4];
        for step in plan.steps.iter() {
            apply_step(&mut self.acc, &self.a[step.ia as usize], &self.b[step.ib as usize], step);
        }
        self.push_products(out);
    }

    /// Execute one full block on `isa`, falling back to the scalar sweeps
    /// when the ISA is not dispatchable on this build + CPU. Every ISA
    /// path is bit-identical to [`LaneScratch::run`] (pinned by the
    /// `simd` module's directed tests and the `width_equiv` properties).
    #[inline]
    pub fn run_with(
        &mut self,
        plan: &LanePlan,
        a: &[U128; W],
        b: &[U128; W],
        out: &mut Vec<U256>,
        isa: SimdIsa,
    ) {
        match isa {
            SimdIsa::Scalar => self.run(plan, a, b, out),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            SimdIsa::Avx2 if SimdIsa::Avx2.available() => {
                // SAFETY: AVX2 presence just verified on this CPU.
                unsafe { super::simd::run_avx2(self, plan, a, b, out) }
            }
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            SimdIsa::Avx512 if SimdIsa::Avx512.available() => {
                // SAFETY: AVX-512F presence just verified on this CPU.
                unsafe { super::simd::run_avx512(self, plan, a, b, out) }
            }
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            SimdIsa::Neon => {
                // SAFETY: NEON is baseline on aarch64.
                unsafe { super::simd::run_neon(self, plan, a, b, out) }
            }
            _ => self.run(plan, a, b, out),
        }
    }

    /// Transpose the SoA accumulator back to AoS [`U256`] products.
    #[inline]
    pub(crate) fn push_products(&self, out: &mut Vec<U256>) {
        let [r0, r1, r2, r3] = &self.acc;
        for (((&l0, &l1), &l2), &l3) in r0.iter().zip(r1).zip(r2).zip(r3) {
            out.push(U256 { limbs: [l0, l1, l2, l3] });
        }
    }
}

impl<const W: usize> Default for LaneScratch<W> {
    fn default() -> Self {
        Self::new()
    }
}

/// Extract every chunk of one operand side for all lanes. Chunk-outer,
/// lane-inner: the limb index, shift and mask are constants inside each
/// lane sweep, and the cross-limb splice is computed branch-free (the
/// `(hi << (63 - sh)) << 1` form is `hi << (64 - sh)` for `sh > 0` and
/// exactly 0 for `sh == 0`, with no per-lane conditional).
#[inline]
pub(crate) fn extract_chunks<const W: usize>(
    specs: &[LaneChunk],
    ops: &[U128; W],
    out: &mut [[u64; W]; MAX_CHUNKS],
) {
    for (spec, dst) in specs.iter().zip(out.iter_mut()) {
        let li = spec.limb as usize;
        let sh = spec.shift;
        let mask = spec.mask;
        if li == 0 {
            // Chunk starts in limb 0: may splice bits in from limb 1.
            for (d, x) in dst.iter_mut().zip(ops.iter()) {
                let lo = x.limbs[0];
                let hi = x.limbs[1];
                *d = ((lo >> sh) | ((hi << (63 - sh)) << 1)) & mask;
            }
        } else {
            // Chunk starts in limb 1: bits past the container read as 0,
            // matching `U128::extract_u64`.
            for (d, x) in dst.iter_mut().zip(ops.iter()) {
                *d = (x.limbs[1] >> sh) & mask;
            }
        }
    }
}

/// Apply one tile across all lanes: multiply the pre-extracted chunks and
/// shift-accumulate into the SoA accumulator. Mirrors the scalar
/// [`super::exec::accumulate_shifted`] exactly — the ≤50-bit product
/// spans limbs `limb..limb+2` (three when the in-limb shift wraps), plus
/// a carry ripple into `limb+3` — but each of those limb rows is one flat
/// lane sweep with the row index and shift hoisted out of the loop.
#[inline]
pub(crate) fn apply_step<const W: usize>(
    acc: &mut [[u64; W]; 4],
    pa: &[u64; W],
    pb: &[u64; W],
    step: &LaneStep,
) {
    let sh = step.shift;
    let limb = step.limb as usize;
    // Split each lane's shifted product into its three limb parts,
    // branch-free: `p1 = prod >> (64 - sh)` is `prod >> 64` when sh == 0,
    // and `(prod >> (127 - sh)) >> 1` is `prod >> (128 - sh)` for sh > 0
    // and 0 for sh == 0 — the same parts the scalar kernel computes.
    let mut p0 = [0u64; W];
    let mut p1 = [0u64; W];
    let mut p2 = [0u64; W];
    for (((d0, d1), d2), (&xa, &xb)) in
        p0.iter_mut().zip(p1.iter_mut()).zip(p2.iter_mut()).zip(pa.iter().zip(pb))
    {
        let prod = (xa as u128) * (xb as u128);
        *d0 = (prod << sh) as u64;
        *d1 = (prod >> (64 - sh)) as u64;
        *d2 = ((prod >> (127 - sh)) >> 1) as u64;
    }
    let mut carry = [0u64; W];
    {
        let row = &mut acc[limb];
        for ((r, &p), c) in row.iter_mut().zip(p0.iter()).zip(carry.iter_mut()) {
            let (v, cy) = r.overflowing_add(p);
            *r = v;
            *c = cy as u64;
        }
    }
    if limb + 1 < 4 {
        add_row(&mut acc[limb + 1], &p1, &mut carry);
    } else {
        debug_assert!(p1.iter().all(|&p| p == 0) && carry.iter().all(|&c| c == 0));
    }
    if limb + 2 < 4 {
        add_row(&mut acc[limb + 2], &p2, &mut carry);
    } else {
        debug_assert!(p2.iter().all(|&p| p == 0) && carry.iter().all(|&c| c == 0));
    }
    if limb + 3 < 4 {
        let row = &mut acc[limb + 3];
        for (r, &c) in row.iter_mut().zip(carry.iter()) {
            *r = r.wrapping_add(c);
        }
    } else {
        debug_assert!(carry.iter().all(|&c| c == 0), "accumulator overflow");
    }
}

/// One accumulator limb row += part + carry-in, producing carry-out.
/// The two single-bit carries cannot both fire (the wrapped sum of
/// `row + p` is at most `2^64 - 2`), so the out-carry stays 0/1.
#[inline]
fn add_row<const W: usize>(row: &mut [u64; W], parts: &[u64; W], carry: &mut [u64; W]) {
    for ((r, &p), c) in row.iter_mut().zip(parts.iter()).zip(carry.iter_mut()) {
        let (v, c1) = r.overflowing_add(p);
        let (v, c2) = v.overflowing_add(*c);
        *r = v;
        *c = (c1 as u64) + (c2 as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_chunks_covers_the_densest_organization() {
        // Baseline9 tiles a full 128-bit container in 9-bit chunks.
        assert!(MAX_CHUNKS >= 128usize.div_ceil(9));
    }

    #[test]
    fn lane_width_roundtrips() {
        for w in LaneWidth::ALL {
            assert_eq!(LaneWidth::from_width(w.width()), Some(w));
        }
        assert_eq!(LaneWidth::from_width(12), None);
        assert_eq!(LaneWidth::default(), LaneWidth::W8);
    }

    #[test]
    fn scalar_isa_is_always_available() {
        assert!(SimdIsa::Scalar.available());
        // Whatever detect() returns must itself be dispatchable.
        assert!(SimdIsa::detect().available());
    }

    #[test]
    fn kernel_name_composes_isa_and_width() {
        assert_eq!(LaneConfig::SCALAR.kernel_name(), "scalar-w8");
        let cfg = LaneConfig { width: LaneWidth::W32, isa: SimdIsa::Avx2 };
        assert_eq!(cfg.kernel_name(), "avx2-w32");
    }
}
