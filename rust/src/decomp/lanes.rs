//! Lane-fused, tile-major SoA execution of compiled plans.
//!
//! The paper's point is that for a fixed precision the partial-product
//! array is *static hardware*: every multiplication fires the exact same
//! blocks in the exact same order. [`super::Plan`] already exploits that
//! per call (pre-resolved steps, no planning); this module exploits it per
//! **batch**. Instead of walking the step table once per operand pair
//! (operand-major, the per-op path), the lane engine walks it once per
//! [`LANES`]-wide block of operands — **tiles outer, lanes inner** — the
//! software analogue of streaming a batch through a deeply pipelined fixed
//! datapath (de Fine Licht et al. 2022).
//!
//! Structure-of-arrays layout is what makes the inner loops branch-free
//! and auto-vectorizable:
//!
//! * every per-step constant (chunk offsets, widths/masks, accumulator
//!   limb index and in-limb shift) is decoded **once per step**, outside
//!   the lane loop;
//! * chunk values are extracted once per *chunk* (not once per tile that
//!   reuses the chunk) into chunk-major `[u64; LANES]` buffers;
//! * the accumulator is a 4-limb SoA array `[[u64; LANES]; 4]`, so the
//!   shift/add/carry chain of one step runs as four flat lane sweeps.
//!
//! The kernels here are bit-identical to the scalar
//! `exec::accumulate_shifted` dataflow; `rust/tests/plan_equiv.rs` pins
//! `Plan::execute_lanes` against N× `Plan::execute` for every scheme
//! kind, width and ragged tail length.

use super::plan::low_mask;
use super::scheme::{Scheme, Tile};
use crate::wideint::{U128, U256};

/// Operands processed per SoA block. Eight 64-bit lanes fill one AVX-512
/// register (or two NEON/AVX2 registers) per sweep; the tail shorter than
/// a block falls back to the scalar per-op kernel.
pub const LANES: usize = 8;

/// Upper bound on chunks per operand side. The narrowest chunk any
/// organization uses is 9 bits and operand widths are ≤ 128, so
/// `ceil(128 / 9) = 15` chunks is the worst case (9x9 baseline).
pub const MAX_CHUNKS: usize = 16;

/// Pre-decoded extraction recipe for one operand chunk: which [`U128`]
/// limb it starts in, the in-limb shift, and the width mask. Decoded once
/// at plan-compile time so the load loop does no division or width
/// arithmetic.
#[derive(Clone, Copy, Debug)]
pub struct LaneChunk {
    /// Limb index of the chunk's low bit (`off / 64`).
    pub limb: u32,
    /// In-limb bit shift of the chunk's low bit (`off % 64`).
    pub shift: u32,
    /// Low `width`-bit mask.
    pub mask: u64,
}

/// One tile of the lane plan, referencing pre-extracted chunks by index
/// (chunk values are shared by every tile in that row/column of the
/// partial-product array, so they are extracted once per block, not once
/// per tile).
#[derive(Clone, Copy, Debug)]
pub struct LaneStep {
    /// Index into the A-side chunk buffer.
    pub ia: u32,
    /// Index into the B-side chunk buffer.
    pub ib: u32,
    /// Accumulator limb index of `off_a + off_b`.
    pub limb: u32,
    /// In-limb bit shift of `off_a + off_b`.
    pub shift: u32,
}

/// The tile-major recipe [`super::Plan`] compiles alongside its scalar
/// step table: per-side chunk extraction specs plus the step list in
/// chunk-index form. Everything the lane kernels read per step is a plain
/// integer resolved at compile time.
#[derive(Clone, Debug)]
pub struct LanePlan {
    /// Extraction recipes for operand A's chunks, least-significant first.
    pub a_chunks: Box<[LaneChunk]>,
    /// Extraction recipes for operand B's chunks.
    pub b_chunks: Box<[LaneChunk]>,
    /// All tiles, row-major, in chunk-index form.
    pub steps: Box<[LaneStep]>,
}

impl LanePlan {
    /// Lower a scheme's tile DAG into the lane form. Called once from
    /// [`super::Plan::compile`]; never on the execute path.
    pub fn compile(scheme: &Scheme, tiles: &[Tile]) -> LanePlan {
        assert!(
            scheme.a_chunks.len() <= MAX_CHUNKS && scheme.b_chunks.len() <= MAX_CHUNKS,
            "scheme exceeds MAX_CHUNKS"
        );
        let chunk_specs = |widths: &[u32]| -> Box<[LaneChunk]> {
            let mut off = 0u32;
            widths
                .iter()
                .map(|&w| {
                    // Chunks always *start* inside the real operand
                    // (off < eff_bits <= 128); only their padding may
                    // extend past it.
                    debug_assert!(off < 128, "chunk start beyond operand container");
                    let spec = LaneChunk { limb: off / 64, shift: off % 64, mask: low_mask(w) };
                    off += w;
                    spec
                })
                .collect()
        };
        let steps = tiles
            .iter()
            .map(|t| {
                let off = t.off_a + t.off_b;
                LaneStep { ia: t.i as u32, ib: t.j as u32, limb: off / 64, shift: off % 64 }
            })
            .collect();
        LanePlan {
            a_chunks: chunk_specs(&scheme.a_chunks),
            b_chunks: chunk_specs(&scheme.b_chunks),
            steps,
        }
    }
}

/// Reusable SoA scratch for one [`LANES`]-wide block of multiplications:
/// chunk-major operand buffers and the 4-limb SoA accumulator. Lives on
/// the stack of [`super::Plan::execute_lanes`] (~3 KiB); no allocation.
pub struct LaneBlock {
    /// `a[c][l]` = chunk `c` of lane `l`'s A operand.
    a: [[u64; LANES]; MAX_CHUNKS],
    /// `b[c][l]` = chunk `c` of lane `l`'s B operand.
    b: [[u64; LANES]; MAX_CHUNKS],
    /// SoA product accumulator: `acc[k][l]` = limb `k` of lane `l`.
    acc: [[u64; LANES]; 4],
}

impl LaneBlock {
    /// Fresh (zeroed) scratch.
    pub fn new() -> LaneBlock {
        LaneBlock {
            a: [[0; LANES]; MAX_CHUNKS],
            b: [[0; LANES]; MAX_CHUNKS],
            acc: [[0; LANES]; 4],
        }
    }

    /// Execute one full block: extract chunks, run every step tile-major,
    /// and append the [`LANES`] products to `out`.
    #[inline]
    pub fn run(
        &mut self,
        plan: &LanePlan,
        a: &[U128; LANES],
        b: &[U128; LANES],
        out: &mut Vec<U256>,
    ) {
        extract_chunks(&plan.a_chunks, a, &mut self.a);
        extract_chunks(&plan.b_chunks, b, &mut self.b);
        self.acc = [[0; LANES]; 4];
        for step in plan.steps.iter() {
            apply_step(&mut self.acc, &self.a[step.ia as usize], &self.b[step.ib as usize], step);
        }
        let [r0, r1, r2, r3] = &self.acc;
        for (((&l0, &l1), &l2), &l3) in r0.iter().zip(r1).zip(r2).zip(r3) {
            out.push(U256 { limbs: [l0, l1, l2, l3] });
        }
    }
}

impl Default for LaneBlock {
    fn default() -> Self {
        Self::new()
    }
}

/// Extract every chunk of one operand side for all lanes. Chunk-outer,
/// lane-inner: the limb index, shift and mask are constants inside each
/// lane sweep, and the cross-limb splice is computed branch-free (the
/// `(hi << (63 - sh)) << 1` form is `hi << (64 - sh)` for `sh > 0` and
/// exactly 0 for `sh == 0`, with no per-lane conditional).
#[inline]
fn extract_chunks(specs: &[LaneChunk], ops: &[U128; LANES], out: &mut [[u64; LANES]; MAX_CHUNKS]) {
    for (spec, dst) in specs.iter().zip(out.iter_mut()) {
        let li = spec.limb as usize;
        let sh = spec.shift;
        let mask = spec.mask;
        if li == 0 {
            // Chunk starts in limb 0: may splice bits in from limb 1.
            for (d, x) in dst.iter_mut().zip(ops.iter()) {
                let lo = x.limbs[0];
                let hi = x.limbs[1];
                *d = ((lo >> sh) | ((hi << (63 - sh)) << 1)) & mask;
            }
        } else {
            // Chunk starts in limb 1: bits past the container read as 0,
            // matching `U128::extract_u64`.
            for (d, x) in dst.iter_mut().zip(ops.iter()) {
                *d = (x.limbs[1] >> sh) & mask;
            }
        }
    }
}

/// Apply one tile across all lanes: multiply the pre-extracted chunks and
/// shift-accumulate into the SoA accumulator. Mirrors the scalar
/// [`super::exec::accumulate_shifted`] exactly — the ≤50-bit product
/// spans limbs `limb..limb+2` (three when the in-limb shift wraps), plus
/// a carry ripple into `limb+3` — but each of those limb rows is one flat
/// lane sweep with the row index and shift hoisted out of the loop.
#[inline]
fn apply_step(acc: &mut [[u64; LANES]; 4], pa: &[u64; LANES], pb: &[u64; LANES], step: &LaneStep) {
    let sh = step.shift;
    let limb = step.limb as usize;
    // Split each lane's shifted product into its three limb parts,
    // branch-free: `p1 = prod >> (64 - sh)` is `prod >> 64` when sh == 0,
    // and `(prod >> (127 - sh)) >> 1` is `prod >> (128 - sh)` for sh > 0
    // and 0 for sh == 0 — the same parts the scalar kernel computes.
    let mut p0 = [0u64; LANES];
    let mut p1 = [0u64; LANES];
    let mut p2 = [0u64; LANES];
    for (((d0, d1), d2), (&xa, &xb)) in
        p0.iter_mut().zip(p1.iter_mut()).zip(p2.iter_mut()).zip(pa.iter().zip(pb))
    {
        let prod = (xa as u128) * (xb as u128);
        *d0 = (prod << sh) as u64;
        *d1 = (prod >> (64 - sh)) as u64;
        *d2 = ((prod >> (127 - sh)) >> 1) as u64;
    }
    let mut carry = [0u64; LANES];
    {
        let row = &mut acc[limb];
        for ((r, &p), c) in row.iter_mut().zip(p0.iter()).zip(carry.iter_mut()) {
            let (v, cy) = r.overflowing_add(p);
            *r = v;
            *c = cy as u64;
        }
    }
    if limb + 1 < 4 {
        add_row(&mut acc[limb + 1], &p1, &mut carry);
    } else {
        debug_assert!(p1.iter().all(|&p| p == 0) && carry.iter().all(|&c| c == 0));
    }
    if limb + 2 < 4 {
        add_row(&mut acc[limb + 2], &p2, &mut carry);
    } else {
        debug_assert!(p2.iter().all(|&p| p == 0) && carry.iter().all(|&c| c == 0));
    }
    if limb + 3 < 4 {
        let row = &mut acc[limb + 3];
        for (r, &c) in row.iter_mut().zip(carry.iter()) {
            *r = r.wrapping_add(c);
        }
    } else {
        debug_assert!(carry.iter().all(|&c| c == 0), "accumulator overflow");
    }
}

/// One accumulator limb row += part + carry-in, producing carry-out.
/// The two single-bit carries cannot both fire (the wrapped sum of
/// `row + p` is at most `2^64 - 2`), so the out-carry stays 0/1.
#[inline]
fn add_row(row: &mut [u64; LANES], parts: &[u64; LANES], carry: &mut [u64; LANES]) {
    for ((r, &p), c) in row.iter_mut().zip(parts.iter()).zip(carry.iter_mut()) {
        let (v, c1) = r.overflowing_add(p);
        let (v, c2) = v.overflowing_add(*c);
        *r = v;
        *c = (c1 as u64) + (c2 as u64);
    }
}
