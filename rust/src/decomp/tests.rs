//! Tests for the decomposition engine: block counts must match the paper's
//! figures exactly, and tiled execution must be bit-exact for every scheme
//! and every input.

use super::*;
use crate::fpu::{
    mul_bits_wide, DirectMul, Fp128, Fp32, Fp64, RoundMode, SigBatchMultiplier, SigMultiplier,
    WideProd, FP256, FP512, WIDE_PROD_LIMBS,
};
use crate::proput::{forall, Rng};
use crate::wideint::{mul_u128, PackedBits, U128, U256};


// ---------------------------------------------------------------------
// Paper figure block counts (E2, E3, E4)
// ---------------------------------------------------------------------

#[test]
fn sp_civp_uses_one_24x24() {
    // §II.A: single precision = one 24x24 block.
    let c = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Single));
    assert_eq!(c.total_blocks, 1);
    assert_eq!(c.count(BlockKind::M24x24), 1);
    assert_eq!(c.padded_blocks, 0);
    assert_eq!(c.utilization, 1.0);
}

#[test]
fn sp_baseline18_uses_four_blocks() {
    // §II.A context: 24x24 on an 18x18 fabric needs 2x2 = 4 blocks.
    let c = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Single));
    assert_eq!(c.total_blocks, 4);
    assert_eq!(c.count(BlockKind::M18x18), 4);
    assert!(c.padded_blocks > 0); // 24 = 18 + 6: padding in the top chunk
    assert!(c.utilization < 1.0);
}

#[test]
fn dp_civp_matches_fig2() {
    // Fig. 2(b): 57x57 = four 24x24 + four 24x9 + one 9x9 = 9 blocks.
    let c = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Double));
    assert_eq!(c.padded_bits, 57);
    assert_eq!(c.total_blocks, 9);
    assert_eq!(c.count(BlockKind::M24x24), 4);
    assert_eq!(c.count(BlockKind::M24x9), 4);
    assert_eq!(c.count(BlockKind::M9x9), 1);
}

#[test]
fn dp_baseline18_uses_nine_blocks() {
    // §II.B: "The 54x54 bit multiplication can be achieved using nine 18x18
    // bit multipliers".
    let c = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Double));
    assert_eq!(c.padded_bits, 54);
    assert_eq!(c.total_blocks, 9);
    assert_eq!(c.count(BlockKind::M18x18), 9);
}

#[test]
fn qp_civp_matches_fig4() {
    // Fig. 4: 114x114 = 4 x 57x57 = 16 x 24x24 + 16 x 24x9 + 4 x 9x9 = 36.
    let c = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Quad));
    assert_eq!(c.padded_bits, 114);
    assert_eq!(c.total_blocks, 36);
    assert_eq!(c.count(BlockKind::M24x24), 16);
    assert_eq!(c.count(BlockKind::M24x9), 16);
    assert_eq!(c.count(BlockKind::M9x9), 4);
}

#[test]
fn qp_baseline18_is_49_blocks() {
    // §II.C: "it will require 49 18x18 bit multipliers" (7x7 over 126 bits).
    let c = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Quad));
    assert_eq!(c.padded_bits, 126);
    assert_eq!(c.total_blocks, analysis::PAPER_CLAIMED_QP_TOTAL_18X18);
    assert_eq!(c.count(BlockKind::M18x18), 49);
}

#[test]
fn qp_baseline18_wastage_recomputed_vs_paper() {
    // The paper claims 17/49 wasted blocks (35%). Recomputed: the top chunk
    // holds 5 real bits, so padded tiles = 7 + 7 - 1 = 13 (26.5%). We pin
    // the recomputed value and keep the paper's constant for reporting.
    let c = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Quad));
    assert_eq!(c.padded_blocks, 13);
    assert_ne!(c.padded_blocks, analysis::PAPER_CLAIMED_QP_WASTED_18X18);
    // Direction of the claim holds: a significant fraction is padded.
    assert!(c.padded_fraction() > 0.25);
}

#[test]
fn qp_civp_near_perfect_utilization() {
    // CIVP pads 113 -> 114: exactly one padding bit. Only tiles touching
    // the top 9-bit chunk see it.
    let c = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Quad));
    assert!(c.utilization > 0.98, "civp quad utilization {}", c.utilization);
    let b18 = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Quad));
    assert!(c.utilization > b18.utilization);
}

#[test]
fn dp_civp_utilization_beats_what_paper_concedes() {
    // §II.B concedes 18x18 "seems the better choice" for DP in block count
    // (9 vs 9) — but CIVP still wins utilization because 54 pads 1 bit vs
    // 57 pads 4.
    let civp = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Double));
    let b18 = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Double));
    assert_eq!(civp.total_blocks, b18.total_blocks);
    // Paper's concession: same block count; CIVP's capacity is larger
    // (24-bit ports), so raw utilization is lower — record the real numbers.
    assert!(civp.utilization > 0.85);
    assert!(b18.utilization > 0.9);
}

// ---------------------------------------------------------------------
// Sub-single classes: the §II census extended downward (binary16 and
// bfloat16 on the same block sets).
// ---------------------------------------------------------------------

#[test]
fn bf16_civp_is_one_9x9() {
    // An 8-bit significand pads to 9: the whole product is a single 9x9
    // firing with one padding bit per port.
    let c = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Bf16));
    assert_eq!(c.padded_bits, 9);
    assert_eq!(c.total_blocks, 1);
    assert_eq!(c.count(BlockKind::M9x9), 1);
    assert_eq!(c.padded_blocks, 1);
    assert!((c.utilization - 64.0 / 81.0).abs() < 1e-12);
}

#[test]
fn half_civp_is_two_24x9() {
    // 11-bit operands: A stays whole on the 24 port, B splits [9, 2] on
    // the 9 port — two 24x9 firings, zero padding bits (11 = 9 + 2).
    let s = Scheme::new(SchemeKind::Civp, OpClass::Half);
    assert_eq!(s.a_chunks, vec![11]);
    assert_eq!(s.b_chunks, vec![9, 2]);
    assert_eq!(s.padded_bits, 11);
    let c = scheme_census(&s);
    assert_eq!(c.total_blocks, 2);
    assert_eq!(c.count(BlockKind::M24x9), 2);
    assert_eq!(c.padded_blocks, 0, "11 = 9 + 2 tiles exactly");
    assert!((c.utilization - 121.0 / 432.0).abs() < 1e-12);
}

#[test]
fn sub_single_wastage_on_18x18_baseline() {
    // The paper's wasted-block criterion applied below single precision:
    // an 18x18 block multiplying 11- or 8-bit operands is mostly padding.
    let half18 = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Half));
    assert_eq!(half18.total_blocks, 1);
    assert_eq!(half18.padded_blocks, 1);
    assert!((half18.utilization - 121.0 / 324.0).abs() < 1e-12);
    let bf18 = scheme_census(&Scheme::new(SchemeKind::Baseline18, OpClass::Bf16));
    assert_eq!(bf18.total_blocks, 1);
    assert!((bf18.utilization - 64.0 / 324.0).abs() < 1e-12);
    // bf16 is where CIVP's 9x9 pool wins outright: ~4x the utilization of
    // one 18x18. Binary16 is the honest trade: two 24x9s carry more raw
    // capacity (432 vs 324 bit-cells) but keep the big 24x24 pool free —
    // the census records both.
    let bf_civp = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Bf16));
    assert!(bf_civp.utilization > bf18.utilization * 3.0);
    let half_civp = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Half));
    assert_eq!(half_civp.count(BlockKind::M24x24), 0, "half never touches the 24x24 pool");
    assert!(half_civp.utilization < half18.utilization, "capacity cost recorded honestly");
}

#[test]
fn baseline25x18_counts() {
    // DSP48E-style: A in 25s, B in 18s.
    let sp = scheme_census(&Scheme::new(SchemeKind::Baseline25x18, OpClass::Single));
    assert_eq!(sp.total_blocks, 1 * 2); // 24->one 25-chunk, 24->two 18-chunks
    let qp = scheme_census(&Scheme::new(SchemeKind::Baseline25x18, OpClass::Quad));
    assert_eq!(qp.total_blocks, 5 * 7);
}

#[test]
fn baseline9_counts() {
    let sp = scheme_census(&Scheme::new(SchemeKind::Baseline9, OpClass::Single));
    assert_eq!(sp.total_blocks, 9); // 27x27 in 9s
    let qp = scheme_census(&Scheme::new(SchemeKind::Baseline9, OpClass::Quad));
    assert_eq!(qp.total_blocks, 13 * 13);
}

#[test]
fn dead_blocks_only_when_chunk_all_padding() {
    // No scheme for IEEE precisions produces an all-padding chunk.
    for prec in OpClass::ALL {
        for kind in SchemeKind::ALL {
            let c = scheme_census(&Scheme::new(kind, prec));
            assert_eq!(c.dead_blocks, 0, "{kind:?} {prec:?}");
        }
    }
}

#[test]
fn tile_offsets_cover_operand_exactly() {
    for prec in OpClass::ALL {
        for kind in SchemeKind::ALL {
            let s = Scheme::new(kind, prec);
            let sum_a: u32 = s.a_chunks.iter().sum();
            let sum_b: u32 = s.b_chunks.iter().sum();
            assert!(sum_a >= s.eff_bits);
            assert!(sum_b >= s.eff_bits);
            let tiles = s.tiles();
            if kind == SchemeKind::Karatsuba24 && prec.is_wide() {
                // DAG tiling: the tile set is the concatenation of the
                // recursion leaves (offsets leaf-local), not a flat
                // cross-product — but block_count must agree with it.
                assert_eq!(tiles.len(), s.block_count());
            } else {
                assert_eq!(tiles.len(), s.a_chunks.len() * s.b_chunks.len());
            }
            // every tile's chunk fits its block
            for t in &tiles {
                assert!(t.kind.fits(t.wa, t.wb), "{t:?}");
                assert!(t.eff_a <= t.wa && t.eff_b <= t.wb);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Exact execution (the ModelSim-equivalent functional check)
// ---------------------------------------------------------------------

#[test]
fn execute_exact_all_schemes_all_precisions() {
    forall(0x200, 2_000, |rng| {
        for prec in OpClass::ALL {
            if prec.is_wide() {
                continue; // wide classes run the tree path — see the wide section
            }
            for kind in SchemeKind::ALL {
                let s = Scheme::new(kind, prec);
                let a = rng.sig(prec.sig_bits());
                let b = rng.sig(prec.sig_bits());
                let mut stats = ExecStats::default();
                let got = execute(&s, a, b, &mut stats);
                assert_eq!(got, mul_u128(a, b), "{} exactness", s.name);
                assert_eq!(stats.tiles as usize, s.block_count());
            }
        }
    });
}

#[test]
fn execute_exact_integer_widths() {
    // The "combined integer" claim: CIVP blocks serve arbitrary-width
    // integer multiplication exactly.
    forall(0x201, 500, |rng| {
        let width = rng.range(2, 128) as u32;
        for kind in SchemeKind::ALL {
            let s = Scheme::for_int(kind, width);
            let a = rng.sig(width);
            let b = rng.sig(width);
            let mut stats = ExecStats::default();
            let got = execute(&s, a, b, &mut stats);
            assert_eq!(got, mul_u128(a, b), "{} width={width}", s.name);
        }
    });
}

#[test]
fn execute_edge_operands() {
    // all-zeros (denormal path feeds normalized values, but the executor
    // must still be exact), all-ones, single-bit.
    for prec in OpClass::ALL {
        if prec.is_wide() {
            continue;
        }
        let bits = prec.sig_bits();
        let ones = U128::ONE.shl(bits).wrapping_sub(&U128::ONE);
        let one = U128::ONE;
        let top = U128::ONE.shl(bits - 1);
        for kind in SchemeKind::ALL {
            let s = Scheme::new(kind, prec);
            for (a, b) in [(ones, ones), (one, ones), (top, top), (U128::ZERO, ones)] {
                let mut st = ExecStats::default();
                assert_eq!(execute(&s, a, b, &mut st), mul_u128(a, b), "{}", s.name);
            }
        }
    }
}

#[test]
fn decomp_mul_drives_ieee_pipeline_bit_exact() {
    // Full-system check: CIVP-decomposed significand multiply inside the
    // IEEE pipeline == hardware f32/f64 multiply.
    forall(0x202, 5_000, |rng| {
        let mut m = DecompMul::new(SchemeKind::Civp);
        let a = f64::from_bits(rng.nasty_bits64());
        let b = f64::from_bits(rng.nasty_bits64());
        let (r, _) = Fp64::from_f64(a).mul_with(Fp64::from_f64(b), RoundMode::NearestEven, &mut m);
        let hw = a * b;
        if hw.is_nan() {
            assert!(r.to_f64().is_nan());
        } else {
            assert_eq!(r.0, hw.to_bits(), "a={a:e} b={b:e}");
        }

        let a = f32::from_bits(rng.nasty_bits32());
        let b = f32::from_bits(rng.nasty_bits32());
        let (r, _) = Fp32::from_f32(a).mul_with(Fp32::from_f32(b), RoundMode::NearestEven, &mut m);
        let hw = a * b;
        if hw.is_nan() {
            assert!(r.to_f32().is_nan());
        } else {
            assert_eq!(r.0, hw.to_bits(), "a={a:e} b={b:e}");
        }
    });
}

#[test]
fn decomp_mul_all_baselines_agree_on_fp128() {
    // Quad has no hardware oracle; instead all four organizations plus the
    // direct multiplier must produce identical packed results.
    forall(0x203, 2_000, |rng| {
        let a = Fp128::from_f64(f64::from_bits(rng.nasty_bits64()));
        let b = Fp128::from_f64(f64::from_bits(rng.nasty_bits64()));
        let (expect, _) = a.mul_with(b, RoundMode::NearestEven, &mut DirectMul);
        for kind in SchemeKind::ALL {
            let mut m = DecompMul::new(kind);
            let (got, _) = a.mul_with(b, RoundMode::NearestEven, &mut m);
            if expect.is_nan() {
                assert!(got.is_nan());
            } else {
                assert_eq!(got.0, expect.0, "{kind:?}");
            }
        }
    });
}

#[test]
fn decomp_mul_stats_accumulate() {
    let mut m = DecompMul::new(SchemeKind::Civp);
    let x = Fp64::from_f64(1.5);
    let y = Fp64::from_f64(2.5);
    for _ in 0..10 {
        x.mul_with(y, RoundMode::NearestEven, &mut m);
    }
    assert_eq!(m.stats.muls, 10);
    assert_eq!(m.stats.tiles, 90); // 9 blocks per DP multiply
    assert_eq!(m.stats.ops(BlockKind::M24x24), 40);
    assert_eq!(m.stats.ops(BlockKind::M24x9), 40);
    assert_eq!(m.stats.ops(BlockKind::M9x9), 10);
    m.reset_stats();
    assert_eq!(m.stats.muls, 0);
}

#[test]
fn decomp_mul_verified_mode() {
    let mut m = DecompMul::verified(SchemeKind::Civp);
    let (r, _) =
        Fp64::from_f64(1.1).mul_with(Fp64::from_f64(2.2), RoundMode::NearestEven, &mut m);
    assert_eq!(r.to_f64(), 1.1 * 2.2);
}

#[test]
fn analysis_full_table_shape() {
    let table = AnalysisRow::full_table();
    assert_eq!(table.len(), OpClass::COUNT * SchemeKind::COUNT); // full registry cross-product
    // CIVP quad row repeats Fig. 4 counts.
    let qp_civp = table
        .iter()
        .find(|r| r.class == OpClass::Quad && r.kind == SchemeKind::Civp)
        .unwrap();
    assert_eq!(qp_civp.census.total_blocks, 36);
}

// ---------------------------------------------------------------------
// Compiled plans (the hot-path lowering)
// ---------------------------------------------------------------------

#[test]
fn plan_steps_mirror_tiles() {
    for prec in OpClass::ALL {
        for kind in SchemeKind::ALL {
            let scheme = Scheme::new(kind, prec);
            let tiles = scheme.tiles();
            let plan = Plan::compile(scheme);
            if prec.is_wide() {
                // Wide plans lower to the tile tree, not the flat step
                // table.
                assert!(plan.is_wide());
                assert!(plan.steps().is_empty());
                continue;
            }
            assert_eq!(plan.steps().len(), tiles.len());
            for (s, t) in plan.steps().iter().zip(&tiles) {
                assert_eq!((s.off_a, s.wa, s.off_b, s.wb), (t.off_a, t.wa, t.off_b, t.wb));
                let off = t.off_a + t.off_b;
                assert_eq!(s.limb, off / 64);
                assert_eq!(s.shift, off % 64);
            }
        }
    }
}

#[test]
fn plan_per_mul_stats_are_one_multiply() {
    let plan = PlanCache::get(SchemeKind::Civp, OpClass::Double);
    let pm = plan.per_mul_stats();
    assert_eq!(pm.muls, 1);
    assert_eq!(pm.tiles, 9);
    assert_eq!(pm.ops(BlockKind::M24x24), 4);
    assert_eq!(pm.ops(BlockKind::M24x9), 4);
    assert_eq!(pm.ops(BlockKind::M9x9), 1);
    // Executing twice merges the delta twice.
    let mut stats = ExecStats::default();
    let a = U128::ONE.shl(52);
    plan.execute(a, a, &mut stats);
    plan.execute(a, a, &mut stats);
    assert_eq!(stats.muls, 2);
    assert_eq!(stats.tiles, 18);
}

#[test]
fn decomp_mul_shares_cached_plans() {
    let mut m1 = DecompMul::new(SchemeKind::Civp);
    let mut m2 = DecompMul::new(SchemeKind::Civp);
    assert!(std::sync::Arc::ptr_eq(&m1.plan_for(53), &m2.plan_for(53)));
    assert_eq!(m1.scheme_for(53).padded_bits, 57);
}

#[test]
fn plan_exact_for_random_sigs_every_scheme() {
    forall(0x210, 1_000, |rng| {
        for prec in OpClass::ALL {
            if prec.is_wide() {
                continue;
            }
            for kind in SchemeKind::ALL {
                let plan = PlanCache::get(kind, prec);
                let a = rng.sig(prec.sig_bits());
                let b = rng.sig(prec.sig_bits());
                let mut stats = ExecStats::default();
                assert_eq!(plan.execute(a, b, &mut stats), mul_u128(a, b), "{kind:?} {prec:?}");
            }
        }
    });
}

#[test]
fn stats_utilization_bounds() {
    forall(0x204, 200, |rng| {
        let width = rng.range(2, 128) as u32;
        let s = Scheme::for_int(SchemeKind::Civp, width);
        let c = scheme_census(&s);
        assert!(c.utilization > 0.0 && c.utilization <= 1.0);
    });
}

#[test]
fn by_kind_is_deterministic_and_sorted() {
    // `ExecStats::by_kind` returns a BTreeMap so report output and golden
    // comparisons are stable run-to-run: keys iterate in `BlockKind`
    // order, and two identical stat sets render identically.
    let mut stats = ExecStats::default();
    let plan = PlanCache::get(SchemeKind::Civp, OpClass::Quad);
    let a = U128::ONE.shl(112);
    plan.execute(a, a, &mut stats);
    let m = stats.by_kind();
    let keys: Vec<BlockKind> = m.keys().copied().collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "by_kind iteration must be ordered");
    assert_eq!(format!("{:?}", stats.by_kind()), format!("{m:?}"));
    assert_eq!(m[&BlockKind::M24x24], 16); // Fig. 4 counts, in order
}

// ---------------------------------------------------------------------
// accumulate_shifted — the shared inner kernel of the scalar and lane
// executors. Edge cases: shift == 0, products wrapping a limb boundary,
// and carries rippling into the top limb.
// ---------------------------------------------------------------------

/// Oracle: `acc + (prod << (64*limb + shift))` via plain wide arithmetic.
fn acc_oracle(acc: U256, prod: u128, limb: usize, shift: u32) -> U256 {
    acc.wrapping_add(&U256::from_u128(prod).shl(64 * limb as u32 + shift))
}

fn run_kernel(acc: U256, prod: u128, limb: usize, shift: u32) -> U256 {
    let mut out = acc;
    exec::accumulate_shifted(&mut out, prod, limb, shift);
    out
}

#[test]
fn accumulate_shifted_shift_zero() {
    // shift == 0 must place the product exactly at the limb boundary,
    // including a full-width 128-bit product (high half into limb+1).
    for limb in 0..3usize {
        for prod in [0u128, 1, u64::MAX as u128, (u64::MAX as u128) << 64 | 7, u128::MAX >> 1] {
            let got = run_kernel(U256::ZERO, prod, limb, 0);
            assert_eq!(got, acc_oracle(U256::ZERO, prod, limb, 0), "limb={limb} prod={prod:#x}");
        }
    }
    // limb = 3 with shift 0: only the low 64 bits may be non-zero.
    let got = run_kernel(U256::ZERO, 0xFFFF_FFFF_FFFF_FFFF, 3, 0);
    assert_eq!(got.limbs, [0, 0, 0, u64::MAX]);
}

#[test]
fn accumulate_shifted_limb_boundary_wrap() {
    // A shifted product spans up to three limbs when the in-limb shift
    // wraps; sweep every shift against the oracle at every base limb.
    let prods = [
        (1u128 << 50) - 1,          // max real tile product (25x25)
        1u128 << 49,
        0x000F_FFFF_FFFF_FFFF,
        (1u128 << 63) | 1,
        (1u128 << 64) | (1 << 13),  // > 64 bits: exercises the middle part
    ];
    for limb in 0..3usize {
        for shift in 0..64u32 {
            for &prod in &prods {
                // Keep the shifted value inside 256 bits (the kernel
                // debug-asserts on true overflow, as hardware would).
                if 64 * limb as u32 + shift + 128 - prod.leading_zeros() > 255 {
                    continue;
                }
                let got = run_kernel(U256::ZERO, prod, limb, shift);
                assert_eq!(
                    got,
                    acc_oracle(U256::ZERO, prod, limb, shift),
                    "limb={limb} shift={shift} prod={prod:#x}"
                );
            }
        }
    }
}

#[test]
fn accumulate_shifted_carry_into_top_limb() {
    // All-ones accumulator below the top limb: any addition ripples a
    // carry chain all the way into limb 3.
    let acc = U256 { limbs: [u64::MAX, u64::MAX, u64::MAX, 0] };
    let got = run_kernel(acc, 1, 0, 0);
    assert_eq!(got.limbs, [0, 0, 0, 1]);
    // Carry generated by the middle part of a wrapped product.
    let acc = U256 { limbs: [0, u64::MAX, u64::MAX, 41] };
    let got = run_kernel(acc, 1u128 << 63, 0, 1); // adds 1 << 64
    assert_eq!(got.limbs, [0, 0, 0, 42]);
    // Carry out of the part written directly below the top limb.
    let acc = U256 { limbs: [7, 0, u64::MAX, 9] };
    let got = run_kernel(acc, 1, 2, 0);
    assert_eq!(got.limbs, [7, 0, 0, 10]);
    assert_eq!(got, acc_oracle(acc, 1, 2, 0));
}

// ---------------------------------------------------------------------
// Wide classes (binary256 / binary512): the Karatsuba planner and the
// tile-tree execution path. The paper's census model extended *upward*.
// ---------------------------------------------------------------------

/// Random wide operand, `< 2^bits` (`bits <= 512`).
fn wide_operand(rng: &mut Rng, bits: u32) -> PackedBits {
    let mut v = PackedBits::ZERO;
    for limb in v.limbs.iter_mut() {
        *limb = rng.next_u64();
    }
    let mut v = v.mask_low(bits);
    if rng.chance(0.5) {
        v.set_bit(bits - 1); // exercise full-width (normalized) values too
    }
    v
}

#[test]
fn karatsuba_tree_shape_fp256_fp512() {
    // Fp256 significand (237 bits): one split into 118/119/120-bit leaves.
    let t = karatsuba_tree(237);
    let mut widths = Vec::new();
    t.leaf_widths(&mut widths);
    assert_eq!(widths, vec![118, 119, 120]);
    // Fp512 significand (489 bits): three levels of recursion, 27 leaves.
    let t = karatsuba_tree(489);
    assert_eq!(t.leaf_count(), 27);
    let mut widths = Vec::new();
    t.leaf_widths(&mut widths);
    assert!(widths.iter().all(|&w| (25..=128).contains(&w)), "{widths:?}");
    // At or below the crossover the planner never splits: the narrow
    // flat/lane executors stay tile-identical to Civp.
    for w in 1..=KARATSUBA_CROSSOVER {
        assert_eq!(karatsuba_tree(w), KaraTree::Leaf(w));
    }
    assert!(matches!(karatsuba_tree(KARATSUBA_CROSSOVER + 1), KaraTree::Split { .. }));
}

#[test]
fn wide_census_karatsuba_is_subquadratic() {
    // Flat all-pairs CIVP tiling is quadratic in the chunk count:
    // 13² = 169 tiles at Fp256, 26² = 676 at Fp512 (exact [24,24,9]
    // chunking, zero padding). Karatsuba replaces the cross-products with
    // three half-width recursions: 3 × 25 = 75 and 27 × 9 = 243 tiles.
    let n256 = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Fp256));
    assert_eq!(n256.total_blocks, 169);
    assert_eq!(n256.padded_blocks, 0);
    assert_eq!(n256.utilization, 1.0);
    let n512 = scheme_census(&Scheme::new(SchemeKind::Civp, OpClass::Fp512));
    assert_eq!(n512.total_blocks, 676);
    assert_eq!(n512.padded_blocks, 0);
    let k256 = scheme_census(&Scheme::new(SchemeKind::Karatsuba24, OpClass::Fp256));
    assert_eq!(k256.total_blocks, 75);
    let k512 = scheme_census(&Scheme::new(SchemeKind::Karatsuba24, OpClass::Fp512));
    assert_eq!(k512.total_blocks, 243);
    // Sub-quadratic growth: doubling the width should *less* than
    // quadruple the tile bill (the naive ratio is exactly 4).
    let kara_ratio = k512.total_blocks as f64 / k256.total_blocks as f64;
    let naive_ratio = n512.total_blocks as f64 / n256.total_blocks as f64;
    assert!(kara_ratio < naive_ratio, "{kara_ratio} vs {naive_ratio}");
    assert!(kara_ratio < 4.0);
}

#[test]
fn wide_census_matches_plan_per_mul() {
    // The census (static tile walk) and the compiled wide plan's
    // per-multiply stats delta are built from the same leaf tiling — they
    // must agree exactly, for both organizations of both wide classes.
    for class in [OpClass::Fp256, OpClass::Fp512] {
        for kind in SchemeKind::ALL {
            let census = scheme_census(&Scheme::new(kind, class));
            let plan = PlanCache::get(kind, class);
            assert!(plan.is_wide());
            let pm = plan.per_mul_stats();
            assert_eq!(pm.muls, 1, "{kind:?} {class:?}");
            assert_eq!(pm.tiles, census.total_blocks as u64, "{kind:?} {class:?}");
            assert_eq!(pm.padded_tiles, census.padded_blocks as u64, "{kind:?} {class:?}");
            for (bk, n) in census.by_kind.iter() {
                assert_eq!(pm.ops(*bk), *n as u64, "{kind:?} {class:?} {bk:?}");
            }
        }
    }
}

#[test]
fn wide_plan_exact_every_scheme() {
    // Bit-exactness of the wide tree path — Karatsuba's add/subtract
    // combine network included — against the schoolbook limb oracle, for
    // every organization at both wide widths.
    forall(0x220, 400, |rng| {
        for class in [OpClass::Fp256, OpClass::Fp512] {
            let bits = class.sig_bits();
            let a = wide_operand(rng, bits);
            let b = wide_operand(rng, bits);
            let oracle = a.mul_full::<WIDE_PROD_LIMBS>(&b);
            for kind in SchemeKind::ALL {
                let plan = PlanCache::get(kind, class);
                let mut stats = ExecStats::default();
                let got = plan.execute_wide(a, b, &mut stats);
                assert_eq!(got, oracle, "{kind:?} {class:?}");
                assert_eq!(stats.muls, 1);
                assert_eq!(stats.tiles, plan.per_mul_stats().tiles);
            }
        }
    });
}

#[test]
fn wide_edge_operands() {
    // All-ones, single-bit, top-bit and zero operands through the
    // Karatsuba tree: the combine subtraction must never underflow.
    for class in [OpClass::Fp256, OpClass::Fp512] {
        let bits = class.sig_bits();
        let ones = PackedBits::ONE.shl(bits).wrapping_sub(&PackedBits::ONE);
        let one = PackedBits::ONE;
        let top = PackedBits::ONE.shl(bits - 1);
        for kind in [SchemeKind::Civp, SchemeKind::Karatsuba24] {
            let plan = PlanCache::get(kind, class);
            for (a, b) in
                [(ones, ones), (one, ones), (top, top), (PackedBits::ZERO, ones), (top, one)]
            {
                let mut st = ExecStats::default();
                let got = plan.execute_wide(a, b, &mut st);
                assert_eq!(got, a.mul_full::<WIDE_PROD_LIMBS>(&b), "{kind:?} {class:?}");
            }
        }
    }
}

#[test]
fn wide_batch_matches_scalar() {
    // One batch call == N scalar tree walks: outputs and merged stats.
    forall(0x221, 40, |rng| {
        let class = if rng.chance(0.5) { OpClass::Fp256 } else { OpClass::Fp512 };
        let bits = class.sig_bits();
        let n = rng.range(1, 33) as usize;
        let a: Vec<PackedBits> = (0..n).map(|_| wide_operand(rng, bits)).collect();
        let b: Vec<PackedBits> = (0..n).map(|_| wide_operand(rng, bits)).collect();
        let plan = PlanCache::get(SchemeKind::Karatsuba24, class);
        let mut batch_stats = ExecStats::default();
        let mut out = Vec::new();
        plan.execute_batch_wide(&a, &b, &mut batch_stats, &mut out);
        let mut scalar_stats = ExecStats::default();
        for i in 0..n {
            let want = plan.execute_wide(a[i], b[i], &mut scalar_stats);
            assert_eq!(out[i], want, "i={i}");
        }
        assert_eq!(batch_stats, scalar_stats);
    });
}

#[test]
fn decomp_mul_wide_verified_and_stats() {
    // The adapter's wide overrides: oracle-verified products and the same
    // per-multiply accounting as the narrow path.
    let mut m = DecompMul::verified(SchemeKind::Karatsuba24);
    let a = PackedBits::from_u64(0xDEAD_BEEF).shl(200).or(&PackedBits::from_u64(12345));
    let b = PackedBits::ONE.shl(236).or(&PackedBits::from_u64(987));
    let p = m.mul_sig_wide(a, b, 237);
    assert_eq!(p, a.mul_full::<WIDE_PROD_LIMBS>(&b));
    assert_eq!(m.stats.muls, 1);
    assert_eq!(m.stats.tiles, 75);
    let mut out: Vec<WideProd> = Vec::new();
    m.mul_sig_batch_wide(&[a, b], &[b, a], 237, &mut out);
    assert_eq!(out.len(), 2);
    assert_eq!(m.stats.muls, 3);
    assert_eq!(m.stats.tiles, 225);
}

#[test]
fn wide_ieee_pipeline_all_schemes_agree() {
    // Full binary256/binary512 multiplications: every decomposed
    // organization must match the direct multiplier bit-for-bit, flags
    // included, across all rounding modes — the wide analogue of
    // `decomp_mul_all_baselines_agree_on_fp128`.
    forall(0x222, 150, |rng| {
        for fmt in [&FP256, &FP512] {
            let a = wide_operand(rng, fmt.total_bits());
            let b = wide_operand(rng, fmt.total_bits());
            let mode = RoundMode::ALL[rng.below(RoundMode::COUNT as u64) as usize];
            let (want, want_flags) = mul_bits_wide(fmt, a, b, mode, &mut DirectMul);
            for kind in SchemeKind::ALL {
                let mut m = DecompMul::new(kind);
                let (got, got_flags) = mul_bits_wide(fmt, a, b, mode, &mut m);
                assert_eq!(got, want, "{kind:?} {} {mode:?}", fmt.name);
                assert_eq!(got_flags, want_flags, "{kind:?} {} {mode:?}", fmt.name);
            }
        }
    });
}

#[test]
fn accumulate_shifted_matches_oracle_random() {
    forall(0x600, 4_000, |rng| {
        // Random ≤50-bit products (the real tile range), random base
        // position, random accumulator with top-limb headroom.
        let prod = (rng.next_u64() as u128) & ((1u128 << 50) - 1);
        let limb = rng.below(4) as usize;
        let shift = if limb == 3 { rng.below(14) as u32 } else { rng.below(64) as u32 };
        let acc = U256 {
            limbs: [
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64(),
                rng.next_u64() >> 2, // headroom: the oracle add cannot overflow
            ],
        };
        let got = run_kernel(acc, prod, limb, shift);
        assert_eq!(got, acc_oracle(acc, prod, limb, shift), "limb={limb} shift={shift}");
    });
}
