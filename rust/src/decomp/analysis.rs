//! Static analysis of partition schemes — the numbers behind every claim in
//! §II and §III of the paper: block counts by kind, padded ("wasted")
//! blocks, and aggregate multiplier-array utilization.

use super::scheme::{BlockKind, Scheme, SchemeKind, Tile};
use crate::fpu::OpClass;
use std::collections::BTreeMap;

/// Paper §II.C: the authors state that 17 of the 49 `18x18` blocks in a
/// quad multiplication are wasted ("35%"). Recomputing from 113 = 6·18 + 5
/// gives 7 + 7 − 1 = 13 tiles touching the 5-bit top chunk (26.5%). Both
/// numbers are reported; see DESIGN.md §1 and EXPERIMENTS.md E5.
pub const PAPER_CLAIMED_QP_WASTED_18X18: u32 = 17;
/// Paper §II.C: total 18x18 blocks for quad (7 × 7) — this one checks out.
pub const PAPER_CLAIMED_QP_TOTAL_18X18: u32 = 49;

/// Census of one scheme's tile set.
#[derive(Clone, Debug)]
pub struct BlockCensus {
    /// Scheme display name.
    pub scheme: String,
    /// Organization family.
    pub kind: SchemeKind,
    /// Real operand width.
    pub eff_bits: u32,
    /// Padded operand width.
    pub padded_bits: u32,
    /// Blocks by kind.
    pub by_kind: BTreeMap<BlockKind, u32>,
    /// Total dedicated blocks consumed.
    pub total_blocks: u32,
    /// Blocks with padding on a port (paper's wasted blocks).
    pub padded_blocks: u32,
    /// Blocks multiplying only padding (contribute nothing).
    pub dead_blocks: u32,
    /// Useful bit-products / capacity bit-products.
    pub utilization: f64,
    /// The tiles themselves (for detailed reporting).
    pub tiles: Vec<Tile>,
}

impl BlockCensus {
    /// Count for one block kind.
    pub fn count(&self, kind: BlockKind) -> u32 {
        self.by_kind.get(&kind).copied().unwrap_or(0)
    }
    /// Fraction of blocks carrying padding.
    pub fn padded_fraction(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        self.padded_blocks as f64 / self.total_blocks as f64
    }
}

/// Run the census for a scheme.
pub fn scheme_census(scheme: &Scheme) -> BlockCensus {
    let tiles = scheme.tiles();
    let mut by_kind = BTreeMap::new();
    let mut padded = 0u32;
    let mut dead = 0u32;
    let mut useful = 0u64;
    let mut capacity = 0u64;
    for t in &tiles {
        *by_kind.entry(t.kind).or_insert(0u32) += 1;
        if t.is_padded() {
            padded += 1;
        }
        if t.is_dead() {
            dead += 1;
        }
        useful += (t.eff_a * t.eff_b) as u64;
        capacity += t.kind.capacity() as u64;
    }
    BlockCensus {
        scheme: scheme.name.clone(),
        kind: scheme.kind,
        eff_bits: scheme.eff_bits,
        padded_bits: scheme.padded_bits,
        total_blocks: tiles.len() as u32,
        by_kind,
        padded_blocks: padded,
        dead_blocks: dead,
        utilization: if capacity == 0 { 1.0 } else { useful as f64 / capacity as f64 },
        tiles,
    }
}

/// One row of the §III analysis table (E6): a (class, organization) pair
/// with its census. The table now extends the paper's census *downward*
/// past single precision: the sub-single registry classes (binary16,
/// bfloat16) get the same block/wastage accounting against every baseline.
#[derive(Clone, Debug)]
pub struct AnalysisRow {
    /// Operation class.
    pub class: OpClass,
    /// Organization family.
    pub kind: SchemeKind,
    /// Census for the scheme.
    pub census: BlockCensus,
}

impl AnalysisRow {
    /// Build the full registry × organization cross-product table the
    /// paper's §III argues from.
    pub fn full_table() -> Vec<AnalysisRow> {
        let mut rows = Vec::new();
        for class in OpClass::ALL {
            for kind in SchemeKind::ALL {
                let scheme = Scheme::new(kind, class);
                rows.push(AnalysisRow { class, kind, census: scheme_census(&scheme) });
            }
        }
        rows
    }
}
