//! Work-stealing multi-core batch executor for the lane engine.
//!
//! The lane engine ([`Plan::execute_lanes`]) is 8-wide SoA but
//! single-threaded: one large batch saturates one core while the rest of
//! the machine idles. The CIVP decomposition makes every wide multiply a
//! DAG of *independent* tile products, so a batch splits perfectly — this
//! module adds the missing axis of parallelism without changing a single
//! result bit.
//!
//! Design (std-only — the build environment has no crossbeam):
//!
//! * an [`Executor`] owns a fixed pool of per-core worker threads, each
//!   with its own chunk deque (a `Mutex<VecDeque>` — the critical section
//!   is a pointer-sized pop, so contention is negligible next to the
//!   multi-microsecond chunk execution it guards);
//! * a submitted batch is split into **block-aligned chunks** (every
//!   chunk length is a multiple of the executor's SoA lane width —
//!   [`super::lanes::LANES`] by default, or the [`LaneConfig`] width it
//!   was built with — so the parallel block decomposition is *identical*
//!   to the sequential one) and scattered round-robin across the worker
//!   deques; the scalar ragged tail (`len % width`) stays on the
//!   submitting thread;
//! * workers pop from the front of their own deque; an idle worker
//!   **steals from the back of the busiest deque** (largest depth), so
//!   load imbalance self-corrects without a global queue;
//! * the submitting thread *helps*: while its batch is in flight it
//!   drains chunks like a worker, then parks on the batch's completion
//!   condvar — so even a 1-worker executor makes progress and a storm of
//!   submitters cannot starve itself;
//! * each chunk writes its products into a disjoint range of the output
//!   buffer and its [`ExecStats`] into a per-chunk slot; after the last
//!   chunk completes, the submitter merges the slots **in chunk order**
//!   (then the tail), so the merged stats are bit-for-bit identical to
//!   the sequential path regardless of which worker ran what when.
//!
//! Equivalence with the sequential [`Plan::execute_batch`] — outputs,
//! flag unions through [`crate::fpu::FpuBatch`], and merged stats — is
//! pinned by `rust/tests/parallel_equiv.rs` (property tests over every
//! `SchemeKind × OpClass`, ragged tails, worker counts 1–8 and batch
//! sizes straddling the threshold) and hammered from many submitting
//! threads by `rust/tests/parallel_stress.rs`.

use super::exec::ExecStats;
use super::lanes::LaneConfig;
use super::plan::Plan;
use crate::wideint::{U128, U256};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Default minimum batch size fanned out across the executor; smaller
/// batches keep the single-threaded lane path (the fan-out fixed cost —
/// queue pushes, a wakeup, a condvar wait — only pays for itself on
/// batches at least this large). Matches the default `batcher.max_batch`,
/// so a service that opts in with `--cores` parallelizes exactly its full
/// batches.
pub const DEFAULT_PAR_THRESHOLD: usize = 256;

/// Smallest chunk the splitter produces, in SoA blocks of the executor's
/// lane width. Chunks are the steal granularity: too small and the deque
/// traffic dominates, too large and stealing cannot rebalance. At the
/// default width this is the pre-parameterization `4 * LANES = 32`
/// elements, so the default split — and with it the committed
/// `parallel/model-scaling-*` baselines — is unchanged.
const MIN_CHUNK_BLOCKS: usize = 4;

/// Target number of chunks per worker, so idle workers always find
/// something to steal while the batch is in flight.
const CHUNKS_PER_WORKER: usize = 4;

/// How long an idle worker parks between wakeup checks. The wake protocol
/// notifies on every submit; the timeout only bounds the cost of a lost
/// race, it is not the steady-state latency.
const IDLE_PARK: Duration = Duration::from_millis(1);

/// The block-aligned chunk split for a batch: `(chunk_len, n_chunks)`
/// over the `full` block-aligned prefix (`full % block == 0`, where
/// `block` is the executor's SoA lane width — [`super::lanes::LANES`]
/// by default).
/// Exposed so the bench model (`benches/bench_parallel.rs`) and the gate
/// (`python/tools/check_bench.py`) reason about the *actual* splitting
/// policy rather than a parallel re-implementation of it.
pub fn chunk_plan(full: usize, workers: usize, block: usize) -> (usize, usize) {
    debug_assert!(block > 0, "chunk_plan needs a positive block width");
    debug_assert_eq!(full % block, 0, "chunk_plan takes the block-aligned prefix");
    let min_chunk = MIN_CHUNK_BLOCKS * block;
    if full == 0 {
        return (min_chunk, 0);
    }
    let target = (full / (workers.max(1) * CHUNKS_PER_WORKER)).max(min_chunk);
    // Round up to a block multiple so every chunk boundary is a block
    // boundary — the parallel block decomposition is then identical to
    // the sequential one, which is what makes the outputs bit-exact.
    let chunk = target.div_ceil(block) * block;
    (chunk, full.div_ceil(chunk))
}

/// One chunk's worth of per-slot stats, written by exactly one executor
/// thread and read by the submitter only after the completion barrier.
struct StatSlot(std::cell::UnsafeCell<ExecStats>);

// SAFETY: each slot is written by the single thread that executes its
// chunk (disjoint indices), and read by the submitting thread only after
// `BatchJob::remaining` has reached zero — the AcqRel decrement plus the
// completion-mutex handoff order every write before every read.
unsafe impl Sync for StatSlot {}

/// A batch in flight: type-erased pointers into the caller's slices plus
/// the completion state. The submitting thread keeps the borrows alive
/// for the whole job lifetime (it blocks in [`Executor::execute_batch`]
/// until `remaining == 0`), which is what makes the raw pointers sound.
struct BatchJob {
    plan: *const Plan,
    a: *const U128,
    b: *const U128,
    /// Output base for the lane-aligned prefix (disjoint per-chunk
    /// ranges; the ragged tail is a separate slice on the submitter).
    out: *mut U256,
    full: usize,
    chunk: usize,
    n_chunks: usize,
    stats: Box<[StatSlot]>,
    remaining: AtomicUsize,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: the pointees are borrowed slices owned by the submitting
// thread, which outlives the job (see `BatchJob` docs); all mutable
// access is to disjoint chunk ranges, and the completion protocol
// (AcqRel `remaining` + mutex) sequences writes before the final read.
unsafe impl Send for BatchJob {}
unsafe impl Sync for BatchJob {}

impl BatchJob {
    /// Element range of chunk `index`.
    #[inline]
    fn range(&self, index: usize) -> (usize, usize) {
        let start = index * self.chunk;
        (start, (start + self.chunk).min(self.full))
    }

    /// Record one finished chunk; the last one flips the done flag and
    /// wakes the submitter.
    fn complete_one(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = self.done.lock().unwrap();
            *done = true;
            self.done_cv.notify_all();
        }
    }
}

/// One queued chunk.
struct Task {
    job: Arc<BatchJob>,
    index: usize,
}

/// Steal/execute counters for one worker (see [`Executor::counters`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerCounters {
    /// Chunks this worker executed (own-queue pops and steals).
    pub executed: u64,
    /// Of those, chunks stolen from another worker's deque.
    pub stolen: u64,
}

/// Point-in-time executor telemetry (see [`Executor::counters`]).
#[derive(Clone, Debug, Default)]
pub struct ExecutorCounters {
    /// Per-worker execute/steal counts.
    pub workers: Vec<WorkerCounters>,
    /// Chunks executed inline by submitting threads while helping.
    pub helper_executed: u64,
    /// Batches that took the parallel fan-out path.
    pub parallel_batches: u64,
    /// Batches below the threshold that stayed single-threaded.
    pub sequential_batches: u64,
}

struct ExecShared {
    /// One chunk deque per worker. Owners pop the front; thieves pop the
    /// back of the deepest deque.
    queues: Vec<Mutex<VecDeque<Task>>>,
    /// Queue depths mirrored outside the locks so the busiest-queue scan
    /// is lock-free.
    depths: Vec<AtomicUsize>,
    /// Idle-park mutex/condvar pair for workers with empty deques.
    idle: Mutex<()>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    /// Round-robin cursor so successive batches start scattering at
    /// different queues (keeps concurrent submitters off one deque).
    next_queue: AtomicUsize,
    executed: Vec<AtomicU64>,
    stolen: Vec<AtomicU64>,
    helper_executed: AtomicU64,
    parallel_batches: AtomicU64,
    sequential_batches: AtomicU64,
    /// Lane configuration every chunk executes under (chunk boundaries
    /// are aligned to its width, so parallel ≡ sequential stays exact at
    /// every width/ISA).
    lane: LaneConfig,
}

impl ExecShared {
    /// Pop from worker `i`'s own deque (front — FIFO keeps chunk latency
    /// roughly submission-ordered).
    fn pop_local(&self, i: usize) -> Option<Task> {
        let task = self.queues[i].lock().unwrap().pop_front();
        if task.is_some() {
            self.depths[i].fetch_sub(1, Ordering::Relaxed);
        }
        task
    }

    /// Steal from the back of the busiest deque (`!= me` when `me` is a
    /// worker; submitting threads pass `None` and may take from anyone).
    fn steal(&self, me: Option<usize>) -> Option<Task> {
        loop {
            let mut best = None;
            let mut best_depth = 0;
            for (j, depth) in self.depths.iter().enumerate() {
                if Some(j) == me {
                    continue;
                }
                let d = depth.load(Ordering::Relaxed);
                if d > best_depth {
                    best_depth = d;
                    best = Some(j);
                }
            }
            let j = best?;
            let task = self.queues[j].lock().unwrap().pop_back();
            match task {
                Some(t) => {
                    self.depths[j].fetch_sub(1, Ordering::Relaxed);
                    return Some(t);
                }
                // Raced another thief for the last chunk — rescan.
                None => continue,
            }
        }
    }

    /// Execute one chunk: run the lane kernel over the chunk's range into
    /// `scratch`, copy into the job's disjoint output range, park the
    /// stats in the chunk's slot, and tick the completion count.
    fn run_task(&self, task: Task, scratch: &mut Vec<U256>) {
        let job = &*task.job;
        let (start, end) = job.range(task.index);
        // SAFETY: the submitting thread keeps the slices alive until the
        // job completes, and `[start, end)` ranges are disjoint per chunk
        // (see `BatchJob`).
        let (plan, a, b) = unsafe {
            (
                &*job.plan,
                std::slice::from_raw_parts(job.a.add(start), end - start),
                std::slice::from_raw_parts(job.b.add(start), end - start),
            )
        };
        let mut stats = ExecStats::default();
        plan.execute_lanes_cfg(self.lane, a, b, &mut stats, scratch);
        unsafe {
            std::ptr::copy_nonoverlapping(scratch.as_ptr(), job.out.add(start), end - start);
            *job.stats[task.index].0.get() = stats;
        }
        job.complete_one();
    }

    fn worker_loop(&self, i: usize) {
        let mut scratch: Vec<U256> = Vec::new();
        loop {
            if let Some(task) = self.pop_local(i) {
                self.run_task(task, &mut scratch);
                self.executed[i].fetch_add(1, Ordering::Relaxed);
                continue;
            }
            if let Some(task) = self.steal(Some(i)) {
                self.run_task(task, &mut scratch);
                self.executed[i].fetch_add(1, Ordering::Relaxed);
                self.stolen[i].fetch_add(1, Ordering::Relaxed);
                continue;
            }
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            // Park until a submit notifies (the timeout only bounds a
            // lost wakeup race; submits always notify under this mutex).
            let guard = self.idle.lock().unwrap();
            if self.depths.iter().any(|d| d.load(Ordering::Relaxed) > 0)
                || self.shutdown.load(Ordering::Acquire)
            {
                continue;
            }
            let _unused = self.work_cv.wait_timeout(guard, IDLE_PARK).unwrap();
        }
    }
}

/// The shared work-stealing batch executor (see the module docs).
///
/// One `Executor` is created per process deployment (the CLI builds it
/// from `--cores` / `service.cores`) and shared by every
/// [`crate::coordinator::NativeBackend`] via `Arc` — the worker pool is a
/// machine resource, not a per-backend one.
///
/// ```
/// use civp::decomp::{Executor, ExecStats, OpClass, PlanCache, SchemeKind};
/// use civp::proput::Rng;
///
/// let exec = Executor::with_threshold(2, 64);
/// let plan = PlanCache::get(SchemeKind::Civp, OpClass::Double);
/// let mut rng = Rng::new(7);
/// let a: Vec<_> = (0..200).map(|_| rng.sig(53)).collect();
/// let b: Vec<_> = (0..200).map(|_| rng.sig(53)).collect();
/// let (mut seq, mut par) = (ExecStats::default(), ExecStats::default());
/// let (mut out_seq, mut out_par) = (Vec::new(), Vec::new());
/// plan.execute_batch(&a, &b, &mut seq, &mut out_seq);
/// exec.execute_batch(&plan, &a, &b, &mut par, &mut out_par);
/// assert_eq!(out_seq, out_par); // bit-for-bit, stats included
/// assert_eq!(seq.muls, par.muls);
/// ```
pub struct Executor {
    shared: Arc<ExecShared>,
    workers: Vec<JoinHandle<()>>,
    threshold: usize,
}

impl Executor {
    /// Spawn an executor with `workers` worker threads and the default
    /// parallel threshold ([`DEFAULT_PAR_THRESHOLD`]).
    pub fn new(workers: usize) -> Executor {
        Self::with_threshold(workers, DEFAULT_PAR_THRESHOLD)
    }

    /// Spawn an executor with an explicit parallel threshold: batches
    /// shorter than `par_threshold` run the single-threaded lane path on
    /// the submitting thread, untouched. Uses the scalar default lane
    /// configuration (`W = 8`).
    pub fn with_threshold(workers: usize, par_threshold: usize) -> Executor {
        Self::with_config(workers, par_threshold, LaneConfig::SCALAR)
    }

    /// Spawn an executor with an explicit parallel threshold and lane
    /// configuration. Chunk boundaries are aligned to the configured
    /// width, so every chunk is whole SoA blocks and the parallel
    /// decomposition equals the sequential one at that width.
    pub fn with_config(workers: usize, par_threshold: usize, lane: LaneConfig) -> Executor {
        let n = workers.max(1);
        let shared = Arc::new(ExecShared {
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            depths: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            idle: Mutex::new(()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_queue: AtomicUsize::new(0),
            executed: (0..n).map(|_| AtomicU64::new(0)).collect(),
            stolen: (0..n).map(|_| AtomicU64::new(0)).collect(),
            helper_executed: AtomicU64::new(0),
            parallel_batches: AtomicU64::new(0),
            sequential_batches: AtomicU64::new(0),
            lane,
        });
        let handles = (0..n)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("civp-par-{i}"))
                    .spawn(move || shared.worker_loop(i))
                    .expect("spawn executor worker")
            })
            .collect();
        Executor { shared, workers: handles, threshold: par_threshold.max(1) }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// The configured parallel threshold.
    pub fn par_threshold(&self) -> usize {
        self.threshold
    }

    /// The lane configuration (SoA width × vector ISA) every chunk
    /// executes under.
    pub fn lane_config(&self) -> LaneConfig {
        self.shared.lane
    }

    /// Execute a whole batch through the compiled plan — the parallel
    /// counterpart of [`Plan::execute_batch`], and bit-for-bit identical
    /// to it: products, output order and the stats merged into `stats`
    /// (per-chunk stats are merged deterministically in chunk order).
    ///
    /// Batches shorter than the threshold (or too small to split into
    /// two chunks) run the sequential lane path inline.
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    pub fn execute_batch(
        &self,
        plan: &Plan,
        a: &[U128],
        b: &[U128],
        stats: &mut ExecStats,
        out: &mut Vec<U256>,
    ) {
        assert_eq!(a.len(), b.len(), "operand length mismatch");
        let n = a.len();
        let block = self.shared.lane.width.width();
        let full = n - n % block;
        let (chunk, n_chunks) = chunk_plan(full, self.workers.len(), block);
        if n < self.threshold || n_chunks < 2 {
            self.shared.sequential_batches.fetch_add(1, Ordering::Relaxed);
            plan.execute_batch_cfg(self.shared.lane, a, b, stats, out);
            return;
        }
        self.shared.parallel_batches.fetch_add(1, Ordering::Relaxed);
        out.clear();
        out.resize(n, U256::ZERO);
        let (body, tail_out) = out.split_at_mut(full);
        let job = Arc::new(BatchJob {
            plan,
            a: a.as_ptr(),
            b: b.as_ptr(),
            out: body.as_mut_ptr(),
            full,
            chunk,
            n_chunks,
            stats: (0..n_chunks)
                .map(|_| StatSlot(std::cell::UnsafeCell::new(ExecStats::default())))
                .collect(),
            remaining: AtomicUsize::new(n_chunks),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        // Scatter the chunks round-robin across the worker deques,
        // starting at a rotating queue, then wake everyone.
        let shared = &*self.shared;
        let start = shared.next_queue.fetch_add(1, Ordering::Relaxed);
        for index in 0..n_chunks {
            let q = (start + index) % shared.queues.len();
            shared.queues[q].lock().unwrap().push_back(Task { job: job.clone(), index });
            shared.depths[q].fetch_add(1, Ordering::Relaxed);
        }
        {
            let _guard = shared.idle.lock().unwrap();
            shared.work_cv.notify_all();
        }
        // The scalar ragged tail stays on the submitting thread.
        let mut tail_stats = ExecStats::default();
        for (slot, (&x, &y)) in tail_out.iter_mut().zip(a[full..].iter().zip(&b[full..])) {
            *slot = plan.execute(x, y, &mut tail_stats);
        }
        // Help drain while the batch is in flight, then park on the
        // completion condvar.
        let mut scratch: Vec<U256> = Vec::new();
        while job.remaining.load(Ordering::Acquire) > 0 {
            match shared.steal(None) {
                Some(task) => {
                    shared.run_task(task, &mut scratch);
                    shared.helper_executed.fetch_add(1, Ordering::Relaxed);
                }
                None => {
                    let mut done = job.done.lock().unwrap();
                    while !*done {
                        done = job.done_cv.wait(done).unwrap();
                    }
                    break;
                }
            }
        }
        // Deterministic merge: chunk slots in chunk order, then the tail.
        // SAFETY: `remaining == 0` (AcqRel handoff) — every slot write
        // happened-before this read and no thread touches the job again.
        for slot in job.stats.iter() {
            stats.merge(unsafe { &*slot.0.get() });
        }
        stats.merge(&tail_stats);
    }

    /// Snapshot of the per-worker steal/execute counters and batch-path
    /// totals.
    pub fn counters(&self) -> ExecutorCounters {
        let s = &*self.shared;
        ExecutorCounters {
            workers: (0..self.workers.len())
                .map(|i| WorkerCounters {
                    executed: s.executed[i].load(Ordering::Relaxed),
                    stolen: s.stolen[i].load(Ordering::Relaxed),
                })
                .collect(),
            helper_executed: s.helper_executed.load(Ordering::Relaxed),
            parallel_batches: s.parallel_batches.load(Ordering::Relaxed),
            sequential_batches: s.sequential_batches.load(Ordering::Relaxed),
        }
    }

    /// Publish the executor telemetry into a metrics registry as gauges
    /// (`par_worker{i}_executed` / `par_worker{i}_stolen` /
    /// `par_helper_executed` / `par_batches_{parallel,sequential}`).
    /// Gauges, not counters: the executor owns the monotonic state and a
    /// snapshot publisher must be idempotent.
    pub fn publish(&self, registry: &crate::metrics::Registry) {
        let c = self.counters();
        for (i, w) in c.workers.iter().enumerate() {
            registry.gauge(&format!("par_worker{i}_executed")).set(w.executed as i64);
            registry.gauge(&format!("par_worker{i}_stolen")).set(w.stolen as i64);
        }
        registry.gauge("par_helper_executed").set(c.helper_executed as i64);
        registry.gauge("par_batches_parallel").set(c.parallel_batches as i64);
        registry.gauge("par_batches_sequential").set(c.sequential_batches as i64);
        registry.gauge("par_lane_width").set(self.shared.lane.width.width() as i64);
    }
}

impl std::fmt::Debug for Executor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executor")
            .field("workers", &self.workers.len())
            .field("par_threshold", &self.threshold)
            .finish()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        // No batch can be in flight here (`execute_batch` borrows `self`
        // until its job completes), so the deques are empty.
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _guard = self.shared.idle.lock().unwrap();
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{LaneWidth, OpClass, PlanCache, SchemeKind, SimdIsa, LANES};
    use crate::proput::Rng;

    #[test]
    fn chunk_plan_is_block_aligned_and_covers_every_width() {
        for width in LaneWidth::ALL {
            let block = width.width();
            for workers in 1..=8 {
                for n in [0usize, 8, 64, 256, 1000, 4096, 65536] {
                    let full = n - n % block;
                    let (chunk, count) = chunk_plan(full, workers, block);
                    assert_eq!(chunk % block, 0, "chunk not block-aligned");
                    assert!(chunk >= MIN_CHUNK_BLOCKS * block);
                    if full == 0 {
                        assert_eq!(count, 0);
                    } else {
                        assert_eq!(count, full.div_ceil(chunk));
                        assert!((count - 1) * chunk < full && count * chunk >= full);
                    }
                }
            }
        }
    }

    /// The default width must reproduce the pre-parameterization split
    /// exactly — the committed `parallel/model-scaling-*` baselines are
    /// derived from it.
    #[test]
    fn default_width_split_matches_legacy_constants() {
        assert_eq!(chunk_plan(0, 4, LANES), (32, 0));
        assert_eq!(chunk_plan(1024, 4, LANES), (64, 16));
        assert_eq!(chunk_plan(8192, 8, LANES), (256, 32));
    }

    #[test]
    fn executor_carries_its_lane_config() {
        let cfg = LaneConfig { width: LaneWidth::W16, isa: SimdIsa::Scalar };
        let exec = Executor::with_config(2, 64, cfg);
        assert_eq!(exec.lane_config(), cfg);
        let plan = PlanCache::get(SchemeKind::Civp, OpClass::Double);
        let mut rng = Rng::new(17);
        let n = 333; // ragged under both widths
        let a: Vec<U128> = (0..n).map(|_| rng.sig(53)).collect();
        let b: Vec<U128> = (0..n).map(|_| rng.sig(53)).collect();
        let (mut seq, mut par) = (ExecStats::default(), ExecStats::default());
        let (mut out_seq, mut out_par) = (Vec::new(), Vec::new());
        plan.execute_batch(&a, &b, &mut seq, &mut out_seq);
        exec.execute_batch(&plan, &a, &b, &mut par, &mut out_par);
        assert_eq!(out_seq, out_par, "W16 executor diverges from scalar sequential");
        assert_eq!(seq.muls, par.muls);
    }

    #[test]
    fn below_threshold_stays_sequential() {
        let exec = Executor::with_threshold(2, 256);
        let plan = PlanCache::get(SchemeKind::Civp, OpClass::Double);
        let mut rng = Rng::new(3);
        let a: Vec<U128> = (0..100).map(|_| rng.sig(53)).collect();
        let b: Vec<U128> = (0..100).map(|_| rng.sig(53)).collect();
        let mut stats = ExecStats::default();
        let mut out = Vec::new();
        exec.execute_batch(&plan, &a, &b, &mut stats, &mut out);
        let c = exec.counters();
        assert_eq!(c.sequential_batches, 1);
        assert_eq!(c.parallel_batches, 0);
        assert_eq!(stats.muls, 100);
    }

    #[test]
    fn parallel_path_counts_and_matches() {
        let exec = Executor::with_threshold(3, 64);
        let plan = PlanCache::get(SchemeKind::Civp, OpClass::Quad);
        let mut rng = Rng::new(11);
        let n = 777; // ragged tail of 1
        let a: Vec<U128> = (0..n).map(|_| rng.sig(113)).collect();
        let b: Vec<U128> = (0..n).map(|_| rng.sig(113)).collect();
        let (mut seq, mut par) = (ExecStats::default(), ExecStats::default());
        let (mut out_seq, mut out_par) = (Vec::new(), Vec::new());
        plan.execute_batch(&a, &b, &mut seq, &mut out_seq);
        exec.execute_batch(&plan, &a, &b, &mut par, &mut out_par);
        assert_eq!(out_seq, out_par);
        assert_eq!(seq.muls, par.muls);
        assert_eq!(seq.tiles, par.tiles);
        assert_eq!(seq.useful_bitops, par.useful_bitops);
        let c = exec.counters();
        assert_eq!(c.parallel_batches, 1);
        let ran: u64 =
            c.workers.iter().map(|w| w.executed).sum::<u64>() + c.helper_executed;
        let full = n - n % LANES;
        let (_, chunks) = chunk_plan(full, exec.workers(), LANES);
        assert_eq!(ran as usize, chunks, "every chunk executed exactly once");
    }

    #[test]
    fn publish_exports_gauges() {
        let exec = Executor::with_threshold(2, 32);
        let plan = PlanCache::get(SchemeKind::Civp, OpClass::Single);
        let mut rng = Rng::new(5);
        let a: Vec<U128> = (0..512).map(|_| rng.sig(24)).collect();
        let b: Vec<U128> = (0..512).map(|_| rng.sig(24)).collect();
        let mut stats = ExecStats::default();
        let mut out = Vec::new();
        exec.execute_batch(&plan, &a, &b, &mut stats, &mut out);
        let registry = crate::metrics::Registry::new();
        exec.publish(&registry);
        let snap = registry.snapshot();
        assert!(snap.gauges.contains_key("par_worker0_executed"));
        assert!(snap.gauges.contains_key("par_worker1_stolen"));
        assert_eq!(snap.gauges["par_batches_parallel"], 1);
    }
}
