//! Bench-harness support (the build environment has no registry access, so
//! `criterion` is unavailable; this module provides the timing loop the
//! `benches/` targets share).
//!
//! Protocol per measurement: warmup iterations, then `samples` timed
//! batches of `iters_per_sample` calls; reports ns/op at p50 (median of
//! batch means), mean, and min — the same summary criterion prints. Batch
//! results are black-boxed to keep the optimizer honest.

use std::hint::black_box;
use std::time::Instant;

/// One measurement result.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Median ns/op across samples.
    pub ns_per_op_p50: f64,
    /// Mean ns/op.
    pub ns_per_op_mean: f64,
    /// Fastest sample's ns/op.
    pub ns_per_op_min: f64,
    /// Total ops timed.
    pub total_ops: u64,
}

impl Measurement {
    /// Ops per second at the median.
    pub fn ops_per_sec(&self) -> f64 {
        if self.ns_per_op_p50 == 0.0 {
            return f64::INFINITY;
        }
        1e9 / self.ns_per_op_p50
    }
}

/// Time `op` (which should perform ONE operation per call).
pub fn bench(name: &str, warmup: u64, samples: u64, iters_per_sample: u64, mut op: impl FnMut()) -> Measurement {
    for _ in 0..warmup {
        op();
    }
    let mut per_sample = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            op();
        }
        let dt = t0.elapsed().as_nanos() as f64;
        per_sample.push(dt / iters_per_sample as f64);
    }
    per_sample.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let m = Measurement {
        ns_per_op_p50: per_sample[per_sample.len() / 2],
        ns_per_op_mean: per_sample.iter().sum::<f64>() / per_sample.len() as f64,
        ns_per_op_min: per_sample[0],
        total_ops: samples * iters_per_sample,
    };
    println!(
        "{name:<44} {:>10.1} ns/op (p50)   {:>10.1} ns/op (min)   {:>12.0} op/s",
        m.ns_per_op_p50,
        m.ns_per_op_min,
        m.ops_per_sec()
    );
    m
}

/// Convenience: black-box a value (re-export for benches).
pub fn bb<T>(v: T) -> T {
    black_box(v)
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a table row (generic alignment helper).
pub fn row(cols: &[&str], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cols.iter().zip(widths) {
        line.push_str(&format!("{c:<w$} ", w = w));
    }
    println!("{}", line.trim_end());
}
