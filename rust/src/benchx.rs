//! Bench-harness support (the build environment has no registry access, so
//! `criterion` is unavailable; this module provides the timing loop the
//! `benches/` targets share).
//!
//! Protocol per measurement: warmup iterations, then `samples` timed
//! batches of `iters_per_sample` calls; reports ns/op at p50 (median of
//! batch means), mean, and min — the same summary criterion prints. Batch
//! results are black-boxed to keep the optimizer honest.
//!
//! Two additions for perf-trajectory tracking (§Perf):
//!
//! * **quick mode** — setting `CIVP_BENCH_QUICK=1` divides iteration
//!   counts (see [`scaled`]), so CI can smoke-run every bench target in
//!   seconds and catch harness rot without paying full measurement cost;
//! * **machine-readable output** — a [`JsonReport`] collects named
//!   measurements and writes them as a JSON array (`name`, `ns_per_op_*`,
//!   `ops_per_sec`), which the benches emit as `BENCH_*.json` at the repo
//!   root so every run leaves a comparable artifact.

use std::hint::black_box;
use std::time::Instant;

/// One measurement result.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Median ns/op across samples.
    pub ns_per_op_p50: f64,
    /// Mean ns/op.
    pub ns_per_op_mean: f64,
    /// Fastest sample's ns/op.
    pub ns_per_op_min: f64,
    /// Total ops timed.
    pub total_ops: u64,
}

impl Measurement {
    /// Ops per second at the median.
    pub fn ops_per_sec(&self) -> f64 {
        if self.ns_per_op_p50 == 0.0 {
            return f64::INFINITY;
        }
        1e9 / self.ns_per_op_p50
    }

    /// A degenerate measurement where every percentile equals
    /// `ns_per_op` — the shape for one-shot wall timings and
    /// closed-form model rows, which have no sample distribution.
    pub fn uniform(ns_per_op: f64, total_ops: u64) -> Measurement {
        Measurement {
            ns_per_op_p50: ns_per_op,
            ns_per_op_mean: ns_per_op,
            ns_per_op_min: ns_per_op,
            total_ops,
        }
    }

    /// Median speedup of `self` over `slower` (`slower p50 / self p50`):
    /// ≥ 1.0 means `self` is at least as fast. The ratio the bench
    /// verdict tables and the JSON gate invariants are computed from.
    pub fn p50_speedup_over(&self, slower: &Measurement) -> f64 {
        slower.ns_per_op_p50 / self.ns_per_op_p50
    }
}

/// Time `op` (which should perform ONE operation per call).
pub fn bench(
    name: &str,
    warmup: u64,
    samples: u64,
    iters_per_sample: u64,
    mut op: impl FnMut(),
) -> Measurement {
    for _ in 0..warmup {
        op();
    }
    let mut per_sample = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters_per_sample {
            op();
        }
        let dt = t0.elapsed().as_nanos() as f64;
        per_sample.push(dt / iters_per_sample as f64);
    }
    // total_cmp: a NaN sample (e.g. a zero-duration division artifact)
    // must sort deterministically instead of panicking the harness.
    per_sample.sort_by(f64::total_cmp);
    let m = Measurement {
        ns_per_op_p50: per_sample[per_sample.len() / 2],
        ns_per_op_mean: per_sample.iter().sum::<f64>() / per_sample.len() as f64,
        ns_per_op_min: per_sample[0],
        total_ops: samples * iters_per_sample,
    };
    println!(
        "{name:<44} {:>10.1} ns/op (p50)   {:>10.1} ns/op (min)   {:>12.0} op/s",
        m.ns_per_op_p50,
        m.ns_per_op_min,
        m.ops_per_sec()
    );
    m
}

/// Convenience: black-box a value (re-export for benches).
pub fn bb<T>(v: T) -> T {
    black_box(v)
}

/// Wrap one wall-clock run (`ops` completed in `wall_s` seconds) as a
/// [`Measurement`], so one-shot end-to-end timings land in the JSON
/// artifacts alongside the sampled benches.
pub fn wall_measurement(ops: u64, wall_s: f64) -> Measurement {
    Measurement::uniform(wall_s * 1e9 / ops.max(1) as f64, ops)
}

/// Render the bench-closing speedup table shared by the lane/format
/// benches: one `label  N.NNx faster|SLOWER` line per entry (ratios from
/// [`Measurement::p50_speedup_over`]), then `PASS: {pass}` when every
/// entry is ≥ 1.0 or `FAIL: {fail}` otherwise. Returns that verdict so
/// callers can also assert on it.
pub fn verdict_table(title: &str, rows: &[(String, f64)], pass: &str, fail: &str) -> bool {
    section(title);
    let mut all_faster = true;
    for (label, speedup) in rows {
        let verdict = if *speedup >= 1.0 { "faster" } else { "SLOWER" };
        println!("{label:<20} {speedup:>6.2}x {verdict}");
        all_faster &= *speedup >= 1.0;
    }
    println!("\n{}", if all_faster { format!("PASS: {pass}") } else { format!("FAIL: {fail}") });
    all_faster
}

/// True when `CIVP_BENCH_QUICK` is set (to anything but `0`): benches
/// should shrink workloads so a CI smoke run finishes in seconds.
pub fn quick() -> bool {
    std::env::var("CIVP_BENCH_QUICK").map(|v| v != "0").unwrap_or(false)
}

/// Scale an iteration/request count for the current mode: full value
/// normally, `1/50th` (min 1) in quick mode.
pub fn scaled(n: u64) -> u64 {
    if quick() {
        (n / 50).max(1)
    } else {
        n
    }
}

/// Collects named [`Measurement`]s and renders them as a JSON array —
/// the machine-readable artifact (`BENCH_*.json`) the benches write at
/// the repo root. Hand-rolled serialization (no serde offline).
#[derive(Clone, Debug, Default)]
pub struct JsonReport {
    entries: Vec<(String, Measurement)>,
}

impl JsonReport {
    /// New empty report.
    pub fn new() -> JsonReport {
        JsonReport::default()
    }

    /// Record one named measurement.
    pub fn push(&mut self, name: &str, m: Measurement) {
        self.entries.push((name.to_string(), m));
    }

    /// Render as a JSON array of objects.
    pub fn to_json(&self) -> String {
        fn num(v: f64) -> String {
            // JSON has no NaN/Infinity; clamp degenerate measurements.
            if v.is_finite() {
                format!("{v:.3}")
            } else {
                "0.0".to_string()
            }
        }
        let mut out = String::from("[\n");
        for (i, (name, m)) in self.entries.iter().enumerate() {
            // Bench names are ASCII identifiers/labels; escape the two
            // characters that could break a JSON string anyway.
            let esc: String = name
                .chars()
                .flat_map(|c| match c {
                    '"' => vec!['\\', '"'],
                    '\\' => vec!['\\', '\\'],
                    c => vec![c],
                })
                .collect();
            out.push_str(&format!(
                "  {{\"name\": \"{esc}\", \"ns_per_op_p50\": {}, \"ns_per_op_mean\": {}, \"ns_per_op_min\": {}, \"ops_per_sec\": {}, \"total_ops\": {}}}{}\n",
                num(m.ns_per_op_p50),
                num(m.ns_per_op_mean),
                num(m.ns_per_op_min),
                num(m.ops_per_sec()),
                m.total_ops,
                if i + 1 < self.entries.len() { "," } else { "" }
            ));
        }
        out.push_str("]\n");
        out
    }

    /// Write the JSON to `path` and print a pointer line.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())?;
        println!("wrote {path} ({} measurements)", self.entries.len());
        Ok(())
    }
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a table row (generic alignment helper).
pub fn row(cols: &[&str], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cols.iter().zip(widths) {
        line.push_str(&format!("{c:<w$} ", w = w));
    }
    println!("{}", line.trim_end());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_measurement_and_speedup() {
        let slow = Measurement::uniform(4.0, 10);
        let fast = Measurement::uniform(2.0, 10);
        assert_eq!(slow.ns_per_op_p50, slow.ns_per_op_min);
        assert_eq!(slow.ns_per_op_p50, slow.ns_per_op_mean);
        assert_eq!(fast.p50_speedup_over(&slow), 2.0);
        assert_eq!(slow.p50_speedup_over(&fast), 0.5);
        assert_eq!(wall_measurement(10, 40e-9).ns_per_op_p50, 4.0);
    }

    #[test]
    fn verdict_table_verdict() {
        let ok = vec![("a".to_string(), 1.5), ("b".to_string(), 1.0)];
        assert!(verdict_table("t", &ok, "p", "f"));
        let bad = vec![("a".to_string(), 1.5), ("b".to_string(), 0.9)];
        assert!(!verdict_table("t", &bad, "p", "f"));
    }

    #[test]
    fn json_report_shape() {
        let mut r = JsonReport::new();
        r.push(
            "label \"quoted\"",
            Measurement {
                ns_per_op_p50: 1.5,
                ns_per_op_mean: 2.0,
                ns_per_op_min: 1.0,
                total_ops: 10,
            },
        );
        r.push(
            "degenerate",
            Measurement {
                ns_per_op_p50: 0.0,
                ns_per_op_mean: 0.0,
                ns_per_op_min: 0.0,
                total_ops: 0,
            },
        );
        let j = r.to_json();
        assert!(j.trim_start().starts_with('['));
        assert!(j.trim_end().ends_with(']'));
        assert_eq!(j.matches('{').count(), 2);
        assert_eq!(j.matches('}').count(), 2);
        assert!(j.contains("\"ns_per_op_p50\": 1.500"));
        assert!(j.contains("label \\\"quoted\\\""));
        // p50 == 0 makes ops_per_sec infinite; JSON has no Infinity, so it
        // is clamped to 0.0.
        assert!(j.contains("\"ops_per_sec\": 0.0"));
        // exactly one separating comma between the two objects
        assert_eq!(j.matches("},").count(), 1);
    }
}
