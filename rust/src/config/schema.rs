//! Typed service configuration with defaults, file loading and validation.

use super::toml::{parse_toml, TomlValue};
use crate::decomp::{OpClass, SchemeKind};
use crate::fabric::FabricKind;
use crate::trace::{WorkloadMix, WorkloadSpec};
use crate::error::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Default per-connection writer-queue bound for the network edge (the
/// value `service.net_writer_queue` and `--writer-queue` default to —
/// matches the constant the PR-8 thread-per-connection listener used).
pub const DEFAULT_NET_WRITER_QUEUE: usize = 256;

/// Everything `civp-server` needs to run. Every field has a default; a
/// config file overrides selectively.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceConfig {
    /// Artifacts directory (HLO + manifest).
    pub artifacts_dir: String,
    /// Worker threads per precision queue.
    pub workers: usize,
    /// Work-stealing lane-executor cores (`--cores`). `0` disables the
    /// parallel executor: every batch runs single-threaded on its
    /// submitting service worker.
    pub cores: usize,
    /// Minimum batch size that fans out across the lane executor
    /// (`--par-threshold`); smaller batches stay sequential where the
    /// split/steal overhead would dominate.
    pub par_threshold: usize,
    /// SoA lane-block width (`--lane-width`): operands per
    /// structure-of-arrays block on the batch path. One of 8, 16 or 32;
    /// every width is bit-identical, wider blocks feed the wider SIMD
    /// sweeps when the `simd` feature and the host ISA allow it.
    pub lane_width: usize,
    /// Max requests per batch (dispatch earlier on timeout).
    pub max_batch: usize,
    /// Batch linger: how long to wait filling a batch, in microseconds.
    pub linger_us: u64,
    /// Bounded queue depth per precision (backpressure beyond this).
    pub queue_depth: usize,
    /// Network edge: per-connection bound on responses queued for the
    /// socket (`service.net_writer_queue` / `--writer-queue`). When a
    /// connection has this many responses waiting, its worker stops
    /// reading the socket — the mechanism that turns a slow reader into
    /// TCP backpressure instead of unbounded buffering.
    pub net_writer_queue: usize,
    /// Partition organization for the simulated fabric accounting.
    pub scheme: SchemeKind,
    /// Fabric preset to account against.
    pub fabric: FabricKind,
    /// Fabric scale (number of quad-columns).
    pub fabric_scale: u32,
    /// Workload for built-in generators.
    pub workload: WorkloadSpec,
    /// Explicit per-class weight overrides (`workload.mix_<class>` TOML
    /// keys or the CLI `--mix` option). When any weight is set the custom
    /// mix replaces the named spec's distribution.
    pub custom_mix: Option<WorkloadMix>,
    /// Number of requests for batch/bench runs.
    pub requests: usize,
    /// PRNG seed.
    pub seed: u64,
    /// Use the PJRT engine (false = native softfloat backend only).
    pub use_pjrt: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            artifacts_dir: "artifacts".to_string(),
            workers: 2,
            cores: 0,
            par_threshold: crate::decomp::DEFAULT_PAR_THRESHOLD,
            lane_width: crate::decomp::LANES,
            max_batch: 256,
            linger_us: 200,
            queue_depth: 4096,
            net_writer_queue: DEFAULT_NET_WRITER_QUEUE,
            scheme: SchemeKind::Civp,
            fabric: FabricKind::Civp,
            fabric_scale: 1,
            workload: WorkloadSpec::Graphics,
            custom_mix: None,
            requests: 10_000,
            seed: 20260710,
            use_pjrt: true,
        }
    }
}

impl ServiceConfig {
    /// Load from a TOML-subset file, overriding defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<ServiceConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-subset text.
    pub fn from_toml(text: &str) -> Result<ServiceConfig> {
        let kv = parse_toml(text)?;
        let mut cfg = ServiceConfig::default();
        cfg.apply(&kv)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// The effective op-class mix: the custom per-class weights when any
    /// were configured, otherwise the named spec's distribution.
    pub fn mix(&self) -> WorkloadMix {
        self.custom_mix.unwrap_or_else(|| self.workload.mix())
    }

    /// Set one class's custom-mix weight (lazily initializing the custom
    /// mix to all-zero so only explicitly listed classes carry mass).
    pub fn set_mix_weight(&mut self, class: OpClass, weight: f64) -> Result<()> {
        if !weight.is_finite() || weight < 0.0 {
            bail!("mix weight for {} must be a finite non-negative number", class.name());
        }
        let mix = self.custom_mix.get_or_insert(WorkloadMix::ZERO);
        mix.weights[class.index()] = weight;
        Ok(())
    }

    fn apply(&mut self, kv: &BTreeMap<String, TomlValue>) -> Result<()> {
        for (key, value) in kv {
            match key.as_str() {
                "service.artifacts_dir" => self.artifacts_dir = req_str(key, value)?,
                "service.workers" => self.workers = req_usize(key, value)?,
                "service.cores" => self.cores = req_usize(key, value)?,
                "service.par_threshold" => self.par_threshold = req_usize(key, value)?,
                "service.lane_width" => self.lane_width = req_usize(key, value)?,
                "service.use_pjrt" => {
                    self.use_pjrt =
                        value.as_bool().with_context(|| format!("{key} must be bool"))?
                }
                "service.net_writer_queue" => self.net_writer_queue = req_usize(key, value)?,
                "batcher.max_batch" => self.max_batch = req_usize(key, value)?,
                "batcher.linger_us" => self.linger_us = req_usize(key, value)? as u64,
                "batcher.queue_depth" => self.queue_depth = req_usize(key, value)?,
                "fabric.scheme" => {
                    let s = req_str(key, value)?;
                    self.scheme = SchemeKind::parse(&s)
                        .with_context(|| format!("unknown scheme {s:?}"))?;
                }
                "fabric.kind" => {
                    let s = req_str(key, value)?;
                    self.fabric = match s.as_str() {
                        "civp" => FabricKind::Civp,
                        "legacy" => FabricKind::Legacy,
                        other => bail!("unknown fabric {other:?}"),
                    };
                }
                "fabric.scale" => self.fabric_scale = req_usize(key, value)? as u32,
                "workload.spec" => {
                    let s = req_str(key, value)?;
                    self.workload = WorkloadSpec::parse(&s)
                        .with_context(|| format!("unknown workload {s:?}"))?;
                }
                "workload.requests" => self.requests = req_usize(key, value)?,
                "workload.seed" => self.seed = req_usize(key, value)? as u64,
                other => {
                    // `workload.mix_<class>` — one optional weight per
                    // registry class; the accepted key set grows with the
                    // registry automatically.
                    if let Some(class) =
                        other.strip_prefix("workload.mix_").and_then(OpClass::parse)
                    {
                        let w = value
                            .as_float()
                            .with_context(|| format!("{key} must be a number"))?;
                        self.set_mix_weight(class, w)?;
                    } else {
                        bail!("unknown config key {other:?}");
                    }
                }
            }
        }
        Ok(())
    }

    /// Sanity-check field ranges.
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("service.workers must be >= 1");
        }
        if self.max_batch == 0 {
            bail!("batcher.max_batch must be >= 1");
        }
        if self.par_threshold == 0 {
            bail!("service.par_threshold must be >= 1");
        }
        if crate::decomp::LaneWidth::from_width(self.lane_width).is_none() {
            bail!(
                "service.lane_width must be one of 8, 16 or 32 (got {})",
                self.lane_width
            );
        }
        if self.net_writer_queue == 0 {
            bail!("service.net_writer_queue must be >= 1");
        }
        if self.queue_depth < self.max_batch {
            bail!(
                "batcher.queue_depth ({}) must be >= max_batch ({})",
                self.queue_depth,
                self.max_batch
            );
        }
        if self.fabric_scale == 0 {
            bail!("fabric.scale must be >= 1");
        }
        // Weights are individually finite and non-negative (enforced in
        // `set_mix_weight`), so a zero-or-less total means no mass at all.
        if let Some(mix) = &self.custom_mix {
            if mix.total() <= 0.0 {
                bail!("workload.mix_* weights must carry positive total mass");
            }
        }
        // scheme/fabric compatibility mirrors `FabricConfig::can_serve`:
        // CIVP tiles need 24x24/24x9 blocks (CIVP fabric only); 18x18 and
        // 25x18 tiles need the legacy fabric; 9x9 runs anywhere.
        let compatible = match self.scheme {
            // Karatsuba leaves compile to the CIVP tile vocabulary, so the
            // recursive organization has the same fabric needs as flat CIVP.
            SchemeKind::Civp | SchemeKind::Karatsuba24 => self.fabric == FabricKind::Civp,
            SchemeKind::Baseline18 | SchemeKind::Baseline25x18 => {
                self.fabric == FabricKind::Legacy
            }
            SchemeKind::Baseline9 => true,
        };
        if !compatible {
            bail!(
                "scheme {:?} cannot run on fabric {:?} (missing block kinds)",
                self.scheme,
                self.fabric
            );
        }
        Ok(())
    }
}

fn req_str(key: &str, v: &TomlValue) -> Result<String> {
    Ok(v.as_str().with_context(|| format!("{key} must be a string"))?.to_string())
}

fn req_usize(key: &str, v: &TomlValue) -> Result<usize> {
    let i = v.as_int().with_context(|| format!("{key} must be an integer"))?;
    if i < 0 {
        bail!("{key} must be non-negative");
    }
    Ok(i as usize)
}
