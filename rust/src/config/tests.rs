//! Config parser + schema tests.

use super::*;
use crate::decomp::SchemeKind;
use crate::fabric::FabricKind;
use crate::trace::WorkloadSpec;

#[test]
fn toml_scalars() {
    let kv = parse_toml(
        r#"
# top comment
name = "civp"   # trailing comment
count = 42
neg = -3
big = 1_000_000
hexv = 0xff
ratio = 0.5
sci = 1e3
on = true
off = false
[section]
key = "value"
"#,
    )
    .unwrap();
    assert_eq!(kv["name"], TomlValue::Str("civp".into()));
    assert_eq!(kv["count"], TomlValue::Int(42));
    assert_eq!(kv["neg"], TomlValue::Int(-3));
    assert_eq!(kv["big"], TomlValue::Int(1_000_000));
    assert_eq!(kv["hexv"], TomlValue::Int(255));
    assert_eq!(kv["ratio"], TomlValue::Float(0.5));
    assert_eq!(kv["sci"], TomlValue::Float(1000.0));
    assert_eq!(kv["on"], TomlValue::Bool(true));
    assert_eq!(kv["off"], TomlValue::Bool(false));
    assert_eq!(kv["section.key"], TomlValue::Str("value".into()));
}

#[test]
fn toml_hash_inside_string() {
    let kv = parse_toml(r##"path = "a#b""##).unwrap();
    assert_eq!(kv["path"], TomlValue::Str("a#b".into()));
}

#[test]
fn toml_errors() {
    assert!(parse_toml("[unterminated").is_err());
    assert!(parse_toml("no_equals_here").is_err());
    assert!(parse_toml("x = ").is_err());
    assert!(parse_toml("x = \"open").is_err());
    assert!(parse_toml("x = 1\nx = 2").is_err());
    assert!(parse_toml("= 5").is_err());
    assert!(parse_toml("x = what").is_err());
}

#[test]
fn value_accessors() {
    assert_eq!(TomlValue::Int(3).as_float(), Some(3.0));
    assert_eq!(TomlValue::Float(0.5).as_int(), None);
    assert_eq!(TomlValue::Str("x".into()).as_bool(), None);
    assert_eq!(TomlValue::Bool(true).as_bool(), Some(true));
}

#[test]
fn config_defaults() {
    let cfg = ServiceConfig::default();
    assert_eq!(cfg.scheme, SchemeKind::Civp);
    assert_eq!(cfg.fabric, FabricKind::Civp);
    cfg.validate().unwrap();
}

#[test]
fn config_overrides() {
    let cfg = ServiceConfig::from_toml(
        r#"
[service]
workers = 4
use_pjrt = false
[batcher]
max_batch = 64
linger_us = 50
queue_depth = 1024
[fabric]
scheme = "18x18"
kind = "legacy"
scale = 2
[workload]
spec = "scientific"
requests = 500
seed = 99
"#,
    )
    .unwrap();
    assert_eq!(cfg.workers, 4);
    assert!(!cfg.use_pjrt);
    assert_eq!(cfg.max_batch, 64);
    assert_eq!(cfg.linger_us, 50);
    assert_eq!(cfg.scheme, SchemeKind::Baseline18);
    assert_eq!(cfg.fabric, FabricKind::Legacy);
    assert_eq!(cfg.fabric_scale, 2);
    assert_eq!(cfg.workload, WorkloadSpec::Scientific);
    assert_eq!(cfg.requests, 500);
    assert_eq!(cfg.seed, 99);
}

#[test]
fn config_parallel_executor_keys() {
    // Defaults: parallel executor disabled, threshold at the library
    // default so a bare `--cores N` flag is immediately useful.
    let cfg = ServiceConfig::default();
    assert_eq!(cfg.cores, 0);
    assert_eq!(cfg.par_threshold, crate::decomp::DEFAULT_PAR_THRESHOLD);
    // Overrides round-trip.
    let cfg =
        ServiceConfig::from_toml("[service]\ncores = 4\npar_threshold = 128\n").unwrap();
    assert_eq!(cfg.cores, 4);
    assert_eq!(cfg.par_threshold, 128);
    // A zero threshold would make the sequential fallback unreachable.
    assert!(ServiceConfig::from_toml("[service]\npar_threshold = 0\n").is_err());
    // cores = 0 is the documented "disabled" value, not an error.
    ServiceConfig::from_toml("[service]\ncores = 0\n").unwrap();
}

#[test]
fn config_net_writer_queue_key() {
    // Default matches the constant the network edge uses.
    let cfg = ServiceConfig::default();
    assert_eq!(cfg.net_writer_queue, DEFAULT_NET_WRITER_QUEUE);
    assert_eq!(cfg.net_writer_queue, 256);
    // TOML override round-trips.
    let cfg = ServiceConfig::from_toml("[service]\nnet_writer_queue = 64\n").unwrap();
    assert_eq!(cfg.net_writer_queue, 64);
    // A zero bound would mean no reply may ever be queued.
    assert!(ServiceConfig::from_toml("[service]\nnet_writer_queue = 0\n").is_err());
    assert!(ServiceConfig::from_toml("[service]\nnet_writer_queue = -1\n").is_err());
}

#[test]
fn config_rejects_unknown_key() {
    assert!(ServiceConfig::from_toml("[service]\nbogus = 1\n").is_err());
    assert!(ServiceConfig::from_toml("[workload]\nmix_float8 = 0.5\n").is_err());
}

#[test]
fn config_custom_mix_over_registry_classes() {
    use crate::decomp::OpClass;
    let cfg = ServiceConfig::from_toml(
        "[workload]\nspec = \"graphics\"\nmix_half = 0.25\nmix_bf16 = 0.5\nmix_single = 0.25\n",
    )
    .unwrap();
    let mix = cfg.mix();
    assert_eq!(mix.weight(OpClass::Bf16), 0.5);
    assert_eq!(mix.weight(OpClass::Half), 0.25);
    assert_eq!(mix.weight(OpClass::Single), 0.25);
    // Custom weights replace the named spec entirely: unlisted classes
    // carry zero mass.
    assert_eq!(mix.weight(OpClass::Double), 0.0);
    assert_eq!(mix.weight(OpClass::Quad), 0.0);
    // Without mix_* keys, the named spec's distribution applies.
    let spec_only = ServiceConfig::from_toml("[workload]\nspec = \"ml\"\n").unwrap();
    assert_eq!(spec_only.mix(), WorkloadSpec::MlInference.mix());
    // All-zero custom mass is rejected.
    assert!(ServiceConfig::from_toml("[workload]\nmix_half = 0.0\n").is_err());
    assert!(ServiceConfig::from_toml("[workload]\nmix_half = -1.0\n").is_err());
}

#[test]
fn config_rejects_incompatible_scheme_fabric() {
    // CIVP scheme on legacy fabric: missing 24x24 blocks.
    let err = ServiceConfig::from_toml("[fabric]\nscheme = \"civp\"\nkind = \"legacy\"\n");
    assert!(err.is_err());
    // 18x18 scheme on civp fabric: missing 18x18 blocks.
    let err = ServiceConfig::from_toml("[fabric]\nscheme = \"18x18\"\nkind = \"civp\"\n");
    assert!(err.is_err());
    // 9x9 runs anywhere.
    ServiceConfig::from_toml("[fabric]\nscheme = \"9x9\"\nkind = \"civp\"\n").unwrap();
    ServiceConfig::from_toml("[fabric]\nscheme = \"9x9\"\nkind = \"legacy\"\n").unwrap();
}

#[test]
fn config_range_validation() {
    assert!(ServiceConfig::from_toml("[service]\nworkers = 0\n").is_err());
    assert!(ServiceConfig::from_toml("[batcher]\nmax_batch = 0\n").is_err());
    assert!(
        ServiceConfig::from_toml("[batcher]\nmax_batch = 512\nqueue_depth = 256\n").is_err()
    );
    assert!(ServiceConfig::from_toml("[fabric]\nscale = 0\n").is_err());
    assert!(ServiceConfig::from_toml("[workload]\nrequests = -1\n").is_err());
}
