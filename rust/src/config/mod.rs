//! Configuration substrate: a minimal TOML-subset parser (offline build —
//! no serde) and the typed service configuration.

mod schema;
mod toml;
#[cfg(test)]
mod tests;

pub use schema::{ServiceConfig, DEFAULT_NET_WRITER_QUEUE};
pub use toml::{parse_toml, TomlValue};
