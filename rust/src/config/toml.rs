//! Minimal TOML-subset parser.
//!
//! Supports what the service config needs: `[section]` headers, `key =
//! value` with string / integer / float / bool values, `#` comments and
//! blank lines. Nested tables, arrays and multi-line strings are out of
//! scope (a config that needs them should graduate to a real TOML crate
//! when the build environment has registry access).

use crate::error::{bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
}

impl TomlValue {
    /// As string, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    /// As integer (accepts Int only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(v) => Some(*v),
            _ => None,
        }
    }
    /// As float (accepts Float or Int).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(v) => Some(*v),
            TomlValue::Int(v) => Some(*v as f64),
            _ => None,
        }
    }
    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse TOML-subset text into `section.key -> value` (keys outside any
/// section land under the empty section `""`).
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1)
            };
            let name = name.trim();
            if name.is_empty() || name.contains(['[', ']']) {
                bail!("line {}: bad section name {name:?}", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`, got {line:?}", lineno + 1)
        };
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        let parsed = parse_value(value.trim())
            .with_context(|| format!("line {}: value for {full}", lineno + 1))?;
        if out.insert(full.clone(), parsed).is_some() {
            bail!("line {}: duplicate key {full}", lineno + 1);
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<TomlValue> {
    if v.is_empty() {
        bail!("empty value");
    }
    if let Some(stripped) = v.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else { bail!("unterminated string") };
        if inner.contains('"') {
            bail!("embedded quote in string (escapes unsupported)");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if v.contains(['.', 'e', 'E']) && !v.starts_with("0x") {
        if let Ok(f) = v.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Some(hex) = v.strip_prefix("0x") {
        if let Ok(i) = i64::from_str_radix(hex, 16) {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(i) = v.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    bail!("cannot parse value {v:?}")
}
