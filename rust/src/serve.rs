//! Serving-layer admission vocabulary shared by every submit path.
//!
//! Before the network edge landed, each layer spelled "request turned
//! away" differently: the batcher had `SubmitError::{QueueFull, Closed}`,
//! the cluster had `ClusterSubmitError::{Saturated, Unservable, Closed}`,
//! and a wire protocol would have needed a third spelling. This module is
//! the single vocabulary: [`AdmissionError`] is returned by
//! [`crate::coordinator::Service::try_submit`], by
//! [`crate::cluster::Cluster::try_submit`], and mapped 1:1 onto the wire
//! status codes in [`crate::net::wire::Status`] — one admission-error type
//! across coordinator, cluster and net.

/// Why a submit was not admitted.
///
/// The three outcomes have distinct retry semantics, which is why they
/// must not collapse into one "error" blob on the wire:
///
/// * [`Saturated`](AdmissionError::Saturated) — transient backpressure;
///   retrying after replies drain can succeed.
/// * [`Unservable`](AdmissionError::Unservable) — no live capacity for
///   this op class at all; retrying cannot succeed until capacity is
///   restored, so blocking submit paths fail fast instead of spinning.
/// * [`Draining`](AdmissionError::Draining) — the serving layer is
///   shutting down; the connection/client should go elsewhere.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AdmissionError {
    /// Every candidate queue or shard is at its bound — cluster- or
    /// service-wide backpressure. Transient: retrying can succeed once
    /// in-flight replies are consumed.
    Saturated,
    /// No live shard can serve this op class (drained, or the block kinds
    /// the class needs are gone). Not backpressure — permanent until
    /// capacity is restored.
    Unservable,
    /// The service, shard or cluster has closed its queues and is
    /// draining; no new work is admitted.
    Draining,
}

impl AdmissionError {
    /// Stable display / wire name.
    pub const fn name(self) -> &'static str {
        match self {
            AdmissionError::Saturated => "saturated",
            AdmissionError::Unservable => "unservable",
            AdmissionError::Draining => "draining",
        }
    }
}

impl core::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        match self {
            AdmissionError::Saturated => write!(f, "all queues saturated (backpressure)"),
            AdmissionError::Unservable => {
                write!(f, "no live capacity can serve this op class")
            }
            AdmissionError::Draining => write!(f, "serving layer draining (shutdown)"),
        }
    }
}

impl std::error::Error for AdmissionError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_stable_and_distinct() {
        let all = [
            AdmissionError::Saturated,
            AdmissionError::Unservable,
            AdmissionError::Draining,
        ];
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_ne!(a, b);
                assert_ne!(a.name(), b.name());
            }
            assert!(!format!("{a}").is_empty());
        }
        assert_eq!(AdmissionError::Saturated.name(), "saturated");
    }
}
