//! Minimal argument parser (offline build — no clap), plus the shared
//! flag surface every serving command resolves through.
//!
//! Supports `binary <command> [--key value] [--flag]` invocations. The
//! `serve`, `cluster`, `serve-net` and `loadgen` commands all accept the
//! same common knobs (`--mix`, `--cores`, `--lane-width`, `--policy`,
//! `--inflight`, ...); [`Args::service_config`], [`Args::backend_choice`]
//! and [`Args::cluster_config`] are the one parsing path those knobs go
//! through, so a flag means the same thing under every command.

use crate::cluster::{ClusterConfig, RouterPolicy};
use crate::config::ServiceConfig;
use crate::coordinator::{BackendChoice, NativeOptions};
use crate::decomp::{Executor, LaneConfig, LaneWidth, OpClass, SchemeKind};
use crate::error::{bail, err, Result};
use crate::fabric::FabricKind;
use crate::net::server::{NetServerConfig, DEFAULT_NET_WORKERS, DEFAULT_PIPELINE_DEPTH};
use crate::runtime::EngineHandle;
use crate::trace::WorkloadSpec;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Parsed command line: a positional command plus `--key value` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First positional argument.
    pub command: Option<String>,
    /// `--key value` pairs and bare `--flag`s (value `"true"`).
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare `--` is not supported");
                }
                let value = match iter.peek() {
                    Some(v) if !v.starts_with("--") => iter.next().unwrap(),
                    _ => "true".to_string(),
                };
                if out.options.insert(key.to_string(), value).is_some() {
                    bail!("duplicate option --{key}");
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                bail!("unexpected positional argument {arg:?}");
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Integer option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// Flag presence.
    pub fn get_flag(&self, key: &str) -> bool {
        self.options.get(key).map(|v| v == "true").unwrap_or(false)
    }

    /// Float option with default.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// Resolve the shared service knobs — `--config`, `--requests`,
    /// `--workload`, `--mix`, `--artifacts`, `--cores`,
    /// `--par-threshold`, `--lane-width` — into a validated
    /// [`ServiceConfig`]. Every serving command parses through here.
    pub fn service_config(&self) -> Result<ServiceConfig> {
        let mut cfg = match self.options.get("config") {
            Some(path) => ServiceConfig::from_file(path)?,
            None => ServiceConfig::default(),
        };
        if let Some(n) = self.options.get("requests") {
            cfg.requests = n.parse()?;
        }
        if let Some(w) = self.options.get("workload") {
            cfg.workload =
                WorkloadSpec::parse(w).ok_or_else(|| err!("unknown workload {w:?}"))?;
        }
        if let Some(s) = self.options.get("scheme") {
            // `--scheme karatsuba24` etc.: re-target the partition
            // organization and follow it with the compatible fabric preset
            // (the same table as `ServiceConfig::validate`).
            cfg.scheme =
                SchemeKind::parse(s).ok_or_else(|| err!("unknown scheme {s:?}"))?;
            cfg.fabric = match cfg.scheme {
                SchemeKind::Civp | SchemeKind::Karatsuba24 => FabricKind::Civp,
                SchemeKind::Baseline18 | SchemeKind::Baseline25x18 => FabricKind::Legacy,
                SchemeKind::Baseline9 => cfg.fabric,
            };
        }
        if let Some(spec) = self.options.get("mix") {
            // `--mix half=0.2,bf16=0.3,...` — explicit per-class weights
            // over the open registry; unlisted classes get zero mass.
            for part in spec.split(',').filter(|p| !p.is_empty()) {
                let (name, weight) = part
                    .split_once('=')
                    .ok_or_else(|| err!("--mix entries are class=weight, got {part:?}"))?;
                let class = OpClass::parse(name.trim())
                    .ok_or_else(|| err!("unknown op class {name:?} in --mix"))?;
                cfg.set_mix_weight(class, weight.trim().parse()?)?;
            }
        }
        if let Some(dir) = self.options.get("artifacts") {
            cfg.artifacts_dir = dir.clone();
        }
        if let Some(n) = self.options.get("cores") {
            cfg.cores = n.parse()?;
        }
        if let Some(n) = self.options.get("par-threshold") {
            cfg.par_threshold = n.parse()?;
        }
        if let Some(n) = self.options.get("lane-width") {
            cfg.lane_width = n.parse()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Resolve `--backend` (+ the lane/executor knobs already folded into
    /// `cfg`) into a [`BackendChoice`]. With `--cores N` (N > 0) the
    /// native options carry a shared work-stealing lane executor; results
    /// stay bit-for-bit identical to the single-threaded path for every
    /// width and dispatched ISA.
    pub fn backend_choice(&self, cfg: &ServiceConfig) -> Result<BackendChoice> {
        Ok(match self.get_str("backend", "native").as_str() {
            "native" => {
                let mut opts = NativeOptions::new(cfg.scheme);
                opts = if cfg.cores > 0 {
                    opts.executor(Arc::new(Executor::with_config(
                        cfg.cores,
                        cfg.par_threshold,
                        lane_config(cfg)?,
                    )))
                } else {
                    opts.lane_config(lane_config(cfg)?)
                };
                BackendChoice::Native(opts)
            }
            "pjrt" => BackendChoice::Pjrt(EngineHandle::load(cfg.artifacts_dir.clone())?),
            other => bail!("unknown backend {other:?}"),
        })
    }

    /// Resolve the cluster knobs — `--shards`, `--policy`, `--inflight`,
    /// `--spares` — around an already-resolved per-shard service config.
    pub fn cluster_config(&self, service: ServiceConfig) -> Result<ClusterConfig> {
        let policy_name = self.get_str("policy", "least-loaded");
        let policy = RouterPolicy::parse(&policy_name)
            .ok_or_else(|| err!("unknown policy {policy_name:?} (try `help`)"))?;
        Ok(ClusterConfig {
            shards: self.get_usize("shards", 4)?,
            service,
            policy,
            max_inflight: self.get_usize("inflight", 4096)? as u64,
            spares_per_block: self.get_usize("spares", 2)? as u32,
        })
    }

    /// Resolve the network-edge knobs — `--addr`, `--writer-queue`
    /// (defaulting to the resolved `service.net_writer_queue`),
    /// `--net-workers`, `--pipeline-depth`, `--max-conns` (accept-side
    /// connection cap, 0 = unlimited), `--idle-timeout` (ms before an
    /// idle connection is reaped, 0 = never), `--schemes` (extra
    /// [`SchemeKind`]s this listener serves through their own clusters)
    /// — around an already-resolved cluster config.
    pub fn net_server_config(
        &self,
        default_addr: &str,
        cluster: ClusterConfig,
    ) -> Result<NetServerConfig> {
        let writer_queue = self.get_usize("writer-queue", cluster.service.net_writer_queue)?;
        if writer_queue == 0 {
            bail!("--writer-queue must be >= 1");
        }
        let net_workers = self.get_usize("net-workers", DEFAULT_NET_WORKERS)?;
        if net_workers == 0 {
            bail!("--net-workers must be >= 1");
        }
        let pipeline_depth = self.get_usize("pipeline-depth", DEFAULT_PIPELINE_DEPTH)?;
        if pipeline_depth == 0 {
            bail!("--pipeline-depth must be >= 1");
        }
        let max_conns = self.get_usize("max-conns", 0)?;
        let idle_timeout = match self.get_usize("idle-timeout", 0)? {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms as u64)),
        };
        let mut extra_schemes = Vec::new();
        for name in self
            .get_str("schemes", "")
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
        {
            let scheme = SchemeKind::parse(name)
                .ok_or_else(|| err!("unknown scheme {name:?} in --schemes"))?;
            if scheme != cluster.service.scheme && !extra_schemes.contains(&scheme) {
                extra_schemes.push(scheme);
            }
        }
        Ok(NetServerConfig {
            addr: self.get_str("addr", default_addr),
            cluster,
            writer_queue,
            net_workers,
            pipeline_depth,
            extra_schemes,
            max_conns,
            idle_timeout,
        })
    }

    /// Resolve `--sweep rate1,rate2,...` into an ascending offered-load
    /// list; `None` when the flag is absent (plain single-rate run).
    pub fn sweep_rates(&self) -> Result<Option<Vec<f64>>> {
        let Some(spec) = self.options.get("sweep") else {
            return Ok(None);
        };
        let rates: Vec<f64> = spec
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|s| s.parse::<f64>().map_err(|_| err!("bad --sweep rate {s:?}")))
            .collect::<Result<_>>()?;
        if rates.is_empty() {
            bail!("--sweep needs at least one rate");
        }
        Ok(Some(rates))
    }

    /// Resolve `--workloads` (comma-separated [`WorkloadSpec`] names) for
    /// the load generator; `default` when absent.
    pub fn workloads(&self, default: &str) -> Result<Vec<WorkloadSpec>> {
        self.get_str("workloads", default)
            .split(',')
            .filter(|s| !s.trim().is_empty())
            .map(|s| {
                WorkloadSpec::parse(s.trim())
                    .ok_or_else(|| err!("unknown workload {s:?} in --workloads"))
            })
            .collect()
    }
}

/// Resolve the configured lane width plus the best vector ISA the host
/// offers (AVX-512 → AVX2 → scalar on x86_64, NEON on aarch64; always
/// scalar without the `simd` feature).
fn lane_config(cfg: &ServiceConfig) -> Result<LaneConfig> {
    let width = LaneWidth::from_width(cfg.lane_width)
        .ok_or_else(|| err!("--lane-width must be 8, 16 or 32 (got {})", cfg.lane_width))?;
    Ok(LaneConfig::detect(width))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_and_options() {
        let a = p(&["serve", "--workers", "4", "--verbose", "--name", "x"]);
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("workers", 1).unwrap(), 4);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.get_str("name", ""), "x");
        assert_eq!(a.get_str("missing", "d"), "d");
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Args::parse(vec!["a".into(), "b".into()]).is_err());
        assert!(Args::parse(vec!["--x".into(), "1".into(), "--x".into(), "2".into()]).is_err());
        assert!(Args::parse(vec!["--".into()]).is_err());
    }

    #[test]
    fn flag_followed_by_option() {
        let a = p(&["run", "--flag", "--n", "3"]);
        assert!(a.get_flag("flag"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn shared_service_knobs_resolve_one_way() {
        let a = p(&[
            "serve",
            "--requests",
            "123",
            "--workload",
            "ml",
            "--cores",
            "2",
            "--lane-width",
            "16",
            "--mix",
            "half=0.5,single=0.5",
        ]);
        let cfg = a.service_config().unwrap();
        assert_eq!(cfg.requests, 123);
        assert_eq!(cfg.cores, 2);
        assert_eq!(cfg.lane_width, 16);
        // --mix overrides the named workload's weights entirely.
        assert!(cfg.mix().weight(OpClass::Half) > 0.0);
        let backend = a.backend_choice(&cfg).unwrap();
        assert!(backend.executor().is_some(), "--cores 2 must share an executor");
        assert!(p(&["serve", "--workload", "nope"]).service_config().is_err());
        assert!(p(&["serve", "--mix", "half-0.5"]).service_config().is_err());
    }

    #[test]
    fn scheme_flag_retargets_and_keeps_fabric_compatible() {
        let cfg = p(&["serve", "--scheme", "karatsuba24"]).service_config().unwrap();
        assert_eq!(cfg.scheme, SchemeKind::Karatsuba24);
        assert_eq!(cfg.fabric, FabricKind::Civp);
        let cfg = p(&["serve", "--scheme", "18x18"]).service_config().unwrap();
        assert_eq!(cfg.scheme, SchemeKind::Baseline18);
        assert_eq!(cfg.fabric, FabricKind::Legacy);
        assert!(p(&["serve", "--scheme", "nope"]).service_config().is_err());
    }

    #[test]
    fn cluster_knobs_resolve_under_any_command() {
        for cmd in ["cluster", "serve-net", "loadgen"] {
            let a = p(&[cmd, "--shards", "2", "--policy", "round-robin", "--inflight", "7"]);
            let ccfg = a.cluster_config(ServiceConfig::default()).unwrap();
            assert_eq!(ccfg.shards, 2);
            assert_eq!(ccfg.policy, RouterPolicy::RoundRobin);
            assert_eq!(ccfg.max_inflight, 7);
        }
        let bad = p(&["cluster", "--policy", "nope"]);
        assert!(bad.cluster_config(ServiceConfig::default()).is_err());
    }

    #[test]
    fn net_knobs_resolve_and_validate() {
        let a = p(&[
            "serve-net",
            "--writer-queue",
            "64",
            "--net-workers",
            "8",
            "--pipeline-depth",
            "16",
            "--schemes",
            "18x18, 9x9",
        ]);
        let cluster = a.cluster_config(ServiceConfig::default()).unwrap();
        let net = a.net_server_config("127.0.0.1:0", cluster).unwrap();
        assert_eq!(net.writer_queue, 64);
        assert_eq!(net.net_workers, 8);
        assert_eq!(net.pipeline_depth, 16);
        assert_eq!(net.extra_schemes, vec![SchemeKind::Baseline18, SchemeKind::Baseline9]);
        // Admission knobs resolve: a cap plus an idle window in ms.
        let a = p(&["serve-net", "--max-conns", "128", "--idle-timeout", "2500"]);
        let cluster = a.cluster_config(ServiceConfig::default()).unwrap();
        let net = a.net_server_config("127.0.0.1:0", cluster).unwrap();
        assert_eq!(net.max_conns, 128);
        assert_eq!(net.idle_timeout, Some(std::time::Duration::from_millis(2500)));
        // Defaults: writer queue from the service config, pool constants,
        // no connection cap, no idle reaping.
        let a = p(&["serve-net"]);
        let cluster = a.cluster_config(ServiceConfig::default()).unwrap();
        let net = a.net_server_config("127.0.0.1:0", cluster).unwrap();
        assert_eq!(net.writer_queue, crate::config::DEFAULT_NET_WRITER_QUEUE);
        assert_eq!(net.net_workers, DEFAULT_NET_WORKERS);
        assert_eq!(net.pipeline_depth, DEFAULT_PIPELINE_DEPTH);
        assert!(net.extra_schemes.is_empty());
        assert_eq!(net.max_conns, 0);
        assert_eq!(net.idle_timeout, None);
        // The primary scheme is not duplicated into the extras.
        let a = p(&["serve-net", "--schemes", "civp,18x18,18x18"]);
        let cluster = a.cluster_config(ServiceConfig::default()).unwrap();
        let net = a.net_server_config("127.0.0.1:0", cluster).unwrap();
        assert_eq!(net.extra_schemes, vec![SchemeKind::Baseline18]);
        for bad in [
            vec!["serve-net", "--writer-queue", "0"],
            vec!["serve-net", "--net-workers", "0"],
            vec!["serve-net", "--pipeline-depth", "0"],
            vec!["serve-net", "--schemes", "nope"],
        ] {
            let a = p(&bad);
            let cluster = a.cluster_config(ServiceConfig::default()).unwrap();
            assert!(a.net_server_config("127.0.0.1:0", cluster).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn sweep_rate_lists_parse() {
        assert_eq!(p(&["loadgen"]).sweep_rates().unwrap(), None);
        let a = p(&["loadgen", "--sweep", "500, 1000,2000"]);
        assert_eq!(a.sweep_rates().unwrap(), Some(vec![500.0, 1000.0, 2000.0]));
        assert!(p(&["loadgen", "--sweep", "500,x"]).sweep_rates().is_err());
        assert!(p(&["loadgen", "--sweep", ","]).sweep_rates().is_err());
    }

    #[test]
    fn workload_lists_and_floats() {
        let a = p(&["loadgen", "--workloads", "mixed, ml", "--rate", "2.5"]);
        let specs = a.workloads("mixed").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name(), "mixed");
        assert_eq!(specs[1].name(), "ml");
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("missing", 1.5).unwrap(), 1.5);
        assert!(p(&["loadgen", "--workloads", "nope"]).workloads("mixed").is_err());
    }
}
