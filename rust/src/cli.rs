//! Minimal argument parser (offline build — no clap).
//!
//! Supports `binary <command> [--key value] [--flag]` invocations, which is
//! all `civp-server` needs.

use crate::error::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: a positional command plus `--key value` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First positional argument.
    pub command: Option<String>,
    /// `--key value` pairs and bare `--flag`s (value `"true"`).
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare `--` is not supported");
                }
                let value = match iter.peek() {
                    Some(v) if !v.starts_with("--") => iter.next().unwrap(),
                    _ => "true".to_string(),
                };
                if out.options.insert(key.to_string(), value).is_some() {
                    bail!("duplicate option --{key}");
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                bail!("unexpected positional argument {arg:?}");
            }
        }
        Ok(out)
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Integer option with default.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    /// Flag presence.
    pub fn get_flag(&self, key: &str) -> bool {
        self.options.get(key).map(|v| v == "true").unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_and_options() {
        let a = p(&["serve", "--workers", "4", "--verbose", "--name", "x"]);
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("workers", 1).unwrap(), 4);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.get_str("name", ""), "x");
        assert_eq!(a.get_str("missing", "d"), "d");
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Args::parse(vec!["a".into(), "b".into()]).is_err());
        assert!(Args::parse(vec!["--x".into(), "1".into(), "--x".into(), "2".into()]).is_err());
        assert!(Args::parse(vec!["--".into()]).is_err());
    }

    #[test]
    fn flag_followed_by_option() {
        let a = p(&["run", "--flag", "--n", "3"]);
        assert!(a.get_flag("flag"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }
}
