//! Std-only event-driven TCP front-end: a bounded connection-worker pool
//! multiplexing many sockets per thread.
//!
//! Thread model (no async runtime, no per-connection threads):
//!
//! ```text
//!   accept thread ── assigns each new socket to the least-loaded worker
//!        │
//!        ▼
//!   civp-net-0 .. civp-net-{W-1}   ── fixed pool, W = net_workers
//!        │  each worker owns a slab of connections and rotates over it
//!        │  with non-blocking reads/writes (WouldBlock ⇒ move on, park
//!        │  briefly only when a full rotation made no progress)
//!        │
//!        │  per connection:
//!        │    reassembly buffer ── bytes in, frames parsed out
//!        │    in-flight deque   ── ≤ pipeline_depth submitted requests;
//!        │                         completions drain OUT OF ORDER
//!        │                         (responses carry request ids)
//!        │    writer queue      ── ≤ writer_queue encoded responses;
//!        │                         full ⇒ the worker stops reading this
//!        │                         socket ⇒ TCP backpressure
//!        ▼
//!   per-scheme clusters ── one listener serves several `SchemeKind`s by
//!   routing each frame to its scheme's cluster (frames for schemes the
//!   deployment does not serve still answer `Unsupported`)
//! ```
//!
//! The steady-state thread count is `net_workers + 1` (accept) plus the
//! per-cluster worker pools — a function of configuration, never of the
//! connection count. [`NetServer::worker_registry`] exposes the pool so
//! tests can assert the bound without groveling `/proc`.
//!
//! **Pipelining.** A client may write many frames without waiting for
//! replies. Each connection submits at most `pipeline_depth` requests
//! into the cluster concurrently; beyond that the worker stops parsing
//! (and, buffers full, stops reading — backpressure again). Completions
//! are written as they arrive, so responses can legally overtake each
//! other; the request id on every response is what clients key on.
//!
//! Framing-level failures (truncated stream, oversized length prefix)
//! get one [`Status::BadRequest`] response, then the connection drains
//! its in-flight replies and closes — the byte stream cannot be
//! resynchronized. In-frame decode failures answer `BadRequest` and keep
//! the connection open: framing is intact, so subsequent frames parse.

use super::wire::{self, Request, Response, Status};
use crate::cluster::{Cluster, ClusterConfig, ClusterReply, ClusterReport};
use crate::config::{ServiceConfig, DEFAULT_NET_WRITER_QUEUE};
use crate::coordinator::{BackendChoice, TryRecvError};
use crate::decomp::{OpClass, SchemeKind};
use crate::error::{Context, Result};
use crate::fabric::FabricKind;
use crate::fpu::RoundMode;
use crate::metrics::{Counter, Registry, Snapshot};
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default connection-worker pool size.
pub const DEFAULT_NET_WORKERS: usize = 4;

/// Default per-connection pipelined in-flight bound.
pub const DEFAULT_PIPELINE_DEPTH: usize = 32;

/// How long an idle worker parks when a full slab rotation made no
/// progress (short enough to stay responsive, long enough not to spin).
const IDLE_PARK: Duration = Duration::from_micros(100);

/// Socket read chunk size (one rotation reads at most this much per
/// connection, so one firehose connection cannot starve its slab mates).
const READ_CHUNK: usize = 4096;

/// Compact the reassembly buffer once this many parsed bytes accumulate.
const COMPACT_AT: usize = 8 * 1024;

/// Listener deployment shape.
#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// Bind address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// The primary cluster behind the listener (shards, policy, in-flight
    /// bound). Its `service.scheme` is the primary partition organization
    /// this listener serves.
    pub cluster: ClusterConfig,
    /// Per-connection bound on responses queued for the socket. When
    /// full, the worker stops completing replies and stops reading that
    /// socket, which is the mechanism that turns cluster latency and
    /// slow readers into TCP backpressure.
    pub writer_queue: usize,
    /// Connection-worker pool size (the `civp-net-{i}` threads). The
    /// steady-state thread count of the edge is this plus the accept
    /// thread — independent of connection count.
    pub net_workers: usize,
    /// Per-connection bound on requests submitted into the cluster and
    /// not yet completed (the pipelining window).
    pub pipeline_depth: usize,
    /// Additional schemes served by this listener, each through its own
    /// cluster (same shard/policy shape as the primary, scheme and
    /// fabric preset swapped). Requests for schemes in neither set
    /// answer [`Status::Unsupported`].
    pub extra_schemes: Vec<SchemeKind>,
    /// Hard cap on simultaneously open connections, enforced at the
    /// accept thread: a connection arriving at the cap is closed
    /// immediately (counted in `net_conns_rejected`) so the fixed worker
    /// pool never multiplexes more sockets than the deployment sized
    /// for. `0` means unlimited.
    pub max_conns: usize,
    /// Close a connection after this much inactivity (no bytes read or
    /// written, nothing in flight). Reclaims slots held by idle or
    /// half-dead peers — without it, `max_conns` slots leak to clients
    /// that connected and walked away. `None` disables the timeout.
    pub idle_timeout: Option<Duration>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            addr: "127.0.0.1:0".to_string(),
            cluster: ClusterConfig::default(),
            writer_queue: DEFAULT_NET_WRITER_QUEUE,
            net_workers: DEFAULT_NET_WORKERS,
            pipeline_depth: DEFAULT_PIPELINE_DEPTH,
            extra_schemes: Vec::new(),
            max_conns: 0,
            idle_timeout: None,
        }
    }
}

/// A per-shard service config re-targeted at `scheme`: the fabric preset
/// follows the scheme's block-kind needs (mirrors
/// `ServiceConfig::validate`'s compatibility table).
fn scheme_service(mut svc: ServiceConfig, scheme: SchemeKind) -> ServiceConfig {
    svc.scheme = scheme;
    svc.fabric = match scheme {
        // Karatsuba leaves are CIVP tile vocabularies, so the karatsuba
        // organization runs on the CIVP fabric preset.
        SchemeKind::Civp | SchemeKind::Karatsuba24 => FabricKind::Civp,
        SchemeKind::Baseline18 | SchemeKind::Baseline25x18 => FabricKind::Legacy,
        // 9x9 tiles run on either fabric — keep the configured preset.
        SchemeKind::Baseline9 => svc.fabric,
    };
    svc
}

/// The scheme routing table: one optional cluster per registry scheme.
struct SchemeClusters {
    by_scheme: [Option<Arc<Cluster>>; SchemeKind::COUNT],
}

impl SchemeClusters {
    fn get(&self, scheme: SchemeKind) -> Option<&Arc<Cluster>> {
        self.by_scheme[scheme.index()].as_ref()
    }
}

/// One entry in a connection's pipelined in-flight deque.
enum Pending {
    /// Admitted into a cluster; completed whenever the reply lands (out
    /// of order with its neighbours is fine — responses carry ids).
    Submitted {
        id: u64,
        class: OpClass,
        reply: ClusterReply,
    },
    /// Already resolved at parse time (admission/decode/validation
    /// outcome) — encoded as soon as the writer queue has room.
    Immediate(Response),
}

/// One multiplexed connection owned by a pool worker.
struct Conn {
    stream: TcpStream,
    /// Reassembly buffer: raw bytes in, frames parsed out at `rdpos`.
    rdbuf: Vec<u8>,
    rdpos: usize,
    /// Pipelined requests: submitted or immediately-resolved, bounded by
    /// `pipeline_depth`.
    inflight: VecDeque<Pending>,
    /// Encoded responses awaiting the socket, bounded by `writer_queue`
    /// responses (`wr_queued` counts them; `wrpos` is the write cursor).
    wrbuf: Vec<u8>,
    wrpos: usize,
    wr_queued: usize,
    /// Peer closed its write half (EOF seen).
    read_closed: bool,
    /// Framing lost: answer what is owed, flush, then close.
    closing: bool,
    /// Last time this connection made any progress (read, write, parse,
    /// completion) — the idle-timeout reaper's clock.
    last_activity: Instant,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rdbuf: Vec::with_capacity(READ_CHUNK),
            rdpos: 0,
            inflight: VecDeque::new(),
            wrbuf: Vec::with_capacity(256),
            wrpos: 0,
            wr_queued: 0,
            read_closed: false,
            closing: false,
            last_activity: Instant::now(),
        }
    }

    /// Unparsed byte count sitting in the reassembly buffer.
    fn unparsed(&self) -> usize {
        self.rdbuf.len() - self.rdpos
    }
}

/// Accept-side handle to one pool worker: its injection queue plus the
/// live connection count (accept balances on it; the registry reads it).
struct WorkerShared {
    name: String,
    incoming: Mutex<Vec<TcpStream>>,
    conns: AtomicUsize,
}

/// Pool-wide instruments shared by every worker.
struct NetInstruments {
    /// Frames answered, by wire status code.
    status_frames: Vec<Arc<Counter>>,
    /// High-water mark of any connection's pipelined in-flight depth.
    inflight_hwm: AtomicU64,
    /// Connections turned away at the accept thread (`max_conns` hit).
    conns_rejected: Arc<Counter>,
    /// Connections closed by the idle-timeout reaper.
    conns_idle_closed: Arc<Counter>,
}

/// Per-connection limits, resolved once at startup.
#[derive(Clone, Copy)]
struct ConnLimits {
    writer_queue: usize,
    pipeline_depth: usize,
    /// Close fully-idle connections after this long (None = never).
    idle_timeout: Option<Duration>,
}

/// A running network serving edge: accept thread + worker pool +
/// per-scheme clusters.
pub struct NetServer {
    local_addr: SocketAddr,
    clusters: Arc<SchemeClusters>,
    primary: SchemeKind,
    stop: Arc<AtomicBool>,
    workers: Vec<Arc<WorkerShared>>,
    worker_handles: Vec<JoinHandle<()>>,
    accept: JoinHandle<()>,
    metrics: Registry,
    instruments: Arc<NetInstruments>,
}

impl NetServer {
    /// Bind, start the per-scheme clusters and the worker pool, return
    /// immediately.
    pub fn start(cfg: &NetServerConfig, backend: BackendChoice) -> Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding listener on {}", cfg.addr))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        let primary = cfg.cluster.service.scheme;

        let mut by_scheme: [Option<Arc<Cluster>>; SchemeKind::COUNT] = Default::default();
        by_scheme[primary.index()] = Some(Arc::new(Cluster::start(&cfg.cluster, backend.clone())));
        for &scheme in &cfg.extra_schemes {
            if by_scheme[scheme.index()].is_some() {
                continue;
            }
            let mut ccfg = cfg.cluster.clone();
            ccfg.service = scheme_service(ccfg.service, scheme);
            // Native backends re-target cleanly; the PJRT artifacts are
            // scheme-agnostic, so extra schemes under a PJRT deployment
            // get a plain native cluster for that organization.
            let scheme_backend = match &backend {
                BackendChoice::Native(opts) => BackendChoice::Native(opts.clone().scheme(scheme)),
                BackendChoice::Pjrt(_) => BackendChoice::native(scheme),
            };
            by_scheme[scheme.index()] = Some(Arc::new(Cluster::start(&ccfg, scheme_backend)));
        }
        let clusters = Arc::new(SchemeClusters { by_scheme });

        let metrics = Registry::new();
        let status_frames = Status::ALL
            .iter()
            .map(|s| metrics.counter(&format!("net_frames_{}", s.name())))
            .collect();
        let instruments = Arc::new(NetInstruments {
            status_frames,
            inflight_hwm: AtomicU64::new(0),
            conns_rejected: metrics.counter("net_conns_rejected"),
            conns_idle_closed: metrics.counter("net_conns_idle_closed"),
        });

        let stop = Arc::new(AtomicBool::new(false));
        let limits = ConnLimits {
            writer_queue: cfg.writer_queue.max(1),
            pipeline_depth: cfg.pipeline_depth.max(1),
            idle_timeout: cfg.idle_timeout,
        };
        let mut workers = Vec::new();
        let mut worker_handles = Vec::new();
        for i in 0..cfg.net_workers.max(1) {
            let shared = Arc::new(WorkerShared {
                name: format!("civp-net-{i}"),
                incoming: Mutex::new(Vec::new()),
                conns: AtomicUsize::new(0),
            });
            workers.push(shared.clone());
            let clusters = clusters.clone();
            let stop = stop.clone();
            let instruments = instruments.clone();
            worker_handles.push(
                std::thread::Builder::new()
                    .name(shared.name.clone())
                    .spawn(move || worker_loop(&shared, &clusters, &stop, limits, &instruments))
                    .context("spawning net worker")?,
            );
        }

        let accept = {
            let stop = stop.clone();
            let workers = workers.clone();
            let instruments = instruments.clone();
            let max_conns = cfg.max_conns;
            std::thread::spawn(move || {
                for incoming in listener.incoming() {
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let stream = match incoming {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    // Connection admission: at the cap, close at accept
                    // instead of queueing the socket onto a worker —
                    // `max_conns` bounds slab sizes, not just threads.
                    if max_conns > 0 {
                        let open: usize =
                            workers.iter().map(|w| w.conns.load(Ordering::Acquire)).sum();
                        if open >= max_conns {
                            instruments.conns_rejected.inc();
                            let _ = stream.shutdown(Shutdown::Both);
                            continue;
                        }
                    }
                    // Least-loaded assignment over the fixed pool: the
                    // connection count is the only signal accept needs.
                    let target = workers
                        .iter()
                        .min_by_key(|w| w.conns.load(Ordering::Acquire))
                        .expect("worker pool is never empty");
                    target.conns.fetch_add(1, Ordering::AcqRel);
                    target.incoming.lock().unwrap().push(stream);
                }
            })
        };

        Ok(NetServer {
            local_addr,
            clusters,
            primary,
            stop,
            workers,
            worker_handles,
            accept,
            metrics,
            instruments,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The primary cluster behind the listener (op counters, metrics —
    /// the e2e oracle that per-class executed counts match frames sent).
    pub fn cluster(&self) -> &Cluster {
        self.cluster_for(self.primary).expect("primary cluster always exists")
    }

    /// The cluster serving `scheme`, when this deployment serves it.
    pub fn cluster_for(&self, scheme: SchemeKind) -> Option<&Cluster> {
        self.clusters.get(scheme).map(|c| c.as_ref())
    }

    /// Every scheme this listener serves (primary first).
    pub fn schemes(&self) -> Vec<SchemeKind> {
        let mut out = vec![self.primary];
        for scheme in SchemeKind::ALL {
            if scheme != self.primary && self.clusters.get(scheme).is_some() {
                out.push(scheme);
            }
        }
        out
    }

    /// The connection-worker pool: one `(name, live connections)` row per
    /// worker. The pool is fixed at startup — its length bounds the
    /// edge's thread count no matter how many sockets are connected,
    /// which is exactly what tests assert instead of groveling `/proc`.
    pub fn worker_registry(&self) -> Vec<(String, usize)> {
        self.workers
            .iter()
            .map(|w| (w.name.clone(), w.conns.load(Ordering::Acquire)))
            .collect()
    }

    /// Net-edge telemetry snapshot: open connections, per-worker
    /// multiplexed-connection counts, the pipelined in-flight depth
    /// high-water mark, and frames answered per wire status code.
    /// Gauges are refreshed from the live pool at snapshot time (the
    /// same pattern as [`Cluster::metrics`]).
    pub fn metrics(&self) -> Snapshot {
        let mut open = 0usize;
        for w in &self.workers {
            let n = w.conns.load(Ordering::Acquire);
            open += n;
            self.metrics.gauge(&format!("{}_connections", w.name)).set(n as i64);
        }
        self.metrics.gauge("net_open_connections").set(open as i64);
        self.metrics
            .gauge("net_pipeline_inflight_hwm")
            .set(self.instruments.inflight_hwm.load(Ordering::Relaxed) as i64);
        self.metrics.snapshot()
    }

    /// Stop accepting, close every live connection, join the pool, then
    /// drain every cluster and return the primary scheme's final report.
    pub fn stop(self) -> ClusterReport {
        let NetServer {
            local_addr,
            clusters,
            primary,
            stop,
            workers,
            worker_handles,
            accept,
            ..
        } = self;
        stop.store(true, Ordering::Release);
        // Unblock the accept loop (it re-checks `stop` per connection).
        let _ = TcpStream::connect(local_addr);
        let _ = accept.join();
        drop(workers);
        for handle in worker_handles {
            let _ = handle.join();
        }
        // Joining the pool dropped every in-flight reply; drain all
        // clusters and report the primary one.
        let mut report = None;
        for scheme in SchemeKind::ALL {
            if let Some(cluster) = &clusters.by_scheme[scheme.index()] {
                cluster.drain();
                if scheme == primary {
                    report = Some(cluster.report());
                }
            }
        }
        report.expect("primary cluster always exists")
    }
}

/// Outcome of one connection pump.
enum Pump {
    Alive { progress: bool },
    Closed,
}

/// One pool worker: adopt injected sockets, rotate over the slab, park
/// briefly when a full rotation made no progress.
fn worker_loop(
    shared: &WorkerShared,
    clusters: &SchemeClusters,
    stop: &AtomicBool,
    limits: ConnLimits,
    instruments: &NetInstruments,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut chunk = [0u8; READ_CHUNK];
    loop {
        // Adopt new sockets before checking `stop`, so connections
        // assigned during shutdown are closed rather than leaked.
        {
            let mut incoming = shared.incoming.lock().unwrap();
            for stream in incoming.drain(..) {
                let _ = stream.set_nodelay(true);
                if stream.set_nonblocking(true).is_err() {
                    shared.conns.fetch_sub(1, Ordering::AcqRel);
                    continue;
                }
                conns.push(Conn::new(stream));
            }
        }
        if stop.load(Ordering::Acquire) {
            for conn in &conns {
                let _ = conn.stream.shutdown(Shutdown::Both);
            }
            shared.conns.fetch_sub(conns.len(), Ordering::AcqRel);
            return;
        }
        let mut progress = false;
        let mut i = 0;
        while i < conns.len() {
            match pump_conn(&mut conns[i], clusters, limits, instruments, &mut chunk) {
                Pump::Alive { progress: p } => {
                    progress |= p;
                    i += 1;
                }
                Pump::Closed => {
                    conns.swap_remove(i);
                    shared.conns.fetch_sub(1, Ordering::AcqRel);
                    progress = true;
                }
            }
        }
        if !progress {
            std::thread::park_timeout(IDLE_PARK);
        }
    }
}

/// Drive one connection one step: complete ready replies, flush the
/// socket, read what is available, parse frames, submit. Never blocks.
fn pump_conn(
    conn: &mut Conn,
    clusters: &SchemeClusters,
    limits: ConnLimits,
    instruments: &NetInstruments,
    chunk: &mut [u8],
) -> Pump {
    let mut progress = false;

    // 1. Complete in-flight requests into the writer queue — out of
    //    order, wherever in the deque a reply has landed.
    let mut idx = 0;
    while idx < conn.inflight.len() && conn.wr_queued < limits.writer_queue {
        let resp = match &conn.inflight[idx] {
            Pending::Immediate(resp) => Some(*resp),
            Pending::Submitted { id, class, reply } => match reply.try_recv() {
                Ok(done) => Some(Response::ok(*class, *id, done.bits)),
                Err(TryRecvError::Empty) => None,
                // Admitted but the shard died before replying: the
                // client still gets exactly one response for the frame.
                Err(TryRecvError::Disconnected) => {
                    Some(Response::error(Status::Internal, *class, *id))
                }
            },
        };
        match resp {
            Some(resp) => {
                conn.inflight.remove(idx);
                resp.encode(&mut conn.wrbuf);
                conn.wr_queued += 1;
                instruments.status_frames[resp.status.code() as usize].inc();
                progress = true;
            }
            None => idx += 1,
        }
    }

    // 2. Flush queued response bytes (non-blocking).
    while conn.wrpos < conn.wrbuf.len() {
        match conn.stream.write(&conn.wrbuf[conn.wrpos..]) {
            Ok(0) => return Pump::Closed,
            Ok(n) => {
                conn.wrpos += n;
                progress = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return Pump::Closed,
        }
    }
    if conn.wrpos > 0 && conn.wrpos == conn.wrbuf.len() {
        conn.wrbuf.clear();
        conn.wrpos = 0;
        conn.wr_queued = 0;
    }

    // 3. Read newly arrived bytes — unless framing is lost, the peer
    //    already hit EOF, or the pipelining/writer bounds say stop
    //    (stopping the reads is what propagates TCP backpressure).
    let may_read = !conn.closing
        && !conn.read_closed
        && conn.inflight.len() < limits.pipeline_depth
        && conn.wr_queued < limits.writer_queue;
    if may_read {
        match conn.stream.read(chunk) {
            Ok(0) => {
                conn.read_closed = true;
                progress = true;
            }
            Ok(n) => {
                conn.rdbuf.extend_from_slice(&chunk[..n]);
                progress = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return Pump::Closed,
        }
    }

    // 4. Parse complete frames and submit, up to the pipelining bound.
    while !conn.closing
        && conn.inflight.len() < limits.pipeline_depth
        && conn.unparsed() >= 4
    {
        let len_bytes: [u8; 4] =
            conn.rdbuf[conn.rdpos..conn.rdpos + 4].try_into().expect("4 bytes checked");
        let len = u32::from_le_bytes(len_bytes);
        if len == 0 || len > wire::MAX_FRAME {
            // Framing lost: answer once, drain what is owed, then close.
            conn.inflight.push_back(Pending::Immediate(Response::error(
                Status::BadRequest,
                OpClass::from_index(0),
                0,
            )));
            conn.closing = true;
            progress = true;
            break;
        }
        let len = len as usize;
        if conn.unparsed() < 4 + len {
            break; // frame still reassembling
        }
        let payload = &conn.rdbuf[conn.rdpos + 4..conn.rdpos + 4 + len];
        let pending = match Request::decode(payload) {
            // In-frame error: framing intact, connection stays open.
            Err(_) => Pending::Immediate(Response::error(
                Status::BadRequest,
                OpClass::from_index(0),
                0,
            )),
            Ok(req) => route(req, clusters),
        };
        conn.rdpos += 4 + len;
        conn.inflight.push_back(pending);
        instruments.inflight_hwm.fetch_max(conn.inflight.len() as u64, Ordering::Relaxed);
        progress = true;
    }
    if conn.rdpos > 0 && (conn.rdpos == conn.rdbuf.len() || conn.rdpos >= COMPACT_AT) {
        conn.rdbuf.drain(..conn.rdpos);
        conn.rdpos = 0;
    }

    // 5. Close once everything owed has been answered and flushed: after
    //    framing loss, or after peer EOF with no bytes left to serve.
    let drained = conn.inflight.is_empty() && conn.wrbuf.is_empty();
    let eof_done = conn.read_closed && conn.unparsed() == 0;
    if drained && (conn.closing || eof_done) {
        let _ = conn.stream.shutdown(Shutdown::Both);
        return Pump::Closed;
    }

    // 6. Idle reaping: a connection that owes nothing (no in-flight
    //    requests, no queued bytes, no half-parsed frame) and has made no
    //    progress for the idle window is closed to reclaim its slot.
    if progress {
        conn.last_activity = Instant::now();
    } else if let Some(timeout) = limits.idle_timeout {
        let idle = drained && conn.unparsed() == 0;
        if idle && conn.last_activity.elapsed() >= timeout {
            instruments.conns_idle_closed.inc();
            let _ = conn.stream.shutdown(Shutdown::Both);
            return Pump::Closed;
        }
    }
    Pump::Alive { progress }
}

/// Route one decoded request to its scheme's cluster.
fn route(req: Request, clusters: &SchemeClusters) -> Pending {
    let cluster = match clusters.get(req.scheme) {
        Some(cluster) => cluster,
        None => return Pending::Immediate(Response::error(Status::Unsupported, req.class, req.id)),
    };
    if req.round != RoundMode::NearestEven {
        // The batch backends only run round-to-nearest-even.
        return Pending::Immediate(Response::error(Status::Unsupported, req.class, req.id));
    }
    match cluster.try_submit(req.id, req.class, req.a, req.b) {
        Ok(reply) => Pending::Submitted { id: req.id, class: req.class, reply },
        // Backpressure and shutdown become status responses — the
        // connection survives a saturated cluster.
        Err(e) => Pending::Immediate(Response::error(Status::from(e), req.class, req.id)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::FrameRead;

    fn tiny_config() -> NetServerConfig {
        NetServerConfig {
            cluster: ClusterConfig {
                shards: 1,
                service: ServiceConfig {
                    workers: 1,
                    max_batch: 32,
                    linger_us: 100,
                    ..Default::default()
                },
                ..Default::default()
            },
            net_workers: 2,
            ..Default::default()
        }
    }

    fn send_recv(stream: &mut TcpStream, frame: &[u8]) -> Response {
        stream.write_all(frame).unwrap();
        let mut payload = Vec::new();
        assert_eq!(wire::read_frame(stream, &mut payload).unwrap(), FrameRead::Frame);
        Response::decode(&payload).unwrap()
    }

    fn request_frame(id: u64, class: OpClass, scheme: SchemeKind, a: u128, b: u128) -> Vec<u8> {
        let mut frame = Vec::new();
        let (a, b) = (a.into(), b.into());
        Request { id, class, scheme, round: RoundMode::NearestEven, a, b }.encode(&mut frame);
        frame
    }

    #[test]
    fn loopback_multiply_and_unsupported() {
        let server =
            NetServer::start(&tiny_config(), BackendChoice::native(SchemeKind::Civp)).unwrap();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        let one = OpClass::Double.format().one();
        let frame = request_frame(42, OpClass::Double, SchemeKind::Civp, one, one);
        let resp = send_recv(&mut stream, &frame);
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.id, 42);
        assert_eq!(resp.bits, one, "1.0 * 1.0 is exact over the wire too");
        // A scheme this deployment does not serve: a status response, not
        // a close.
        let bad = request_frame(43, OpClass::Double, SchemeKind::Baseline18, one, one);
        let resp = send_recv(&mut stream, &bad);
        assert_eq!(resp.status, Status::Unsupported);
        assert_eq!(resp.id, 43);
        // The connection survived both: one more good request.
        let frame = request_frame(44, OpClass::Double, SchemeKind::Civp, one, one);
        assert_eq!(send_recv(&mut stream, &frame).status, Status::Ok);
        drop(stream);
        let report = server.stop();
        assert_eq!(report.total_ops, 2, "only the two supported requests executed");
    }

    #[test]
    fn malformed_frame_gets_bad_request_not_a_hang() {
        let server =
            NetServer::start(&tiny_config(), BackendChoice::native(SchemeKind::Civp)).unwrap();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        // Oversized length prefix: one BadRequest, then the server closes.
        stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let mut payload = Vec::new();
        assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
        let resp = Response::decode(&payload).unwrap();
        assert_eq!(resp.status, Status::BadRequest);
        assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Eof);
        server.stop();
    }

    #[test]
    fn per_scheme_routing_serves_multiple_clusters() {
        let mut cfg = tiny_config();
        cfg.extra_schemes = vec![SchemeKind::Baseline18, SchemeKind::Baseline9];
        let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();
        assert_eq!(
            server.schemes(),
            vec![SchemeKind::Civp, SchemeKind::Baseline18, SchemeKind::Baseline9]
        );
        let one = OpClass::Single.format().one();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        for (id, scheme) in
            [(1, SchemeKind::Civp), (2, SchemeKind::Baseline18), (3, SchemeKind::Baseline9)]
        {
            let frame = request_frame(id, OpClass::Single, scheme, one, one);
            let resp = send_recv(&mut stream, &frame);
            assert_eq!(resp.status, Status::Ok, "{scheme:?} must be served, not Unsupported");
            assert_eq!(resp.id, id);
            assert_eq!(resp.bits, one);
        }
        // A scheme outside the served set still answers Unsupported.
        let frame = request_frame(4, OpClass::Single, SchemeKind::Baseline25x18, one, one);
        assert_eq!(send_recv(&mut stream, &frame).status, Status::Unsupported);
        // Each scheme's ops landed in its own cluster.
        for scheme in [SchemeKind::Civp, SchemeKind::Baseline18, SchemeKind::Baseline9] {
            let ops: u64 = server.cluster_for(scheme).unwrap().op_counts().values().sum();
            assert_eq!(ops, 1, "{scheme:?} cluster executed exactly its own frame");
        }
        assert!(server.cluster_for(SchemeKind::Baseline25x18).is_none());
        drop(stream);
        server.stop();
    }

    #[test]
    fn worker_pool_is_bounded_and_metrics_count_statuses() {
        let mut cfg = tiny_config();
        cfg.net_workers = 3;
        let server = NetServer::start(&cfg, BackendChoice::native(SchemeKind::Civp)).unwrap();
        // 9 connections over a 3-worker pool: the registry shows 3
        // workers (thread bound = pool size, not connection count) with
        // every connection assigned to one of them.
        let one = OpClass::Single.format().one();
        let mut streams: Vec<TcpStream> = (0..9)
            .map(|_| TcpStream::connect(server.local_addr()).unwrap())
            .collect();
        for (i, stream) in streams.iter_mut().enumerate() {
            let frame = request_frame(i as u64, OpClass::Single, SchemeKind::Civp, one, one);
            assert_eq!(send_recv(stream, &frame).status, Status::Ok);
        }
        let registry = server.worker_registry();
        assert_eq!(registry.len(), 3, "pool size is fixed at startup");
        assert_eq!(
            registry.iter().map(|(_, n)| n).sum::<usize>(),
            9,
            "every connection is owned by exactly one pool worker"
        );
        assert!(
            registry.iter().all(|(_, n)| *n == 3),
            "least-loaded assignment spreads 9 conns evenly over 3 workers: {registry:?}"
        );
        let snapshot = server.metrics();
        assert_eq!(snapshot.gauges["net_open_connections"], 9);
        assert_eq!(snapshot.counters["net_frames_ok"], 9);
        assert_eq!(snapshot.counters["net_frames_unsupported"], 0);
        assert!(snapshot.gauges["net_pipeline_inflight_hwm"] >= 1);
        drop(streams);
        server.stop();
    }
}
