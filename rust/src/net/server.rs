//! Std-only multi-threaded TCP listener feeding the cluster router.
//!
//! Thread model (no async runtime — blocking I/O end to end):
//!
//! ```text
//!   accept thread ── one per listener, spawns per-connection pairs
//!     ├─ reader thread ── read_frame → decode → Cluster::try_submit
//!     │                    │ admission/decode errors become status
//!     │                    ▼ responses, never dropped connections
//!     │    bounded writer queue (reader blocks when full ⇒ it stops
//!     │    reading the socket ⇒ TCP backpressure reaches the client)
//!     │                    ▼
//!     └─ writer thread ── FIFO: ClusterReply::recv → encode → write
//! ```
//!
//! Responses are written in request order per connection (the writer
//! drains its queue FIFO), trading head-of-line latency for a protocol
//! with no reordering to track. Cross-connection parallelism comes from
//! the per-connection thread pairs; within the cluster, batching and the
//! shard worker pools parallelize as in the in-process paths.
//!
//! Framing-level failures (truncated stream, oversized length prefix)
//! get one [`Status::BadRequest`] response and then the connection
//! closes — the byte stream cannot be resynchronized. In-frame decode
//! failures (bad version, unknown class index, length mismatch against a
//! valid prefix) also answer `BadRequest` but keep the connection open:
//! framing is intact, so subsequent frames still parse.

use super::wire::{self, FrameRead, Request, Response, Status};
use crate::cluster::{Cluster, ClusterConfig, ClusterReply, ClusterReport};
use crate::coordinator::BackendChoice;
use crate::decomp::{OpClass, SchemeKind};
use crate::error::{Context, Result};
use crate::fpu::RoundMode;
use std::io::{BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Listener deployment shape.
#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// Bind address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// The cluster behind the listener (shards, policy, in-flight bound).
    /// Its `service.scheme` is the one partition organization this
    /// listener serves; requests for any other scheme — or for a rounding
    /// mode other than round-to-nearest-even, the only mode the batch
    /// backends run — are answered [`Status::Unsupported`].
    pub cluster: ClusterConfig,
    /// Per-connection bound on replies awaiting the writer. When full,
    /// the reader stops pulling frames off the socket, which is the
    /// mechanism that turns cluster latency into TCP backpressure.
    pub writer_queue: usize,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            addr: "127.0.0.1:0".to_string(),
            cluster: ClusterConfig::default(),
            writer_queue: 256,
        }
    }
}

/// One entry in a connection's FIFO writer queue.
enum Pending {
    /// Admitted into the cluster; the writer blocks on the reply.
    Submitted {
        id: u64,
        class: OpClass,
        reply: ClusterReply,
    },
    /// Already resolved at the reader (admission/decode/validation
    /// outcome) — encoded as-is, in order.
    Immediate(Response),
}

/// A running network serving edge: TCP listener + cluster.
pub struct NetServer {
    local_addr: SocketAddr,
    cluster: Arc<Cluster>,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: JoinHandle<()>,
}

impl NetServer {
    /// Bind, start the cluster and the accept thread, return immediately.
    pub fn start(cfg: &NetServerConfig, backend: BackendChoice) -> Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding listener on {}", cfg.addr))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        let cluster = Arc::new(Cluster::start(&cfg.cluster, backend));
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let cluster = cluster.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            let scheme = cfg.cluster.service.scheme;
            let writer_queue = cfg.writer_queue.max(1);
            std::thread::spawn(move || {
                let mut workers: Vec<JoinHandle<()>> = Vec::new();
                for incoming in listener.incoming() {
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let stream = match incoming {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    // Keep a handle for forced shutdown; readers blocked in
                    // `read` see EOF when `stop` shuts these down.
                    if let Ok(clone) = stream.try_clone() {
                        conns.lock().unwrap().push(clone);
                    }
                    let cluster = cluster.clone();
                    workers.push(std::thread::spawn(move || {
                        handle_conn(stream, &cluster, scheme, writer_queue);
                    }));
                }
                for w in workers {
                    let _ = w.join();
                }
            })
        };
        Ok(NetServer { local_addr, cluster, stop, conns, accept })
    }

    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The cluster behind the listener (op counters, metrics — the e2e
    /// oracle that per-class executed counts match frames sent).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Stop accepting, close every live connection, join every thread,
    /// then drain the cluster and return its final report.
    pub fn stop(self) -> ClusterReport {
        let NetServer { local_addr, cluster, stop, conns, accept } = self;
        stop.store(true, Ordering::Release);
        // Unblock the accept loop (it re-checks `stop` per connection).
        let _ = TcpStream::connect(local_addr);
        for s in conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        let _ = accept.join();
        match Arc::try_unwrap(cluster) {
            Ok(c) => c.shutdown(),
            // Defensive: joining the accept thread joined every reader and
            // writer, so no clone should survive — but never panic in
            // shutdown.
            Err(shared) => {
                shared.drain();
                shared.report()
            }
        }
    }
}

/// Serve one connection: spawn the writer, run the reader inline, join.
fn handle_conn(stream: TcpStream, cluster: &Cluster, scheme: SchemeKind, writer_queue: usize) {
    let _ = stream.set_nodelay(true);
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = sync_channel::<Pending>(writer_queue);
    let writer = std::thread::spawn(move || write_loop(writer_stream, rx));
    read_loop(stream, cluster, scheme, &tx);
    drop(tx); // writer drains the queue FIFO, then exits
    let _ = writer.join();
}

/// Decode frames and resolve admission until EOF / framing loss / error.
fn read_loop(stream: TcpStream, cluster: &Cluster, scheme: SchemeKind, tx: &SyncSender<Pending>) {
    let mut reader = BufReader::new(stream);
    let mut payload = Vec::with_capacity(wire::MAX_REQUEST_PAYLOAD);
    loop {
        match wire::read_frame(&mut reader, &mut payload) {
            // Transport error: the peer is unreachable, nothing to answer.
            Err(_) => return,
            Ok(FrameRead::Eof) => return,
            Ok(FrameRead::Truncated) | Ok(FrameRead::Oversized(_)) => {
                // Framing lost: answer once, then close.
                let resp = Response::error(Status::BadRequest, OpClass::from_index(0), 0);
                let _ = tx.send(Pending::Immediate(resp));
                return;
            }
            Ok(FrameRead::Frame) => {}
        }
        let req = match Request::decode(&payload) {
            Ok(req) => req,
            Err(_) => {
                // In-frame error: framing intact, connection stays open.
                let resp = Response::error(Status::BadRequest, OpClass::from_index(0), 0);
                if tx.send(Pending::Immediate(resp)).is_err() {
                    return;
                }
                continue;
            }
        };
        let pending = if req.scheme != scheme || req.round != RoundMode::NearestEven {
            Pending::Immediate(Response::error(Status::Unsupported, req.class, req.id))
        } else {
            match cluster.try_submit(req.id, req.class, req.a, req.b) {
                Ok(reply) => Pending::Submitted { id: req.id, class: req.class, reply },
                // Backpressure and shutdown become status responses — the
                // connection survives a saturated cluster.
                Err(e) => Pending::Immediate(Response::error(Status::from(e), req.class, req.id)),
            }
        };
        if tx.send(pending).is_err() {
            return; // writer side is gone
        }
    }
}

/// Drain the FIFO queue: wait for each admitted reply, encode, write.
fn write_loop(stream: TcpStream, rx: Receiver<Pending>) {
    let mut writer = BufWriter::new(stream);
    let mut buf = Vec::with_capacity(64);
    while let Ok(pending) = rx.recv() {
        let resp = match pending {
            Pending::Immediate(resp) => resp,
            Pending::Submitted { id, class, reply } => match reply.recv() {
                Ok(done) => Response::ok(class, id, done.bits),
                // Admitted but the shard died before replying: the client
                // still gets exactly one response for the frame.
                Err(_) => Response::error(Status::Internal, class, id),
            },
        };
        buf.clear();
        resp.encode(&mut buf);
        if writer.write_all(&buf).is_err() || writer.flush().is_err() {
            return; // peer gone; remaining replies are dropped with the queue
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceConfig;

    fn tiny_config() -> NetServerConfig {
        NetServerConfig {
            cluster: ClusterConfig {
                shards: 1,
                service: ServiceConfig {
                    workers: 1,
                    max_batch: 32,
                    linger_us: 100,
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        }
    }

    fn send_recv(stream: &mut TcpStream, frame: &[u8]) -> Response {
        stream.write_all(frame).unwrap();
        let mut payload = Vec::new();
        assert_eq!(wire::read_frame(stream, &mut payload).unwrap(), FrameRead::Frame);
        Response::decode(&payload).unwrap()
    }

    #[test]
    fn loopback_multiply_and_unsupported() {
        let server = NetServer::start(
            &tiny_config(),
            BackendChoice::native(SchemeKind::Civp),
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        let one = OpClass::Double.format().one();
        let mut frame = Vec::new();
        Request {
            id: 42,
            class: OpClass::Double,
            scheme: SchemeKind::Civp,
            round: RoundMode::NearestEven,
            a: one,
            b: one,
        }
        .encode(&mut frame);
        let resp = send_recv(&mut stream, &frame);
        assert_eq!(resp.status, Status::Ok);
        assert_eq!(resp.id, 42);
        assert_eq!(resp.bits, one, "1.0 * 1.0 is exact over the wire too");
        // Wrong scheme for this server: a status response, not a close.
        frame.clear();
        Request {
            id: 43,
            class: OpClass::Double,
            scheme: SchemeKind::Baseline18,
            round: RoundMode::NearestEven,
            a: one,
            b: one,
        }
        .encode(&mut frame);
        let resp = send_recv(&mut stream, &frame);
        assert_eq!(resp.status, Status::Unsupported);
        assert_eq!(resp.id, 43);
        // The connection survived both: one more good request.
        frame.clear();
        Request {
            id: 44,
            class: OpClass::Double,
            scheme: SchemeKind::Civp,
            round: RoundMode::NearestEven,
            a: one,
            b: one,
        }
        .encode(&mut frame);
        assert_eq!(send_recv(&mut stream, &frame).status, Status::Ok);
        drop(stream);
        let report = server.stop();
        assert_eq!(report.total_ops, 2, "only the two supported requests executed");
    }

    #[test]
    fn malformed_frame_gets_bad_request_not_a_hang() {
        let server = NetServer::start(
            &tiny_config(),
            BackendChoice::native(SchemeKind::Civp),
        )
        .unwrap();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        // Oversized length prefix: one BadRequest, then the server closes.
        stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let mut payload = Vec::new();
        assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Frame);
        let resp = Response::decode(&payload).unwrap();
        assert_eq!(resp.status, Status::BadRequest);
        assert_eq!(wire::read_frame(&mut stream, &mut payload).unwrap(), FrameRead::Eof);
        server.stop();
    }
}
