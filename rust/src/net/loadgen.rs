//! Built-in load generator for the network serving edge.
//!
//! Open-loop by default: each connection draws arrivals from a
//! [`TraceGen`] with exponential inter-arrival gaps sized so the
//! connections together offer `rate` requests/second, and sends each
//! request at its trace arrival time regardless of replies — the offered
//! load does not slow down when the server does, which is what makes
//! [`Status::Saturated`] responses observable. `rate == 0` switches to a
//! flood (send as fast as the socket accepts).
//!
//! **Closed-loop mode** (`closed_loop` + `concurrency`) additionally
//! bounds the outstanding-request window: each connection holds a token
//! budget (its share of `concurrency`), acquires a token before every
//! send and releases one per reply, so the generator pipelines up to the
//! window and then paces itself off the server's completions. Composed
//! with `rate` it offers *up to* the configured load without ever
//! holding more than the window in flight — the shape [`run_sweep`]
//! drives at several offered rates to trace a latency-vs-load curve
//! (`net/<mix>/p99@<rate>` rows) whose knee the bench gate pins.
//!
//! Per connection, a paired reader thread consumes responses (matched by
//! request id, so the listener's out-of-order pipelined completions are
//! fine) under a read timeout, so replies the server never delivers
//! surface as a `lost` count instead of a hang. The first `warmup`
//! requests per connection are excluded from the latency distribution;
//! every reply is still counted by status. Latency percentiles are exact
//! (all post-warmup samples are kept and sorted — at bench scale this is
//! a few MB, not a reservoir's approximation).

use super::wire::{self, FrameRead, Request, Response, Status};
use crate::benchx::{wall_measurement, JsonReport, Measurement};
use crate::decomp::{OpClass, SchemeKind};
use crate::error::{err, Context, Result};
use crate::fpu::RoundMode;
use crate::trace::{TraceGen, WorkloadMix};
use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Load-generation shape for one run (one workload mix).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent connections (each a sender/reader thread pair).
    pub conns: usize,
    /// Total requests across all connections.
    pub requests: u64,
    /// Leading requests per run excluded from latency stats (split
    /// across connections like `requests`).
    pub warmup: u64,
    /// Offered load in requests/second across all connections;
    /// `0.0` floods (no pacing).
    pub rate: f64,
    /// Bound the outstanding-request window instead of offering load
    /// unconditionally (`--closed-loop`). Composable with `rate`: the
    /// generator offers up to the configured load, never holding more
    /// than `concurrency` requests in flight.
    pub closed_loop: bool,
    /// Outstanding-request window across all connections (closed-loop
    /// mode only; split over connections, each gets at least 1).
    pub concurrency: usize,
    /// Class mix to draw requests from.
    pub mix: WorkloadMix,
    /// Mix label for reports and bench-row names.
    pub mix_name: String,
    /// Scheme stamped on every request (must match the server's).
    pub scheme: SchemeKind,
    /// Rounding mode stamped on every request.
    pub round: RoundMode,
    /// Trace seed (connection `i` uses `seed + i`).
    pub seed: u64,
    /// Reader-side timeout: replies slower than this count as lost.
    pub reply_timeout: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            conns: 4,
            requests: 10_000,
            warmup: 500,
            rate: 0.0,
            closed_loop: false,
            concurrency: 32,
            mix: WorkloadMix::ZERO,
            mix_name: String::new(),
            scheme: SchemeKind::Civp,
            round: RoundMode::NearestEven,
            seed: 20260808,
            reply_timeout: Duration::from_secs(5),
        }
    }
}

/// Merged outcome of one load-generation run.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// Mix label the run drew from.
    pub mix_name: String,
    /// Frames sent.
    pub sent: u64,
    /// `Ok` replies.
    pub ok: u64,
    /// `Saturated` replies (admission backpressure made visible).
    pub saturated: u64,
    /// Replies with any other non-`Ok` status.
    pub other: u64,
    /// Frames sent that never got a reply before timeout/close.
    pub lost: u64,
    /// Wall time of the whole run (connect to last reply), seconds.
    pub wall_s: f64,
    /// Exact latency percentiles over post-warmup replies, nanoseconds.
    pub p50_ns: u64,
    /// 99th percentile latency (ns).
    pub p99_ns: u64,
    /// 99.9th percentile latency (ns).
    pub p999_ns: u64,
    /// Frames sent per op class (the e2e oracle against the server's
    /// per-class op counters).
    pub per_class_sent: [u64; OpClass::COUNT],
}

impl LoadgenReport {
    /// Replies received, any status.
    pub fn replies(&self) -> u64 {
        self.ok + self.saturated + self.other
    }

    /// Sustained reply throughput over the run (replies/second).
    pub fn throughput(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return 0.0;
        }
        self.replies() as f64 / self.wall_s
    }

    /// Human-readable summary block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "mix {:<10} sent {:>8}  ok {:>8}  saturated {:>6}  other {:>4}  lost {:>4}\n",
            self.mix_name, self.sent, self.ok, self.saturated, self.other, self.lost
        ));
        out.push_str(&format!(
            "  throughput {:>10.0} replies/s over {:.3} s\n",
            self.throughput(),
            self.wall_s
        ));
        out.push_str(&format!(
            "  latency    p50 {:>9} ns   p99 {:>9} ns   p999 {:>9} ns\n",
            self.p50_ns, self.p99_ns, self.p999_ns
        ));
        for class in OpClass::ALL {
            let n = self.per_class_sent[class.index()];
            if n > 0 {
                out.push_str(&format!("  sent[{:<9}] {n}\n", class.name()));
            }
        }
        out
    }

    /// Append this run's bench rows to a [`JsonReport`] under
    /// `net/<mix>/...`. Latency rows carry nanoseconds in the
    /// `ns_per_op_*` fields; count rows (`frames-sent`, `replies-*`,
    /// `lost`) carry their count in `total_ops` with zeroed timings, so
    /// the bench gate can check conservation without parsing names.
    pub fn push_bench_rows(&self, report: &mut JsonReport) {
        let prefix = format!("net/{}", self.mix_name);
        let replies = self.replies();
        for (suffix, ns) in [
            ("latency-p50", self.p50_ns),
            ("latency-p99", self.p99_ns),
            ("latency-p999", self.p999_ns),
        ] {
            report.push(&format!("{prefix}/{suffix}"), Measurement::uniform(ns as f64, replies));
        }
        report.push(
            &format!("{prefix}/throughput"),
            wall_measurement(replies.max(1), self.wall_s.max(1e-9)),
        );
        for (suffix, n) in [
            ("frames-sent", self.sent),
            ("replies-ok", self.ok),
            ("replies-saturated", self.saturated),
            ("replies-other", self.other),
            ("lost", self.lost),
        ] {
            report.push(&format!("{prefix}/{suffix}"), Measurement::uniform(0.0, n));
        }
    }
}

/// One point on the latency-vs-offered-load curve.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Offered load for this point (requests/second).
    pub rate: f64,
    /// The full run outcome at that rate.
    pub report: LoadgenReport,
}

/// Outcome of an offered-load sweep: the same workload driven at each
/// configured rate in ascending order, closed-loop, so the curve's knee
/// — the last rate the deployment absorbs without p99 blowing up — is a
/// measurable, gateable property (the bench gate checks knee *location*,
/// not absolute latency, which is what survives machine variance).
#[derive(Clone, Debug)]
pub struct SweepReport {
    /// Mix label the sweep drew from.
    pub mix_name: String,
    /// Connection-worker pool size of the server under test (stamped by
    /// the caller; the gate derives its knee floor from it).
    pub workers: usize,
    /// One entry per swept rate, in the order driven (ascending).
    pub points: Vec<SweepPoint>,
}

impl SweepReport {
    /// Human-readable sweep table.
    pub fn render(&self) -> String {
        let mut out = format!("sweep mix {} ({} server workers)\n", self.mix_name, self.workers);
        for p in &self.points {
            out.push_str(&format!(
                "  rate {:>8}  p50 {:>9} ns  p99 {:>9} ns  ok {:>8}  lost {:>4}\n",
                rate_label(p.rate),
                p.report.p50_ns,
                p.report.p99_ns,
                p.report.ok,
                p.report.lost
            ));
        }
        out
    }

    /// Append the sweep rows to a [`JsonReport`]: per swept rate a
    /// `net/<mix>/p50@<rate>` and `p99@<rate>` latency row and a
    /// `lost@<rate>` count row, plus one `net/<mix>/sweep-workers` count
    /// row carrying the server's worker-pool size (the gate's knee floor
    /// is derived from it). All `net/` rows are never-baselined; the
    /// gate checks curve *shape*, not absolute values.
    pub fn push_bench_rows(&self, report: &mut JsonReport) {
        let prefix = format!("net/{}", self.mix_name);
        report.push(
            &format!("{prefix}/sweep-workers"),
            Measurement::uniform(0.0, self.workers as u64),
        );
        for p in &self.points {
            let rate = rate_label(p.rate);
            let replies = p.report.replies();
            report.push(
                &format!("{prefix}/p50@{rate}"),
                Measurement::uniform(p.report.p50_ns as f64, replies),
            );
            report.push(
                &format!("{prefix}/p99@{rate}"),
                Measurement::uniform(p.report.p99_ns as f64, replies),
            );
            report.push(
                &format!("{prefix}/lost@{rate}"),
                Measurement::uniform(0.0, p.report.lost),
            );
        }
    }
}

/// Stable row-name label for an offered rate: integral rates print
/// without a fraction (`2000`), fractional ones with one decimal.
pub fn rate_label(rate: f64) -> String {
    if rate.fract() == 0.0 && rate.abs() < 1e15 {
        format!("{}", rate as i64)
    } else {
        format!("{rate:.1}")
    }
}

/// Drive one closed-loop run per rate in `rates` (ascending, positive)
/// against the same server and assemble the latency-vs-load curve.
/// `workers` is the server's connection-worker pool size, stamped into
/// the report for the knee gate's floor. Each point perturbs the trace
/// seed so the points are independent draws of the same mix.
pub fn run_sweep(cfg: &LoadgenConfig, rates: &[f64], workers: usize) -> Result<SweepReport> {
    if rates.is_empty() {
        return Err(err!("sweep needs at least one rate"));
    }
    if workers == 0 {
        return Err(err!("sweep needs the server worker count (>= 1)"));
    }
    for pair in rates.windows(2) {
        if pair[0] >= pair[1] {
            return Err(err!("sweep rates must be strictly ascending"));
        }
    }
    if rates[0] <= 0.0 || !rates.iter().all(|r| r.is_finite()) {
        return Err(err!("sweep rates must be positive finite numbers"));
    }
    let mut points = Vec::with_capacity(rates.len());
    for (i, &rate) in rates.iter().enumerate() {
        let mut point_cfg = cfg.clone();
        point_cfg.rate = rate;
        point_cfg.closed_loop = true;
        point_cfg.seed = cfg.seed.wrapping_add((i as u64) << 48);
        points.push(SweepPoint { rate, report: run(&point_cfg)? });
    }
    Ok(SweepReport { mix_name: cfg.mix_name.clone(), workers, points })
}

/// What one connection's reader thread tallied.
#[derive(Default)]
struct ReaderTally {
    received: u64,
    ok: u64,
    saturated: u64,
    other: u64,
    latencies_ns: Vec<u64>,
}

/// Drive one run against `cfg.addr` and merge the per-connection tallies.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.conns == 0 || cfg.requests == 0 {
        return Err(err!("loadgen needs at least 1 connection and 1 request"));
    }
    if cfg.conns > u32::MAX as usize {
        return Err(err!("connection count does not fit the id space"));
    }
    if cfg.closed_loop && cfg.concurrency == 0 {
        return Err(err!("closed-loop mode needs a concurrency window >= 1"));
    }
    let per_conn = split(cfg.requests, cfg.conns);
    let warmup_per_conn = split(cfg.warmup.min(cfg.requests), cfg.conns);
    // Each connection carries rate/conns; exponential gaps at that mean
    // superpose to the configured aggregate offered load.
    let mean_gap_ns = if cfg.rate > 0.0 {
        (cfg.conns as f64 * 1e9 / cfg.rate) as u64
    } else {
        0
    };
    let t0 = Instant::now();
    let workers: Vec<_> = (0..cfg.conns)
        .map(|i| {
            let cfg = cfg.clone();
            let (n, warm) = (per_conn[i], warmup_per_conn[i]);
            std::thread::spawn(move || run_conn(&cfg, i as u32, n, warm, mean_gap_ns))
        })
        .collect();
    let mut report = LoadgenReport {
        mix_name: cfg.mix_name.clone(),
        sent: 0,
        ok: 0,
        saturated: 0,
        other: 0,
        lost: 0,
        wall_s: 0.0,
        p50_ns: 0,
        p99_ns: 0,
        p999_ns: 0,
        per_class_sent: [0; OpClass::COUNT],
    };
    let mut latencies: Vec<u64> = Vec::new();
    for worker in workers {
        let conn = worker.join().map_err(|_| err!("loadgen connection thread panicked"))??;
        report.sent += conn.sent;
        report.ok += conn.tally.ok;
        report.saturated += conn.tally.saturated;
        report.other += conn.tally.other;
        report.lost += conn.sent - conn.tally.received;
        for class in OpClass::ALL {
            report.per_class_sent[class.index()] += conn.per_class[class.index()];
        }
        latencies.extend(conn.tally.latencies_ns);
    }
    report.wall_s = t0.elapsed().as_secs_f64();
    latencies.sort_unstable();
    report.p50_ns = quantile(&latencies, 0.50);
    report.p99_ns = quantile(&latencies, 0.99);
    report.p999_ns = quantile(&latencies, 0.999);
    Ok(report)
}

/// Spread `total` over `parts` buckets, remainder on the leading ones.
fn split(total: u64, parts: usize) -> Vec<u64> {
    let base = total / parts as u64;
    let rem = (total % parts as u64) as usize;
    (0..parts).map(|i| base + u64::from(i < rem)).collect()
}

/// Exact quantile of a sorted sample (nearest-rank on the closed index).
fn quantile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

struct ConnResult {
    sent: u64,
    per_class: [u64; OpClass::COUNT],
    tally: ReaderTally,
}

fn run_conn(
    cfg: &LoadgenConfig,
    conn_idx: u32,
    n: u64,
    warmup: u64,
    mean_gap_ns: u64,
) -> Result<ConnResult> {
    let stream = TcpStream::connect(&cfg.addr)
        .with_context(|| format!("connecting to {}", cfg.addr))?;
    let _ = stream.set_nodelay(true);
    let reader_stream = stream.try_clone().context("cloning stream for the reader")?;
    reader_stream
        .set_read_timeout(Some(cfg.reply_timeout))
        .context("setting reply timeout")?;
    // Closed-loop token window: the sender deposits a token per send
    // (blocking at the window bound), the reader withdraws one per
    // reply. When the reader dies early (timeout/close) the dropped
    // receiver unblocks the sender with an error instead of a deadlock.
    let window = if cfg.closed_loop {
        // This connection's share of the aggregate window, never zero.
        let share = (cfg.concurrency / cfg.conns).max(1);
        Some(std::sync::mpsc::sync_channel::<()>(share))
    } else {
        None
    };
    let (tokens_in, tokens_out) = match window {
        Some((tx, rx)) => (Some(tx), Some(rx)),
        None => (None, None),
    };
    // Send timestamps indexed by per-connection sequence number, written
    // by the sender before each frame and read by the reader on reply.
    let send_ns: Arc<Vec<AtomicU64>> = Arc::new((0..n).map(|_| AtomicU64::new(0)).collect());
    let start = Instant::now();
    let reader = {
        let send_ns = send_ns.clone();
        std::thread::spawn(move || {
            read_replies(reader_stream, n, warmup, &send_ns, start, tokens_out)
        })
    };
    let mut gen = TraceGen::new(cfg.seed.wrapping_add(conn_idx as u64), cfg.mix, mean_gap_ns);
    let mut writer = BufWriter::new(stream);
    let mut buf = Vec::with_capacity(64);
    let mut per_class = [0u64; OpClass::COUNT];
    let mut sent = 0u64;
    for seq in 0..n {
        let trace = gen.next();
        if mean_gap_ns > 0 {
            // Open loop: release at the trace arrival time, replies or not.
            let target = Duration::from_nanos(trace.arrival_ns);
            let elapsed = start.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
        }
        if let Some(tx) = &tokens_in {
            // Closed loop: block until the window has room. A dead
            // reader dropped its receiver — stop offering load.
            if tx.send(()).is_err() {
                break;
            }
        }
        let req = Request {
            id: (u64::from(conn_idx) << 32) | seq,
            class: trace.class,
            scheme: cfg.scheme,
            round: cfg.round,
            a: trace.a,
            b: trace.b,
        };
        buf.clear();
        req.encode(&mut buf);
        send_ns[seq as usize].store(start.elapsed().as_nanos() as u64, Ordering::Release);
        if writer.write_all(&buf).is_err() || writer.flush().is_err() {
            break; // server closed; the reader tallies what came back
        }
        per_class[trace.class.index()] += 1;
        sent += 1;
    }
    let tally = reader.join().map_err(|_| err!("loadgen reader thread panicked"))?;
    Ok(ConnResult { sent, per_class, tally })
}

/// Consume replies until `expect` arrived or the stream times out/closes.
fn read_replies(
    stream: TcpStream,
    expect: u64,
    warmup: u64,
    send_ns: &[AtomicU64],
    start: Instant,
    tokens: Option<std::sync::mpsc::Receiver<()>>,
) -> ReaderTally {
    let mut tally = ReaderTally::default();
    let mut reader = BufReader::new(stream);
    let mut payload = Vec::with_capacity(64);
    while tally.received < expect {
        match wire::read_frame(&mut reader, &mut payload) {
            Ok(FrameRead::Frame) => {}
            // EOF, framing loss, or timeout: the rest counts as lost.
            _ => break,
        }
        let resp = match Response::decode(&payload) {
            Ok(resp) => resp,
            Err(_) => break,
        };
        if let Some(rx) = &tokens {
            // Every reply follows a send that deposited a token, so
            // there is always one to withdraw — never blocks.
            let _ = rx.try_recv();
        }
        tally.received += 1;
        match resp.status {
            Status::Ok => tally.ok += 1,
            Status::Saturated => tally.saturated += 1,
            _ => tally.other += 1,
        }
        let seq = resp.id & 0xffff_ffff;
        if seq >= warmup && (seq as usize) < send_ns.len() {
            let sent_at = send_ns[seq as usize].load(Ordering::Acquire);
            let now = start.elapsed().as_nanos() as u64;
            tally.latencies_ns.push(now.saturating_sub(sent_at));
        }
    }
    tally
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_conserves_and_balances() {
        assert_eq!(split(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(split(3, 4), vec![1, 1, 1, 0]);
        assert_eq!(split(8, 2).iter().sum::<u64>(), 8);
    }

    #[test]
    fn quantiles_are_exact_on_small_samples() {
        assert_eq!(quantile(&[], 0.5), 0);
        assert_eq!(quantile(&[7], 0.999), 7);
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(quantile(&v, 0.50), 50);
        assert_eq!(quantile(&v, 0.99), 99);
        assert_eq!(quantile(&v, 0.999), 100);
    }

    #[test]
    fn bench_rows_follow_the_net_schema() {
        let report = LoadgenReport {
            mix_name: "mixed".to_string(),
            sent: 100,
            ok: 90,
            saturated: 8,
            other: 2,
            lost: 0,
            wall_s: 0.5,
            p50_ns: 1000,
            p99_ns: 5000,
            p999_ns: 9000,
            per_class_sent: [20; OpClass::COUNT],
        };
        let mut json = JsonReport::new();
        report.push_bench_rows(&mut json);
        let text = json.to_json();
        for name in [
            "net/mixed/latency-p50",
            "net/mixed/latency-p99",
            "net/mixed/latency-p999",
            "net/mixed/throughput",
            "net/mixed/frames-sent",
            "net/mixed/replies-ok",
            "net/mixed/replies-saturated",
            "net/mixed/replies-other",
            "net/mixed/lost",
        ] {
            assert!(text.contains(&format!("\"name\": \"{name}\"")), "{name} missing");
        }
        assert_eq!(report.replies(), 100);
        assert_eq!(report.throughput(), 200.0);
        assert!(report.render().contains("saturated"));
    }

    #[test]
    fn rate_labels_are_stable_row_names() {
        assert_eq!(rate_label(2000.0), "2000");
        assert_eq!(rate_label(500.0), "500");
        assert_eq!(rate_label(1234.5), "1234.5");
        assert_eq!(rate_label(0.25), "0.2");
    }

    fn sweep_fixture() -> SweepReport {
        let point = |rate: f64, p99: u64, lost: u64| SweepPoint {
            rate,
            report: LoadgenReport {
                mix_name: "mixed".to_string(),
                sent: 100,
                ok: 100 - lost,
                saturated: 0,
                other: 0,
                lost,
                wall_s: 0.5,
                p50_ns: p99 / 2,
                p99_ns: p99,
                p999_ns: p99 * 2,
                per_class_sent: [20; OpClass::COUNT],
            },
        };
        SweepReport {
            mix_name: "mixed".to_string(),
            workers: 4,
            points: vec![point(500.0, 1000, 0), point(1000.0, 1100, 0), point(2000.0, 9000, 0)],
        }
    }

    #[test]
    fn sweep_rows_follow_the_net_schema() {
        let sweep = sweep_fixture();
        let mut json = JsonReport::new();
        sweep.push_bench_rows(&mut json);
        let text = json.to_json();
        for name in [
            "net/mixed/sweep-workers",
            "net/mixed/p50@500",
            "net/mixed/p99@500",
            "net/mixed/lost@500",
            "net/mixed/p99@1000",
            "net/mixed/p99@2000",
            "net/mixed/lost@2000",
        ] {
            assert!(text.contains(&format!("\"name\": \"{name}\"")), "{name} missing");
        }
        assert!(sweep.render().contains("rate"));
    }

    #[test]
    fn sweep_rejects_bad_rate_lists_before_connecting() {
        // Validation happens before any socket work, so no server needed.
        let cfg = LoadgenConfig { addr: "127.0.0.1:1".to_string(), ..Default::default() };
        assert!(run_sweep(&cfg, &[], 4).is_err(), "empty rate list");
        assert!(run_sweep(&cfg, &[1000.0, 500.0], 4).is_err(), "descending rates");
        assert!(run_sweep(&cfg, &[500.0, 500.0], 4).is_err(), "duplicate rates");
        assert!(run_sweep(&cfg, &[0.0, 500.0], 4).is_err(), "non-positive rate");
        assert!(run_sweep(&cfg, &[500.0], 0).is_err(), "zero worker count");
    }
}
