//! Length-prefixed binary wire protocol for the network serving edge.
//!
//! Every frame on the wire is a little-endian `u32` payload length
//! followed by exactly that many payload bytes. Operand width is the op
//! class's packed size (`total_bits / 8`), so a bfloat16 request is 16
//! payload bytes and a binary512 request is [`MAX_REQUEST_PAYLOAD`]:
//!
//! ```text
//!   request  = len:u32 | ver:u8 | class:u8 | scheme:u8 | round:u8
//!            | id:u64 | a:[u8; w] | b:[u8; w]            (w = bits/8)
//!   response = len:u32 | ver:u8 | status:u8 | class:u8
//!            | id:u64 | bits:[u8; w]                     (bits iff Ok)
//! ```
//!
//! `class` and `scheme` carry *pinned wire ids* ([`class_wire_id`],
//! [`scheme_wire_id`]) — explicit per-variant byte assignments frozen for
//! protocol compatibility. Today they coincide with the registry indices
//! because new classes are appended, but the wire tables are authoritative:
//! reordering an enum must not (and, with the compat test in this module,
//! cannot silently) change what a deployed client sends. `round` is the
//! [`RoundMode::index`] (that registry is IEEE-fixed and closed). Decoding
//! is total: every malformed payload maps to a [`WireError`] (never a
//! panic), which the listener answers with [`Status::BadRequest`].
//!
//! Admission outcomes map 1:1 onto status codes —
//! [`crate::serve::AdmissionError`] `impl`s `Into<Status>` — so cluster
//! backpressure reaches the client as a [`Status::Saturated`] *response*,
//! not a dropped connection.

use crate::decomp::{OpClass, SchemeKind};
use crate::fpu::RoundMode;
use crate::serve::AdmissionError;
use crate::wideint::PackedBits;
use std::io;

/// Protocol version carried in every frame.
pub const VERSION: u8 = 1;

/// Fixed request-payload bytes before the operands.
const REQ_FIXED: usize = 12;

/// Fixed response-payload bytes before the (optional) result bits.
const RESP_FIXED: usize = 11;

/// Largest legal request payload (binary512: 12 + 2×64 bytes).
pub const MAX_REQUEST_PAYLOAD: usize = REQ_FIXED + 128;

/// Hard bound on any frame's payload length. A length prefix above this
/// is a framing error ([`FrameRead::Oversized`]) — the reader refuses to
/// allocate or skip it, answers `BadRequest` and closes.
pub const MAX_FRAME: u32 = 160;

/// Packed operand width in bytes for one op class.
pub const fn operand_bytes(class: OpClass) -> usize {
    (class.total_bits() / 8) as usize
}

/// Pinned wire byte for an op class. These assignments are frozen: a
/// deployed client's `class` byte must mean the same format forever, so
/// new classes take fresh ids and existing rows never change. (The compat
/// test `wire_ids_are_pinned` fails the build if one does.)
pub const fn class_wire_id(class: OpClass) -> u8 {
    match class {
        OpClass::Bf16 => 0,
        OpClass::Half => 1,
        OpClass::Single => 2,
        OpClass::Double => 3,
        OpClass::Quad => 4,
        OpClass::Fp256 => 5,
        OpClass::Fp512 => 6,
    }
}

/// Inverse of [`class_wire_id`]; `None` for unassigned bytes.
pub fn class_from_wire_id(id: u8) -> Option<OpClass> {
    OpClass::ALL.into_iter().find(|c| class_wire_id(*c) == id)
}

/// Pinned wire byte for a partition scheme (same freeze policy as
/// [`class_wire_id`]).
pub const fn scheme_wire_id(kind: SchemeKind) -> u8 {
    match kind {
        SchemeKind::Civp => 0,
        SchemeKind::Baseline18 => 1,
        SchemeKind::Baseline25x18 => 2,
        SchemeKind::Baseline9 => 3,
        SchemeKind::Karatsuba24 => 4,
    }
}

/// Inverse of [`scheme_wire_id`]; `None` for unassigned bytes.
pub fn scheme_from_wire_id(id: u8) -> Option<SchemeKind> {
    SchemeKind::ALL.into_iter().find(|k| scheme_wire_id(*k) == id)
}

/// Response status codes. `Saturated`/`Unservable`/`Draining` mirror
/// [`AdmissionError`] (the unified admission vocabulary); the rest are
/// wire-layer outcomes with no in-process admission analogue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Status {
    /// Executed; the response carries the product bits.
    Ok = 0,
    /// Cluster-wide backpressure ([`AdmissionError::Saturated`]) —
    /// transient, retry after draining replies.
    Saturated = 1,
    /// No live capacity serves this class
    /// ([`AdmissionError::Unservable`]) — do not retry.
    Unservable = 2,
    /// Server shutting down ([`AdmissionError::Draining`]).
    Draining = 3,
    /// The frame did not decode ([`WireError`]).
    BadRequest = 4,
    /// Decoded fine, but asks for a scheme or rounding mode this server
    /// is not configured to serve.
    Unsupported = 5,
    /// The request was admitted but its reply was lost server-side.
    Internal = 6,
}

impl Status {
    /// Every status, indexed by wire code.
    pub const ALL: [Status; 7] = [
        Status::Ok,
        Status::Saturated,
        Status::Unservable,
        Status::Draining,
        Status::BadRequest,
        Status::Unsupported,
        Status::Internal,
    ];

    /// Wire code.
    pub const fn code(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Status::code`]; `None` for unknown codes.
    pub fn from_code(code: u8) -> Option<Status> {
        Self::ALL.get(code as usize).copied()
    }

    /// Stable display name.
    pub const fn name(self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::Saturated => "saturated",
            Status::Unservable => "unservable",
            Status::Draining => "draining",
            Status::BadRequest => "bad-request",
            Status::Unsupported => "unsupported",
            Status::Internal => "internal",
        }
    }
}

impl From<AdmissionError> for Status {
    fn from(e: AdmissionError) -> Status {
        match e {
            AdmissionError::Saturated => Status::Saturated,
            AdmissionError::Unservable => Status::Unservable,
            AdmissionError::Draining => Status::Draining,
        }
    }
}

/// Why a payload failed to decode. Exhaustive and panic-free: the
/// listener turns any of these into one [`Status::BadRequest`] response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Payload shorter than the fixed header.
    Truncated,
    /// Unknown protocol version byte.
    BadVersion(u8),
    /// Class index outside the [`OpClass`] registry.
    BadClass(u8),
    /// Scheme index outside [`SchemeKind::ALL`].
    BadScheme(u8),
    /// Rounding-mode index outside [`RoundMode::ALL`].
    BadRound(u8),
    /// Status code outside [`Status::ALL`] (response decode).
    BadStatus(u8),
    /// Payload length inconsistent with the class's operand width.
    LengthMismatch {
        /// Length the decoded header implies.
        expect: usize,
        /// Length actually received.
        got: usize,
    },
}

impl core::fmt::Display for WireError {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "payload shorter than fixed header"),
            WireError::BadVersion(v) => write!(f, "unknown protocol version {v}"),
            WireError::BadClass(c) => write!(f, "class index {c} outside registry"),
            WireError::BadScheme(s) => write!(f, "scheme index {s} outside registry"),
            WireError::BadRound(r) => write!(f, "rounding-mode index {r} out of range"),
            WireError::BadStatus(s) => write!(f, "unknown status code {s}"),
            WireError::LengthMismatch { expect, got } => {
                write!(f, "payload length {got} != {expect} implied by header")
            }
        }
    }
}

impl std::error::Error for WireError {}

/// One multiplication request as it crosses the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Request {
    /// Client-chosen request id, echoed verbatim in the response.
    pub id: u64,
    /// Op class of both operands and the result.
    pub class: OpClass,
    /// Partition organization the client expects to be serving.
    pub scheme: SchemeKind,
    /// Rounding mode.
    pub round: RoundMode,
    /// Packed operand A (low `total_bits` valid).
    pub a: PackedBits,
    /// Packed operand B.
    pub b: PackedBits,
}

impl Request {
    /// Append the full frame (length prefix + payload) to `buf`.
    /// Operands are truncated to the class's packed width.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        let w = operand_bytes(self.class);
        buf.extend_from_slice(&((REQ_FIXED + 2 * w) as u32).to_le_bytes());
        buf.push(VERSION);
        buf.push(class_wire_id(self.class));
        buf.push(scheme_wire_id(self.scheme));
        buf.push(self.round.index() as u8);
        buf.extend_from_slice(&self.id.to_le_bytes());
        write_packed(buf, &self.a, w);
        write_packed(buf, &self.b, w);
    }

    /// Decode a request payload (the bytes *after* the length prefix).
    pub fn decode(payload: &[u8]) -> Result<Request, WireError> {
        if payload.len() < REQ_FIXED {
            return Err(WireError::Truncated);
        }
        if payload[0] != VERSION {
            return Err(WireError::BadVersion(payload[0]));
        }
        let class = class_from_wire_id(payload[1]).ok_or(WireError::BadClass(payload[1]))?;
        let scheme = scheme_from_wire_id(payload[2]).ok_or(WireError::BadScheme(payload[2]))?;
        let round = RoundMode::from_index(payload[3] as usize)
            .ok_or(WireError::BadRound(payload[3]))?;
        let id = u64::from_le_bytes(payload[4..12].try_into().unwrap());
        let w = operand_bytes(class);
        let expect = REQ_FIXED + 2 * w;
        if payload.len() != expect {
            return Err(WireError::LengthMismatch { expect, got: payload.len() });
        }
        let a = read_packed(&payload[REQ_FIXED..REQ_FIXED + w]);
        let b = read_packed(&payload[REQ_FIXED + w..]);
        Ok(Request { id, class, scheme, round, a, b })
    }
}

/// One response as it crosses the wire. `bits` is meaningful only when
/// `status` is [`Status::Ok`]; `class` on an error response echoes the
/// request's class when it decoded (placeholder index 0 otherwise).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Response {
    /// Outcome.
    pub status: Status,
    /// Op class (sizes the result field when `Ok`).
    pub class: OpClass,
    /// Request id echoed back (0 when the request never decoded).
    pub id: u64,
    /// Packed product bits (`Ok` only).
    pub bits: PackedBits,
}

impl Response {
    /// A successful response carrying the product bits.
    pub fn ok(class: OpClass, id: u64, bits: impl Into<PackedBits>) -> Response {
        Response { status: Status::Ok, class, id, bits: bits.into() }
    }

    /// A non-`Ok` response (no result bits on the wire).
    pub fn error(status: Status, class: OpClass, id: u64) -> Response {
        debug_assert!(status != Status::Ok, "error responses carry no bits");
        Response { status, class, id, bits: PackedBits::ZERO }
    }

    /// Append the full frame (length prefix + payload) to `buf`.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        let w = if self.status == Status::Ok { operand_bytes(self.class) } else { 0 };
        buf.extend_from_slice(&((RESP_FIXED + w) as u32).to_le_bytes());
        buf.push(VERSION);
        buf.push(self.status.code());
        buf.push(class_wire_id(self.class));
        buf.extend_from_slice(&self.id.to_le_bytes());
        if self.status == Status::Ok {
            write_packed(buf, &self.bits, w);
        }
    }

    /// Decode a response payload (the bytes *after* the length prefix).
    pub fn decode(payload: &[u8]) -> Result<Response, WireError> {
        if payload.len() < RESP_FIXED {
            return Err(WireError::Truncated);
        }
        if payload[0] != VERSION {
            return Err(WireError::BadVersion(payload[0]));
        }
        let status = Status::from_code(payload[1]).ok_or(WireError::BadStatus(payload[1]))?;
        let class = class_from_wire_id(payload[2]).ok_or(WireError::BadClass(payload[2]))?;
        let id = u64::from_le_bytes(payload[3..11].try_into().unwrap());
        let expect = RESP_FIXED + if status == Status::Ok { operand_bytes(class) } else { 0 };
        if payload.len() != expect {
            return Err(WireError::LengthMismatch { expect, got: payload.len() });
        }
        let bits =
            if status == Status::Ok { read_packed(&payload[RESP_FIXED..]) } else { PackedBits::ZERO };
        Ok(Response { status, class, id, bits })
    }
}

/// Emit the low `w` bytes of a packed word, little-endian. `w` is an
/// operand width from the registry, so `w <= 64` (binary512) always.
fn write_packed(buf: &mut Vec<u8>, v: &PackedBits, w: usize) {
    debug_assert!(w <= 8 * v.limbs.len());
    for i in 0..w {
        buf.push((v.limbs[i / 8] >> (8 * (i % 8))) as u8);
    }
}

/// Zero-extend up to 64 little-endian bytes into a packed word.
fn read_packed(bytes: &[u8]) -> PackedBits {
    debug_assert!(bytes.len() <= 64);
    let mut v = PackedBits::ZERO;
    for (i, &b) in bytes.iter().enumerate() {
        v.limbs[i / 8] |= (b as u64) << (8 * (i % 8));
    }
    v
}

/// Outcome of one [`read_frame`] call.
#[derive(Debug, PartialEq, Eq)]
pub enum FrameRead {
    /// A complete payload was read into the buffer.
    Frame,
    /// Clean end of stream at a frame boundary.
    Eof,
    /// The stream ended mid-header or mid-payload.
    Truncated,
    /// The length prefix is 0 or exceeds [`MAX_FRAME`] — framing is lost
    /// and the stream cannot be resynchronized.
    Oversized(u32),
}

/// Read one frame's payload into `buf` (cleared first). Transport errors
/// (including read timeouts) surface as `Err`; protocol-shaped failures
/// surface as non-`Frame` variants so callers can answer before closing.
pub fn read_frame(r: &mut impl io::Read, buf: &mut Vec<u8>) -> io::Result<FrameRead> {
    let mut len_bytes = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let n = r.read(&mut len_bytes[got..])?;
        if n == 0 {
            return Ok(if got == 0 { FrameRead::Eof } else { FrameRead::Truncated });
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_bytes);
    if len == 0 || len > MAX_FRAME {
        return Ok(FrameRead::Oversized(len));
    }
    buf.clear();
    buf.resize(len as usize, 0);
    let mut filled = 0;
    while filled < buf.len() {
        let n = r.read(&mut buf[filled..])?;
        if n == 0 {
            return Ok(FrameRead::Truncated);
        }
        filled += n;
    }
    Ok(FrameRead::Frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proput::{forall, Rng};

    /// Random packed operand with every bit position of the class's width
    /// exercised (wide classes included — no `u128` shift anywhere).
    fn rand_operand(rng: &mut Rng, class: OpClass) -> PackedBits {
        let mut v = PackedBits::ZERO;
        for limb in v.limbs.iter_mut() {
            *limb = rng.next_u64();
        }
        v.mask_low(class.total_bits())
    }

    /// Decode one frame from raw bytes (length prefix included), the way
    /// the listener sees it.
    fn decode_stream(bytes: &[u8]) -> (FrameRead, Vec<u8>) {
        let mut cursor = std::io::Cursor::new(bytes);
        let mut buf = Vec::new();
        let fr = read_frame(&mut cursor, &mut buf).unwrap();
        (fr, buf)
    }

    #[test]
    fn request_roundtrip_every_class_scheme_round() {
        // The satellite property: every registry class × every partition
        // scheme × every rounding mode survives encode → frame → decode
        // bit-exactly, with random (masked) operand bits.
        forall(0x9E7, 500, |rng| {
            for class in OpClass::ALL {
                for scheme in SchemeKind::ALL {
                    for round in RoundMode::ALL {
                        let req = Request {
                            id: rng.next_u64(),
                            class,
                            scheme,
                            round,
                            a: rand_operand(rng, class),
                            b: rand_operand(rng, class),
                        };
                        let mut buf = Vec::new();
                        req.encode(&mut buf);
                        assert!(buf.len() <= 4 + MAX_REQUEST_PAYLOAD);
                        let (fr, payload) = decode_stream(&buf);
                        assert_eq!(fr, FrameRead::Frame);
                        assert_eq!(Request::decode(&payload), Ok(req));
                    }
                }
            }
        });
    }

    #[test]
    fn response_roundtrip_ok_and_every_error_status() {
        forall(0x9E8, 2000, |rng| {
            let class = OpClass::from_index(rng.below(OpClass::COUNT as u64) as usize);
            let id = rng.next_u64();
            let bits = rand_operand(rng, class);
            let ok = Response::ok(class, id, bits);
            let mut buf = Vec::new();
            ok.encode(&mut buf);
            let (fr, payload) = decode_stream(&buf);
            assert_eq!(fr, FrameRead::Frame);
            assert_eq!(Response::decode(&payload), Ok(ok));
            for status in Status::ALL {
                if status == Status::Ok {
                    continue;
                }
                let err = Response::error(status, class, id);
                buf.clear();
                err.encode(&mut buf);
                let (fr, payload) = decode_stream(&buf);
                assert_eq!(fr, FrameRead::Frame);
                assert_eq!(Response::decode(&payload), Ok(err));
            }
        });
    }

    #[test]
    fn status_codes_are_stable_and_mirror_admission_errors() {
        for (i, s) in Status::ALL.into_iter().enumerate() {
            assert_eq!(s.code() as usize, i);
            assert_eq!(Status::from_code(s.code()), Some(s));
        }
        assert_eq!(Status::from_code(7), None);
        assert_eq!(Status::from(AdmissionError::Saturated), Status::Saturated);
        assert_eq!(Status::from(AdmissionError::Unservable), Status::Unservable);
        assert_eq!(Status::from(AdmissionError::Draining), Status::Draining);
    }

    /// Protocol-compatibility freeze. These bytes are what deployed
    /// clients have on the wire: if this test fails, an enum edit changed
    /// an *existing* assignment — revert it and append instead. Adding a
    /// new class/scheme extends these tables with a fresh id; it never
    /// renumbers a row.
    #[test]
    fn wire_ids_are_pinned() {
        let classes: [(OpClass, u8); 7] = [
            (OpClass::Bf16, 0),
            (OpClass::Half, 1),
            (OpClass::Single, 2),
            (OpClass::Double, 3),
            (OpClass::Quad, 4),
            (OpClass::Fp256, 5),
            (OpClass::Fp512, 6),
        ];
        assert_eq!(classes.len(), OpClass::COUNT, "new class: add its pinned wire id here");
        for (class, id) in classes {
            assert_eq!(class_wire_id(class), id, "{} wire id changed", class.name());
            assert_eq!(class_from_wire_id(id), Some(class));
        }
        let schemes: [(SchemeKind, u8); 5] = [
            (SchemeKind::Civp, 0),
            (SchemeKind::Baseline18, 1),
            (SchemeKind::Baseline25x18, 2),
            (SchemeKind::Baseline9, 3),
            (SchemeKind::Karatsuba24, 4),
        ];
        assert_eq!(schemes.len(), SchemeKind::COUNT, "new scheme: add its pinned wire id here");
        for (kind, id) in schemes {
            assert_eq!(scheme_wire_id(kind), id, "{} wire id changed", kind.name());
            assert_eq!(scheme_from_wire_id(id), Some(kind));
        }
        // Bytes beyond the tables stay unassigned (decode rejects them).
        for id in OpClass::COUNT as u8..=u8::MAX {
            assert_eq!(class_from_wire_id(id), None);
        }
        for id in SchemeKind::COUNT as u8..=u8::MAX {
            assert_eq!(scheme_from_wire_id(id), None);
        }
    }

    /// Byte-exact golden frames: pins the frame layout (offsets, LE order,
    /// operand truncation) in addition to the id tables above.
    #[test]
    fn wire_frames_are_byte_stable() {
        let req = Request {
            id: 0x0102_0304_0506_0708,
            class: OpClass::Single,
            scheme: SchemeKind::Karatsuba24,
            round: RoundMode::TowardZero,
            a: PackedBits::from_u128(0x3F80_0001),
            b: PackedBits::from_u128(0x4000_0002),
        };
        let mut buf = Vec::new();
        req.encode(&mut buf);
        assert_eq!(
            buf,
            vec![
                20, 0, 0, 0, // len = 12 + 2*4
                1,  // version
                2,  // class: single
                4,  // scheme: karatsuba24
                2,  // round: toward-zero
                0x08, 0x07, 0x06, 0x05, 0x04, 0x03, 0x02, 0x01, // id LE
                0x01, 0x00, 0x80, 0x3F, // a LE
                0x02, 0x00, 0x00, 0x40, // b LE
            ]
        );
        let resp = Response::ok(OpClass::Bf16, 9, PackedBits::from_u128(0xBEEF));
        buf.clear();
        resp.encode(&mut buf);
        assert_eq!(
            buf,
            vec![
                13, 0, 0, 0, // len = 11 + 2
                1, // version
                0, // status: ok
                0, // class: bf16
                9, 0, 0, 0, 0, 0, 0, 0, // id LE
                0xEF, 0xBE, // bits LE
            ]
        );
    }

    fn valid_request_frame() -> Vec<u8> {
        let req = Request {
            id: 7,
            class: OpClass::Single,
            scheme: SchemeKind::Civp,
            round: RoundMode::NearestEven,
            a: PackedBits::from_u128(0x3F80_0000),
            b: PackedBits::from_u128(0x3F80_0000),
        };
        let mut buf = Vec::new();
        req.encode(&mut buf);
        buf
    }

    #[test]
    fn malformed_bad_version() {
        let mut frame = valid_request_frame();
        frame[4] = 99; // version byte is first payload byte
        let (fr, payload) = decode_stream(&frame);
        assert_eq!(fr, FrameRead::Frame);
        assert_eq!(Request::decode(&payload), Err(WireError::BadVersion(99)));
    }

    #[test]
    fn malformed_unknown_indices() {
        let mut frame = valid_request_frame();
        frame[5] = OpClass::COUNT as u8;
        let (_, payload) = decode_stream(&frame);
        assert_eq!(Request::decode(&payload), Err(WireError::BadClass(OpClass::COUNT as u8)));

        let mut frame = valid_request_frame();
        frame[6] = 200;
        let (_, payload) = decode_stream(&frame);
        assert_eq!(Request::decode(&payload), Err(WireError::BadScheme(200)));

        let mut frame = valid_request_frame();
        frame[7] = RoundMode::COUNT as u8;
        let (_, payload) = decode_stream(&frame);
        assert_eq!(Request::decode(&payload), Err(WireError::BadRound(RoundMode::COUNT as u8)));
    }

    #[test]
    fn malformed_length_mismatch() {
        // Claim Single (needs 12 + 8) but frame only 12 + 4 payload bytes.
        let frame = valid_request_frame();
        let short = &frame[..4 + REQ_FIXED + 4];
        let mut with_len = ((REQ_FIXED + 4) as u32).to_le_bytes().to_vec();
        with_len.extend_from_slice(&short[4..]);
        let (fr, payload) = decode_stream(&with_len);
        assert_eq!(fr, FrameRead::Frame);
        assert_eq!(
            Request::decode(&payload),
            Err(WireError::LengthMismatch { expect: REQ_FIXED + 8, got: REQ_FIXED + 4 })
        );
    }

    #[test]
    fn malformed_truncated_header_and_payload() {
        // Stream ends inside the 4-byte length prefix.
        let (fr, _) = decode_stream(&[0x10, 0x00]);
        assert_eq!(fr, FrameRead::Truncated);
        // Stream ends inside the payload.
        let frame = valid_request_frame();
        let (fr, _) = decode_stream(&frame[..frame.len() - 3]);
        assert_eq!(fr, FrameRead::Truncated);
        // Empty stream is a clean EOF, not an error.
        let (fr, _) = decode_stream(&[]);
        assert_eq!(fr, FrameRead::Eof);
    }

    #[test]
    fn malformed_oversized_and_zero_length() {
        let (fr, _) = decode_stream(&u32::MAX.to_le_bytes());
        assert_eq!(fr, FrameRead::Oversized(u32::MAX));
        let (fr, _) = decode_stream(&0u32.to_le_bytes());
        assert_eq!(fr, FrameRead::Oversized(0));
        // MAX_FRAME itself is fine (boundary).
        let mut frame = (MAX_FRAME).to_le_bytes().to_vec();
        frame.extend_from_slice(&vec![0u8; MAX_FRAME as usize]);
        let (fr, payload) = decode_stream(&frame);
        assert_eq!(fr, FrameRead::Frame);
        assert_eq!(payload.len(), MAX_FRAME as usize);
    }
}
