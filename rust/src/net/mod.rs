//! Layer-5 network serving edge: the cluster behind a TCP socket.
//!
//! Everything below this layer speaks in-process Rust (`Cluster::submit`
//! returns a [`crate::cluster::ClusterReply`]); this module is the wire
//! boundary — the deployment shape where the variable-precision
//! multiplication service is a network service:
//!
//! * [`wire`] — the length-prefixed binary protocol: version byte,
//!   registry-indexed class/scheme/rounding-mode bytes, operands at the
//!   class's packed width, and a status byte on every response. Decoding
//!   is total — malformed frames become [`wire::Status::BadRequest`]
//!   responses, never panics or hangs.
//! * [`server`] — a std-only multi-threaded listener (`civp-server
//!   serve-net`): per-connection reader/writer thread pairs around a
//!   bounded FIFO reply queue, decoding frames into
//!   [`crate::cluster::Cluster::try_submit`]. Admission outcomes
//!   ([`crate::serve::AdmissionError`]) map 1:1 onto wire status codes,
//!   so a saturated cluster answers `Saturated` instead of dropping the
//!   connection, and a full writer queue stops the socket reads — TCP
//!   backpressure end to end.
//! * [`loadgen`] — the built-in open-loop load generator (`civp-server
//!   loadgen`): exponential arrivals over the [`crate::trace`] workload
//!   mixes, connection fan-out, warmup exclusion, exact p50/p99/p999
//!   latency percentiles and sustained throughput, emitted as
//!   `BENCH_net.json` rows the bench gate validates.

pub mod loadgen;
pub mod server;
pub mod wire;

pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use server::{NetServer, NetServerConfig};
pub use wire::Status;
