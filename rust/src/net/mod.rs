//! Layer-5 network serving edge: the cluster behind a TCP socket.
//!
//! Everything below this layer speaks in-process Rust (`Cluster::submit`
//! returns a [`crate::cluster::ClusterReply`]); this module is the wire
//! boundary — the deployment shape where the variable-precision
//! multiplication service is a network service:
//!
//! * [`wire`] — the length-prefixed binary protocol: version byte,
//!   registry-indexed class/scheme/rounding-mode bytes, operands at the
//!   class's packed width, and a status byte on every response. Decoding
//!   is total — malformed frames become [`wire::Status::BadRequest`]
//!   responses, never panics or hangs. Responses carry the request id,
//!   so out-of-order completion of pipelined requests is wire-legal.
//! * [`server`] — a std-only event-driven listener (`civp-server
//!   serve-net`): a bounded pool of `civp-net-{i}` connection workers,
//!   each multiplexing a slab of non-blocking sockets (per-connection
//!   reassembly buffers, `WouldBlock` rotation), with request
//!   pipelining up to a per-connection in-flight depth and one listener
//!   routing frames to per-[`crate::decomp::SchemeKind`] clusters.
//!   Admission outcomes ([`crate::serve::AdmissionError`]) map 1:1 onto
//!   wire status codes, so a saturated cluster answers `Saturated`
//!   instead of dropping the connection; a full writer queue stops that
//!   socket's reads — TCP backpressure end to end. Thread count is a
//!   function of configuration, never of connection count.
//! * [`loadgen`] — the built-in load generator (`civp-server loadgen`):
//!   exponential arrivals over the [`crate::trace`] workload mixes,
//!   connection fan-out, warmup exclusion, exact p50/p99/p999 latency
//!   percentiles, an optional closed-loop outstanding-request window
//!   (`--closed-loop --concurrency`), and an offered-load sweep
//!   (`--sweep`) emitting `net/<mix>/p99@<rate>` curve rows whose knee
//!   location the bench gate pins.

pub mod loadgen;
pub mod server;
pub mod wire;

pub use loadgen::{LoadgenConfig, LoadgenReport, SweepReport};
pub use server::{NetServer, NetServerConfig};
pub use wire::Status;
