//! Named counters, gauges and histograms with a point-in-time snapshot.

use super::hist::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A settable instantaneous value (queue depths, in-flight requests,
/// routing weights). Unlike [`Counter`] it can move in both directions;
/// all operations are relaxed atomics — safe to touch from any thread.
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    /// Set the current value.
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }
    /// Add `n` (may be negative).
    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }
    /// Decrement by one.
    pub fn dec(&self) {
        self.add(-1);
    }
    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Registry of named counters, gauges and histograms. Lookup takes a read
/// lock; the hot path holds `Arc`s to the instruments, so recording is
/// lock-free.
#[derive(Debug, Default)]
pub struct Registry {
    counters: RwLock<BTreeMap<String, Arc<Counter>>>,
    gauges: RwLock<BTreeMap<String, Arc<Gauge>>>,
    hists: RwLock<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// New empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create a counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            return c.clone();
        }
        let mut w = self.counters.write().unwrap();
        w.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::default())).clone()
    }

    /// Get or create a gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        if let Some(g) = self.gauges.read().unwrap().get(name) {
            return g.clone();
        }
        let mut w = self.gauges.write().unwrap();
        w.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::default())).clone()
    }

    /// Get or create a histogram.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        if let Some(h) = self.hists.read().unwrap().get(name) {
            return h.clone();
        }
        let mut w = self.hists.write().unwrap();
        w.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new())).clone()
    }

    /// Point-in-time snapshot of everything.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .counters
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let gauges = self
            .gauges
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.get()))
            .collect();
        let hists = self
            .hists
            .read()
            .unwrap()
            .iter()
            .map(|(k, h)| {
                (k.clone(), HistSummary {
                    count: h.count(),
                    mean: h.mean(),
                    p50: h.quantile(0.5),
                    p99: h.quantile(0.99),
                    max: h.max(),
                })
            })
            .collect();
        Snapshot { counters, gauges, hists }
    }
}

/// Summary of one histogram.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSummary {
    /// Sample count.
    pub count: u64,
    /// Mean value.
    pub mean: f64,
    /// Approximate median.
    pub p50: u64,
    /// Approximate 99th percentile.
    pub p99: u64,
    /// Maximum.
    pub max: u64,
}

/// Snapshot of a registry.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauge values by name.
    pub gauges: BTreeMap<String, i64>,
    /// Histogram summaries by name.
    pub hists: BTreeMap<String, HistSummary>,
}

impl Snapshot {
    /// Render as aligned text (for the CLI and examples).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k:<40} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("{k:<40} {v}\n"));
        }
        for (k, h) in &self.hists {
            out.push_str(&format!(
                "{k:<40} n={} mean={:.0} p50={} p99={} max={}\n",
                h.count, h.mean, h.p50, h.p99, h.max
            ));
        }
        out
    }
}
