//! Telemetry substrate: counters, gauges and latency histograms for the
//! service and the cluster layer.
//!
//! Hot-path friendly: recording a latency is a few atomic increments into
//! log-spaced buckets — no locks, no allocation.

mod hist;
mod registry;
#[cfg(test)]
mod tests;

pub use hist::Histogram;
pub use registry::{Counter, Gauge, Registry, Snapshot};
