//! Metrics tests: bucket math, quantile monotonicity, concurrent recording.

use super::*;
use crate::proput::forall;
use std::sync::Arc;

#[test]
fn histogram_basic() {
    let h = Histogram::new();
    assert_eq!(h.count(), 0);
    assert_eq!(h.quantile(0.5), 0);
    for v in [1u64, 2, 3, 100, 1000] {
        h.record(v);
    }
    assert_eq!(h.count(), 5);
    assert_eq!(h.max(), 1000);
    assert!((h.mean() - 221.2).abs() < 0.01);
}

#[test]
fn quantiles_monotone_and_bounding() {
    forall(0x400, 200, |rng| {
        let h = Histogram::new();
        let n = rng.range(1, 500);
        let mut max = 0;
        for _ in 0..n {
            let mag = rng.below(40);
            let v = rng.below(1 << mag) + 1;
            max = max.max(v);
            h.record(v);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
        // bucket upper bounds can exceed max by at most 2x
        assert!(p99 <= max.next_power_of_two().max(2) * 2);
    });
}

#[test]
fn histogram_reset() {
    let h = Histogram::new();
    h.record(5);
    h.reset();
    assert_eq!(h.count(), 0);
    assert_eq!(h.max(), 0);
}

#[test]
fn concurrent_recording() {
    let h = Arc::new(Histogram::new());
    let threads: Vec<_> = (0..8)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                for i in 0..10_000u64 {
                    h.record(i + t);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(h.count(), 80_000);
}

#[test]
fn registry_dedup_and_snapshot() {
    let r = Registry::new();
    let c1 = r.counter("reqs");
    let c2 = r.counter("reqs");
    c1.inc();
    c2.add(2);
    assert_eq!(r.counter("reqs").get(), 3);
    r.histogram("lat").record(42);
    r.gauge("inflight").set(5);
    let snap = r.snapshot();
    assert_eq!(snap.counters["reqs"], 3);
    assert_eq!(snap.gauges["inflight"], 5);
    assert_eq!(snap.hists["lat"].count, 1);
    let text = snap.render();
    assert!(text.contains("reqs"));
    assert!(text.contains("inflight"));
    assert!(text.contains("lat"));
}

#[test]
fn gauge_moves_both_directions() {
    let r = Registry::new();
    let g = r.gauge("depth");
    g.inc();
    g.inc();
    g.dec();
    assert_eq!(g.get(), 1);
    g.add(-5);
    assert_eq!(g.get(), -4);
    g.set(7);
    // same name resolves to the same instrument
    assert_eq!(r.gauge("depth").get(), 7);
}

#[test]
fn gauge_concurrent_inc_dec_balances() {
    let r = Registry::new();
    let g = r.gauge("inflight");
    let threads: Vec<_> = (0..8)
        .map(|_| {
            let g = g.clone();
            std::thread::spawn(move || {
                for _ in 0..10_000 {
                    g.inc();
                    g.dec();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(g.get(), 0);
}
