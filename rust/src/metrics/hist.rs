//! Lock-free log-bucketed latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: one per power-of-two of nanoseconds up to ~36 minutes.
const BUCKETS: usize = 42;

/// A log2-bucketed histogram of u64 samples (typically nanoseconds).
///
/// Bucket `i` holds samples in `[2^i, 2^(i+1))`; recording is one atomic
/// add. Quantiles are approximate (bucket upper bound), which is fine for
/// p50/p99 reporting.
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample.
    pub fn record(&self, value: u64) {
        let idx = (64 - value.leading_zeros()).saturating_sub(1).min(BUCKETS as u32 - 1);
        self.buckets[idx as usize].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.max.fetch_max(value, Ordering::Relaxed);
    }

    /// Total samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean sample value (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Maximum recorded value.
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile (`q` in [0, 1]): upper bound of the bucket
    /// containing the q-th sample.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64 * q).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        self.max()
    }

    /// Reset all buckets.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}
