//! Minimal property-testing support (the build environment has no network
//! registry, so `proptest` is unavailable — this module provides the subset
//! the test suite needs: a fast deterministic PRNG, value generators, and a
//! `forall` driver with failure reporting).
//!
//! All randomized tests in the crate derive their stream from a fixed seed
//! so failures are reproducible; the failing iteration index and raw inputs
//! are printed in the panic message.

/// SplitMix64 — tiny, fast, full-period 64-bit generator. Good enough for
/// test-input generation (not for cryptography).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. The same seed always yields the same stream.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free mapping; bias is negligible for
        // test-generation purposes (< 2^-64 * n).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// A "nasty" u64 for floating-point bit patterns: biased toward
    /// boundary exponents, all-ones/all-zeros significands, and values near
    /// powers of two — where rounding bugs live.
    pub fn nasty_bits64(&mut self) -> u64 {
        match self.below(8) {
            0 => self.next_u64(),                      // uniform
            1 => 0,                                    // +0
            2 => self.next_u64() & 0x000F_FFFF_FFFF_FFFF, // subnormal-ish
            3 => {
                // near-overflow exponent, random significand
                let sig = self.next_u64() & 0x000F_FFFF_FFFF_FFFF;
                0x7FE0_0000_0000_0000 | sig
            }
            4 => {
                // minimal normal exponent
                let sig = self.next_u64() & 0x000F_FFFF_FFFF_FFFF;
                0x0010_0000_0000_0000 | sig
            }
            5 => {
                // all-ones significand (rounding carry propagation)
                let exp = self.below(0x7FF) << 52;
                exp | 0x000F_FFFF_FFFF_FFFF
            }
            6 => {
                // power of two
                self.below(0x7FF) << 52
            }
            _ => {
                // random exponent, sparse significand
                let exp = self.below(0x7FF) << 52;
                exp | (1u64 << self.below(52))
            }
        }
    }

    /// A uniform `bits`-wide value with the top (hidden) bit set — the
    /// shape of a normalized IEEE significand. Shared by the decomposition
    /// property tests and benches so they draw from one distribution.
    pub fn sig(&mut self, bits: u32) -> crate::wideint::U128 {
        let mut v = crate::wideint::U128::ZERO;
        v.limbs[0] = self.next_u64();
        v.limbs[1] = self.next_u64();
        let mut v = v.mask_low(bits);
        v.set_bit(bits - 1);
        v
    }

    /// Same spirit for 32-bit patterns.
    pub fn nasty_bits32(&mut self) -> u32 {
        match self.below(8) {
            0 => self.next_u32(),
            1 => 0,
            2 => self.next_u32() & 0x007F_FFFF,
            3 => 0x7F00_0000 | (self.next_u32() & 0x007F_FFFF),
            4 => 0x0080_0000 | (self.next_u32() & 0x007F_FFFF),
            5 => ((self.below(0xFF) as u32) << 23) | 0x007F_FFFF,
            6 => (self.below(0xFF) as u32) << 23,
            _ => ((self.below(0xFF) as u32) << 23) | (1u32 << self.below(23)),
        }
    }
}

/// Run `body` for `iters` deterministic random iterations. On panic the
/// failing iteration index is included so the case can be re-run alone with
/// [`case`].
pub fn forall(seed: u64, iters: u64, mut body: impl FnMut(&mut Rng)) {
    for i in 0..iters {
        let mut rng = Rng::new(seed ^ (i.wrapping_mul(0xA24BAED4963EE407)));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            panic!(
                "property failed at iteration {i} (seed={seed:#x}): {}",
                panic_message(&e)
            );
        }
    }
}

/// Re-run a single iteration of a [`forall`] by index (debugging aid).
pub fn case(seed: u64, index: u64, mut body: impl FnMut(&mut Rng)) {
    let mut rng = Rng::new(seed ^ (index.wrapping_mul(0xA24BAED4963EE407)));
    body(&mut rng);
}

fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let n = rng.range(1, 1000);
            let v = rng.below(n);
            assert!(v < n);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(9);
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn forall_reports_iteration() {
        // A failing property panics with the iteration index in the message.
        let result = std::panic::catch_unwind(|| {
            forall(1, 50, |rng| {
                assert!(rng.below(100) < 90, "intentional failure");
            });
        });
        let msg = match result {
            Err(e) => {
                if let Some(s) = e.downcast_ref::<String>() {
                    s.clone()
                } else {
                    String::new()
                }
            }
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("property failed at iteration"), "msg: {msg}");
        // And the reported iteration is reproducible via `case`.
        let ok = std::panic::catch_unwind(|| {
            forall(1, 50, |rng| {
                let _ = rng.below(100);
            });
        });
        assert!(ok.is_ok());
    }
}
