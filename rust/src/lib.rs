//! # civp — Combined Integer and Variable Precision FP Multiplication
//!
//! A full-system reproduction of *"Combined Integer and Variable Precision
//! (CIVP) Floating Point Multiplication Architecture for FPGAs"*
//! (Thapliyal, Arabnia, Bajpai, Sharma — 2007).
//!
//! The paper proposes replacing the dedicated `18x18` / `25x18` multiplier
//! blocks in FPGAs with `24x24` / `24x9` blocks (keeping `9x9`) so that
//! single-, double- and quadruple-precision significand products tile the
//! block array with zero wasted computation. This crate builds everything
//! needed to evaluate that claim end-to-end:
//!
//! * [`wideint`] — exact multi-limb integers (the 226-bit quad product).
//! * [`fpu`] — full IEEE-754 softfloat over the open [`OpClass`] format
//!   registry (bfloat16 / binary16 / binary32 / binary64 / binary128) with
//!   a pluggable significand multiplier, verified bit-exactly against
//!   hardware where hardware exists.
//! * [`decomp`] — the paper's contribution: partition schemes (CIVP Fig. 2 /
//!   Fig. 4 and the 18x18 / 25x18 / 9x9 baselines), tile-DAG generation and
//!   exact tiled execution with per-block utilization accounting.
//! * [`fabric`] — a cycle-level FPGA DSP-block fabric simulator with
//!   area / latency / dynamic-energy cost models.
//! * [`coordinator`] — a variable-precision multiplication service (router,
//!   dynamic batcher, worker pool, adaptive-precision escalation) — the
//!   "multimedia processing" deployment shape the paper motivates.
//! * [`cluster`] — sharded serving across N independent fabric columns:
//!   pluggable routing policies (round-robin / least-loaded /
//!   precision-affinity), per-shard admission control with spill-over,
//!   and degradation-aware traffic weighting over [`fabric::repair`].
//! * [`net`] — the network serving edge: a length-prefixed binary wire
//!   protocol, a std-only multi-threaded TCP listener feeding the cluster
//!   router, and a built-in open-loop load generator.
//! * [`serve`] — the unified admission vocabulary
//!   ([`serve::AdmissionError`]) shared by coordinator, cluster and wire.
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas numeric
//!   backends (`artifacts/*.hlo.txt`).
//! * [`trace`], [`metrics`], [`config`] — workload generation, telemetry
//!   and configuration substrates.
//!
//! Start with `ARCHITECTURE.md` for the module map and request dataflow,
//! and `cargo run --release --example quickstart` for a guided tour.

#![warn(missing_docs)]

pub mod benchx;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod decomp;
pub mod error;
pub mod fabric;
pub mod fpu;
pub mod metrics;
pub mod net;
pub mod proput;
pub mod runtime;
pub mod serve;
pub mod trace;
pub mod wideint;

pub use decomp::{OpClass, Plan, PlanCache, Scheme, SchemeKind};
pub use fpu::{Bf16, Fp128, Fp16, Fp32, Fp64, RoundMode};
pub use serve::AdmissionError;
