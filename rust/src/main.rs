//! `civp-server` — leader entrypoint for the CIVP variable-precision
//! multiplication service.
//!
//! Commands:
//!
//! * `serve`    — drive a synthetic multimedia trace through the service
//!                (router → batcher → workers → backend) and print the
//!                serving + fabric reports.
//! * `cluster`  — drive a trace through the sharded multi-fabric cluster
//!                (router policies, admission control, degradation demo).
//! * `analyze`  — print the §III block/utilization analysis table (E6).
//! * `predicates` — run the adaptive-precision geometric-predicate demo.
//! * `info`     — load the PJRT engine and print artifact facts.
//!
//! Run `civp-server help` for options.

use civp::cli::Args;
use civp::cluster::{Cluster, ClusterConfig, RouterPolicy};
use civp::error::{bail, err, Result};
use civp::config::ServiceConfig;
use civp::coordinator::{orient2d_adaptive, AdaptiveStats, BackendChoice, Service};
use civp::decomp::{AnalysisRow, LaneConfig, LaneWidth, OpClass, SchemeKind};
use civp::runtime::EngineHandle;
use civp::trace::{TraceGen, WorkloadSpec};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    if let Err(e) = run() {
        eprintln!("civp-server: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("serve") => serve(&args),
        Some("cluster") => cluster(&args),
        Some("analyze") => analyze(),
        Some("predicates") => predicates(&args),
        Some("info") => info(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?} (try `help`)"),
    }
}

fn print_help() {
    println!(
        "civp-server — CIVP variable-precision multiplication service

USAGE: civp-server <command> [options]

COMMANDS
  serve        run a synthetic trace through the service
               --config <file>      TOML config (see ServiceConfig)
               --requests <n>       override request count
               --workload <spec>    graphics|scientific|uniform|single-only|mixed|ml
               --mix <spec>         custom class weights, e.g.
                                    half=0.2,bf16=0.3,single=0.5 (overrides --workload)
               --backend <b>        native|pjrt (default native)
               --artifacts <dir>    artifacts directory (pjrt backend)
               --cores <n>          work-stealing lane-executor cores
                                    (0 = single-threaded, the default)
               --par-threshold <n>  min batch size that fans out (default 256)
               --lane-width <n>     SoA lane-block width: 8|16|32 (default 8);
                                    wider blocks feed the SIMD sweeps when the
                                    `simd` build and the host ISA allow it
  cluster      run a synthetic trace through the sharded cluster
               --shards <n>         shard count (default 4)
               --policy <p>         round-robin|least-loaded|precision-affinity
               --inflight <n>       per-shard in-flight bound (default 4096)
               --spares <n>         spare sub-units per block (default 2)
               --degrade <shard>    inject faults into one shard first
               --faults <n>         fault count for --degrade (default 8)
               --backend <b>        native|pjrt (default native)
               (also accepts serve's --config/--requests/--workload/--mix/
                --artifacts/--cores/--par-threshold/--lane-width)
  analyze      print the paper's block/utilization analysis table
  predicates   adaptive-precision orient2d demo
               --points <n>         number of predicates (default 2000)
  info         print loaded-engine facts
               --artifacts <dir>    artifacts directory
  help         this text"
    );
}

fn load_config(args: &Args) -> Result<ServiceConfig> {
    let mut cfg = match args.options.get("config") {
        Some(path) => ServiceConfig::from_file(path)?,
        None => ServiceConfig::default(),
    };
    if let Some(n) = args.options.get("requests") {
        cfg.requests = n.parse()?;
    }
    if let Some(w) = args.options.get("workload") {
        cfg.workload =
            WorkloadSpec::parse(w).ok_or_else(|| err!("unknown workload {w:?}"))?;
    }
    if let Some(spec) = args.options.get("mix") {
        // `--mix half=0.2,bf16=0.3,...` — explicit per-class weights over
        // the open registry; unlisted classes get zero mass.
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (name, weight) = part
                .split_once('=')
                .ok_or_else(|| err!("--mix entries are class=weight, got {part:?}"))?;
            let class = OpClass::parse(name.trim())
                .ok_or_else(|| err!("unknown op class {name:?} in --mix"))?;
            cfg.set_mix_weight(class, weight.trim().parse()?)?;
        }
    }
    if let Some(dir) = args.options.get("artifacts") {
        cfg.artifacts_dir = dir.clone();
    }
    if let Some(n) = args.options.get("cores") {
        cfg.cores = n.parse()?;
    }
    if let Some(n) = args.options.get("par-threshold") {
        cfg.par_threshold = n.parse()?;
    }
    if let Some(n) = args.options.get("lane-width") {
        cfg.lane_width = n.parse()?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Resolve the configured lane width plus the best vector ISA the host
/// offers (AVX-512 → AVX2 → scalar on x86_64, NEON on aarch64; always
/// scalar without the `simd` feature).
fn lane_config(cfg: &ServiceConfig) -> Result<LaneConfig> {
    let width = LaneWidth::from_width(cfg.lane_width)
        .ok_or_else(|| err!("--lane-width must be 8, 16 or 32 (got {})", cfg.lane_width))?;
    Ok(LaneConfig::detect(width))
}

/// Resolve `--backend` (+ `--cores`/`--lane-width`) into a worker-backend
/// choice. With `--cores N` (N > 0) the native backend fans large batches
/// out across a shared work-stealing lane executor; results stay
/// bit-for-bit identical to the single-threaded path for every width and
/// dispatched ISA.
fn make_backend(args: &Args, cfg: &ServiceConfig) -> Result<BackendChoice> {
    Ok(match args.get_str("backend", "native").as_str() {
        "native" if cfg.cores > 0 => BackendChoice::NativeParallel(
            cfg.scheme,
            Arc::new(civp::decomp::Executor::with_config(
                cfg.cores,
                cfg.par_threshold,
                lane_config(cfg)?,
            )),
        ),
        "native" => BackendChoice::NativeLane(cfg.scheme, lane_config(cfg)?),
        "pjrt" => BackendChoice::Pjrt(EngineHandle::load(cfg.artifacts_dir.clone())?),
        other => bail!("unknown backend {other:?}"),
    })
}

fn serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let backend = make_backend(args, &cfg)?;
    println!(
        "serving {} requests of workload `{}` (scheme {:?}, fabric {:?}, cores {}, \
         lane kernel {})",
        cfg.requests,
        cfg.workload.name(),
        cfg.scheme,
        cfg.fabric,
        cfg.cores,
        backend.lane_config().map_or_else(|| "pjrt".to_string(), |l| l.kernel_name())
    );
    let svc = Service::start(&cfg, backend);
    let mut gen = TraceGen::new(cfg.seed, cfg.mix(), 0);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(4096);
    for req in gen.take(cfg.requests) {
        pending.push(svc.submit(req.id, req.class, req.a, req.b).expect("service closed"));
        // cap in-flight to keep memory bounded
        if pending.len() >= 4096 {
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    let fabric = svc.fabric_report();
    let report = svc.shutdown();
    println!("\n== serving report ==");
    println!("wall time            {:.3} s", wall.as_secs_f64());
    println!("throughput           {:.0} mult/s", report.responses as f64 / wall.as_secs_f64());
    print!("{}", report.snapshot.render());
    println!("\n== fabric report ({}) ==", fabric.fabric);
    for class in &fabric.per_class {
        println!("  {:<16} {:>10} ops", class.label, class.ops);
    }
    println!("cycles               {}", fabric.cycles);
    println!("ops/cycle            {:.3}", fabric.throughput());
    println!("dynamic energy       {:.1}", fabric.dyn_energy);
    println!("wasted energy        {:.1}%", fabric.wasted_fraction() * 100.0);
    println!("energy/op            {:.3}", fabric.energy_per_op());
    Ok(())
}

fn cluster(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let shards = args.get_usize("shards", 4)?;
    let policy_name = args.get_str("policy", "least-loaded");
    let policy = RouterPolicy::parse(&policy_name)
        .ok_or_else(|| err!("unknown policy {policy_name:?} (try `help`)"))?;
    let ccfg = ClusterConfig {
        shards,
        service: cfg.clone(),
        policy,
        max_inflight: args.get_usize("inflight", 4096)? as u64,
        spares_per_block: args.get_usize("spares", 2)? as u32,
    };
    let backend = make_backend(args, &cfg)?;
    println!(
        "cluster: {shards} shards, policy `{}`, workload `{}`, {} requests",
        policy.name(),
        cfg.workload.name(),
        cfg.requests
    );
    let mut cluster = Cluster::start(&ccfg, backend);
    if let Some(d) = args.options.get("degrade") {
        let shard: usize = d.parse()?;
        if shard >= shards {
            bail!("--degrade {shard} out of range (cluster has {shards} shards)");
        }
        let faults = args.get_usize("faults", 8)?;
        let mut rng = civp::proput::Rng::new(cfg.seed);
        let out = cluster.degrade_shard(shard, civp::decomp::BlockKind::M24x24, faults, &mut rng);
        let st = &cluster.states()[shard];
        println!(
            "degraded shard {shard}: {} faults repaired, {} blocks lost -> weight {}/{}, \
             quad-one-wave {}",
            out.repaired,
            out.lost,
            st.weight(),
            civp::cluster::FULL_WEIGHT,
            st.quad_one_wave()
        );
    }
    let mut gen = TraceGen::new(cfg.seed, cfg.mix(), 0);
    let t0 = Instant::now();
    // Cap held replies below the cluster's total in-flight budget: every
    // un-received reply pins a per-shard slot, so holding >= shards ×
    // inflight of them would livelock the blocking submit.
    let budget = (ccfg.max_inflight as usize).saturating_mul(shards);
    let drain_at = 4096.min(budget / 2).max(1);
    let mut pending = Vec::with_capacity(drain_at);
    for req in gen.take(cfg.requests) {
        let rx = cluster
            .submit(req.id, req.class, req.a, req.b)
            .map_err(|e| err!("cluster submit failed: {e}"))?;
        pending.push(rx);
        if pending.len() >= drain_at {
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    println!("\n== cluster metrics ==");
    print!("{}", cluster.metrics().render());
    let report = cluster.shutdown();
    println!("\n== cluster report ==");
    println!("wall time            {:.3} s", wall.as_secs_f64());
    println!(
        "throughput           {:.0} mult/s",
        report.accepted as f64 / wall.as_secs_f64()
    );
    print!("{}", report.render());
    Ok(())
}

fn analyze() -> Result<()> {
    println!("== paper §III analysis: blocks per multiplication ==\n");
    println!(
        "{:<10} {:<8} {:>6} {:>7} {:>7} {:>6} {:>6} {:>8} {:>8}",
        "class", "scheme", "blocks", "24x24", "24x9", "9x9", "18x18", "padded", "util%"
    );
    for row in AnalysisRow::full_table() {
        let c = &row.census;
        println!(
            "{:<10} {:<8} {:>6} {:>7} {:>7} {:>6} {:>6} {:>8} {:>8.1}",
            row.class.name(),
            row.kind.name(),
            c.total_blocks,
            c.count(civp::decomp::BlockKind::M24x24),
            c.count(civp::decomp::BlockKind::M24x9),
            c.count(civp::decomp::BlockKind::M9x9),
            c.count(civp::decomp::BlockKind::M18x18),
            c.padded_blocks,
            c.utilization * 100.0
        );
    }
    println!(
        "\npaper claims (§II.C): quad on 18x18 needs {} blocks, {} wasted (35%);\n\
         recomputed wastage is 13/49 = 26.5% — see EXPERIMENTS.md E5.",
        civp::decomp::analysis::PAPER_CLAIMED_QP_TOTAL_18X18,
        civp::decomp::analysis::PAPER_CLAIMED_QP_WASTED_18X18
    );
    Ok(())
}

fn predicates(args: &Args) -> Result<()> {
    let n = args.get_usize("points", 2000)?;
    let cfg = ServiceConfig::default();
    let svc = Service::start(&cfg, BackendChoice::Native(SchemeKind::Civp));
    let mut stats = AdaptiveStats::default();
    let mut rng = civp::proput::Rng::new(7);
    let t0 = Instant::now();
    for _ in 0..n {
        // mix of generic and degenerate (collinear) triangles
        let degenerate = rng.chance(0.3);
        let c0 = (rng.f64(), rng.f64());
        let c1 = (rng.f64(), rng.f64());
        let c2 = if degenerate {
            let t = rng.f64();
            (c0.0 + t * (c1.0 - c0.0), c0.1 + t * (c1.1 - c0.1))
        } else {
            (rng.f64(), rng.f64())
        };
        orient2d_adaptive(&svc, c0, c1, c2, &mut stats);
    }
    println!("adaptive orient2d over {n} triangles in {:?}", t0.elapsed());
    println!(
        "settled: single={} double={} quad={}",
        stats.settled_single, stats.settled_double, stats.settled_quad
    );
    let fabric = svc.fabric_report();
    println!("precision traffic mix observed by the fabric:");
    for class in &fabric.per_class {
        println!("  {:<16} {} ops", class.label, class.ops);
    }
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let handle = EngineHandle::load(dir)?;
    let info = handle.info()?;
    println!("platform   {}", info.platform);
    println!("batch      {}", info.batch);
    println!("classes    {:?}", info.loaded);
    // smoke multiply
    let out = handle.mul(
        OpClass::Double,
        vec![(2.0f64).to_bits() as u128],
        vec![(3.0f64).to_bits() as u128],
    )?;
    println!("2.0 * 3.0  = {} (via PJRT)", f64::from_bits(out[0] as u64));
    handle.stop();
    Ok(())
}
