//! `civp-server` — leader entrypoint for the CIVP variable-precision
//! multiplication service.
//!
//! Commands:
//!
//! * `serve`     — drive a synthetic multimedia trace through the service
//!                 (router → batcher → workers → backend) and print the
//!                 serving + fabric reports.
//! * `cluster`   — drive a trace through the sharded multi-fabric cluster
//!                 (router policies, admission control, degradation demo).
//! * `serve-net` — expose per-scheme clusters over TCP through a bounded
//!                 connection-worker pool (length-prefixed binary
//!                 protocol; see `civp::net::wire`).
//! * `loadgen`   — load generator against a `serve-net` listener (or an
//!                 embedded loopback one): open-loop, closed-loop
//!                 (`--closed-loop`), or an offered-load sweep
//!                 (`--sweep`), emitting latency/throughput rows as
//!                 `BENCH_net.json` / `BENCH_net_sweep.json`.
//! * `analyze`   — print the §III block/utilization analysis table (E6).
//! * `predicates` — run the adaptive-precision geometric-predicate demo.
//! * `info`      — load the PJRT engine and print artifact facts.
//!
//! The serving commands share one flag surface: `--mix`, `--cores`,
//! `--lane-width`, `--policy`, `--inflight` and friends resolve through
//! the same `civp::cli` helpers under every command. Run
//! `civp-server help` for options.

use civp::benchx::JsonReport;
use civp::cli::Args;
use civp::cluster::Cluster;
use civp::config::ServiceConfig;
use civp::coordinator::{orient2d_adaptive, AdaptiveStats, BackendChoice, Service};
use civp::decomp::{AnalysisRow, OpClass, SchemeKind};
use civp::error::{bail, err, Result};
use civp::net::{LoadgenConfig, NetServer};
use civp::runtime::EngineHandle;
use civp::trace::TraceGen;
use std::time::{Duration, Instant};

fn main() {
    if let Err(e) = run() {
        eprintln!("civp-server: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_deref() {
        Some("serve") => serve(&args),
        Some("cluster") => cluster(&args),
        Some("serve-net") => serve_net(&args),
        Some("loadgen") => loadgen(&args),
        Some("analyze") => analyze(),
        Some("predicates") => predicates(&args),
        Some("info") => info(&args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?} (try `help`)"),
    }
}

fn print_help() {
    println!(
        "civp-server — CIVP variable-precision multiplication service

USAGE: civp-server <command> [options]

SHARED OPTIONS (serve / cluster / serve-net / loadgen)
  --config <file>      TOML config (see ServiceConfig)
  --requests <n>       override request count
  --workload <spec>    graphics|scientific|uniform|single-only|mixed|ml
  --mix <spec>         custom class weights, e.g.
                       half=0.2,bf16=0.3,single=0.5 (overrides --workload)
  --scheme <s>         partition organization: civp|18x18|25x18|9x9|
                       karatsuba24 (recursive sub-quadratic tiling for
                       the wide fp256/fp512 classes; narrow classes fall
                       back to flat CIVP tiles)
  --backend <b>        native|pjrt (default native)
  --artifacts <dir>    artifacts directory (pjrt backend)
  --cores <n>          work-stealing lane-executor cores
                       (0 = single-threaded, the default)
  --par-threshold <n>  min batch size that fans out (default 256)
  --lane-width <n>     SoA lane-block width: 8|16|32 (default 8)

CLUSTER OPTIONS (cluster / serve-net / loadgen's embedded server)
  --shards <n>         shard count (default 4)
  --policy <p>         round-robin|least-loaded|precision-affinity
  --inflight <n>       per-shard in-flight bound (default 4096)
  --spares <n>         spare sub-units per block (default 2)

COMMANDS
  serve        run a synthetic trace through the in-process service
  cluster      run a synthetic trace through the sharded cluster
               --degrade <shard>    inject faults into one shard first
               --faults <n>         fault count for --degrade (default 8)
  serve-net    expose per-scheme clusters over TCP (worker-pool edge)
               --addr <host:port>   bind address (default 127.0.0.1:7070;
                                    port 0 picks an ephemeral port)
               --duration <secs>    serve this long then report (0 =
                                    forever, the default)
               --net-workers <n>    connection-worker pool size (default 4;
                                    thread count is pool-sized, never
                                    connection-sized)
               --pipeline-depth <n> per-connection pipelined in-flight
                                    bound (default 32)
               --writer-queue <n>   per-connection reply queue bound
                                    (default service.net_writer_queue, 256)
               --max-conns <n>      accept-side cap on open connections;
                                    arrivals beyond it are closed at
                                    accept and counted in
                                    net_conns_rejected (0 = unlimited)
               --idle-timeout <ms>  close connections idle this long so
                                    their slots come back (0 = never)
               --schemes <list>     extra schemes served via their own
                                    clusters, e.g. 18x18,9x9 (others
                                    answer `unsupported`)
  loadgen      drive load at a serve-net listener
               --addr <host:port>   target server; omit to run against an
                                    embedded loopback server (which also
                                    accepts the serve-net options above)
               --workloads <list>   comma-separated mixes (default the
                                    --workload value, default mixed)
               --conns <n>          connections (default 4)
               --rate <r/s>         offered load, 0 = flood (the default)
               --closed-loop        bound outstanding requests instead of
                                    offering load unconditionally
               --concurrency <n>    closed-loop window across connections
                                    (default 32)
               --sweep <r1,r2,...>  drive one closed-loop run per rate
                                    (ascending) and emit the p99-vs-load
                                    curve (BENCH_net_sweep.json rows);
                                    against --addr, pass --net-workers to
                                    state the server's pool size for the
                                    knee gate
               --warmup <n>         leading requests excluded from latency
                                    stats (default requests/20)
               --json <path>        write bench rows (BENCH_net.json or
                                    BENCH_net_sweep.json under --sweep)
  analyze      print the paper's block/utilization analysis table
  predicates   adaptive-precision orient2d demo
               --points <n>         number of predicates (default 2000)
  info         print loaded-engine facts
  help         this text"
    );
}

fn serve(args: &Args) -> Result<()> {
    let cfg = args.service_config()?;
    let backend = args.backend_choice(&cfg)?;
    println!(
        "serving {} requests of workload `{}` (scheme {:?}, fabric {:?}, cores {}, \
         lane kernel {})",
        cfg.requests,
        cfg.workload.name(),
        cfg.scheme,
        cfg.fabric,
        cfg.cores,
        backend.lane_config().map_or_else(|| "pjrt".to_string(), |l| l.kernel_name())
    );
    let svc = Service::start(&cfg, backend);
    let mut gen = TraceGen::new(cfg.seed, cfg.mix(), 0);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(4096);
    for req in gen.take(cfg.requests) {
        pending.push(svc.submit(req.id, req.class, req.a, req.b).expect("service closed"));
        // cap in-flight to keep memory bounded
        if pending.len() >= 4096 {
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    let fabric = svc.fabric_report();
    let report = svc.shutdown();
    println!("\n== serving report ==");
    println!("wall time            {:.3} s", wall.as_secs_f64());
    println!("throughput           {:.0} mult/s", report.responses as f64 / wall.as_secs_f64());
    print!("{}", report.snapshot.render());
    println!("\n== fabric report ({}) ==", fabric.fabric);
    for class in &fabric.per_class {
        println!("  {:<16} {:>10} ops", class.label, class.ops);
    }
    println!("cycles               {}", fabric.cycles);
    println!("ops/cycle            {:.3}", fabric.throughput());
    println!("dynamic energy       {:.1}", fabric.dyn_energy);
    println!("wasted energy        {:.1}%", fabric.wasted_fraction() * 100.0);
    println!("energy/op            {:.3}", fabric.energy_per_op());
    Ok(())
}

fn cluster(args: &Args) -> Result<()> {
    let cfg = args.service_config()?;
    let ccfg = args.cluster_config(cfg.clone())?;
    let backend = args.backend_choice(&cfg)?;
    let shards = ccfg.shards;
    println!(
        "cluster: {shards} shards, policy `{}`, workload `{}`, {} requests",
        ccfg.policy.name(),
        cfg.workload.name(),
        cfg.requests
    );
    let mut cluster = Cluster::start(&ccfg, backend);
    if let Some(d) = args.options.get("degrade") {
        let shard: usize = d.parse()?;
        if shard >= shards {
            bail!("--degrade {shard} out of range (cluster has {shards} shards)");
        }
        let faults = args.get_usize("faults", 8)?;
        let mut rng = civp::proput::Rng::new(cfg.seed);
        let out = cluster.degrade_shard(shard, civp::decomp::BlockKind::M24x24, faults, &mut rng);
        let st = &cluster.states()[shard];
        println!(
            "degraded shard {shard}: {} faults repaired, {} blocks lost -> weight {}/{}, \
             quad-one-wave {}",
            out.repaired,
            out.lost,
            st.weight(),
            civp::cluster::FULL_WEIGHT,
            st.quad_one_wave()
        );
    }
    let mut gen = TraceGen::new(cfg.seed, cfg.mix(), 0);
    let t0 = Instant::now();
    // Cap held replies below the cluster's total in-flight budget: every
    // un-received reply pins a per-shard slot, so holding >= shards ×
    // inflight of them would livelock the blocking submit.
    let budget = (ccfg.max_inflight as usize).saturating_mul(shards);
    let drain_at = 4096.min(budget / 2).max(1);
    let mut pending = Vec::with_capacity(drain_at);
    for req in gen.take(cfg.requests) {
        let rx = cluster
            .submit(req.id, req.class, req.a, req.b)
            .map_err(|e| err!("cluster submit failed: {e}"))?;
        pending.push(rx);
        if pending.len() >= drain_at {
            for rx in pending.drain(..) {
                let _ = rx.recv();
            }
        }
    }
    for rx in pending {
        let _ = rx.recv();
    }
    let wall = t0.elapsed();
    println!("\n== cluster metrics ==");
    print!("{}", cluster.metrics().render());
    let report = cluster.shutdown();
    println!("\n== cluster report ==");
    println!("wall time            {:.3} s", wall.as_secs_f64());
    println!(
        "throughput           {:.0} mult/s",
        report.accepted as f64 / wall.as_secs_f64()
    );
    print!("{}", report.render());
    Ok(())
}

fn serve_net(args: &Args) -> Result<()> {
    let cfg = args.service_config()?;
    let net_cfg = args.net_server_config("127.0.0.1:7070", args.cluster_config(cfg.clone())?)?;
    let backend = args.backend_choice(&cfg)?;
    let shards = net_cfg.cluster.shards;
    let policy = net_cfg.cluster.policy;
    let server = NetServer::start(&net_cfg, backend)?;
    println!(
        "serve-net: listening on {} (schemes {:?}, {shards} shards/scheme, policy `{}`, \
         per-shard inflight {})",
        server.local_addr(),
        server.schemes(),
        policy.name(),
        net_cfg.cluster.max_inflight
    );
    println!(
        "  edge: {} net workers, pipeline depth {}, writer queue {}",
        net_cfg.net_workers, net_cfg.pipeline_depth, net_cfg.writer_queue
    );
    let duration = args.get_usize("duration", 0)?;
    if duration == 0 {
        println!("serving until killed (pass --duration <secs> for a bounded run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_secs(duration as u64));
    println!("\n== net metrics ==");
    print!("{}", server.metrics().render());
    let report = server.stop();
    println!("\n== cluster report ==");
    print!("{}", report.render());
    Ok(())
}

fn loadgen(args: &Args) -> Result<()> {
    let cfg = args.service_config()?;
    let specs = args.workloads(cfg.workload.name())?;
    let external_addr = args.options.get("addr").cloned();
    let sweep = args.sweep_rates()?;
    let mut json = JsonReport::new();
    for spec in specs {
        // Each mix gets a fresh server in embedded mode, so the per-class
        // op counters it reports cover exactly this run.
        let (addr, server, workers) = match &external_addr {
            // Against a remote server the pool size cannot be observed
            // over the wire — `--net-workers` states it for the sweep's
            // knee floor (and must match the server's flag).
            Some(addr) => (
                addr.clone(),
                None,
                args.get_usize("net-workers", civp::net::server::DEFAULT_NET_WORKERS)?,
            ),
            None => {
                let net_cfg =
                    args.net_server_config("127.0.0.1:0", args.cluster_config(cfg.clone())?)?;
                let server = NetServer::start(&net_cfg, args.backend_choice(&cfg)?)?;
                (server.local_addr().to_string(), Some(server), net_cfg.net_workers)
            }
        };
        let lg = LoadgenConfig {
            addr,
            conns: args.get_usize("conns", 4)?,
            requests: cfg.requests as u64,
            warmup: args.get_usize("warmup", (cfg.requests / 20).max(1))? as u64,
            rate: args.get_f64("rate", 0.0)?,
            closed_loop: args.get_flag("closed-loop") || sweep.is_some(),
            concurrency: args.get_usize("concurrency", 32)?,
            mix: spec.mix(),
            mix_name: spec.name().to_string(),
            scheme: cfg.scheme,
            seed: cfg.seed,
            ..LoadgenConfig::default()
        };
        if let Some(rates) = &sweep {
            println!(
                "loadgen sweep: mix `{}`, {} requests/point over {} conns \
                 (window {}), rates {rates:?} -> {}",
                lg.mix_name, lg.requests, lg.conns, lg.concurrency, lg.addr
            );
            let sweep_report = civp::net::loadgen::run_sweep(&lg, rates, workers)?;
            print!("{}", sweep_report.render());
            sweep_report.push_bench_rows(&mut json);
        } else {
            println!(
                "loadgen: mix `{}`, {} requests over {} conns at {}{} -> {}",
                lg.mix_name,
                lg.requests,
                lg.conns,
                if lg.rate > 0.0 { format!("{} req/s", lg.rate) } else { "flood".to_string() },
                if lg.closed_loop {
                    format!(" (closed loop, window {})", lg.concurrency)
                } else {
                    String::new()
                },
                lg.addr
            );
            let report = civp::net::loadgen::run(&lg)?;
            print!("{}", report.render());
            report.push_bench_rows(&mut json);
        }
        if let Some(server) = server {
            // Embedded mode doubles as the e2e oracle: everything the
            // generator sent must be visible in the cluster's counters.
            let executed: u64 = server.cluster().op_counts().values().sum();
            let cluster_report = server.stop();
            println!(
                "  server executed {executed} ops ({} accepted, {} saturated)",
                cluster_report.accepted, cluster_report.rejected_saturated
            );
        }
    }
    if let Some(path) = args.options.get("json") {
        json.write(path)?;
    }
    Ok(())
}

fn analyze() -> Result<()> {
    println!("== paper §III analysis: blocks per multiplication ==\n");
    println!(
        "{:<10} {:<8} {:>6} {:>7} {:>7} {:>6} {:>6} {:>8} {:>8}",
        "class", "scheme", "blocks", "24x24", "24x9", "9x9", "18x18", "padded", "util%"
    );
    for row in AnalysisRow::full_table() {
        let c = &row.census;
        println!(
            "{:<10} {:<8} {:>6} {:>7} {:>7} {:>6} {:>6} {:>8} {:>8.1}",
            row.class.name(),
            row.kind.name(),
            c.total_blocks,
            c.count(civp::decomp::BlockKind::M24x24),
            c.count(civp::decomp::BlockKind::M24x9),
            c.count(civp::decomp::BlockKind::M9x9),
            c.count(civp::decomp::BlockKind::M18x18),
            c.padded_blocks,
            c.utilization * 100.0
        );
    }
    println!(
        "\npaper claims (§II.C): quad on 18x18 needs {} blocks, {} wasted (35%);\n\
         recomputed wastage is 13/49 = 26.5% — see EXPERIMENTS.md E5.",
        civp::decomp::analysis::PAPER_CLAIMED_QP_TOTAL_18X18,
        civp::decomp::analysis::PAPER_CLAIMED_QP_WASTED_18X18
    );
    Ok(())
}

fn predicates(args: &Args) -> Result<()> {
    let n = args.get_usize("points", 2000)?;
    let cfg = ServiceConfig::default();
    let svc = Service::start(&cfg, BackendChoice::native(SchemeKind::Civp));
    let mut stats = AdaptiveStats::default();
    let mut rng = civp::proput::Rng::new(7);
    let t0 = Instant::now();
    for _ in 0..n {
        // mix of generic and degenerate (collinear) triangles
        let degenerate = rng.chance(0.3);
        let c0 = (rng.f64(), rng.f64());
        let c1 = (rng.f64(), rng.f64());
        let c2 = if degenerate {
            let t = rng.f64();
            (c0.0 + t * (c1.0 - c0.0), c0.1 + t * (c1.1 - c0.1))
        } else {
            (rng.f64(), rng.f64())
        };
        orient2d_adaptive(&svc, c0, c1, c2, &mut stats);
    }
    println!("adaptive orient2d over {n} triangles in {:?}", t0.elapsed());
    println!(
        "settled: single={} double={} quad={}",
        stats.settled_single, stats.settled_double, stats.settled_quad
    );
    let fabric = svc.fabric_report();
    println!("precision traffic mix observed by the fabric:");
    for class in &fabric.per_class {
        println!("  {:<16} {} ops", class.label, class.ops);
    }
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let handle = EngineHandle::load(dir)?;
    let info = handle.info()?;
    println!("platform   {}", info.platform);
    println!("batch      {}", info.batch);
    println!("classes    {:?}", info.loaded);
    // smoke multiply
    let out = handle.mul(
        OpClass::Double,
        vec![(2.0f64).to_bits() as u128],
        vec![(3.0f64).to_bits() as u128],
    )?;
    println!("2.0 * 3.0  = {} (via PJRT)", f64::from_bits(out[0] as u64));
    handle.stop();
    Ok(())
}
