//! Minimal error substrate (offline build — no `anyhow`).
//!
//! Provides the subset of `anyhow`'s surface the crate uses: a type-erased
//! [`Error`] carrying a context chain, the [`Result`] alias, the
//! [`Context`] extension trait for `Result`/`Option`, and the [`bail!`],
//! [`ensure!`] and [`err!`] macros. Formatting mirrors `anyhow`: plain
//! `{}` prints the outermost context, alternate `{:#}` prints the whole
//! chain separated by `": "`.

use std::fmt;

/// A type-erased error: an ordered chain of messages, outermost context
/// first, root cause last.
///
/// Deliberately does **not** implement [`std::error::Error`]; that is what
/// permits the blanket `From<E: std::error::Error>` conversion used by the
/// `?` operator (the same design decision `anyhow` makes).
pub struct Error {
    /// Outermost context first, root cause last. Never empty.
    chain: Vec<String>,
}

/// Crate-wide result alias (error type defaults to [`Error`]).
pub type Result<T, E = Error> = core::result::Result<T, E>;

impl Error {
    /// Create an error from a single message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { chain: vec![msg.into()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `context` / `with_context` to `Result` and
/// `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message, converting to [`Result<T>`].
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for core::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for core::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (the `anyhow!` equivalent).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::error::Error::msg(format!($($arg)*)));
        }
    };
}

pub use crate::{bail, ensure, err};

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/forever").context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_chain_formats_like_anyhow() {
        let e = io_fail().unwrap_err();
        let plain = format!("{e}");
        let full = format!("{e:#}");
        assert_eq!(plain, "reading config");
        assert!(full.starts_with("reading config: "), "{full}");
        assert!(full.len() > plain.len());
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("seven is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "seven is right out");
        let e = err!("v={}", 5);
        assert_eq!(e.root_cause(), "v=5");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| "missing thing").unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }
}
