//! The generic IEEE-754 multiplication pipeline with a pluggable
//! significand multiplier.
//!
//! `mul_bits` implements the full standard (specials, subnormals, all five
//! rounding modes, exception flags); the *integer significand product* —
//! the block the paper redesigns — is abstracted behind [`SigMultiplier`]
//! so the CIVP decomposition and the 18x18 / 25x18 / 9x9 baselines can all
//! drive a real FP multiply and be checked bit-for-bit against hardware.

use super::format::{FpClass, FpFormat, Unpacked};
use super::round::{round_shift, RoundMode};
use crate::wideint::{mul_u128, PackedBits, Wide, U128, U256};

/// Limb count of the exact wide significand product: two 489-bit Fp512
/// significands multiply into ≤ 978 bits, held in a `Wide<16>` (1024-bit)
/// word.
pub const WIDE_PROD_LIMBS: usize = 16;

/// The exact double-width product of two wide significands.
pub type WideProd = Wide<WIDE_PROD_LIMBS>;

/// IEEE-754 exception flags raised by an operation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Flags {
    /// Result differs from the infinitely-precise result.
    pub inexact: bool,
    /// Result overflowed to ±∞ / max-finite.
    pub overflow: bool,
    /// Result is tiny (subnormal range) and inexact.
    pub underflow: bool,
    /// Invalid operation (0 × ∞, or a signalling NaN input).
    pub invalid: bool,
}

impl Flags {
    /// Merge another flag set in (bitwise or).
    pub fn merge(&mut self, other: Flags) {
        self.inexact |= other.inexact;
        self.overflow |= other.overflow;
        self.underflow |= other.underflow;
        self.invalid |= other.invalid;
    }
}

/// The exact integer multiplier for `width`-bit significands — the unit the
/// paper replaces. Implementations: [`DirectMul`] (plain widening multiply,
/// the oracle) and `decomp::DecompMul` (tile-level execution through a
/// partition scheme, tallying simulated FPGA block usage).
pub trait SigMultiplier {
    /// Exact product of `a × b`, where `a, b < 2^width`.
    fn mul_sig(&mut self, a: U128, b: U128, width: u32) -> U256;

    /// Exact product for significands wider than 128 bits (`width` up to
    /// 489). The default is the direct widening multiply — the oracle every
    /// decomposed implementation is pinned against; `decomp::DecompMul`
    /// overrides it with tile-plan execution (naive all-pairs or the
    /// Karatsuba DAG).
    fn mul_sig_wide(&mut self, a: PackedBits, b: PackedBits, width: u32) -> WideProd {
        let _ = width;
        a.mul_full::<WIDE_PROD_LIMBS>(&b)
    }
}

/// Oracle multiplier: one widening schoolbook multiply, no decomposition.
#[derive(Clone, Copy, Debug, Default)]
pub struct DirectMul;

impl SigMultiplier for DirectMul {
    fn mul_sig(&mut self, a: U128, b: U128, _width: u32) -> U256 {
        mul_u128(a, b)
    }
}

/// The special-case lattice shared by [`mul_bits`] and the batched
/// pipeline in [`super::batch`]: returns `Some(packed_result)` when either
/// operand short-circuits the multiply (NaN, 0 × ∞, ±∞, ±0), raising
/// `invalid` into `flags` where IEEE-754 requires it. `None` means both
/// operands are finite and non-zero — the caller multiplies significands.
pub(super) fn special_product(
    fmt: &FpFormat,
    a: U128,
    b: U128,
    ua: &Unpacked,
    ub: &Unpacked,
    sign: bool,
    flags: &mut Flags,
) -> Option<U128> {
    if ua.class == FpClass::Nan || ub.class == FpClass::Nan {
        flags.invalid |= fmt.is_signaling_nan(a) || fmt.is_signaling_nan(b);
        return Some(fmt.quiet_nan());
    }
    match (ua.class, ub.class) {
        (FpClass::Infinite, FpClass::Zero) | (FpClass::Zero, FpClass::Infinite) => {
            flags.invalid = true;
            Some(fmt.quiet_nan())
        }
        (FpClass::Infinite, _) | (_, FpClass::Infinite) => Some(fmt.inf(sign)),
        (FpClass::Zero, _) | (_, FpClass::Zero) => Some(fmt.zero(sign)),
        _ => None,
    }
}

/// Round, renormalize, detect underflow/overflow and pack an exact
/// double-width significand product — the back half of the pipeline,
/// shared by [`mul_bits`] and the batched path in [`super::batch`] so the
/// two can never drift. `exp_sum` is the sum of the operands' normalized
/// unbiased exponents; `inexact`/`underflow`/`overflow` are OR-ed into
/// `flags`.
pub(super) fn finish_product(
    fmt: &FpFormat,
    sign: bool,
    exp_sum: i32,
    prod: U256,
    mode: RoundMode,
    flags: &mut Flags,
) -> U128 {
    let f = fmt.frac_bits;
    // Both significands are in [2^f, 2^(f+1)), so the product is in
    // [2^(2f), 2^(2f+2)) — its MSB sits at bit 2f or 2f+1.
    debug_assert!(!prod.is_zero());
    let top = prod.bit_len() - 1;
    debug_assert!(top == 2 * f || top == 2 * f + 1);

    // Unbiased exponent of the product when its significand is interpreted
    // with the integer (hidden) bit at `top`.
    let mut exp = exp_sum + (top as i32 - 2 * f as i32);

    // --- Shift down to sig_bits, handling underflow denormalization ------
    // Keeping f+1 bits means shifting right by (top - f).
    let mut shift = top - f;
    if exp < fmt.emin() {
        // Result is tiny: denormalize so the final significand aligns with
        // exponent emin, folding the extra shifted-out bits into sticky.
        let extra = (fmt.emin() - exp) as u32;
        shift = shift.saturating_add(extra);
        exp = fmt.emin();
    }

    let rounded = round_shift(prod, shift, mode, sign);
    flags.inexact |= rounded.inexact;
    let mut sig = rounded.sig;

    // Rounding may carry out one extra bit (e.g. 0b111..1 + 1): renormalize.
    if sig.bit_len() > fmt.sig_bits() {
        // Carry-out is always into exactly one extra bit and the low bits
        // are then zero, so a plain shift is exact.
        debug_assert!(sig.bit_len() == fmt.sig_bits() + 1);
        sig = sig.shr(1);
        exp += 1;
    }

    // Underflow flag: tiny (needed denormalization, i.e. the rounded result
    // lies below the normal range) AND inexact. "Tininess after rounding":
    // a value that rounded up into the normal range (sig has the hidden
    // bit and exp == emin) is not tiny.
    let hidden = U128::ONE.shl(f);
    let sig128: U128 = sig.narrow();
    let is_subnormal_result =
        exp == fmt.emin() && sig128.cmp_wide(&hidden) == core::cmp::Ordering::Less;
    if is_subnormal_result && rounded.inexact {
        flags.underflow = true;
    }

    // --- Overflow ---------------------------------------------------------
    if exp > fmt.emax() {
        flags.overflow = true;
        flags.inexact = true;
        let to_inf = match mode {
            RoundMode::NearestEven | RoundMode::NearestAway => true,
            RoundMode::TowardZero => false,
            RoundMode::TowardPositive => !sign,
            RoundMode::TowardNegative => sign,
        };
        return if to_inf { fmt.inf(sign) } else { fmt.max_finite(sign) };
    }

    if sig.is_zero() {
        // Complete underflow to zero.
        return fmt.zero(sign);
    }

    fmt.pack(sign, exp, sig128)
}

/// Wide-operand twin of [`special_product`]: the same IEEE special-case
/// lattice over [`PackedBits`] operands and `Unpacked<8>` fields, using the
/// `_w` constant constructors.
pub(super) fn special_product_w(
    fmt: &FpFormat,
    a: PackedBits,
    b: PackedBits,
    ua: &Unpacked<8>,
    ub: &Unpacked<8>,
    sign: bool,
    flags: &mut Flags,
) -> Option<PackedBits> {
    if ua.class == FpClass::Nan || ub.class == FpClass::Nan {
        flags.invalid |= fmt.is_signaling_nan_g(a) || fmt.is_signaling_nan_g(b);
        return Some(fmt.quiet_nan_w());
    }
    match (ua.class, ub.class) {
        (FpClass::Infinite, FpClass::Zero) | (FpClass::Zero, FpClass::Infinite) => {
            flags.invalid = true;
            Some(fmt.quiet_nan_w())
        }
        (FpClass::Infinite, _) | (_, FpClass::Infinite) => Some(fmt.inf_w(sign)),
        (FpClass::Zero, _) | (_, FpClass::Zero) => Some(fmt.zero_w(sign)),
        _ => None,
    }
}

/// Wide-operand twin of [`finish_product`]: rounds an exact [`WideProd`]
/// significand product down to a wide format, with identical underflow /
/// overflow / renormalization semantics (the round stage itself is the
/// shared limb-generic `round_shift`).
pub(super) fn finish_product_w(
    fmt: &FpFormat,
    sign: bool,
    exp_sum: i32,
    prod: WideProd,
    mode: RoundMode,
    flags: &mut Flags,
) -> PackedBits {
    let f = fmt.frac_bits;
    debug_assert!(!prod.is_zero());
    let top = prod.bit_len() - 1;
    debug_assert!(top == 2 * f || top == 2 * f + 1);

    let mut exp = exp_sum + (top as i32 - 2 * f as i32);
    let mut shift = top - f;
    if exp < fmt.emin() {
        let extra = (fmt.emin() - exp) as u32;
        shift = shift.saturating_add(extra);
        exp = fmt.emin();
    }

    let rounded = round_shift(prod, shift, mode, sign);
    flags.inexact |= rounded.inexact;
    let mut sig = rounded.sig;

    if sig.bit_len() > fmt.sig_bits() {
        debug_assert!(sig.bit_len() == fmt.sig_bits() + 1);
        sig = sig.shr(1);
        exp += 1;
    }

    let hidden = PackedBits::ONE.shl(f);
    let sig_w: PackedBits = sig.narrow();
    let is_subnormal_result =
        exp == fmt.emin() && sig_w.cmp_wide(&hidden) == core::cmp::Ordering::Less;
    if is_subnormal_result && rounded.inexact {
        flags.underflow = true;
    }

    if exp > fmt.emax() {
        flags.overflow = true;
        flags.inexact = true;
        let to_inf = match mode {
            RoundMode::NearestEven | RoundMode::NearestAway => true,
            RoundMode::TowardZero => false,
            RoundMode::TowardPositive => !sign,
            RoundMode::TowardNegative => sign,
        };
        return if to_inf { fmt.inf_w(sign) } else { fmt.max_finite_w(sign) };
    }

    if sig.is_zero() {
        return fmt.zero_w(sign);
    }

    fmt.pack_g(sign, exp, sig_w)
}

/// Multiply two wide packed values (Fp256/Fp512) under rounding mode
/// `mode`, computing the significand product through `m.mul_sig_wide`. The
/// wide twin of [`mul_bits`], stage for stage.
pub fn mul_bits_wide(
    fmt: &FpFormat,
    a: PackedBits,
    b: PackedBits,
    mode: RoundMode,
    m: &mut dyn SigMultiplier,
) -> (PackedBits, Flags) {
    let mut flags = Flags::default();
    let ua = fmt.unpack_g(a);
    let ub = fmt.unpack_g(b);
    let sign = ua.sign ^ ub.sign;

    if let Some(bits) = special_product_w(fmt, a, b, &ua, &ub, sign, &mut flags) {
        return (bits, flags);
    }

    let na = ua.normalize(fmt);
    let nb = ub.normalize(fmt);

    let prod = m.mul_sig_wide(na.sig, nb.sig, fmt.sig_bits());

    let bits = finish_product_w(fmt, sign, na.exp + nb.exp, prod, mode, &mut flags);
    (bits, flags)
}

/// Multiply a batch of wide packed values elementwise — the wide analog of
/// [`mul_bits_batch`] (per-op, scalar pipeline per element; wide classes
/// have no lane-fused path, their parallelism lives in the tile DAG).
pub fn mul_bits_batch_wide(
    fmt: &FpFormat,
    a: &[PackedBits],
    b: &[PackedBits],
    mode: RoundMode,
    m: &mut dyn SigMultiplier,
    out: &mut Vec<PackedBits>,
) -> Flags {
    assert_eq!(a.len(), b.len(), "operand length mismatch");
    out.clear();
    out.reserve(a.len());
    let mut flags = Flags::default();
    for (&x, &y) in a.iter().zip(b) {
        let (bits, f) = mul_bits_wide(fmt, x, y, mode, m);
        flags.merge(f);
        out.push(bits);
    }
    flags
}

/// Multiply two packed values of format `fmt` under rounding mode `mode`,
/// computing the significand product through `m`. Returns the packed result
/// and the exception flags.
pub fn mul_bits(
    fmt: &FpFormat,
    a: U128,
    b: U128,
    mode: RoundMode,
    m: &mut dyn SigMultiplier,
) -> (U128, Flags) {
    let mut flags = Flags::default();
    let ua = fmt.unpack(a);
    let ub = fmt.unpack(b);
    let sign = ua.sign ^ ub.sign;

    // --- Special-case lattice -------------------------------------------
    if let Some(bits) = special_product(fmt, a, b, &ua, &ub, sign, &mut flags) {
        return (bits, flags);
    }

    // --- Normalize subnormal inputs --------------------------------------
    let na = ua.normalize(fmt);
    let nb = ub.normalize(fmt);

    // --- Exact significand product (the paper's block) -------------------
    let prod = m.mul_sig(na.sig, nb.sig, fmt.sig_bits());

    // --- Round / renormalize / pack ---------------------------------------
    let bits = finish_product(fmt, sign, na.exp + nb.exp, prod, mode, &mut flags);
    (bits, flags)
}

/// Multiply a whole batch of packed values elementwise — **per-op mode**:
/// each element runs the full scalar [`mul_bits`] pipeline in turn. Writes
/// the packed products into `out` (cleared first) and returns the union of
/// the exception flags raised.
///
/// §Perf: the serving stack no longer uses this path in steady state — it
/// goes through the lane-fused [`super::batch::FpuBatch`], which peels
/// specials into a scalar sidecar and streams the significand products
/// tile-major through `Plan::execute_lanes`. This function remains the
/// per-op reference the property tests and `bench_lanes` pin the fused
/// path against. Operand patterns travel in the low bits of `u128`
/// regardless of precision, mirroring [`crate::coordinator::Request`].
///
/// # Panics
///
/// Panics if `a` and `b` have different lengths — callers with untrusted
/// input validate first (the coordinator's `Backend::execute` guards with
/// an error before reaching this point).
pub fn mul_bits_batch(
    fmt: &FpFormat,
    a: &[u128],
    b: &[u128],
    mode: RoundMode,
    m: &mut dyn SigMultiplier,
    out: &mut Vec<u128>,
) -> Flags {
    assert_eq!(a.len(), b.len(), "operand length mismatch");
    out.clear();
    out.reserve(a.len());
    let mut flags = Flags::default();
    for (&x, &y) in a.iter().zip(b) {
        let (bits, f) = mul_bits(fmt, U128::from_u128(x), U128::from_u128(y), mode, m);
        flags.merge(f);
        out.push(bits.as_u128());
    }
    flags
}
