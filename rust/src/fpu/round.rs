//! IEEE-754 rounding of an exact wide product down to a target significand.
//!
//! The multiplier array produces the *exact* double-width product; rounding
//! reduces it to `sig_bits` with guard/sticky semantics. This stage is
//! shared by every precision and every multiplier backend.

use crate::wideint::Wide;

/// IEEE-754 rounding-direction attributes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RoundMode {
    /// roundTiesToEven (default).
    NearestEven,
    /// roundTiesToAway.
    NearestAway,
    /// roundTowardZero.
    TowardZero,
    /// roundTowardPositive.
    TowardPositive,
    /// roundTowardNegative.
    TowardNegative,
}

impl RoundMode {
    /// All five modes (test sweeps).
    pub const ALL: [RoundMode; 5] = [
        RoundMode::NearestEven,
        RoundMode::NearestAway,
        RoundMode::TowardZero,
        RoundMode::TowardPositive,
        RoundMode::TowardNegative,
    ];

    /// Number of modes.
    pub const COUNT: usize = Self::ALL.len();

    /// Dense index (position in [`RoundMode::ALL`]) — the wire encoding.
    #[inline]
    pub const fn index(self) -> usize {
        self as usize
    }

    /// Inverse of [`RoundMode::index`]; `None` for out-of-range indices
    /// (the checked path wire decoding needs).
    #[inline]
    pub fn from_index(i: usize) -> Option<RoundMode> {
        Self::ALL.get(i).copied()
    }
}

/// Outcome of [`round_shift`]. `N` is the product limb count — the default
/// (`N = 4`, a `U256` product) serves every narrow class; wide formats
/// round `Wide<16>` products through the same function.
#[derive(Clone, Copy, Debug)]
pub struct Rounded<const N: usize = 4> {
    /// Rounded significand (may have grown one bit past the target width —
    /// caller renormalizes).
    pub sig: Wide<N>,
    /// Any discarded bit was non-zero (inexact).
    pub inexact: bool,
}

/// Shift `value` right by `shift` bits, rounding the discarded bits per
/// `mode`. `sign` is the sign of the datum (directional modes depend on it).
///
/// `shift == 0` returns the value unchanged and exact. Shifts larger than
/// the value's width collapse everything into the sticky bit.
pub fn round_shift<const N: usize>(
    value: Wide<N>,
    shift: u32,
    mode: RoundMode,
    sign: bool,
) -> Rounded<N> {
    if shift == 0 {
        return Rounded { sig: value, inexact: false };
    }
    let kept = value.shr(shift);
    let round_bit = value.bit(shift - 1);
    let sticky = if shift >= 2 { value.any_below(shift - 1) } else { false };
    let inexact = round_bit || sticky;
    if !inexact {
        return Rounded { sig: kept, inexact: false };
    }
    let increment = match mode {
        RoundMode::NearestEven => round_bit && (sticky || kept.bit(0)),
        RoundMode::NearestAway => round_bit,
        RoundMode::TowardZero => false,
        RoundMode::TowardPositive => !sign,
        RoundMode::TowardNegative => sign,
    };
    let sig = if increment { kept.wrapping_add(&Wide::ONE) } else { kept };
    Rounded { sig, inexact }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proput::forall;
    use crate::wideint::U256;

    fn rs(v: u64, shift: u32, mode: RoundMode, sign: bool) -> (u64, bool) {
        let r = round_shift(U256::from_u64(v), shift, mode, sign);
        (r.sig.as_u64(), r.inexact)
    }

    #[test]
    fn exact_shift_is_exact() {
        assert_eq!(rs(0b1000, 3, RoundMode::NearestEven, false), (1, false));
        assert_eq!(rs(0b10100, 2, RoundMode::TowardZero, false), (0b101, false));
    }

    #[test]
    fn nearest_even_ties() {
        // 0b101 >> 1: kept=0b10, round=1, sticky=0 -> tie -> stays even (10)
        assert_eq!(rs(0b101, 1, RoundMode::NearestEven, false), (0b10, true));
        // 0b111 >> 1: kept=0b11, round=1, sticky=0 -> tie -> to even (100)
        assert_eq!(rs(0b111, 1, RoundMode::NearestEven, false), (0b100, true));
        // 0b1011 >> 2: kept=0b10, round=1, sticky=1 -> round up (11)
        assert_eq!(rs(0b1011, 2, RoundMode::NearestEven, false), (0b11, true));
    }

    #[test]
    fn nearest_away_ties_up() {
        assert_eq!(rs(0b101, 1, RoundMode::NearestAway, false), (0b11, true));
        assert_eq!(rs(0b111, 1, RoundMode::NearestAway, false), (0b100, true));
    }

    #[test]
    fn directional_modes() {
        // value 0b1001 >> 2 = 0b10 remainder 01 (inexact, below half)
        assert_eq!(rs(0b1001, 2, RoundMode::TowardZero, false), (0b10, true));
        assert_eq!(rs(0b1001, 2, RoundMode::TowardPositive, false), (0b11, true));
        assert_eq!(rs(0b1001, 2, RoundMode::TowardPositive, true), (0b10, true));
        assert_eq!(rs(0b1001, 2, RoundMode::TowardNegative, true), (0b11, true));
        assert_eq!(rs(0b1001, 2, RoundMode::TowardNegative, false), (0b10, true));
    }

    #[test]
    fn huge_shift_all_sticky() {
        let one = U256::ONE;
        let r = round_shift(one, 200, RoundMode::NearestEven, false);
        assert!(r.sig.is_zero());
        assert!(r.inexact);
        let r = round_shift(one, 200, RoundMode::TowardPositive, false);
        assert_eq!(r.sig.as_u64(), 1); // rounds up from sticky
    }

    #[test]
    fn rne_matches_reference_formula() {
        // Property: for random v and shift<=32, RNE equals floor((v + half +
        // tie_adjust) >> shift) computed with u128 arithmetic.
        forall(0x31, 5000, |rng| {
            let v = rng.next_u64() as u128;
            let shift = rng.range(1, 32) as u32;
            let kept = v >> shift;
            let rem = v & ((1u128 << shift) - 1);
            let half = 1u128 << (shift - 1);
            let expect = if rem > half || (rem == half && kept & 1 == 1) {
                kept + 1
            } else {
                kept
            };
            let got = round_shift(U256::from_u128(v), shift, RoundMode::NearestEven, false);
            assert_eq!(got.sig.as_u128(), expect, "v={v:#x} shift={shift}");
            assert_eq!(got.inexact, rem != 0);
        });
    }

    #[test]
    fn wide_round_matches_narrow() {
        // The generic round path is limb-count agnostic: a Wide<16> product
        // rounds bit-identically to the U256 path on shared-range values.
        use crate::wideint::Wide;
        forall(0x33, 2000, |rng| {
            let v = rng.next_u64() as u128 | ((rng.next_u64() as u128) << 64);
            let shift = rng.range(1, 100) as u32;
            let sign = rng.chance(0.5);
            for mode in RoundMode::ALL {
                let narrow = round_shift(U256::from_u128(v), shift, mode, sign);
                let wide = round_shift(Wide::<16>::from_u128(v), shift, mode, sign);
                assert_eq!(narrow.sig.as_u128(), wide.sig.as_u128(), "v={v:#x} shift={shift}");
                assert_eq!(narrow.inexact, wide.inexact);
            }
        });
    }

    #[test]
    fn ordering_between_modes() {
        // TowardNegative <= TowardZero(sign-adjusted) <= TowardPositive
        forall(0x32, 3000, |rng| {
            let v = rng.next_u64();
            let shift = rng.range(1, 40) as u32;
            let down = rs(v, shift, RoundMode::TowardNegative, false).0;
            let up = rs(v, shift, RoundMode::TowardPositive, false).0;
            let ne = rs(v, shift, RoundMode::NearestEven, false).0;
            assert!(down <= ne && ne <= up);
            assert!(up - down <= 1);
        });
    }
}
