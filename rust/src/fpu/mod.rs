//! IEEE-754 software floating point (softfloat) substrate.
//!
//! The paper's contribution is a *significand-multiplier organization*;
//! everything around it (unpack, normalize, round, pack, special cases) is
//! standard IEEE-754. This module implements that standard machinery
//! generically over an [`FpFormat`], with the significand multiplier left
//! pluggable via [`SigMultiplier`], so the CIVP decomposition engine (and
//! the baseline 18x18 / 25x18 tilings) can be dropped into a real FP
//! multiply and verified bit-exactly against hardware.
//!
//! The served formats live in the open [`OpClass`] registry — the paper's
//! three precisions plus two sub-single and two wide classes:
//! * bfloat16  — 1 sign, 8 exponent,  7 fraction  (8-bit significand)
//! * binary16  — 1 sign, 5 exponent,  10 fraction (11-bit significand)
//! * binary32  — 1 sign, 8 exponent,  23 fraction (24-bit significand)
//! * binary64  — 1 sign, 11 exponent, 52 fraction (53-bit significand)
//! * binary128 — 1 sign, 15 exponent, 112 fraction (113-bit significand)
//! * binary256 — 1 sign, 19 exponent, 236 fraction (237-bit significand)
//! * binary512 — 1 sign, 23 exponent, 488 fraction (489-bit significand)
//!
//! The two wide classes outgrow the `U128` operand word: their packed
//! values travel as [`crate::wideint::PackedBits`] through the `_wide`
//! entry points ([`mul_bits_wide`], [`FpuBatch::mul_batch_bits_wide`]),
//! which share every stage implementation with the narrow pipeline via
//! limb-generic unpack/round/pack.
//!
//! Two execution shapes share the same stage implementations: the scalar
//! per-op pipeline ([`mul_bits`], the oracle) and the lane-fused batch
//! pipeline ([`FpuBatch`] over a [`SigBatchMultiplier`]), which peels
//! specials into a scalar sidecar and multiplies all remaining
//! significands in one tile-major batch call.

mod batch;
mod class;
mod format;
mod round;
mod softfp;
mod types;
#[cfg(test)]
mod tests;
#[cfg(test)]
mod golden;

pub use batch::{FpScalar, FpuBatch, SigBatchMultiplier};
pub use class::OpClass;
pub use format::{FpClass, FpFormat, Unpacked, BF16, DOUBLE, FP256, FP512, HALF, QUAD, SINGLE};
pub use round::RoundMode;
pub use softfp::{
    mul_bits, mul_bits_batch, mul_bits_batch_wide, mul_bits_wide, DirectMul, Flags, SigMultiplier,
    WideProd, WIDE_PROD_LIMBS,
};
pub use types::{Bf16, Fp128, Fp16, Fp32, Fp64};
