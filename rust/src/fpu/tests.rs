//! Oracle tests: the softfloat multiply must agree bit-for-bit with native
//! hardware IEEE-754 f32/f64 multiplication (round-to-nearest-even) across
//! uniform and adversarial ("nasty") bit patterns, and obey algebraic laws
//! in binary128 where no hardware oracle exists.

use super::*;
use crate::proput::forall;
use crate::wideint::U128;

fn soft_mul_f64(a: f64, b: f64) -> f64 {
    Fp64::from_f64(a).mul(Fp64::from_f64(b)).to_f64()
}

fn soft_mul_f32(a: f32, b: f32) -> f32 {
    Fp32::from_f32(a).mul(Fp32::from_f32(b)).to_f32()
}

/// Compare softfloat result against hardware for one f64 pair. NaN results
/// compare as "both NaN" (payloads are implementation-defined).
fn check_f64(a: f64, b: f64) {
    let hw = a * b;
    let sw = soft_mul_f64(a, b);
    if hw.is_nan() {
        assert!(sw.is_nan(), "a={a:e} b={b:e}: hw NaN, sw {sw:e}");
    } else {
        assert_eq!(
            sw.to_bits(),
            hw.to_bits(),
            "a={a:e}({:#x}) b={b:e}({:#x}): hw={hw:e}({:#x}) sw={sw:e}({:#x})",
            a.to_bits(),
            b.to_bits(),
            hw.to_bits(),
            sw.to_bits()
        );
    }
}

fn check_f32(a: f32, b: f32) {
    let hw = a * b;
    let sw = soft_mul_f32(a, b);
    if hw.is_nan() {
        assert!(sw.is_nan(), "a={a:e} b={b:e}: hw NaN, sw {sw:e}");
    } else {
        assert_eq!(sw.to_bits(), hw.to_bits(), "a={a:e} b={b:e}: hw={hw:e} sw={sw:e}");
    }
}

#[test]
fn f64_simple_values() {
    check_f64(1.5, 2.0);
    check_f64(0.1, 0.2);
    check_f64(-3.7, 1e18);
    check_f64(1e308, 10.0); // overflow
    check_f64(1e-308, 1e-10); // underflow to subnormal
    check_f64(f64::MIN_POSITIVE, 0.5);
    check_f64(0.0, -5.0);
    check_f64(-0.0, 5.0);
}

#[test]
fn f64_specials() {
    check_f64(f64::INFINITY, 2.0);
    check_f64(f64::NEG_INFINITY, -2.0);
    check_f64(f64::INFINITY, 0.0); // invalid -> NaN
    check_f64(f64::NAN, 1.0);
    check_f64(1.0, f64::NAN);
    check_f64(f64::INFINITY, f64::INFINITY);
}

#[test]
fn f64_subnormal_boundaries() {
    let min_sub = f64::from_bits(1);
    let max_sub = f64::from_bits(0x000F_FFFF_FFFF_FFFF);
    check_f64(min_sub, 0.5); // underflows to zero
    check_f64(min_sub, 1.5);
    check_f64(max_sub, 1.0000000001);
    check_f64(max_sub, 2.0); // subnormal * 2 -> normal
    check_f64(min_sub, 1e300);
    check_f64(max_sub, max_sub);
}

#[test]
fn f64_rounding_carry_chain() {
    // Significand all-ones forces the round-up carry path.
    let a = f64::from_bits(0x3FEF_FFFF_FFFF_FFFF);
    check_f64(a, a);
    check_f64(a, 1.0 + f64::EPSILON);
}

#[test]
fn f64_uniform_property() {
    forall(0x100, 20_000, |rng| {
        let a = f64::from_bits(rng.next_u64());
        let b = f64::from_bits(rng.next_u64());
        check_f64(a, b);
    });
}

#[test]
fn f64_nasty_property() {
    forall(0x101, 30_000, |rng| {
        let a = f64::from_bits(rng.nasty_bits64());
        let b = f64::from_bits(rng.nasty_bits64());
        check_f64(a, b);
    });
}

#[test]
fn f32_uniform_property() {
    forall(0x102, 20_000, |rng| {
        let a = f32::from_bits(rng.next_u32());
        let b = f32::from_bits(rng.next_u32());
        check_f32(a, b);
    });
}

#[test]
fn f32_nasty_property() {
    forall(0x103, 30_000, |rng| {
        let a = f32::from_bits(rng.nasty_bits32());
        let b = f32::from_bits(rng.nasty_bits32());
        check_f32(a, b);
    });
}

#[test]
fn f64_directed_rounding_brackets_exact() {
    // down <= exact <= up, and they differ by at most 1 ulp.
    forall(0x104, 5_000, |rng| {
        let a = f64::from_bits(rng.nasty_bits64());
        let b = f64::from_bits(rng.nasty_bits64());
        if !(a.is_finite() && b.is_finite()) {
            return;
        }
        let fa = Fp64::from_f64(a);
        let fb = Fp64::from_f64(b);
        let (dn, _) = fa.mul_with(fb, RoundMode::TowardNegative, &mut DirectMul);
        let (up, _) = fa.mul_with(fb, RoundMode::TowardPositive, &mut DirectMul);
        let (ne, _) = fa.mul_with(fb, RoundMode::NearestEven, &mut DirectMul);
        let (dn, up, ne) = (dn.to_f64(), up.to_f64(), ne.to_f64());
        if dn.is_nan() {
            return;
        }
        assert!(dn <= up, "a={a:e} b={b:e} dn={dn:e} up={up:e}");
        assert!(dn <= ne && ne <= up, "a={a:e} b={b:e}");
    });
}

#[test]
fn f64_toward_zero_magnitude() {
    // |RTZ result| <= |RNE result| always.
    forall(0x105, 5_000, |rng| {
        let a = f64::from_bits(rng.nasty_bits64());
        let b = f64::from_bits(rng.nasty_bits64());
        if !(a.is_finite() && b.is_finite()) {
            return;
        }
        let fa = Fp64::from_f64(a);
        let fb = Fp64::from_f64(b);
        let (tz, _) = fa.mul_with(fb, RoundMode::TowardZero, &mut DirectMul);
        let (ne, _) = fa.mul_with(fb, RoundMode::NearestEven, &mut DirectMul);
        if tz.to_f64().is_nan() {
            return;
        }
        assert!(tz.to_f64().abs() <= ne.to_f64().abs());
    });
}

#[test]
fn flags_inexact_overflow_underflow() {
    let fa = Fp64::from_f64(1e308);
    let (r, fl) = fa.mul_with(Fp64::from_f64(10.0), RoundMode::NearestEven, &mut DirectMul);
    assert_eq!(r.to_f64(), f64::INFINITY);
    assert!(fl.overflow && fl.inexact);

    let (r, fl) = Fp64::from_f64(1e-308)
        .mul_with(Fp64::from_f64(1e-10), RoundMode::NearestEven, &mut DirectMul);
    assert!(r.to_f64().is_subnormal() || r.to_f64() == 0.0);
    assert!(fl.underflow && fl.inexact);

    let (_, fl) =
        Fp64::from_f64(1.5).mul_with(Fp64::from_f64(2.0), RoundMode::NearestEven, &mut DirectMul);
    assert_eq!(fl, Flags::default());

    let (r, fl) = Fp64::from_f64(f64::INFINITY)
        .mul_with(Fp64::from_f64(0.0), RoundMode::NearestEven, &mut DirectMul);
    assert!(r.is_nan());
    assert!(fl.invalid);
}

#[test]
fn flags_snan_invalid() {
    let snan = Fp64(0x7FF0_0000_0000_0001);
    let (r, fl) = snan.mul_with(Fp64::from_f64(1.0), RoundMode::NearestEven, &mut DirectMul);
    assert!(r.is_nan());
    assert!(fl.invalid);
    // Quiet NaN input: NaN result but NOT invalid.
    let qnan = Fp64::from_f64(f64::NAN);
    let (r, fl) = qnan.mul_with(Fp64::from_f64(1.0), RoundMode::NearestEven, &mut DirectMul);
    assert!(r.is_nan());
    assert!(!fl.invalid);
}

#[test]
fn overflow_directed_modes_saturate() {
    let fa = Fp64::from_f64(1e308);
    let fb = Fp64::from_f64(10.0);
    let (r, _) = fa.mul_with(fb, RoundMode::TowardZero, &mut DirectMul);
    assert_eq!(r.to_f64(), f64::MAX);
    let (r, _) = fa.mul_with(fb, RoundMode::TowardNegative, &mut DirectMul);
    assert_eq!(r.to_f64(), f64::MAX);
    let (r, _) = fa.mul_with(fb, RoundMode::TowardPositive, &mut DirectMul);
    assert_eq!(r.to_f64(), f64::INFINITY);
    // Negative product mirror-image.
    let (r, _) = fa.mul_with(Fp64::from_f64(-10.0), RoundMode::TowardPositive, &mut DirectMul);
    assert_eq!(r.to_f64(), f64::MIN);
    let (r, _) = fa.mul_with(Fp64::from_f64(-10.0), RoundMode::TowardNegative, &mut DirectMul);
    assert_eq!(r.to_f64(), f64::NEG_INFINITY);
}

// ------------------------------------------------------------------
// binary128: no hardware oracle — algebraic laws + exact-product cases.
// Golden vectors from an independent Python big-int model live in
// `golden.rs`.
// ------------------------------------------------------------------

#[test]
fn fp128_identity_and_sign_laws() {
    forall(0x110, 5_000, |rng| {
        let a = Fp128::from_f64(f64::from_bits(rng.nasty_bits64()));
        if a.is_nan() {
            return;
        }
        // x * 1 == x
        assert_eq!(a.mul(Fp128::ONE).0, a.0, "identity law");
        // x * 2 == exact scaling (exponent bump) for normals well in range
        let u = QUAD.unpack(U128::from_u128(a.0));
        if u.class == FpClass::Normal && u.exp < QUAD.emax() - 1 {
            let doubled = a.mul(Fp128::TWO);
            let ud = QUAD.unpack(U128::from_u128(doubled.0));
            assert_eq!(ud.exp, u.exp + 1);
            assert_eq!(ud.sig, u.sig);
        }
    });
}

#[test]
fn fp128_commutative() {
    forall(0x111, 5_000, |rng| {
        let a = Fp128::from_f64(f64::from_bits(rng.nasty_bits64()));
        let b = Fp128::from_f64(f64::from_bits(rng.nasty_bits64()));
        let ab = a.mul(b);
        let ba = b.mul(a);
        if ab.is_nan() {
            assert!(ba.is_nan());
        } else {
            assert_eq!(ab.0, ba.0);
        }
    });
}

#[test]
fn fp128_exact_products_match_f64() {
    // When both operands have <= 26 significant bits, the product has <= 52
    // and is exact in BOTH binary64 and binary128 — so the quad product must
    // equal the widened f64 product bit-for-bit.
    forall(0x112, 10_000, |rng| {
        let a = (rng.below(1 << 26) as i64 - (1 << 25)) as f64;
        let b = (rng.below(1 << 26) as i64 - (1 << 25)) as f64;
        let qa = Fp128::from_f64(a);
        let qb = Fp128::from_f64(b);
        let qprod = qa.mul(qb);
        let expect = Fp128::from_f64(a * b);
        assert_eq!(qprod.0, expect.0, "a={a} b={b}");
    });
}

#[test]
fn fp128_f64_products_widen_exactly() {
    // Any two f64 values multiply exactly in binary128 when the f64 multiply
    // itself is exact (106-bit product always fits 113 bits) — compare the
    // quad product against the widened f64 product whenever the f64 multiply
    // reports exactness via a round-trip check.
    forall(0x113, 10_000, |rng| {
        let a = f64::from_bits(rng.nasty_bits64());
        let b = f64::from_bits(rng.nasty_bits64());
        if !a.is_finite() || !b.is_finite() {
            return;
        }
        let (sw, fl) =
            Fp64::from_f64(a).mul_with(Fp64::from_f64(b), RoundMode::NearestEven, &mut DirectMul);
        if fl.inexact || fl.overflow || fl.underflow {
            return;
        }
        // Exact in f64 -> quad must agree after widening.
        let qprod = Fp128::from_f64(a).mul(Fp128::from_f64(b));
        assert_eq!(qprod.0, Fp128::from_f64(sw.to_f64()).0, "a={a:e} b={b:e}");
    });
}

#[test]
fn fp128_specials() {
    let inf = Fp128(QUAD.inf(false).as_u128());
    let zero = Fp128(0);
    assert!(inf.mul(zero).is_nan());
    assert_eq!(inf.mul(Fp128::TWO).0, inf.0);
    let neg_two = Fp128(Fp128::TWO.0 | (1u128 << 127));
    assert_eq!(inf.mul(neg_two).0, QUAD.inf(true).as_u128());
    // -0 * 2 = -0
    let neg_zero = Fp128(1u128 << 127);
    assert_eq!(neg_zero.mul(Fp128::TWO).0, neg_zero.0);
}

#[test]
fn fp128_overflow_underflow() {
    let max = Fp128(QUAD.max_finite(false).as_u128());
    let (r, fl) = max.mul_with(Fp128::TWO, RoundMode::NearestEven, &mut DirectMul);
    assert_eq!(r.0, QUAD.inf(false).as_u128());
    assert!(fl.overflow);
    // min-normal * 0.5 -> subnormal (exact halving: inexact=false)
    let min_normal = Fp128(1u128 << 112);
    let half = Fp128(0x3FFE_0000_0000_0000_0000_0000_0000_0000);
    let (r, fl) = min_normal.mul_with(half, RoundMode::NearestEven, &mut DirectMul);
    assert_eq!(QUAD.unpack(U128::from_u128(r.0)).class, FpClass::Subnormal);
    assert!(!fl.inexact);
    assert!(!fl.underflow); // exact subnormal: no underflow flag
}

#[test]
fn all_round_modes_run_every_class() {
    for mode in RoundMode::ALL {
        let (r, _) = Bf16::from_f32(1.5).mul_with(Bf16::from_f32(2.5), mode, &mut DirectMul);
        assert_eq!(r.to_f32(), 3.75); // exact in every mode
        let (r, _) = Fp16::from_f32(1.5).mul_with(Fp16::from_f32(2.5), mode, &mut DirectMul);
        assert_eq!(r.to_f32(), 3.75);
        let (r, _) = Fp32::from_f32(1.1).mul_with(Fp32::from_f32(2.2), mode, &mut DirectMul);
        assert!((r.to_f32() - 2.42).abs() < 1e-5);
        let (r, _) = Fp64::from_f64(1.1).mul_with(Fp64::from_f64(2.2), mode, &mut DirectMul);
        assert!((r.to_f64() - 2.42).abs() < 1e-12);
        let (r, _) = Fp128::from_f64(1.5).mul_with(Fp128::from_f64(2.5), mode, &mut DirectMul);
        assert_eq!(r.to_f64_lossy(), 3.75);
    }
}

#[test]
fn fp16_exhaustive_vs_f32_oracle_sample_plane() {
    // Exhaustive over one full operand plane: every binary16 value times a
    // fixed set of multipliers, against the exact-f32-product oracle.
    for b in [0x3C00u16, 0x0001, 0x7BFF, 0x0400, 0xBC01] {
        for a_bits in 0..=u16::MAX {
            let a = Fp16(a_bits);
            let got = a.mul(Fp16(b));
            let want = Fp16::from_f32(a.to_f32() * Fp16(b).to_f32());
            if want.is_nan() {
                assert!(got.is_nan(), "a={a_bits:#06x} b={b:#06x}");
            } else {
                assert_eq!(got.0, want.0, "a={a_bits:#06x} b={b:#06x}");
            }
        }
    }
}
