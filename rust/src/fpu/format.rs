//! IEEE-754 binary interchange format descriptors and pack/unpack.
//!
//! All packed values travel as [`U128`] regardless of precision (binary32
//! occupies the low 32 bits, etc.), so one generic code path serves every
//! format. This mirrors the paper's framing: the *only* thing that changes
//! between precisions is the significand width handed to the multiplier
//! array (24 / 53 / 113 bits).

use crate::wideint::{PackedBits, Wide, U128};

/// Floating-point datum class after unpacking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FpClass {
    /// ±0
    Zero,
    /// Exponent field 0, fraction non-zero.
    Subnormal,
    /// Ordinary normalized value.
    Normal,
    /// ±∞
    Infinite,
    /// Quiet or signalling NaN.
    Nan,
}

/// An IEEE-754 binary interchange format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FpFormat {
    /// Human name ("single", "double", "quad").
    pub name: &'static str,
    /// Exponent field width in bits.
    pub exp_bits: u32,
    /// Fraction (trailing significand) width in bits, excluding hidden bit.
    pub frac_bits: u32,
}

/// bfloat16: the ML truncated-single format — 8-bit significand. Sub-single:
/// the whole significand product fits one `9x9` CIVP block.
pub const BF16: FpFormat = FpFormat { name: "bf16", exp_bits: 8, frac_bits: 7 };
/// binary16 ("half") — 11-bit significand. Sub-single: tiles onto the `24x9`
/// CIVP block (one whole 11-bit operand on the 24 port, the other split
/// `[9, 2]` across the 9 port).
pub const HALF: FpFormat = FpFormat { name: "half", exp_bits: 5, frac_bits: 10 };
/// binary32: the paper's "single precision" — 24-bit significand.
pub const SINGLE: FpFormat = FpFormat { name: "single", exp_bits: 8, frac_bits: 23 };
/// binary64: Fig. 1 — 53-bit significand.
pub const DOUBLE: FpFormat = FpFormat { name: "double", exp_bits: 11, frac_bits: 52 };
/// binary128: Fig. 3 — 113-bit significand.
pub const QUAD: FpFormat = FpFormat { name: "quad", exp_bits: 15, frac_bits: 112 };
/// binary256: IEEE interchange formula (exp = 4·log2(k) − 13) — 237-bit
/// significand. First format whose packed value no longer fits `U128`;
/// wide operands travel as [`PackedBits`] through the `_w` entry points.
pub const FP256: FpFormat = FpFormat { name: "fp256", exp_bits: 19, frac_bits: 236 };
/// binary512 by the same interchange formula — 489-bit significand. The
/// stress case for the sub-quadratic Karatsuba tile planner: naive
/// all-pairs tiling is quadratic in the 26-chunk significand.
pub const FP512: FpFormat = FpFormat { name: "fp512", exp_bits: 23, frac_bits: 488 };

impl FpFormat {
    /// Total storage width (1 + exp_bits + frac_bits).
    pub const fn total_bits(&self) -> u32 {
        1 + self.exp_bits + self.frac_bits
    }
    /// Significand width including the hidden bit — the integer multiplier
    /// width the paper reasons about (24 / 53 / 113).
    pub const fn sig_bits(&self) -> u32 {
        self.frac_bits + 1
    }
    /// Exponent bias.
    pub const fn bias(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }
    /// Minimum unbiased exponent of a normal number.
    pub const fn emin(&self) -> i32 {
        1 - self.bias()
    }
    /// Maximum unbiased exponent of a finite number.
    pub const fn emax(&self) -> i32 {
        self.bias()
    }
    /// All-ones biased exponent (Inf/NaN marker).
    pub const fn exp_mask(&self) -> u32 {
        (1 << self.exp_bits) - 1
    }

    /// Positive infinity bit pattern.
    pub fn inf(&self, sign: bool) -> U128 {
        let mut v = U128::from_u64(self.exp_mask() as u64).shl(self.frac_bits);
        if sign {
            v.set_bit(self.total_bits() - 1);
        }
        v
    }

    /// Canonical quiet NaN (sign 0, exponent all ones, MSB of fraction set).
    pub fn quiet_nan(&self) -> U128 {
        let mut v = self.inf(false);
        v.set_bit(self.frac_bits - 1);
        v
    }

    /// Largest finite value with the given sign.
    pub fn max_finite(&self, sign: bool) -> U128 {
        // exponent emax (biased exp_mask-1), fraction all ones
        let exp = (self.exp_mask() - 1) as u64;
        let mut v = U128::from_u64(exp).shl(self.frac_bits);
        let frac = U128::ONE.shl(self.frac_bits).wrapping_sub(&U128::ONE);
        v = v.or(&frac);
        if sign {
            v.set_bit(self.total_bits() - 1);
        }
        v
    }

    /// Positive one's bit pattern (biased exponent = bias, zero fraction) —
    /// the registry-derived constant tests and examples use instead of
    /// hand-mirrored per-format tables.
    pub const fn one(&self) -> u128 {
        (self.bias() as u128) << self.frac_bits
    }

    /// ±0 bit pattern.
    pub fn zero(&self, sign: bool) -> U128 {
        if sign {
            let mut v = U128::ZERO;
            v.set_bit(self.total_bits() - 1);
            v
        } else {
            U128::ZERO
        }
    }

    /// Unpack a bit pattern into fields + class.
    pub fn unpack(&self, bits: U128) -> Unpacked {
        self.unpack_g(bits)
    }

    /// Limb-generic unpack: same field logic for any operand word wide
    /// enough to hold `total_bits()` (`U128` for the narrow registry,
    /// [`PackedBits`] for Fp256/Fp512).
    pub fn unpack_g<const N: usize>(&self, bits: Wide<N>) -> Unpacked<N> {
        debug_assert!(
            bits.bit_len() <= self.total_bits(),
            "packed value wider than format"
        );
        let sign = bits.bit(self.total_bits() - 1);
        let biased = bits.extract_u64(self.frac_bits, self.exp_bits) as u32;
        let frac = bits.mask_low(self.frac_bits);
        let (class, exp, sig) = if biased == self.exp_mask() {
            if frac.is_zero() {
                (FpClass::Infinite, 0, Wide::ZERO)
            } else {
                (FpClass::Nan, 0, frac)
            }
        } else if biased == 0 {
            if frac.is_zero() {
                (FpClass::Zero, 0, Wide::ZERO)
            } else {
                // Subnormal: significand has no hidden bit; report the raw
                // fraction with exponent emin. `normalize()` shifts it up.
                (FpClass::Subnormal, self.emin(), frac)
            }
        } else {
            let mut sig = frac;
            sig.set_bit(self.frac_bits); // hidden one
            (FpClass::Normal, biased as i32 - self.bias(), sig)
        };
        Unpacked { sign, class, exp, sig }
    }

    /// Pack fields back into a bit pattern. `exp` is the unbiased exponent
    /// of a value whose significand `sig` carries the hidden bit at
    /// position `frac_bits` (normal) or is below it (subnormal, `exp ==
    /// emin`). No rounding happens here.
    pub fn pack(&self, sign: bool, exp: i32, sig: U128) -> U128 {
        self.pack_g(sign, exp, sig)
    }

    /// Limb-generic pack — see [`FpFormat::pack`].
    pub fn pack_g<const N: usize>(&self, sign: bool, exp: i32, sig: Wide<N>) -> Wide<N> {
        debug_assert!(sig.bit_len() <= self.sig_bits());
        let hidden = Wide::ONE.shl(self.frac_bits);
        let (biased, frac) = if sig.cmp_wide(&hidden) == core::cmp::Ordering::Less {
            // Subnormal or zero.
            debug_assert!(sig.is_zero() || exp == self.emin(), "subnormal pack at wrong exp");
            (0u64, sig)
        } else {
            let biased = (exp + self.bias()) as u64;
            debug_assert!(biased >= 1 && biased < self.exp_mask() as u64);
            (biased, sig.wrapping_sub(&hidden))
        };
        let mut v = Wide::from_u64(biased).shl(self.frac_bits).or(&frac);
        if sign {
            v.set_bit(self.total_bits() - 1);
        }
        v
    }

    /// True if the pattern is a signalling NaN (NaN with quiet bit clear).
    pub fn is_signaling_nan(&self, bits: U128) -> bool {
        self.is_signaling_nan_g(bits)
    }

    /// Limb-generic signalling-NaN test — see [`FpFormat::is_signaling_nan`].
    pub fn is_signaling_nan_g<const N: usize>(&self, bits: Wide<N>) -> bool {
        let u = self.unpack_g(bits);
        u.class == FpClass::Nan && !bits.bit(self.frac_bits - 1)
    }

    /// Positive infinity as a wide packed operand.
    pub fn inf_w(&self, sign: bool) -> PackedBits {
        let mut v = PackedBits::from_u64(self.exp_mask() as u64).shl(self.frac_bits);
        if sign {
            v.set_bit(self.total_bits() - 1);
        }
        v
    }

    /// Canonical quiet NaN as a wide packed operand.
    pub fn quiet_nan_w(&self) -> PackedBits {
        let mut v = self.inf_w(false);
        v.set_bit(self.frac_bits - 1);
        v
    }

    /// Largest finite value as a wide packed operand.
    pub fn max_finite_w(&self, sign: bool) -> PackedBits {
        let exp = (self.exp_mask() - 1) as u64;
        let mut v = PackedBits::from_u64(exp).shl(self.frac_bits);
        let frac = PackedBits::ONE.shl(self.frac_bits).wrapping_sub(&PackedBits::ONE);
        v = v.or(&frac);
        if sign {
            v.set_bit(self.total_bits() - 1);
        }
        v
    }

    /// ±0 as a wide packed operand.
    pub fn zero_w(&self, sign: bool) -> PackedBits {
        let mut v = PackedBits::ZERO;
        if sign {
            v.set_bit(self.total_bits() - 1);
        }
        v
    }

    /// Positive one as a wide packed operand — the wide-format analog of
    /// [`FpFormat::one`], whose `u128` return cannot hold Fp256/Fp512.
    pub fn one_w(&self) -> PackedBits {
        PackedBits::from_u64(self.bias() as u64).shl(self.frac_bits)
    }
}

/// Unpacked floating-point datum. `N` is the operand limb count: the
/// default (`N = 2`, a `U128` significand) serves every narrow registry
/// class; wide formats unpack through [`FpFormat::unpack_g`] into
/// `Unpacked<8>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Unpacked<const N: usize = 2> {
    /// Sign bit (true = negative).
    pub sign: bool,
    /// Datum class.
    pub class: FpClass,
    /// Unbiased exponent (valid for Normal/Subnormal).
    pub exp: i32,
    /// Significand. Normal: hidden bit set at `frac_bits`. Subnormal: raw
    /// fraction. NaN: payload.
    pub sig: Wide<N>,
}

impl<const N: usize> Unpacked<N> {
    /// Normalize a subnormal into `Normal` representation (hidden bit at
    /// `frac_bits`), adjusting the exponent. No-op for normals.
    pub fn normalize(&self, fmt: &FpFormat) -> Unpacked<N> {
        match self.class {
            FpClass::Subnormal => {
                let shift = fmt.sig_bits() - self.sig.bit_len();
                Unpacked {
                    sign: self.sign,
                    class: FpClass::Normal,
                    exp: self.exp - shift as i32,
                    sig: self.sig.shl(shift),
                }
            }
            _ => *self,
        }
    }
}

#[cfg(test)]
mod format_tests {
    use super::*;

    #[test]
    fn sub_single_field_widths() {
        // binary16: 1 + 5 + 10; hidden bit -> 11-bit significand.
        assert_eq!(HALF.total_bits(), 16);
        assert_eq!(HALF.sig_bits(), 11);
        assert_eq!(HALF.bias(), 15);
        assert_eq!(HALF.emin(), -14);
        assert_eq!(HALF.emax(), 15);
        // bfloat16: 1 + 8 + 7; hidden bit -> 8-bit significand. Same
        // exponent range as binary32.
        assert_eq!(BF16.total_bits(), 16);
        assert_eq!(BF16.sig_bits(), 8);
        assert_eq!(BF16.bias(), 127);
        assert_eq!(BF16.emin(), SINGLE.emin());
    }

    #[test]
    fn sub_single_special_patterns() {
        // binary16 constants: +inf 0x7C00, qNaN 0x7E00, max 0x7BFF.
        assert_eq!(HALF.inf(false).as_u64(), 0x7C00);
        assert_eq!(HALF.quiet_nan().as_u64(), 0x7E00);
        assert_eq!(HALF.max_finite(false).as_u64(), 0x7BFF);
        assert_eq!(HALF.zero(true).as_u64(), 0x8000);
        // bfloat16 constants: +inf 0x7F80, qNaN 0x7FC0, max 0x7F7F.
        assert_eq!(BF16.inf(false).as_u64(), 0x7F80);
        assert_eq!(BF16.quiet_nan().as_u64(), 0x7FC0);
        assert_eq!(BF16.max_finite(false).as_u64(), 0x7F7F);
        // 1.0 derived from the registry format, every class.
        assert_eq!(HALF.one(), 0x3C00);
        assert_eq!(BF16.one(), 0x3F80);
        assert_eq!(SINGLE.one(), 0x3F80_0000);
        assert_eq!(DOUBLE.one(), 0x3FF0_0000_0000_0000);
        assert_eq!(QUAD.one(), 0x3FFF_u128 << 112);
    }

    #[test]
    fn sub_single_unpack_pack_roundtrip() {
        for fmt in [&HALF, &BF16] {
            for bits in 0..(1u64 << 16) {
                let raw = U128::from_u64(bits);
                let u = fmt.unpack(raw);
                if u.class == FpClass::Nan {
                    continue; // NaN payloads canonicalize
                }
                assert_eq!(fmt.pack(u.sign, u.exp, u.sig), raw, "{} {bits:#06x}", fmt.name);
            }
        }
    }

    #[test]
    fn field_widths_match_paper_figures() {
        // Fig. 1: double = 1 + 11 + 52; hidden bit -> 53-bit significand.
        assert_eq!(DOUBLE.total_bits(), 64);
        assert_eq!(DOUBLE.sig_bits(), 53);
        assert_eq!(DOUBLE.bias(), 1023);
        // Fig. 3: quad = 1 + 15 + 112; hidden bit -> 113 bits.
        assert_eq!(QUAD.total_bits(), 128);
        assert_eq!(QUAD.sig_bits(), 113);
        assert_eq!(QUAD.bias(), 16383);
        // Single: 24-bit significand drives the 24x24 block claim.
        assert_eq!(SINGLE.total_bits(), 32);
        assert_eq!(SINGLE.sig_bits(), 24);
        assert_eq!(SINGLE.bias(), 127);
    }

    #[test]
    fn unpack_pack_roundtrip_f64() {
        for v in [0.0f64, -0.0, 1.0, -1.5, 1e-300, 1e300, f64::MIN_POSITIVE] {
            let bits = U128::from_u64(v.to_bits());
            let u = DOUBLE.unpack(bits);
            let repacked = DOUBLE.pack(u.sign, u.exp, u.sig);
            assert_eq!(repacked.as_u64(), v.to_bits(), "roundtrip {v}");
        }
    }

    #[test]
    fn classify_specials() {
        assert_eq!(DOUBLE.unpack(U128::from_u64(f64::NAN.to_bits())).class, FpClass::Nan);
        assert_eq!(
            DOUBLE.unpack(U128::from_u64(f64::INFINITY.to_bits())).class,
            FpClass::Infinite
        );
        assert_eq!(DOUBLE.unpack(U128::from_u64(0)).class, FpClass::Zero);
        assert_eq!(DOUBLE.unpack(U128::from_u64(1)).class, FpClass::Subnormal);
        assert_eq!(DOUBLE.unpack(U128::from_u64(1.0f64.to_bits())).class, FpClass::Normal);
    }

    #[test]
    fn normalize_subnormal() {
        // smallest positive subnormal: sig = 1, normalizes to hidden bit with
        // exponent emin - 52.
        let u = DOUBLE.unpack(U128::from_u64(1));
        let n = u.normalize(&DOUBLE);
        assert_eq!(n.class, FpClass::Normal);
        assert_eq!(n.sig.bit_len(), 53);
        assert_eq!(n.exp, DOUBLE.emin() - 52);
    }

    #[test]
    fn special_patterns() {
        assert_eq!(DOUBLE.inf(false).as_u64(), f64::INFINITY.to_bits());
        assert_eq!(DOUBLE.inf(true).as_u64(), f64::NEG_INFINITY.to_bits());
        assert_eq!(DOUBLE.max_finite(false).as_u64(), f64::MAX.to_bits());
        assert_eq!(DOUBLE.zero(true).as_u64(), (-0.0f64).to_bits());
        assert!(f64::from_bits(DOUBLE.quiet_nan().as_u64()).is_nan());
        assert_eq!(SINGLE.inf(false).as_u64(), f32::INFINITY.to_bits() as u64);
        assert_eq!(SINGLE.max_finite(false).as_u64(), f32::MAX.to_bits() as u64);
    }

    #[test]
    fn wide_field_widths_match_interchange_formula() {
        // binary256: 1 + 19 + 236; hidden bit -> 237-bit significand.
        assert_eq!(FP256.total_bits(), 256);
        assert_eq!(FP256.sig_bits(), 237);
        assert_eq!(FP256.bias(), 262_143);
        // binary512: 1 + 23 + 488; hidden bit -> 489-bit significand.
        assert_eq!(FP512.total_bits(), 512);
        assert_eq!(FP512.sig_bits(), 489);
        assert_eq!(FP512.bias(), 4_194_303);
    }

    #[test]
    fn wide_special_patterns_and_roundtrip() {
        for fmt in [&FP256, &FP512] {
            assert_eq!(fmt.unpack_g(fmt.inf_w(false)).class, FpClass::Infinite);
            assert_eq!(fmt.unpack_g(fmt.quiet_nan_w()).class, FpClass::Nan);
            assert!(!fmt.is_signaling_nan_g(fmt.quiet_nan_w()));
            let mut snan = fmt.inf_w(false);
            snan.set_bit(0);
            assert!(fmt.is_signaling_nan_g(snan), "{}", fmt.name);
            // 1.0: unbiased exponent 0, significand = hidden bit alone.
            let one = fmt.unpack_g(fmt.one_w());
            assert_eq!(one.class, FpClass::Normal);
            assert_eq!(one.exp, 0);
            assert_eq!(one.sig.bit_len(), fmt.sig_bits());
            // max_finite unpacks at emax and repacks bit-exactly.
            let mf = fmt.max_finite_w(true);
            let u = fmt.unpack_g(mf);
            assert_eq!(u.class, FpClass::Normal);
            assert_eq!(u.exp, fmt.emax());
            assert_eq!(fmt.pack_g(u.sign, u.exp, u.sig), mf, "{}", fmt.name);
            // Smallest subnormal normalizes exactly like the narrow path.
            let tiny = fmt.unpack_g(PackedBits::ONE).normalize(fmt);
            assert_eq!(tiny.class, FpClass::Normal);
            assert_eq!(tiny.exp, fmt.emin() - fmt.frac_bits as i32);
            assert!(fmt.zero_w(true).bit(fmt.total_bits() - 1));
            assert!(fmt.zero_w(false).is_zero());
        }
    }

    #[test]
    fn snan_detection() {
        // f64 sNaN: exponent all ones, quiet bit clear, payload non-zero.
        let snan = 0x7FF0_0000_0000_0001u64;
        assert!(DOUBLE.is_signaling_nan(U128::from_u64(snan)));
        assert!(!DOUBLE.is_signaling_nan(U128::from_u64(f64::NAN.to_bits())));
        assert!(!DOUBLE.is_signaling_nan(U128::from_u64(f64::INFINITY.to_bits())));
    }
}
