//! The lane-fused batched FP pipeline: batched unpack/classify with a
//! scalar specials sidecar, one lane-wise significand multiply per batch,
//! and batched normalize/round.
//!
//! [`super::mul_bits`] runs the whole IEEE pipeline per element — unpack,
//! special lattice, significand product (one virtual
//! [`SigMultiplier`](super::SigMultiplier) call), round, pack. Correct, but on a batch it re-dispatches the
//! multiplier and interleaves the branchy special handling with the
//! numeric loop for every single element. [`FpuBatch`] restructures the
//! batch into the stages the hardware pipeline of the paper (and the
//! deep-pipelined FPGA FP cores in the related work) actually has:
//!
//! 1. **unpack/classify** — one pass over the operands; elements with a
//!    special operand (NaN, ±∞, ±0) are resolved immediately through the
//!    shared [`special_product`] lattice (the *scalar sidecar*), while
//!    finite×finite elements deposit their normalized significands into
//!    reusable SoA-feeding buffers, so the multiply stage sees no
//!    branches;
//! 2. **significand multiply** — one [`SigBatchMultiplier::mul_sig_batch`]
//!    call for the whole batch. The decomposition implementation
//!    (`decomp::DecompMul`) routes this through `Plan::execute_lanes`,
//!    the tile-major SoA kernel;
//! 3. **normalize/round/pack** — one pass over the exact products through
//!    the shared [`finish_product`] stage, scattering results back to
//!    their original batch positions and OR-ing the flag union.
//!
//! Because stages 1 and 3 call the *same* helpers as the scalar pipeline
//! and stage 2 is pinned to the per-op multiplier by property tests, the
//! fused path is bit-for-bit identical to N× [`super::mul_bits`]
//! (`rust/tests/plan_equiv.rs`), specials, flags and all.

use super::format::{FpFormat, BF16, DOUBLE, HALF, QUAD, SINGLE};
use super::round::RoundMode;
use super::softfp::{
    finish_product, finish_product_w, special_product, special_product_w, DirectMul, Flags,
    WideProd, WIDE_PROD_LIMBS,
};
use super::types::{Bf16, Fp128, Fp16, Fp32, Fp64};
use crate::wideint::{mul_u128, PackedBits, U128, U256};

/// Batch counterpart of [`SigMultiplier`](super::SigMultiplier): the
/// exact integer multiplier for a whole batch of `width`-bit significand
/// pairs, writing the double-width products into `out` (cleared first).
///
/// Implementations: [`DirectMul`] (a widening multiply per element — the
/// oracle) and `decomp::DecompMul`, which executes the batch tile-major
/// through `Plan::execute_lanes` and accounts the block usage with one
/// scaled stats merge.
pub trait SigBatchMultiplier {
    /// Exact products of `a[i] × b[i]`, where `a[i], b[i] < 2^width`.
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    fn mul_sig_batch(&mut self, a: &[U128], b: &[U128], width: u32, out: &mut Vec<U256>);

    /// Exact products for wide significands (width up to 489). Default:
    /// one direct widening multiply per element — the oracle.
    /// `decomp::DecompMul` overrides it with per-element tile-plan
    /// execution (wide classes have no lane-fused SoA path; their
    /// parallelism lives in the tile DAG itself).
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    fn mul_sig_batch_wide(
        &mut self,
        a: &[PackedBits],
        b: &[PackedBits],
        width: u32,
        out: &mut Vec<WideProd>,
    ) {
        let _ = width;
        assert_eq!(a.len(), b.len(), "operand length mismatch");
        out.clear();
        out.reserve(a.len());
        for (x, y) in a.iter().zip(b) {
            out.push(x.mul_full::<WIDE_PROD_LIMBS>(y));
        }
    }
}

impl SigBatchMultiplier for DirectMul {
    fn mul_sig_batch(&mut self, a: &[U128], b: &[U128], _width: u32, out: &mut Vec<U256>) {
        assert_eq!(a.len(), b.len(), "operand length mismatch");
        out.clear();
        out.reserve(a.len());
        for (&x, &y) in a.iter().zip(b) {
            out.push(mul_u128(x, y));
        }
    }
}

/// A packed IEEE scalar the batched pipeline can process: one of
/// [`Bf16`], [`Fp16`], [`Fp32`], [`Fp64`], [`Fp128`] — one per
/// [`super::OpClass`]. Carries its format descriptor and the `u128`
/// bit-pattern conversions the generic surface needs.
pub trait FpScalar: Copy {
    /// The IEEE interchange format of this scalar.
    const FORMAT: FpFormat;
    /// Raw bit pattern in the low bits of a `u128`.
    fn to_bits_u128(self) -> u128;
    /// Rebuild from a packed bit pattern.
    fn from_bits_u128(bits: u128) -> Self;
}

impl FpScalar for Bf16 {
    const FORMAT: FpFormat = BF16;
    fn to_bits_u128(self) -> u128 {
        self.0 as u128
    }
    fn from_bits_u128(bits: u128) -> Self {
        Bf16(bits as u16)
    }
}

impl FpScalar for Fp16 {
    const FORMAT: FpFormat = HALF;
    fn to_bits_u128(self) -> u128 {
        self.0 as u128
    }
    fn from_bits_u128(bits: u128) -> Self {
        Fp16(bits as u16)
    }
}

impl FpScalar for Fp32 {
    const FORMAT: FpFormat = SINGLE;
    fn to_bits_u128(self) -> u128 {
        self.0 as u128
    }
    fn from_bits_u128(bits: u128) -> Self {
        Fp32(bits as u32)
    }
}

impl FpScalar for Fp64 {
    const FORMAT: FpFormat = DOUBLE;
    fn to_bits_u128(self) -> u128 {
        self.0 as u128
    }
    fn from_bits_u128(bits: u128) -> Self {
        Fp64(bits as u64)
    }
}

impl FpScalar for Fp128 {
    const FORMAT: FpFormat = QUAD;
    fn to_bits_u128(self) -> u128 {
        self.0
    }
    fn from_bits_u128(bits: u128) -> Self {
        Fp128(bits)
    }
}

/// Metadata one finite×finite element carries from the classify stage to
/// the finish stage.
struct LaneMeta {
    /// Index into the batch (and `out`).
    idx: u32,
    /// Result sign.
    sign: bool,
    /// Sum of the normalized operands' unbiased exponents.
    exp_sum: i32,
}

/// The lane-fused batch FP engine: owns a batch significand multiplier
/// plus the reusable stage buffers, so steady-state batches allocate
/// nothing (the coordinator keeps one `FpuBatch` per worker).
///
/// ```
/// use civp::decomp::{DecompMul, SchemeKind};
/// use civp::fpu::{Fp64, FpuBatch, RoundMode};
///
/// let mut fpu = FpuBatch::new(DecompMul::new(SchemeKind::Civp));
/// let a: Vec<Fp64> = [0.5, 3.0, f64::NAN].iter().map(|&v| Fp64::from_f64(v)).collect();
/// let b: Vec<Fp64> = [4.0, 0.25, 1.0].iter().map(|&v| Fp64::from_f64(v)).collect();
/// let mut out = Vec::new();
/// fpu.mul_batch(&a, &b, RoundMode::NearestEven, &mut out);
/// assert_eq!(out[0].to_f64(), 2.0);
/// assert_eq!(out[1].to_f64(), 0.75);
/// assert!(out[2].to_f64().is_nan()); // specials resolved in the sidecar
/// ```
pub struct FpuBatch<M> {
    m: M,
    sig_a: Vec<U128>,
    sig_b: Vec<U128>,
    prods: Vec<U256>,
    sig_aw: Vec<PackedBits>,
    sig_bw: Vec<PackedBits>,
    prods_w: Vec<WideProd>,
    meta: Vec<LaneMeta>,
    bits_a: Vec<u128>,
    bits_b: Vec<u128>,
    bits_out: Vec<u128>,
}

impl<M: SigBatchMultiplier> FpuBatch<M> {
    /// New engine around a batch significand multiplier.
    pub fn new(m: M) -> FpuBatch<M> {
        FpuBatch {
            m,
            sig_a: Vec::new(),
            sig_b: Vec::new(),
            prods: Vec::new(),
            sig_aw: Vec::new(),
            sig_bw: Vec::new(),
            prods_w: Vec::new(),
            meta: Vec::new(),
            bits_a: Vec::new(),
            bits_b: Vec::new(),
            bits_out: Vec::new(),
        }
    }

    /// The underlying significand multiplier (e.g. to read
    /// `DecompMul::stats`).
    pub fn multiplier(&self) -> &M {
        &self.m
    }

    /// Mutable access to the underlying multiplier.
    pub fn multiplier_mut(&mut self) -> &mut M {
        &mut self.m
    }

    /// Multiply a typed batch elementwise through the fused pipeline,
    /// writing into `out` (cleared first) and returning the union of the
    /// exception flags. Bit-identical to calling
    /// [`Fp64::mul_with`](crate::fpu::Fp64::mul_with) (etc.) per element
    /// with the equivalent scalar multiplier.
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    pub fn mul_batch<T: FpScalar>(
        &mut self,
        a: &[T],
        b: &[T],
        mode: RoundMode,
        out: &mut Vec<T>,
    ) -> Flags {
        // Move the bit scratch out so `self` stays borrowable for the
        // core call (plain Vec moves — no allocation, no copies beyond
        // the packing itself).
        let mut bits_a = std::mem::take(&mut self.bits_a);
        let mut bits_b = std::mem::take(&mut self.bits_b);
        let mut bits_out = std::mem::take(&mut self.bits_out);
        bits_a.clear();
        bits_a.extend(a.iter().map(|v| v.to_bits_u128()));
        bits_b.clear();
        bits_b.extend(b.iter().map(|v| v.to_bits_u128()));
        let flags = self.mul_batch_bits(&T::FORMAT, &bits_a, &bits_b, mode, &mut bits_out);
        out.clear();
        out.extend(bits_out.iter().map(|&v| T::from_bits_u128(v)));
        self.bits_a = bits_a;
        self.bits_b = bits_b;
        self.bits_out = bits_out;
        flags
    }

    /// The bits-level entry point (what the coordinator's native backend
    /// calls): multiply packed `fmt` patterns elementwise, writing packed
    /// results into `out` (cleared first) and returning the flag union.
    ///
    /// The three stages described in the module docs run here: classify
    /// with the specials sidecar, one batched significand multiply, then
    /// the shared finish stage scattering results back into place.
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    pub fn mul_batch_bits(
        &mut self,
        fmt: &FpFormat,
        a: &[u128],
        b: &[u128],
        mode: RoundMode,
        out: &mut Vec<u128>,
    ) -> Flags {
        assert_eq!(a.len(), b.len(), "operand length mismatch");
        assert!(a.len() <= u32::MAX as usize, "batch too large");
        out.clear();
        out.resize(a.len(), 0);
        self.sig_a.clear();
        self.sig_b.clear();
        self.meta.clear();
        let mut flags = Flags::default();

        // --- Stage 1: unpack/classify; specials to the scalar sidecar ---
        for (i, (&xa, &xb)) in a.iter().zip(b).enumerate() {
            let pa = U128::from_u128(xa);
            let pb = U128::from_u128(xb);
            let ua = fmt.unpack(pa);
            let ub = fmt.unpack(pb);
            let sign = ua.sign ^ ub.sign;
            if let Some(bits) = special_product(fmt, pa, pb, &ua, &ub, sign, &mut flags) {
                out[i] = bits.as_u128();
                continue;
            }
            let na = ua.normalize(fmt);
            let nb = ub.normalize(fmt);
            self.sig_a.push(na.sig);
            self.sig_b.push(nb.sig);
            self.meta.push(LaneMeta { idx: i as u32, sign, exp_sum: na.exp + nb.exp });
        }

        // --- Stage 2: one lane-wise significand multiply per batch ------
        self.m.mul_sig_batch(&self.sig_a, &self.sig_b, fmt.sig_bits(), &mut self.prods);
        debug_assert_eq!(self.prods.len(), self.meta.len());

        // --- Stage 3: batched normalize/round/pack, scattered back ------
        for (meta, &prod) in self.meta.iter().zip(self.prods.iter()) {
            let mut ef = Flags::default();
            let bits = finish_product(fmt, meta.sign, meta.exp_sum, prod, mode, &mut ef);
            flags.merge(ef);
            out[meta.idx as usize] = bits.as_u128();
        }
        flags
    }

    /// Wide-operand twin of [`FpuBatch::mul_batch_bits`] for Fp256/Fp512:
    /// the same three stages over [`PackedBits`] operands, with the
    /// significand multiply going through
    /// [`SigBatchMultiplier::mul_sig_batch_wide`].
    ///
    /// # Panics
    ///
    /// Panics if `a` and `b` have different lengths.
    pub fn mul_batch_bits_wide(
        &mut self,
        fmt: &FpFormat,
        a: &[PackedBits],
        b: &[PackedBits],
        mode: RoundMode,
        out: &mut Vec<PackedBits>,
    ) -> Flags {
        assert_eq!(a.len(), b.len(), "operand length mismatch");
        assert!(a.len() <= u32::MAX as usize, "batch too large");
        out.clear();
        out.resize(a.len(), PackedBits::ZERO);
        self.sig_aw.clear();
        self.sig_bw.clear();
        self.meta.clear();
        let mut flags = Flags::default();

        // --- Stage 1: unpack/classify; specials to the scalar sidecar ---
        for (i, (&pa, &pb)) in a.iter().zip(b).enumerate() {
            let ua = fmt.unpack_g(pa);
            let ub = fmt.unpack_g(pb);
            let sign = ua.sign ^ ub.sign;
            if let Some(bits) = special_product_w(fmt, pa, pb, &ua, &ub, sign, &mut flags) {
                out[i] = bits;
                continue;
            }
            let na = ua.normalize(fmt);
            let nb = ub.normalize(fmt);
            self.sig_aw.push(na.sig);
            self.sig_bw.push(nb.sig);
            self.meta.push(LaneMeta { idx: i as u32, sign, exp_sum: na.exp + nb.exp });
        }

        // --- Stage 2: one batched wide significand multiply -------------
        self.m.mul_sig_batch_wide(&self.sig_aw, &self.sig_bw, fmt.sig_bits(), &mut self.prods_w);
        debug_assert_eq!(self.prods_w.len(), self.meta.len());

        // --- Stage 3: batched normalize/round/pack, scattered back ------
        for (meta, &prod) in self.meta.iter().zip(self.prods_w.iter()) {
            let mut ef = Flags::default();
            let bits = finish_product_w(fmt, meta.sign, meta.exp_sum, prod, mode, &mut ef);
            flags.merge(ef);
            out[meta.idx as usize] = bits;
        }
        flags
    }
}
